"""Pragma parsing: ``# reprolint: allow(<rule>[, <rule>]) — <reason>``.

A pragma suppresses matching violations on its own line and on the line
directly below (so it can ride at the end of the offending statement or
stand on its own line above it).  The reason is mandatory: a pragma is a
reviewed exemption from a protocol invariant, and "trust me" is not a
reason.  Reasonless pragmas surface as ``pragma-reason`` violations.

Comments are found with ``tokenize`` so strings that merely *contain*
pragma-looking text are never misread as pragmas.
"""
from __future__ import annotations

import io
import re
import tokenize
from dataclasses import dataclass, field

# "allow(rule-a, rule-b)" then a separator (em-dash / hyphens / colon)
# and the reason.  The separator is required so the reason is visibly a
# reason, not a trailing word soup.
_PRAGMA_RE = re.compile(
    r"#\s*reprolint:\s*allow\(\s*(?P<rules>[a-z0-9_,\s-]+?)\s*\)"
    r"\s*(?:(?:—|--+|-|:)\s*(?P<reason>.*\S))?\s*$")
# anything that says "reprolint:" but does not parse — flagged, because a
# silently ignored pragma is worse than none
_PRAGMA_LIKE_RE = re.compile(r"#\s*reprolint\s*:")


@dataclass
class Pragma:
    line: int
    rules: tuple[str, ...]
    reason: str
    used: bool = field(default=False, compare=False)


def scan_pragmas(source: str) -> tuple[dict[int, Pragma], list[tuple[int, str]]]:
    """Return ``{line: Pragma}`` plus ``(line, message)`` problems —
    malformed pragmas and pragmas missing their reason."""
    pragmas: dict[int, Pragma] = {}
    problems: list[tuple[int, str]] = []
    try:
        tokens = tokenize.generate_tokens(io.StringIO(source).readline)
        comments = [(tok.start[0], tok.string) for tok in tokens
                    if tok.type == tokenize.COMMENT]
    except (tokenize.TokenError, SyntaxError, IndentationError):
        return pragmas, problems   # the engine reports the parse error
    for line, text in comments:
        m = _PRAGMA_RE.search(text)
        if m is None:
            if _PRAGMA_LIKE_RE.search(text):
                problems.append(
                    (line, "unparseable reprolint pragma — expected "
                           "'# reprolint: allow(rule) — reason'"))
            continue
        rules = tuple(r.strip() for r in m.group("rules").split(",")
                      if r.strip())
        reason = (m.group("reason") or "").strip()
        if not rules:
            problems.append((line, "pragma allows no rules"))
            continue
        if not reason:
            problems.append(
                (line, f"pragma allow({', '.join(rules)}) has no reason — "
                       "a pragma is a reviewed exemption and must say why"))
            continue
        pragmas[line] = Pragma(line=line, rules=rules, reason=reason)
    return pragmas, problems


def find_pragma(pragmas: dict[int, Pragma], rule: str,
                line: int) -> Pragma | None:
    """The pragma governing a violation of ``rule`` at ``line``: same
    line, or the line directly above."""
    for ln in (line, line - 1):
        p = pragmas.get(ln)
        if p is not None and rule in p.rules:
            return p
    return None
