"""CLI:  python -m tools.reprolint [paths...] [options]

With no paths, lints the default roots (src/repro, tools, benchmarks).
With paths (pre-commit hands us changed files), reports only those files
— cross-file analysis still covers the whole tree so nothing is missed
for lack of context.

Exit codes: 0 clean, 1 violations (or parse errors), 2 usage error.
"""
from __future__ import annotations

import argparse
import sys
from pathlib import Path

from .engine import run, render_human, render_json
from .rules import ALL_RULES


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(
        prog="reprolint",
        description="AST-based recovery-invariant checker for this repo")
    ap.add_argument("paths", nargs="*",
                    help="files to report on (default: whole tree)")
    ap.add_argument("--root", default=None,
                    help="repo root (default: auto — the directory "
                         "containing tools/reprolint)")
    ap.add_argument("--json", action="store_true", dest="as_json",
                    help="machine-readable JSON on stdout (same shape "
                         "as benchmarks.diff --json)")
    ap.add_argument("--stats", action="store_true",
                    help="append pragma statistics (total, per rule, "
                         "unused) to the report")
    ap.add_argument("--list-rules", action="store_true",
                    help="print each rule and the invariant it protects")
    args = ap.parse_args(argv)

    if args.list_rules:
        for rule in ALL_RULES:
            print(f"{rule.name}\n    {rule.invariant}")
        return 0

    root = Path(args.root).resolve() if args.root else \
        Path(__file__).resolve().parents[2]
    try:
        report = run(root, paths=args.paths or None)
    except FileNotFoundError as exc:
        print(f"reprolint: {exc}", file=sys.stderr)
        return 2
    if args.as_json:
        print(render_json(report))
    else:
        print(render_human(report, stats=args.stats))
    return 0 if report.ok else 1


if __name__ == "__main__":
    sys.exit(main())
