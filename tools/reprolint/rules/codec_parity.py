"""codec-parity: every record kind and field survives the media codec.

The bug class this kills: add a field to a record dataclass in
``core/records.py`` (or a whole new ``RecKind``) and forget
``media/codec.py`` — every in-memory test stays green, and the field
silently vanishes on the first archive seal, to be discovered by a cold
restore that reconstructs the wrong state.  Cross-file checks:

  * every ``RecKind`` member maps to a class in ``REC_CLASSES``;
  * every mapped class has an ``isinstance`` branch in ``encode_record``
    and is constructed somewhere in the codec (the decode side);
  * every *comparable* dataclass field (``compare=False`` fields are
    derived memos, excluded from equality and from serialization on
    purpose) is read in its encode branch and written by decode.

A class whose ``kind`` property returns ``self.op`` gets ``op`` credit
from an access to ``.kind`` (the UPDATE/INSERT/DELETE family encodes the
op through the kind byte).
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Optional, Set, Tuple

from ..astutil import _walk_no_funcs, receiver_tail
from ..engine import FileCtx, Project, Rule, Violation

RECORDS_SUFFIX = "core/records.py"
CODEC_SUFFIX = "media/codec.py"


# ------------------------------------------------------- records.py side
def _field_is_comparable(value: Optional[ast.AST]) -> bool:
    """False when the default is ``field(..., compare=False)``."""
    if isinstance(value, ast.Call) and receiver_tail(value.func) == "field":
        for kw in value.keywords:
            if kw.arg == "compare" and isinstance(kw.value, ast.Constant) \
                    and kw.value.value is False:
                return False
    return True


class _RecordsInfo:
    def __init__(self) -> None:
        self.kinds: Dict[str, int] = {}            # RecKind member -> line
        self.mapping: Dict[str, str] = {}          # RecKind member -> class
        self.mapping_line = 0
        self.classes: Dict[str, Tuple[int, List[str]]] = {}  # name -> (line, fields)
        self.kind_returns_op: Set[str] = set()     # classes whose .kind is self.op


def _parse_records(tree: ast.AST) -> _RecordsInfo:
    info = _RecordsInfo()
    for node in tree.body:
        if isinstance(node, ast.ClassDef):
            bases = {receiver_tail(b) for b in node.bases}
            if node.name == "RecKind":
                for stmt in node.body:
                    if isinstance(stmt, ast.Assign) and \
                            isinstance(stmt.targets[0], ast.Name):
                        info.kinds[stmt.targets[0].id] = stmt.lineno
                continue
            if "LogRec" not in bases and node.name != "LogRec":
                continue
            fields: List[str] = []
            for stmt in node.body:
                if isinstance(stmt, ast.AnnAssign) and \
                        isinstance(stmt.target, ast.Name):
                    if _field_is_comparable(stmt.value):
                        fields.append(stmt.target.id)
                elif isinstance(stmt, ast.FunctionDef) and \
                        stmt.name == "kind":
                    for sub in ast.walk(stmt):
                        if isinstance(sub, ast.Return) and \
                                isinstance(sub.value, ast.Attribute) and \
                                sub.value.attr == "op":
                            info.kind_returns_op.add(node.name)
            if node.name != "LogRec":
                info.classes[node.name] = (node.lineno, fields)
        elif isinstance(node, (ast.Assign, ast.AnnAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            if any(isinstance(t, ast.Name) and t.id == "REC_CLASSES"
                   for t in targets) and isinstance(node.value, ast.Dict):
                info.mapping_line = node.lineno
                for k, v in zip(node.value.keys, node.value.values):
                    kname = receiver_tail(k) if k is not None else None
                    vname = receiver_tail(v)
                    if kname and vname:
                        info.mapping[kname] = vname
    return info


# --------------------------------------------------------- codec.py side
def _encode_accesses(tree: ast.AST
                     ) -> Tuple[Dict[str, Set[str]], Set[str], int]:
    """(per-class attribute reads inside its isinstance branch,
    function-wide reads on the record argument, def line) for
    ``encode_record``."""
    per_class: Dict[str, Set[str]] = {}
    everywhere: Set[str] = set()
    line = 0
    for node in ast.walk(tree):
        if not (isinstance(node, ast.FunctionDef)
                and node.name == "encode_record"):
            continue
        line = node.lineno
        arg = node.args.args[0].arg if node.args.args else "rec"

        def reads(n: ast.AST) -> Set[str]:
            return {s.attr for s in ast.walk(n)
                    if isinstance(s, ast.Attribute)
                    and isinstance(s.value, ast.Name)
                    and s.value.id == arg}

        def branch_classes(test: ast.AST) -> List[str]:
            for c in ast.walk(test):
                if isinstance(c, ast.Call) and \
                        receiver_tail(c.func) == "isinstance" and \
                        len(c.args) == 2:
                    t = c.args[1]
                    elts = t.elts if isinstance(t, ast.Tuple) else [t]
                    return [receiver_tail(e) for e in elts
                            if receiver_tail(e)]
            return []

        def visit(stmts: List[ast.stmt]) -> None:
            for stmt in stmts:
                if isinstance(stmt, ast.If):
                    classes = branch_classes(stmt.test)
                    got = reads(ast.Module(body=stmt.body,
                                           type_ignores=[]))
                    for cls in classes:
                        per_class.setdefault(cls, set()).update(got)
                    visit(stmt.orelse)
                else:
                    everywhere.update(reads(stmt))

        visit(node.body)
    return per_class, everywhere, line


def _decode_writes(tree: ast.AST) -> Dict[str, Set[str]]:
    """Per-class set of fields the decode side produces: constructor
    keywords anywhere, plus ``v.<attr> = ...`` stores on variables
    assigned from ``Cls.__new__`` within the same function."""
    writes: Dict[str, Set[str]] = {}
    class_names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.Call):
            name = receiver_tail(node.func)
            if name and name[:1].isupper() and name.endswith("Rec"):
                class_names.add(name)
                writes.setdefault(name, set()).update(
                    kw.arg for kw in node.keywords if kw.arg)
    # Cls.__new__ fast paths: var = Cls.__new__(Cls); var.f = ...
    for func in [n for n in ast.walk(tree)
                 if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))]:
        newvars: Dict[str, str] = {}
        for stmt in _walk_no_funcs(func):
            if isinstance(stmt, ast.Assign) and \
                    isinstance(stmt.value, ast.Call) and \
                    isinstance(stmt.value.func, ast.Attribute) and \
                    stmt.value.func.attr == "__new__":
                cls = receiver_tail(stmt.value.func.value)
                if cls and isinstance(stmt.targets[0], ast.Name):
                    newvars[stmt.targets[0].id] = cls
        if not newvars:
            continue
        for stmt in _walk_no_funcs(func):
            if isinstance(stmt, ast.Assign):
                for t in stmt.targets:
                    if isinstance(t, ast.Attribute) and \
                            isinstance(t.value, ast.Name) and \
                            t.value.id in newvars:
                        writes.setdefault(newvars[t.value.id],
                                          set()).add(t.attr)
    return writes


class CodecParityRule(Rule):
    name = "codec-parity"
    invariant = ("every RecKind and every comparable record field "
                 "round-trips through media/codec.py — nothing becomes "
                 "silently unarchivable")

    def finish(self, project: Project) -> Iterable[Violation]:
        records = project.find(RECORDS_SUFFIX)
        codec = project.find(CODEC_SUFFIX)
        if records is None or codec is None or \
                records.tree is None or codec.tree is None:
            return []   # mini-projects without the pair have no parity
        out: List[Violation] = []
        info = _parse_records(records.tree)
        enc_by_class, enc_everywhere, enc_line = \
            _encode_accesses(codec.tree)
        dec_writes = _decode_writes(codec.tree)

        for kind, line in info.kinds.items():
            if kind not in info.mapping:
                out.append(Violation(
                    self.name, records.path, line,
                    f"RecKind.{kind} has no REC_CLASSES entry — the codec "
                    "coverage contract cannot see it"))
        for kind, cls in info.mapping.items():
            if cls not in info.classes:
                out.append(Violation(
                    self.name, records.path, info.mapping_line,
                    f"REC_CLASSES maps RecKind.{kind} to unknown record "
                    f"class {cls}"))

        for cls in sorted(set(info.mapping.values())):
            line, fields = info.classes.get(cls, (0, []))
            if cls not in enc_by_class:
                out.append(Violation(
                    self.name, codec.path, enc_line or 1,
                    f"encode_record has no isinstance branch for {cls}"))
                continue
            if cls not in dec_writes:
                out.append(Violation(
                    self.name, codec.path, 1,
                    f"{cls} is never constructed in the codec — decode "
                    "cannot produce it"))
                continue
            enc = enc_by_class[cls] | enc_everywhere
            if "kind" in enc and cls in info.kind_returns_op:
                enc.add("op")   # the kind byte IS the op for this family
            dec = dec_writes[cls]
            if cls in info.kind_returns_op:
                dec.add("op")   # fast paths store op from the kind byte
            for f in fields:
                if f not in enc:
                    out.append(Violation(
                        self.name, records.path, line,
                        f"{cls}.{f} is never serialized in encode_record "
                        "— it would vanish on the first archive seal"))
                if f not in dec and f != "lsn":
                    # lsn is decoded generically before kind dispatch
                    out.append(Violation(
                        self.name, records.path, line,
                        f"{cls}.{f} is never reconstructed by the codec "
                        "decode side"))
        return out
