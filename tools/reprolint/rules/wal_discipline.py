"""wal-discipline: durable backend writes sit behind a stable-LSN check.

Logical recovery has no page LSNs on the log to detect a page that hit
media ahead of its log records — write-ahead ordering is enforced purely
by the convention that everything durable is derived from the *stable*
log prefix.  Concretely: any function that publishes bytes through a
``MediaBackend`` (``*.backend.put(...)``) must be governed by a
stable-LSN clamp (``stable_lsn`` / ``wal_lsn``), either in its own body
or in every in-project caller chain that can reach it.

The check is call-graph reachability over bare names (reprolint resolves
no types): a writer is *safe* when its body references the clamp, or
when every function that calls it is (recursively) safe.  A writer
reachable without passing a clamp — including a public entry point with
no in-project callers — is flagged.  Writes that are legitimately
outside WAL ordering (the master pointer, the archive-meta frontier)
carry pragmas stating exactly why.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterable, List, Set, Tuple

from ..astutil import (_walk_no_funcs, body_names, call_name, receiver_tail,
                       walk_functions)
from ..engine import Project, Rule, Violation

SRC_PREFIX = "src/repro/"
CLAMP_NAMES = {"stable_lsn", "_stable_lsn", "wal_lsn"}


def _writer_lines(func: ast.AST) -> List[int]:
    """Lines inside ``func`` that call ``<...>.backend.put(...)`` (the
    receiver chain must end in ``backend`` — ``page.put`` / ``btree.put``
    are tree mutations, not durable publication)."""
    lines = []
    for node in _walk_no_funcs(func):
        if isinstance(node, ast.Call) and \
                isinstance(node.func, ast.Attribute) and \
                node.func.attr == "put" and \
                receiver_tail(node.func.value) == "backend":
            lines.append(node.lineno)
    return lines


class WalDisciplineRule(Rule):
    name = "wal-discipline"
    invariant = ("every backend.put() is reachable only through a "
                 "stable-LSN clamp (WAL ordering has no page-LSN "
                 "runtime check to fall back on)")

    def finish(self, project: Project) -> Iterable[Violation]:
        # function table over src/repro: bare name -> [(path, qualname,
        # node)]; call edges by bare name
        funcs: List[Tuple[str, str, ast.AST]] = []
        for path, ctx in project.files.items():
            if ctx.tree is None or not path.startswith(SRC_PREFIX):
                continue
            for qual, node in walk_functions(ctx.tree):
                funcs.append((path, qual, node))

        by_bare: Dict[str, List[int]] = {}
        for i, (_, qual, _node) in enumerate(funcs):
            by_bare.setdefault(qual.rsplit(".", 1)[-1], []).append(i)

        checked: Set[int] = set()
        callers: Dict[int, Set[int]] = {i: set() for i in range(len(funcs))}
        for i, (_, _, node) in enumerate(funcs):
            names = body_names(node)
            if names & CLAMP_NAMES:
                checked.add(i)
            for sub in _walk_no_funcs(node):
                if isinstance(sub, ast.Call):
                    cname = call_name(sub)
                    if cname is None:
                        continue
                    for j in by_bare.get(cname, ()):
                        if j != i:
                            callers[j].add(i)

        # safe = clamp in body, or every caller safe (cycles -> unsafe)
        memo: Dict[int, bool] = {}

        def safe(i: int, stack: Set[int]) -> bool:
            if i in memo:
                return memo[i]
            if i in checked:
                memo[i] = True
                return True
            if i in stack or not callers[i]:
                return False        # cycle or uncalled public entry
            stack.add(i)
            ok = all(safe(c, stack) for c in callers[i])
            stack.discard(i)
            memo[i] = ok
            return ok

        out: List[Violation] = []
        for i, (path, qual, node) in enumerate(funcs):
            lines = _writer_lines(node)
            if not lines or safe(i, set()):
                continue
            for line in lines:
                out.append(Violation(
                    self.name, path, line,
                    f"{qual} publishes to a backend but no stable-LSN "
                    "clamp governs it (not in its body, not on every "
                    "caller path) — gate it or pragma the protocol "
                    "reason"))
        return out
