"""metric-name: registry names are canonical and kind-stable.

The metrics registry keys everything by flat name + labels; nothing
validates the names at runtime beyond kind conflicts *on the same
process* — two call sites registering ``repl.lag`` as a gauge and
``repl_lag`` as a counter would just coexist as two metrics and every
dashboard/bench assertion quietly reads the wrong one.  Checked:

  * literal names match ``subsystem.noun(.noun)*`` —
    ``^[a-z][a-z0-9_]*(\\.[a-z0-9_]+)+$`` (≥ 2 dot-separated segments,
    lower_snake each);
  * label keys are ``lower_snake`` identifiers;
  * a name keeps one kind (counter/gauge/histogram) across every call
    site in the tree — cross-file, because the registry only sees one
    process at a time but the tree is forever;
  * contract names with a documented kind (the commit-to-visible
    histogram, the recovery progress/ETA gauges) register with exactly
    that kind — these are the metrics external dashboards key on, so a
    same-kind-everywhere drift (e.g. everyone agreeing on a gauge) would
    pass the cross-file check while silently breaking the contract.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, Iterable, List, Tuple

from ..astutil import const_str, receiver_tail
from ..engine import FileCtx, Project, Rule, Violation

NAME_RE = re.compile(r"^[a-z][a-z0-9_]*(\.[a-z0-9_]+)+$")
LABEL_RE = re.compile(r"^[a-z][a-z0-9_]*$")
KINDS = {"counter", "gauge", "histogram"}
#: receivers that are (aliases of) the metrics registry at call sites
REGISTRY_NAMES = {"metrics", "_metrics", "obs_metrics", "REGISTRY",
                  "registry", "reg"}
#: the registry implementation itself defines the accessors — skip it
IMPL_SUFFIX = "obs/metrics.py"
#: contract metrics: documented names that external consumers (dashboards,
#: bench assertions, the post-mortem renderer) key on with a fixed kind.
#: The cross-file check alone can't catch everyone drifting to the same
#: wrong kind, so these are pinned here.
WELL_KNOWN_KINDS = {
    "repl.commit_to_visible_ms": "histogram",
    "repl.c2v.ship_wait_ms": "histogram",
    "repl.c2v.queue_wait_ms": "histogram",
    "repl.c2v.apply_ms": "histogram",
    "recovery.progress": "gauge",
    "recovery.eta_ms": "gauge",
}


def _metric_calls(ctx: FileCtx) -> Iterable[Tuple[str, str, ast.Call]]:
    """(kind, literal-name, call) for registry accessor calls with a
    string-literal name.  Dynamic names (``reg.gauge(name)`` in the
    dataclass bridge) are invisible to static checking and skipped."""
    for node in ast.walk(ctx.tree):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in KINDS
                and receiver_tail(node.func.value) in REGISTRY_NAMES
                and node.args):
            continue
        name = const_str(node.args[0])
        if name is not None:
            yield node.func.attr, name, node


class MetricNamingRule(Rule):
    name = "metric-name"
    invariant = ("metric names are subsystem.noun(.noun)* with "
                 "lower_snake labels, each name keeps one kind "
                 "(counter/gauge/histogram) across all call sites, and "
                 "contract names register with their documented kind")

    def check_file(self, ctx: FileCtx) -> Iterable[Violation]:
        if ctx.tree is None or ctx.path.endswith(IMPL_SUFFIX):
            return []
        out: List[Violation] = []
        for kind, name, node in _metric_calls(ctx):
            pinned = WELL_KNOWN_KINDS.get(name)
            if pinned is not None and kind != pinned:
                out.append(Violation(
                    self.name, ctx.path, node.lineno,
                    f"contract metric {name!r} registered as {kind} but is "
                    f"documented as a {pinned} — external consumers key on "
                    "that kind"))
            if not NAME_RE.match(name):
                out.append(Violation(
                    self.name, ctx.path, node.lineno,
                    f"metric name {name!r} is not subsystem.noun(.noun)* "
                    "(lower_snake segments, at least one dot)"))
            for kw in node.keywords:
                if kw.arg is not None and not LABEL_RE.match(kw.arg):
                    out.append(Violation(
                        self.name, ctx.path, node.lineno,
                        f"metric label {kw.arg!r} on {name!r} is not a "
                        "lower_snake identifier"))
        return out

    def finish(self, project: Project) -> Iterable[Violation]:
        first: Dict[str, Tuple[str, str, int]] = {}   # name -> kind, path, line
        out: List[Violation] = []
        for path, ctx in sorted(project.files.items()):
            if ctx.tree is None or path.endswith(IMPL_SUFFIX):
                continue
            for kind, name, node in _metric_calls(ctx):
                seen = first.get(name)
                if seen is None:
                    first[name] = (kind, path, node.lineno)
                elif seen[0] != kind:
                    out.append(Violation(
                        self.name, path, node.lineno,
                        f"metric {name!r} registered as {kind} here but "
                        f"as {seen[0]} at {seen[1]}:{seen[2]} — one name, "
                        "one kind"))
        return out
