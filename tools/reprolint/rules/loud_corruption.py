"""loud-corruption: corruption is always loud, broad catches are reviewed.

The media layer's contract (PR 4) is that a torn frame, a bad CRC or an
unknown format version *always* raises — decoding never returns a short
stream, scans never silently skip.  One careless ``except`` anywhere on
the recovery path voids that contract, so:

  * an ``except`` clause that names a corruption error (or, inside the
    recovery engine, one of its bases) must re-raise;
  * inside the engine dirs (core/ media/ archive/ replication/) ANY
    bare/broad except needs a pragma, even if it re-raises — a broad
    catch there runs cleanup code in contexts its author never
    enumerated, and the pragma records the protocol reason;
  * elsewhere under src/repro a broad except that re-raises is fine
    (cleanup-and-propagate), but one that swallows needs a pragma.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from ..astutil import contains_raise, exception_names
from ..engine import FileCtx, Rule, Violation

CORRUPTION_ERRORS = {"CorruptSegmentError", "UnknownFormatError",
                     "TruncatedLogError"}
#: bases of the corruption errors — catching these inside the engine
#: swallows corruption just as surely (TruncatedLogError is a
#: LookupError; CorruptSegmentError/UnknownFormatError are RuntimeErrors)
CORRUPTION_BASES = {"RuntimeError", "LookupError"}
BROAD = {"Exception", "BaseException"}

ENGINE_DIRS = ("src/repro/core/", "src/repro/media/",
               "src/repro/archive/", "src/repro/replication/",
               "src/repro/faults/")
SRC_PREFIX = "src/repro/"


class LoudCorruptionRule(Rule):
    name = "loud-corruption"
    invariant = ("CorruptSegmentError / UnknownFormatError / "
                 "TruncatedLogError are never swallowed; broad excepts "
                 "on the recovery engine carry a reviewed pragma")

    def check_file(self, ctx: FileCtx) -> Iterable[Violation]:
        if ctx.tree is None or not ctx.path.startswith(SRC_PREFIX):
            return []
        in_engine = ctx.in_dir(*ENGINE_DIRS)
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            names = exception_names(node)
            caught_corruption = (
                set(names) & CORRUPTION_ERRORS
                or (in_engine and set(names) & CORRUPTION_BASES))
            reraises = contains_raise(
                ast.Module(body=node.body, type_ignores=[]))
            if caught_corruption and not reraises:
                out.append(Violation(
                    self.name, ctx.path, node.lineno,
                    f"except clause catches "
                    f"{', '.join(sorted(caught_corruption))} without "
                    "re-raising — corruption must stay loud"))
                continue
            broad = (node.type is None) or (set(names) & BROAD)
            if not broad:
                continue
            what = ", ".join(names) if names else "bare except"
            if in_engine:
                out.append(Violation(
                    self.name, ctx.path, node.lineno,
                    f"broad except ({what}) on a recovery-engine path — "
                    "narrow it to the exceptions the protocol expects, "
                    "or pragma it with the protocol reason"))
            elif not reraises:
                out.append(Violation(
                    self.name, ctx.path, node.lineno,
                    f"broad except ({what}) swallows exceptions — narrow "
                    "it, re-raise, or pragma it with a reason"))
        return out
