"""retry-discipline: transient errors retry through policy, nothing else.

The fault layer's classification contract (PR 10) has exactly one
retryable class: ``BackendUnavailableError`` (base
``TransientMediaError``) — the backend *did nothing*, so re-issuing the
call is safe.  Corruption errors mean the backend *returned damaged
bytes*, and retrying those either loops forever or, worse, papers over
a real torn write.  Two ways code drifts off that contract:

  * one ``except`` clause catching a transient error *together with* a
    corruption error (or a broad base) — the handler body necessarily
    treats "retry me" and "stop everything" the same way;
  * a hand-rolled retry loop: ``except BackendUnavailableError`` inside
    a ``while`` with no ``RetryPolicy`` in sight.  Unbounded hand-rolled
    loops spin forever through a dead backend and, without the seeded
    backoff, make fault campaigns non-reproducible.  ``for`` loops are
    exempt — iterating items and degrading per item (the background
    flusher idiom) is bounded by construction.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from ..astutil import body_names, enclosing_function, exception_names
from ..engine import FileCtx, Rule, Violation

TRANSIENT = {"BackendUnavailableError", "TransientMediaError"}
#: never-retry classes (and their shared base): one handler must not
#: treat these and a transient outage alike
NON_RETRYABLE = {"CorruptSegmentError", "UnknownFormatError",
                 "TruncatedLogError", "PageCorruptError", "MediaError",
                 "Exception", "BaseException"}
#: a function that constructs/receives a RetryPolicy or calls its
#: seeded backoff is using the sanctioned machinery, not hand-rolling
POLICY_MARKERS = {"RetryPolicy", "backoff"}

SRC_PREFIX = "src/repro/"


class RetryDisciplineRule(Rule):
    name = "retry-discipline"
    invariant = ("only BackendUnavailableError is retryable, and retry "
                 "loops go through the seeded RetryPolicy — never a "
                 "hand-rolled while, never mixed with corruption errors")

    def check_file(self, ctx: FileCtx) -> Iterable[Violation]:
        if ctx.tree is None or not ctx.path.startswith(SRC_PREFIX):
            return []
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if not isinstance(node, ast.ExceptHandler):
                continue
            names = set(exception_names(node))
            transient = names & TRANSIENT
            if not transient:
                continue
            mixed = names & NON_RETRYABLE
            if mixed:
                out.append(Violation(
                    self.name, ctx.path, node.lineno,
                    f"one handler catches {', '.join(sorted(transient))} "
                    f"together with {', '.join(sorted(mixed))} — a "
                    "transient outage retries, corruption never does; "
                    "classify them in separate handlers"))
                continue
            if self._in_while(node, ctx.parents):
                func = enclosing_function(node, ctx.parents)
                markers = body_names(func) if func is not None else set()
                if not markers & POLICY_MARKERS:
                    out.append(Violation(
                        self.name, ctx.path, node.lineno,
                        "hand-rolled retry loop: "
                        f"{', '.join(sorted(transient))} caught inside a "
                        "while loop with no RetryPolicy — unbounded spins "
                        "and unseeded waits break fault-campaign "
                        "reproducibility; use faults.RetryPolicy"))
        return out

    @staticmethod
    def _in_while(node: ast.AST, parents: dict) -> bool:
        """Is the handler inside a ``while`` within the same function?"""
        cur = parents.get(node)
        while cur is not None:
            if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef,
                                ast.Lambda)):
                return False
            if isinstance(cur, ast.While):
                return True
            cur = parents.get(cur)
        return False
