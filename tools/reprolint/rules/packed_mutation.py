"""packed-mutation: direct container writes on a Page pair with an
invalidating mutator.

Packed pages (``core/pages.py``) cache derived state keyed off the
mutable containers: the serialized bytes (``_raw``), the sorted leaf
view (``_sorted``) and the incremental payload size (``_payload``).
The sanctioned mutators — ``put`` / ``delete`` / the property *setters*
(whole-container assignment) — maintain or drop those caches.  A direct
in-place write (``page.records[k] = v``, ``node.keys.append(...)``)
bypasses them: the page keeps serving the stale packed bytes or sorted
view, which is a silent-corruption bug — reads disagree with writes and
the next flush persists the pre-write image.

The rule flags, inside the engine core (``src/repro/core/``, excluding
``pages.py`` itself, which owns the caches), every in-place mutation of
a ``.records`` / ``.keys`` / ``.children`` attribute: subscript stores
and deletes, and mutating container-method calls.  A flagged site is
safe when the enclosing function also calls ``invalidate_sorted()`` /
``put()`` / ``delete()`` on the *same receiver* (matched by dotted-name
text); when the receiver is not a plain dotted name, any
``invalidate_sorted()`` call in the function counts.
"""
from __future__ import annotations

import ast
from typing import Iterable, List, Optional, Tuple

from ..astutil import _walk_no_funcs, enclosing_function
from ..engine import FileCtx, Rule, Violation

CORE_PREFIX = "src/repro/core/"
OWNER_FILE = "pages.py"

CONTAINERS = frozenset({"records", "keys", "children"})
MUTATORS = frozenset({"append", "insert", "pop", "clear", "update",
                      "setdefault", "extend", "remove", "sort",
                      "reverse", "popitem"})
SAFE_CALLS = frozenset({"invalidate_sorted", "put", "delete"})


def _dotted(node: ast.AST) -> Optional[str]:
    """``self.btree.root`` -> ``"self.btree.root"``; None when the chain
    bottoms out in a call/subscript."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def _container_attr(node: ast.AST) -> Optional[Tuple[Optional[str], str]]:
    """(receiver dotted name, container attr) when ``node`` is
    ``<recv>.records`` / ``.keys`` / ``.children``."""
    if isinstance(node, ast.Attribute) and node.attr in CONTAINERS:
        return _dotted(node.value), node.attr
    return None


def _mutations(tree: ast.AST):
    """Yield (node, receiver, container, verb) for every in-place write."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.Assign, ast.AugAssign)):
            targets = node.targets if isinstance(node, ast.Assign) \
                else [node.target]
            for t in targets:
                if isinstance(t, ast.Subscript):
                    hit = _container_attr(t.value)
                    if hit is not None:
                        yield node, hit[0], hit[1], "subscript store"
        elif isinstance(node, ast.Delete):
            for t in node.targets:
                if isinstance(t, ast.Subscript):
                    hit = _container_attr(t.value)
                    if hit is not None:
                        yield node, hit[0], hit[1], "subscript delete"
        elif isinstance(node, ast.Call) \
                and isinstance(node.func, ast.Attribute) \
                and node.func.attr in MUTATORS:
            hit = _container_attr(node.func.value)
            if hit is not None:
                yield node, hit[0], hit[1], f".{node.func.attr}() call"


def _has_safe_call(scope: ast.AST, recv: Optional[str]) -> bool:
    for node in _walk_no_funcs(scope):
        if not (isinstance(node, ast.Call)
                and isinstance(node.func, ast.Attribute)
                and node.func.attr in SAFE_CALLS):
            continue
        if recv is None:
            if node.func.attr == "invalidate_sorted":
                return True
            continue
        if _dotted(node.func.value) == recv:
            return True
    return False


class PackedMutationRule(Rule):
    name = "packed-mutation"
    invariant = ("in-place writes to Page.records/keys/children outside "
                 "pages.py pair with an invalidating mutator (put / delete "
                 "/ invalidate_sorted) on the same receiver — stale packed "
                 "bytes or sorted views must never survive a write")

    def check_file(self, ctx: FileCtx) -> Iterable[Violation]:
        if ctx.tree is None or not ctx.path.startswith(CORE_PREFIX) \
                or ctx.path.endswith(OWNER_FILE):
            return []
        out: List[Violation] = []
        for node, recv, container, verb in _mutations(ctx.tree):
            scope = enclosing_function(node, ctx.parents)
            if scope is not None and _has_safe_call(scope, recv):
                continue
            who = recv or "<expr>"
            out.append(Violation(
                self.name, ctx.path, node.lineno,
                f"in-place {verb} on {who}.{container} with no "
                f"invalidating mutator ({who}.invalidate_sorted() / "
                f".put() / .delete()) in this function — the page's "
                "packed bytes and sorted cache go stale silently"))
        return out
