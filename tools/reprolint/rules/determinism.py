"""determinism: the recovery engine computes the same state every run.

Every correctness test in this repo is an oracle test: recover /
restore / apply, then compare against ``committed_state_oracle``.  That
methodology (and crash-replay debugging, and the log-shipping contract
— a replica re-executes the primary's stream and must land on identical
state) only works if the engine is a pure function of the log.  Wall
clocks and unseeded randomness are how that dies, one "harmless"
timestamp at a time.

Inside ``core/ media/ archive/ replication/``, flagged:

  * ``time.time`` / ``time.time_ns`` (``perf_counter`` for *measuring*
    is fine — timings are reported, never used to compute state);
  * ``datetime.now`` / ``utcnow`` / ``today``;
  * importing the stdlib ``random`` module at all — even unused, it is
    an attractive nuisance on the engine (``jax.random`` is keyed and
    explicit, and lives outside these dirs anyway).
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from ..engine import FileCtx, Rule, Violation

ENGINE_DIRS = ("src/repro/core/", "src/repro/media/",
               "src/repro/archive/", "src/repro/replication/",
               "src/repro/faults/")
WALL_CLOCK = {("time", "time"), ("time", "time_ns")}
DATETIME_NOW = {("datetime", "now"), ("datetime", "utcnow"),
                ("datetime", "today")}


class DeterminismRule(Rule):
    name = "determinism"
    invariant = ("no wall clocks or unseeded randomness in the recovery "
                 "engine — recovered state is a pure function of the "
                 "log, which is what every oracle test asserts")

    def check_file(self, ctx: FileCtx) -> Iterable[Violation]:
        if ctx.tree is None or not ctx.in_dir(*ENGINE_DIRS):
            return []
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, ast.Import):
                for alias in node.names:
                    if alias.name == "random":
                        out.append(Violation(
                            self.name, ctx.path, node.lineno,
                            "stdlib `random` imported on the recovery "
                            "engine — unseeded randomness breaks oracle "
                            "equality; if you need randomness here, "
                            "thread an explicit seed"))
            elif isinstance(node, ast.ImportFrom):
                if node.module == "random":
                    out.append(Violation(
                        self.name, ctx.path, node.lineno,
                        "stdlib `random` imported on the recovery "
                        "engine — unseeded randomness breaks oracle "
                        "equality"))
            elif isinstance(node, ast.Attribute) and \
                    isinstance(node.value, ast.Name):
                pair = (node.value.id, node.attr)
                if pair in WALL_CLOCK:
                    out.append(Violation(
                        self.name, ctx.path, node.lineno,
                        "time.time on the recovery engine — state must "
                        "be a function of the log, not the clock "
                        "(perf_counter is fine for measuring)"))
                elif pair in DATETIME_NOW:
                    out.append(Violation(
                        self.name, ctx.path, node.lineno,
                        f"datetime.{node.attr} on the recovery engine — "
                        "state must be a function of the log"))
        return out
