"""tracer-guard: disabled tracing must cost nothing on hot paths.

``Tracer.event`` returns immediately when disabled — but the *caller*
has already built the kwargs dict by then.  PR 6's CI-asserted probe
bound (≤5% on batched Log1 redo) only holds because every per-record
probe is written as::

    if TRACER.enabled:
        TRACER.event("io.demand", pid=pid, ...)

This rule pins the idiom: any ``<tracer>.event(...)`` call that passes
keyword arguments must sit under an ``if ... .enabled`` guard in the
same function.  (Spans are exempt: ``TRACER.span`` is per-phase, not
per-record, and returns a shared null span when disabled.)

The flight recorder (PR 8) is held to a stricter form of the same
budget: it has no disabled state to guard on, so every
``<flight>.record(...)`` call must be the compact positional-tuple
form — a literal kind string plus plain numbers.  Keyword arguments,
f-strings, dict/set/list displays, or comprehensions at the call site
would allocate on the always-on path and erode the CI-asserted ≤5%
flight-recorder bound.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from ..astutil import receiver_tail, under_enabled_guard
from ..engine import FileCtx, Rule, Violation

SRC_PREFIX = "src/repro/"
TRACER_NAMES = {"TRACER", "_TRACER", "tracer", "_tracer"}
FLIGHT_NAMES = {"FLIGHT", "_FLIGHT", "flight", "_flight"}
#: argument constructs that allocate/format on the always-on hot path
_FLIGHT_BANNED = (ast.JoinedStr, ast.Dict, ast.DictComp, ast.List,
                  ast.ListComp, ast.Set, ast.SetComp, ast.GeneratorExp)


class TracerGuardRule(Rule):
    name = "tracer-guard"
    invariant = ("tracer .event(kwargs) calls sit under `if "
                 "TRACER.enabled` so disabled probes never build the "
                 "kwargs dict, and always-on FLIGHT.record calls stay "
                 "compact positional tuples (no f-strings/dicts/kwargs)")

    def check_file(self, ctx: FileCtx) -> Iterable[Violation]:
        if ctx.tree is None or not ctx.path.startswith(SRC_PREFIX):
            return []
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)):
                continue
            tail = receiver_tail(node.func.value)
            if (node.func.attr == "event" and tail in TRACER_NAMES
                    and node.keywords):
                if under_enabled_guard(node, ctx.parents):
                    continue
                out.append(Violation(
                    self.name, ctx.path, node.lineno,
                    "tracer event with kwargs outside an `if "
                    "TRACER.enabled` guard — the kwargs dict is built even "
                    "when tracing is off"))
            elif node.func.attr == "record" and tail in FLIGHT_NAMES:
                if node.keywords:
                    out.append(Violation(
                        self.name, ctx.path, node.lineno,
                        "flight-recorder record() call passes keywords — "
                        "the always-on hot path takes the compact "
                        "positional form record(kind, a, b, c)"))
                elif any(isinstance(sub, _FLIGHT_BANNED)
                         for arg in node.args for sub in ast.walk(arg)):
                    out.append(Violation(
                        self.name, ctx.path, node.lineno,
                        "flight-recorder record() argument builds an "
                        "f-string/dict/comprehension — the always-on hot "
                        "path takes plain numbers and a literal kind"))
        return out
