"""tracer-guard: disabled tracing must cost nothing on hot paths.

``Tracer.event`` returns immediately when disabled — but the *caller*
has already built the kwargs dict by then.  PR 6's CI-asserted probe
bound (≤5% on batched Log1 redo) only holds because every per-record
probe is written as::

    if TRACER.enabled:
        TRACER.event("io.demand", pid=pid, ...)

This rule pins the idiom: any ``<tracer>.event(...)`` call that passes
keyword arguments must sit under an ``if ... .enabled`` guard in the
same function.  (Spans are exempt: ``TRACER.span`` is per-phase, not
per-record, and returns a shared null span when disabled.)
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from ..astutil import receiver_tail, under_enabled_guard
from ..engine import FileCtx, Rule, Violation

SRC_PREFIX = "src/repro/"
TRACER_NAMES = {"TRACER", "_TRACER", "tracer", "_tracer"}


class TracerGuardRule(Rule):
    name = "tracer-guard"
    invariant = ("tracer .event(kwargs) calls sit under `if "
                 "TRACER.enabled` so disabled probes never build the "
                 "kwargs dict (the PR-6 probe-overhead bound)")

    def check_file(self, ctx: FileCtx) -> Iterable[Violation]:
        if ctx.tree is None or not ctx.path.startswith(SRC_PREFIX):
            return []
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call)
                    and isinstance(node.func, ast.Attribute)
                    and node.func.attr == "event"
                    and receiver_tail(node.func.value) in TRACER_NAMES
                    and node.keywords):
                continue
            if under_enabled_guard(node, ctx.parents):
                continue
            out.append(Violation(
                self.name, ctx.path, node.lineno,
                "tracer event with kwargs outside an `if "
                "TRACER.enabled` guard — the kwargs dict is built even "
                "when tracing is off"))
        return out
