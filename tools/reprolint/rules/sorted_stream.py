"""sorted-stream: batched-apply call sites prove their ordering.

``DataComponent.apply_batch`` and ``tc.apply_shipped_batch`` are only
correct for streams whose *per-key LSN order* is preserved: the engines
run a stable sort keyed on the composite key alone, so records must
arrive in stream (LSN) order or per-key order is scrambled and redo
re-executes history out of order (exactly-once apply breaks silently —
absolute after-images make most scrambles invisible to tests).

The rule makes every call site carry its proof: either a ``sort`` /
``sorted`` of the stream lexically dominates the call in the same
function, or the site carries a pragma stating why the stream is
already LSN-ordered (log-scan windows, commit-ordered buffers, ...).
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from ..astutil import (_walk_no_funcs, call_name, enclosing_function,
                       receiver_tail)
from ..engine import FileCtx, Rule, Violation

SRC_PREFIX = "src/repro/"


def _is_batched_apply(call: ast.Call) -> bool:
    if not isinstance(call.func, ast.Attribute):
        return False
    attr = call.func.attr
    if attr == "apply_shipped_batch":
        return True
    # plain .apply_batch exists on Replica/ShardedApplier too (ship-batch
    # ingest, no ordering precondition) — only the DC engine is gated
    return attr == "apply_batch" and \
        receiver_tail(call.func.value) == "dc"


def _sort_before(func: ast.AST, line: int) -> bool:
    for node in _walk_no_funcs(func):
        if isinstance(node, ast.Call) and node.lineno <= line:
            name = call_name(node)
            if name in ("sorted", "sort"):
                return True
    return False


class SortedStreamRule(Rule):
    name = "sorted-stream"
    invariant = ("streams handed to the batched apply engines are "
                 "LSN-ordered — proven by a dominating sort or a pragma "
                 "naming the ordering source")

    def check_file(self, ctx: FileCtx) -> Iterable[Violation]:
        if ctx.tree is None or not ctx.path.startswith(SRC_PREFIX):
            return []
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if not (isinstance(node, ast.Call) and _is_batched_apply(node)):
                continue
            func = enclosing_function(node, ctx.parents)
            if func is not None and _sort_before(func, node.lineno):
                continue
            target = node.func.attr   # type: ignore[union-attr]
            out.append(Violation(
                self.name, ctx.path, node.lineno,
                f"{target}() call with no dominating sort in this "
                "function — sort the stream here, or pragma the reason "
                "it is already LSN-ordered"))
        return out
