"""dataclass-hygiene: no shared mutable defaults; memo fields stay out
of equality.

Two sharp edges this codebase has already cut itself on:

  * a mutable default argument (``def f(x=[])``) is one object shared
    across calls — on an engine whose objects live as long as a
    database, the aliasing bug surfaces far from the definition;
  * record dataclasses carry *derived memo* fields (``UpdateRec.ck``,
    the cached composite key, marked ``repr=False``).  If such a field
    participates in ``__eq__``, codec round-trip equality breaks the
    moment one side has warmed its memo and the other has not — the
    property tests compare decoded records against originals, so a
    missing ``compare=False`` turns a cache into a correctness bug.
    Rule: a ``field(repr=False, ...)`` on a dataclass must also say
    ``compare=False``.
"""
from __future__ import annotations

import ast
from typing import Iterable, List

from ..astutil import decorator_names, receiver_tail
from ..engine import FileCtx, Rule, Violation

MUTABLE_CALLS = {"list", "dict", "set", "bytearray"}


def _is_mutable_literal(node: ast.AST) -> bool:
    if isinstance(node, (ast.List, ast.Dict, ast.Set)):
        return True
    if isinstance(node, ast.Call) and isinstance(node.func, ast.Name) \
            and node.func.id in MUTABLE_CALLS and not node.args \
            and not node.keywords:
        return True
    return False


class DataclassHygieneRule(Rule):
    name = "dataclass-hygiene"
    invariant = ("no mutable default arguments; dataclass memo fields "
                 "(repr=False) set compare=False so codec round-trip "
                 "equality ignores caches")

    def check_file(self, ctx: FileCtx) -> Iterable[Violation]:
        if ctx.tree is None:
            return []
        out: List[Violation] = []
        for node in ast.walk(ctx.tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                defaults = list(node.args.defaults) + \
                    [d for d in node.args.kw_defaults if d is not None]
                for d in defaults:
                    if _is_mutable_literal(d):
                        out.append(Violation(
                            self.name, ctx.path, d.lineno,
                            f"mutable default argument in {node.name}() — "
                            "one shared object across every call; use "
                            "None and create it inside"))
            elif isinstance(node, ast.ClassDef) and \
                    "dataclass" in decorator_names(node):
                for stmt in node.body:
                    if not (isinstance(stmt, ast.AnnAssign)
                            and isinstance(stmt.value, ast.Call)
                            and receiver_tail(stmt.value.func) == "field"):
                        continue
                    kwargs = {kw.arg: kw.value
                              for kw in stmt.value.keywords if kw.arg}
                    repr_off = isinstance(kwargs.get("repr"), ast.Constant) \
                        and kwargs["repr"].value is False
                    compare_off = isinstance(kwargs.get("compare"),
                                             ast.Constant) \
                        and kwargs["compare"].value is False
                    if repr_off and not compare_off:
                        fname = getattr(stmt.target, "id", "?")
                        out.append(Violation(
                            self.name, ctx.path, stmt.lineno,
                            f"dataclass memo field {fname!r} is "
                            "repr=False but not compare=False — a warm "
                            "cache would break round-trip equality"))
                    default = kwargs.get("default")
                    if default is not None and _is_mutable_literal(default):
                        out.append(Violation(
                            self.name, ctx.path, stmt.lineno,
                            "mutable field(default=...) — use "
                            "default_factory"))
        return out
