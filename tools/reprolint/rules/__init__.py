"""Rule registry.  Adding a rule: write the module, list the class here,
add a failing + passing + pragma'd fixture trio in
``tests/test_reprolint.py``, and a row in the README table.  CI's
meta-test keeps the live tree violation-free, so land the rule and its
true-positive fixes in the same change."""
from .codec_parity import CodecParityRule
from .dataclass_hygiene import DataclassHygieneRule
from .determinism import DeterminismRule
from .loud_corruption import LoudCorruptionRule
from .metric_naming import MetricNamingRule
from .packed_mutation import PackedMutationRule
from .retry_discipline import RetryDisciplineRule
from .sorted_stream import SortedStreamRule
from .tracer_guard import TracerGuardRule
from .wal_discipline import WalDisciplineRule

ALL_RULES = (
    CodecParityRule,
    LoudCorruptionRule,
    WalDisciplineRule,
    RetryDisciplineRule,
    SortedStreamRule,
    PackedMutationRule,
    TracerGuardRule,
    MetricNamingRule,
    DeterminismRule,
    DataclassHygieneRule,
)

__all__ = ["ALL_RULES"] + [r.__name__ for r in ALL_RULES]
