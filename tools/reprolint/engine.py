"""reprolint driver: file discovery, rule dispatch, pragma suppression,
human/JSON output, exit-code gating.

Two pass shapes:

  per-file    ``Rule.check_file(ctx)`` sees one parsed file at a time.
  cross-file  ``Rule.finish(project)`` runs after every file is parsed
              and may correlate files (codec parity, call-graph WAL
              reachability, metric-kind consistency).

Cross-file rules always analyse the *full* default tree even when the
CLI selects a subset of files (pre-commit hands us only what changed);
their findings are then filtered to the selection.  Analysing a subset
would manufacture false positives — a write whose stable-LSN check
lives in an unselected caller would look unguarded.
"""
from __future__ import annotations

import ast
import json
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, Iterable, List, Optional, Sequence

from .pragmas import Pragma, find_pragma, scan_pragmas

#: scanned when no explicit paths are given; tests/ is deliberately out
#: (rule fixtures there must be able to violate on purpose)
DEFAULT_ROOTS = ("src/repro", "tools", "benchmarks")

_SKIP_DIRS = {"__pycache__", ".git", ".pytest_cache", "artifacts"}


@dataclass
class Violation:
    rule: str
    path: str                  # repo-relative, posix separators
    line: int
    message: str
    suppressed: bool = False
    reason: str = ""           # pragma reason when suppressed

    def format(self) -> str:
        return f"{self.path}:{self.line}: [{self.rule}] {self.message}"

    def to_json(self) -> dict:
        out = {"rule": self.rule, "path": self.path, "line": self.line,
               "message": self.message}
        if self.suppressed:
            out["suppressed"] = True
            out["reason"] = self.reason
        return out


class FileCtx:
    """One parsed file: source, AST, pragmas, lazy parent map."""

    def __init__(self, path: str, source: str):
        self.path = path
        self.source = source
        self.tree: Optional[ast.AST] = None
        self.parse_error: Optional[str] = None
        try:
            self.tree = ast.parse(source)
        except (SyntaxError, ValueError) as exc:
            self.parse_error = str(exc)
        self.pragmas, self.pragma_problems = scan_pragmas(source)
        self._parents: Optional[dict] = None

    @property
    def parents(self) -> dict:
        if self._parents is None:
            from .astutil import build_parents
            self._parents = build_parents(self.tree) if self.tree else {}
        return self._parents

    def in_dir(self, *prefixes: str) -> bool:
        return any(self.path.startswith(p) for p in prefixes)


class Project:
    """All parsed files plus the root they are relative to."""

    def __init__(self, root: Path, files: Dict[str, FileCtx]):
        self.root = root
        self.files = files

    def find(self, suffix: str) -> Optional[FileCtx]:
        """The unique file whose path ends with ``suffix`` (anchor files
        for cross-file rules — suffix-matched so test fixtures can live
        under a tmp root with the same layout)."""
        hits = [c for p, c in self.files.items() if p.endswith(suffix)]
        return hits[0] if len(hits) == 1 else None


class Rule:
    """Base rule.  ``name`` is the pragma token; ``invariant`` is the
    one-line statement of what the rule protects (surfaced in --list-rules
    and the README table)."""
    name = "abstract"
    invariant = ""

    def check_file(self, ctx: FileCtx) -> Iterable[Violation]:
        return ()

    def finish(self, project: Project) -> Iterable[Violation]:
        return ()


@dataclass
class Report:
    violations: List[Violation] = field(default_factory=list)   # live
    suppressed: List[Violation] = field(default_factory=list)   # pragma'd
    checked_files: int = 0
    pragma_count: int = 0
    pragmas_by_rule: Dict[str, int] = field(default_factory=dict)
    unused_pragmas: List[str] = field(default_factory=list)     # "path:line"

    @property
    def ok(self) -> bool:
        return not self.violations

    def to_json(self) -> dict:
        return {
            "ok": self.ok,
            "checked_files": self.checked_files,
            "violation_count": len(self.violations),
            "violations": [v.to_json() for v in self.violations],
            "suppressed_count": len(self.suppressed),
            "suppressed": [v.to_json() for v in self.suppressed],
            "stats": {
                "pragma_count": self.pragma_count,
                "pragmas_by_rule": dict(sorted(
                    self.pragmas_by_rule.items())),
                "unused_pragmas": self.unused_pragmas,
            },
        }


def _discover(root: Path, rel_roots: Sequence[str]) -> List[Path]:
    out: List[Path] = []
    for rel in rel_roots:
        base = root / rel
        if base.is_file():
            out.append(base)
            continue
        if not base.is_dir():
            continue
        for p in sorted(base.rglob("*.py")):
            if not any(part in _SKIP_DIRS for part in p.parts):
                out.append(p)
    return out


def load_project(root: Path,
                 rel_roots: Sequence[str] = DEFAULT_ROOTS) -> Project:
    files: Dict[str, FileCtx] = {}
    for p in _discover(root, rel_roots):
        rel = p.relative_to(root).as_posix()
        try:
            files[rel] = FileCtx(rel, p.read_text(encoding="utf-8"))
        except (OSError, UnicodeDecodeError) as exc:
            ctx = FileCtx(rel, "")
            ctx.parse_error = f"unreadable: {exc}"
            files[rel] = ctx
    return Project(root, files)


def run(root: Path, paths: Optional[Sequence[str]] = None,
        rules: Optional[Sequence[Rule]] = None) -> Report:
    """Lint ``root``.  ``paths`` (repo-relative) restricts which files
    violations are *reported* for; analysis always covers the default
    tree so cross-file rules see whole invariants."""
    from .rules import ALL_RULES
    active = list(rules) if rules is not None else [r() for r in ALL_RULES]

    project = load_project(root)
    selected: Optional[set] = None
    if paths is not None:
        selected = set()
        for raw in paths:
            p = Path(raw)
            rel = (p if not p.is_absolute()
                   else p.relative_to(root)).as_posix()
            selected.add(rel)
            # a selected file outside the default roots is parsed too,
            # so `reprolint some/new/file.py` just works
            if rel not in project.files:
                full = root / rel
                if full.is_file():
                    project.files[rel] = FileCtx(
                        rel, full.read_text(encoding="utf-8"))

    report = Report(checked_files=len(project.files))
    raw: List[Violation] = []

    for ctx in project.files.values():
        if ctx.parse_error is not None:
            raw.append(Violation("parse", ctx.path, 1,
                                 f"cannot parse: {ctx.parse_error}"))
            continue
        for line, msg in ctx.pragma_problems:
            raw.append(Violation("pragma-reason", ctx.path, line, msg))
        for rule in active:
            raw.extend(rule.check_file(ctx))
    for rule in active:
        raw.extend(rule.finish(project))

    # pragma suppression + bookkeeping
    for v in raw:
        ctx = project.files.get(v.path)
        pragma: Optional[Pragma] = None
        if ctx is not None and v.rule not in ("parse", "pragma-reason"):
            pragma = find_pragma(ctx.pragmas, v.rule, v.line)
        if pragma is not None:
            pragma.used = True
            v.suppressed, v.reason = True, pragma.reason
    for ctx in project.files.values():
        for pragma in ctx.pragmas.values():
            report.pragma_count += 1
            for r in pragma.rules:
                report.pragmas_by_rule[r] = \
                    report.pragmas_by_rule.get(r, 0) + 1
            if not pragma.used:
                report.unused_pragmas.append(f"{ctx.path}:{pragma.line}")

    def _want(v: Violation) -> bool:
        return selected is None or v.path in selected
    order = (lambda v: (v.path, v.line, v.rule))
    report.violations = sorted((v for v in raw
                                if not v.suppressed and _want(v)), key=order)
    report.suppressed = sorted((v for v in raw
                                if v.suppressed and _want(v)), key=order)
    return report


def render_human(report: Report, stats: bool = False) -> str:
    lines: List[str] = [v.format() for v in report.violations]
    if stats:
        lines.append("")
        lines.append(f"reprolint: {report.checked_files} files, "
                     f"{len(report.violations)} violation(s), "
                     f"{len(report.suppressed)} suppressed, "
                     f"{report.pragma_count} pragma(s)")
        for rule, n in sorted(report.pragmas_by_rule.items()):
            lines.append(f"  pragma allow({rule}): {n}")
        for loc in report.unused_pragmas:
            lines.append(f"  unused pragma: {loc}")
    elif report.ok:
        lines.append(f"reprolint: {report.checked_files} files clean "
                     f"({len(report.suppressed)} suppressed by pragma)")
    else:
        lines.append(f"reprolint: {len(report.violations)} violation(s) "
                     f"in {report.checked_files} files")
    return "\n".join(lines)


def render_json(report: Report) -> str:
    return json.dumps(report.to_json(), indent=1)
