"""Small AST helpers shared by the rules.

Everything here is name-based heuristics over a single parse — reprolint
resolves no imports and runs no code.  The helpers therefore answer
"what does this syntax *say*", and the rules are written so that the
approximation errs toward asking for a pragma, never toward silence.
"""
from __future__ import annotations

import ast
from typing import Iterator, Optional


def receiver_tail(node: ast.AST) -> Optional[str]:
    """Final identifier of an attribute chain: ``self.archive.backend``
    -> ``"backend"``, ``backend`` -> ``"backend"``.  ``None`` when the
    chain bottoms out in a call/subscript (e.g. ``super().x``)."""
    if isinstance(node, ast.Name):
        return node.id
    if isinstance(node, ast.Attribute):
        return node.attr
    return None


def call_name(call: ast.Call) -> Optional[str]:
    """Bare name of the called function/method (``foo`` / ``x.foo``)."""
    if isinstance(call.func, ast.Name):
        return call.func.id
    if isinstance(call.func, ast.Attribute):
        return call.func.attr
    return None


def exception_names(handler: ast.ExceptHandler) -> tuple[str, ...]:
    """Names caught by an ``except`` clause; empty tuple for a bare
    ``except:``."""
    t = handler.type
    if t is None:
        return ()
    elts = t.elts if isinstance(t, ast.Tuple) else [t]
    out = []
    for e in elts:
        name = receiver_tail(e)
        if name is not None:
            out.append(name)
    return tuple(out)


def contains_raise(node: ast.AST) -> bool:
    """Does the body re-raise (any ``raise``), even from nested
    statements?  Nested function bodies do not count — a ``raise``
    inside a closure does not propagate the caught exception."""
    for child in _walk_no_funcs(node):
        if isinstance(child, ast.Raise):
            return True
    return False


def _walk_no_funcs(node: ast.AST) -> Iterator[ast.AST]:
    """``ast.walk`` that does not descend into nested function/class
    definitions."""
    stack = [node]
    while stack:
        n = stack.pop()
        yield n
        for child in ast.iter_child_nodes(n):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef,
                                  ast.ClassDef, ast.Lambda)):
                continue
            stack.append(child)


def walk_functions(tree: ast.AST) -> Iterator[tuple[str, ast.AST]]:
    """Yield ``(qualname, funcdef)`` for every (nested) function, with
    ``Class.method`` qualnames."""
    def _walk(node: ast.AST, prefix: str) -> Iterator[tuple[str, ast.AST]]:
        for child in ast.iter_child_nodes(node):
            if isinstance(child, (ast.FunctionDef, ast.AsyncFunctionDef)):
                q = f"{prefix}{child.name}"
                yield q, child
                yield from _walk(child, f"{q}.")
            elif isinstance(child, ast.ClassDef):
                yield from _walk(child, f"{prefix}{child.name}.")
            else:
                yield from _walk(child, prefix)
    yield from _walk(tree, "")


def body_names(func: ast.AST) -> set[str]:
    """Every bare identifier and attribute name appearing in a function
    body (not descending into nested defs)."""
    out: set[str] = set()
    for n in _walk_no_funcs(func):
        if isinstance(n, ast.Name):
            out.add(n.id)
        elif isinstance(n, ast.Attribute):
            out.add(n.attr)
    return out


def build_parents(tree: ast.AST) -> dict[ast.AST, ast.AST]:
    """Child -> parent map for ancestor walks (guard detection)."""
    parents: dict[ast.AST, ast.AST] = {}
    for node in ast.walk(tree):
        for child in ast.iter_child_nodes(node):
            parents[child] = node
    return parents


def enclosing_function(node: ast.AST,
                       parents: dict[ast.AST, ast.AST]) -> Optional[ast.AST]:
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return cur
        cur = parents.get(cur)
    return None


def under_enabled_guard(node: ast.AST,
                        parents: dict[ast.AST, ast.AST]) -> bool:
    """Is ``node`` inside an ``if`` whose test mentions ``.enabled`` (the
    ``if TRACER.enabled:`` idiom)?  The guard must be in the same
    function — an enabled-check in a caller does not make the kwargs
    free at this call site."""
    cur = parents.get(node)
    while cur is not None:
        if isinstance(cur, (ast.FunctionDef, ast.AsyncFunctionDef)):
            return False
        if isinstance(cur, ast.If):
            for n in ast.walk(cur.test):
                if isinstance(n, ast.Attribute) and n.attr == "enabled":
                    return True
                if isinstance(n, ast.Name) and n.id == "enabled":
                    return True
        cur = parents.get(cur)
    return False


def decorator_names(cls: ast.ClassDef) -> set[str]:
    out = set()
    for d in cls.decorator_list:
        target = d.func if isinstance(d, ast.Call) else d
        name = receiver_tail(target)
        if name is not None:
            out.add(name)
    return out


def const_str(node: ast.AST) -> Optional[str]:
    if isinstance(node, ast.Constant) and isinstance(node.value, str):
        return node.value
    return None
