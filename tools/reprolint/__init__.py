"""reprolint — AST-based checker for this repo's recovery invariants.

The recovery protocol has no page LSNs on the log to catch mistakes at
runtime: WAL ordering, LSN-monotone redo and exactly-once idempotent
apply are *conventions*, spread across ~15 modules and enforced — before
this tool — only by reviewer memory.  reprolint machine-checks them:

  codec-parity        every RecKind / record field survives the codec
  loud-corruption     corruption errors are never swallowed
  wal-discipline      backend writes sit behind a stable-LSN check
  sorted-stream       batched apply call sites prove their ordering
  tracer-guard        hot-path event probes cost nothing when disabled
  metric-name         registry names are canonical, kinds consistent
  determinism         no wall clocks / unseeded randomness in the engine
  dataclass-hygiene   no mutable defaults; memo fields are compare=False

Violations are suppressed per line with a *reasoned* pragma:

    # reprolint: allow(rule-name) — why this site is exempt

A pragma without a reason is itself a violation.  See ``README.md``
("Static analysis") and ``CONTRIBUTING.md`` for the rule table and the
policy on adding rules / granting pragmas.
"""
from .engine import DEFAULT_ROOTS, Report, Violation, run

__all__ = ["DEFAULT_ROOTS", "Report", "Violation", "run"]
