"""Crash-point torture: crash at EVERY backend operation, recover, compare.

The sweep runs one scripted, fully deterministic workload — load, user
transactions, fuzzy snapshots, archiver seal/master-save/truncate, prune,
explicit checkpoint page flushes, and a sharded-replica catch-up with
epoch barriers — over a ``FaultyBackend`` that carries *all* durable
artifacts (page blobs, sealed segments, snapshot rows, the master
pointer).  A profiling pass with an empty ``FaultPlan`` counts the
backend operations and stamps which workload phase each op index falls
in; the sweep then re-runs the workload once per injection point with a
crash (clean or torn-write) scripted at exactly that op, and checks the
two recovery stories against ``committed_state_oracle``:

  in-process   ``db.crash()`` + ``recover(LOG1, batched)`` — must equal
               the committed prefix, or (torn-write sweeps only) die
               loudly on the injected corruption;
  cold         ``cold_restore`` from the backend alone — must equal the
               committed prefix at its own target LSN, raise the
               documented nothing-sealed-yet ``ValueError``, or die
               loudly on injected corruption.

"Loudly" is a closed list: ``CorruptSegmentError`` / ``UnknownFormatError``
/ ``TruncatedLogError`` / ``PageCorruptError``.  Any other exception, and
any silently wrong state, fails the sweep — that is the whole point.

A third sweep scripts *transient* outages (``BackendUnavailableError``)
at every put/get and requires the workload to complete — retry layers
absorbing every injection — with the final primary, replica, and cold
restore all oracle-equal.

Usage:
  PYTHONPATH=src python tools/torture.py             # bounded default sweep
  PYTHONPATH=src python tools/torture.py --full      # every point (CI job)
  PYTHONPATH=src python tools/torture.py --stride 7 --max-points 40
Exits non-zero on the first contract violation; prints a phase x outcome
matrix either way.
"""
from __future__ import annotations

import argparse
import sys
from dataclasses import dataclass, field
from typing import Optional

from repro.archive import Archiver, LogArchive, SnapshotStore
from repro.core import (Database, Strategy, make_key, recover,
                        recovered_state)
from repro.core.log import TruncatedLogError
from repro.core.pages import PageCorruptError
from repro.faults import (KIND_CRASH, KIND_TORN_CRASH, KIND_UNAVAILABLE,
                          FaultPlan, FaultSpec, FaultyBackend, InjectedCrash,
                          RetryPolicy)
from repro.media import (CorruptSegmentError, MemoryBackend,
                         UnknownFormatError, cold_restore)
from repro.replication import LogShipper, ShardedApplier

#: the only exceptions a post-fault recovery may legally die with — every
#: one of them names corruption or a documented empty-archive degradation
LOUD = (CorruptSegmentError, UnknownFormatError, TruncatedLogError,
        PageCorruptError)

#: ctx of the most recent run_workload call, reachable after an
#: InjectedCrash unwound it (module-global on purpose: the exception IS
#: the return path for a crashed workload)
_last_ctx: Optional["TortureCtx"] = None

N_ROWS = 120
ROWS = [(f"k{i:04d}".encode(), bytes(((i * 7) % 251,)) * 36)
        for i in range(N_ROWS)]


def _txn_ops(round_no: int, j: int):
    """Deterministic op mix: mostly updates, some inserts/deletes."""
    sel = (round_no * 13 + j * 5) % N_ROWS
    roll = (round_no * 31 + j * 17) % 10
    if roll < 7:
        return [("update", "t", ROWS[sel][0],
                 bytes(((round_no + j) % 251,)) * 30)]
    if roll < 9:
        return [("insert", "t", f"x{round_no:03d}{j:02d}".encode(),
                 bytes(((round_no * j + 3) % 251,)) * 20)]
    return [("delete", "t", ROWS[sel][0], None)]


@dataclass
class TortureCtx:
    """Everything the driver needs after an ``InjectedCrash`` unwound the
    workload: references survive here even though the run did not."""
    plan: FaultPlan
    backend: Optional[FaultyBackend] = None
    db: Optional[Database] = None
    base: Optional[dict] = None
    archiver: Optional[Archiver] = None
    snaps: Optional[SnapshotStore] = None
    replica: Optional[ShardedApplier] = None
    marks: list = field(default_factory=list)    # (phase, first op index)
    ledger: list = field(default_factory=list)   # (commit_lsn, ops) per txn
    pending: Optional[list] = None               # ops of the txn in flight
    snap1_target: Optional[int] = None           # LSN pinning snapshot1

    def mark(self, phase: str) -> None:
        self.marks.append((phase, self.plan.total_ops + 1))

    def phase_of(self, op_index: int) -> str:
        name = "pre"
        for phase, first in self.marks:
            if first <= op_index:
                name = phase
        return name


def run_workload(plan: FaultPlan, *, retries: bool = False) -> TortureCtx:
    """The scripted workload.  With ``retries`` every retryable layer gets
    a ``RetryPolicy`` (the transient sweep); without, layers run with
    single-attempt policies so a crash sweep is not perturbed by backoff
    bookkeeping.  Raises ``InjectedCrash`` when the plan says so — the
    ``TortureCtx`` keeps the references the driver needs afterwards."""
    global _last_ctx
    ctx = TortureCtx(plan=plan)
    _last_ctx = ctx
    policy = (lambda seed: RetryPolicy(max_attempts=5, seed=seed)) if retries \
        else (lambda seed: None)
    faulty = FaultyBackend(MemoryBackend(), plan)
    ctx.backend = faulty

    ctx.mark("load")
    db = Database(page_size=1024, cache_pages=12, tracker_interval=20,
                  bg_flush_per_txn=2, page_backend=faulty,
                  media_retry=policy(1))
    ctx.db = db
    db.load_table("t", ROWS)
    ctx.base = {make_key("t", k): v for k, v in ROWS}

    arch = LogArchive(segment_records=24, backend=faulty, cache_segments=2,
                      retry=policy(2))
    snaps = SnapshotStore()
    archiver = Archiver(db, archive=arch, snapshots=snaps,
                        retry=policy(3) or RetryPolicy(max_attempts=1))
    ctx.archiver, ctx.snaps = archiver, snaps

    def txns(phase, round_no, n):
        ctx.mark(phase)
        for j in range(n):
            # the pending/ledger pair is the oracle's bookkeeping: a txn
            # whose run_txn never returned may still have committed stably
            # (the crash can land in post-commit page flushing) — the
            # driver resolves that boundary via last_stable_commit_lsn
            ops = _txn_ops(round_no, j)
            ctx.pending = ops
            lsn = db.run_txn(ops)
            ctx.ledger.append((lsn, ops))
            ctx.pending = None

    def take(phase):
        ctx.mark(phase)
        if retries:
            RetryPolicy(max_attempts=5, seed=4).call(
                snaps.take, db, chunk_keys=16)
        else:
            snaps.take(db, chunk_keys=16)

    txns("txns1", 1, 10)
    take("snapshot1")
    ctx.snap1_target = db.log.end_lsn     # pins snapshot1 for the ship phase
    ctx.mark("seal1")
    archiver.run_once()
    txns("txns2", 2, 10)
    ctx.mark("checkpoint")
    db.checkpoint()
    take("snapshot2")
    ctx.mark("seal2")
    archiver.run_once()
    ctx.mark("prune")
    archiver.prune(keep_snapshots=2)      # keep snapshot1: ship reseeds there
    txns("txns3", 3, 6)
    ctx.mark("seal3")
    archiver.run_once()

    # replica catch-up: reseed at the OLD snapshot (snapshot1) so the
    # shipping cursor starts below the truncation base and every poll
    # reads through the archive splice — sealed segments on the faulty
    # backend — and the sharded applier ends on an epoch barrier
    ctx.mark("ship")
    shipper = LogShipper(db, batch_records=32, retry=policy(5))
    rep = snaps.restore_replica("torture", target_lsn=ctx.snap1_target,
                                replica_cls=ShardedApplier,
                                n_shards=2, epoch_txns=4, page_size=4096,
                                cache_pages=64)
    ctx.replica = rep
    rep.resubscribe(shipper)
    if retries:
        rep.catch_up(shipper, retry=RetryPolicy(max_attempts=5, seed=6))
    else:
        rep.catch_up(shipper, retry=RetryPolicy(max_attempts=1))
    ctx.mark("barrier")
    rep.barrier()
    ctx.mark("done")
    return ctx


# ----------------------------------------------------------------- oracle
def shadow_oracle(ctx: TortureCtx, image, upto_lsn=None) -> dict:
    """The committed prefix, computed from the driver's own ledger rather
    than a log scan — the workload prunes archive segments mid-run, so
    ``committed_state_oracle``'s replay-from-LSN-1 is (correctly!)
    impossible afterwards.  The ledger records every txn whose ``run_txn``
    returned; the one in flight at crash time is included iff the image
    shows a stable commit NEWER than the last ledgered one (its commit was
    durable even though the driver never saw the return)."""
    stable = image.log.last_stable_commit_lsn
    hi = stable if upto_lsn is None else min(upto_lsn, stable)
    commits = list(ctx.ledger)
    last_recorded = commits[-1][0] if commits else 0
    if ctx.pending is not None and stable > last_recorded:
        commits.append((stable, ctx.pending))
    state = dict(ctx.base)
    for lsn, ops in commits:
        if lsn > hi:
            break
        for verb, table, key, value in ops:
            k = make_key(table, key)
            if verb == "delete":
                state.pop(k, None)
            else:
                state[k] = value            # absolute after-image semantics
    return state


# --------------------------------------------------------------- verdicts
def check_crash_point(at: int, kind: str) -> tuple[str, str, str]:
    """Re-run the workload with a crash scripted at backend op ``at``;
    recover both ways.  Returns (phase, in-process outcome, cold outcome);
    raises AssertionError on any contract violation."""
    plan = FaultPlan(faults=(FaultSpec(op="*", kind=kind, at=at),))
    try:
        ctx = run_workload(plan)
        # the plan never fired (at > total ops) — nothing to verify
        return ctx.phase_of(at), "not-reached", "not-reached"
    except InjectedCrash:
        ctx = _last_ctx
    phase = ctx.phase_of(at)
    if ctx.db is None:
        return phase, "pre-db", "pre-db"
    # a crash inside load_table interrupts the *unlogged* bulk build —
    # the committed-prefix oracle only covers logged operations, so for
    # those points we require recovery to complete (or die loudly on a
    # torn blob) without asserting on the partially-built content
    mid_load = ctx.base is None

    image = ctx.db.crash()
    oracle = None if mid_load else shadow_oracle(ctx, image)

    # in-process: the paper's own recovery over the crash image
    try:
        rec_db, _ = recover(image, Strategy.LOG1, batched=True,
                            page_size=2048)
        if oracle is not None:
            assert recovered_state(rec_db) == oracle, (
                f"recover() at op {at} ({kind}, {phase}): state diverges "
                "from the committed oracle")
        live = "mid-load" if mid_load else "ok"
    except LOUD:
        assert kind == KIND_TORN_CRASH, (
            f"recover() at op {at} ({kind}, {phase}) died loudly with no "
            "torn write in play — a clean crash must always recover")
        live = "loud"

    # cold: the dead-primary story, from the backend bytes alone
    try:
        restored, stats = cold_restore(ctx.backend, page_size=4096,
                                       retry=RetryPolicy(max_attempts=1))
        if oracle is not None:
            cold_oracle = shadow_oracle(ctx, image,
                                        upto_lsn=stats.target_lsn)
            assert dict(restored.scan_all()) == cold_oracle, (
                f"cold_restore at op {at} ({kind}, {phase}): state "
                "diverges from the committed oracle at LSN "
                f"{stats.target_lsn}")
        cold = "mid-load" if mid_load else "ok"
    except ValueError:
        cold = "no-archive"          # documented: nothing sealed yet
    except LOUD:
        assert kind == KIND_TORN_CRASH, (
            f"cold_restore at op {at} ({kind}, {phase}) died loudly with "
            "no torn write in play")
        cold = "loud"
    return phase, live, cold


def check_transient_point(at: int) -> tuple[str, str, str]:
    """Script a 2-op transient outage at ``at`` (puts and gets); the
    retry-wired workload must complete and stay oracle-equal end to end."""
    plan = FaultPlan(faults=(
        FaultSpec(op="put", kind=KIND_UNAVAILABLE, at=at, count=2),
        FaultSpec(op="get", kind=KIND_UNAVAILABLE, at=at, count=2),
    ))
    ctx = run_workload(plan, retries=True)
    # the workload is over: disarm before the verdicts below clone the
    # store / cold-restore, else a spec that never reached its window
    # during the run fires on verification reads instead
    plan.disarm()
    if not ctx.plan.injected:
        return "beyond-end", "not-reached", "not-reached"
    # ``at`` counts per-op-kind (the Nth put / Nth get); the injected
    # trace records the *global* op index, which is what phases map
    phase = ctx.phase_of(ctx.plan.injected[0][0])
    image = ctx.db.crash()
    oracle = shadow_oracle(ctx, image)
    rec_db, _ = recover(image, Strategy.LOG1, batched=True, page_size=2048)
    assert recovered_state(rec_db) == oracle, (
        f"transient outage at op {at} ({phase}): post-outage recover "
        "diverges from the oracle")
    applied_oracle = shadow_oracle(ctx, image,
                                   upto_lsn=ctx.replica.applied_lsn)
    assert ctx.replica.user_state() == applied_oracle, (
        f"transient outage at op {at} ({phase}): replica diverges from "
        "the oracle at its applied watermark")
    restored, stats = cold_restore(ctx.backend, page_size=4096)
    assert dict(restored.scan_all()) == shadow_oracle(
        ctx, image, upto_lsn=stats.target_lsn), (
        f"transient outage at op {at} ({phase}): cold restore diverges")
    return phase, "ok", "ok"


# ------------------------------------------------------------------ driver
def profile() -> TortureCtx:
    """Fault-free pass: counts backend ops, stamps phases, and checks the
    baseline end-state invariants the sweeps rely on."""
    ctx = run_workload(FaultPlan())
    image = ctx.db.crash()
    oracle = shadow_oracle(ctx, image)
    rec_db, _ = recover(image, Strategy.LOG1, batched=True, page_size=2048)
    assert recovered_state(rec_db) == oracle, "baseline recover() diverges"
    applied_oracle = shadow_oracle(ctx, image,
                                   upto_lsn=ctx.replica.applied_lsn)
    assert ctx.replica.user_state() == applied_oracle, \
        "baseline replica diverges"
    ctx.plan.disarm()
    restored, stats = cold_restore(ctx.backend, page_size=4096)
    assert dict(restored.scan_all()) == shadow_oracle(
        ctx, image, upto_lsn=stats.target_lsn), \
        "baseline cold_restore diverges"
    return ctx


def sweep(points, kinds, *, verbose=False):
    """Run the crash sweeps (and the transient sweep) over ``points``.
    Returns (matrix, violations): matrix maps (phase, kind, outcome) ->
    count; violations is a list of failure strings."""
    matrix: dict = {}
    violations: list[str] = []
    total = len(points) * (len(kinds) + 1)
    done = 0
    for at in points:
        checks = [(k, lambda a=at, kk=k: check_crash_point(a, kk))
                  for k in kinds]
        checks.append(("transient", lambda a=at: check_transient_point(a)))
        for kind, run in checks:
            done += 1
            try:
                phase, live, cold = run()
            except AssertionError as exc:
                violations.append(str(exc))
                matrix[("?", kind, "VIOLATION")] = \
                    matrix.get(("?", kind, "VIOLATION"), 0) + 1
                continue
            for side, outcome in (("live", live), ("cold", cold)):
                key = (phase, kind, f"{side}:{outcome}")
                matrix[key] = matrix.get(key, 0) + 1
            if verbose:
                print(f"  [{done}/{total}] op {at:4d} {kind:<10s} "
                      f"{phase:<10s} live={live} cold={cold}")
    return matrix, violations


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.split("\n")[0])
    ap.add_argument("--stride", type=int, default=11,
                    help="test every Nth injectable point (default 11)")
    ap.add_argument("--max-points", type=int, default=48,
                    help="cap on points per sweep (default 48)")
    ap.add_argument("--full", action="store_true",
                    help="every point, no cap (the CI torture job)")
    ap.add_argument("--verbose", action="store_true")
    args = ap.parse_args(argv)

    ctx = profile()
    total_ops = ctx.plan.total_ops
    phases = ", ".join(f"{p}@{i}" for p, i in ctx.marks)
    print(f"workload: {total_ops} backend ops | phases: {phases}")

    if args.full:
        points = list(range(1, total_ops + 1))
    else:
        points = list(range(1, total_ops + 1, max(1, args.stride)))
        # always include the first op of every phase — those are the
        # boundaries where half-done multi-blob operations live
        points = sorted(set(points)
                        | {i for _, i in ctx.marks if i <= total_ops})
        if len(points) > args.max_points:
            step = len(points) / args.max_points
            points = [points[int(i * step)] for i in range(args.max_points)]
    print(f"sweeping {len(points)} points x "
          f"({KIND_CRASH}, {KIND_TORN_CRASH}, transient)")

    matrix, violations = sweep(points, [KIND_CRASH, KIND_TORN_CRASH],
                               verbose=args.verbose)

    print("\nphase x outcome matrix:")
    for (phase, kind, outcome), n in sorted(matrix.items()):
        print(f"  {phase:<12s} {kind:<10s} {outcome:<16s} {n:4d}")
    if violations:
        print(f"\n{len(violations)} CONTRACT VIOLATION(S):", file=sys.stderr)
        for v in violations:
            print(f"  - {v}", file=sys.stderr)
        return 1
    print(f"\ntorture sweep green: {len(points)} points, "
          f"{len(points) * 3} scenarios, 0 violations")
    return 0


if __name__ == "__main__":
    sys.exit(main())
