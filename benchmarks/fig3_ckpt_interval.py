"""Figure 3 / Appendix C reproduction: redo time vs checkpoint interval
(ci, 5ci, 10ci).  Log0 grows linearly with the interval; Log1/SQL1
sub-linearly (DPT bounded by the dirty cache); Log2/SQL2 only modestly
(prefetching amortizes)."""
from __future__ import annotations

import json
from dataclasses import replace

from .harness import BenchSetup, build_crash_image, run_all_strategies


def run(fast: bool = False) -> dict:
    base_ci = 500 if fast else 2_000
    setup = BenchSetup(n_rows=30_000 if fast else 100_000,
                       cache_pages=512, n_ckpts=2)
    rows = []
    for mult in (1, 5, 10):
        s = replace(setup, ckpt_updates=base_ci * mult)
        image, base, info = build_crash_image(s)
        for r in run_all_strategies(image, base, s):
            rows.append({
                "ckpt_interval_updates": base_ci * mult,
                "interval_mult": mult,
                "strategy": r.strategy,
                "modeled_ms": round(r.modeled_ms, 1),
                "fetches": r.fetches,
                "dpt_size": r.dpt_size,
                "log_records": r.log_records,
                "correct": r.correct,
            })
    return {"name": "fig3_ckpt_interval", "rows": rows}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
