"""Key-range parallel apply benchmark: can a sharded standby keep up with a
write-heavy primary where the serial applier cannot?

  1. shard scaling — apply throughput of ``ShardedApplier`` at 1/2/4/8
     shards vs the serial ``Replica`` baseline, on uniform and skewed
     (hot-set) key distributions.  The sharded path owes its headroom to two
     things the epoch barrier makes legal: the durable watermark row is
     read-modified-written once per *epoch* instead of once per source
     transaction, and the background page-flush budget is spent per epoch —
     pages redirtied within an epoch flush once.  The n_shards=1 row
     isolates that epoch amortization from sharding proper; the per-shard
     dispatch-imbalance column shows what a multicore applier would see.
  2. epoch-crash recovery — crash the standby at an arbitrary point between
     barriers, recover locally, and verify the durable ``(applied, resume)``
     watermark is the consistent pre-epoch point and that re-shipping
     converges to the oracle.

Every run cross-checks the replica (4 KiB pages) against
``committed_state_oracle`` of the 8 KiB-page primary.
"""
from __future__ import annotations

import json
import random
import time

from repro.core import Database, committed_state_oracle, make_key
from repro.replication import Replica, ReplicaSet, ShardedApplier

PAGE_PRIMARY, PAGE_REPLICA = 8192, 4096
HOT_FRAC = 0.001         # skewed runs: this fraction of keys takes HOT_PROB
HOT_PROB = 0.8           # of the update traffic (a handful of hot keys, so
                         # hash partitioning cannot spread the hot set)
EPOCH_TXNS = 64


def _setup(rng, n_rows, *, n_shards=0, value_size=60):
    """n_shards=0: serial Replica baseline; else a ShardedApplier."""
    rows = [(f"k{i:07d}".encode(), rng.randbytes(value_size))
            for i in range(n_rows)]
    primary = Database(page_size=PAGE_PRIMARY, cache_pages=512,
                       tracker_interval=100, bg_flush_per_txn=4)
    primary.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}
    kw = dict(page_size=PAGE_REPLICA, cache_pages=1024, tracker_interval=100,
              bg_flush_per_txn=4, seed_tables={"t": rows})
    replica = ShardedApplier("r1", n_shards=n_shards, epoch_txns=EPOCH_TXNS,
                             **kw) if n_shards else Replica("r1", **kw)
    return primary, replica, base


def _drive(primary, rng, n_rows, n_txns, ops_per_txn, skew=False):
    hot = max(1, int(n_rows * HOT_FRAC))
    for _ in range(n_txns):
        ops = []
        for _ in range(ops_per_txn):
            k = rng.randrange(hot) if skew and rng.random() < HOT_PROB \
                else rng.randrange(n_rows)
            ops.append(("update", "t", f"k{k:07d}".encode(),
                        rng.randbytes(60)))
        primary.run_txn(ops)


def _measure_apply(n_rows, n_txns, ops_per_txn, n_shards, skew):
    """One full setup + drive + timed sync; returns (ops/s, applied, replica).
    The oracle cross-check runs outside the timed region."""
    rng = random.Random(21)
    primary, replica, base = _setup(rng, n_rows, n_shards=n_shards)
    rs = ReplicaSet(primary, [replica])
    _drive(primary, rng, n_rows, n_txns, ops_per_txn, skew=skew)
    t0 = time.perf_counter()
    applied = rs.sync()
    wall = time.perf_counter() - t0
    ok = replica.user_state() == committed_state_oracle(primary.crash(), base)
    assert ok, f"replica diverged at skew={skew}/n_shards={n_shards}"
    return applied / wall, applied, replica


def bench_shard_scaling(fast: bool) -> list[dict]:
    n_rows = 5_000 if fast else 20_000
    n_txns = 1_500 if fast else 8_000
    ops_per_txn = 1                       # write-heavy: commit-rate bound
    repeats = 2                           # best-of: damp shared-runner noise
    rows = []
    for dist in ("uniform", "skewed"):
        serial_rate = None
        for n_shards in (0, 1, 2, 4, 8):
            rate, applied, replica = max(
                (_measure_apply(n_rows, n_txns, ops_per_txn, n_shards,
                                skew=(dist == "skewed"))
                 for _ in range(repeats)), key=lambda m: m[0])
            wall = applied / rate
            ok = True                     # asserted inside _measure_apply
            if n_shards == 0:
                serial_rate = rate
            speedup = rate / serial_rate
            imb = replica.imbalance() if n_shards else 1.0
            label = "serial" if n_shards == 0 else f"shards={n_shards}"
            rows.append({
                "name": f"parallel_apply/{dist}/{label}",
                "dist": dist,
                "n_shards": n_shards,
                "applied_ops": applied,
                "apply_ops_per_s": round(rate, 1),
                "speedup_vs_serial": round(speedup, 2),
                "dispatch_imbalance": round(imb, 2),
                "us_per_call": wall / max(applied, 1) * 1e6,
                "derived": f"{rate:,.0f} ops/s {speedup:.2f}x "
                           f"imb={imb:.2f} ok={ok}",
            })
            if n_shards == 4 and dist == "uniform":
                assert speedup >= 2.0, (
                    f"acceptance: 4-shard apply {speedup:.2f}x serial, "
                    "expected >= 2x")
    return rows


def bench_epoch_crash(fast: bool) -> list[dict]:
    """Crash the standby between epoch barriers (an arbitrary mid-epoch
    point), recover locally, and verify (a) the durable watermark is a
    consistent pre-epoch resume point, (b) re-shipping from it converges."""
    n_rows = 3_000 if fast else 10_000
    n_txns = 400 if fast else 1_500
    rows = []
    for crash_at_records in (37, 293, 1111):
        # best-of-2 on the recover wall: the recovery itself is
        # deterministic (seeded workload), but a single sample eats
        # whatever GC pause the setup's garbage schedules — one outlier
        # here flaked the bench-diff gate.  Consistency is asserted on
        # every repeat, only the timing takes the min.
        wall_ms = float("inf")
        for _ in range(2):
            rng = random.Random(22)
            primary, replica, base = _setup(rng, n_rows, n_shards=4)
            rs = ReplicaSet(primary, [replica])
            _drive(primary, rng, n_rows, n_txns, 2)
            # partial apply: stop mid-stream, between barriers
            rs.sync(max_records=crash_at_records)
            mid_epoch = replica._dispatched_lsn > replica.applied_lsn
            t0 = time.perf_counter()
            replica.recover_local()
            wall_ms = min(wall_ms, (time.perf_counter() - t0) * 1e3)
            assert replica.resume_lsn <= replica.applied_lsn + 1, \
                "recovered watermark inconsistent"
            assert replica.queued_slices() == 0 and not replica.pending
            replica.resubscribe(rs.shipper)
            rs.sync()
            ok = replica.user_state() == committed_state_oracle(
                primary.crash(), base)
            assert ok, f"diverged after mid-epoch crash at {crash_at_records}"
        rows.append({
            "name": f"parallel_apply/crash@{crash_at_records}rec",
            "crash_at_records": crash_at_records,
            "mid_epoch": mid_epoch,
            "recover_ms": round(wall_ms, 2),
            "redropped_dup_txns": replica.dropped_dup_txns,
            "us_per_call": wall_ms * 1e3,
            "derived": f"recover={wall_ms:.1f}ms mid_epoch={mid_epoch} "
                       f"dups={replica.dropped_dup_txns} ok={ok}",
        })
    return rows


def run(fast: bool = False) -> dict:
    rows = bench_shard_scaling(fast) + bench_epoch_crash(fast)
    return {"name": "parallel_apply", "rows": rows}


if __name__ == "__main__":
    print(json.dumps(run(fast=True), indent=1))
