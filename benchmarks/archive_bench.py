"""Archive benchmark: the three costs that decide whether logical
snapshots + log archival earn their keep.

  1. restore time vs snapshot cadence — point-in-time restore replays
     committed redo from the newest covering snapshot; more frequent
     snapshots mean a shorter replay and a faster restore, at the cost of
     more scans;
  2. live-log memory bound under truncation — with an Archiver running at
     the snapshot cadence, the in-memory record count stays bounded by the
     inter-snapshot distance while the sealed archive absorbs history (and
     crash recovery still works through the splice cursor);
  3. re-seed vs full replay — a standby joining late from a snapshot
     (restore_replica + catch-up shipping) against one replaying the whole
     history from LSN 1; the speedup is what makes promote() able to
     re-seed failover survivors instead of detaching them.

Every row cross-checks against ``committed_state_oracle`` (point-in-time
form for restores).
"""
from __future__ import annotations

import json
import random
import time

from repro.archive import Archiver, LogArchive, SnapshotStore
from repro.core import Database, committed_state_oracle, make_key
from repro.replication import Replica, ReplicaSet

PAGE_PRIMARY, PAGE_REPLICA = 8192, 4096


def _setup(rng, n_rows, value_size=60):
    rows = [(f"k{i:07d}".encode(), rng.randbytes(value_size))
            for i in range(n_rows)]
    primary = Database(page_size=PAGE_PRIMARY, cache_pages=512,
                       tracker_interval=100, bg_flush_per_txn=4)
    primary.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}
    return primary, rows, base


def _drive(primary, rng, n_rows, n_txns, ops_per_txn=8):
    for _ in range(n_txns):
        primary.run_txn([("update", "t",
                          f"k{rng.randrange(n_rows):07d}".encode(),
                          rng.randbytes(60)) for _ in range(ops_per_txn)])


def bench_restore_vs_cadence(fast: bool) -> list[dict]:
    """Restore-to-tip wall time as the snapshot cadence varies: from one
    snapshot at load time (full redo replay) down to one every total/8
    transactions (short replay)."""
    n_rows = 2_000 if fast else 10_000
    total_txns = 400 if fast else 2_000
    rows_out = []
    for n_snaps in (1, 4, 8):
        rng = random.Random(21)
        primary, _, base = _setup(rng, n_rows)
        store = SnapshotStore()
        per_gap = total_txns // n_snaps
        for _ in range(n_snaps):
            store.take(primary, chunk_keys=512,
                       on_chunk=lambda: _drive(primary, rng, n_rows, 1))
            _drive(primary, rng, n_rows, per_gap)
        target = primary.log.stable_lsn
        t0 = time.perf_counter()
        restored, stats = store.restore(target, primary,
                                        page_size=PAGE_REPLICA)
        wall_ms = (time.perf_counter() - t0) * 1e3
        ok = dict(restored.scan_all()) == committed_state_oracle(
            primary.crash(), base, upto_lsn=target)
        assert ok, f"restore diverged at n_snaps={n_snaps}"
        rows_out.append({
            "name": f"archive_restore/snapshots={n_snaps}",
            "snapshots": n_snaps,
            "cadence_txns": per_gap,
            "replayed_txns": stats.replayed_txns,
            "replayed_ops": stats.replayed_ops,
            "restore_ms": round(wall_ms, 2),
            "us_per_call": wall_ms * 1e3,
            "derived": f"replay={stats.replayed_txns}txns "
                       f"restore={wall_ms:.0f}ms ok={ok}",
        })
    return rows_out


def bench_memory_bound(fast: bool) -> list[dict]:
    """Live LogManager record count under an Archiver running at the
    snapshot cadence, vs the ever-growing total history; ends with a crash
    + LOG1 recovery through the splice cursor."""
    from repro.core import Strategy, recover
    n_rows = 2_000 if fast else 10_000
    rounds, per_round = (8, 50) if fast else (20, 100)
    rows_out = []
    for cadence_rounds in (0, 1, 4):         # snapshots every N rounds; 0=off
        rng = random.Random(22)
        primary, _, base = _setup(rng, n_rows)
        store = SnapshotStore()
        archiver = Archiver(primary, archive=LogArchive(segment_records=512),
                            snapshots=store)
        peak = 0
        t0 = time.perf_counter()
        for i in range(rounds):
            _drive(primary, rng, n_rows, per_round)
            # high-water mark: just before the archiver gets to run
            peak = max(peak, primary.log.in_memory_records)
            if cadence_rounds and (i + 1) % cadence_rounds == 0:
                store.take(primary, chunk_keys=1024)
                archiver.run_once()
        wall_ms = (time.perf_counter() - t0) * 1e3
        image = primary.crash()
        recovered, _ = recover(image, Strategy.LOG1, page_size=PAGE_PRIMARY)
        ok = dict(recovered.scan_all()) == committed_state_oracle(image, base)
        assert ok, f"post-truncation recovery diverged " \
                   f"(cadence={cadence_rounds})"
        total = primary.log.end_lsn
        rows_out.append({
            "name": f"archive_memory/cadence={cadence_rounds or 'off'}",
            "cadence_rounds": cadence_rounds,
            "peak_in_memory_records": peak,
            "total_log_records": total,
            "bound_frac": round(peak / total, 3),
            "us_per_call": wall_ms / rounds * 1e3,
            "derived": f"peak={peak} total={total} "
                       f"frac={peak / total:.2f} recover_ok={ok}",
        })
    # the point of the exercise: truncation bounds memory well below history
    assert rows_out[1]["peak_in_memory_records"] < \
        rows_out[0]["peak_in_memory_records"] / 2, \
        "truncation did not bound the live log"
    return rows_out


def bench_reseed_vs_full_replay(fast: bool) -> list[dict]:
    """A standby joining a long-lived primary: snapshot re-seed + catch-up
    vs full replay from LSN 1.  The speedup is the promote()-survivor
    story in benchmark form."""
    n_rows = 2_000 if fast else 10_000
    history_txns = 600 if fast else 3_000
    tail_txns = 25 if fast else 100
    rng = random.Random(23)
    primary, rows, base = _setup(rng, n_rows)
    store = SnapshotStore()
    _drive(primary, rng, n_rows, history_txns)
    store.take(primary, chunk_keys=1024,
               on_chunk=lambda: _drive(primary, rng, n_rows, 1))
    _drive(primary, rng, n_rows, tail_txns)   # snapshot slightly stale
    oracle = committed_state_oracle(primary.crash(), base)

    # full replay: seeded as of the initial load, ships the whole history
    rs = ReplicaSet(primary)
    full = Replica("full", page_size=PAGE_REPLICA, cache_pages=1024,
                   seed_tables={"t": rows})
    t0 = time.perf_counter()
    rs.add_replica(full)
    rs.sync()
    t_full = time.perf_counter() - t0
    assert full.user_state() == oracle, "full-replay standby diverged"

    # re-seed: newest snapshot + catch-up from its redo point
    rs2 = ReplicaSet(primary, snapshots=store)
    t0 = time.perf_counter()
    seeded = store.restore_replica("seeded", page_size=PAGE_REPLICA,
                                   cache_pages=1024)
    rs2.add_replica(seeded)
    rs2.sync()
    t_seed = time.perf_counter() - t0
    assert seeded.user_state() == oracle, "re-seeded standby diverged"

    speedup = t_full / max(t_seed, 1e-9)
    assert speedup >= 2.0, \
        f"re-seed speedup {speedup:.1f}x below the 2x acceptance bound"
    return [{
        "name": "archive_reseed/vs_full_replay",
        "history_txns": history_txns,
        "tail_txns": tail_txns,
        "full_replay_ms": round(t_full * 1e3, 1),
        "reseed_ms": round(t_seed * 1e3, 1),
        "speedup": round(speedup, 2),
        "us_per_call": t_seed * 1e6,
        "derived": f"reseed={t_seed * 1e3:.0f}ms "
                   f"full={t_full * 1e3:.0f}ms {speedup:.1f}x ok=True",
    }]


def bench_prune_guard(fast: bool) -> list[dict]:
    """Regression guard for the prune index scheme: dropping segments one
    at a time from a long archive must cost the same per segment as from
    a short one (the old ``pop(0)``-per-segment implementation grew
    per-segment cost with archive length — quadratic in total).  The
    guard itself lives in ``media_bench`` (the layer that owns the
    scheme); delegating keeps one implementation and one bound, relabeled
    into this table so an archive-side regression is still reported
    here."""
    from .media_bench import bench_prune_scaling
    return [{**row, "name": row["name"].replace("media_prune",
                                                "archive_prune")}
            for row in bench_prune_scaling(fast)]


def run(fast: bool = False) -> dict:
    rows = (bench_restore_vs_cadence(fast) + bench_memory_bound(fast)
            + bench_reseed_vs_full_replay(fast) + bench_prune_guard(fast))
    return {"name": "archive", "rows": rows}


if __name__ == "__main__":
    print(json.dumps(run(fast=True), indent=1))
