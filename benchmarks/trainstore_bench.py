"""Training-state recovery vs update sparsity (the paper's claim, measured on
the framework's own state store).

Workload: an embedding-table-like state (rows x row_elems fp32) logged through
TrainWAL with delta-only chunk transactions; per step a FRACTION of rows is
touched.  Sweep the fraction: at 1-5% (embedding/MoE regime) the DPT prunes
nearly everything; at 100% (dense-AdamW regime) it honestly degenerates —
quantifying DESIGN.md §Arch-applicability."""
from __future__ import annotations

import json

import numpy as np

from repro.core import Strategy, recover
from repro.state_store import TrainWAL, WALConfig


def run(fast: bool = False) -> dict:
    n_rows, row_elems = (200, 1024) if fast else (400, 2048)
    steps = 15 if fast else 25
    rows_out = []
    for frac in (0.01, 0.05, 0.2, 1.0):
        rng = np.random.default_rng(0)
        import jax.numpy as jnp
        state = {"table": jnp.asarray(
            rng.normal(size=(n_rows, row_elems)), jnp.float32)}
        wal_cfg = WALConfig(chunk_interval=1, ckpt_interval=1000,
                            bg_flush_pages=16, cache_pages=4096,
                            chunk_elems=row_elems, tracker_interval=10)
        wal = TrainWAL(wal_cfg)
        wal.log_state(0, 0, state)
        wal.db.checkpoint()
        arr = np.array(state["table"])
        touch = max(1, int(n_rows * frac))
        for step in range(1, steps):
            idx = rng.integers(0, n_rows, size=touch)
            arr[idx] += rng.normal(size=(len(idx), row_elems)).astype(np.float32)
            wal.log_state(step, step, {"table": jnp.asarray(arr)})
        image = wal.crash()
        res = {}
        for s in (Strategy.LOG0, Strategy.LOG1, Strategy.LOG2):
            _, st = recover(image, s, cache_pages=4096,
                            page_size=wal_cfg.page_size)
            res[s.value] = st
        rows_out.append({
            "touched_frac": frac,
            "log0_fetches": res["Log0"].io.total_reads(),
            "log1_fetches": res["Log1"].io.total_reads(),
            "log2_fetches": res["Log2"].io.total_reads(),
            "log1_dpt": res["Log1"].dpt_size,
            "log0_modeled_ms": round(res["Log0"].io.modeled_ms, 1),
            "log1_modeled_ms": round(res["Log1"].io.modeled_ms, 1),
            "log2_modeled_ms": round(res["Log2"].io.modeled_ms, 1),
            "speedup_log1_vs_log0": round(
                res["Log0"].io.modeled_ms
                / max(1e-9, res["Log1"].io.modeled_ms), 2),
        })
    return {"name": "trainstore_sparsity", "rows": rows_out}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
