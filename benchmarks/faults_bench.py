"""Fault-layer cost table: the hook must be free, the outage must be cheap.

Two CI-asserted claims about PR 10's injection/retry stack:

  * ``faults/hook_overhead`` — the *disabled* injection path (a
    ``FaultyBackend`` whose plan has no specs: one counter bump and an
    empty spec scan per backend op) costs < 5% of the batched Log1 redo
    wall when scaled by the op count of a full cold restore.  Same
    methodology as the probe-overhead bound: time the hot primitive in
    isolation, multiply by the run's own op count — a direct wall-clock
    diff of two restores is noise at this magnitude.

  * ``faults/restore@...`` — cold restore through a backend suffering a
    seeded transient-outage campaign converges to the *same state* as the
    fault-free restore (oracle-asserted), and the retry machinery charges
    its backoff to ``slept_ms`` instead of stalling the wall clock, so
    wall time scales with re-issued reads, not with the backoff schedule.
"""
from __future__ import annotations

import json
import random
import time

from repro.archive import Archiver, LogArchive, SnapshotStore
from repro.core import Database, Strategy, recover, recovered_state
from repro.faults import (KIND_UNAVAILABLE, FaultPlan, FaultyBackend,
                          RetryPolicy)
from repro.media import MemoryBackend, cold_restore

from .recovery_bench import _quiet_gc, _redo_setup


def _archived_primary(fast: bool):
    """A sealed + snapshotted primary on a MemoryBackend; returns
    ``(inner_backend, expected_state)`` where expected is the live
    primary's committed state (every txn below is committed)."""
    rng = random.Random(11)
    n_rows = 400 if fast else 1500
    rows = [(f"k{i:05d}".encode(), bytes((i % 251,)) * 40)
            for i in range(n_rows)]
    db = Database(page_size=4096, cache_pages=256, tracker_interval=50,
                  bg_flush_per_txn=2)
    db.load_table("t", rows)
    for _ in range(300 if fast else 1200):
        k = rows[rng.randrange(n_rows)][0]
        db.run_txn([("update", "t", k,
                     bytes((rng.randrange(251),)) * 32)])
    inner = MemoryBackend()
    arch = LogArchive(segment_records=16, backend=inner)
    snaps = SnapshotStore()
    archiver = Archiver(db, archive=arch, snapshots=snaps)
    snaps.take(db, chunk_keys=16)
    archiver.run_once()
    return inner, dict(db.scan_all())


def bench_restore_under_outage(fast: bool) -> tuple[list[dict], int]:
    """Restore wall vs injected transient-fault count, oracle-asserted.
    Returns the rows plus the fault-free restore's backend op count (the
    scale factor for the hook-overhead bound)."""
    inner, expected = _archived_primary(fast)

    # fault-free pass: the oracle for every faulted pass, and the op
    # count one restore actually performs
    probe = FaultPlan()
    db0, stats0 = cold_restore(FaultyBackend(inner, probe), page_size=4096)
    state0 = dict(db0.scan_all())
    assert state0 == expected, \
        "fault-free cold restore diverged from the live primary"
    n_ops = probe.total_ops

    rows = []
    for n_faults in (0, 4, 16):
        best_ms, last = float("inf"), None
        for rep in range(2):
            # fresh plan per repetition: FaultPlan carries campaign state
            plan = FaultPlan.generate(
                seed=1000 + n_faults, n_faults=n_faults,
                ops=("get", "get_head", "list"),
                kinds=(KIND_UNAVAILABLE,), window=max(n_ops, 1))
            retry = RetryPolicy(max_attempts=8, seed=n_faults + rep)
            with _quiet_gc():
                t0 = time.perf_counter()
                db, stats = cold_restore(FaultyBackend(inner, plan),
                                         page_size=4096, retry=retry)
                wall_ms = (time.perf_counter() - t0) * 1e3
            assert dict(db.scan_all()) == state0, \
                f"restore under {n_faults} transient faults diverged " \
                "from the fault-free restore"
            injected = len(plan.injected)
            assert n_faults == 0 or injected > 0, \
                "campaign injected nothing — window misses every op"
            if wall_ms < best_ms:
                best_ms, last = wall_ms, (retry, injected, plan.total_ops)
        retry, injected, total_ops = last
        rows.append({
            "name": f"faults/restore@faults={n_faults}",
            "us_per_call": best_ms * 1e3 / max(total_ops, 1),
            "restore_wall_ms": round(best_ms, 2),
            "backend_ops": total_ops,
            "injected": injected,
            "retries": retry.retries,
            "backoff_charged_ms": round(retry.slept_ms, 3),
            "derived": f"{injected} outages absorbed by "
                       f"{retry.retries} retries "
                       f"(charged {retry.slept_ms:.1f}ms, "
                       f"wall {best_ms:.1f}ms) ok=True",
        })
    return rows, n_ops


def bench_hook_overhead(fast: bool, n_restore_ops: int) -> list[dict]:
    """The disabled-injection bound: per-op hook delta measured hot,
    scaled by a real restore's op count, < 5% of batched Log1 redo."""
    s, image, oracle = _redo_setup(fast)
    kw = dict(cache_pages=s.cache_pages, batched=True, batch_window=8192)
    t_redo = float("inf")
    with _quiet_gc():
        recover(image, Strategy.LOG1, **kw)        # warm decode caches
        for _ in range(5):
            db, st = recover(image, Strategy.LOG1, **kw)
            t_redo = min(t_redo, st.redo_wall_ms)
    assert recovered_state(db) == oracle, \
        "batched Log1 redo diverged from the committed-state oracle"

    # hot per-op cost, bare vs hooked; the payload copy cancels in the
    # subtraction, so what remains is the match() counter + empty scan
    inner = MemoryBackend()
    payload = bytes(64)
    inner.put("b", payload)
    hooked = FaultyBackend(MemoryBackend(), FaultPlan())
    hooked.put("b", payload)
    n = 50_000 if fast else 200_000
    with _quiet_gc():
        t0 = time.perf_counter()
        for _ in range(n):
            inner.get("b")
        t_bare = (time.perf_counter() - t0) * 1e3 / n
        t0 = time.perf_counter()
        for _ in range(n):
            hooked.get("b")
        t_hook = (time.perf_counter() - t0) * 1e3 / n
    delta_ms = max(t_hook - t_bare, 0.0)
    hook_ms = delta_ms * n_restore_ops
    frac = hook_ms / max(t_redo, 1e-9)
    assert frac <= 0.05, \
        f"disabled injection hook costs {hook_ms:.3f}ms over " \
        f"{n_restore_ops} backend ops ({frac:.1%} of the {t_redo:.2f}ms " \
        "batched Log1 redo wall) — above the 5% CI bound"
    return [{
        "name": "faults/hook_overhead",
        "us_per_call": delta_ms * 1e3,
        "redo_wall_ms": round(t_redo, 2),
        "hook_ms": round(hook_ms, 4),
        "hook_frac": round(frac, 5),
        "restore_ops": n_restore_ops,
        "derived": f"hook {frac:.2%} of {t_redo:.1f}ms redo wall "
                   f"({delta_ms*1e3:.3f}us/op x {n_restore_ops} ops) "
                   "ok=True",
    }]


def run(fast: bool = False) -> dict:
    rows, n_ops = bench_restore_under_outage(fast)
    rows = bench_hook_overhead(fast, n_ops) + rows
    return {"name": "faults", "rows": rows}


if __name__ == "__main__":
    print(json.dumps(run(fast=True), indent=1))
