"""Aggregate the dry-run artifacts into the §Roofline table
(artifacts/dryrun/*.json -> markdown + JSON)."""
from __future__ import annotations

import json
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "dryrun"


def load_cells(mesh: str = "single") -> list[dict]:
    cells = []
    for f in sorted(ART.glob(f"*__{mesh}.json")):
        cells.append(json.loads(f.read_text()))
    return cells


def fmt_s(x: float) -> str:
    if x == 0:
        return "0"
    if x < 1e-3:
        return f"{x*1e6:.1f}us"
    if x < 1:
        return f"{x*1e3:.1f}ms"
    return f"{x:.2f}s"


def markdown_table(mesh: str = "single") -> str:
    cells = load_cells(mesh)
    lines = [
        "| arch | shape | compute | memory | collective | dominant | "
        "roofline-frac | useful-FLOP-ratio | peak GB/dev |",
        "|---|---|---|---|---|---|---|---|---|",
    ]
    for c in cells:
        if c.get("status") == "skipped":
            lines.append(f"| {c['arch']} | {c['shape']} | — | — | — | "
                         f"skipped: {c['reason'][:60]} | | | |")
            continue
        if c.get("status") != "ok":
            lines.append(f"| {c['arch']} | {c['shape']} | ERROR | | | | | | |")
            continue
        r = c["roofline"]
        peak = (c["memory"].get("peak_bytes") or 0) / 1e9
        lines.append(
            f"| {c['arch']} | {c['shape']} | {fmt_s(r['compute_s'])} | "
            f"{fmt_s(r['memory_s'])} | {fmt_s(r['collective_s'])} | "
            f"{r['dominant'].replace('_s','')} | "
            f"{r['roofline_fraction']:.3f} | "
            f"{r.get('useful_flop_ratio', 0):.3f} | {peak:.2f} |")
    return "\n".join(lines)


def run(fast: bool = False) -> dict:
    cells = load_cells("single")
    ok = [c for c in cells if c.get("status") == "ok"]
    return {"name": "roofline_table",
            "n_ok": len(ok),
            "n_skipped": sum(1 for c in cells if c.get("status") == "skipped"),
            "n_error": sum(1 for c in cells if c.get("status") == "error"),
            "rows": [{
                "arch": c["arch"], "shape": c["shape"],
                **{k: c["roofline"][k] for k in
                   ("compute_s", "memory_s", "collective_s", "dominant",
                    "roofline_fraction")},
            } for c in ok]}


if __name__ == "__main__":
    print(markdown_table("single"))
