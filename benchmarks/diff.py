"""Bench-artifact regression gate.

    PYTHONPATH=src python -m benchmarks.diff [--json]   (or: make bench-diff)

Compares the newest ``artifacts/bench_<n>.json`` against the previous run
*of the same mode* (fast vs full — their absolute numbers are not
comparable) and fails loudly on a >2x ``us_per_call`` regression in any
oracle-asserted row.  Only the modules whose rows carry correctness
oracles are gated: a 2x slide there is a real pipeline regression, not a
tuning drift in an informational table.  With fewer than two comparable
artifacts the gate is a no-op pass — the first run of a fresh checkout
has nothing to diff against.

Artifact hygiene: a bench_<n>.json that cannot be read or parsed (a
truncated write, a corrupted checkout) is *warned about by name*, never
silently skipped — a gate that quietly ignores its own baseline is not a
gate.  If the artifact that cannot be read is the newest one, there is
nothing trustworthy to judge, so the gate warns and no-op passes rather
than judging the current commit against a stale pair.

``--json`` emits one machine-readable verdict object (same spirit as
``reprolint --json``) so CI consumes every gate in a uniform shape.
"""
from __future__ import annotations

import json
import re
import sys
from pathlib import Path
from typing import Optional

ART_ROOT = Path(__file__).resolve().parents[1] / "artifacts"

# modules whose rows are oracle-asserted (recovered state checked against
# the committed-state oracle / acceptance bounds inside the bench itself)
GUARDED_MODULES = {"recovery_pipeline", "pagepack", "replication",
                   "parallel_apply", "archive", "media", "faults"}
THRESHOLD = 2.0
# rows faster than this are pure timer noise at 2x granularity
MIN_US = 50.0


def scan_artifacts(root: Optional[Path] = None
                   ) -> tuple[list[dict], list[str], bool]:
    """``(summaries oldest-first, warnings, newest_unreadable)``.

    Every ``bench_<n>.json`` that matches the name pattern but cannot be
    read/parsed produces a warning naming the file and the error;
    ``newest_unreadable`` is True when the artifact with the highest run
    index is among them (the gate's subject is untrustworthy)."""
    root = ART_ROOT if root is None else root
    entries: list[tuple[int, Optional[dict]]] = []
    warnings: list[str] = []
    for p in sorted(root.glob("bench_*.json")):
        m = re.fullmatch(r"bench_(\d+)\.json", p.name)
        if not m:
            continue
        try:
            entries.append((int(m.group(1)), json.loads(p.read_text())))
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as exc:
            warnings.append(f"unreadable bench artifact {p.name}: "
                            f"{type(exc).__name__}: {exc}")
            entries.append((int(m.group(1)), None))
    entries.sort(key=lambda t: t[0])
    newest_unreadable = bool(entries) and entries[-1][1] is None
    return ([r for _, r in entries if r is not None], warnings,
            newest_unreadable)


def load_runs(root: Optional[Path] = None) -> list[dict]:
    """All readable bench summaries, oldest first (compat shim over
    ``scan_artifacts`` — warnings are the caller's job there)."""
    runs, _, _ = scan_artifacts(root)
    return runs


def compare_runs(old: dict, new: dict,
                 threshold: float = THRESHOLD) -> list[str]:
    """Regression lines for guarded rows that got > ``threshold``x slower
    between two summaries (rows present in both, by module+name)."""
    prev = {(r["module"], r["name"]): r for r in old.get("rows", [])
            if r.get("module") in GUARDED_MODULES}
    regressions = []
    for r in new.get("rows", []):
        if r.get("module") not in GUARDED_MODULES:
            continue
        p = prev.get((r["module"], r["name"]))
        if p is None:
            continue
        a, b = p.get("us_per_call"), r.get("us_per_call")
        if not a or not b or a < MIN_US:
            continue
        if b > a * threshold:
            regressions.append(
                f"{r['module']}/{r['name']}: {a:.1f}us -> {b:.1f}us "
                f"({b / a:.2f}x, threshold {threshold:.1f}x)")
    return regressions


def diff(root: Optional[Path] = None) -> dict:
    """The gate as data: ``{ok, status, detail, warnings, regressions,
    old_run, new_run, mode, threshold}``.  ``ok`` is False only for real
    regressions — missing/unreadable baselines degrade to a loud pass."""
    out = {"ok": True, "status": "", "detail": "", "warnings": [],
           "regressions": [], "old_run": None, "new_run": None,
           "mode": None, "threshold": THRESHOLD}
    runs, warnings, newest_unreadable = scan_artifacts(root)
    out["warnings"] = warnings
    if newest_unreadable:
        out["status"] = "newest-unreadable"
        out["detail"] = ("the newest bench artifact is unreadable — "
                         "nothing trustworthy to judge; re-run "
                         "`make bench-smoke` to lay down a fresh baseline")
        return out
    if not runs:
        out["status"] = "no-artifacts"
        out["detail"] = "no bench artifacts yet — nothing to compare"
        return out
    new = runs[-1]
    out["new_run"], out["mode"] = new.get("run"), new.get("mode")
    olds = [r for r in runs[:-1] if r.get("mode") == new.get("mode")]
    if not olds:
        out["status"] = "first-of-mode"
        out["detail"] = (f"run {new.get('run')} is the first "
                         f"{new.get('mode')}-mode artifact — nothing "
                         "to compare")
        return out
    old = olds[-1]
    out["old_run"] = old.get("run")
    out["regressions"] = compare_runs(old, new)
    out["ok"] = not out["regressions"]
    out["status"] = "regressions" if out["regressions"] else "clean"
    return out


def main(argv: Optional[list[str]] = None) -> int:
    args = sys.argv[1:] if argv is None else argv
    as_json = "--json" in args
    verdict = diff()
    if as_json:
        print(json.dumps(verdict, indent=1))
        return 0 if verdict["ok"] else 1
    for w in verdict["warnings"]:
        print(f"bench-diff: WARNING: {w}", file=sys.stderr)
    if verdict["status"] in ("newest-unreadable", "no-artifacts",
                             "first-of-mode"):
        print(f"bench-diff: {verdict['detail']}")
        return 0
    label = (f"run {verdict['old_run']} -> {verdict['new_run']} "
             f"({verdict['mode']} mode)")
    if verdict["regressions"]:
        print(f"bench-diff: {len(verdict['regressions'])} "
              f"regression(s) {label}:")
        for line in verdict["regressions"]:
            print(f"  REGRESSION {line}")
        return 1
    print(f"bench-diff: no >{THRESHOLD:.0f}x regressions in "
          f"oracle-asserted rows, {label}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
