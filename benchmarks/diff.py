"""Bench-artifact regression gate.

    PYTHONPATH=src python -m benchmarks.diff        (or: make bench-diff)

Compares the newest ``artifacts/bench_<n>.json`` against the previous run
*of the same mode* (fast vs full — their absolute numbers are not
comparable) and fails loudly on a >2x ``us_per_call`` regression in any
oracle-asserted row.  Only the modules whose rows carry correctness
oracles are gated: a 2x slide there is a real pipeline regression, not a
tuning drift in an informational table.  With fewer than two comparable
artifacts the gate is a no-op pass — the first run of a fresh checkout
has nothing to diff against.
"""
from __future__ import annotations

import json
import re
import sys
from pathlib import Path

ART_ROOT = Path(__file__).resolve().parents[1] / "artifacts"

# modules whose rows are oracle-asserted (recovered state checked against
# the committed-state oracle / acceptance bounds inside the bench itself)
GUARDED_MODULES = {"recovery_pipeline", "replication", "parallel_apply",
                   "archive", "media"}
THRESHOLD = 2.0
# rows faster than this are pure timer noise at 2x granularity
MIN_US = 50.0


def load_runs(root: Path = ART_ROOT) -> list[dict]:
    """All bench summaries, oldest first."""
    runs = []
    for p in sorted(root.glob("bench_*.json")):
        m = re.fullmatch(r"bench_(\d+)\.json", p.name)
        if not m:
            continue
        try:
            runs.append((int(m.group(1)), json.loads(p.read_text())))
        except (OSError, json.JSONDecodeError):
            continue
    runs.sort(key=lambda t: t[0])
    return [r for _, r in runs]


def compare_runs(old: dict, new: dict,
                 threshold: float = THRESHOLD) -> list[str]:
    """Regression lines for guarded rows that got > ``threshold``x slower
    between two summaries (rows present in both, by module+name)."""
    prev = {(r["module"], r["name"]): r for r in old.get("rows", [])
            if r.get("module") in GUARDED_MODULES}
    regressions = []
    for r in new.get("rows", []):
        if r.get("module") not in GUARDED_MODULES:
            continue
        p = prev.get((r["module"], r["name"]))
        if p is None:
            continue
        a, b = p.get("us_per_call"), r.get("us_per_call")
        if not a or not b or a < MIN_US:
            continue
        if b > a * threshold:
            regressions.append(
                f"{r['module']}/{r['name']}: {a:.1f}us -> {b:.1f}us "
                f"({b / a:.2f}x, threshold {threshold:.1f}x)")
    return regressions


def main() -> int:
    runs = load_runs()
    if not runs:
        print("bench-diff: no bench artifacts yet — nothing to compare")
        return 0
    new = runs[-1]
    olds = [r for r in runs[:-1] if r.get("mode") == new.get("mode")]
    if not olds:
        print(f"bench-diff: run {new.get('run')} is the first "
              f"{new.get('mode')}-mode artifact — nothing to compare")
        return 0
    old = olds[-1]
    regressions = compare_runs(old, new)
    label = (f"run {old.get('run')} -> {new.get('run')} "
             f"({new.get('mode')} mode)")
    if regressions:
        print(f"bench-diff: {len(regressions)} regression(s) {label}:")
        for line in regressions:
            print(f"  REGRESSION {line}")
        return 1
    print(f"bench-diff: no >{THRESHOLD:.0f}x regressions in "
          f"oracle-asserted rows, {label}")
    return 0


if __name__ == "__main__":
    sys.exit(main())
