"""Appendix D reproduction: the DPT-construction spectrum.

  paper    — DirtySet + WrittenSet + FW-LSN + FirstDirty  (Section 4.1)
  perfect  — D.1: exact per-update LSNs in Delta records (DPT == SQL's)
  reduced  — D.2: no FW-LSN/FirstDirty; coarser rLSNs, prune only prior
             intervals' entries

Trade-off measured: Delta-record payload (logging overhead during normal
execution) vs DPT size / redo time."""
from __future__ import annotations

import json
from dataclasses import replace

from repro.core import Strategy
from repro.core.records import DeltaRec

from .harness import BenchSetup, build_crash_image, run_all_strategies


def _delta_payload(image) -> int:
    total = 0
    for rec in image.log.scan(1):
        if isinstance(rec, DeltaRec):
            total += 8 * (len(rec.dirty_set) + len(rec.written_set)) + 24
            if rec.dirty_lsns is not None:
                total += 8 * len(rec.dirty_lsns)
    return total


def run(fast: bool = False) -> dict:
    setup = BenchSetup(n_rows=30_000 if fast else 100_000,
                       cache_pages=512,
                       ckpt_updates=1_000 if fast else 4_000, n_ckpts=2)
    rows = []
    for mode in ("paper", "perfect", "reduced"):
        s = replace(setup, delta_mode=mode)
        image, base, info = build_crash_image(s)
        res = run_all_strategies(image, base, s,
                                 strategies=[Strategy.LOG1, Strategy.SQL1])
        log1 = next(r for r in res if r.strategy == "Log1")
        sql1 = next(r for r in res if r.strategy == "SQL1")
        rows.append({
            "delta_mode": mode,
            "delta_payload_bytes": _delta_payload(image),
            "log1_modeled_ms": round(log1.modeled_ms, 1),
            "log1_dpt": log1.dpt_size,
            "log1_fetches": log1.fetches,
            "sql1_dpt": sql1.dpt_size,
            "sql1_fetches": sql1.fetches,
            "correct": log1.correct and sql1.correct,
        })
    return {"name": "appendix_d_variants", "rows": rows}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
