"""Replication benchmark: the three costs that decide whether logical
log-shipping can serve read traffic at scale.

  1. apply throughput vs primary commit rate — how fast a standby's
     continuous logical redo consumes the stream, as transaction size (and
     thus commit-record overhead per op) varies;
  2. steady-state lag vs shipping batch size — small batches ship eagerly
     but pay per-poll overhead, large batches amortize it but let the
     standby fall further behind between polls;
  3. failover time vs lag — promote() must drain the un-applied tail, undo
     in-flight losers, and checkpoint; its cost is linear in how far behind
     the chosen standby was.

Every run cross-checks the replica (4 KiB pages) against
``committed_state_oracle`` of the 8 KiB-page primary.
"""
from __future__ import annotations

import json
import random
import time

from repro.core import Database, committed_state_oracle, make_key
from repro.replication import Replica, ReplicaSet

PAGE_PRIMARY, PAGE_REPLICA = 8192, 4096


def _setup(rng, n_rows, value_size=60):
    rows = [(f"k{i:07d}".encode(), rng.randbytes(value_size))
            for i in range(n_rows)]
    primary = Database(page_size=PAGE_PRIMARY, cache_pages=512,
                       tracker_interval=100, bg_flush_per_txn=4)
    primary.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}
    replica = Replica("r1", page_size=PAGE_REPLICA, cache_pages=1024,
                      tracker_interval=100, bg_flush_per_txn=4,
                      seed_tables={"t": rows})
    return primary, replica, rows, base


def _drive(primary, rng, n_rows, n_txns, ops_per_txn):
    for _ in range(n_txns):
        primary.run_txn([("update", "t",
                          f"k{rng.randrange(n_rows):07d}".encode(),
                          rng.randbytes(60)) for _ in range(ops_per_txn)])


def bench_apply_throughput(fast: bool) -> list[dict]:
    """Replica apply rate as the primary's commit rate (commits per op)
    varies: 1, 10 and 50 ops per transaction."""
    n_rows = 5_000 if fast else 20_000
    total_ops = 2_000 if fast else 10_000
    rows = []
    for ops_per_txn in (1, 10, 50):
        rng = random.Random(11)
        primary, replica, _, base = _setup(rng, n_rows)
        rs = ReplicaSet(primary, [replica])
        _drive(primary, rng, n_rows, total_ops // ops_per_txn, ops_per_txn)
        t0 = time.perf_counter()
        applied = rs.sync()
        wall = time.perf_counter() - t0
        ok = replica.user_state() == committed_state_oracle(
            primary.crash(), base)
        assert ok, f"replica diverged at ops_per_txn={ops_per_txn}"
        rows.append({
            "name": f"repl_apply/ops_per_txn={ops_per_txn}",
            "ops_per_txn": ops_per_txn,
            "applied_ops": applied,
            "apply_ops_per_s": round(applied / wall, 1),
            "us_per_call": wall / max(applied, 1) * 1e6,
            "derived": f"{applied / wall:,.0f} ops/s "
                       f"txns={replica.applied_txns} ok={ok}",
        })
    return rows


def bench_lag_vs_batch(fast: bool) -> list[dict]:
    """Steady-state lag: one bounded poll per committed transaction, batch
    size swept.  Lag is measured in primary-LSN units behind the last
    stable commit."""
    n_rows = 5_000 if fast else 20_000
    n_polls = 75 if fast else 300
    ops_per_txn, txns_per_poll = 10, 2     # ~24+ records produced per poll
    rows = []
    for batch in (16, 32, 256):
        rng = random.Random(12)
        primary, replica, _, base = _setup(rng, n_rows)
        rs = ReplicaSet(primary, [replica], batch_records=batch)
        lags, t_apply = [], 0.0
        for _ in range(n_polls):
            _drive(primary, rng, n_rows, txns_per_poll, ops_per_txn)
            t0 = time.perf_counter()
            rs.sync(max_records=batch)
            t_apply += time.perf_counter() - t0
            lags.append(replica.lag(primary.log))
        rs.sync()                              # drain, then cross-check
        assert replica.user_state() == committed_state_oracle(
            primary.crash(), base), f"replica diverged at batch={batch}"
        mean_lag = sum(lags) / len(lags)
        rows.append({
            "name": f"repl_lag/batch={batch}",
            "batch_records": batch,
            "mean_lag_lsn": round(mean_lag, 1),
            "max_lag_lsn": max(lags),
            "us_per_call": t_apply / n_polls * 1e6,
            "derived": f"mean_lag={mean_lag:.0f} max_lag={max(lags)} "
                       f"polls={rs.shipper.polls}",
        })
    return rows


def bench_failover_vs_lag(fast: bool) -> list[dict]:
    """Failover: crash the primary with the standby N transactions behind
    (plus one stable in-flight loser), then time promote()'s
    drain + loser-undo + end-of-recovery checkpoint."""
    n_rows = 5_000 if fast else 20_000
    ops_per_txn = 10
    rows = []
    for behind_txns in (0, 50, 200) if fast else (0, 200, 1000):
        rng = random.Random(13)
        primary, replica, _, base = _setup(rng, n_rows)
        rs = ReplicaSet(primary, [replica])
        _drive(primary, rng, n_rows, 100 if fast else 400, ops_per_txn)
        rs.sync()                                  # caught up ...
        _drive(primary, rng, n_rows, behind_txns, ops_per_txn)  # ... then not
        loser = primary.tc.begin()
        primary.tc.update(loser, "t", b"k0000001", b"LOSER")
        primary.log.flush()
        image = primary.crash()
        lag = replica.lag(image.log)
        t0 = time.perf_counter()
        new_primary = rs.promote(image=image)
        wall_ms = (time.perf_counter() - t0) * 1e3
        ok = dict(new_primary.scan_all()) == committed_state_oracle(image, base)
        assert ok, f"promoted state diverged at behind={behind_txns}"
        rows.append({
            "name": f"repl_failover/behind={behind_txns}txns",
            "behind_txns": behind_txns,
            "lag_lsn_at_crash": lag,
            "promote_ms": round(wall_ms, 2),
            "us_per_call": wall_ms * 1e3,
            "derived": f"lag={lag}lsn promote={wall_ms:.1f}ms ok={ok}",
        })
    return rows


def run(fast: bool = False) -> dict:
    rows = (bench_apply_throughput(fast) + bench_lag_vs_batch(fast)
            + bench_failover_vs_lag(fast))
    return {"name": "replication", "rows": rows}


if __name__ == "__main__":
    print(json.dumps(run(fast=True), indent=1))
