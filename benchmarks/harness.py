"""Shared benchmark harness: the paper's Section 5.2 experimental setup,
scaled for this container.

Paper setup -> ours (scale factor ~20x on rows, same structure):
  * single table, (key, data) rows, clustered index
  * update-only workload, 10 updates/txn, uniform keys (worst case, App. B)
  * warm the cache to steady state (2x cache fill) before measuring
  * crash after N checkpoints, M updates past the last one, ~100 updates
    past the last Delta/BW record (tail of the log)
  * all five strategies recover the SAME crash image over the common log
"""
from __future__ import annotations

import random
import time
from dataclasses import dataclass, field

from repro.core import (CrashImage, Database, Strategy,
                        committed_state_oracle, make_key, recover,
                        recovered_state)


@dataclass
class BenchSetup:
    n_rows: int = 100_000
    value_size: int = 100
    cache_pages: int = 1024
    tracker_interval: int = 100      # updates per Delta/BW record
    bg_flush_per_txn: int = 4
    ckpt_updates: int = 4_000        # updates per checkpoint interval
    n_ckpts: int = 3
    tail_updates: int = 100          # past the last tracker record
    ops_per_txn: int = 10
    seed: int = 0
    delta_mode: str = "paper"


@dataclass
class BenchResult:
    strategy: str
    modeled_ms: float
    wall_ms: float
    fetches: int
    sync_reads: int
    prefetch_reads: int
    dpt_size: int
    redone: int
    pruned: int
    log_records: int
    correct: bool
    n_delta_recs: int = 0
    n_bw_recs: int = 0


def build_crash_image(s: BenchSetup) -> tuple[CrashImage, dict, dict]:
    """Run the workload; returns (image, oracle_base, run_info)."""
    rng = random.Random(s.seed)
    db = Database(cache_pages=s.cache_pages,
                  tracker_interval=s.tracker_interval,
                  bg_flush_per_txn=s.bg_flush_per_txn,
                  delta_mode=s.delta_mode)
    rows = [(f"k{i:08d}".encode(), rng.randbytes(s.value_size))
            for i in range(s.n_rows)]
    db.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}

    def run_updates(n_updates: int):
        for _ in range(n_updates // s.ops_per_txn):
            db.run_txn([("update", "t",
                         f"k{rng.randrange(s.n_rows):08d}".encode(),
                         rng.randbytes(s.value_size))
                        for _ in range(s.ops_per_txn)])

    # warm to steady state: 2x the cache size in page touches
    run_updates(max(2 * s.cache_pages, 2000))
    for _ in range(s.n_ckpts):
        db.checkpoint()
        run_updates(s.ckpt_updates)
    run_updates(s.tail_updates)          # tail past the last tracker record
    info = {
        "n_delta_recs": db.dc.n_delta_recs,
        "n_bw_recs": db.dc.n_bw_recs,
        "stable_pages": len(db.store),
        "leaf_pages": None,
        "dirty_at_crash": len(db.dc.pool.dirty_pids()),
        "log_len": db.log.end_lsn,
    }
    return db.crash(), base, info


def run_all_strategies(image: CrashImage, base: dict, s: BenchSetup,
                       check: bool = True,
                       strategies=None) -> list[BenchResult]:
    oracle = committed_state_oracle(image, base) if check else None
    out = []
    for strat in (strategies or list(Strategy)):
        t0 = time.perf_counter()
        db, st = recover(image, strat, cache_pages=s.cache_pages,
                         delta_mode=s.delta_mode)
        wall = (time.perf_counter() - t0) * 1e3
        ok = (recovered_state(db) == oracle) if check else True
        out.append(BenchResult(
            strategy=strat.value,
            modeled_ms=st.io.modeled_ms,
            wall_ms=wall,
            fetches=st.io.total_reads(),
            sync_reads=st.io.sync_reads,
            prefetch_reads=st.io.prefetch_reads,
            dpt_size=st.dpt_size,
            redone=st.redo.redone,
            pruned=st.redo.skipped_dpt,
            log_records=st.log_records,
            correct=ok))
    return out
