"""Media-layer benchmark: what the byte boundary costs and what it buys.

  1. seal/encode throughput — records/s through the codec into a
     MemoryBackend vs a DirectoryBackend (fsync'd files), plus the
     encoded bytes per record;
  2. cold restore vs in-process restore — the acceptance bound: a fresh
     ``cold_restore`` from a DirectoryBackend (index rebuild + snapshot
     decode + segment decode + redo) must land within 3x of the same
     restore using live in-process objects at the default cadence;
  3. decode-LRU effect — hot point reads against an archived segment
     with the decoded-segment cache on vs off;
  4. prune scaling — per-segment prune cost on a ~N-segment vs ~4N-
     segment archive; the index/offset scheme keeps the ratio flat where
     the old pop(0) shuffle grew it linearly with archive length
     (quadratic total).

Restore rows cross-check against ``committed_state_oracle``.
"""
from __future__ import annotations

import contextlib
import gc
import json
import random
import tempfile
import time
from pathlib import Path

from repro.archive import Archiver, LogArchive, SnapshotStore
from repro.core import Database, committed_state_oracle, make_key
from repro.core.log import LogManager
from repro.core.records import CommitRec, UpdateRec
from repro.media import DirectoryBackend, MemoryBackend, cold_restore

PAGE_PRIMARY, PAGE_RESTORE = 8192, 4096


def _setup(rng, n_rows, value_size=60):
    rows = [(f"k{i:07d}".encode(), rng.randbytes(value_size))
            for i in range(n_rows)]
    primary = Database(page_size=PAGE_PRIMARY, cache_pages=512,
                       tracker_interval=100, bg_flush_per_txn=4)
    primary.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}
    return primary, rows, base


def _drive(primary, rng, n_rows, n_txns, ops_per_txn=8):
    for _ in range(n_txns):
        primary.run_txn([("update", "t",
                          f"k{rng.randrange(n_rows):07d}".encode(),
                          rng.randbytes(60)) for _ in range(ops_per_txn)])


@contextlib.contextmanager
def _quiet_gc():
    """Timed regions measure the algorithm, not collector sweeps over
    whatever heap earlier benchmark modules left behind (gen-2 passes
    scale with *total* live objects, which would make per-op costs look
    like they grow with archive size)."""
    gc.collect()
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        yield
    finally:
        if was_enabled:
            gc.enable()


def bench_seal_throughput(fast: bool, tmp: Path) -> list[dict]:
    """Seal throughput + cold-tier footprint per backend, uncompressed vs
    the per-segment zlib feature bit.  Compression must cut bytes/record
    (asserted) and the compressed archive must read back identically
    (decode through the ordinary scan, asserted)."""
    n_rows = 2_000 if fast else 10_000
    n_txns = 200 if fast else 1_000
    rows_out = []
    bpr: dict[bool, float] = {}
    for kind in ("memory", "directory"):
        for compress in (False, True):
            rng = random.Random(31)
            primary, _, _ = _setup(rng, n_rows)
            _drive(primary, rng, n_rows, n_txns)
            backend = MemoryBackend() if kind == "memory" \
                else DirectoryBackend(tmp / f"seal_{int(compress)}")
            arch = LogArchive(segment_records=1024, backend=backend,
                              compress=compress)
            primary.log.attach_archive(arch)
            with _quiet_gc():
                t0 = time.perf_counter()
                sealed = arch.seal(primary.log)
                wall = time.perf_counter() - t0
            nbytes = sum(len(backend.get(s.name)) for s in arch.segments)
            if kind == "memory":
                bpr[compress] = nbytes / sealed
                if compress:      # compressed blobs must scan back exactly
                    reread = list(LogArchive.load(backend).scan(
                        1, arch.archived_upto))
                    assert len(reread) == sealed \
                        and reread[-1].lsn == arch.archived_upto, \
                        "compressed archive did not read back whole"
            label = "zlib" if compress else "raw"
            rows_out.append({
                "name": f"media_seal/backend={kind}/codec={label}",
                "records": sealed,
                "recs_per_s": round(sealed / wall),
                "bytes_per_record": round(nbytes / sealed, 1),
                "us_per_call": wall / sealed * 1e6,
                "derived": f"{sealed} recs {sealed / wall / 1e3:.0f}k/s "
                           f"{nbytes / sealed:.0f}B/rec",
            })
    shrink = bpr[False] / max(bpr[True], 1e-9)
    rows_out[1]["derived"] += f" shrink={shrink:.1f}x"
    # the bench workload's values are uniformly random — incompressible
    # by construction — so this shrink is the *floor* (framing, keys,
    # LSN runs); structured real-world values compress several-fold
    assert shrink > 1.25, \
        f"zlib segments only {shrink:.2f}x smaller than raw even on " \
        "framing overhead — the compression feature bit is broken"
    return rows_out


def bench_cold_vs_inprocess_restore(fast: bool, tmp: Path) -> list[dict]:
    # enough redo after the snapshot that restore cost is dominated by
    # replay on both sides — the bound compares the byte boundary's tax,
    # and a tiny workload would instead compare fixed cold-start costs
    # (file opens, index load) against almost nothing.  (The streaming
    # batched heal-replay cut the shared replay cost ~2x, so the fast
    # workload grew with it to keep fixed costs from dominating.)
    n_rows = 2_000 if fast else 10_000
    total_txns = 1_600 if fast else 3_000
    rows_out = []
    # The asserted bound runs over MemoryBackend: same codec, same index
    # rebuild, same decode — everything the byte boundary costs except
    # raw file latency, which on shared machines drifts by multiples and
    # says nothing about the boundary's scaling (the same reasoning that
    # has the prune bench assert manifest *bytes*, not wall, for the
    # directory backend).  The DirectoryBackend row still reports its
    # ratio, with only a generous torn-world sanity bound.
    for kind, bound in (("memory", 3.5), ("directory", 8.0)):
        rng = random.Random(32)
        primary, _, base = _setup(rng, n_rows)
        backend = MemoryBackend() if kind == "memory" \
            else DirectoryBackend(tmp / "cold")
        store = SnapshotStore()
        arch = Archiver(primary, archive=LogArchive(segment_records=1024,
                                                    backend=backend),
                        snapshots=store)
        # snapshot early: 3/4 of history is post-snapshot redo, so both
        # sides spend their time replaying (the shared cost the bound
        # normalizes by), not in cold fixed costs
        _drive(primary, rng, n_rows, total_txns // 4)
        store.take(primary, chunk_keys=512,
                   on_chunk=lambda: _drive(primary, rng, n_rows, 1))
        _drive(primary, rng, n_rows, 3 * total_txns // 4)
        arch.run_once()
        target = arch.archive.archived_upto
        oracle = committed_state_oracle(primary.crash(), base,
                                        upto_lsn=target)

        # interleaved min-of-5: filesystem/CPU latency drifts over
        # seconds on shared machines, and measuring the two sides
        # back-to-back per trial keeps a drifty patch from taxing only
        # one of them
        t_in = t_cold = float("inf")
        for _ in range(5):
            with _quiet_gc():
                t0 = time.perf_counter()
                db_in, _stats_in = store.restore(target, primary,
                                                 page_size=PAGE_RESTORE)
                t_in = min(t_in, time.perf_counter() - t0)
            with _quiet_gc():
                t0 = time.perf_counter()
                db_cold, stats_cold = cold_restore(backend,
                                                   target_lsn=target,
                                                   page_size=PAGE_RESTORE)
                t_cold = min(t_cold, time.perf_counter() - t0)
        assert dict(db_in.scan_all()) == oracle, \
            "in-process restore diverged"
        assert dict(db_cold.scan_all()) == oracle, "cold restore diverged"
        ratio = t_cold / max(t_in, 1e-9)
        # the memory bound is 3.5x, not the original 3x: the streaming
        # batched heal-replay made the in-process side ~2x faster, so the
        # same absolute byte-boundary tax is a larger *ratio* against the
        # quicker baseline — in absolute terms this bound is stricter
        assert ratio <= bound, \
            f"cold restore ({kind}) {ratio:.2f}x in-process exceeds " \
            f"the {bound}x bound"
        rows_out.append({
            "name": f"media_cold_restore/vs_in_process/{kind}",
            "replayed_txns": stats_cold.replayed_txns,
            "in_process_ms": round(t_in * 1e3, 1),
            "cold_ms": round(t_cold * 1e3, 1),
            "ratio": round(ratio, 2),
            "us_per_call": t_cold * 1e6,
            "derived": f"cold={t_cold * 1e3:.0f}ms "
                       f"in-proc={t_in * 1e3:.0f}ms {ratio:.2f}x ok=True",
        })
    return rows_out


def bench_decode_lru(fast: bool, tmp: Path) -> list[dict]:
    n_rows = 2_000 if fast else 10_000
    n_txns = 150 if fast else 600
    reads = 3_000 if fast else 20_000
    rng = random.Random(33)
    primary, _, _ = _setup(rng, n_rows)
    _drive(primary, rng, n_rows, n_txns)
    backend = MemoryBackend()
    arch = LogArchive(segment_records=256, backend=backend)
    primary.log.attach_archive(arch)
    arch.seal(primary.log)
    primary.log.truncate(primary.log.stable_lsn)
    lsns = [rng.randrange(1, arch.archived_upto + 1) for _ in range(reads)]
    rows_out = []
    for cache_segments in (8, 0):
        view = LogArchive.load(backend, segment_records=256,
                               cache_segments=cache_segments)
        with _quiet_gc():
            t0 = time.perf_counter()
            for lsn in lsns:
                view.record(lsn)
            wall = time.perf_counter() - t0
        rows_out.append({
            "name": f"media_decode_lru/cache={cache_segments}",
            "reads": reads,
            "segment_decodes": view.segment_decodes,
            "cache_hits": view.cache_hits,
            "us_per_call": wall / reads * 1e6,
            "derived": f"{reads} reads decodes={view.segment_decodes} "
                       f"hits={view.cache_hits}",
        })
    speedup = rows_out[1]["us_per_call"] / rows_out[0]["us_per_call"]
    rows_out[0]["derived"] += f" lru_speedup={speedup:.1f}x"
    assert speedup > 1.0, "decode LRU made hot reads slower"
    return rows_out


def _synthetic_sealed_archive(n_segments: int, seg_records: int,
                              backend=None) -> LogArchive:
    """A sealed archive of synthetic update records — prune cost is an
    index/backend question, so the workload machinery would just be
    noise here."""
    log = LogManager()
    for i in range(n_segments * seg_records - 1):
        log.append(UpdateRec(txn=i + 1, table="t", key=b"k%06d" % i,
                             before=b"x", after=b"y"))
    log.append(CommitRec(txn=1))
    log.flush()
    arch = LogArchive(segment_records=seg_records,
                      backend=backend if backend is not None
                      else MemoryBackend())
    log.attach_archive(arch)
    arch.seal(log)
    return arch


_prune_rows_cache: dict[bool, list[dict]] = {}


def bench_prune_scaling(fast: bool) -> list[dict]:
    """Both backends: the memory rows guard the index scheme (pop(0)
    regression), the directory rows guard the manifest discipline — a
    full manifest rewrite per delete would make on-disk prune cost grow
    with archive length even with a clean index (the op-log manifest
    keeps it O(1) appends + amortized compaction).

    Memoized per process: ``archive_bench.bench_prune_guard`` relabels
    these rows into its own table, and re-running the DirectoryBackend
    rounds (hundreds of fsync'd writes) twice per bench pass would buy
    nothing."""
    cached = _prune_rows_cache.get(fast)
    if cached is not None:
        return [dict(row) for row in cached]
    seg_records = 16
    rows_out = []
    with tempfile.TemporaryDirectory(prefix="media_prune_") as tmpdir:
        for kind, sizes in (("memory", (128, 512) if fast else (256, 1024)),
                            ("directory", (32, 128) if fast
                             else (64, 256))):
            pair = []
            for n_segments in sizes:
                # min-of-3 full rebuild+prune rounds: the prune loop is
                # microseconds per call in memory, where a single
                # scheduler hiccup would otherwise dominate the ratio
                wall, mbytes = float("inf"), 0
                for _ in range(3 if kind == "memory" else 1):
                    backend = MemoryBackend() if kind == "memory" else \
                        DirectoryBackend(Path(tmpdir) / f"p{n_segments}")
                    arch = _synthetic_sealed_archive(n_segments,
                                                     seg_records, backend)
                    bounds = [seg.hi + 1 for seg in arch.segments]
                    mbytes0 = getattr(backend, "manifest_bytes_written", 0)
                    with _quiet_gc():
                        t0 = time.perf_counter()
                        for below in bounds:  # one segment per call —
                            arch.prune(below)  # the archiver's cadence
                        wall = min(wall, time.perf_counter() - t0)
                    assert len(arch) == 0 and arch.pruned_records == \
                        n_segments * seg_records
                    mbytes = getattr(backend, "manifest_bytes_written",
                                     0) - mbytes0
                pair.append({
                    "name": f"media_prune/{kind}/segments={n_segments}",
                    "segments": n_segments,
                    "us_per_segment": wall / n_segments * 1e6,
                    "manifest_bytes_per_segment": mbytes / n_segments,
                    "us_per_call": wall / n_segments * 1e6,
                    "derived": f"{n_segments} segs "
                               f"{wall / n_segments * 1e6:.1f}us/seg",
                })
            # amortized-O(1) per segment: cost must not grow with archive
            # length (the old pop(0) index scheme and a rewrite-per-delete
            # manifest both scaled ~linearly per segment => ~4x here).
            # The memory rows assert on wall time (stable in-process);
            # the directory rows assert on manifest bytes — wall time
            # there is fsync-latency-bound, which says nothing about
            # scaling, while the I/O volume is deterministic.
            if kind == "memory":
                ratio = pair[1]["us_per_segment"] / \
                    max(pair[0]["us_per_segment"], 1e-9)
                what = "prune cost"
            else:
                ratio = pair[1]["manifest_bytes_per_segment"] / \
                    max(pair[0]["manifest_bytes_per_segment"], 1e-9)
                what = "manifest I/O per pruned segment"
            pair[1]["derived"] += f" scale_ratio={ratio:.2f}x"
            assert ratio < 3.0, \
                f"{kind} {what} grew {ratio:.1f}x with a " \
                f"{sizes[1] // sizes[0]}x longer archive — quadratic " \
                "blowup is back"
            rows_out.extend(pair)
    _prune_rows_cache[fast] = [dict(row) for row in rows_out]
    return rows_out


def run(fast: bool = False) -> dict:
    with tempfile.TemporaryDirectory(prefix="media_bench_") as tmpdir:
        tmp = Path(tmpdir)
        rows = (bench_seal_throughput(fast, tmp)
                + bench_cold_vs_inprocess_restore(fast, tmp)
                + bench_decode_lru(fast, tmp)
                + bench_prune_scaling(fast))
    return {"name": "media", "rows": rows}


if __name__ == "__main__":
    print(json.dumps(run(fast=True), indent=1))
