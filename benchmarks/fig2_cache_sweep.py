"""Figure 2 reproduction: redo statistics vs database cache size.

2(a) redo time per strategy, 2(b) DPT size as a fraction of cache,
2(c) Delta-log vs BW-log record counts — one common log per cache size.
Cache sizes sweep ~2%..60% of the data pages, mirroring 64MB..2048MB over a
3.5GB table in the paper.
"""
from __future__ import annotations

import json
from dataclasses import replace

from .harness import BenchSetup, build_crash_image, run_all_strategies


def run(fast: bool = False) -> dict:
    base_setup = BenchSetup(n_rows=30_000 if fast else 100_000,
                            ckpt_updates=1_000 if fast else 4_000,
                            n_ckpts=2 if fast else 3)
    # data pages ~ n_rows * 122B / (8192*0.7); sweep 2%..60%
    n_pages = base_setup.n_rows * (base_setup.value_size + 22) // 5734
    caches = [max(32, int(n_pages * f)) for f in (0.02, 0.1, 0.25, 0.6)]
    rows = []
    for cache in caches:
        s = replace(base_setup, cache_pages=cache)
        image, base, info = build_crash_image(s)
        results = run_all_strategies(image, base, s)
        for r in results:
            rows.append({
                "cache_pages": cache,
                "cache_frac": round(cache / n_pages, 3),
                "strategy": r.strategy,
                "modeled_ms": round(r.modeled_ms, 1),
                "wall_ms": round(r.wall_ms, 1),
                "fetches": r.fetches,
                "dpt_size": r.dpt_size,
                "dpt_frac_of_cache": round(r.dpt_size / cache, 3),
                "n_delta_recs": info["n_delta_recs"],
                "n_bw_recs": info["n_bw_recs"],
                "dirty_at_crash": info["dirty_at_crash"],
                "correct": r.correct,
            })
    return {"name": "fig2_cache_sweep", "n_data_pages": n_pages, "rows": rows}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
