"""Kernel microbenchmarks (CPU: oracles give the timing signal; the Pallas
kernels run in interpret mode for correctness, their perf case is made
structurally via the roofline analysis).  Times the recovery engine's hot
paths too: redo ops/sec is the paper-engine analogue of tokens/sec."""
from __future__ import annotations

import json
import random
import time

import jax
import jax.numpy as jnp

from repro.core import Database, Strategy, make_key, recover
from repro.kernels import ref


def _time(fn, *args, iters=3) -> float:
    fn(*args)                       # compile/warm
    t0 = time.perf_counter()
    for _ in range(iters):
        out = fn(*args)
    jax.tree.map(lambda a: a.block_until_ready()
                 if hasattr(a, "block_until_ready") else a, out)
    return (time.perf_counter() - t0) / iters * 1e6   # us


def run(fast: bool = False) -> dict:
    rows = []
    ks = jax.random.split(jax.random.PRNGKey(0), 6)

    B, H, S, hd = 1, 4, 512, 64
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32)
    k = jax.random.normal(ks[1], (B, H, S, hd), jnp.float32)
    v = jax.random.normal(ks[2], (B, H, S, hd), jnp.float32)
    f = jax.jit(lambda a, b, c: ref.flash_attention_ref(a, b, c))
    rows.append({"name": "attention_ref_512", "us_per_call": _time(f, q, k, v),
                 "derived": f"{4*B*H*S*S*hd/1e9:.2f} GFLOP"})

    r_ = jax.random.normal(ks[3], (B, H, S, hd), jnp.float32)
    lw = -jnp.ones((B, H, S, hd), jnp.float32) * 0.1
    u = jnp.ones((H, hd), jnp.float32) * 0.1
    f = jax.jit(lambda a, b, c, d, e: ref.wkv6_ref(a, b, c, d, e))
    rows.append({"name": "wkv6_ref_512", "us_per_call": _time(f, r_, k, v, lw, u),
                 "derived": f"state {hd}x{hd}/head"})

    # recovery engine: redo throughput
    rng = random.Random(0)
    db = Database(cache_pages=512, tracker_interval=100, bg_flush_per_txn=4)
    n_rows = 5_000 if fast else 20_000
    db.load_table("t", [(f"k{i:08d}".encode(), rng.randbytes(100))
                        for i in range(n_rows)])
    for _ in range(100):
        db.run_txn([("update", "t", f"k{rng.randrange(n_rows):08d}".encode(),
                     rng.randbytes(100)) for _ in range(10)])
    db.checkpoint()
    for _ in range(200):
        db.run_txn([("update", "t", f"k{rng.randrange(n_rows):08d}".encode(),
                     rng.randbytes(100)) for _ in range(10)])
    image = db.crash()
    t0 = time.perf_counter()
    _, st = recover(image, Strategy.LOG1, cache_pages=512)
    dt = time.perf_counter() - t0
    rows.append({"name": "logical_redo_throughput",
                 "us_per_call": dt / max(1, st.redo.submitted) * 1e6,
                 "derived": f"{st.redo.submitted/dt:.0f} redo ops/s wall"})
    return {"name": "kernel_bench", "rows": rows}


if __name__ == "__main__":
    print(json.dumps(run(), indent=1))
