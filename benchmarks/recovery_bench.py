"""Streaming batched redo pipeline benchmark: what one log pass and
amortized B-tree apply buy over the paper's per-record algorithms.

  1. batched redo throughput — the same crash image recovered with
     per-record Log0/Log1/Log2 (Algorithms 2/5 verbatim) vs batched Log1
     (sorted windows through the leaf-resident cursor); the acceptance
     bound asserts batched Log1 >= 2x per-record Log1 per-record redo
     throughput on the uniform workload, every variant oracle-checked;
  1b. packed pages + bounded pool — batched Log1 redo over packed pages
     vs the eager dict-page baseline (>= 1.5x asserted, cold decode
     caches each round, oracle-equal) and the same recovery through a
     pool a quarter of the page set (peak resident frames <= capacity
     asserted);
  2. window sweep — cursor reuse fraction and redo wall vs batch_window,
     showing where traversal amortization saturates;
  3. streaming cold restore — `cold_restore` through the windowed
     decode-and-apply pipeline vs the materializing path: peak decoded-
     segment residency must stay bounded by the LRU window and peak
     buffered redo ops by the apply window (asserted), at <= 1.25x the
     materializing wall time (asserted), oracle-equal (asserted).

Wall-clock comparisons interleave the contenders and take per-side
minima (this machine's latency drifts across seconds; see media_bench).
"""
from __future__ import annotations

import contextlib
import gc
import json
import random
import tempfile
import time
from pathlib import Path

from repro.archive import Archiver, LogArchive, SnapshotStore
from repro.core import (Strategy, committed_state_oracle, make_key, recover,
                        recovered_state)
from repro.core.tc import Database
from repro.media import DirectoryBackend, cold_restore

from .harness import BenchSetup, build_crash_image


@contextlib.contextmanager
def _quiet_gc():
    gc.collect()
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        yield
    finally:
        if was_enabled:
            gc.enable()


def _redo_setup(fast: bool):
    # n_rows keeps the tree at height 3 even in fast mode — a height-2
    # tree leaves one internal hop to amortize and understates the win
    s = BenchSetup(n_rows=30_000 if fast else 50_000,
                   cache_pages=4096,
                   ckpt_updates=8_000 if fast else 16_000,
                   n_ckpts=1, value_size=60,
                   tracker_interval=100, bg_flush_per_txn=4)
    image, base, info = build_crash_image(s)
    oracle = committed_state_oracle(image, base)
    return s, image, oracle


def bench_batched_redo(fast: bool) -> list[dict]:
    s, image, oracle = _redo_setup(fast)
    window = 8192
    variants = [
        ("Log0", Strategy.LOG0, {}),
        ("Log1", Strategy.LOG1, {}),
        ("Log2", Strategy.LOG2, {}),
        ("Log1-batched", Strategy.LOG1,
         {"batched": True, "batch_window": window}),
    ]
    best: dict[str, object] = {}
    with _quiet_gc():
        for name, strat, kw in variants:       # warm decode/ck caches once
            recover(image, strat, cache_pages=s.cache_pages, **kw)
        # interleaved minima: 3 rounds for the context rows, 7 for the two
        # sides of the asserted ratio (this machine's latency drifts, and
        # the bound must compare algorithms, not scheduler luck)
        for rnd in range(7):
            for name, strat, kw in variants:
                if rnd >= 3 and name not in ("Log1", "Log1-batched"):
                    continue
                db, st = recover(image, strat, cache_pages=s.cache_pages,
                                 **kw)
                assert recovered_state(db) == oracle, \
                    f"{name} diverged from the committed-state oracle"
                prev = best.get(name)
                if prev is None or st.redo_wall_ms < prev.redo_wall_ms:
                    best[name] = st
    rows = []
    for name, _strat, _kw in variants:
        st = best[name]
        us_per_rec = st.redo_wall_ms * 1e3 / max(st.log_records, 1)
        rows.append({
            "name": f"recovery_redo/{name}",
            "log_records": st.log_records,
            "redo_wall_ms": round(st.redo_wall_ms, 2),
            "us_per_record": round(us_per_rec, 3),
            "redone": st.redo.redone,
            "skipped_dpt": st.redo.skipped_dpt,
            "skipped_plsn": st.redo.skipped_plsn,
            "cursor_reuses": st.cursor_reuses,
            "cursor_traversals": st.cursor_traversals,
            "us_per_call": us_per_rec,
            "derived": f"{st.log_records} recs {st.redo_wall_ms:.1f}ms "
                       f"redone={st.redo.redone} ok=True",
        })
    per_rec = best["Log1"].redo_wall_ms
    batched = best["Log1-batched"].redo_wall_ms
    speedup = per_rec / max(batched, 1e-9)
    rows[-1]["speedup_vs_log1"] = round(speedup, 2)
    rows[-1]["derived"] += f" speedup={speedup:.2f}x"
    # window-size distribution across every batched flush this process ran
    # (quantiles from the registry histogram, PR 8): a p50 far below the
    # configured window means redo is flushing on txn boundaries, not fill
    from repro import obs
    wr = obs.value("recovery.window_records")
    if isinstance(wr, dict) and wr.get("count"):
        rows[-1]["window_p50"] = wr["p50"]
        rows[-1]["window_p95"] = wr["p95"]
        rows[-1]["window_p99"] = wr["p99"]
    assert speedup >= 2.0, \
        f"batched Log1 redo throughput only {speedup:.2f}x per-record " \
        "Log1 — below the 2x acceptance bound"
    return rows


def bench_packed_pool(fast: bool) -> list[dict]:
    """Packed-page + bounded-pool acceptance bounds, CI-asserted:

      * batched Log1 redo over packed pages must run >= 1.5x the
        dict-page baseline.  The baseline (``eager_decode``) is the
        pre-packed behaviour: every decoded page materializes its dict
        form whether or not redo ever touches its records.  The workload
        is the paper's conservative-DPT shape — a coarse tracker
        interval plus aggressive background flushing, so the DPT
        overestimates and redo fetches many pages only to discover, from
        the packed header's plsn alone, that they are already current
        (zero-decode is exactly that discovery made O(1)).  Both sides
        start every round from a cold decode cache: a crash destroys any
        in-memory decoded state, so first-touch decode cost is part of
        recovery, not an amortizable warm-up.  Separate per-mode caches,
        interleaved minima, every run oracle-checked;
      * the same crash image recovered through a pool holding a quarter
        of the page set must keep peak resident frames <= capacity while
        still matching the oracle — the bounded-pool contract under a
        page set that exceeds memory.
    """
    from collections import OrderedDict
    # ckpt_updates stays at 8k in both modes on purpose: a longer redo
    # span adds *shared* apply work that dilutes the decode asymmetry the
    # bound measures; full mode scales the page set instead
    s = BenchSetup(n_rows=40_000 if fast else 60_000,
                   cache_pages=4096,
                   ckpt_updates=8_000,
                   n_ckpts=1, value_size=20,
                   tracker_interval=500, bg_flush_per_txn=8)
    image, base, _info = build_crash_image(s)
    oracle = committed_state_oracle(image, base)
    kw = dict(cache_pages=s.cache_pages, batched=True, batch_window=8192)

    def cold(mode: str):
        # a fresh decode cache per run: recovery after a crash never
        # starts with decoded pages in memory, for either format
        image.store._decoded = OrderedDict()
        image.store.eager_decode = (mode == "dict")
        db, st = recover(image, Strategy.LOG1, **kw)
        assert recovered_state(db) == oracle, \
            f"{mode}-page recovery diverged from the committed-state oracle"
        return st

    best: dict[str, object] = {}
    with _quiet_gc():
        for mode in ("packed", "dict"):
            cold(mode)                      # warm module state, not caches
        for _ in range(7):
            for mode in ("packed", "dict"):
                st = cold(mode)
                prev = best.get(mode)
                if prev is None or st.redo_wall_ms < prev.redo_wall_ms:
                    best[mode] = st
    image.store.eager_decode = False
    rows = []
    for mode in ("dict", "packed"):
        st = best[mode]
        rows.append({
            "name": f"recovery_packed/{mode}",
            "log_records": st.log_records,
            "redo_wall_ms": round(st.redo_wall_ms, 2),
            "redone": st.redo.redone,
            "skipped_plsn": st.redo.skipped_plsn,
            "us_per_call": st.redo_wall_ms * 1e3 / max(st.log_records, 1),
            "derived": f"{st.redo_wall_ms:.1f}ms redone={st.redo.redone} "
                       f"plsn_skip={st.redo.skipped_plsn} ok=True",
        })
    speedup = best["dict"].redo_wall_ms \
        / max(best["packed"].redo_wall_ms, 1e-9)
    rows[-1]["speedup"] = round(speedup, 2)
    rows[-1]["derived"] += f" speedup={speedup:.2f}x"
    assert speedup >= 1.5, \
        f"batched Log1 redo over packed pages only {speedup:.2f}x the " \
        "dict-page baseline — below the 1.5x acceptance bound"

    # bounded-pool leg: page set 4x the frame budget, packed path
    n_pages = len(image.store)
    cap = max(32, n_pages // 4)
    assert n_pages > cap, "page set must exceed the pool for this row"
    pool_best = None
    with _quiet_gc():
        for _ in range(3):
            image.store._decoded = OrderedDict()
            db, st = recover(image, Strategy.LOG1, cache_pages=cap,
                             batched=True, batch_window=8192)
            assert recovered_state(db) == oracle, \
                "bounded-pool recovery diverged from the oracle"
            if pool_best is None or st.redo_wall_ms < pool_best.redo_wall_ms:
                pool_best = st
    assert pool_best.pool_peak_resident <= cap, \
        f"{pool_best.pool_peak_resident} frames resident during recovery " \
        f"> the {cap}-frame budget — the buffer pool is not bounded"
    assert pool_best.pool_evictions > 0, \
        "a pool a quarter of the page set never evicted — the bound " \
        "was not exercised"
    rows.append({
        "name": "recovery_packed/pool_quarter",
        "capacity": cap,
        "stable_pages": n_pages,
        "peak_resident": pool_best.pool_peak_resident,
        "evictions": pool_best.pool_evictions,
        "flushes": pool_best.pool_flushes,
        "redo_wall_ms": round(pool_best.redo_wall_ms, 2),
        "us_per_call": pool_best.redo_wall_ms * 1e3
        / max(pool_best.log_records, 1),
        "derived": f"peak={pool_best.pool_peak_resident}/{cap} frames "
                   f"over {n_pages} pages "
                   f"evict={pool_best.pool_evictions} ok=True",
    })
    return rows


def bench_probe_overhead(fast: bool) -> list[dict]:
    """The observability cost bound, CI-asserted: the disabled-by-default
    probe path must cost < 5% of the batched Log1 redo wall.

    There is no probe-free build left to diff against, so the disabled
    cost is measured directly: time the actual disabled primitives — the
    ``if TRACER.enabled`` guard and the null ``TRACER.span(...)`` call
    (kwargs build included) — in isolation, scale them by the run's own
    probe counts (one guard per demand read / pace / apply_batch, one
    null span per redo window plus the phase spans), and require the
    total under 5% of the measured disabled redo wall.  The *enabled*
    overhead (per-IO event dicts are real work, ~10-20% here) is
    reported in the same row and only sanity-capped at 2x so a
    pathological probe regression still fails CI.

    The flight recorder (PR 8) has no disabled state — it records on
    every demand read and redo window unconditionally — so its budget is
    measured the same way: time ``FLIGHT.record`` hot in isolation,
    scale by the run's own recorded-event delta, and require the total
    under 5% of the batched Log1 redo wall."""
    import time as _time

    from repro import obs
    from repro.obs.flightrec import FLIGHT
    s, image, oracle = _redo_setup(fast)
    kw = dict(cache_pages=s.cache_pages, batched=True, batch_window=8192)
    t_off = t_on = float("inf")
    st = None
    n_flight = 0
    with _quiet_gc():
        recover(image, Strategy.LOG1, **kw)        # warm decode/ck caches
        try:
            for _ in range(7):
                obs.disable()
                rec0 = FLIGHT.recorded
                db, cand = recover(image, Strategy.LOG1, **kw)
                n_flight = max(n_flight, FLIGHT.recorded - rec0)
                t_off = min(t_off, cand.redo_wall_ms)
                st = cand
                obs.enable()
                obs.TRACER.clear()                 # don't accumulate events
                db, _ = recover(image, Strategy.LOG1, **kw)
                t_on = min(t_on, _.redo_wall_ms)
        finally:
            obs.disable()
            obs.TRACER.clear()
    assert recovered_state(db) == oracle, \
        "traced recovery diverged from the committed-state oracle"

    # per-primitive cost of the DISABLED path, measured hot
    n = 200_000
    tr = obs.TRACER
    t0 = _time.perf_counter()
    for _ in range(n):
        if tr.enabled:
            pass
    guard_ms = (_time.perf_counter() - t0) * 1e3 / n
    t0 = _time.perf_counter()
    for _ in range(n):
        with tr.span("probe", records=0, start=0):
            pass
    span_ms = (_time.perf_counter() - t0) * 1e3 / n
    t0 = _time.perf_counter()
    for _ in range(n):
        FLIGHT.record("probe", 1, 2, 0.0)
    flight_call_ms = (_time.perf_counter() - t0) * 1e3 / n
    FLIGHT.clear()

    # probe counts from the run's own stats: one guard per demand read
    # (hit/partial/sync all check), per prefetch pace, per apply_batch
    # call; one null span per redo window; ~5 phase spans
    demand_reads = (st.io.prefetch_hits + st.io.partial_stalls
                    + st.io.sync_reads)
    guards = demand_reads + st.io.prefetch_ios + 2 * st.windows
    probe_ms = guards * guard_ms + (st.windows + 5) * span_ms
    frac = probe_ms / max(t_off, 1e-9)
    assert frac <= 0.05, \
        f"disabled probe path costs {probe_ms:.3f}ms " \
        f"({frac:.1%} of the {t_off:.2f}ms batched Log1 redo wall) — " \
        f"above the 5% CI bound"

    # the always-on flight recorder gets the same 5% budget, scaled by
    # the number of events one recovery actually records
    flight_ms = n_flight * flight_call_ms
    flight_frac = flight_ms / max(t_off, 1e-9)
    assert flight_frac <= 0.05, \
        f"always-on flight recorder costs {flight_ms:.3f}ms for " \
        f"{n_flight} events ({flight_frac:.1%} of the {t_off:.2f}ms " \
        f"batched Log1 redo wall) — above the 5% CI bound"

    overhead = t_on / max(t_off, 1e-9)
    assert t_on <= t_off * 2.0 + 1.0, \
        f"enabled tracing costs {overhead:.2f}x on batched Log1 redo " \
        f"({t_off:.2f}ms -> {t_on:.2f}ms) — pathological probe regression"
    return [{
        "name": "recovery_probe/overhead",
        "redo_wall_off_ms": round(t_off, 2),
        "redo_wall_on_ms": round(t_on, 2),
        "disabled_probe_ms": round(probe_ms, 4),
        "disabled_probe_frac": round(frac, 5),
        "flight_events": n_flight,
        "flight_ms": round(flight_ms, 4),
        "flight_frac": round(flight_frac, 5),
        "enabled_overhead": round(overhead, 3),
        "us_per_call": t_off * 1e3 / max(st.log_records, 1),
        "derived": f"disabled probes {frac:.2%} of {t_off:.1f}ms wall "
                   f"flight {flight_frac:.2%} "
                   f"(enabled x{overhead:.2f}) ok=True",
    }]


def bench_prefetch_overlap(fast: bool) -> list[dict]:
    """True Log2 prefetch overlap, from traced per-record issue/consume
    events.  Asserts the pacing-parity invariant the batched-mode fix
    restored — batched redo issues exactly the per-record PF-list schedule
    (same pid groups, same order; only clocks may differ, because demand
    stalls land at different points) — and that batched issues are spread
    across the window's work rather than collapsed onto its start clock
    (the window-granular bug this replaces)."""
    from repro import obs
    from repro.core.storage import issue_schedule, prefetch_overlap
    s, image, oracle = _redo_setup(fast)

    def traced(**kw):
        obs.TRACER.clear()
        db, st = recover(image, Strategy.LOG2, cache_pages=s.cache_pages,
                         **kw)
        assert recovered_state(db) == oracle, "Log2 diverged from oracle"
        ev = list(obs.TRACER.events)
        return st, issue_schedule(ev), prefetch_overlap(ev), ev

    with _quiet_gc():
        obs.enable()
        try:
            st_p, sched_p, ov_p, _ = traced()
            st_b, sched_b, ov_b, ev_b = traced(batched=True,
                                               batch_window=8192)
        finally:
            obs.disable()
            obs.TRACER.clear()
    assert sched_p, "Log2 issued no PF-list prefetches — pacing is dead"
    assert sched_b == sched_p, \
        f"batched Log2 issue schedule diverged from per-record pacing " \
        f"({len(sched_b)} vs {len(sched_p)} issues)"
    clocks = [e["attrs"]["clock"] for e in ev_b
              if e.get("name") == "io.prefetch.issue"]
    distinct = len(set(clocks))
    # legit per-record pacing occasionally issues several 8-page groups in
    # one pace call (shared clock); the window-granular bug collapses to
    # ~one clock per window — orders of magnitude below half
    assert distinct >= 0.5 * len(clocks), \
        f"batched Log2 prefetches collapse onto {distinct} issue clocks " \
        f"for {len(clocks)} issues — pacing regressed to window-granular"
    return [{
        "name": "recovery_prefetch/log2_overlap",
        "per_record_overlap": ov_p["overlap"],
        "batched_overlap": ov_b["overlap"],
        "per_record_stall_ms": ov_p["stall_ms"],
        "batched_stall_ms": ov_b["stall_ms"],
        "issues": len(sched_b),
        "us_per_call": st_b.redo_wall_ms * 1e3 / max(st_b.log_records, 1),
        # the remaining overlap gap is real batched-IO behaviour (demand
        # reads land at the window end, after more work has overlapped),
        # now *measured* instead of manufactured by front-loaded issues
        "derived": f"per-rec={ov_p['overlap']:.0%} "
                   f"batched={ov_b['overlap']:.0%} "
                   f"issues={len(sched_b)} ok=True",
    }]


def bench_window_sweep(fast: bool) -> list[dict]:
    s, image, oracle = _redo_setup(fast)
    rows = []
    with _quiet_gc():
        for window in (64, 1024, 8192):
            wall, st = float("inf"), None
            for _ in range(3):
                db, cand = recover(image, Strategy.LOG1,
                                   cache_pages=s.cache_pages,
                                   batched=True, batch_window=window)
                assert recovered_state(db) == oracle
                if cand.redo_wall_ms < wall:
                    wall, st = cand.redo_wall_ms, cand
            total = st.cursor_reuses + st.cursor_traversals
            reuse = st.cursor_reuses / max(total, 1)
            assert st.peak_window_records <= window, \
                f"window {window}: {st.peak_window_records} records " \
                "buffered — the redo window is not bounded"
            rows.append({
                "name": f"recovery_window/batch={window}",
                "batch_window": window,
                "redo_wall_ms": round(wall, 2),
                "peak_window_records": st.peak_window_records,
                "cursor_reuse_frac": round(reuse, 3),
                "us_per_call": wall * 1e3 / max(st.log_records, 1),
                "derived": f"reuse={reuse:.0%} "
                           f"peak={st.peak_window_records} ok=True",
            })
    return rows


def bench_streaming_restore(fast: bool, tmp: Path) -> list[dict]:
    n_rows = 2_000 if fast else 10_000
    total_txns = 800 if fast else 2_500
    cache_segments = 4
    apply_window = 1024
    rng = random.Random(41)
    rows = [(f"k{i:07d}".encode(), rng.randbytes(60)) for i in range(n_rows)]
    primary = Database(page_size=8192, cache_pages=512,
                       tracker_interval=100, bg_flush_per_txn=4)
    primary.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}

    def drive(n_txns):
        for _ in range(n_txns):
            primary.run_txn([("update", "t",
                              f"k{rng.randrange(n_rows):07d}".encode(),
                              rng.randbytes(60)) for _ in range(8)])

    backend = DirectoryBackend(tmp / "stream")
    store = SnapshotStore()
    arch = Archiver(primary,
                    archive=LogArchive(segment_records=256, backend=backend,
                                       cache_segments=cache_segments),
                    snapshots=store)
    drive(total_txns // 4)
    store.take(primary, chunk_keys=512, on_chunk=lambda: drive(1))
    drive(3 * total_txns // 4)          # long redo tail: the memory story
    arch.run_once()
    target = arch.archive.archived_upto
    oracle = committed_state_oracle(primary.crash(), base, upto_lsn=target)

    t_stream = t_mat = float("inf")
    st_stream = st_mat = None
    with _quiet_gc():
        for _ in range(5):
            t0 = time.perf_counter()
            db_s, cand_s = cold_restore(backend, target_lsn=target,
                                        page_size=4096,
                                        cache_segments=cache_segments,
                                        apply_window=apply_window)
            w = time.perf_counter() - t0
            if w < t_stream:
                t_stream, st_stream = w, cand_s
            assert dict(db_s.scan_all()) == oracle, "streaming diverged"
            t0 = time.perf_counter()
            db_m, cand_m = cold_restore(backend, target_lsn=target,
                                        page_size=4096, streaming=False)
            w = time.perf_counter() - t0
            if w < t_mat:
                t_mat, st_mat = w, cand_m
            assert dict(db_m.scan_all()) == oracle, "materializing diverged"
    # the memory bounds the pipeline exists for.  The +1 is the insert
    # transient (peak samples before eviction — deliberately, so a broken
    # eviction discipline CAN fail this; caller-side materialization is
    # what the peak_buffered_ops bounds below catch)
    assert st_stream.peak_cached_segments <= cache_segments + 1, \
        f"{st_stream.peak_cached_segments} decoded segments resident — " \
        f"the {cache_segments}-segment LRU window did not bound decode"
    bound = apply_window + 64           # window + in-flight straddlers
    assert st_stream.peak_buffered_ops <= bound, \
        f"streaming restore buffered {st_stream.peak_buffered_ops} ops " \
        f"(> {bound}): the apply window is not bounding memory"
    assert st_stream.peak_buffered_ops < st_mat.peak_buffered_ops, \
        "streaming restore holds no fewer redo records than materializing"
    ratio = t_stream / max(t_mat, 1e-9)
    assert ratio <= 1.25, \
        f"streaming restore {ratio:.2f}x materializing exceeds the " \
        "1.25x wall-time bound"
    return [{
        "name": "recovery_stream_restore/vs_materializing",
        "replayed_txns": st_stream.replayed_txns,
        "stream_ms": round(t_stream * 1e3, 1),
        "materializing_ms": round(t_mat * 1e3, 1),
        "ratio": round(ratio, 2),
        "stream_peak_ops": st_stream.peak_buffered_ops,
        "materializing_peak_ops": st_mat.peak_buffered_ops,
        "peak_cached_segments": st_stream.peak_cached_segments,
        "us_per_call": t_stream * 1e6,
        "derived": f"stream={t_stream * 1e3:.0f}ms "
                   f"mat={t_mat * 1e3:.0f}ms {ratio:.2f}x "
                   f"ops={st_stream.peak_buffered_ops}/"
                   f"{st_mat.peak_buffered_ops} "
                   f"segs={st_stream.peak_cached_segments} ok=True",
    }]


def run(fast: bool = False) -> dict:
    with tempfile.TemporaryDirectory(prefix="recovery_bench_") as tmpdir:
        rows = (bench_batched_redo(fast)
                + bench_packed_pool(fast)
                + bench_probe_overhead(fast)
                + bench_window_sweep(fast)
                + bench_prefetch_overlap(fast)
                + bench_streaming_restore(fast, Path(tmpdir)))
    return {"name": "recovery_pipeline", "rows": rows}


if __name__ == "__main__":
    print(json.dumps(run(fast=True), indent=1))
