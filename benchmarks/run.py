"""Benchmark entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV lines per benchmark row, writes
full JSON to artifacts/bench/, and appends one machine-readable
``artifacts/bench_<n>.json`` summary per run (monotonic ``n``) so the
perf trajectory across commits is diffable without parsing stdout.
Per-module metric snapshots land in ``artifacts/bench/
metrics_timeseries.jsonl`` and the final registry state in Prometheus
text form at ``artifacts/bench/metrics.prom``.
--full uses the paper-scaled setup (slower); the default "fast" mode
keeps the whole suite under ~3 minutes.

Failure discipline: each module runs to completion independently (one
broken table must not hide the others' numbers), but any failure — an
oracle assertion inside a sub-benchmark most importantly — makes the
runner exit non-zero, so CI cannot greenlight a diverging benchmark.
"""
from __future__ import annotations

import json
import re
import sys
import time
import traceback
from pathlib import Path

ART_ROOT = Path(__file__).resolve().parents[1] / "artifacts"
ART = ART_ROOT / "bench"


def _next_run_index() -> int:
    """Next monotonic run index.  A truncated/corrupt bench_<n>.json still
    claims its index (so we never overwrite evidence of the torn write)
    but is warned about loudly — bench-diff will skip it, and a silent
    skip here would leave the perf trajectory with an unexplained hole."""
    mx = 0
    for p in ART_ROOT.glob("bench_*.json"):
        m = re.fullmatch(r"bench_(\d+)\.json", p.name)
        if not m:
            continue
        mx = max(mx, int(m.group(1)))
        try:
            json.loads(p.read_text())
        except (OSError, json.JSONDecodeError, UnicodeDecodeError) as exc:
            print(f"bench: WARNING: existing artifact {p.name} is "
                  f"unreadable ({type(exc).__name__}: {exc}) — keeping "
                  "its run index, bench-diff will not use it as a "
                  "baseline", file=sys.stderr)
    return mx + 1


def write_summary(results: list[dict], failures: list[str],
                  fast: bool) -> Path:
    """One flat, machine-readable record of this run: every row's key
    metrics plus per-module status — the perf-trajectory unit.  The
    process-wide metrics snapshot rides along so each artifact carries the
    telemetry (cache hit rates, windows, shipped records, ...) that
    explains its numbers."""
    from repro.obs import metrics as obs_metrics
    summary = {
        "run": _next_run_index(),
        "timestamp": time.strftime("%Y-%m-%dT%H:%M:%S%z"),
        "mode": "fast" if fast else "full",
        "modules": [
            {"name": out["name"], "rows": len(out["rows"]), "ok": True}
            for out in results
        ] + [{"name": name, "rows": 0, "ok": False} for name in failures],
        "failures": failures,
        "rows": [
            {"module": out["name"], **{
                key: row[key] for key in
                ("name", "us_per_call", "derived", "speedup",
                 "speedup_vs_log1", "ratio", "recs_per_s",
                 "bytes_per_record", "p50", "p95", "p99",
                 "window_p50", "window_p95", "window_p99",
                 "flight_frac")
                if key in row}}
            for out in results for row in out["rows"]
        ],
        "metrics": obs_metrics.snapshot(),
    }
    path = ART_ROOT / f"bench_{summary['run']}.json"
    path.write_text(json.dumps(summary, indent=1))
    return path


def main() -> None:
    fast = "--full" not in sys.argv
    from . import (appendix_d_variants, archive_bench, faults_bench,
                   fig2_cache_sweep, fig3_ckpt_interval, kernel_bench,
                   media_bench, pagepack_bench, parallel_apply_bench,
                   recovery_bench, replication_bench, roofline_table,
                   trainstore_bench)
    from repro.obs.export import Sampler, prometheus_text
    ART.mkdir(parents=True, exist_ok=True)
    failures: list[str] = []
    results: list[dict] = []
    # per-module metric snapshots: one JSONL row after each module, so a
    # regression shows *which table* moved a counter, not just that the
    # end-of-run total moved
    sampler = Sampler(ART / "metrics_timeseries.jsonl", period_ms=0.0)
    print("name,us_per_call,derived")
    for mod in (fig2_cache_sweep, fig3_ckpt_interval, appendix_d_variants,
                recovery_bench, pagepack_bench, replication_bench,
                parallel_apply_bench, archive_bench, media_bench,
                faults_bench, trainstore_bench, kernel_bench,
                roofline_table):
        try:
            out = mod.run(fast=fast)
        except Exception:
            failures.append(mod.__name__)
            print(f"# FAILED {mod.__name__}:", file=sys.stderr)
            traceback.print_exc()
            sampler.tick(force=True, note=f"{mod.__name__} FAILED")
            continue
        results.append(out)
        sampler.tick(force=True, note=out["name"])
        (ART / f"{out['name']}.json").write_text(json.dumps(out, indent=1))
        for row in out["rows"]:
            if "us_per_call" in row:
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"\"{row.get('derived','')}\"")
            elif "strategy" in row:
                label = out["name"]
                key = row.get("cache_pages") or row.get(
                    "ckpt_interval_updates") or ""
                us = row.get("modeled_ms", 0.0) * 1e3
                derived = (f"dpt={row.get('dpt_size','')} "
                           f"fetch={row.get('fetches','')} "
                           f"ok={row.get('correct','')}")
                print(f"{label}/{row['strategy']}@{key},{us:.0f},\"{derived}\"")
            elif "touched_frac" in row:
                print(f"trainstore/touch={row['touched_frac']},"
                      f"{row['log1_modeled_ms']*1e3:.0f},"
                      f"\"log0={row['log0_modeled_ms']}ms "
                      f"speedup={row['speedup_log1_vs_log0']}x "
                      f"dpt={row['log1_dpt']}\"")
            elif "delta_mode" in row:
                print(f"appendix_d/{row['delta_mode']},"
                      f"{row['log1_modeled_ms']*1e3:.0f},"
                      f"\"dpt={row['log1_dpt']} "
                      f"payload={row['delta_payload_bytes']}B\"")
            else:
                print(f"{out['name']}/{row.get('arch','')}__"
                      f"{row.get('shape','')},"
                      f"{row.get('compute_s', 0)*1e6:.0f},"
                      f"\"dom={row.get('dominant','')}\"")
    sampler.close()
    (ART / "metrics.prom").write_text(prometheus_text())
    summary_path = write_summary(results, failures, fast)
    print(f"# full JSON written to artifacts/bench/; run summary at "
          f"{summary_path.relative_to(ART_ROOT.parent)}", file=sys.stderr)
    if failures:
        print(f"# {len(failures)} benchmark module(s) FAILED: "
              f"{', '.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
