"""Benchmark entry point: one module per paper table/figure.

    PYTHONPATH=src python -m benchmarks.run [--full]

Prints ``name,us_per_call,derived`` CSV lines per benchmark row, and writes
full JSON to artifacts/bench/.  --full uses the paper-scaled setup (slower);
the default "fast" mode keeps the whole suite under ~3 minutes.

Failure discipline: each module runs to completion independently (one
broken table must not hide the others' numbers), but any failure — an
oracle assertion inside a sub-benchmark most importantly — makes the
runner exit non-zero, so CI cannot greenlight a diverging benchmark.
"""
from __future__ import annotations

import json
import sys
import traceback
from pathlib import Path

ART = Path(__file__).resolve().parents[1] / "artifacts" / "bench"


def main() -> None:
    fast = "--full" not in sys.argv
    from . import (appendix_d_variants, archive_bench, fig2_cache_sweep,
                   fig3_ckpt_interval, kernel_bench, media_bench,
                   parallel_apply_bench, replication_bench, roofline_table,
                   trainstore_bench)
    ART.mkdir(parents=True, exist_ok=True)
    failures: list[str] = []
    print("name,us_per_call,derived")
    for mod in (fig2_cache_sweep, fig3_ckpt_interval, appendix_d_variants,
                replication_bench, parallel_apply_bench, archive_bench,
                media_bench, trainstore_bench, kernel_bench, roofline_table):
        try:
            out = mod.run(fast=fast)
        except Exception:
            failures.append(mod.__name__)
            print(f"# FAILED {mod.__name__}:", file=sys.stderr)
            traceback.print_exc()
            continue
        (ART / f"{out['name']}.json").write_text(json.dumps(out, indent=1))
        for row in out["rows"]:
            if "us_per_call" in row:
                print(f"{row['name']},{row['us_per_call']:.1f},"
                      f"\"{row.get('derived','')}\"")
            elif "strategy" in row:
                label = out["name"]
                key = row.get("cache_pages") or row.get(
                    "ckpt_interval_updates") or ""
                us = row.get("modeled_ms", 0.0) * 1e3
                derived = (f"dpt={row.get('dpt_size','')} "
                           f"fetch={row.get('fetches','')} "
                           f"ok={row.get('correct','')}")
                print(f"{label}/{row['strategy']}@{key},{us:.0f},\"{derived}\"")
            elif "touched_frac" in row:
                print(f"trainstore/touch={row['touched_frac']},"
                      f"{row['log1_modeled_ms']*1e3:.0f},"
                      f"\"log0={row['log0_modeled_ms']}ms "
                      f"speedup={row['speedup_log1_vs_log0']}x "
                      f"dpt={row['log1_dpt']}\"")
            elif "delta_mode" in row:
                print(f"appendix_d/{row['delta_mode']},"
                      f"{row['log1_modeled_ms']*1e3:.0f},"
                      f"\"dpt={row['log1_dpt']} "
                      f"payload={row['delta_payload_bytes']}B\"")
            else:
                print(f"{out['name']}/{row.get('arch','')}__"
                      f"{row.get('shape','')},"
                      f"{row.get('compute_s', 0)*1e6:.0f},"
                      f"\"dom={row.get('dominant','')}\"")
    print("# full JSON written to artifacts/bench/", file=sys.stderr)
    if failures:
        print(f"# {len(failures)} benchmark module(s) FAILED: "
              f"{', '.join(failures)}", file=sys.stderr)
        sys.exit(1)


if __name__ == "__main__":
    main()
