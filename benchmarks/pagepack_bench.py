"""Packed-page benchmark: what the zero-decode hot path buys, and what
the bounded buffer pool costs when the page set exceeds memory.

  1. point-read — random key probes through ``PageStore.read_page().
     get()`` over the same blobs in two modes: packed (O(1) decode,
     bisect over the slot directory) vs dict pages (``eager_decode``
     materializes every page at decode — the pre-packed behaviour,
     kept as the measured baseline).  *cold* rows decode from a fresh
     cache each round (the post-crash shape, where zero-decode pays);
     *warm* rows reuse the decode cache, whose hot entries promote to
     dual form, so both modes converge to C-speed container reads —
     the warm rows exist to prove that parity.  Every probe's value is
     checked against the build-time oracle before timing;
  2. leaf-scan — full ``sorted_items()`` sweeps over every leaf, same
     two modes x cold/warm, record counts asserted equal;
  3. redo capacity sweep — one crash image recovered with batched Log1
     at pool capacities of ~inf / 50% / 10% of its stable page set:
     every run is oracle-asserted, peak resident frames must stay
     <= capacity (the bounded-pool contract), and the constrained
     points must actually evict — a sweep where the pool never fills
     measures nothing.

The asserted packed-vs-dict *speedup* bound lives in recovery_bench
(bench_packed_pool); this module is the fine-grained view.  Wall-clock
comparisons interleave the contenders and take per-side minima.
"""
from __future__ import annotations

import contextlib
import gc
import json
import random
import time

from repro.core import Strategy, committed_state_oracle, recover, \
    recovered_state
from repro.core.pages import empty_leaf
from repro.core.storage import PageStore

from .harness import BenchSetup, build_crash_image


@contextlib.contextmanager
def _quiet_gc():
    gc.collect()
    was_enabled = gc.isenabled()
    gc.disable()
    try:
        yield
    finally:
        if was_enabled:
            gc.enable()


def _build_blobs(n_pages: int, recs_per_page: int, value_size: int,
                 seed: int = 17):
    """One backend full of packed leaf blobs plus a (pid, key) -> value
    oracle; both bench modes read the same bytes."""
    rng = random.Random(seed)
    store = PageStore()
    oracle: dict[tuple[int, bytes], bytes] = {}
    for _ in range(n_pages):
        pg = empty_leaf(store.allocate_pid())
        for i in range(recs_per_page):
            k = f"{rng.getrandbits(48):012x}/{i:04d}".encode()
            v = rng.randbytes(value_size)
            pg.put(k, v, 1)
            oracle[(pg.pid, k)] = v
        store.write_page(pg)
    return store.backend, oracle


def _fresh_store(backend, mode: str) -> PageStore:
    """A PageStore over the shared blobs with its own cold decode cache
    (separate per mode — a shared content-keyed cache would let one mode
    serve the other's decoded form and erase the contrast)."""
    store = PageStore(backend)
    store.eager_decode = (mode == "dict")
    return store


def _two_phase_rows(kind: str, backend, rounds: int, run_cold, run_warm,
                    cold_calls: int, warm_calls: int) -> list[dict]:
    """cold rows: fresh decode cache every round, each page touched once
    (the post-crash first-touch shape — what zero-decode is for).  warm
    rows: one persistent store per mode, so hot entries promote to dual
    form and both modes converge to container-speed reads."""
    warm_stores = {m: _fresh_store(backend, m) for m in ("dict", "packed")}
    for store in warm_stores.values():
        run_warm(store)                     # populate + promote
    best = {("cold", m): float("inf") for m in ("dict", "packed")}
    best.update({("warm", m): float("inf") for m in ("dict", "packed")})
    with _quiet_gc():
        for _ in range(rounds):
            for mode in ("dict", "packed"):
                w = run_cold(_fresh_store(backend, mode))
                best[("cold", mode)] = min(best[("cold", mode)], w)
                w = run_warm(warm_stores[mode])
                best[("warm", mode)] = min(best[("warm", mode)], w)
    rows = []
    for temp, calls in (("cold", cold_calls), ("warm", warm_calls)):
        for mode in ("dict", "packed"):
            rows.append({
                "name": f"pagepack_{kind}/{temp}_{mode}",
                "us_per_call": best[(temp, mode)] * 1e6 / calls,
                "derived": "ok=True",
            })
        speedup = best[(temp, "dict")] / max(best[(temp, "packed")], 1e-9)
        rows[-1]["speedup"] = round(speedup, 2)
        rows[-1]["derived"] += f" speedup={speedup:.2f}x"
    return rows


def bench_point_read(fast: bool) -> list[dict]:
    n_pages = 128 if fast else 256
    recs_per_page = 64
    backend, oracle = _build_blobs(n_pages, recs_per_page, value_size=60)
    rng = random.Random(23)
    by_pid: dict[int, list[bytes]] = {}
    for pid, key in oracle:
        by_pid.setdefault(pid, []).append(key)
    # cold probes: ONE key per page, shuffled — every read is a
    # first-touch decode, the case the packed format exists for
    probes_cold = [(pid, rng.choice(keys)) for pid, keys in by_pid.items()]
    rng.shuffle(probes_cold)
    probes_warm = rng.sample(sorted(oracle), k=2_000)
    store = _fresh_store(backend, "packed")  # correctness pass, untimed
    for pid, key in probes_warm[:200]:
        got = store.read_page(pid).get(key)
        assert got == oracle[(pid, key)], \
            f"point-read returned a wrong value for {key!r}"

    def probe_all(probes):
        def run(store) -> float:
            read_page = store.read_page
            t0 = time.perf_counter()
            for pid, key in probes:
                read_page(pid).get(key)
            return time.perf_counter() - t0
        return run

    rows = _two_phase_rows("point_read", backend, 5,
                           probe_all(probes_cold), probe_all(probes_warm),
                           len(probes_cold), len(probes_warm))
    for r in rows:
        r["derived"] = f"{n_pages}p x {recs_per_page}r " + r["derived"]
    return rows


def bench_leaf_scan(fast: bool) -> list[dict]:
    n_pages = 128 if fast else 256
    recs_per_page = 64
    backend, _ = _build_blobs(n_pages, recs_per_page, value_size=60)
    pids = sorted(int(name[5:]) for name in backend.list("page/"))
    expect = n_pages * recs_per_page

    def run_once(store) -> float:
        t0 = time.perf_counter()
        seen = 0
        for pid in pids:
            seen += len(store.read_page(pid).sorted_items())
        w = time.perf_counter() - t0
        assert seen == expect, f"leaf scan saw {seen} records != {expect}"
        return w

    rows = _two_phase_rows("leaf_scan", backend, 5, run_once, run_once,
                           n_pages, n_pages)
    for r in rows:
        r["derived"] = f"{expect} recs " + r["derived"]
    return rows


def bench_capacity_sweep(fast: bool) -> list[dict]:
    s = BenchSetup(n_rows=10_000 if fast else 25_000,
                   cache_pages=2048,
                   ckpt_updates=4_000 if fast else 10_000,
                   n_ckpts=1, value_size=60,
                   tracker_interval=100, bg_flush_per_txn=4)
    image, base, _info = build_crash_image(s)
    oracle = committed_state_oracle(image, base)
    n_pages = len(image.store)
    points = [("inf", 1 << 30),
              ("50%", max(16, n_pages // 2)),
              ("10%", max(16, n_pages // 10))]
    rows = []
    with _quiet_gc():
        recover(image, Strategy.LOG1, cache_pages=1 << 30,
                batched=True, batch_window=8192)   # warm decode/ck caches
        for label, cap in points:
            best = None
            for _ in range(3):
                db, st = recover(image, Strategy.LOG1, cache_pages=cap,
                                 batched=True, batch_window=8192)
                assert recovered_state(db) == oracle, \
                    f"capacity={label} recovery diverged from the oracle"
                if best is None or st.redo_wall_ms < best.redo_wall_ms:
                    best = st
            assert best.pool_peak_resident <= cap, \
                f"capacity={label}: {best.pool_peak_resident} frames " \
                f"resident > the {cap}-frame budget — the pool is unbounded"
            if cap < n_pages:
                assert best.pool_evictions > 0, \
                    f"capacity={label}: a {cap}-frame pool over " \
                    f"{n_pages} pages never evicted — the sweep point " \
                    "is not exercising eviction"
            rows.append({
                "name": f"pagepack_redo/cap={label}",
                "capacity": cap,
                "stable_pages": n_pages,
                "peak_resident": best.pool_peak_resident,
                "evictions": best.pool_evictions,
                "flushes": best.pool_flushes,
                "redo_wall_ms": round(best.redo_wall_ms, 2),
                "us_per_call": best.redo_wall_ms * 1e3
                / max(best.log_records, 1),
                "derived": f"peak={best.pool_peak_resident}/{cap} "
                           f"evict={best.pool_evictions} "
                           f"flush={best.pool_flushes} ok=True",
            })
    return rows


def run(fast: bool = False) -> dict:
    rows = (bench_point_read(fast)
            + bench_leaf_scan(fast)
            + bench_capacity_sweep(fast))
    return {"name": "pagepack", "rows": rows}


if __name__ == "__main__":
    print(json.dumps(run(fast=True), indent=1))
