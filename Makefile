# One-command gates for every PR.
#   make test        tier-1 suite (the ROADMAP verify command)
#   make lint        reprolint invariant checker + mypy strictness table
#   make bench-smoke fast benchmark pass (all tables/figures + replication)
#   make bench-diff  >2x regression gate vs the previous bench artifact
#   make torture     strided crash-point sweep (tier-1 slice)
#   make torture-full  every injectable backend op, all kinds (CI job)
#   make trace-demo  crash + traced recovery, timeline printed
#   make blackbox-demo  staged crash + black-box dump + post-mortem render
#   make examples    run every example end-to-end
PY      := python
PYPATH  := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test lint bench-smoke bench-diff torture torture-full trace-demo \
        blackbox-demo examples all

all: lint test bench-smoke bench-diff examples

test:
	$(PYPATH) $(PY) -m pytest -x -q

# reprolint gates unconditionally; mypy runs when available (the dev
# container does not ship it) and is SKIPPED loudly otherwise — CI's lint
# job installs it, so the strictness table is always enforced upstream.
lint:
	$(PYPATH) $(PY) -m tools.reprolint --stats
	@if $(PY) -c "import mypy" 2>/dev/null; then \
		$(PYPATH) $(PY) -m mypy; \
	else \
		echo "lint: mypy SKIPPED (not installed here; CI enforces the" \
		     "pyproject strictness table)"; \
	fi

bench-smoke:
	$(PYPATH) $(PY) -m benchmarks.run

bench-diff:
	$(PYPATH) $(PY) -m benchmarks.diff

# crash-point torture: crash the scripted workload at sampled backend ops
# (torture) or every single one (torture-full), recover both ways, require
# oracle-equality or documented loud death
torture:
	$(PYPATH) $(PY) tools/torture.py

torture-full:
	$(PYPATH) $(PY) tools/torture.py --full --verbose

trace-demo:
	$(PY) examples/recovery_timeline.py

blackbox-demo:
	$(PY) examples/blackbox_demo.py

examples:
	$(PY) examples/quickstart.py
	$(PY) examples/replica_relayout.py
	$(PY) examples/train_with_recovery.py
	$(PY) examples/serve_batched.py
	$(PY) examples/recovery_timeline.py
	$(PY) examples/blackbox_demo.py
