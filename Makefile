# One-command gates for every PR.
#   make test        tier-1 suite (the ROADMAP verify command)
#   make bench-smoke fast benchmark pass (all tables/figures + replication)
#   make bench-diff  >2x regression gate vs the previous bench artifact
#   make trace-demo  crash + traced recovery, timeline printed
#   make examples    run every example end-to-end
PY      := python
PYPATH  := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke bench-diff trace-demo examples all

all: test bench-smoke bench-diff examples

test:
	$(PYPATH) $(PY) -m pytest -x -q

bench-smoke:
	$(PYPATH) $(PY) -m benchmarks.run

bench-diff:
	$(PYPATH) $(PY) -m benchmarks.diff

trace-demo:
	$(PY) examples/recovery_timeline.py

examples:
	$(PY) examples/quickstart.py
	$(PY) examples/replica_relayout.py
	$(PY) examples/train_with_recovery.py
	$(PY) examples/serve_batched.py
	$(PY) examples/recovery_timeline.py
