# One-command gates for every PR.
#   make test        tier-1 suite (the ROADMAP verify command)
#   make bench-smoke fast benchmark pass (all tables/figures + replication)
#   make examples    run every example end-to-end
PY      := python
PYPATH  := PYTHONPATH=src$(if $(PYTHONPATH),:$(PYTHONPATH))

.PHONY: test bench-smoke examples all

all: test bench-smoke examples

test:
	$(PYPATH) $(PY) -m pytest -x -q

bench-smoke:
	$(PYPATH) $(PY) -m benchmarks.run

examples:
	$(PY) examples/quickstart.py
	$(PY) examples/replica_relayout.py
	$(PY) examples/train_with_recovery.py
	$(PY) examples/serve_batched.py
