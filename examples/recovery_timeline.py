"""Recovery timeline: a traced crash-recovery run, end to end.

Builds a database, runs an update workload across checkpoints, crashes
it, then recovers the crash image with tracing enabled.  The trace is
written as JSONL next to the run (``artifacts/recovery_trace.jsonl``)
and rendered as a human-readable timeline: analysis/redo/undo/checkpoint
phase walls, per-window apply spans, aggregated IO events, and the
decode-cache hit rates from the metrics registry — the same numbers the
legacy ``RecoveryStats`` reports, now correlated on one clock.

    PYTHONPATH=src python examples/recovery_timeline.py   (or: make trace-demo)
"""
import random
import sys
from pathlib import Path

sys.path.insert(0, "src")

from repro import obs
from repro.core import (Database, Strategy, committed_state_oracle, make_key,
                        recover, recovered_state)

N_ROWS, VALUE = 10_000, 80
rng = random.Random(7)

print("1. load table, run transactions across checkpoints, crash ...")
db = Database(cache_pages=1024, tracker_interval=100, bg_flush_per_txn=4)
rows = [(f"k{i:08d}".encode(), rng.randbytes(VALUE)) for i in range(N_ROWS)]
db.load_table("t", rows)
base = {make_key("t", k): v for k, v in rows}

def txn_batch(n):
    for _ in range(n):
        db.run_txn([("update", "t", f"k{rng.randrange(N_ROWS):08d}".encode(),
                     rng.randbytes(VALUE)) for _ in range(10)])

txn_batch(200)
for _ in range(2):
    db.checkpoint()
    txn_batch(150)
image = db.crash()
print(f"   crash image: {len(image.log)} log records, "
      f"{len(image.store)} stable pages\n")

print("2. recover with tracing enabled (batched Log1) ...")
obs.reset()                        # fresh metrics + empty trace
obs.enable()
db2, stats = recover(image, Strategy.LOG1, batched=True, batch_window=512)
obs.disable()

assert recovered_state(db2) == committed_state_oracle(image, base), \
    "recovered state diverged from the committed-state oracle"
print(f"   ok: {stats.log_records} records redone in "
      f"{stats.redo_wall_ms:.1f}ms across {stats.windows} windows\n")

trace_path = Path("artifacts") / "recovery_trace.jsonl"
obs.trace.export_jsonl(trace_path)
print(f"3. trace written to {trace_path} "
      f"({len(obs.TRACER.events)} events); timeline:\n")
print(obs.render_timeline(snapshot=obs.snapshot()))

# the registry view agrees with the returned dataclass
view = type(stats).from_registry()
assert view.log_records == stats.log_records
assert view.redo_wall_ms == stats.redo_wall_ms
print(f"\n4. registry view consistent: recovery.redo_wall_ms = "
      f"{obs.value('recovery.redo_wall_ms'):.3f}ms "
      f"(= RecoveryStats.redo_wall_ms)")
