"""Quickstart: the logical recovery engine in 60 seconds.

Builds a small database, runs an update workload with checkpoints and fuzzy
flushing, crashes it, and recovers the same crash image with all five
strategies of the paper's study (Log0/Log1/Log2 logical, SQL1/SQL2
physiological) — printing the side-by-side redo statistics that Figure 2 is
made of, and verifying every strategy reproduces the identical state.

    PYTHONPATH=src python examples/quickstart.py
"""
import random
import sys

sys.path.insert(0, "src")

from repro.core import (Database, Strategy, committed_state_oracle, make_key,
                        recover, recovered_state)

N_ROWS, VALUE = 20_000, 100
rng = random.Random(0)

print("1. load table + warm the cache ...")
db = Database(cache_pages=1024, tracker_interval=100, bg_flush_per_txn=4)
rows = [(f"k{i:08d}".encode(), rng.randbytes(VALUE)) for i in range(N_ROWS)]
db.load_table("t", rows)
base = {make_key("t", k): v for k, v in rows}

def txn_batch(n):
    for _ in range(n):
        db.run_txn([("update", "t", f"k{rng.randrange(N_ROWS):08d}".encode(),
                     rng.randbytes(VALUE)) for _ in range(10)])

txn_batch(300)                      # warmup to steady state
print("2. checkpoints + more updates, then crash ...")
for _ in range(3):
    db.checkpoint()
    txn_batch(200)
image = db.crash()
print(f"   crash image: {len(image.log)} log records, "
      f"{len(image.store)} stable pages\n")

oracle = committed_state_oracle(image, base)
print(f"{'strategy':8s} {'modeled_ms':>10s} {'fetches':>8s} {'DPT':>6s} "
      f"{'redone':>7s} {'pruned':>7s} {'correct':>8s}")
for s in Strategy:
    rec_db, st = recover(image, s, cache_pages=1024)
    ok = recovered_state(rec_db) == oracle
    print(f"{s.value:8s} {st.io.modeled_ms:10.1f} "
          f"{st.io.total_reads():8d} {st.dpt_size:6d} "
          f"{st.redo.redone:7d} {st.redo.skipped_dpt:7d} {str(ok):>8s}")
print("\nLog1/Log2 (logical, DPT from Delta-records) track SQL1/SQL2 "
      "(physiological)\nwhile Log0 (no DPT) pays for every logged page — "
      "the paper's result.")
