"""Batched serving demo: prefill + greedy decode with KV caches, on any of
the ten architectures (reduced preset for CPU).

    PYTHONPATH=src python examples/serve_batched.py
    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-3b
"""
import sys

sys.path.insert(0, "src")

from repro.launch.serve import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "qwen2.5-3b", "--preset", "smoke",
                     "--batch", "4", "--prompt-len", "32", "--gen", "16"]
    main()
