"""End-to-end driver: train an LM with the logical-recovery state store,
hard-crash it mid-run, restore + replay, verify bit-exactness, finish the
run.  (The deliverable's "train a ~100M model for a few hundred steps" is
this script with --preset 100m --steps 300; the default is sized for a quick
demonstration on one CPU core.)

    PYTHONPATH=src python examples/train_with_recovery.py
    PYTHONPATH=src python examples/train_with_recovery.py \
        --arch qwen3-moe-30b-a3b --preset 100m --steps 300 --crash-at 140
"""
import sys

sys.path.insert(0, "src")

from repro.launch.train import main

if __name__ == "__main__":
    if len(sys.argv) == 1:
        sys.argv += ["--arch", "llama3.2-3b", "--preset", "30m",
                     "--steps", "30", "--crash-at", "17",
                     "--chunk-interval", "5", "--ckpt-interval", "10",
                     "--batch", "2", "--seq", "64"]
    main()
