"""Black-box flight recorder: crash, dump, post-mortem — from bytes alone.

Builds a database, runs an update workload, crashes it, then stages a
*failed* recovery (an injected fault mid-redo).  The always-on flight
recorder dumps its ring + metrics snapshot as a versioned black-box blob
on the way down; ``render_postmortem`` reconstructs the last-seconds
timeline and names the interrupted phase from the dump file alone — no
process state, no trace, no REPL.  A second, clean recovery then runs
with the live progress display.

    PYTHONPATH=src python examples/blackbox_demo.py   (or: make blackbox-demo)
"""
import io
import random
import sys
from pathlib import Path

sys.path.insert(0, "src")

from repro import obs
from repro.core import (Database, Strategy, committed_state_oracle, make_key,
                        recover, recovered_state)
from repro.obs.progress import ProgressObserver

N_ROWS, VALUE = 10_000, 80
rng = random.Random(11)

DUMP_DIR = Path("artifacts") / "blackbox"
DUMP_DIR.mkdir(parents=True, exist_ok=True)
obs.FLIGHT.configure(sink=DUMP_DIR)

print("1. load table, run transactions, crash ...")
db = Database(cache_pages=1024, tracker_interval=100, bg_flush_per_txn=4)
rows = [(f"k{i:08d}".encode(), rng.randbytes(VALUE)) for i in range(N_ROWS)]
db.load_table("t", rows)
base = {make_key("t", k): v for k, v in rows}
for _ in range(300):
    db.run_txn([("update", "t", f"k{rng.randrange(N_ROWS):08d}".encode(),
                 rng.randbytes(VALUE)) for _ in range(10)])
image = db.crash()
crash_dump = obs.FLIGHT.last_dump
print(f"   crash image: {len(image.log)} log records; "
      f"black box dumped to {crash_dump}\n")

print("2. recovery that dies mid-redo (injected fault at 50%) ...")


class _Sabotage(ProgressObserver):
    """Progress observer that raises once redo crosses the halfway mark —
    stands in for an OOM kill / power cut landing mid-phase."""

    def update(self, done_units, records=None):
        super().update(done_units, records)
        if self.fraction >= 0.5:
            raise RuntimeError("injected fault: process died mid-redo")


try:
    recover(image, Strategy.LOG1, batched=True, batch_window=512,
            progress=_Sabotage(out=io.StringIO()))
except RuntimeError as exc:
    print(f"   recovery failed as staged: {exc}")
fail_dump = obs.FLIGHT.last_dump
assert fail_dump is not None and fail_dump != crash_dump, \
    "failed recovery should have produced a second black-box dump"
print(f"   black box dumped to {fail_dump}\n")

print("3. post-mortem from the dump file alone:\n")
report = obs.render_postmortem(obs.load_dump(fail_dump), tail=40)
print(report)
phase = obs.interrupted_phase(obs.load_dump(fail_dump)["events"])
assert phase is not None and "redo window" in phase, \
    f"post-mortem should name the interrupted redo window, got {phase!r}"

print("\n4. clean recovery with live progress ...")
db2, stats = recover(image, Strategy.LOG1, batched=True, batch_window=512,
                     progress=ProgressObserver("recover"))
assert recovered_state(db2) == committed_state_oracle(image, base), \
    "recovered state diverged from the committed-state oracle"
print(f"   ok: {stats.log_records} records in {stats.redo_wall_ms:.1f}ms; "
      f"recovery.progress = {obs.value('recovery.progress'):.1f}")
