"""Heterogeneous replica via logical log shipping (the paper's Section 1.1
motivation): because the TC log carries no PIDs, the SAME log stream
maintains a replica whose physical layout is completely different — here a
DC with 4 KiB pages replicating a primary with 8 KiB pages.

Physiological (PID-addressed) records could never do this: the primary's
page 17 does not exist on the replica.

Steps:
  1. primary (8 KiB pages) runs an update workload,
  2. its committed logical records are shipped and applied at the replica
     (4 KiB pages, its own B-tree, its own Delta-records),
  3. states compare equal,
  4. the REPLICA is crashed and recovered with DPT-assisted logical redo —
     recovery is geometry-local, using the replica's own Delta-log records.

    PYTHONPATH=src python examples/replica_relayout.py
"""
import random
import sys

sys.path.insert(0, "src")

from repro.core import (Database, Strategy, CommitRec, UpdateRec, RecKind,
                        recover, recovered_state)

rng = random.Random(1)
N_ROWS = 5_000

print("1. primary: 8 KiB pages, workload + checkpointing ...")
primary = Database(cache_pages=512, tracker_interval=50, bg_flush_per_txn=2,
                   page_size=8192)
rows = [(f"k{i:07d}".encode(), rng.randbytes(80)) for i in range(N_ROWS)]
primary.load_table("t", rows)
for i in range(150):
    primary.run_txn([("update", "t",
                      f"k{rng.randrange(N_ROWS):07d}".encode(),
                      rng.randbytes(80)) for _ in range(10)])
    if i % 60 == 59:
        primary.checkpoint()
image = primary.crash()

print("2. replica: 4 KiB pages, apply the shipped LOGICAL records ...")
replica = Database(cache_pages=2048, tracker_interval=50, bg_flush_per_txn=2,
                   page_size=4096)
replica.load_table("t", rows)
committed = {r.txn for r in image.log.scan(1) if isinstance(r, CommitRec)}
applied = 0
for rec in image.log.scan(1):
    if isinstance(rec, UpdateRec) and rec.txn in committed:
        verb = {RecKind.UPDATE: "update", RecKind.INSERT: "insert",
                RecKind.DELETE: "delete"}[rec.op]
        replica.run_txn([(verb, rec.table, rec.key, rec.after)])
        applied += 1
print(f"   applied {applied} logical records "
      f"(primary tree height={primary.dc.btree.height}, "
      f"replica height={replica.dc.btree.height}, "
      f"replica pages={len(replica.store)})")

from repro.core import committed_state_oracle, make_key
base = {make_key("t", k): v for k, v in rows}
oracle = committed_state_oracle(image, base)
assert dict(replica.scan_all()) == oracle, "replica diverged from primary!"
print("3. replica state == primary committed state  (different page size!)")

print("4. crash the replica; recover it with DPT-assisted logical redo ...")
replica.checkpoint()
for i in range(60):
    replica.run_txn([("update", "t",
                      f"k{rng.randrange(N_ROWS):07d}".encode(),
                      rng.randbytes(80)) for _ in range(10)])
r_image = replica.crash()
r_db, stats = recover(r_image, Strategy.LOG1, cache_pages=2048,
                      page_size=4096)
print(f"   redo: {stats.redo.submitted} submitted, {stats.redo.redone} "
      f"redone, {stats.redo.skipped_dpt} DPT-pruned, "
      f"DPT={stats.dpt_size}, fetches={stats.io.total_reads()}")
print("   replica recovered on its own geometry — logical recovery is "
      "placement-oblivious.")
