"""Heterogeneous hot standby via the replication subsystem (the paper's
Section 1.1 motivation, now a real subsystem: ``repro.replication``).

Because the TC log carries no PIDs, the SAME shipped log stream maintains a
replica whose physical layout is completely different — here a DC with
4 KiB pages standing by for a primary with 8 KiB pages.  Physiological
(PID-addressed) records could never do this: the primary's page 17 does not
exist on the replica.

Steps:
  1. primary (8 KiB pages) runs an update workload; a ReplicaSet ships its
     stable logical records to a 4 KiB-page standby and routes reads with
     read-your-writes LSN tokens,
  2. states compare equal under committed_state_oracle,
  3. the REPLICA crashes and recovers *locally* with DPT-assisted logical
     redo (Strategy.LOG1), restores its durable watermark, re-subscribes
     through a fresh shipper, and converges again,
  4. the PRIMARY crashes; promote() drains the shipped tail, undoes the
     in-flight loser logically, checkpoints, and hands back a writable
     primary.

    PYTHONPATH=src python examples/replica_relayout.py
"""
import random
import sys

sys.path.insert(0, "src")

from repro.core import Database, Strategy, committed_state_oracle, make_key
from repro.replication import Replica, ReplicaSet

rng = random.Random(1)
N_ROWS = 5_000

print("1. primary 8 KiB pages, standby 4 KiB pages, shipped + routed ...")
rows = [(f"k{i:07d}".encode(), rng.randbytes(80)) for i in range(N_ROWS)]
primary = Database(cache_pages=512, tracker_interval=50, bg_flush_per_txn=2,
                   page_size=8192)
primary.load_table("t", rows)
base = {make_key("t", k): v for k, v in rows}
replica = Replica("standby", page_size=4096, cache_pages=2048,
                  tracker_interval=50, bg_flush_per_txn=2,
                  seed_tables={"t": rows})
rs = ReplicaSet(primary, [replica])

token = 0
for i in range(150):
    token = rs.write([("update", "t",
                       f"k{rng.randrange(N_ROWS):07d}".encode(),
                       rng.randbytes(80)) for _ in range(10)])
    if i % 10 == 9:
        rs.sync()
    if i % 60 == 59:
        primary.checkpoint()
res = rs.read("t", b"k0000001", min_lsn=token)   # read-your-writes
rs.sync()
print(f"   applied {replica.applied_ops} ops in {replica.applied_txns} txns "
      f"(primary height={primary.dc.btree.height}, "
      f"replica height={replica.db.dc.btree.height}); "
      f"token-read served by {res.source}")

oracle = committed_state_oracle(primary.crash(), base)
assert replica.user_state() == oracle, "replica diverged from primary!"
print("2. replica state == primary committed state  (different page size!)")

print("3. crash the replica; recover locally with Log1; re-subscribe ...")
stats = replica.recover_local(Strategy.LOG1)
print(f"   redo: {stats.redo.submitted} submitted, {stats.redo.redone} "
      f"redone, {stats.redo.skipped_dpt} DPT-pruned, DPT={stats.dpt_size}; "
      f"watermark applied={replica.applied_lsn} resume={replica.resume_lsn}")
replica.resubscribe(rs.shipper)
for _ in range(30):
    rs.write([("update", "t", f"k{rng.randrange(N_ROWS):07d}".encode(),
               rng.randbytes(80)) for _ in range(10)])
rs.sync()
oracle = committed_state_oracle(primary.crash(), base)
assert replica.user_state() == oracle, "replica diverged after recovery!"
print("   converged again — recovery strategies compose with replication.")

print("4. crash the PRIMARY mid-transaction; promote the standby ...")
loser = primary.tc.begin()
primary.tc.update(loser, "t", b"k0000002", b"LOSER")
primary.log.flush()                       # stable but uncommitted
image = primary.crash()
new_primary = rs.promote(image=image)
assert dict(new_primary.scan_all()) == committed_state_oracle(image, base), \
    "promotion diverged!"
new_primary.run_txn([("update", "t", b"k0000003", b"post-failover")])
assert new_primary.dc.read("t", b"k0000003") == b"post-failover"
print("   standby promoted: tail drained, loser undone with CLRs, "
      "end-of-recovery checkpoint taken, writes accepted.")
