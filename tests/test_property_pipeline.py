"""Hypothesis sweep for the streaming batched redo pipeline: streamed
single-pass recovery with batched apply, and streaming restore, must be
oracle-equal to the committed prefix across random crash points, batch
windows and strategies.  Skip-guarded (hypothesis is an optional dev
dependency); the seeded samples in test_recovery_pipeline.py always run.
"""
import pytest

hypothesis = pytest.importorskip("hypothesis")
from hypothesis import given, settings, strategies as st  # noqa: E402

from repro.core import (Strategy, committed_state_oracle, recover,  # noqa: E402
                        recovered_state)
from test_recovery_pipeline import _archived_primary, mixed_workload  # noqa: E402


@settings(max_examples=20, deadline=None)
@given(seed=st.integers(0, 10_000),
       window=st.sampled_from([1, 13, 128, 4096]),
       strategy=st.sampled_from([Strategy.LOG0, Strategy.LOG1,
                                 Strategy.LOG2]),
       n_txns=st.integers(20, 90))
def test_property_streamed_batched_recovery_oracle_equal(seed, window,
                                                         strategy, n_txns):
    db, base = mixed_workload(seed, n_rows=300, n_txns=n_txns,
                              ckpt_at=n_txns // 2, cache_pages=64)
    image = db.crash()
    oracle = committed_state_oracle(image, base)
    bat_db, _ = recover(image, strategy, cache_pages=64,
                        batched=True, batch_window=window)
    assert recovered_state(bat_db) == oracle


@settings(max_examples=10, deadline=None)
@given(seed=st.integers(0, 10_000),
       apply_window=st.sampled_from([1, 32, 1024]),
       cut=st.floats(0.2, 1.0))
def test_property_streaming_restore_oracle_equal(seed, apply_window, cut):
    primary, base, _backend, store, _arch = _archived_primary(seed)
    lo = store.latest().end_lsn
    hi = primary.log.stable_lsn
    target = lo + int((hi - lo) * cut)
    oracle = committed_state_oracle(primary.crash(), base, upto_lsn=target)
    db, _ = store.restore(target, primary, page_size=8192,
                          apply_window=apply_window)
    assert dict(db.scan_all()) == oracle
