"""Core recovery engine: unit + integration tests.

The central invariant (Section 5's side-by-side methodology): every strategy
recovering the same crash image must produce the byte-identical committed
database state, equal to a pure-dict oracle replay.
"""
import random

import pytest

from repro.core import (CrashImage, Database, Strategy,
                        committed_state_oracle, make_key, recover,
                        recovered_state)
from repro.core.pages import Page, empty_internal, empty_leaf
from repro.core.records import RecKind

ALL_STRATEGIES = list(Strategy)


# --------------------------------------------------------------------- pages
def test_page_roundtrip_leaf():
    p = empty_leaf(7)
    p.put(b"alpha", b"1" * 100, 5)
    p.put(b"beta", b"2" * 50, 9)
    p.slsn = 3
    q = Page.from_bytes(p.to_bytes())
    assert q.pid == 7 and q.plsn == 9 and q.slsn == 3
    assert q.records == {b"alpha": b"1" * 100, b"beta": b"2" * 50}


def test_page_roundtrip_internal():
    p = empty_internal(9)
    p.keys = [b"k1", b"k5"]
    p.children = [1, 2, 3]
    q = Page.from_bytes(p.to_bytes())
    assert q.keys == [b"k1", b"k5"] and q.children == [1, 2, 3]
    assert not q.is_leaf


def test_page_crc_detects_corruption():
    p = empty_leaf(1)
    p.put(b"k", b"v", 1)
    raw = bytearray(p.to_bytes())
    raw[-1] ^= 0xFF
    from repro.core.pages import PageCorruptError
    with pytest.raises(PageCorruptError):
        Page.from_bytes(bytes(raw))


# -------------------------------------------------------------------- harness
def make_db(n_rows=2000, value_size=60, cache_pages=256, **kw) -> tuple[Database, dict]:
    db = Database(cache_pages=cache_pages, **kw)
    rows = [(f"k{i:08d}".encode(), bytes([i % 251]) * value_size)
            for i in range(n_rows)]
    db.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}
    return db, base


def run_uniform_updates(db: Database, n_txns: int, rng: random.Random,
                        n_rows: int, ops_per_txn: int = 10, value_size: int = 60):
    for _ in range(n_txns):
        ops = []
        for _ in range(ops_per_txn):
            i = rng.randrange(n_rows)
            ops.append(("update", "t", f"k{i:08d}".encode(),
                        rng.randbytes(value_size)))
        db.run_txn(ops)


# ------------------------------------------------------------------ engine
def test_btree_basic_ops():
    db, _ = make_db(n_rows=500)
    assert db.dc.read("t", b"k00000007") == bytes([7]) * 60
    txn = db.tc.begin()
    db.tc.update(txn, "t", b"k00000007", b"new-value")
    db.tc.commit(txn)
    assert db.dc.read("t", b"k00000007") == b"new-value"
    assert db.dc.btree.height >= 2     # bulk build produced a real tree


def test_splits_happen_and_scan_is_sorted():
    db = Database(cache_pages=1024)
    db.bootstrap_empty()
    rng = random.Random(0)
    keys = [f"{rng.randrange(10**9):012d}".encode() for _ in range(3000)]
    txn = db.tc.begin()
    for k in keys:
        db.tc.insert(txn, "t", k, b"x" * 64)
    db.tc.commit(txn)
    assert db.dc.btree.smo_count > 5
    items = db.scan_all()
    assert [k for k, _ in items] == sorted(k for k, _ in items)
    assert len(items) == len(set(keys))


def test_abort_restores_before_images():
    db, base = make_db(n_rows=100)
    before = db.dc.read("t", b"k00000001")
    txn = db.tc.begin()
    db.tc.update(txn, "t", b"k00000001", b"doomed")
    db.tc.insert(txn, "t", b"zz-new-key", b"doomed-too")
    db.tc.abort(txn)
    assert db.dc.read("t", b"k00000001") == before
    assert db.dc.read("t", b"zz-new-key") is None


# ------------------------------------------------------- recovery equivalence
@pytest.mark.parametrize("strategy", ALL_STRATEGIES, ids=[s.value for s in ALL_STRATEGIES])
def test_recovery_matches_oracle(strategy):
    rng = random.Random(42)
    db, base = make_db(n_rows=2000, cache_pages=128,
                       tracker_interval=50, bg_flush_per_txn=2)
    run_uniform_updates(db, 100, rng, 2000)
    db.checkpoint()
    run_uniform_updates(db, 150, rng, 2000)
    # in-flight loser transaction at crash time
    txn = db.tc.begin()
    db.tc.update(txn, "t", b"k00000000", b"loser-update")
    db.log.flush()
    image = db.crash()

    rec_db, stats = recover(image, strategy, cache_pages=128)
    assert recovered_state(rec_db) == committed_state_oracle(image, base)
    assert stats.redo.submitted > 0
    if strategy.uses_dpt:
        assert stats.dpt_size > 0


@pytest.mark.parametrize("strategy", ALL_STRATEGIES, ids=[s.value for s in ALL_STRATEGIES])
def test_recovery_with_inserts_deletes_and_splits(strategy):
    rng = random.Random(7)
    db, base = make_db(n_rows=800, cache_pages=64, tracker_interval=40,
                       bg_flush_per_txn=1)
    oracle_keys = {f"k{i:08d}".encode() for i in range(800)}
    for t in range(120):
        ops = []
        for _ in range(6):
            roll = rng.random()
            if roll < 0.5:
                i = rng.randrange(800)
                ops.append(("update", "t", f"k{i:08d}".encode(), rng.randbytes(60)))
            elif roll < 0.85:
                ops.append(("insert", "t", f"n{rng.randrange(10**9):010d}".encode(),
                            rng.randbytes(60)))
            else:
                i = rng.randrange(800)
                ops.append(("delete", "t", f"k{i:08d}".encode(), None))
        db.run_txn(ops)
        if t == 60:
            db.checkpoint()
    image = db.crash()
    rec_db, _ = recover(image, strategy, cache_pages=64)
    assert recovered_state(rec_db) == committed_state_oracle(image, base)


def test_all_strategies_agree_exactly():
    rng = random.Random(3)
    db, base = make_db(n_rows=1500, cache_pages=96, tracker_interval=30,
                       bg_flush_per_txn=3)
    run_uniform_updates(db, 80, rng, 1500)
    db.checkpoint()
    run_uniform_updates(db, 120, rng, 1500)
    image = db.crash()
    states = {}
    for s in ALL_STRATEGIES:
        rec_db, _ = recover(image, s, cache_pages=96)
        states[s.value] = recovered_state(rec_db)
    first = states["Log0"]
    for name, st in states.items():
        assert st == first, f"{name} diverged from Log0"


def test_dpt_reduces_fetches():
    """The paper's Fig 2 claim in miniature: Log1 fetches far fewer pages than
    Log0 and exactly tracks SQL1's data-page requests (Section 5.3)."""
    rng = random.Random(11)
    db, base = make_db(n_rows=4000, cache_pages=512, tracker_interval=100,
                       bg_flush_per_txn=4)
    run_uniform_updates(db, 200, rng, 4000)
    db.checkpoint()
    run_uniform_updates(db, 300, rng, 4000)
    image = db.crash()
    _, s_log0 = recover(image, Strategy.LOG0, cache_pages=512)
    _, s_log1 = recover(image, Strategy.LOG1, cache_pages=512)
    _, s_sql1 = recover(image, Strategy.SQL1, cache_pages=512)
    assert s_log1.io.sync_reads < s_log0.io.sync_reads
    # Log1 == SQL1 on *data* pages; Log1 additionally reads index pages
    assert s_log1.redo.skipped_dpt >= s_sql1.redo.skipped_dpt * 0.5
    assert s_log1.dpt_size == s_sql1.dpt_size or \
        abs(s_log1.dpt_size - s_sql1.dpt_size) <= max(3, 0.1 * s_sql1.dpt_size)


def test_crash_recover_continue_crash_recover():
    """Recovery produces a *live* database: continue the workload, crash
    again, recover again (double-crash path exercises CLR redo + new deltas)."""
    rng = random.Random(5)
    db, base = make_db(n_rows=600, cache_pages=64, tracker_interval=25,
                       bg_flush_per_txn=2)
    run_uniform_updates(db, 60, rng, 600)
    db.checkpoint()
    run_uniform_updates(db, 40, rng, 600)
    image1 = db.crash()

    db2, _ = recover(image1, Strategy.LOG1, cache_pages=64)
    oracle1 = committed_state_oracle(image1, base)
    assert recovered_state(db2) == oracle1

    run_uniform_updates(db2, 50, rng, 600)
    db2.checkpoint()
    run_uniform_updates(db2, 30, rng, 600)
    image2 = db2.crash()
    for s in (Strategy.LOG1, Strategy.SQL1, Strategy.LOG2):
        db3, _ = recover(image2, s, cache_pages=64)
        # oracle over image2's full log with the same original base
        assert recovered_state(db3) == committed_state_oracle(image2, base)


def test_recovery_without_any_checkpoint():
    db = Database(cache_pages=64, tracker_interval=20)
    db.bootstrap_empty()
    rng = random.Random(9)
    for _ in range(30):
        db.run_txn([("insert", "t", rng.randbytes(8).hex().encode(),
                     rng.randbytes(40)) for _ in range(5)])
    image = db.crash()
    for s in ALL_STRATEGIES:
        rec_db, _ = recover(image, s, cache_pages=64)
        assert recovered_state(rec_db) == committed_state_oracle(image, {})
