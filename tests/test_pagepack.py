"""Packed binary pages + bounded buffer pool.

Four invariant families:

  codec     pack -> unpack is exact for arbitrary leaves/internals; any
            truncation or bit flip raises PageCorruptError loudly — a
            torn page is never a short page; v0 bytes decode forever.
  reads     every zero-decode read op on the packed form agrees with the
            materialized dict form.
  cache     the decode cache evicts LRU one-at-a-time, never wholesale.
  pool      residency stays <= capacity, pins block eviction, dirty
            victims flush through the WAL clamp, and a recovery whose
            page set exceeds the pool still matches the oracle.
"""
import random

import pytest

from repro.core import (Database, Strategy, committed_state_oracle, make_key,
                        recover, recovered_state)
from repro.core.bufferpool import BufferPool
from repro.core.log import LogManager
from repro.core.pages import (HEADER_SIZE, PAGE_MAGIC, PAGE_VERSION,
                              SLOT_OVERHEAD, Page, PageCorruptError,
                              empty_internal, empty_leaf, pack_v0)
from repro.core.storage import PageStore


# ----------------------------------------------------------------- builders
def make_leaf(rng: random.Random, n: int, pid: int = 7) -> Page:
    p = empty_leaf(pid)
    for i in range(n):
        k = rng.randbytes(rng.randrange(1, 24))
        v = rng.randbytes(rng.randrange(0, 64))
        p.put(k, v, i + 1)
    p.slsn = rng.randrange(0, 100)
    return p


def make_internal(rng: random.Random, n: int, pid: int = 9) -> Page:
    p = empty_internal(pid)
    seps = sorted({rng.randbytes(rng.randrange(1, 16)) for _ in range(n)})
    p.keys = seps
    p.children = [rng.randrange(1, 1 << 40) for _ in range(len(seps) + 1)]
    p.slsn = rng.randrange(0, 100)
    return p


def assert_equivalent(packed: Page, dictform: Page) -> None:
    """Every read op must agree between the two forms."""
    assert packed == dictform
    assert packed.n_entries() == dictform.n_entries()
    assert packed.serialized_size() == dictform.serialized_size()
    if packed.is_leaf:
        assert packed.sorted_items() == sorted(dictform.records.items())
        for k, _ in dictform.records.items():
            assert packed.get(k) == dictform.get(k)
        assert packed.get(b"\x00nope") == dictform.get(b"\x00nope")
    else:
        n = dictform.sep_count()
        assert packed.sep_count() == n
        assert packed.child_count() == n + 1
        probes = [dictform.sep_at(i) for i in range(n)]
        probes += [s + b"\x00" for s in probes] + [b"", b"\xff" * 20]
        for i in range(n):
            assert packed.sep_at(i) == dictform.sep_at(i)
        for i in range(n + 1):
            assert packed.child_at(i) == dictform.child_at(i)
        assert packed.child_at(-1) == dictform.child_at(-1)
        for key in probes:
            assert packed.child_index(key) == dictform.child_index(key)


# ------------------------------------------------------ seeded round trips
@pytest.mark.parametrize("seed", [1, 2, 3, 4, 5])
def test_leaf_roundtrip_and_read_equivalence(seed):
    rng = random.Random(seed)
    for n in (0, 1, 2, rng.randrange(3, 80)):
        orig = make_leaf(random.Random(seed * 100 + n), n)
        raw = orig.clone().to_bytes()
        packed = Page.from_bytes(raw)
        assert packed._raw is not None          # genuinely packed
        assert_equivalent(packed, orig)
        # repack of an untouched packed page is the identical frame
        assert packed.to_bytes() == raw
        # materialized copy re-packs to the identical frame too
        assert Page.from_bytes(raw).materialize().to_bytes() == raw


@pytest.mark.parametrize("seed", [11, 12, 13, 14, 15])
def test_internal_roundtrip_and_read_equivalence(seed):
    rng = random.Random(seed)
    for n in (1, 2, rng.randrange(3, 60)):
        orig = make_internal(random.Random(seed * 100 + n), n)
        raw = orig.clone().to_bytes()
        packed = Page.from_bytes(raw)
        assert packed._raw is not None
        assert_equivalent(packed, orig)
        assert packed.to_bytes() == raw


def test_packed_mutation_unpacks_and_reads_back():
    orig = make_leaf(random.Random(42), 20)
    p = Page.from_bytes(orig.to_bytes())
    p.put(b"new-key", b"new-val", 999)
    p.delete(next(iter(orig.records)), 1000)
    assert p._raw is None                       # cache dropped on write
    q = Page.from_bytes(p.to_bytes())
    assert q == p and q.plsn == 1000
    assert q.get(b"new-key") == b"new-val"


def test_split_sizing_identical_packed_vs_dict():
    """Split decisions must replay identically whether redo finds the
    page packed or materialized: would_overflow agrees byte-for-byte."""
    rng = random.Random(7)
    leaf = make_leaf(rng, 40)
    packed = Page.from_bytes(leaf.to_bytes())
    for _ in range(200):
        k = rng.randbytes(rng.randrange(1, 30))
        v = rng.randbytes(rng.randrange(0, 120))
        for ps in (256, 1024, leaf.serialized_size(),
                   leaf.serialized_size() + len(k) + len(v) + SLOT_OVERHEAD):
            assert (packed.would_overflow(k, v, ps)
                    == leaf.would_overflow(k, v, ps))


def test_copy_of_packed_page_is_o1_and_isolated():
    p = Page.from_bytes(make_leaf(random.Random(3), 12).to_bytes())
    c = p.copy()
    assert c._raw is p._raw                     # shared immutable bytes
    c.put(b"k", b"v", 5)
    assert p.get(b"k") is None                  # copy diverged privately
    assert p._raw is not None


# ------------------------------------------------------------- corruption
def test_truncation_at_every_boundary_is_loud():
    raw = make_leaf(random.Random(9), 8).to_bytes()
    for cut in range(len(raw)):
        with pytest.raises(PageCorruptError):
            Page.from_bytes(raw[:cut])


def test_bit_flips_are_loud_never_wrong():
    rng = random.Random(13)
    for builder in (make_leaf, make_internal):
        page = builder(rng, 10)
        raw = page.to_bytes()
        for _ in range(200):
            i = rng.randrange(len(raw))
            bad = bytearray(raw)
            bad[i] ^= 1 << rng.randrange(8)
            try:
                got = Page.from_bytes(bytes(bad))
            except PageCorruptError:
                continue
            # a flip inside the magic demotes the frame to the v0 decode
            # path, whose own CRC rejects it (PageCorruptError above) —
            # so any successful decode must be byte-identical input
            assert bytes(bad) == raw or got == page, \
                "corrupt frame decoded silently"


def test_unknown_version_byte_is_loud():
    raw = bytearray(make_leaf(random.Random(1), 3).to_bytes())
    assert raw[:3] == PAGE_MAGIC
    raw[3] = PAGE_VERSION + 1
    with pytest.raises(PageCorruptError, match="version"):
        Page.from_bytes(bytes(raw))


def test_v0_bytes_decode_forever():
    """Old bytes live inside archived SMORec images: the legacy layout
    must decode exactly, forever."""
    rng = random.Random(21)
    leaf, node = make_leaf(rng, 15), make_internal(rng, 8)
    for page in (leaf, node):
        got = Page.from_bytes(pack_v0(page))
        assert got == page
        # and a v0 page re-serializes as v1 going forward
        assert got.to_bytes()[:3] == PAGE_MAGIC
    with pytest.raises(PageCorruptError):
        Page.from_bytes(pack_v0(leaf)[:-3])


# -------------------------------------------------- hypothesis round trip
try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYP = True
except ImportError:                    # pragma: no cover — optional dep
    HAVE_HYP = False

if HAVE_HYP:
    record_sets = st.dictionaries(st.binary(min_size=1, max_size=40),
                                  st.binary(max_size=120), max_size=60)

    @given(recs=record_sets, pid=st.integers(1, 1 << 40),
           plsn=st.integers(0, 1 << 50), cut=st.integers(0, 10_000),
           flip=st.integers(0, 10_000))
    @settings(max_examples=60, deadline=None)
    def test_property_pack_unpack_exact_and_loud(recs, pid, plsn, cut, flip):
        p = empty_leaf(pid)
        for k, v in recs.items():
            p.put(k, v, plsn)
        raw = p.to_bytes()
        q = Page.from_bytes(raw)
        assert q == p and sorted(recs.items()) == q.sorted_items()
        if len(raw) > HEADER_SIZE:
            with pytest.raises(PageCorruptError):
                Page.from_bytes(raw[:HEADER_SIZE + cut % (len(raw) - HEADER_SIZE)])
        bad = bytearray(raw)
        bad[flip % len(bad)] ^= 0xA5
        if bytes(bad) != raw:
            try:
                got = Page.from_bytes(bytes(bad))
            except PageCorruptError:
                got = None
            assert got is None or got == p


# ---------------------------------------------------------- decode cache
def test_decode_cache_evicts_lru_not_wholesale():
    store = PageStore()
    keep = 4
    store.DECODE_CACHE_MAX = keep
    pages = []
    for i in range(1, 10):
        pg = empty_leaf(store.allocate_pid())
        pg.put(f"k{i}".encode(), b"v" * i, i)
        store.write_page(pg)
        pages.append(pg.pid)
    for pid in pages:
        store.read_page(pid)
    assert len(store._decoded) == keep          # bounded, not cleared
    h0, m0 = store.decode_hits, store.decode_misses
    store.read_page(pages[-1])                  # hottest entry: still cached
    assert (store.decode_hits, store.decode_misses) == (h0 + 1, m0)
    store.read_page(pages[0])                   # coldest: evicted -> miss
    assert store.decode_misses == m0 + 1
    assert len(store._decoded) == keep          # still bounded


def test_page_blobs_live_on_the_backend(tmp_path):
    from repro.media.backend import DirectoryBackend
    backend = DirectoryBackend(tmp_path / "pages")
    store = PageStore(backend)
    pg = empty_leaf(store.allocate_pid())
    pg.put(b"k", b"v", 1)
    store.write_page(pg)
    assert backend.list("page/") == [f"page/{pg.pid:012d}"]
    # a fresh store over the same backend sees the page (cold restart)
    again = PageStore(DirectoryBackend(tmp_path / "pages"))
    got = again.read_page(pg.pid)
    assert got is not None and got.get(b"k") == b"v"
    assert again.read_page(999) is None         # missing = answer, not error


# ------------------------------------------------------------ buffer pool
def _pool(capacity) -> BufferPool:
    store = PageStore()
    log = LogManager()
    for i in range(20):
        pg = empty_leaf(store.allocate_pid())
        pg.put(f"k{i:03d}".encode(), b"v", 1)
        store.write_page(pg)
    return BufferPool(store, log, capacity_pages=capacity)


def test_pool_residency_is_bounded():
    pool = _pool(capacity=5)
    for pid in range(1, 21):
        assert pool.get(pid) is not None
    assert len(pool) <= 5
    assert pool.peak_resident <= 5
    assert pool.evictions >= 15


def test_pool_pinned_frames_are_never_victims():
    pool = _pool(capacity=3)
    pool.get(1, pin=True)
    pool.get(2, pin=True)
    for pid in range(3, 15):
        pool.get(pid)
    assert 1 in pool.buffers and 2 in pool.buffers
    pool.unpin(1)
    pool.unpin(2)
    for pid in range(15, 21):
        pool.get(pid)
    assert len(pool) <= 3


def test_pool_all_pinned_overflows_softly():
    pool = _pool(capacity=2)
    pool.get(1, pin=True)
    pool.get(2, pin=True)
    assert pool.get(3) is not None              # overflow, not deadlock
    assert len(pool) == 3
    pool.unpin(1)
    pool.unpin(2)


def test_pool_clock_prefers_clean_victims():
    pool = _pool(capacity=4)
    for pid in (1, 2, 3, 4):
        pool.get(pid)
    pool.mark_dirty(2, 10)
    # age every ref bit out, then fault: a clean frame must go first
    flushes_before = pool.flushes
    pool.get(5)
    assert 2 in pool.buffers                    # dirty frame survived
    assert pool.flushes == flushes_before       # and nothing was flushed


def test_pool_dirty_eviction_respects_wal_clamp():
    pool = _pool(capacity=2)
    log = pool.log
    from repro.core.records import UpdateRec
    lsn = log.append(UpdateRec(txn=1, table="t", key=b"k", before=None,
                               after=b"v"))
    assert log.stable_lsn < lsn                 # record not yet stable
    pool.get(1)
    pool.mark_dirty(1, lsn)
    pool.get(2)
    pool.mark_dirty(2, lsn)
    pool.get(3)                                 # every victim is dirty now
    assert pool.flushes >= 1                    # eviction had to flush
    assert log.stable_lsn >= lsn                # WAL forced first


def test_pool_metrics_counters_track_stats():
    from repro.obs import metrics as obs_metrics
    snap0 = obs_metrics.REGISTRY.snapshot()
    pool = _pool(capacity=4)
    for pid in range(1, 13):
        pool.get(pid)
    pool.get(12)                                # one warm hit
    snap = obs_metrics.REGISTRY.snapshot()

    def delta(key):
        return snap.get(key, 0) - snap0.get(key, 0)

    assert delta("bufferpool.hits") == pool.hits == 1
    assert delta("bufferpool.misses") == pool.fetches == 12
    assert delta("bufferpool.evictions") == pool.evictions
    assert pool.evictions >= 8


def test_recovery_with_pool_smaller_than_page_set_matches_oracle():
    """The acceptance shape: crash-recover a database whose page set
    exceeds the pool, under every logical strategy — bounded residency
    with byte-identical results."""
    rng = random.Random(99)
    db = Database(cache_pages=512, tracker_interval=40)
    rows = [(f"k{i:06d}".encode(), rng.randbytes(80)) for i in range(3000)]
    db.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}
    for _ in range(120):
        db.run_txn([("update", "t", f"k{rng.randrange(3000):06d}".encode(),
                     rng.randbytes(80)) for _ in range(5)])
    image = db.crash()
    oracle = committed_state_oracle(image, base)
    n_pages = len(image.store)
    cap = max(8, n_pages // 6)
    assert cap < n_pages
    for strategy in (Strategy.LOG0, Strategy.LOG1):
        rec_db, stats = recover(image, strategy, cache_pages=cap,
                                batched=True, batch_window=512)
        assert recovered_state(rec_db) == oracle
        assert stats.pool_capacity == cap
        assert 0 < stats.pool_peak_resident <= cap
        assert stats.pool_evictions > 0
