"""Optimizer + data pipeline unit tests."""
import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_config
from repro.data import TokenPipeline
from repro.optim import AdamWConfig, apply_updates, init_opt_state, schedule


def test_adamw_minimizes_quadratic():
    cfg = AdamWConfig(lr=0.1, weight_decay=0.0, warmup_steps=0,
                      total_steps=200, clip_norm=10.0)
    target = jnp.asarray([3.0, -2.0, 0.5])
    params = {"w": jnp.zeros(3)}
    state = init_opt_state(params)

    def loss_fn(p):
        return jnp.sum(jnp.square(p["w"] - target))

    for _ in range(150):
        g = jax.grad(loss_fn)(params)
        params, state, m = apply_updates(params, g, state, cfg)
    assert float(loss_fn(params)) < 1e-2
    assert m["grad_norm"] >= 0


def test_schedule_warmup_and_cosine():
    cfg = AdamWConfig(lr=1.0, warmup_steps=10, total_steps=110,
                      min_lr_frac=0.1)
    assert float(schedule(cfg, jnp.asarray(0))) < 0.11
    assert abs(float(schedule(cfg, jnp.asarray(10))) - 1.0) < 1e-5
    assert abs(float(schedule(cfg, jnp.asarray(110))) - 0.1) < 1e-5


def test_mixed_precision_master_weights():
    cfg = AdamWConfig(lr=1e-2, warmup_steps=0, total_steps=10)
    params = {"w": jnp.ones(4, jnp.bfloat16)}
    state = init_opt_state(params)
    assert state["master"]["w"].dtype == jnp.float32
    g = {"w": jnp.ones(4, jnp.bfloat16) * 0.1}
    new_p, new_s, _ = apply_updates(params, g, state, cfg)
    assert new_p["w"].dtype == jnp.bfloat16          # compute dtype preserved
    assert new_s["master"]["w"].dtype == jnp.float32


def test_pipeline_determinism_and_resume():
    cfg = get_config("llama3.2-3b").reduced()
    p1 = TokenPipeline(cfg, batch=2, seq=16, seed=7)
    batches = [p1.next() for _ in range(5)]
    snap = p1.snapshot()
    after = [p1.next() for _ in range(3)]

    # restore from snapshot -> identical continuation
    p2 = TokenPipeline(cfg, batch=2, seq=16, seed=7)
    p2.restore(snap)
    again = [p2.next() for _ in range(3)]
    for (i1, b1), (i2, b2) in zip(after, again):
        assert i1 == i2
        assert jnp.array_equal(b1["tokens"], b2["tokens"])

    # batch_at is a pure function of (seed, idx)
    assert jnp.array_equal(p1.batch_at(2)["tokens"], batches[2][1]["tokens"])


def test_pipeline_modality_stubs():
    for arch in ("pixtral-12b", "whisper-base"):
        cfg = get_config(arch).reduced()
        pipe = TokenPipeline(cfg, batch=2, seq=8, seed=0)
        _, b = pipe.next()
        key = "patches" if cfg.family == "vlm" else "frames"
        assert key in b and b[key].ndim == 3
