"""Shared workload generator for the replication test suites.

One definition of the primary fixture and the randomized op mix
(update/insert/delete with occasional aborted transactions) so
test_replication.py and test_parallel_apply.py exercise the same workload
shape at their own scales — change the mix here, and both suites move
together."""
from repro.core import Database, make_key


def make_primary(rng, *, n_rows, val, page_size=8192):
    rows = [(f"k{i:05d}".encode(), rng.randbytes(val)) for i in range(n_rows)]
    db = Database(page_size=page_size, cache_pages=256, tracker_interval=25,
                  bg_flush_per_txn=2)
    db.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}
    return db, rows, base


def random_ops(rng, n, *, n_rows, val):
    ops = []
    for _ in range(n):
        roll = rng.random()
        if roll < 0.7:
            ops.append(("update", "t", f"k{rng.randrange(n_rows):05d}".encode(),
                        rng.randbytes(val)))
        elif roll < 0.9:
            ops.append(("insert", "t", f"x{rng.randrange(10**6):07d}".encode(),
                        rng.randbytes(val)))
        else:
            ops.append(("delete", "t", f"k{rng.randrange(n_rows):05d}".encode(),
                        None))
    return ops


def drive(db, rng, n_txns, *, n_rows, val, abort_frac=0.15):
    for _ in range(n_txns):
        ops = random_ops(rng, rng.randrange(1, 6), n_rows=n_rows, val=val)
        if rng.random() < abort_frac:
            txn = db.tc.begin()
            for verb, table, key, value in ops:
                if verb == "update":
                    db.tc.update(txn, table, key, value)
                elif verb == "insert":
                    db.tc.insert(txn, table, key, value)
                else:
                    db.tc.delete(txn, table, key)
            db.tc.abort(txn)
        else:
            db.run_txn(ops)
