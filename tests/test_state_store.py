"""State store: chunking round-trips + end-to-end crash/restore of a real
(tiny) training run — the paper's technique as training fault tolerance."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import get_config
from repro.core import Strategy
from repro.models import build_model
from repro.optim import AdamWConfig, apply_updates, init_opt_state
from repro.state_store import (TrainWAL, WALConfig, records_to_tree,
                               resume_from_crash, train_with_recovery,
                               tree_to_records)


def test_chunking_roundtrip_mixed_dtypes():
    tree = {
        "a": jnp.arange(100_000, dtype=jnp.float32).reshape(100, 1000),
        "b": {"w": jnp.ones((33,), jnp.bfloat16) * 1.5,
              "s": jnp.asarray(7, jnp.int32)},
    }
    records = dict(tree_to_records(tree, chunk_elems=4096))
    assert len(records) > 25            # 'a' split into many chunks
    out = records_to_tree(tree, records, chunk_elems=4096)
    assert jnp.array_equal(out["a"], tree["a"])
    assert jnp.array_equal(out["b"]["w"], tree["b"]["w"])
    assert out["b"]["s"] == 7
    assert out["b"]["w"].dtype == jnp.bfloat16


def _tiny_trainer():
    cfg = get_config("llama3.2-3b").reduced()
    api = build_model(cfg)
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=2, total_steps=100)
    params = api.init(jax.random.PRNGKey(0))
    state0 = {"params": params, "opt": init_opt_state(params)}

    @jax.jit
    def train_step(state, batch):
        loss, grads = jax.value_and_grad(api.loss)(state["params"], batch)
        new_p, new_opt, m = apply_updates(state["params"], grads,
                                          state["opt"], opt_cfg)
        return {"params": new_p, "opt": new_opt}, {"loss": loss, **m}

    def batch_at(idx):
        key = jax.random.fold_in(jax.random.PRNGKey(42), idx)
        return {"tokens": jax.random.randint(key, (2, 32), 0, cfg.vocab_size,
                                             dtype=jnp.int32)}
    return train_step, state0, batch_at


def _trees_equal(a, b, atol=0.0):
    la, lb = jax.tree.leaves(a), jax.tree.leaves(b)
    assert len(la) == len(lb)
    for x, y in zip(la, lb):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), atol=atol)


@pytest.mark.parametrize("strategy", [Strategy.LOG1, Strategy.LOG2,
                                      Strategy.SQL1])
def test_crash_restore_replay_exact(strategy):
    train_step, state0, batch_at = _tiny_trainer()
    wal_cfg = WALConfig(chunk_interval=4, ckpt_interval=8, bg_flush_pages=4,
                        cache_pages=512, chunk_elems=8192,
                        tracker_interval=50)
    wal = TrainWAL(wal_cfg)
    wal.log_state(0, 0, state0)

    n_steps = 11                        # crash mid-interval: tail replay needed
    final = train_with_recovery(train_step=train_step, init_state=state0,
                                batch_at=batch_at, n_steps=n_steps, wal=wal)
    image = wal.crash()

    wal2, restored, step, stats = resume_from_crash(
        image, state0, train_step=train_step, batch_at=batch_at,
        wal_cfg=wal_cfg, strategy=strategy)
    assert step == n_steps
    # bf16 params + f32 opt state replayed deterministically => exact
    _trees_equal(restored, final)
    assert stats.redo.submitted > 0


def test_restore_continues_training():
    train_step, state0, batch_at = _tiny_trainer()
    wal_cfg = WALConfig(chunk_interval=3, ckpt_interval=6, bg_flush_pages=2,
                        cache_pages=256, chunk_elems=8192)
    wal = TrainWAL(wal_cfg)
    wal.log_state(0, 0, state0)
    # run 7 steps, crash, restore, run 3 more == straight-through 10 steps
    mid = train_with_recovery(train_step=train_step, init_state=state0,
                              batch_at=batch_at, n_steps=7, wal=wal)
    image = wal.crash()
    wal2, restored, step, _ = resume_from_crash(
        image, state0, train_step=train_step, batch_at=batch_at,
        wal_cfg=wal_cfg)
    resumed = train_with_recovery(train_step=train_step, init_state=restored,
                                  batch_at=batch_at, n_steps=10, wal=wal2,
                                  start_step=step)
    straight = state0
    for s in range(10):
        straight, _ = train_step(straight, batch_at(s))
    _trees_equal(resumed, straight)


def test_recovery_cost_scales_with_dirty_pages_not_state_size():
    """The paper's core claim transplanted: with the DPT, redo fetches ~dirty
    pages, NOT every page the log mentions.  The workload is sparse (an
    embedding-table-like state where each step touches a few rows) — the
    regime DESIGN.md documents as the technique's sweet spot; a dense-AdamW
    state dirties everything every step and the DPT honestly degenerates."""
    import numpy as np
    rng = np.random.default_rng(0)
    n_rows, row_elems = 400, 2048          # ~3.2 MB "embedding table"
    state = {"table": jnp.asarray(rng.normal(size=(n_rows, row_elems)),
                                  jnp.float32)}

    wal_cfg = WALConfig(chunk_interval=1, ckpt_interval=100,
                        bg_flush_pages=16, cache_pages=2048,
                        chunk_elems=row_elems, tracker_interval=10)
    wal = TrainWAL(wal_cfg)
    wal.log_state(0, 0, state)
    wal.db.checkpoint()
    arr = np.array(state["table"])
    for step in range(1, 25):
        rows = rng.integers(0, n_rows, size=6)     # sparse touch
        arr[rows] += rng.normal(size=(len(rows), row_elems)).astype(np.float32)
        state = {"table": jnp.asarray(arr)}
        wal.log_state(step, step, state)           # delta_only: 6 chunks/step
    image = wal.crash()
    from repro.core import recover
    _, s_log0 = recover(image, Strategy.LOG0, cache_pages=2048,
                        page_size=wal_cfg.page_size)
    _, s_log1 = recover(image, Strategy.LOG1, cache_pages=2048,
                        page_size=wal_cfg.page_size)
    assert s_log1.redo.skipped_dpt > 0
    assert s_log1.io.sync_reads < s_log0.io.sync_reads, \
        (s_log1.io.sync_reads, s_log0.io.sync_reads)
