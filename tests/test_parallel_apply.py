"""Key-range parallel apply tests: sharded redo vs the committed-state
oracle, per-shard read-your-writes watermarks, epoch-barrier crash
consistency, failover with sharded in-flight buffers, and serial-vs-sharded
convergence under randomized fault schedules."""
import random

import pytest

import repl_workload
from repro.core import (Strategy, committed_state_oracle, make_key)
from repro.replication import (LogShipper, Replica, ReplicaSet,
                               ShardedApplier, hash_partitioner,
                               range_partitioner)

N_ROWS = 300
VAL = 32


def make_primary(rng, page_size=8192):
    return repl_workload.make_primary(rng, n_rows=N_ROWS, val=VAL,
                                      page_size=page_size)


def make_sharded(rows, rid="s1", page_size=4096, **kw):
    kw.setdefault("n_shards", 4)
    kw.setdefault("epoch_txns", 8)
    return ShardedApplier(rid, page_size=page_size, cache_pages=512,
                          tracker_interval=25, bg_flush_per_txn=2,
                          seed_tables={"t": rows}, **kw)


def make_serial(rows, rid="r1", page_size=4096):
    return Replica(rid, page_size=page_size, cache_pages=512,
                   tracker_interval=25, bg_flush_per_txn=2,
                   seed_tables={"t": rows})


def drive(db, rng, n_txns, abort_frac=0.15):
    repl_workload.drive(db, rng, n_txns, n_rows=N_ROWS, val=VAL,
                        abort_frac=abort_frac)


# ---------------------------------------------------------------- partitioners
def test_hash_partitioner_is_stable_and_in_range():
    part = hash_partitioner(5)
    seen = set()
    for i in range(200):
        idx = part("t", f"k{i}".encode())
        assert 0 <= idx < 5
        assert idx == part("t", f"k{i}".encode())     # deterministic
        seen.add(idx)
    assert seen == set(range(5))                      # all shards used


def test_range_partitioner_maps_by_boundaries():
    part = range_partitioner([("t", b"k1"), ("t", b"k2")])
    assert part("t", b"k0") == 0
    assert part("t", b"k1") == 1         # boundary starts the next shard
    assert part("t", b"k15") == 1
    assert part("t", b"k2") == 2
    assert part("t", b"k3") == 2
    with pytest.raises(ValueError, match="sorted"):
        range_partitioner([("t", b"k2"), ("t", b"k1")])


def test_sharded_applier_validates_config():
    with pytest.raises(ValueError, match="n_shards"):
        ShardedApplier("bad", n_shards=0)
    with pytest.raises(ValueError, match="epoch_txns"):
        ShardedApplier("bad", epoch_txns=0)
    with pytest.raises(ValueError, match="partitioner"):
        ShardedApplier("bad", partitioner="zorp")
    rep = ShardedApplier("oob", n_shards=2,
                         partitioner=lambda table, key: 7)
    with pytest.raises(ValueError, match="outside"):
        rep._shard_of("t", b"k")


# ------------------------------------------------------------ oracle equality
def test_sharded_matches_oracle_heterogeneous():
    rng = random.Random(1)
    primary, rows, base = make_primary(rng, page_size=8192)
    rep = make_sharded(rows, page_size=4096)
    rs = ReplicaSet(primary, [rep])
    drive(primary, rng, 60)
    rs.sync(max_records=50)                  # interleave partial syncs
    drive(primary, rng, 40)
    rs.sync()
    oracle = committed_state_oracle(primary.crash(), base)
    assert rep.user_state() == oracle
    assert rep.db.dc.page_size != primary.dc.page_size
    assert rep.barriers > 1                  # epochs actually closed
    assert rep.applied_lsn == primary.log.last_stable_commit_lsn
    assert rep.lag(primary.log) == 0


def test_sharded_commit_buffering_hides_inflight_work():
    rng = random.Random(2)
    primary, rows, base = make_primary(rng)
    rep = make_sharded(rows)
    rs = ReplicaSet(primary, [rep])
    txn = primary.tc.begin()
    primary.tc.update(txn, "t", b"k00000", b"UNCOMMITTED")
    primary.tc.update(txn, "t", b"k00001", b"UNCOMMITTED2")
    primary.log.flush()
    rs.sync()
    assert rep.read("t", b"k00000") == base[make_key("t", b"k00000")]
    assert txn in rep.pending                # merged per-shard slices
    assert len(rep.pending[txn]) == 2
    primary.tc.commit(txn)
    rs.sync()
    assert rep.read("t", b"k00000") == b"UNCOMMITTED"
    assert rep.read("t", b"k00001") == b"UNCOMMITTED2"


def test_sharded_overlapping_redelivery_skips_consumed_records():
    rng = random.Random(3)
    primary, rows, base = make_primary(rng)
    rep = make_sharded(rows)
    rs = ReplicaSet(primary, [rep])
    rs.write([("update", "t", b"k00001", b"A")])
    txn = primary.tc.begin()                 # straddler across the rewind
    primary.tc.update(txn, "t", b"k00002", b"S1")
    primary.tc.update(txn, "t", b"k00007", b"S2")
    primary.log.flush()
    rs.sync()
    assert len(rep.pending[txn]) == 2
    rs.shipper.subscribe("s1", 1)            # re-poll already-shipped range
    rs.sync()
    assert len(rep.pending[txn]) == 2        # per-shard slices not doubled
    assert rep.skipped_dup_recs > 0
    primary.tc.commit(txn)
    rs.sync()
    assert rep.user_state() == committed_state_oracle(primary.crash(), base)


# ----------------------------------------------------- per-shard watermarks
def test_shard_watermark_routing_mid_epoch():
    """Between barriers, a drained shard serves read-your-writes tokens the
    conservative min-over-shards barrier cannot."""
    rng = random.Random(4)
    primary, rows, base = make_primary(rng)
    part = range_partitioner([("t", b"k00150")])       # 2 ranges
    rep = make_sharded(rows, n_shards=2, partitioner=part,
                       epoch_txns=100, auto_pump=False)
    rs = ReplicaSet(primary, [rep])
    tok_a = rs.write([("update", "t", b"k00010", b"A")])   # shard 0
    tok_b = rs.write([("update", "t", b"k00200", b"B")])   # shard 1
    rs.sync()                                # ingests + dispatches, no pump
    assert rep.queued_slices() == 2
    rep.pump(shard=0)                        # only shard 0 applies
    assert rep.applied_lsn == 0              # durable barrier untouched
    assert rep.watermark_for("t", b"k00010") >= tok_a
    assert rep.watermark_for("t", b"k00200") < tok_b
    assert rep.catchup_lsn() < tok_b         # conservative min-over-shards
    res = rs.read("t", b"k00010", min_lsn=tok_a)
    assert res.source == "s1" and res.value == b"A"
    res = rs.read("t", b"k00200", min_lsn=tok_b)
    assert res.source == "primary" and res.value == b"B"
    rep.pump()
    res = rs.read("t", b"k00200", min_lsn=tok_b)
    assert res.source == "s1" and res.value == b"B"
    rep.barrier()                            # close the epoch durably
    assert rep.applied_lsn >= tok_b
    assert rep.resume_lsn == rep.applied_lsn + 1


# ------------------------------------------------- epoch-barrier crash safety
def test_sharded_crash_mid_epoch_recovers_to_barrier():
    rng = random.Random(5)
    primary, rows, base = make_primary(rng)
    rep = make_sharded(rows, n_shards=3, epoch_txns=16)
    rs = ReplicaSet(primary, [rep])
    drive(primary, rng, 50)
    rs.sync(max_records=77)                  # stop partway through the stream
    while rep._dispatched_lsn <= rep.applied_lsn:    # nudge off a barrier
        rs.sync(max_records=3)
    barrier_applied, barrier_resume = rep.applied_lsn, rep.resume_lsn
    assert rep._dispatched_lsn > rep.applied_lsn     # genuinely mid-epoch
    stats = rep.recover_local(Strategy.LOG1)
    assert stats.strategy == "Log1"
    # recovery lands on the single consistent pre-epoch resume point
    assert (rep.applied_lsn, rep.resume_lsn) == (barrier_applied,
                                                 barrier_resume)
    assert rep.resume_lsn <= rep.applied_lsn + 1
    assert rep.queued_slices() == 0 and not rep.pending
    fresh = LogShipper(primary)              # shipper restart: soft cursors
    rep.resubscribe(fresh)
    fresh.drain("s1", rep.apply_batch)
    oracle = committed_state_oracle(primary.crash(), base)
    assert rep.user_state() == oracle


def test_sharded_crash_recovery_via_log2_also_works():
    rng = random.Random(6)
    primary, rows, base = make_primary(rng)
    rep = make_sharded(rows)
    rs = ReplicaSet(primary, [rep])
    drive(primary, rng, 30)
    rs.sync()
    rep.recover_local(Strategy.LOG2)
    rep.resubscribe(rs.shipper)
    drive(primary, rng, 10)
    rs.sync()
    assert rep.user_state() == committed_state_oracle(primary.crash(), base)


# ------------------------------------------------------------------ failover
def test_sharded_promote_merges_shard_buffers_before_undo():
    """An in-flight loser whose records straddle shards must be undone as
    ONE transaction: promote merges the per-shard slices, repeats history
    in LSN order, and undoes newest-first."""
    rng = random.Random(7)
    primary, rows, base = make_primary(rng)
    part = range_partitioner([("t", b"k00150")])
    rep = make_sharded(rows, rid="s1", n_shards=2, partitioner=part)
    rs = ReplicaSet(primary, [rep])
    drive(primary, rng, 30)
    rs.sync(max_records=40)                  # promote must drain the rest
    loser = primary.tc.begin()               # straddles both shards
    primary.tc.update(loser, "t", b"k00010", b"LOSER-LO")
    primary.tc.update(loser, "t", b"k00200", b"LOSER-HI")
    primary.tc.insert(loser, "t", b"k00150x", b"LOSER-NEW")
    primary.log.flush()
    image = primary.crash()
    new_primary = rs.promote(image=image)
    oracle = committed_state_oracle(image, base)
    assert dict(new_primary.scan_all()) == oracle
    assert new_primary.dc.read("t", b"k00150x") is None
    tok = new_primary.run_txn([("update", "t", b"k00009", b"new-era")])
    assert tok > 0 and new_primary.dc.read("t", b"k00009") == b"new-era"


def test_promote_picks_sharded_replica_that_applied_past_its_barrier():
    """Mid-epoch work counts toward promotion choice: catchup_lsn, not the
    durable barrier watermark."""
    rng = random.Random(8)
    primary, rows, _ = make_primary(rng)
    serial = make_serial(rows, "r1")
    sharded = make_sharded(rows, "s1", epoch_txns=10_000, auto_pump=False)
    rs = ReplicaSet(primary, [serial, sharded])
    drive(primary, rng, 20, abort_frac=0.0)
    rs.shipper.drain("s1", sharded.apply_batch)  # only the sharded one syncs
    sharded.pump()                               # applied, but no barrier yet
    assert sharded.applied_lsn < sharded.catchup_lsn()
    rs.promote(image=primary.crash())
    assert sharded.promoted and not serial.promoted


def test_promote_auto_selects_detached_replica_and_reattaches():
    """A detached (unsubscribed) standby can still be the most caught-up
    promotion target; promote must re-attach it instead of raising after
    having popped it from the set."""
    rng = random.Random(9)
    primary, rows, base = make_primary(rng)
    r1 = make_serial(rows, "r1")
    s1 = make_sharded(rows, "s1")
    rs = ReplicaSet(primary, [r1, s1])
    drive(primary, rng, 20, abort_frac=0.0)
    rs.sync()
    drive(primary, rng, 5, abort_frac=0.0)
    rs.shipper.drain("s1", s1.apply_batch)   # only s1 catches up ...
    rs.shipper.unsubscribe("s1")             # ... and is then detached
    new_primary = rs.promote()               # live-shipper path
    assert s1.promoted and not r1.promoted
    oracle = committed_state_oracle(primary.crash(), base)
    assert dict(new_primary.scan_all()) == oracle


def test_sharded_commit_survives_barrier_failure_without_phantom_inflight():
    """A committed transaction whose slice fails to apply (oversized record
    for this geometry) must not reappear as in-flight: it cannot pin the
    resume watermark or be undone as a loser — its slice stays queued as
    committed work."""
    rng = random.Random(10)
    primary, rows, base = make_primary(rng, page_size=8192)
    rep = make_sharded(rows, epoch_txns=1)   # barrier fires inside _commit
    rs = ReplicaSet(primary, [rep])
    rs.write([("update", "t", b"k00001", b"ok")])
    rs.sync()
    wm = rep.applied_lsn
    rs.write([("update", "t", b"k00002", rng.randbytes(5000))])  # > 4 KiB page
    with pytest.raises(ValueError, match="exceeds page size"):
        rs.sync()
    assert not rep._first_lsn                # no phantom in-flight txn
    assert not rep.pending
    assert rep.queued_slices() == 1          # committed work stays queued
    assert rep.applied_lsn == wm             # durable watermark unmoved
    assert not rep.db.tc.active              # no dangling local sub-txn


def test_sharded_barrier_retries_after_transient_failure(monkeypatch):
    """A transiently failing slice leaves committed work queued; an
    overlapping re-delivery of the commit retries the barrier WITHOUT
    re-dispatching or double-counting the source transaction."""
    rng = random.Random(11)
    primary, rows, base = make_primary(rng)
    rep = make_sharded(rows, epoch_txns=1)
    rs = ReplicaSet(primary, [rep])
    tok = rs.write([("update", "t", b"k00001", b"v1")])
    orig, calls = rep._apply_slice, {"n": 0}

    def flaky(s, ops, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            raise RuntimeError("transient apply hiccup")
        return orig(s, ops, **kw)

    monkeypatch.setattr(rep, "_apply_slice", flaky)
    with pytest.raises(RuntimeError, match="transient"):
        rs.sync()
    assert rep.applied_txns == 1 and rep.queued_slices() == 1
    rs.shipper.subscribe("s1", 1)            # overlapping re-delivery
    rs.sync()
    assert rep.applied_txns == 1             # not double-counted
    assert rep.read("t", b"k00001") == b"v1"
    assert rep.applied_lsn >= tok            # barrier finally committed
    assert rep.user_state() == committed_state_oracle(primary.crash(), base)


# ------------------------------------------- randomized convergence (seeded)
def _converge_once(seed, n_shards, epoch_txns):
    rng = random.Random(seed)
    primary, rows, base = make_primary(rng)
    serial = make_serial(rows, "r1")
    sharded = make_sharded(rows, "s1", n_shards=n_shards,
                           epoch_txns=epoch_txns)
    rs = ReplicaSet(primary, [serial, sharded])
    for _ in range(rng.randrange(6, 12)):
        event = rng.random()
        drive(primary, rng, rng.randrange(1, 8))
        if event < 0.35:
            rs.sync(max_records=rng.randrange(5, 60))   # partial batches
        elif event < 0.55:
            rs.sync()
        elif event < 0.7:                    # overlapping re-delivery
            rep = rng.choice([serial, sharded])
            rs.shipper.subscribe(rep.replica_id,
                                 rng.randrange(1, max(rep._ship_pos, 2)))
            rs.sync(max_records=rng.randrange(5, 60))
        else:                                # crash + local recovery
            rep = rng.choice([serial, sharded])
            rep.recover_local(rng.choice([Strategy.LOG1, Strategy.LOG2]))
            rep.resubscribe(rs.shipper)
    rs.sync()
    oracle = committed_state_oracle(primary.crash(), base)
    assert serial.user_state() == oracle, f"serial diverged (seed={seed})"
    assert sharded.user_state() == oracle, f"sharded diverged (seed={seed})"
    assert sharded.applied_lsn == serial.applied_lsn


@pytest.mark.parametrize("seed,n_shards,epoch_txns", [
    (101, 1, 1), (102, 2, 3), (103, 4, 8), (104, 7, 64),
])
def test_serial_and_sharded_converge_randomized(seed, n_shards, epoch_txns):
    _converge_once(seed, n_shards, epoch_txns)
