"""End-to-end driver smoke: launch.train with crash+restore, in-process."""
import sys

import pytest


def test_train_driver_crash_restore(capsys, monkeypatch):
    from repro.launch.train import main
    monkeypatch.setattr(sys, "argv", [
        "train", "--arch", "qwen2.5-3b", "--preset", "smoke",
        "--steps", "8", "--crash-at", "5", "--batch", "2", "--seq", "32",
        "--chunk-interval", "2", "--ckpt-interval", "4"])
    main()
    out = capsys.readouterr().out
    assert "CRASH at step 5" in out
    assert "RECOVERED to step 5" in out
    assert "bit-exact" in out
    assert "done: 8 steps" in out


def test_serve_driver(capsys, monkeypatch):
    from repro.launch.serve import main
    monkeypatch.setattr(sys, "argv", [
        "serve", "--arch", "rwkv6-3b", "--preset", "smoke",
        "--batch", "2", "--prompt-len", "8", "--gen", "3"])
    main()
    out = capsys.readouterr().out
    assert "prefill: batch=2" in out
    assert "decode: 3 steps" in out
