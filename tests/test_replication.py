"""Replication subsystem tests: log shipping, heterogeneous standby apply,
replica-local crash recovery + re-subscription, staleness-bounded routing,
and failover promotion."""
import random

import repl_workload
from repro.core import (Database, LogManager, Strategy, UpdateRec,
                        committed_state_oracle, make_key)
from repro.core.records import CommitRec
from repro.replication import (LogShipper, Replica, ReplicaSet, promote)

N_ROWS = 400
VAL = 40


def make_primary(rng, page_size=8192):
    return repl_workload.make_primary(rng, n_rows=N_ROWS, val=VAL,
                                      page_size=page_size)


def make_replica(rows, rid="r1", page_size=4096):
    return Replica(rid, page_size=page_size, cache_pages=512,
                   tracker_interval=25, bg_flush_per_txn=2,
                   seed_tables={"t": rows})


def random_ops(rng, n):
    return repl_workload.random_ops(rng, n, n_rows=N_ROWS, val=VAL)


def drive(db, rng, n_txns, abort_frac=0.15):
    repl_workload.drive(db, rng, n_txns, n_rows=N_ROWS, val=VAL,
                        abort_frac=abort_frac)


# ---------------------------------------------------------------- scan_stable
def test_scan_stable_batches_and_excludes_tail():
    log = LogManager()
    for i in range(10):
        log.append(UpdateRec(txn=1, table="t", key=b"k", after=b"v"))
    log.flush(upto=7)                       # records 8..10 unforced
    recs, nxt = log.scan_stable(1, max_records=3)
    assert [r.lsn for r in recs] == [1, 2, 3] and nxt == 4
    recs, nxt = log.scan_stable(nxt, max_records=100)
    assert [r.lsn for r in recs] == [4, 5, 6, 7] and nxt == 8
    recs, nxt = log.scan_stable(nxt)        # tail is invisible
    assert recs == [] and nxt == 8
    log.flush()
    recs, nxt = log.scan_stable(nxt)
    assert [r.lsn for r in recs] == [8, 9, 10] and nxt == 11


def test_shipper_filters_to_logical_records():
    rng = random.Random(0)
    primary, rows, _ = make_primary(rng)
    drive(primary, rng, 10, abort_frac=0.0)
    primary.checkpoint()                    # emits ckpt/Delta/BW/RSSP records
    shipper = LogShipper(primary, batch_records=10_000)
    shipper.subscribe("r1")
    batch = shipper.poll("r1")
    kinds = {type(r).__name__ for r in batch.records}
    assert kinds <= {"UpdateRec", "CommitRec", "AbortRec"}
    assert any(isinstance(r, CommitRec) for r in batch.records)


def test_poll_budget_counts_only_logical_records():
    """A checkpoint burst of physical records must not starve a bounded
    poll: the budget counts shipped records, filtered ones skip for free."""
    rng = random.Random(15)
    primary, rows, _ = make_primary(rng)
    drive(primary, rng, 3, abort_frac=0.0)
    primary.checkpoint()                   # bCkpt/Delta/BW/RSSP/eCkpt burst
    drive(primary, rng, 3, abort_frac=0.0)
    shipper = LogShipper(primary, batch_records=4)
    shipper.subscribe("r1", 1)
    total = 0
    while True:
        batch = shipper.poll("r1")
        assert len(batch.records) <= 4
        total += len(batch.records)
        # bounded poll makes logical progress whenever backlog exists
        if batch.has_more:
            assert len(batch.records) == 4
        else:
            break
    logical = sum(1 for r in primary.log.scan(1)
                  if type(r).__name__ in ("UpdateRec", "CommitRec",
                                          "AbortRec"))
    assert total == logical


# --------------------------------------------------- heterogeneous replication
def test_heterogeneous_replica_matches_oracle():
    rng = random.Random(1)
    primary, rows, base = make_primary(rng, page_size=8192)
    rep = make_replica(rows, page_size=4096)      # half the primary page size
    rs = ReplicaSet(primary, [rep])
    drive(primary, rng, 60)
    rs.sync()
    oracle = committed_state_oracle(primary.crash(), base)
    assert rep.user_state() == oracle
    assert rep.applied_lsn > 0 and rep.lag(primary.log) == 0
    # the replica built its own geometry, not a copy of the primary's
    assert rep.db.dc.page_size != primary.dc.page_size


def test_commit_buffering_hides_inflight_work():
    rng = random.Random(2)
    primary, rows, base = make_primary(rng)
    rep = make_replica(rows)
    rs = ReplicaSet(primary, [rep])
    txn = primary.tc.begin()                     # in-flight, stable, no commit
    primary.tc.update(txn, "t", b"k00000", b"UNCOMMITTED")
    primary.log.flush()
    rs.sync()
    assert rep.read("t", b"k00000") == base[make_key("t", b"k00000")]
    assert txn in rep.pending                    # buffered, not applied
    primary.tc.commit(txn)
    rs.sync()
    assert rep.read("t", b"k00000") == b"UNCOMMITTED"


# -------------------------------------------- replica crash -> local recovery
def test_replica_crash_recovers_locally_and_resubscribes():
    rng = random.Random(3)
    primary, rows, base = make_primary(rng)
    rep = make_replica(rows)
    rs = ReplicaSet(primary, [rep])
    drive(primary, rng, 40)
    rs.sync()
    drive(primary, rng, 30)
    rs.sync(max_records=40)                  # mid-apply: partial batch only
    # leave an in-flight primary txn so the replica has a pending buffer
    # (resume watermark < applied watermark territory) at crash time
    txn = primary.tc.begin()
    primary.tc.update(txn, "t", b"k00005", b"straddler")
    primary.log.flush()
    rs.sync(max_records=20)

    stats = rep.recover_local(Strategy.LOG1)
    assert stats.strategy == "Log1"
    assert rep.pending == {}                 # volatile buffers gone
    # watermark restored from the __repl row, crash-consistent with the data
    assert rep.applied_lsn > 0 and rep.resume_lsn <= rep.applied_lsn + 1

    # a FRESH shipper (shipper restart) resumes purely from the replica's
    # durable resume point — no shipper-side state survives, none is needed
    fresh = LogShipper(primary)
    rep.resubscribe(fresh)
    primary.tc.commit(txn)
    fresh.drain("r1", rep.apply_batch)
    oracle = committed_state_oracle(primary.crash(), base)
    assert rep.user_state() == oracle


def test_replica_crash_recovery_via_log2_also_works():
    rng = random.Random(4)
    primary, rows, base = make_primary(rng)
    rep = make_replica(rows)
    rs = ReplicaSet(primary, [rep])
    drive(primary, rng, 30)
    rs.sync()
    rep.recover_local(Strategy.LOG2)
    rep.resubscribe(rs.shipper)
    drive(primary, rng, 10)
    rs.sync()
    oracle = committed_state_oracle(primary.crash(), base)
    assert rep.user_state() == oracle


# ----------------------------------------------------------------- failover
def test_promote_drains_undoes_losers_and_is_writable():
    rng = random.Random(5)
    primary, rows, base = make_primary(rng)
    rep = make_replica(rows)
    rs = ReplicaSet(primary, [rep])
    drive(primary, rng, 40)
    rs.sync(max_records=60)                  # promote must drain the rest
    # stable in-flight loser: shipped but never committed
    txn = primary.tc.begin()
    primary.tc.update(txn, "t", b"k00007", b"LOSER")
    primary.tc.insert(txn, "t", b"xlostrow", b"LOSER")
    primary.log.flush()
    image = primary.crash()

    new_primary = rs.promote(image=image)
    oracle = committed_state_oracle(image, base)
    # promote retired the __repl watermark row, so raw state == oracle
    assert dict(new_primary.scan_all()) == oracle   # loser's effects undone
    assert new_primary.dc.read("t", b"xlostrow") is None
    # writable as a primary
    tok = new_primary.run_txn([("update", "t", b"k00009", b"new-era")])
    assert tok > 0 and new_primary.dc.read("t", b"k00009") == b"new-era"
    # double failure: the NEW primary crashes and recovers with Log1
    from repro.core import recover, recovered_state
    img2 = new_primary.crash()
    db2, _ = recover(img2, Strategy.LOG1)
    assert db2.dc.read("t", b"k00009") == b"new-era"


def test_promote_interleaved_losers_match_crash_recovery():
    """Undo order matters when in-flight losers interleave on one key:
    promote() must converge to the same state recover() produces."""
    rng = random.Random(12)
    primary, rows, base = make_primary(rng)
    rep = make_replica(rows)
    rs = ReplicaSet(primary, [rep])
    v0 = base[make_key("t", b"k00004")]
    a, b = primary.tc.begin(), primary.tc.begin()
    primary.tc.update(a, "t", b"k00004", b"A")      # before = v0
    primary.tc.update(b, "t", b"k00004", b"B")      # before = A
    primary.log.flush()
    image = primary.crash()
    new_primary = rs.promote(image=image)
    from repro.core import recover
    recovered, _ = recover(image, Strategy.LOG1)
    assert new_primary.dc.read("t", b"k00004") \
        == recovered.dc.read("t", b"k00004") == v0


def test_promote_picks_most_caught_up_replica():
    rng = random.Random(6)
    primary, rows, base = make_primary(rng)
    r1, r2 = make_replica(rows, "r1"), make_replica(rows, "r2", page_size=8192)
    rs = ReplicaSet(primary, [r1, r2])
    drive(primary, rng, 20)
    rs.shipper.drain("r2", r2.apply_batch)   # only r2 catches up
    assert r2.applied_lsn > r1.applied_lsn
    rs.promote(image=primary.crash())
    assert r2.promoted and not r1.promoted


# ------------------------------------------------------------- read routing
def test_staleness_bounded_reads_never_stale():
    rng = random.Random(7)
    primary, rows, base = make_primary(rng)
    rep = make_replica(rows)
    rs = ReplicaSet(primary, [rep])
    for i in range(30):
        key = f"k{rng.randrange(N_ROWS):05d}".encode()
        val = f"v{i}".encode()
        tok = rs.write([("update", "t", key, val)])
        # read-your-writes with the token must see the write, synced or not
        res = rs.read("t", key, min_lsn=tok)
        assert res.value == val
        assert res.applied_lsn >= tok
        if i % 3 == 0:
            rs.sync()
    # un-synced replica with a fresh token -> primary must serve
    key, val = b"k00011", b"freshest"
    tok = rs.write([("update", "t", key, val)])
    res = rs.read("t", key, min_lsn=tok)
    assert res.source == "primary" and res.value == val
    rs.sync()
    res = rs.read("t", key, min_lsn=tok)
    assert res.source == "r1" and res.value == val


def test_max_lag_bound_and_round_robin():
    rng = random.Random(8)
    primary, rows, _ = make_primary(rng)
    r1, r2 = make_replica(rows, "r1"), make_replica(rows, "r2")
    rs = ReplicaSet(primary, [r1, r2])
    drive(primary, rng, 10, abort_frac=0.0)
    rs.sync()
    sources = {rs.read("t", b"k00001").source for _ in range(4)}
    assert sources == {"r1", "r2"}           # round-robin across replicas
    drive(primary, rng, 10, abort_frac=0.0)  # both replicas now lag
    res = rs.read("t", b"k00001", max_lag=0)
    assert res.source == "primary"
    rs.sync()
    assert rs.read("t", b"k00001", max_lag=0).source in ("r1", "r2")


def test_primary_fallback_serves_committed_only():
    """The primary fallback must honor the replica path's committed-only
    visibility: in-flight (dirty) primary writes never reach routed reads."""
    rng = random.Random(13)
    primary, rows, base = make_primary(rng)
    rep = make_replica(rows)
    rs = ReplicaSet(primary, [rep])
    tok = rs.write([("update", "t", b"k00015", b"committed")])
    txn = primary.tc.begin()                 # dirty write on the primary
    primary.tc.update(txn, "t", b"k00015", b"DIRTY")
    res = rs.read("t", b"k00015", min_lsn=tok)   # replica lags -> primary
    assert res.source == "primary" and res.value == b"committed"
    primary.tc.commit(txn)
    res = rs.read("t", b"k00015", min_lsn=tok)
    assert res.value == b"DIRTY"             # committed now -> visible


def test_auto_sync_commit_hook():
    rng = random.Random(9)
    primary, rows, base = make_primary(rng)
    rep = make_replica(rows)
    rs = ReplicaSet(primary, [rep], auto_sync=True)
    tok = rs.write([("update", "t", b"k00013", b"pushed")])
    # the commit hook pumped shipping: no explicit sync() call needed
    assert rep.applied_lsn >= tok
    assert rep.read("t", b"k00013") == b"pushed"


def test_oversized_record_fails_atomically():
    """A record that fits the primary's 8 KiB pages but not the replica's
    4 KiB geometry must fail loudly WITHOUT leaving a half-applied local
    transaction or advancing the watermark."""
    import pytest
    rng = random.Random(14)
    primary, rows, base = make_primary(rng, page_size=8192)
    rep = make_replica(rows, page_size=4096)
    rs = ReplicaSet(primary, [rep])
    tok = rs.write([("update", "t", b"k00001", b"small")])
    rs.sync()
    wm_before = rep.applied_lsn
    # one txn: a small op first, then the oversized one (tests prefix undo)
    rs.write([("update", "t", b"k00002", b"prefix"),
              ("update", "t", b"k00003", rng.randbytes(5000))])
    with pytest.raises(ValueError, match="exceeds page size"):
        rs.sync()
    assert rep.applied_lsn == wm_before          # watermark did not move
    assert not rep.db.tc.active                  # no dangling local txn
    # the partially applied prefix was undone: committed-only state intact
    assert rep.read("t", b"k00002") == base[make_key("t", b"k00002")]
    assert rep.read("t", b"k00001") == b"small"


def test_stale_cursor_after_recovery_fails_loudly():
    """Forgetting resubscribe() after a local recovery must raise, not
    silently lose the buffered prefix of straddling transactions."""
    import pytest
    rng = random.Random(11)
    primary, rows, base = make_primary(rng)
    rep = make_replica(rows)
    rs = ReplicaSet(primary, [rep])
    rs.write([("update", "t", b"k00001", b"X")])
    txn = primary.tc.begin()                 # straddler: ships pre-crash,
    primary.tc.update(txn, "t", b"k00002", b"STRADDLE")
    primary.log.flush()
    rs.sync()
    rep.recover_local()                      # pending buffer lost
    primary.tc.commit(txn)                   # ... commits post-crash
    with pytest.raises(RuntimeError, match="re-subscribe"):
        rs.sync()
    rep.resubscribe(rs.shipper)
    rs.sync()
    oracle = committed_state_oracle(primary.crash(), base)
    assert rep.user_state() == oracle


# ------------------------------------------------- apply-path regressions
def test_overlapping_redelivery_skips_consumed_records():
    """Regression: a batch overlapping already-consumed LSNs passes the gap
    check (from_lsn < _ship_pos), and re-delivered records of a straddling
    transaction used to be appended to the buffer AGAIN — double-applying
    its ops at commit.  Re-polling an already-shipped range must skip
    everything below the consumed position."""
    rng = random.Random(20)
    primary, rows, base = make_primary(rng)
    rep = make_replica(rows)
    rs = ReplicaSet(primary, [rep])
    rs.write([("update", "t", b"k00001", b"A")])
    txn = primary.tc.begin()                 # straddler: in-flight, stable
    primary.tc.update(txn, "t", b"k00002", b"S1")
    primary.tc.insert(txn, "t", b"xstraddle", b"S2")
    primary.log.flush()
    rs.sync()
    assert len(rep.pending[txn]) == 2
    rs.shipper.subscribe("r1", 1)            # re-poll already-shipped range
    rs.sync()
    assert len(rep.pending[txn]) == 2        # NOT double-buffered
    assert rep.skipped_dup_recs > 0
    primary.tc.commit(txn)
    rs.sync()
    oracle = committed_state_oracle(primary.crash(), base)
    assert rep.user_state() == oracle
    assert rep.read("t", b"xstraddle") == b"S2"


def test_lag_ignores_unforced_commit_past_stable_point():
    """Regression: lag() claimed distance from the last stable commit but
    computed min(last_commit_lsn, stable_lsn), which is not a commit LSN
    when an unforced commit sits past the stable point — a fully caught-up
    replica reported phantom lag and max_lag routing spuriously fell back
    to the primary."""
    rng = random.Random(21)
    primary, rows, _ = make_primary(rng)
    rep = make_replica(rows)
    rs = ReplicaSet(primary, [rep])
    drive(primary, rng, 10, abort_frac=0.0)
    rs.sync()
    assert rep.lag(primary.log) == 0
    txn = primary.tc.begin()                 # stable in-flight work ...
    primary.tc.update(txn, "t", b"k00003", b"inflight")
    primary.log.flush()                      # ... pushes stable past the
    primary.log.append(CommitRec(txn=txn))   # last commit; commit unforced
    assert primary.log.last_commit_lsn > primary.log.stable_lsn
    assert rep.lag(primary.log) == 0         # was: phantom lag
    res = rs.read("t", b"k00001", max_lag=0)
    assert res.source == "r1"                # was: spurious primary fallback


def test_last_stable_commit_lsn_tracking():
    log = LogManager()
    assert log.last_stable_commit_lsn == 0
    log.append(UpdateRec(txn=1, table="t", key=b"k", after=b"v"))   # lsn 1
    log.append(CommitRec(txn=1))                                    # lsn 2
    log.append(UpdateRec(txn=2, table="t", key=b"k", after=b"w"))   # lsn 3
    log.append(CommitRec(txn=2))                                    # lsn 4
    log.append(UpdateRec(txn=3, table="t", key=b"k", after=b"x"))   # lsn 5
    log.flush(upto=3)                        # commit 4 still unforced
    assert log.last_stable_commit_lsn == 2
    log.flush(upto=5)
    assert log.last_stable_commit_lsn == 4
    log.append(CommitRec(txn=3))                                    # lsn 6
    assert log.last_stable_commit_lsn == 4   # appended, not forced
    survivor = log.crash()                   # tail commit lost
    assert survivor.last_stable_commit_lsn == survivor.last_commit_lsn == 4
    log.flush()
    assert log.last_stable_commit_lsn == 6


def test_shipper_unknown_subscriber_raises_descriptive_error():
    import pytest
    rng = random.Random(22)
    primary, _, _ = make_primary(rng)
    shipper = LogShipper(primary)
    with pytest.raises(KeyError, match="subscribe"):
        shipper.poll("ghost")
    with pytest.raises(KeyError, match="subscribe"):
        shipper.backlog("ghost")


def test_sync_skips_detached_replicas():
    """A replica without a shipping cursor (unsubscribed, e.g. pending a
    re-seed) must not break the whole set's sync."""
    rng = random.Random(23)
    primary, rows, base = make_primary(rng)
    r1, r2 = make_replica(rows, "r1"), make_replica(rows, "r2")
    rs = ReplicaSet(primary, [r1, r2])
    rs.shipper.unsubscribe("r2")
    drive(primary, rng, 10, abort_frac=0.0)
    rs.sync()                                # must not raise
    oracle = committed_state_oracle(primary.crash(), base)
    assert r1.user_state() == oracle
    assert r2.applied_lsn == 0               # untouched, served nothing new
    rs.sync(max_records=16)                  # bounded-poll path too
    assert r2.applied_lsn == 0


# --------------------------------------------------------- max_txn tracking
def test_recovered_txn_ids_do_not_collide():
    rng = random.Random(10)
    primary, rows, _ = make_primary(rng)
    drive(primary, rng, 10)
    image = primary.crash()
    assert image.log.max_txn == max(
        getattr(r, "txn", 0) or 0 for r in image.log.scan(1))
    from repro.core import recover
    db, _ = recover(image, Strategy.LOG1)
    assert db.tc._next_txn > image.log.max_txn
