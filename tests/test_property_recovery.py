"""Property-based tests (hypothesis) for the recovery engine's invariants.

Invariant 1 (equivalence): for ANY workload and ANY crash point, every
  recovery strategy reproduces exactly the committed-transaction state.
Invariant 2 (DPT safety): every page dirty at crash whose first-dirtying op
  is <= the last stable Delta record's TC-LSN appears in the logical DPT with
  rLSN <= its true first-dirtying LSN.
Invariant 3 (pages): serialization round-trips arbitrary record sets.
"""
import random

import pytest

pytest.importorskip("hypothesis")

from hypothesis import HealthCheck, given, settings
from hypothesis import strategies as st

from repro.core import (Database, Strategy, committed_state_oracle, make_key,
                        recover, recovered_state)
from repro.core.dpt import build_dpt_logical
from repro.core.pages import Page, empty_leaf

SETTINGS = dict(max_examples=25, deadline=None,
                suppress_health_check=[HealthCheck.too_slow])


# ---------------------------------------------------------------- workloads
@st.composite
def workload(draw):
    seed = draw(st.integers(0, 2**32 - 1))
    n_rows = draw(st.integers(20, 200))
    n_txns = draw(st.integers(3, 40))
    cache = draw(st.integers(8, 64))
    tracker = draw(st.integers(3, 40))
    bg_flush = draw(st.integers(0, 4))
    ckpt_every = draw(st.integers(0, 15))
    abort_frac = draw(st.floats(0.0, 0.3))
    trailing_loser = draw(st.booleans())
    delta_mode = draw(st.sampled_from(["paper", "perfect", "reduced"]))
    return dict(seed=seed, n_rows=n_rows, n_txns=n_txns, cache=cache,
                tracker=tracker, bg_flush=bg_flush, ckpt_every=ckpt_every,
                abort_frac=abort_frac, trailing_loser=trailing_loser,
                delta_mode=delta_mode)


def build_and_crash(p):
    rng = random.Random(p["seed"])
    db = Database(cache_pages=p["cache"], tracker_interval=p["tracker"],
                  bg_flush_per_txn=p["bg_flush"], delta_mode=p["delta_mode"])
    rows = [(f"k{i:06d}".encode(), bytes([i % 251]) * rng.randrange(20, 60))
            for i in range(p["n_rows"])]
    db.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}

    for t in range(p["n_txns"]):
        ops = []
        for _ in range(rng.randrange(1, 8)):
            roll = rng.random()
            if roll < 0.6:
                i = rng.randrange(p["n_rows"])
                ops.append(("update", "t", f"k{i:06d}".encode(), rng.randbytes(40)))
            elif roll < 0.85:
                ops.append(("insert", "t", f"x{rng.randrange(10**6):08d}".encode(),
                            rng.randbytes(40)))
            else:
                i = rng.randrange(p["n_rows"])
                ops.append(("delete", "t", f"k{i:06d}".encode(), None))
        if rng.random() < p["abort_frac"]:
            txn = db.tc.begin()
            for verb, table, key, value in ops:
                if verb == "update":
                    db.tc.update(txn, table, key, value)
                elif verb == "insert":
                    db.tc.insert(txn, table, key, value)
                else:
                    db.tc.delete(txn, table, key)
            db.tc.abort(txn)
        else:
            db.run_txn(ops)
        if p["ckpt_every"] and t % p["ckpt_every"] == p["ckpt_every"] - 1:
            db.checkpoint()

    if p["trailing_loser"]:
        txn = db.tc.begin()
        for _ in range(rng.randrange(1, 5)):
            i = rng.randrange(p["n_rows"])
            db.tc.update(txn, "t", f"k{i:06d}".encode(), b"loser")
        if rng.random() < 0.5:
            db.log.flush()      # loser ops stable -> must be undone
    return db, base


@given(workload())
@settings(**SETTINGS)
def test_every_strategy_matches_oracle(p):
    db, base = build_and_crash(p)
    image = db.crash()
    oracle = committed_state_oracle(image, base)
    for s in Strategy:
        rec_db, _ = recover(image, s, cache_pages=p["cache"])
        assert recovered_state(rec_db) == oracle, \
            f"{s.value} diverged (seed={p['seed']})"


@given(workload())
@settings(**SETTINGS)
def test_logical_dpt_safety(p):
    if p["delta_mode"] == "reduced":
        p = dict(p, delta_mode="paper")
    db, base = build_and_crash(p)

    # ground truth BEFORE crash: dirty buffers + their true first-dirty LSNs
    true_dirty = {pid: buf.rlsn for pid, buf in db.dc.pool.buffers.items()
                  if buf.dirty}
    image = db.crash()
    log = image.log
    rssp = log.master.bckpt_lsn
    dpt, last_tc_lsn, _pf = build_dpt_logical(log, rssp)
    for pid, first_dirty_lsn in true_dirty.items():
        if first_dirty_lsn <= last_tc_lsn and first_dirty_lsn > rssp:
            e = dpt.find(pid)
            assert e is not None, \
                f"dirty page {pid} (rlsn={first_dirty_lsn}) missing from DPT " \
                f"(lastDelta={last_tc_lsn}, seed={p['seed']})"
            assert e.rlsn <= first_dirty_lsn, \
                f"DPT rlsn {e.rlsn} > true first-dirty {first_dirty_lsn} " \
                f"for page {pid} (seed={p['seed']})"


@given(st.dictionaries(st.binary(min_size=1, max_size=40),
                       st.binary(min_size=0, max_size=200),
                       min_size=0, max_size=40),
       st.integers(0, 2**40), st.integers(0, 2**40))
@settings(max_examples=50, deadline=None)
def test_page_serialization_roundtrip(records, plsn, slsn):
    p = empty_leaf(123)
    p.records = dict(records)
    p.plsn, p.slsn = plsn, slsn
    q = Page.from_bytes(p.to_bytes())
    assert q.records == p.records and q.plsn == plsn and q.slsn == slsn


@given(workload())
@settings(max_examples=10, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
def test_double_crash(p):
    """Crash during 'continued operation' after a recovery; recover again."""
    db, base = build_and_crash(p)
    image1 = db.crash()
    db2, _ = recover(image1, Strategy.LOG1, cache_pages=p["cache"])
    rng = random.Random(p["seed"] ^ 0xDEAD)
    for _ in range(5):
        i = rng.randrange(p["n_rows"])
        db2.run_txn([("update", "t", f"k{i:06d}".encode(), rng.randbytes(30))])
    image2 = db2.crash()
    oracle2 = committed_state_oracle(image2, base)
    for s in (Strategy.LOG0, Strategy.LOG1, Strategy.SQL1):
        db3, _ = recover(image2, s, cache_pages=p["cache"])
        assert recovered_state(db3) == oracle2
