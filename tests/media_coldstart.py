"""Subprocess halves of the cold-restore round-trip test.

Run as a script with two roles so the two halves genuinely share no
process state:

    python media_coldstart.py prepare <dir> <variant>
    python media_coldstart.py restore <dir>

``prepare`` (process A) runs a workload against a fresh primary, seals
segments / takes snapshots / saves the master pointer into a
``DirectoryBackend`` at ``<dir>/backend``, writes the committed-state
oracle for the chosen target to ``<dir>/expect.pickle``, and exits — the
primary dies with the process.  ``restore`` (process B) rebuilds a
database from the backend directory alone via ``media.cold_restore`` and
compares it against the oracle.  Variants:

  live    everything stable is sealed before exit (clean shutdown);
          target = the sealed frontier = the stable tip
  crash   work keeps committing *after* the last seal, and a stable but
          uncommitted loser is left in flight — media only holds the
          prefix sealed before the "crash"; the tail and the loser must
          not surface
  pruned  two snapshot generations, then retention drops the old one and
          prunes the segments only it needed — restore runs above the
          prune floor from the surviving snapshot
"""
import pickle
import random
import sys
from pathlib import Path

from repro.archive import Archiver, LogArchive, SnapshotStore
from repro.core import committed_state_oracle
from repro.media import DirectoryBackend, cold_restore

from repl_workload import drive, make_primary

N_ROWS, VAL = 150, 16


def prepare(workdir: Path, variant: str) -> None:
    rng = random.Random(20260727)
    db, rows, base = make_primary(rng, n_rows=N_ROWS, val=VAL,
                                  page_size=8192)
    backend = DirectoryBackend(workdir / "backend")
    store = SnapshotStore()
    arch = Archiver(db, archive=LogArchive(segment_records=64,
                                           backend=backend),
                    snapshots=store)
    drive(db, rng, 25, n_rows=N_ROWS, val=VAL)
    store.take(db, chunk_keys=48,
               on_chunk=lambda: drive(db, rng, 2, n_rows=N_ROWS, val=VAL))
    drive(db, rng, 25, n_rows=N_ROWS, val=VAL)

    if variant == "live":
        arch.run_once()
        target = db.log.stable_lsn
        assert arch.archive.archived_upto == target
    elif variant == "crash":
        loser = db.tc.begin()
        db.tc.update(loser, "t", rows[0][0], b"LOSER")
        db.log.flush()                       # stable but uncommitted
        arch.run_once()
        target = arch.archive.archived_upto
        # the world moves on after the last seal; none of this reaches media
        drive(db, rng, 20, n_rows=N_ROWS, val=VAL)
    elif variant == "pruned":
        arch.run_once()
        drive(db, rng, 30, n_rows=N_ROWS, val=VAL)
        store.take(db, chunk_keys=48)
        arch.run_once()
        target = arch.archive.archived_upto
        # the oracle itself needs the full history — compute it before
        # retention destroys the pruned prefix (restore does not: it
        # starts at the surviving snapshot's redo_lsn, above the floor)
        oracle = committed_state_oracle(db.crash(), base, upto_lsn=target)
        arch.prune(keep_snapshots=1)         # old generation's history gone
        assert arch.archive.retained_from > 1
    else:
        raise SystemExit(f"unknown variant {variant!r}")

    if variant != "pruned":
        oracle = committed_state_oracle(db.crash(), base, upto_lsn=target)
    (workdir / "expect.pickle").write_bytes(
        pickle.dumps({"target": target, "oracle": oracle,
                      "variant": variant}))


def restore(workdir: Path) -> None:
    expect = pickle.loads((workdir / "expect.pickle").read_bytes())
    db, stats = cold_restore(workdir / "backend",
                             target_lsn=expect["target"], page_size=4096)
    got = dict(db.scan_all())
    if got != expect["oracle"]:
        missing = expect["oracle"].keys() - got.keys()
        extra = got.keys() - expect["oracle"].keys()
        raise SystemExit(
            f"cold restore diverged from the committed-state oracle "
            f"(variant={expect['variant']}, target={expect['target']}): "
            f"{len(missing)} missing, {len(extra)} extra keys")
    # the restored database is writable in this process too
    db.run_txn([("insert", "t", b"cold-start", b"alive")])
    assert db.dc.read("t", b"cold-start") == b"alive"
    print(f"restored variant={expect['variant']} "
          f"target={expect['target']} replayed={stats.replayed_txns}")


if __name__ == "__main__":
    role, workdir = sys.argv[1], Path(sys.argv[2])
    if role == "prepare":
        prepare(workdir, sys.argv[3])
    elif role == "restore":
        restore(workdir)
    else:
        raise SystemExit(f"unknown role {role!r}")
