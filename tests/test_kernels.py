"""Pallas kernels vs pure-jnp oracles: shape/dtype sweeps, interpret mode.

Every kernel must be allclose to its ref.py oracle across head counts, GQA
ratios, sequence lengths (incl. non-multiple-of-block), and dtypes.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from numpy.testing import assert_allclose

from repro.kernels import ref
from repro.kernels.delta_apply import delta_apply
from repro.kernels.flash_attention import flash_attention
from repro.kernels.ops import group_updates_by_page
from repro.kernels.ssd_scan import ssd_scan
from repro.kernels.wkv6 import wkv6

TOL = {jnp.float32: dict(rtol=2e-5, atol=2e-5),
       jnp.bfloat16: dict(rtol=2e-2, atol=2e-2)}


def _tol(dtype):
    return TOL[jnp.bfloat16 if dtype == jnp.bfloat16 else jnp.float32]


# ----------------------------------------------------------- flash attention
@pytest.mark.parametrize("B,H,KV,S,hd", [
    (1, 4, 4, 128, 64),
    (2, 8, 2, 256, 64),      # GQA 4:1
    (1, 4, 1, 384, 128),     # MQA, S not a block multiple
    (2, 2, 2, 64, 32),       # tiny blocks
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
@pytest.mark.parametrize("causal", [True, False])
def test_flash_attention_matches_ref(B, H, KV, S, hd, dtype, causal):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), dtype)
    k = jax.random.normal(ks[1], (B, KV, S, hd), dtype)
    v = jax.random.normal(ks[2], (B, KV, S, hd), dtype)
    qb = 128 if S % 128 == 0 else 64
    out = flash_attention(q, k, v, causal=causal, q_block=qb, kv_block=qb,
                          interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=causal)
    assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                    **_tol(dtype))


def test_flash_attention_long_kv_short_q():
    """Asymmetric prefill-style: q shorter than kv (cross-attention shape)."""
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 4, 128, 64), jnp.float32)
    k = jax.random.normal(ks[1], (1, 4, 512, 64), jnp.float32)
    v = jax.random.normal(ks[2], (1, 4, 512, 64), jnp.float32)
    out = flash_attention(q, k, v, causal=False, interpret=True)
    want = ref.flash_attention_ref(q, k, v, causal=False)
    assert_allclose(np.asarray(out), np.asarray(want), rtol=2e-5, atol=2e-5)


# --------------------------------------------------------------------- wkv6
@pytest.mark.parametrize("B,H,T,hd,chunk", [
    (1, 2, 64, 32, 16),
    (2, 4, 128, 64, 64),
    (1, 3, 96, 64, 32),      # odd head count, chunk < T
    (2, 2, 256, 32, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_wkv6_matches_ref(B, H, T, hd, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(2), 5)
    r = jax.random.normal(ks[0], (B, H, T, hd), dtype)
    k = jax.random.normal(ks[1], (B, H, T, hd), dtype)
    v = jax.random.normal(ks[2], (B, H, T, hd), dtype)
    # realistic decay: logw in [-4, -1e-3)
    logw = -jnp.exp(jax.random.uniform(ks[3], (B, H, T, hd),
                                       minval=-6.0, maxval=1.2)
                    ).astype(jnp.float32).clip(1e-3, 4.0)
    u = (jax.random.normal(ks[4], (H, hd)) * 0.3).astype(jnp.float32)
    out = wkv6(r, k, v, logw.astype(dtype), u, chunk=chunk, interpret=True)
    want = ref.wkv6_ref(r, k, v, logw.astype(dtype), u)
    assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                    rtol=3e-2 if dtype == jnp.bfloat16 else 1e-4,
                    atol=3e-2 if dtype == jnp.bfloat16 else 1e-4)


# ----------------------------------------------------------------- ssd_scan
@pytest.mark.parametrize("B,H,T,P,N,chunk", [
    (1, 2, 64, 32, 16, 32),
    (2, 4, 128, 64, 64, 64),
    (1, 5, 256, 64, 64, 128),
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_ssd_scan_matches_ref(B, H, T, P, N, chunk, dtype):
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    x = jax.random.normal(ks[0], (B, H, T, P), dtype)
    dt = jax.nn.softplus(jax.random.normal(ks[1], (B, H, T))).astype(jnp.float32)
    B_in = jax.random.normal(ks[2], (B, T, N), dtype)
    C_in = jax.random.normal(ks[3], (B, T, N), dtype)
    A = -jnp.exp(jax.random.normal(ks[4], (H,)) * 0.5)
    out = ssd_scan(x, dt.astype(dtype), B_in, C_in, A, chunk=chunk,
                   interpret=True)
    want = ref.ssd_scan_ref(x, dt.astype(dtype), B_in, C_in, A)
    # chunked vs sequential reassociate fp adds: tolerance reflects a
    # T-long product/sum chain, not an implementation bug
    assert_allclose(np.asarray(out, np.float32), np.asarray(want, np.float32),
                    rtol=4e-2 if dtype == jnp.bfloat16 else 2e-3,
                    atol=4e-2 if dtype == jnp.bfloat16 else 1e-3)


# -------------------------------------------------------------- delta_apply
@pytest.mark.parametrize("n_pages,slots,width,max_upd", [
    (4, 16, 32, 8),
    (8, 64, 128, 16),
    (2, 8, 8, 4),
])
@pytest.mark.parametrize("additive", [False, True])
def test_delta_apply_matches_ref(n_pages, slots, width, max_upd, additive):
    ks = jax.random.split(jax.random.PRNGKey(4), 4)
    pages = jax.random.normal(ks[0], (n_pages, slots, width), jnp.float32)
    vals = jax.random.normal(ks[1], (n_pages, max_upd, width), jnp.float32)
    slot_idx = jax.random.randint(ks[2], (n_pages, max_upd), 0, slots,
                                  dtype=jnp.int32)
    mask = jax.random.bernoulli(ks[3], 0.7, (n_pages, max_upd))
    out = delta_apply(pages, vals, slot_idx, mask, additive=additive,
                      interpret=True)
    want = ref.delta_apply_ref(pages, vals, slot_idx, mask, additive=additive)
    assert_allclose(np.asarray(out), np.asarray(want), rtol=1e-6, atol=1e-6)


def test_delta_apply_last_writer_wins_order():
    """Two updates to the same slot: the later one (log order) must win —
    this is the LSN-ordered redo semantics of Algorithm 5."""
    pages = jnp.zeros((1, 4, 2), jnp.float32)
    vals = jnp.array([[[1., 1.], [2., 2.]]])
    slot_idx = jnp.array([[1, 1]], jnp.int32)
    mask = jnp.array([[True, True]])
    out = delta_apply(pages, vals, slot_idx, mask, interpret=True)
    assert_allclose(np.asarray(out[0, 1]), [2., 2.])


def test_group_updates_by_page_roundtrip():
    rng = np.random.default_rng(0)
    n_pages, slots, width, n_upd = 6, 32, 16, 40
    page_idx = rng.integers(0, n_pages, n_upd)
    vals = rng.normal(size=(n_upd, width)).astype(np.float32)
    slot = rng.integers(0, slots, n_upd).astype(np.int32)
    apply_mask = rng.random(n_upd) < 0.8
    v, s, m = group_updates_by_page(page_idx, n_pages, vals, slot, apply_mask)
    pages = np.zeros((n_pages, slots, width), np.float32)
    out = delta_apply(jnp.asarray(pages), jnp.asarray(v), jnp.asarray(s),
                      jnp.asarray(m), interpret=True)
    # oracle: sequential log-order application
    want = pages.copy()
    for u in range(n_upd):
        if apply_mask[u]:
            want[page_idx[u], slot[u]] = vals[u]
    assert_allclose(np.asarray(out), want, rtol=1e-6, atol=1e-6)
