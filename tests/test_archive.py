"""Archive subsystem: sealed-segment log archival + in-memory truncation
(splice-cursor transparency for recovery, analysis and shipping), fuzzy
logical snapshots, point-in-time restore, standby re-seeding
(SnapshotRequired / auto-reseed / promote survivors), and ranged replica
scans with min-over-spanned-shards staleness tokens.

Every test that builds an archive runs twice — once on ``MemoryBackend``
(the PR-3 in-process semantics, unchanged) and once on
``DirectoryBackend`` (encoded blobs on disk) — via the ``make_backend``
fixture: the media layer's contract is that the backend choice is
invisible to everything above it."""
import itertools
import random

import pytest

from repro.archive import (Archiver, LogArchive, SnapshotRequired,
                           SnapshotStore)
from repro.core import (Database, Strategy, TruncatedLogError,
                        committed_state_oracle, make_key, recover)
from repro.media import DirectoryBackend, MemoryBackend
from repro.replication import (LogShipper, Replica, ReplicaSet,
                               ShardedApplier, range_partitioner)

from repl_workload import drive, make_primary

N_ROWS, VAL = 400, 24


def _mix(rng, db, n_txns):
    drive(db, rng, n_txns, n_rows=N_ROWS, val=VAL)


@pytest.fixture(params=["memory", "directory"])
def make_backend(request, tmp_path):
    """Factory for fresh backends of the parametrized kind (a test may
    need several — e.g. one per LSN space after a failover)."""
    if request.param == "memory":
        return MemoryBackend
    counter = itertools.count()
    return lambda: DirectoryBackend(tmp_path / f"backend{next(counter)}")


@pytest.fixture
def primary():
    rng = random.Random(1234)
    db, rows, base = make_primary(rng, n_rows=N_ROWS, val=VAL,
                                  page_size=4096)
    _mix(rng, db, 60)
    return rng, db, rows, base


# ------------------------------------------------------------ archive/splice
def test_seal_truncate_and_splice(primary, make_backend):
    rng, db, rows, base = primary
    full = [r.lsn for r in db.log.scan(1)]
    arch = LogArchive(segment_records=64, backend=make_backend())
    db.log.attach_archive(arch)
    sealed = arch.seal(db.log)
    assert sealed == db.log.stable_lsn
    dropped = db.log.truncate(db.log.stable_lsn)
    assert dropped == sealed
    assert db.log.in_memory_records == db.log.end_lsn - db.log.stable_lsn
    # the splice yields the identical dense sequence
    assert [r.lsn for r in db.log.scan(1)] == full
    # record() reaches into segments transparently
    assert db.log.record(1).lsn == 1
    assert db.log.record(sealed).lsn == sealed
    # appends continue in the same LSN space; incremental seal resumes
    _mix(rng, db, 10)
    assert [r.lsn for r in db.log.scan(1)] == \
        list(range(1, db.log.stable_lsn + 1))
    arch.seal(db.log)
    assert arch.archived_upto == db.log.stable_lsn


def test_truncate_guards(primary, make_backend):
    _, db, _, _ = primary
    with pytest.raises(ValueError, match="no archive"):
        db.log.truncate(10)
    arch = LogArchive(backend=make_backend())
    db.log.attach_archive(arch)
    arch.seal(db.log, upto=20)
    with pytest.raises(ValueError, match="sealed only through"):
        db.log.truncate(30)
    assert db.log.truncate(20) == 20
    assert db.log.truncate(20) == 0          # idempotent


def test_prune_loses_history_loudly(primary, make_backend):
    _, db, _, _ = primary
    arch = LogArchive(segment_records=16, backend=make_backend())
    db.log.attach_archive(arch)
    arch.seal(db.log, upto=50)
    db.log.truncate(50)
    arch.prune(30)
    assert db.log.retained_lsn == arch.retained_from > 1
    with pytest.raises(TruncatedLogError):
        list(db.log.scan(1))
    with pytest.raises(TruncatedLogError):
        db.log.record(1)
    # scans above the prune floor still splice fine
    assert [r.lsn for r in db.log.scan(db.log.retained_lsn)] == \
        list(range(db.log.retained_lsn, db.log.stable_lsn + 1))


def test_recovery_starts_below_truncation(primary, make_backend):
    """Crash after truncation: analysis/redo start at the checkpoint,
    which lives in the archive — recovery must be oblivious."""
    rng, db, rows, base = primary
    db.checkpoint()
    _mix(rng, db, 40)
    arch = LogArchive(segment_records=32, backend=make_backend())
    db.log.attach_archive(arch)
    arch.seal(db.log)
    db.log.truncate(db.log.stable_lsn)       # checkpoint now below the base
    _mix(rng, db, 25)
    loser = db.tc.begin()
    db.tc.update(loser, "t", b"k00001", b"LOSER")
    db.log.flush()
    image = db.crash()
    assert image.log.master.bckpt_lsn <= image.log._base
    for strategy in (Strategy.LOG1, Strategy.LOG2):
        rec_db, stats = recover(image, strategy, page_size=4096)
        assert dict(rec_db.scan_all()) == committed_state_oracle(image, base)
        assert stats.scan_from <= image.log._base


def test_shipping_through_splice(primary, make_backend):
    """A subscriber below the truncation base (but above the prune floor)
    is served from archive segments — truncation is invisible to it."""
    rng, db, rows, base = primary
    arch = LogArchive(segment_records=50, backend=make_backend())
    db.log.attach_archive(arch)
    arch.seal(db.log)
    db.log.truncate(db.log.stable_lsn)
    replica = Replica("r1", page_size=8192, cache_pages=256,
                      seed_tables={"t": rows})
    rs = ReplicaSet(db, [replica])           # subscribes from LSN 1
    _mix(rng, db, 20)
    rs.sync()
    assert replica.user_state() == committed_state_oracle(db.crash(), base)


# ------------------------------------------------------------------ snapshot
def test_fuzzy_snapshot_restore_is_oracle_exact(primary):
    rng, db, rows, base = primary
    store = SnapshotStore()
    snap = store.take(db, chunk_keys=32,
                      on_chunk=lambda: _mix(rng, db, 2))
    assert snap.chunks > 1                   # genuinely chunked
    assert snap.end_lsn > snap.begin_lsn     # writers ran inside the window
    _mix(rng, db, 30)
    target = db.log.stable_lsn
    restored, stats = store.restore(target, db, page_size=16384)
    assert dict(restored.scan_all()) == \
        committed_state_oracle(db.crash(), base, upto_lsn=target)
    assert stats.snapshot_id == snap.snapshot_id
    assert stats.redo_from == snap.redo_lsn
    # restored database is writable and keeps working
    restored.run_txn([("insert", "t", b"post-restore", b"v")])
    assert restored.dc.read("t", b"post-restore") == b"v"


def test_snapshot_excludes_inflight_work(primary):
    """Open transactions at scan time contribute their committed
    before-images, not their in-flight values; in-flight inserts are
    absent, in-flight deletes present."""
    rng, db, rows, base = primary
    from repro.core import split_key
    committed = committed_state_oracle(db.crash(), base)
    k_upd, k_del = sorted(committed)[0], sorted(committed)[1]
    txn = db.tc.begin()
    db.tc.update(txn, *split_key(k_upd), b"UNCOMMITTED")
    db.tc.insert(txn, "t", b"zz-new", b"PHANTOM")
    db.tc.delete(txn, *split_key(k_del))
    store = SnapshotStore()
    snap = store.take(db, chunk_keys=64)
    rows_d = dict(snap.rows)
    assert rows_d[k_upd] == committed[k_upd]
    assert make_key("t", b"zz-new") not in rows_d
    assert rows_d[k_del] == committed[k_del]
    db.tc.abort(txn)
    # a long-running transaction straddling the begin point sets redo_lsn
    # below the window
    txn2 = db.tc.begin()
    db.tc.update(txn2, "t", rows[0][0], b"STRADDLER")
    snap2 = store.take(db)
    assert snap2.redo_lsn < snap2.begin_lsn
    db.tc.commit(txn2)
    target = db.log.stable_lsn
    restored, stats = store.restore(target, db)
    assert stats.snapshot_id == snap2.snapshot_id
    assert dict(restored.scan_all()) == \
        committed_state_oracle(db.crash(), base, upto_lsn=target)


def test_restore_targets_before_and_between_snapshots(primary):
    rng, db, rows, base = primary
    store = SnapshotStore()
    marks = []
    for _ in range(3):
        store.take(db, chunk_keys=64, on_chunk=lambda: _mix(rng, db, 1))
        _mix(rng, db, 25)
        marks.append(db.log.stable_lsn)
    image = db.crash()
    for target in (marks[0], marks[1] - 3, marks[2]):
        restored, _ = store.restore(target, image)
        assert dict(restored.scan_all()) == \
            committed_state_oracle(image, base, upto_lsn=target)
    # before the first snapshot window closes: full replay over base_rows
    early = store.snapshots[0].begin_lsn - 2
    restored, stats = store.restore(early, image, base_rows=base)
    assert stats.snapshot_id is None
    assert dict(restored.scan_all()) == \
        committed_state_oracle(image, base, upto_lsn=early)


def test_restore_from_archive_alone(primary, make_backend):
    """Dead-primary story: sealed segments + snapshots restore with no
    live log at all."""
    rng, db, rows, base = primary
    store = SnapshotStore()
    arch = Archiver(db, archive=LogArchive(backend=make_backend()),
                    snapshots=store)
    store.take(db, chunk_keys=64, on_chunk=lambda: _mix(rng, db, 2))
    _mix(rng, db, 20)
    arch.run_once()                          # seal through stable
    target = arch.archive.archived_upto
    oracle = committed_state_oracle(db.crash(), base, upto_lsn=target)
    restored, _ = store.restore(target)      # no source: archive only
    assert dict(restored.scan_all()) == oracle
    with pytest.raises(ValueError, match="archive alone"):
        store.restore(target + 1)


def test_restore_rejects_unstable_target(primary):
    rng, db, rows, base = primary
    store = SnapshotStore()
    store.take(db)
    txn = db.tc.begin()
    db.tc.update(txn, "t", rows[0][0], b"TAIL")     # unforced tail
    with pytest.raises(ValueError, match="stable"):
        store.restore(db.log.end_lsn, db)


# ------------------------------------------------- truncation watermark/bound
def test_archiver_watermark_and_bounded_memory(primary, make_backend):
    """min(snapshot horizon, slowest subscriber): the live record count
    stays bounded by the snapshot cadence instead of growing with
    history."""
    rng, db, rows, base = primary
    store = SnapshotStore()
    rs = ReplicaSet(db, snapshots=store)
    arch = Archiver(db, archive=LogArchive(backend=make_backend()),
                    snapshots=store, shippers=[rs.shipper])
    assert arch.watermark() == 0             # no snapshot yet: all hot
    store.take(db)
    replica = store.restore_replica("r1", page_size=8192, cache_pages=256)
    rs.add_replica(replica)

    peaks = []
    for _ in range(6):
        _mix(rng, db, 40)
        rs.sync()                            # subscriber keeps up
        store.take(db)
        out = arch.run_once()
        peaks.append(db.log.in_memory_records)
        assert db.log.retained_lsn == 1      # nothing pruned
    assert replica.user_state() == committed_state_oracle(db.crash(), base)
    # memory is bounded by the inter-snapshot distance, not total history
    assert max(peaks) < db.log.end_lsn / 2
    assert db.log._base > 0
    # slowest-subscriber bound: a lagging cursor pins the tail in memory
    lag_cursor = db.log._base + 5
    rs.shipper.subscribe("laggard", lag_cursor)
    _mix(rng, db, 20)
    store.take(db)
    arch.run_once()
    assert db.log._base < lag_cursor         # never truncated past it


# ------------------------------------------- SnapshotRequired / auto-reseed
def _pruned_set(rng, db, make_backend):
    store = SnapshotStore()
    rs = ReplicaSet(db, snapshots=store)
    arch = Archiver(db, archive=LogArchive(segment_records=16,
                                           backend=make_backend()),
                    snapshots=store, shippers=[rs.shipper])
    store.take(db)
    _mix(rng, db, 40)
    store.take(db)
    arch.run_once()
    arch.prune(keep_snapshots=1)
    assert db.log.retained_lsn > 1
    return store, rs, arch


def test_subscribe_below_horizon_raises(primary, make_backend):
    rng, db, rows, base = primary
    store, rs, arch = _pruned_set(rng, db, make_backend)
    with pytest.raises(SnapshotRequired) as exc:
        rs.shipper.subscribe("stale", 1)
    assert exc.value.requested_lsn == 1
    assert exc.value.retained_lsn == db.log.retained_lsn
    assert "re-seed" in str(exc.value)
    # a cursor pruned underneath a stalled subscriber surfaces it at poll:
    # shipper2 is NOT registered with the archiver, so retention advances
    # past its cursor (register it to get the slowest-subscriber bound)
    shipper2 = LogShipper(db.log)
    shipper2.subscribe("ok", db.log.retained_lsn)
    _mix(rng, db, 30)                        # the world moves on ...
    store.take(db)
    arch.run_once()
    arch.prune(keep_snapshots=1)             # ... and prunes past it
    assert db.log.retained_lsn > shipper2.cursors["ok"]
    with pytest.raises(SnapshotRequired):
        shipper2.poll("ok")


def test_add_replica_below_horizon_autoreseeds(primary, make_backend):
    rng, db, rows, base = primary
    store, rs, arch = _pruned_set(rng, db, make_backend)
    stale = Replica("stale", page_size=2048, cache_pages=256)
    assert stale.resume_lsn == 1             # fresh standby: below horizon
    rs.add_replica(stale)                    # SnapshotRequired -> reseed
    assert rs.reseeds == 1
    rs.sync()
    assert stale.user_state() == dict(db.scan_all())
    # without a SnapshotStore the error reaches the caller instead
    rs2 = ReplicaSet(db)
    with pytest.raises(SnapshotRequired):
        rs2.add_replica(Replica("nope", cache_pages=128))


def test_reseeded_replica_survives_local_crash(primary):
    """The reseed watermark is durable: local crash recovery lands on the
    snapshot window and re-subscribes cleanly."""
    rng, db, rows, base = primary
    store = SnapshotStore()
    rs = ReplicaSet(db, snapshots=store)
    store.take(db)
    replica = store.restore_replica("r1", page_size=8192, cache_pages=512)
    rs.add_replica(replica)
    rs.sync()
    _mix(rng, db, 15)
    rs.sync()
    replica.recover_local(Strategy.LOG1)
    replica.resubscribe(rs.shipper)
    _mix(rng, db, 10)
    rs.sync()
    assert replica.user_state() == committed_state_oracle(db.crash(), base)


# ------------------------------------------------------------- promote/reseed
@pytest.mark.parametrize("crash_primary", [False, True])
def test_promote_reseeds_survivors(primary, crash_primary):
    rng, db, rows, base = primary
    store = SnapshotStore()
    rs = ReplicaSet(db, snapshots=store)
    store.take(db)
    all_ids = {"r1", "r2", "r3"}
    for rid, ps in (("r1", 8192), ("r2", 2048), ("r3", 4096)):
        rs.add_replica(store.restore_replica(rid, page_size=ps,
                                             cache_pages=512))
    rs.sync()
    _mix(rng, db, 25)
    rs.sync()
    _mix(rng, db, 10)                        # the set lags the tail
    loser = db.tc.begin()
    db.tc.update(loser, "t", rows[3][0], b"LOSER")
    db.log.flush()
    image = db.crash() if crash_primary else None
    oracle = committed_state_oracle(db.crash(), base)
    new_primary = rs.promote(image=image)
    assert dict(new_primary.scan_all()) == oracle
    # zero permanently-detached survivors: re-seeded AND re-subscribed
    assert len(rs.replicas) == 2
    assert set(rs.replicas) < all_ids
    assert all(rs.shipper.is_subscribed(rid) for rid in rs.replicas)
    # new writes reach every survivor through ordinary shipping
    token = rs.write([("update", "t", rows[4][0], b"AFTER-FAILOVER")])
    rs.sync()
    for r in rs.replicas.values():
        assert r.applied_lsn >= token
        assert r.read("t", rows[4][0]) == b"AFTER-FAILOVER"
        assert r.user_state() == dict(new_primary.scan_all())
    # read routing serves from survivors again
    res = rs.read("t", rows[4][0], min_lsn=token)
    assert res.source in rs.replicas


def test_promote_without_store_still_detaches(primary):
    rng, db, rows, base = primary
    rs = ReplicaSet(db)
    rs.add_replica(Replica("r1", cache_pages=512, seed_tables={"t": rows}))
    rs.add_replica(Replica("r2", cache_pages=512, seed_tables={"t": rows}))
    rs.sync()
    new_primary = rs.promote("r1")
    assert rs.replicas == {}                 # pre-archive behavior intact
    assert dict(new_primary.scan_all()) == \
        committed_state_oracle(db.crash(), base)


# ----------------------------------------------------------- ranged routing
def test_read_range_serial_and_primary_fallback(primary):
    rng, db, rows, base = primary
    store = SnapshotStore()
    rs = ReplicaSet(db, snapshots=store)
    store.take(db)
    replica = store.restore_replica("r1", page_size=8192, cache_pages=512)
    rs.add_replica(replica)
    rs.sync()
    lo, hi = b"k00100", b"k00140"
    res = rs.read_range("t", lo, hi)
    assert res.source == "r1"
    expect = {k: v for k, v in db.scan_all()
              if make_key("t", lo) <= k < make_key("t", hi)}
    assert {make_key("t", k): v for k, v in res.rows} == expect
    # unreachable token -> primary fallback with committed-only visibility
    txn = db.tc.begin()
    db.tc.update(txn, "t", b"k00120", b"DIRTY")
    res2 = rs.read_range("t", lo, hi, min_lsn=db.log.stable_lsn + 10_000)
    assert res2.source == "primary"
    assert dict(res2.rows).get(b"k00120") != b"DIRTY"
    db.tc.abort(txn)


def test_read_range_sharded_min_over_spanned_shards(primary):
    """The ROADMAP rule: a ranged scan over a sharded standby takes the
    min volatile watermark across the shards the range spans — a behind
    shard outside the range must not block, one inside must."""
    rng, db, rows, base = primary
    store = SnapshotStore()
    store.take(db)
    part = range_partitioner([("t", b"k00150"), ("t", b"k00300")])
    sh = store.restore_replica("s1", replica_cls=ShardedApplier,
                               n_shards=3, partitioner=part,
                               epoch_txns=10_000, auto_pump=False,
                               page_size=8192, cache_pages=512)
    rs = ReplicaSet(db, snapshots=store)
    rs.add_replica(sh)
    token = rs.write([("update", "t", b"k00010", b"S0"),   # shard 0
                      ("update", "t", b"k00200", b"S1")])  # shard 1
    rs.sync(max_records=10_000)              # ingest + dispatch, no pump
    sh.pump(shard=1)
    sh.pump(shard=2)
    # shard 0 is behind the token; shards 1 and 2 are current
    assert sh.watermark_for_range("t", b"k00200", b"k00250") >= token
    assert sh.watermark_for_range("t", b"k00000", b"k00100") < token
    r_in = rs.read_range("t", b"k00200", b"k00250", min_lsn=token)
    assert r_in.source == "s1" and r_in.watermark >= token
    r_cross = rs.read_range("t", b"k00100", b"k00200", min_lsn=token)
    assert r_cross.source == "primary"       # spans the behind shard
    sh.pump()
    r_now = rs.read_range("t", b"k00100", b"k00200", min_lsn=token)
    assert r_now.source == "s1"
    # hash partitioner cannot enumerate spans: any range uses the global min
    sh2 = ShardedApplier("s2", n_shards=4, epoch_txns=4, cache_pages=256)
    assert sh2.watermark_for_range("t", b"a", b"b") == sh2.catchup_lsn()


def test_scan_range_matches_point_reads(primary):
    rng, db, rows, base = primary
    store = SnapshotStore()
    store.take(db)
    replica = store.restore_replica("r1", page_size=2048, cache_pages=512)
    rs = ReplicaSet(db, snapshots=store)
    rs.add_replica(replica)
    rs.sync()
    scanned = replica.scan_range("t", b"k00050", b"k00060")
    for k, v in scanned:
        assert replica.read("t", k) == v
    assert [k for k, _ in scanned] == sorted(k for k, _ in scanned)
    # open-ended scans cover the whole table
    all_rows = replica.scan_range("t")
    assert {make_key("t", k): v for k, v in all_rows} == replica.user_state()
