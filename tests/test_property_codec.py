"""Hypothesis property tests for the media codec: ``decode(encode(rec))
== rec`` for randomized instances of every ``RecKind`` (including the
awkward corners — ``DeltaRec.dirty_lsns`` None vs a list, ``SMORec``
image maps, empty/None before/after images, empty tables and keys), plus
segment round-trips and the any-truncation-is-loud property.

Optional dependency: degrades to a skip when hypothesis is absent
(seeded instances of every kind always run in test_media.py).
"""
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.core.records import (AbortRec, BWRec, BeginCkptRec, CLRRec,  # noqa: E402
                                CommitRec, DeltaRec, EndCkptRec, RSSPRec,
                                RecKind, SMORec, SnapshotRec, UpdateRec)
from repro.media import (CorruptSegmentError, decode_record,  # noqa: E402
                         decode_segment, decode_snapshot, encode_record,
                         encode_segment, encode_snapshot)

lsns = st.integers(0, 2**63 - 1)
txns = st.integers(0, 2**63 - 1)
pids = st.integers(-1, 2**31)
tables = st.text(max_size=16)
keys = st.binary(max_size=48)
opt_bytes = st.none() | st.binary(max_size=48)
heights = st.integers(1, 2**31)
update_ops = st.sampled_from([RecKind.UPDATE, RecKind.INSERT,
                              RecKind.DELETE])

record_strategy = st.one_of(
    st.builds(UpdateRec, lsn=lsns, txn=txns, table=tables, key=keys,
              before=opt_bytes, after=opt_bytes, pid=pids, prev_lsn=lsns,
              op=update_ops),
    st.builds(CommitRec, lsn=lsns, txn=txns, prev_lsn=lsns),
    st.builds(AbortRec, lsn=lsns, txn=txns, prev_lsn=lsns),
    st.builds(CLRRec, lsn=lsns, txn=txns, table=tables, key=keys,
              after=opt_bytes, op=update_ops, pid=pids, undone_lsn=lsns,
              undo_next=lsns),
    st.builds(BeginCkptRec, lsn=lsns),
    st.builds(EndCkptRec, lsn=lsns, bckpt_lsn=lsns,
              active_txns=st.dictionaries(txns, lsns, max_size=6)),
    st.builds(BWRec, lsn=lsns,
              written_set=st.lists(pids, max_size=8), fw_lsn=lsns),
    st.builds(DeltaRec, lsn=lsns,
              dirty_set=st.lists(pids, max_size=8),
              written_set=st.lists(pids, max_size=8),
              fw_lsn=lsns, first_dirty=st.integers(0, 2**31),
              tc_lsn=lsns,
              dirty_lsns=st.none() | st.lists(lsns, max_size=8)),
    st.builds(SMORec, lsn=lsns,
              images=st.dictionaries(pids, st.binary(max_size=48),
                                     max_size=4),
              root_pid=pids, next_pid=pids, height=heights),
    st.builds(RSSPRec, lsn=lsns, rssp_lsn=lsns, root_pid=pids,
              next_pid=pids, height=heights),
    st.builds(SnapshotRec, lsn=lsns, snapshot_id=txns,
              oldest_active_lsn=lsns),
)


@settings(max_examples=300, deadline=None)
@given(rec=record_strategy)
def test_record_roundtrips(rec):
    out = decode_record(encode_record(rec))
    assert out == rec
    assert type(out) is type(rec)
    assert out.kind == rec.kind


@settings(max_examples=60, deadline=None)
@given(recs=st.lists(record_strategy, min_size=1, max_size=24),
       lo=st.integers(1, 2**40))
def test_segment_roundtrips(recs, lo):
    for i, rec in enumerate(recs):       # sealed runs are LSN-contiguous
        rec.lsn = lo + i
    blob = encode_segment(recs)
    assert decode_segment(blob) == recs


@settings(max_examples=60, deadline=None)
@given(recs=st.lists(record_strategy, min_size=1, max_size=12),
       data=st.data())
def test_any_truncation_is_loud(recs, data):
    """A segment blob cut anywhere decodes to an error, never to a
    shorter-but-plausible record stream."""
    for i, rec in enumerate(recs):
        rec.lsn = 1 + i
    blob = encode_segment(recs)
    cut = data.draw(st.integers(0, len(blob) - 1), label="cut")
    with pytest.raises(CorruptSegmentError):
        decode_segment(blob[:cut])


@settings(max_examples=60, deadline=None)
@given(snapshot_id=txns, begin=lsns, end=lsns, redo=lsns,
       chunks=st.integers(0, 2**31),
       rows=st.lists(st.tuples(keys, st.binary(max_size=48)),
                     max_size=16))
def test_snapshot_roundtrips(snapshot_id, begin, end, redo, chunks, rows):
    from repro.archive import Snapshot
    snap = Snapshot(snapshot_id=snapshot_id, begin_lsn=begin, end_lsn=end,
                    redo_lsn=redo, rows=tuple(rows), chunks=chunks)
    assert decode_snapshot(encode_snapshot(snap)) == snap
