"""Bounded crash-point torture for tier-1.

A strided slice of the full sweep (``make torture-full`` / the CI torture
job runs every point): crash the scripted workload at a sample of backend
operations — always including the first op of every phase — recover both
ways, and require oracle-equality or documented loud death.  Plus targeted
probes the sweep's sampling might miss: a torn seal write must never
produce a silently short archive, and the profiling pass must keep
covering every phase the sweep's contract names.
"""
import sys
from pathlib import Path

import pytest

REPO = Path(__file__).resolve().parent.parent
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from repro.core.log import TruncatedLogError                  # noqa: E402
from repro.faults import (KIND_CRASH, KIND_TORN_CRASH,        # noqa: E402
                          FaultPlan, FaultSpec, InjectedCrash, RetryPolicy)
from repro.media import (CorruptSegmentError,                 # noqa: E402
                         UnknownFormatError, cold_restore)
from tools import torture                                     # noqa: E402
from tools.torture import (check_crash_point,                 # noqa: E402
                           check_transient_point, profile, run_workload,
                           shadow_oracle, sweep)

EXPECTED_PHASES = ["load", "txns1", "snapshot1", "seal1", "txns2",
                   "checkpoint", "snapshot2", "seal2", "prune", "txns3",
                   "seal3", "ship"]


@pytest.fixture(scope="module")
def baseline():
    """One fault-free profiling pass shared by the module (it asserts the
    baseline recover/replica/cold-restore equalities internally)."""
    return profile()


def test_profile_covers_every_phase(baseline):
    names = [p for p, _ in baseline.marks]
    assert [p for p in names if p in EXPECTED_PHASES] == EXPECTED_PHASES, \
        f"workload lost a phase: {names}"
    assert baseline.plan.total_ops > 40     # thin workloads sweep nothing


def test_strided_crash_sweep(baseline):
    total = baseline.plan.total_ops
    points = sorted(set(range(1, total + 1, 9))
                    | {i for _, i in baseline.marks if i <= total})
    matrix, violations = sweep(points, [KIND_CRASH, KIND_TORN_CRASH])
    assert violations == []
    phases_hit = {phase for (phase, _, _) in matrix}
    assert len(phases_hit & set(EXPECTED_PHASES)) >= 8
    # a clean crash must never go loud — loud is the torn-write budget
    assert not any(kind == KIND_CRASH and outcome.endswith(":loud")
                   for (_, kind, outcome) in matrix)


def test_transient_outage_mid_seal(baseline):
    seal1 = dict(baseline.marks)["seal1"]
    phase, live, cold = check_transient_point(seal1)
    assert (live, cold) == ("ok", "ok")


def test_crash_point_is_deterministic(baseline):
    at = dict(baseline.marks)["txns2"]
    assert check_crash_point(at, KIND_CRASH) == \
        check_crash_point(at, KIND_CRASH)


def test_torn_seal_write_is_loud_never_short():
    """Tear each of the first six segment puts (one run per tear).  A
    torn segment the retained snapshot fully covers is legally
    restorable — but then the state must equal the committed oracle at
    the reported target; a torn segment that redo *does* need must raise
    (CRC / truncation / unindexable archive).  Never a silently short
    restore — and across the set, at least one tear must actually land
    in redo's path and go loud, else the probe proves nothing."""
    saw_loud = False
    for at in range(1, 7):
        plan = FaultPlan(faults=(FaultSpec(
            op="put", kind=KIND_TORN_CRASH, at=at, name_prefix="seg/"),))
        try:
            run_workload(plan)
            break                      # fewer than ``at`` segment puts
        except InjectedCrash:
            pass
        ctx = torture._last_ctx
        assert ctx.db is not None and ctx.base is not None
        try:
            db, stats = cold_restore(ctx.backend, page_size=4096,
                                     retry=RetryPolicy(max_attempts=1))
        except (CorruptSegmentError, UnknownFormatError,
                TruncatedLogError, ValueError):
            saw_loud = True
            continue
        image = ctx.db.crash()
        assert dict(db.scan_all()) == \
            shadow_oracle(ctx, image, upto_lsn=stats.target_lsn), \
            f"torn seg put #{at}: silently wrong restore"
    assert saw_loud, "no torn segment ever reached redo — probe is vacuous"
