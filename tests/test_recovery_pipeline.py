"""Streaming batched redo pipeline: oracle equivalence + unit coverage.

The central claim: the fused single-pass, bounded-window, sorted-batch
redo (and the streaming restore built on the same engine) produces states
byte-identical to the per-record LSN-order paths and the pure-dict
oracle, across crash points, window sizes and strategies.  Seeded
samples always run; the hypothesis sweep piggybacks when available.
"""
import random

import pytest

from repro.archive import Archiver, LogArchive, SnapshotStore
from repro.core import (Database, LeafCursor, Strategy,
                        committed_state_oracle, make_key, recover,
                        recovered_state)
from repro.core.records import UpdateRec
from repro.media import MemoryBackend, cold_restore
from repro.media.codec import (FEAT_ZLIB, SEGMENT_MAGIC, decode_segment,
                               decode_segment_header, encode_record,
                               encode_segment)
from repro.media.errors import CorruptSegmentError, UnknownFormatError


# ------------------------------------------------------------ workloads
def mixed_workload(seed: int, n_rows: int = 600, n_txns: int = 120,
                   ckpt_at: int = 60, cache_pages: int = 96,
                   value_size: int = 60):
    """A primary with updates/inserts/deletes, splits, a mid-run
    checkpoint and an in-flight loser at crash."""
    rng = random.Random(seed)
    db = Database(cache_pages=cache_pages, tracker_interval=40,
                  bg_flush_per_txn=2)
    rows = [(f"k{i:08d}".encode(), bytes([i % 251]) * value_size)
            for i in range(n_rows)]
    db.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}
    for t in range(n_txns):
        ops = []
        for _ in range(6):
            roll = rng.random()
            if roll < 0.5:
                ops.append(("update", "t",
                            f"k{rng.randrange(n_rows):08d}".encode(),
                            rng.randbytes(value_size)))
            elif roll < 0.85:
                ops.append(("insert", "t",
                            f"n{rng.randrange(10**9):010d}".encode(),
                            rng.randbytes(value_size)))
            else:
                ops.append(("delete", "t",
                            f"k{rng.randrange(n_rows):08d}".encode(), None))
        db.run_txn(ops)
        if t == ckpt_at:
            db.checkpoint()
    txn = db.tc.begin()                        # loser in flight at crash
    db.tc.update(txn, "t", b"k00000000", b"loser")
    db.log.flush()
    return db, base


# ------------------------------------------- batched recovery equivalence
@pytest.mark.parametrize("seed,window", [(1, 7), (2, 64), (3, 1 << 20)])
def test_batched_recovery_matches_per_record_and_oracle(seed, window):
    db, base = mixed_workload(seed)
    image = db.crash()
    oracle = committed_state_oracle(image, base)
    for strategy in (Strategy.LOG0, Strategy.LOG1, Strategy.LOG2):
        per_db, per_st = recover(image, strategy, cache_pages=96)
        bat_db, bat_st = recover(image, strategy, cache_pages=96,
                                 batched=True, batch_window=window)
        assert recovered_state(per_db) == oracle
        assert recovered_state(bat_db) == oracle
        # both paths see the same redo stream
        assert bat_st.log_records == per_st.log_records
        assert bat_st.redo.submitted == per_st.redo.submitted
        assert bat_st.peak_window_records <= window


def test_batched_rejects_physiological_strategies():
    db, base = mixed_workload(4, n_txns=10)
    image = db.crash()
    with pytest.raises(ValueError, match="logical strategies only"):
        recover(image, Strategy.SQL1, batched=True)


def test_window_bounds_redo_memory():
    db, base = mixed_workload(5, n_txns=80)
    image = db.crash()
    _db, st = recover(image, Strategy.LOG1, cache_pages=96,
                      batched=True, batch_window=16)
    assert 0 < st.peak_window_records <= 16
    assert st.log_records > 16                 # stream really was windowed
    assert recovered_state(_db) == committed_state_oracle(image, base)


def test_batched_recovered_database_stays_live():
    """Recovery through the batched engine hands back a database that can
    run, checkpoint, crash and recover again (per-record this time)."""
    db, base = mixed_workload(6, n_txns=60)
    image = db.crash()
    db2, _ = recover(image, Strategy.LOG1, cache_pages=96,
                     batched=True, batch_window=128)
    rng = random.Random(99)
    for _ in range(30):
        db2.run_txn([("update", "t", f"k{rng.randrange(600):08d}".encode(),
                      rng.randbytes(60)) for _ in range(5)])
    db2.checkpoint()
    image2 = db2.crash()
    db3, _ = recover(image2, Strategy.LOG1, cache_pages=96)
    assert recovered_state(db3) == committed_state_oracle(image2, base)


# ------------------------------------------------------------ leaf cursor
def test_leaf_cursor_agrees_with_find_leaf_and_reuses():
    db, _ = mixed_workload(7, n_txns=40)
    tree = db.dc.btree
    cur = tree.cursor()
    assert isinstance(cur, LeafCursor)
    keys = sorted(k for k, _ in db.scan_all())
    for k in keys:
        assert cur.seek(k) == tree.find_leaf(k)
    assert cur.traversals + cur.reuses == len(keys)
    assert cur.reuses > cur.traversals        # sorted order amortizes
    cur.invalidate()
    assert cur.seek(keys[0]) == tree.find_leaf(keys[0])


def test_sorted_leaf_cache_invalidates_on_writes():
    from repro.core.pages import empty_leaf
    p = empty_leaf(1)
    p.put(b"b", b"1", 1)
    p.put(b"a", b"2", 2)
    assert p.sorted_items() == [(b"a", b"2"), (b"b", b"1")]
    p.put(b"c", b"3", 3)
    assert p.sorted_items() == [(b"a", b"2"), (b"b", b"1"), (b"c", b"3")]
    p.delete(b"a", 4)
    assert p.sorted_items() == [(b"b", b"1"), (b"c", b"3")]
    from repro.core.pages import SLOT_OVERHEAD
    assert p.payload_size() == sum(len(k) + len(v) + SLOT_OVERHEAD
                                   for k, v in p.records.items())


# --------------------------------------------------- batched shipped apply
def test_apply_shipped_batch_preserves_per_key_order():
    """Several ops on one key inside a batch must land in source-LSN
    order (the stable sort's whole job)."""
    target = Database(cache_pages=64)
    target.bootstrap_empty()
    shipped = []
    for i, val in enumerate((b"first", b"second", b"third")):
        shipped.append(UpdateRec(lsn=10 + i, txn=1, table="t", key=b"k",
                                 before=None, after=val))
    shipped.append(UpdateRec(lsn=20, txn=1, table="t", key=b"a",
                             before=None, after=b"other"))
    txn = target.tc.begin()
    n = target.tc.apply_shipped_batch(txn, shipped)
    target.tc.commit(txn)
    assert n == 4
    assert target.dc.read("t", b"k") == b"third"
    assert target.dc.read("t", b"a") == b"other"


# ------------------------------------------------------ streaming restore
def _archived_primary(seed: int, compress: bool = False):
    rng = random.Random(seed)
    n_rows = 800
    rows = [(f"k{i:07d}".encode(), rng.randbytes(50)) for i in range(n_rows)]
    primary = Database(page_size=4096, cache_pages=256,
                       tracker_interval=50, bg_flush_per_txn=2)
    primary.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}

    def drive(n):
        for _ in range(n):
            primary.run_txn([("update", "t",
                              f"k{rng.randrange(n_rows):07d}".encode(),
                              rng.randbytes(50)) for _ in range(6)])

    backend = MemoryBackend()
    store = SnapshotStore()
    arch = Archiver(primary,
                    archive=LogArchive(segment_records=128, backend=backend,
                                       cache_segments=2, compress=compress),
                    snapshots=store)
    drive(60)
    store.take(primary, chunk_keys=256, on_chunk=lambda: drive(1))
    drive(200)
    arch.run_once()
    return primary, base, backend, store, arch


@pytest.mark.parametrize("apply_window", [8, 256])
def test_streaming_restore_equals_materializing_and_oracle(apply_window):
    primary, base, backend, store, arch = _archived_primary(11)
    target = arch.archive.archived_upto
    oracle = committed_state_oracle(primary.crash(), base, upto_lsn=target)
    db_s, st_s = store.restore(target, primary, page_size=8192,
                               apply_window=apply_window)
    db_m, st_m = store.restore(target, primary, page_size=8192,
                               streaming=False)
    assert dict(db_s.scan_all()) == oracle
    assert dict(db_m.scan_all()) == oracle
    assert st_s.replayed_txns == st_m.replayed_txns
    assert st_s.replayed_ops == st_m.replayed_ops
    # streaming keeps a bounded window; materializing holds the history
    assert st_s.peak_buffered_ops <= apply_window + 16
    assert st_s.peak_buffered_ops < st_m.peak_buffered_ops


def test_streaming_cold_restore_bounds_segment_residency():
    primary, base, backend, store, arch = _archived_primary(12)
    target = arch.archive.archived_upto
    oracle = committed_state_oracle(primary.crash(), base, upto_lsn=target)
    db, st = cold_restore(backend, target_lsn=target, page_size=8192,
                          cache_segments=2, apply_window=64)
    assert dict(db.scan_all()) == oracle
    assert st.streaming
    assert st.peak_cached_segments <= 2 + 1   # +1: pre-eviction sample
    assert st.peak_buffered_ops <= 64 + 16


def test_streaming_restore_drops_aborted_buffers():
    """An aborted transaction inside the redo range must neither apply
    nor linger in the in-flight buffers."""
    primary, base, backend, store, arch = _archived_primary(13)
    txn = primary.tc.begin()
    primary.tc.update(txn, "t", b"k0000001", b"doomed")
    primary.tc.abort(txn)
    primary.run_txn([("update", "t", b"k0000002", b"kept")])
    target = primary.log.stable_lsn
    oracle = committed_state_oracle(primary.crash(), base, upto_lsn=target)
    db, st = store.restore(target, primary, page_size=8192, apply_window=4)
    assert dict(db.scan_all()) == oracle
    assert db.dc.read("t", b"k0000001") != b"doomed"
    assert db.dc.read("t", b"k0000002") == b"kept"


# ------------------------------------------------- compressed segments
def test_compressed_archive_round_trips_and_restores():
    primary, base, backend, store, arch = _archived_primary(14,
                                                            compress=True)
    target = arch.archive.archived_upto
    oracle = committed_state_oracle(primary.crash(), base, upto_lsn=target)
    # blobs really are smaller than their raw re-encoding
    seg = arch.archive.segments[0]
    raw = encode_segment(arch.archive._records(0))
    assert len(backend.get(seg.name)) < len(raw)
    db, _ = cold_restore(backend, target_lsn=target, page_size=8192)
    assert dict(db.scan_all()) == oracle


def test_compression_survives_archive_reopen():
    """A reopened compressed archive must keep compressing: load() adopts
    the newest segment's feature byte (explicit compress= overrides)."""
    primary, base, backend, store, arch = _archived_primary(15,
                                                            compress=True)
    reopened = LogArchive.load(backend, segment_records=128)
    assert reopened.compress is True
    # seal more history through the reopened archive: new blobs compressed
    for _ in range(40):
        primary.run_txn([("update", "t", b"k0000003",
                          random.Random(1).randbytes(50))])
    primary.log.attach_archive(reopened)
    reopened.seal(primary.log)
    from repro.media.codec import FEAT_ZLIB, decode_segment_features
    newest = reopened.segments[-1]
    assert decode_segment_features(
        backend.get_head(newest.name, 64)) & FEAT_ZLIB
    # uncompressed archives stay uncompressed; explicit override wins
    _p2, _b2, backend2, _s2, _a2 = _archived_primary(16)
    assert LogArchive.load(backend2).compress is False
    assert LogArchive.load(backend2, compress=True).compress is True


def test_segment_codec_versions_and_feature_bits():
    recs = [UpdateRec(lsn=i, txn=1, table="t", key=b"k%d" % i,
                      before=None, after=b"v" * 40) for i in range(1, 6)]
    plain = encode_segment(recs)
    packed = encode_segment(recs, compress=True)
    assert decode_segment(plain) == recs
    assert decode_segment(packed) == recs
    assert len(packed) < len(plain)
    assert decode_segment_header(packed[:64]) == (1, 5, 5)

    # a version-1 blob (no feature byte) must stay readable: rebuild one
    # from the same frames
    import struct as _s
    import zlib as _z
    body = b"".join(_s.pack("<II", len(p), _z.crc32(p)) + p
                    for p in map(encode_record, recs))
    hdr = _s.pack("<QQI", 1, 5, 5)
    v1 = (SEGMENT_MAGIC + bytes([1])
          + _s.pack("<II", len(hdr), _z.crc32(hdr)) + hdr + body)
    assert decode_segment(v1) == recs
    assert decode_segment_header(v1[:64]) == (1, 5, 5)

    # unknown feature bits are loud, not ignored
    unknown = bytearray(packed)
    unknown[5] |= 0x80
    with pytest.raises(UnknownFormatError, match="feature bits"):
        decode_segment(bytes(unknown))
    with pytest.raises(UnknownFormatError):
        decode_segment_header(bytes(unknown[:64]))

    # a torn compressed region fails to inflate, never a short scan
    torn = packed[:-7]
    with pytest.raises(CorruptSegmentError):
        decode_segment(torn)
    flipped = bytearray(packed)
    flipped[-3] ^= 0xFF
    with pytest.raises(CorruptSegmentError):
        decode_segment(bytes(flipped))
    assert FEAT_ZLIB == 0x01


# The randomized hypothesis sweep over (seed, window, strategy, crash
# point) lives in tests/test_property_pipeline.py, skip-guarded like the
# other property modules; the seeded samples above always run.


# ------------------------------------------ seeded always-run random sweep
@pytest.mark.parametrize("seed,window,strategy", [
    (101, 1, Strategy.LOG1),
    (202, 13, Strategy.LOG0),
    (303, 128, Strategy.LOG2),
    (404, 4096, Strategy.LOG1),
])
def test_seeded_random_batched_recovery(seed, window, strategy):
    rng = random.Random(seed)
    db, base = mixed_workload(seed, n_rows=300,
                              n_txns=rng.randrange(20, 90),
                              ckpt_at=10, cache_pages=64)
    image = db.crash()
    oracle = committed_state_oracle(image, base)
    bat_db, _ = recover(image, strategy, cache_pages=64,
                        batched=True, batch_window=window)
    assert recovered_state(bat_db) == oracle


@pytest.mark.parametrize("seed,apply_window,cut", [
    (55, 1, 0.3), (66, 32, 0.8), (77, 1024, 1.0),
])
def test_seeded_random_streaming_restore_targets(seed, apply_window, cut):
    primary, base, _backend, store, _arch = _archived_primary(seed)
    lo = store.latest().end_lsn
    hi = primary.log.stable_lsn
    target = lo + int((hi - lo) * cut)
    oracle = committed_state_oracle(primary.crash(), base, upto_lsn=target)
    db, _ = store.restore(target, primary, page_size=8192,
                          apply_window=apply_window)
    assert dict(db.scan_all()) == oracle
