"""Hypothesis property test: serial and key-range-sharded apply both
converge to ``committed_state_oracle`` for any shard count and epoch
length, under randomized fault schedules — partial batches, overlapping
re-deliveries (rewound shipper cursors), and standby crash / local
recovery / re-subscribe at arbitrary points.

Optional dependency: degrades to a skip when hypothesis is absent (the
seeded subset of the same scenario always runs in test_parallel_apply.py).
"""
import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from test_parallel_apply import _converge_once  # noqa: E402


@settings(max_examples=15, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 10_000), n_shards=st.integers(1, 6),
       epoch_txns=st.integers(1, 12))
def test_property_serial_and_sharded_converge(seed, n_shards, epoch_txns):
    _converge_once(seed, n_shards, epoch_txns)
