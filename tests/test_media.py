"""Durable media layer: binary codec round-trips for every record kind,
corruption handling (truncated frame / bad CRC / unknown format version —
always loud, never a short scan), backend semantics (memory + directory),
durable master pointer, the decode LRU, and cold restore — including the
subprocess round-trip that proves a dead primary's backend directory is
sufficient physical context for a fresh process."""
import os
import random
import subprocess
import sys
from pathlib import Path

import pytest

from repro.archive import Archiver, LogArchive, Snapshot, SnapshotStore
from repro.core import LogManager, committed_state_oracle
from repro.core.log import Master
from repro.core.records import (AbortRec, BWRec, BeginCkptRec, CLRRec,
                                CommitRec, DeltaRec, EndCkptRec, RSSPRec,
                                RecKind, SMORec, SnapshotRec, UpdateRec)
from repro.media import (CorruptSegmentError, DirectoryBackend,
                         MemoryBackend, UnknownFormatError, cold_restore,
                         cold_restore_replica, decode_record, decode_segment,
                         decode_snapshot, encode_record, encode_segment,
                         encode_snapshot)
from repro.replication import ReplicaSet

from repl_workload import drive, make_primary

N_ROWS, VAL = 200, 16
TESTS_DIR = Path(__file__).resolve().parent
SRC_DIR = TESTS_DIR.parent / "src"


def sample_records():
    """One value-rich instance of every RecKind (all 13)."""
    return [
        UpdateRec(lsn=7, txn=3, table="t", key=b"k1", before=b"old",
                  after=b"new", pid=42, prev_lsn=5, op=RecKind.UPDATE),
        UpdateRec(lsn=8, txn=3, table="ta/ble", key=b"", before=None,
                  after=b"", pid=-1, prev_lsn=0, op=RecKind.INSERT),
        UpdateRec(lsn=9, txn=4, table="", key=b"\x00\xff", before=b"",
                  after=None, pid=0, prev_lsn=8, op=RecKind.DELETE),
        CommitRec(lsn=10, txn=3, prev_lsn=9),
        AbortRec(lsn=11, txn=4, prev_lsn=9),
        CLRRec(lsn=12, txn=4, table="t", key=b"k1", after=None,
               op=RecKind.DELETE, pid=13, undone_lsn=9, undo_next=0),
        BeginCkptRec(lsn=13),
        EndCkptRec(lsn=14, bckpt_lsn=13, active_txns={3: 9, 9: 2}),
        BWRec(lsn=15, written_set=[1, 2, 3], fw_lsn=4),
        DeltaRec(lsn=16, dirty_set=[5, 5, 6], written_set=[5],
                 fw_lsn=9, first_dirty=2, tc_lsn=15, dirty_lsns=None),
        DeltaRec(lsn=17, dirty_set=[7], written_set=[], fw_lsn=0,
                 first_dirty=0, tc_lsn=16, dirty_lsns=[11]),
        SMORec(lsn=18, images={2: b"page-bytes", 5: b""}, root_pid=2,
               next_pid=6, height=3),
        RSSPRec(lsn=19, rssp_lsn=13, root_pid=2, next_pid=6, height=3),
        SnapshotRec(lsn=20, snapshot_id=2, oldest_active_lsn=9),
    ]


# ------------------------------------------------------------------- codec
def test_record_roundtrip_every_kind():
    from repro.core.records import REC_CLASSES
    seen = set()
    for rec in sample_records():
        out = decode_record(encode_record(rec))
        assert out == rec, f"{rec.kind.name} did not round-trip"
        assert type(out) is type(rec) is REC_CLASSES[rec.kind]
        seen.add(rec.kind)
    # the registry is the codec's coverage contract: every kind the core
    # can log must round-trip through the media codec
    assert seen == set(RecKind) == set(REC_CLASSES), \
        f"kinds not exercised: {set(RecKind) - seen}"


def test_segment_roundtrip_and_header():
    recs = sample_records()
    for i, rec in enumerate(recs):       # contiguous LSNs, as sealed runs are
        rec.lsn = 100 + i
    blob = encode_segment(recs)
    from repro.media import decode_segment_header
    assert decode_segment_header(blob) == (100, 100 + len(recs) - 1,
                                           len(recs))
    assert decode_segment(blob) == recs


def test_snapshot_roundtrip():
    snap = Snapshot(snapshot_id=3, begin_lsn=50, end_lsn=61, redo_lsn=47,
                    rows=((b"t\x00a", b"v1"), (b"t\x00b", b"")), chunks=4)
    assert decode_snapshot(encode_snapshot(snap)) == snap
    empty = Snapshot(snapshot_id=1, begin_lsn=2, end_lsn=2, redo_lsn=3,
                     rows=(), chunks=1)
    assert decode_snapshot(encode_snapshot(empty)) == empty


def test_master_roundtrip_via_backend(tmp_path):
    log = LogManager()
    log.set_master(end_ckpt=44, bckpt=40, rssp_rec=42)
    backend = DirectoryBackend(tmp_path)
    log.save_master(backend)
    assert LogManager.load_master(backend) == Master(44, 40, 42)
    assert LogManager.load_master(MemoryBackend()) == Master()  # never saved
    with pytest.raises(ValueError, match="MediaBackend"):
        LogManager().save_master()           # no archive, no backend


# -------------------------------------------------------------- corruption
def _segment_blob():
    recs = sample_records()
    for i, rec in enumerate(recs):
        rec.lsn = 1 + i
    return encode_segment(recs)


def test_truncated_frame_is_loud():
    blob = _segment_blob()
    with pytest.raises(CorruptSegmentError, match="truncated"):
        decode_segment(blob[:-3])
    with pytest.raises(CorruptSegmentError, match="truncated"):
        decode_segment(blob[: len(blob) // 2])
    with pytest.raises(CorruptSegmentError):
        decode_segment(blob[:6])             # not even a whole header


def test_bad_crc_is_loud():
    blob = bytearray(_segment_blob())
    blob[-1] ^= 0xFF                         # flip a bit inside a payload
    with pytest.raises(CorruptSegmentError, match="CRC mismatch"):
        decode_segment(bytes(blob))


def test_unknown_format_version_is_loud():
    blob = _segment_blob()
    newer = blob[:4] + bytes([99]) + blob[5:]
    with pytest.raises(UnknownFormatError, match="format version 99"):
        decode_segment(newer)
    with pytest.raises(CorruptSegmentError, match="bad magic"):
        decode_segment(b"JUNK" + blob[4:])


def test_corrupt_segment_never_yields_short_scan():
    """The TruncatedLogError contract in byte form: a scan that would
    miss records raises, it never returns fewer records."""
    rng = random.Random(5)
    db, rows, base = make_primary(rng, n_rows=N_ROWS, val=VAL)
    backend = MemoryBackend()
    arch = LogArchive(segment_records=32, backend=backend, cache_segments=0)
    db.log.attach_archive(arch)
    drive(db, rng, 30, n_rows=N_ROWS, val=VAL)
    arch.seal(db.log)
    db.log.truncate(db.log.stable_lsn)
    victim = arch.segments[1].name
    backend.put(victim, backend.get(victim)[:-9])        # torn mid-frame
    with pytest.raises(CorruptSegmentError, match="truncated"):
        list(db.log.scan(1))
    with pytest.raises(CorruptSegmentError):
        db.log.record(arch.segments[1].lo)
    # segments around the torn one still read fine
    assert [r.lsn for r in db.log.scan(1, arch.segments[0].hi)] == \
        list(range(1, arch.segments[0].hi + 1))


# ---------------------------------------------------------------- backends
@pytest.mark.parametrize("kind", ["memory", "directory"])
def test_backend_semantics(kind, tmp_path):
    backend = MemoryBackend() if kind == "memory" \
        else DirectoryBackend(tmp_path / "b")
    backend.put("seg/000000000001", b"one")
    backend.put("snap/00000001", b"two")
    backend.put("master", b"three")
    assert backend.get("seg/000000000001") == b"one"
    assert backend.list() == ["master", "seg/000000000001", "snap/00000001"]
    assert backend.list("seg/") == ["seg/000000000001"]
    backend.put("seg/000000000001", b"grown")            # atomic replace
    assert backend.get("seg/000000000001") == b"grown"
    backend.delete("snap/00000001")
    backend.delete("snap/00000001")                      # idempotent
    assert not backend.exists("snap/00000001")
    with pytest.raises(KeyError, match="snap/00000001"):
        backend.get("snap/00000001")


def test_manifest_oplog_compacts_and_survives(tmp_path):
    """The manifest is an append-only op log (O(1) per mutation); it must
    replay to the right live set across reopen and compact itself once
    tombstones dominate — a steady seal/prune cadence must not grow it
    with history."""
    b = DirectoryBackend(tmp_path / "b")
    for i in range(200):
        b.put(f"seg/{i:012d}", b"x" * 8)
        if i >= 2:
            b.delete(f"seg/{i - 2:012d}")
    live = {f"seg/{198:012d}", f"seg/{199:012d}"}
    assert set(b.list()) == live
    # 398 ops total, 2 live names: compaction must have kept the log small
    manifest_lines = (tmp_path / "b" / "MANIFEST").read_text().splitlines()
    assert len(manifest_lines) <= DirectoryBackend.COMPACT_MIN_OPS + 4
    reopened = DirectoryBackend(tmp_path / "b")
    assert set(reopened.list()) == live


def test_attach_backend_backfills_existing_snapshots(tmp_path):
    """A snapshot taken before the Archiver (and its backend) existed
    must still reach durable media — cold restore and in-process restore
    have to see the same snapshot set."""
    rng = random.Random(11)
    db, rows, base = make_primary(rng, n_rows=N_ROWS, val=VAL)
    store = SnapshotStore()
    early = store.take(db, chunk_keys=64)        # pre-attachment snapshot
    drive(db, rng, 10, n_rows=N_ROWS, val=VAL)
    backend = DirectoryBackend(tmp_path / "bf")
    Archiver(db, archive=LogArchive(segment_records=64, backend=backend),
             snapshots=store).run_once()
    assert backend.exists(f"snap/{early.snapshot_id:08d}")
    target = min(db.log.stable_lsn, early.end_lsn + 15)
    oracle = committed_state_oracle(db.crash(), base, upto_lsn=target)
    restored, stats = cold_restore(backend, target_lsn=target)
    assert stats.snapshot_id == early.snapshot_id
    assert dict(restored.scan_all()) == oracle


def test_directory_backend_survives_reopen(tmp_path):
    b1 = DirectoryBackend(tmp_path / "b")
    b1.put("seg/000000000001", b"payload")
    b1.put("master", b"m")
    b1.delete("master")
    # a stray file without a manifest entry (crash between blob write and
    # manifest publish) must be invisible
    (tmp_path / "b" / "stray").write_bytes(b"garbage")
    b2 = DirectoryBackend(tmp_path / "b")
    assert b2.list() == ["seg/000000000001"]
    assert b2.get("seg/000000000001") == b"payload"
    with pytest.raises(KeyError):
        b2.get("stray")
    with pytest.raises(ValueError, match="escapes"):
        b2.put("../outside", b"x")


# -------------------------------------------------------------- decode LRU
def test_decode_lru_bounds_decodes():
    rng = random.Random(6)
    db, rows, base = make_primary(rng, n_rows=N_ROWS, val=VAL)
    arch = LogArchive(segment_records=32, cache_segments=2)
    db.log.attach_archive(arch)
    drive(db, rng, 40, n_rows=N_ROWS, val=VAL)
    arch.seal(db.log)
    db.log.truncate(db.log.stable_lsn)
    lo = arch.segments[0].lo
    for _ in range(50):                      # hot point reads, one segment
        db.log.record(lo)
    assert arch.segment_decodes <= 2         # first touch only
    assert arch.cache_hits >= 49
    assert len(arch._cache) <= 2             # LRU never exceeds its bound
    full = list(db.log.scan(1))              # cold sweep decodes each once
    assert len(full) == db.log.stable_lsn
    assert arch.segment_decodes <= len(arch.segments) + 2
    # cache_segments=0 disables caching entirely
    arch0 = LogArchive.load(arch.backend, cache_segments=0)
    arch0.record(lo)
    arch0.record(lo)
    assert arch0.segment_decodes == 2 and len(arch0._cache) == 0


# ------------------------------------------------------------ cold restore
def _sealed_primary(tmp_path, *, extra_after_seal=0):
    rng = random.Random(9)
    db, rows, base = make_primary(rng, n_rows=N_ROWS, val=VAL)
    backend = DirectoryBackend(tmp_path / "cold")
    store = SnapshotStore()
    arch = Archiver(db, archive=LogArchive(segment_records=64,
                                           backend=backend),
                    snapshots=store)
    drive(db, rng, 25, n_rows=N_ROWS, val=VAL)
    store.take(db, chunk_keys=64,
               on_chunk=lambda: drive(db, rng, 2, n_rows=N_ROWS, val=VAL))
    drive(db, rng, 25, n_rows=N_ROWS, val=VAL)
    arch.run_once()
    if extra_after_seal:
        drive(db, rng, extra_after_seal, n_rows=N_ROWS, val=VAL)
    return db, base, backend, arch.archive.archived_upto


def test_cold_restore_fresh_objects(tmp_path):
    """Same-process form: restore touches nothing but the backend (fresh
    LogArchive/SnapshotStore built inside cold_restore)."""
    db, base, backend, sealed = _sealed_primary(tmp_path,
                                                extra_after_seal=15)
    oracle = committed_state_oracle(db.crash(), base, upto_lsn=sealed)
    restored, stats = cold_restore(backend, page_size=16384)
    assert stats.target_lsn == sealed        # defaults to the sealed frontier
    assert dict(restored.scan_all()) == oracle
    # a point-in-time target below the frontier works too
    mid = sealed - 20
    restored2, _ = cold_restore(tmp_path / "cold", target_lsn=mid)
    assert dict(restored2.scan_all()) == \
        committed_state_oracle(db.crash(), base, upto_lsn=mid)
    with pytest.raises(ValueError, match="nothing to restore"):
        cold_restore(DirectoryBackend(tmp_path / "empty"))


def test_cold_restore_replica_and_reseed_from_backend(tmp_path):
    """A standby seeded from the dead primary's media catches up against
    the restored primary through ordinary shipping."""
    db, base, backend, sealed = _sealed_primary(tmp_path)
    oracle = committed_state_oracle(db.crash(), base, upto_lsn=sealed)
    new_primary, _ = cold_restore(backend)
    rep = cold_restore_replica(backend, "r1", page_size=2048,
                               cache_pages=256)
    rs = ReplicaSet(new_primary)
    # the restored primary's LSN space differs from the dead one's; the
    # replica positions in *media* LSN space, so re-subscription must use
    # the restored log — reseed pins applied/resume to the snapshot window
    assert rep.applied_lsn > 0 and rep.resume_lsn > 0
    rep2 = cold_restore_replica(backend, "r2", page_size=8192,
                                cache_pages=256)
    assert rep2.user_state() == dict(rep.user_state())
    # reseed_from_backend on an existing replica lands at the same window
    from repro.replication import Replica
    joiner = Replica("r3", cache_pages=256)
    snap = joiner.reseed_from_backend(backend)
    assert (joiner.applied_lsn, joiner.resume_lsn) == \
        (snap.begin_lsn, snap.redo_lsn)
    assert joiner.user_state() == rep.user_state()
    with pytest.raises(ValueError, match="no usable snapshot"):
        Replica("r4", cache_pages=128).reseed_from_backend(
            MemoryBackend())
    # and the cold-restored primary serves reads equal to the oracle
    assert dict(new_primary.scan_all()) == oracle


def test_archive_log_view_serves_cold_readers(tmp_path):
    """The read-only LogManager over cold bytes must behave like a real
    log to its consumers: the oracle runs against it directly, scans
    splice down into segments, the master pointer is live, and —
    critically — commit-relative lag is honest (a NULL stable-commit
    watermark would make any stale replica read as fully caught up)."""
    from repro.media import archive_log_view
    db, base, backend, sealed = _sealed_primary(tmp_path)
    view = archive_log_view(backend)
    assert view.stable_lsn == sealed
    assert [r.lsn for r in view.scan(1)] == list(range(1, sealed + 1))
    assert view.master.end_ckpt_lsn > 0          # loaded, not default
    # oracle accepts the bare LogManager (the documented cold form)
    oracle = committed_state_oracle(db.crash(), base, upto_lsn=sealed)
    assert committed_state_oracle(view, base) == oracle
    # honest lag: the view knows its newest stable commit, so a replica
    # seeded from an older snapshot measures a real, nonzero lag
    assert view.last_stable_commit_lsn > 0
    rep = cold_restore_replica(backend, "lagger", cache_pages=256)
    assert rep.applied_lsn < view.last_stable_commit_lsn
    assert rep.lag(view) == view.last_stable_commit_lsn - rep.applied_lsn
    assert rep.lag(view) > 0


@pytest.mark.parametrize("variant", ["live", "crash", "pruned"])
def test_cold_restore_across_process_boundary(tmp_path, variant):
    """The acceptance test of the media layer: process A runs a workload,
    seals, snapshots, exits; process B — sharing nothing but a directory —
    restores at the chosen target and equals the committed-state oracle."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.pathsep.join(
        [str(SRC_DIR), str(TESTS_DIR)]
        + ([env["PYTHONPATH"]] if env.get("PYTHONPATH") else []))
    script = TESTS_DIR / "media_coldstart.py"
    for role_args in (["prepare", str(tmp_path), variant],
                      ["restore", str(tmp_path)]):
        proc = subprocess.run([sys.executable, str(script), *role_args],
                              env=env, capture_output=True, text=True,
                              timeout=300)
        assert proc.returncode == 0, (
            f"{role_args[0]} subprocess failed (variant={variant}):\n"
            f"stdout: {proc.stdout}\nstderr: {proc.stderr}")
    assert (tmp_path / "backend" / "MANIFEST").exists()
