"""Observability layer: registry/tracer units, traced-recovery acceptance,
Log2 pacing parity, decode-cache counters, shard gauges, bench-diff gate."""
import dataclasses
import json
import random
import sys
from pathlib import Path

import pytest

from repro import obs
from repro.archive import Archiver, LogArchive, SnapshotStore
from repro.core import (Database, RecoveryStats, Strategy,
                        committed_state_oracle, make_key, recover,
                        recovered_state)
from repro.core.storage import issue_schedule, prefetch_overlap
from repro.replication import LogShipper, ShardedApplier

import repl_workload

sys.path.insert(0, str(Path(__file__).resolve().parents[1]))
from benchmarks import diff as bench_diff  # noqa: E402


@pytest.fixture(autouse=True)
def _clean_obs():
    """Every test starts and ends with tracing off and a clear trace;
    metrics are reset per-prefix inside tests that assert on them (the
    registry is process-wide by design)."""
    obs.disable()
    obs.TRACER.clear()
    yield
    obs.disable()
    obs.TRACER.clear()


# ------------------------------------------------------------------ registry
def test_registry_counters_gauges_histograms():
    obs.REGISTRY.reset("test_reg")
    c = obs.counter("test_reg.hits")
    c.inc()
    c.inc(4)
    assert obs.value("test_reg.hits") == 5
    g = obs.gauge("test_reg.depth")
    g.set(7)
    g.inc(-2)
    assert obs.value("test_reg.depth") == 5
    h = obs.histogram("test_reg.window")
    for v in (10, 20, 30):
        h.observe(v)
    s = obs.value("test_reg.window")
    assert s == {"count": 3, "sum": 60.0, "min": 10, "max": 30, "avg": 20.0,
                 "p50": 20, "p95": 30, "p99": 30}
    # untouched metrics read as 0, and re-requesting returns the same object
    assert obs.value("test_reg.never") == 0
    assert obs.counter("test_reg.hits") is c


def test_registry_labels_flatten_sorted_and_isolate():
    obs.REGISTRY.reset("test_lbl")
    obs.gauge("test_lbl.lag", shard=1, replica="r1").set(10)
    obs.gauge("test_lbl.lag", replica="r1", shard=2).set(20)
    snap = obs.snapshot("test_lbl")
    # labels sort alphabetically regardless of kwargs order
    assert snap == {"test_lbl.lag{replica=r1,shard=1}": 10,
                    "test_lbl.lag{replica=r1,shard=2}": 20}
    assert obs.value("test_lbl.lag", shard=1, replica="r1") == 10


def test_registry_reset_zeroes_in_place():
    """Call sites cache Counter references at import; reset must zero the
    object, never replace it."""
    obs.REGISTRY.reset("test_rst")
    c = obs.counter("test_rst.n")
    c.inc(9)
    obs.REGISTRY.reset("test_rst")
    assert obs.value("test_rst.n") == 0
    c.inc()                      # the cached reference still feeds the key
    assert obs.value("test_rst.n") == 1
    assert obs.counter("test_rst.n") is c


def test_registry_kind_conflict_is_loud():
    obs.REGISTRY.reset("test_kind")
    obs.counter("test_kind.x")
    with pytest.raises(TypeError, match="already registered"):
        obs.gauge("test_kind.x")


def test_publish_and_load_dataclass_round_trip():
    obs.REGISTRY.reset("recovery")
    st = RecoveryStats(strategy="Log1", log_records=123, batched=True,
                       redo_wall_ms=4.5)
    st.redo.redone = 77
    st.io.sync_reads = 9
    st.publish()
    assert obs.value("recovery.log_records") == 123
    assert obs.value("recovery.batched") == 1
    assert obs.value("recovery.redo.redone") == 77
    assert obs.value("recovery.io.sync_reads") == 9
    view = RecoveryStats.from_registry()
    assert view.log_records == 123 and view.batched is True
    assert view.redo_wall_ms == 4.5
    assert view.redo.redone == 77 and view.io.sync_reads == 9
    assert view.strategy == ""          # non-numeric fields stay default


# -------------------------------------------------------------------- tracer
def test_tracer_disabled_is_silent_and_shared():
    sp1 = obs.TRACER.span("a", k=1)
    sp2 = obs.TRACER.span("b")
    assert sp1 is sp2                   # the shared null span
    with sp1 as s:
        s.set(more=2)
    obs.TRACER.event("never")
    assert obs.TRACER.events == []


def test_tracer_nesting_events_and_jsonl(tmp_path):
    obs.enable()
    with obs.span("outer", tag="t") as o:
        with obs.span("inner"):
            obs.event("leaf", n=3)
        o.set(late=1)
    obs.disable()
    ev = obs.TRACER.events
    kinds = [(e["type"], e["name"]) for e in ev]
    assert kinds == [("begin", "outer"), ("begin", "inner"),
                     ("event", "leaf"), ("end", "inner"), ("end", "outer")]
    outer_id = ev[0]["span"]
    inner_id = ev[1]["span"]
    assert ev[0]["parent"] == 0 and ev[1]["parent"] == outer_id
    assert ev[2]["parent"] == inner_id
    assert ev[4]["attrs"] == {"tag": "t", "late": 1}    # set() rides the end
    assert ev[0]["wall"] > 0 and ev[3]["dur_ms"] >= 0
    path = obs.trace.export_jsonl(tmp_path / "t.jsonl")
    assert obs.load_jsonl(path) == ev
    obs.TRACER.clear()
    assert obs.TRACER.events == [] and obs.TRACER._stack == []


def test_timeline_renders_tree_and_aggregates():
    obs.enable()
    with obs.span("recover"):
        with obs.span("redo"):
            for _ in range(5):
                obs.event("io.demand", stall_ms=2.0)
    obs.disable()
    out = obs.render_timeline()
    assert "recover" in out and "└─ redo" in out
    assert "5x io.demand" in out and "stall_ms=10.0" in out


# ------------------------------------------------- traced recovery acceptance
def _crash_image(n_rows=4000, n_txns=250, seed=11):
    rng = random.Random(seed)
    db = Database(cache_pages=512, tracker_interval=50, bg_flush_per_txn=2)
    rows = [(f"k{i:06d}".encode(), rng.randbytes(40)) for i in range(n_rows)]
    db.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}

    def drive(n):
        for _ in range(n):
            db.run_txn([("update", "t",
                         f"k{rng.randrange(n_rows):06d}".encode(),
                         rng.randbytes(40)) for _ in range(6)])

    drive(n_txns // 2)
    db.checkpoint()
    drive(n_txns // 2)
    return db.crash(), base


def test_traced_batched_recovery_timeline_and_registry_view():
    """The PR's acceptance run: one traced recover(batched=True) produces
    phase spans whose walls match the stats, window spans that sum to
    log_records, and a registry view consistent with RecoveryStats."""
    image, base = _crash_image()
    oracle = committed_state_oracle(image, base)
    obs.REGISTRY.reset("recovery")
    obs.enable()
    db, stats = recover(image, Strategy.LOG1, batched=True,
                        batch_window=256)
    obs.disable()
    assert recovered_state(db) == oracle

    ev = obs.TRACER.events
    roots = obs.build_tree(ev)
    assert [r.name for r in roots] == ["recover"]
    phases = [c.name for c in roots[0].children]
    assert phases == ["analysis", "redo", "undo", "checkpoint"]
    redo = roots[0].children[1]
    windows = [c for c in redo.children if c.name == "redo.window"]
    assert len(windows) == stats.windows >= 2
    assert sum(w.attrs["records"] for w in windows) == stats.log_records
    # span walls and stats timers measure the same regions
    analysis, = [c for c in roots[0].children if c.name == "analysis"]
    assert analysis.attrs["analysis_ms"] == round(stats.analysis_ms, 3)
    assert redo.attrs["redo_wall_ms"] == round(stats.redo_wall_ms, 3)
    assert abs(redo.dur_ms - stats.redo_wall_ms) < 5.0

    # the legacy dataclass is a view over the registry
    view = RecoveryStats.from_registry()
    for f in dataclasses.fields(RecoveryStats):
        got, want = getattr(view, f.name), getattr(stats, f.name)
        if isinstance(want, (bool, int, float)):
            assert got == want, f"registry view diverged on {f.name}"
    assert view.redo == stats.redo and view.io == stats.io

    out = obs.render_timeline(snapshot=obs.snapshot())
    for needle in ("recover", "analysis", "redo.window", "undo",
                   "checkpoint", "cache: pagestore decode cache"):
        assert needle in out, f"timeline missing {needle!r}"


# ------------------------------------------------------- Log2 pacing parity
def test_log2_batched_pacing_matches_per_record_schedule():
    """The iosim fix: batched Log2 must issue the PF-list on the exact
    per-record schedule (same pid groups, same order), with issues spread
    across the window's work — not collapsed onto the window start, which
    was the window-granular bug that overstated prefetch overlap."""
    image, base = _crash_image(seed=13)
    oracle = committed_state_oracle(image, base)

    def traced(**kw):
        obs.TRACER.clear()
        # small lookahead so the pacer actually gates issues at this scale
        # (the default would swallow the whole small pf_list in one burst)
        db, st = recover(image, Strategy.LOG2, lookahead=16, **kw)
        assert recovered_state(db) == oracle
        return list(obs.TRACER.events)

    obs.enable()
    ev_per = traced()
    ev_bat = traced(batched=True, batch_window=256)
    obs.disable()

    sched_per, sched_bat = issue_schedule(ev_per), issue_schedule(ev_bat)
    assert sched_per, "Log2 issued no PF-list prefetches"
    assert sched_bat == sched_per

    # batched issues spread across work positions (distinct modeled
    # clocks), except the initial lookahead burst
    clocks = [e["attrs"]["clock"] for e in ev_bat
              if e.get("name") == "io.prefetch.issue"]
    assert len(set(clocks)) > len(clocks) // 2

    ov_per, ov_bat = prefetch_overlap(ev_per), prefetch_overlap(ev_bat)
    assert ov_per["issued"] == ov_bat["issued"]
    assert ov_per["consumed"] > 0 and ov_bat["consumed"] > 0
    # batched demand reads land at the window end, after more work has
    # overlapped — so prefetching must absorb (fully or partially) at
    # least as many demands, and never pay more cold random reads.  A
    # single hit-vs-partial flip is modeled-clock luck (page-layout
    # changes move split points and hence prefetch run grouping), so the
    # full-hit fraction is not asserted ordinal on its own.
    assert (ov_bat["hits"] + ov_bat["partials"]
            >= ov_per["hits"] + ov_per["partials"])
    assert ov_bat["syncs"] <= ov_per["syncs"]


# ------------------------------------------------------ decode-cache counters
def test_pagestore_decode_cache_cold_then_warm_via_registry():
    image, base = _crash_image(n_rows=1500, n_txns=80, seed=17)
    obs.REGISTRY.reset("pagestore")
    recover(image, Strategy.LOG1)
    cold = obs.snapshot("pagestore")
    assert cold["pagestore.decode_misses"] > 0
    # same image again: the content-keyed cache is shared across clones,
    # so the second recovery decodes (almost) nothing new
    recover(image, Strategy.LOG1)
    warm = obs.snapshot("pagestore")
    new_hits = warm["pagestore.decode_hits"] - cold["pagestore.decode_hits"]
    new_misses = (warm["pagestore.decode_misses"]
                  - cold["pagestore.decode_misses"])
    assert new_hits > new_misses
    assert new_hits >= cold["pagestore.decode_misses"] // 2
    # reset path: keys zero but the cached module counters keep feeding
    obs.REGISTRY.reset("pagestore")
    assert obs.snapshot("pagestore")["pagestore.decode_hits"] == 0
    recover(image, Strategy.LOG1)
    assert obs.value("pagestore.decode_hits") > 0


def test_archive_lru_cold_then_warm_via_registry():
    rng = random.Random(23)
    db = Database(cache_pages=256, tracker_interval=50, bg_flush_per_txn=2)
    rows = [(f"k{i:05d}".encode(), rng.randbytes(40)) for i in range(800)]
    db.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}
    arch = Archiver(db, archive=LogArchive(segment_records=256,
                                           cache_segments=64),
                    snapshots=SnapshotStore())

    def drive(n):
        for _ in range(n):
            db.run_txn([("update", "t",
                         f"k{rng.randrange(800):05d}".encode(),
                         rng.randbytes(40)) for _ in range(5)])

    drive(60)
    arch.snapshots.take(db)
    drive(60)
    arch.run_once()
    store = arch.snapshots          # Archiver attached the archive to it
    target = arch.archive.archived_upto
    oracle = committed_state_oracle(db.crash(), base, upto_lsn=target)

    obs.REGISTRY.reset("archive")
    db1, _ = store.restore(target)               # cold: decodes segments
    assert dict(db1.scan_all()) == oracle
    cold = obs.snapshot("archive")
    assert cold["archive.segment_decodes"] > 0
    db2, _ = store.restore(target)               # warm: served by the LRU
    assert dict(db2.scan_all()) == oracle
    warm = obs.snapshot("archive")
    assert warm["archive.segment_decodes"] == cold["archive.segment_decodes"]
    assert warm["archive.cache_hits"] > cold["archive.cache_hits"]
    # counter reset leaves the instance tallies (the per-archive API) alone
    decodes_inst = arch.archive.segment_decodes
    obs.REGISTRY.reset("archive")
    assert obs.value("archive.segment_decodes") == 0
    assert arch.archive.segment_decodes == decodes_inst


# ----------------------------------------------------------- shard imbalance
def _dispatch(primary, rep):
    shipper = LogShipper(primary)
    shipper.subscribe(rep.replica_id, from_lsn=rep.resume_lsn)
    shipper.drain(rep.replica_id, rep.apply_batch)


def test_dispatch_imbalance_gauge_moves_under_skew():
    rng = random.Random(31)
    n_rows, val = 400, 24

    def run(rid, hot_key):
        primary, rows, _ = repl_workload.make_primary(rng, n_rows=n_rows,
                                                      val=val)
        rep = ShardedApplier(rid, page_size=4096, cache_pages=512,
                             tracker_interval=25, bg_flush_per_txn=2,
                             seed_tables={"t": rows}, n_shards=4,
                             epoch_txns=8)
        for _ in range(40):
            if hot_key:
                ops = [("update", "t", b"k00042", rng.randbytes(val))
                       for _ in range(4)]
            else:
                ops = [("update", "t",
                        f"k{rng.randrange(n_rows):05d}".encode(),
                        rng.randbytes(val)) for _ in range(4)]
            primary.run_txn(ops)
        _dispatch(primary, rep)
        return rep

    uniform = run("u1", hot_key=False)
    skewed = run("s1", hot_key=True)

    g_uniform = obs.value("repl.dispatch_imbalance", replica="u1")
    g_skewed = obs.value("repl.dispatch_imbalance", replica="s1")
    assert g_uniform == round(uniform.imbalance(), 4)
    assert g_skewed == round(skewed.imbalance(), 4)
    # one hot key lands every op on one shard: imbalance == n_shards
    assert g_skewed == 4.0
    assert g_uniform < 2.0 < g_skewed

    # per-shard gauges are live and account for every dispatched op
    dispatched = [obs.value("repl.shard.dispatched_ops",
                            replica="s1", shard=i) for i in range(4)]
    assert sum(dispatched) == sum(s.dispatched_ops for s in skewed.shards)
    assert sorted(dispatched)[:3] == [0, 0, 0]   # cold shards
    for i in range(4):
        assert obs.value("repl.shard.lag", replica="s1", shard=i) == 0
        assert obs.value("repl.shard.watermark",
                         replica="s1", shard=i) == skewed.shard_watermark(i)


def test_shard_gauges_show_lag_with_manual_pump():
    rng = random.Random(37)
    primary, rows, _ = repl_workload.make_primary(rng, n_rows=200, val=24)
    rep = ShardedApplier("m1", page_size=4096, cache_pages=512,
                         tracker_interval=25, bg_flush_per_txn=2,
                         seed_tables={"t": rows}, n_shards=2,
                         partitioner=lambda t, k: k[-1] % 2,
                         epoch_txns=10_000, auto_pump=False)
    for i in range(12):
        primary.run_txn([("update", "t", f"k{i % 200:05d}".encode(),
                          rng.randbytes(24))])
    _dispatch(primary, rep)
    rep.pump(shard=0)                  # shard 1 still queued
    rep.publish_metrics()
    lag0 = obs.value("repl.shard.lag", replica="m1", shard=0)
    lag1 = obs.value("repl.shard.lag", replica="m1", shard=1)
    assert lag0 == 0 and lag1 > 0
    rep.pump()
    rep.barrier()
    rep.publish_metrics()
    assert obs.value("repl.shard.lag", replica="m1", shard=1) == 0


# --------------------------------------------------------------- bench-diff
def _summary(mode, rows):
    return {"run": 1, "mode": mode,
            "rows": [{"module": m, "name": n, "us_per_call": us}
                     for m, n, us in rows]}


def test_bench_diff_flags_guarded_regressions_only():
    old = _summary("fast", [
        ("recovery_pipeline", "recovery_redo/Log1", 100.0),
        ("recovery_pipeline", "recovery_redo/Log0", 100.0),
        ("kernel_bench", "kernel/sort", 100.0),      # not oracle-guarded
        ("media", "media/tiny", 10.0),               # below the noise floor
    ])
    new = _summary("fast", [
        ("recovery_pipeline", "recovery_redo/Log1", 250.0),   # 2.5x: flag
        ("recovery_pipeline", "recovery_redo/Log0", 150.0),   # 1.5x: ok
        ("kernel_bench", "kernel/sort", 900.0),               # unguarded
        ("media", "media/tiny", 45.0),                        # noise floor
    ])
    regressions = bench_diff.compare_runs(old, new)
    assert len(regressions) == 1
    assert "recovery_redo/Log1" in regressions[0]
    assert "2.50x" in regressions[0]
    assert bench_diff.compare_runs(old, old) == []


def test_bench_diff_gate_is_graceful_without_history(tmp_path,
                                                     monkeypatch):
    monkeypatch.setattr(bench_diff, "ART_ROOT", tmp_path)
    assert bench_diff.main() == 0            # no artifacts at all
    (tmp_path / "bench_1.json").write_text(
        '{"run": 1, "mode": "fast", "rows": []}')
    assert bench_diff.main() == 0            # nothing to compare against
    (tmp_path / "bench_2.json").write_text(
        '{"run": 2, "mode": "full", "rows": []}')
    assert bench_diff.main() == 0            # different mode: still no pair


def test_bench_diff_warns_on_unreadable_artifact(tmp_path, monkeypatch,
                                                 capsys):
    monkeypatch.setattr(bench_diff, "ART_ROOT", tmp_path)
    (tmp_path / "bench_1.json").write_text('{"run": 1, "mo')   # torn write
    (tmp_path / "bench_2.json").write_text(
        '{"run": 2, "mode": "fast", "rows": []}')
    assert bench_diff.main() == 0
    err = capsys.readouterr().err
    assert "WARNING" in err and "bench_1.json" in err       # loud, by name


def test_bench_diff_newest_unreadable_is_loud_noop_pass(tmp_path,
                                                        monkeypatch,
                                                        capsys):
    monkeypatch.setattr(bench_diff, "ART_ROOT", tmp_path)
    (tmp_path / "bench_1.json").write_text(
        '{"run": 1, "mode": "fast", "rows": []}')
    (tmp_path / "bench_2.json").write_text('{"run": 2,')       # truncated
    assert bench_diff.main() == 0            # no-op pass, never a crash
    out = capsys.readouterr()
    assert "bench_2.json" in out.err         # the culprit is named
    assert "unreadable" in out.out           # and the verdict says why


def test_bench_diff_json_verdict(tmp_path, monkeypatch, capsys):
    monkeypatch.setattr(bench_diff, "ART_ROOT", tmp_path)
    rows = ('[{"module": "media", "name": "blob", "us_per_call": %s}]')
    (tmp_path / "bench_1.json").write_text(
        '{"run": 1, "mode": "fast", "rows": %s}' % (rows % "100.0"))
    (tmp_path / "bench_2.json").write_text(
        '{"run": 2, "mode": "fast", "rows": %s}' % (rows % "300.0"))
    assert bench_diff.main(["--json"]) == 1
    verdict = json.loads(capsys.readouterr().out)
    assert verdict["ok"] is False and verdict["status"] == "regressions"
    assert verdict["old_run"] == 1 and verdict["new_run"] == 2
    assert len(verdict["regressions"]) == 1
    assert "media/blob" in verdict["regressions"][0]
