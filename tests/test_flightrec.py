"""Flight recorder + crash forensics tests: ring semantics, black-box
dump round-trips, crash-site dumps (explicit crash, mid-redo fault,
mid-shard-apply fault), torn-dump refusal, the dump-file-alone
post-mortem (subprocess), commit-to-visible histograms, live recovery
progress, and the Prometheus/JSONL exporters."""
import io
import json
import os
import random
import subprocess
import sys
from pathlib import Path

import pytest

import repl_workload
from repro import obs
from repro.archive import Archiver, LogArchive, SnapshotStore
from repro.core import (Database, Strategy, committed_state_oracle, make_key,
                        recover, recovered_state)
from repro.media import DirectoryBackend, cold_restore
from repro.media.errors import CorruptSegmentError
from repro.obs.flightrec import FlightRecorder, decode_dump
from repro.obs.progress import ProgressObserver
from repro.replication import LogShipper, Replica, ShardedApplier

REPO = Path(__file__).resolve().parents[1]
N_ROWS = 300
VAL = 32


@pytest.fixture(autouse=True)
def _obs_isolation():
    obs.FLIGHT.configure(sink=None)
    obs.FLIGHT.clear()
    obs.disable()
    obs.TRACER.clear()
    yield
    obs.FLIGHT.configure(sink=None)
    obs.FLIGHT.clear()
    obs.disable()
    obs.TRACER.clear()


def make_primary(rng):
    return repl_workload.make_primary(rng, n_rows=N_ROWS, val=VAL)


def drive(db, rng, n_txns, abort_frac=0.15):
    repl_workload.drive(db, rng, n_txns, n_rows=N_ROWS, val=VAL,
                        abort_frac=abort_frac)


def _crash_image(seed=3, n_txns=80):
    rng = random.Random(seed)
    db, rows, base = make_primary(rng)
    drive(db, rng, n_txns, abort_frac=0.0)
    return db.crash(), base


class _Saboteur(ProgressObserver):
    """Raises once redo crosses the halfway mark — a stand-in for an OOM
    kill or power cut landing mid-phase."""

    def __init__(self):
        super().__init__("recover", out=io.StringIO())

    def update(self, done_units, records=None):
        super().update(done_units, records)
        if self.fraction >= 0.5:
            raise RuntimeError("injected fault mid-redo")


def _failed_recovery_dump(sink_dir):
    """Stage a recovery that dies mid-redo; returns the dump path."""
    image, _base = _crash_image()
    obs.FLIGHT.configure(sink=sink_dir)
    with pytest.raises(RuntimeError, match="injected fault"):
        recover(image, Strategy.LOG1, batched=True, batch_window=64,
                progress=_Saboteur())
    path = obs.FLIGHT.last_dump
    assert path is not None
    return path


# ------------------------------------------------------------------- ring
def test_ring_bounds_order_and_dropped():
    fr = FlightRecorder(capacity=4)
    for i in range(10):
        fr.record("k", i)
    evs = fr.events()
    assert [e[2] for e in evs] == [6, 7, 8, 9]      # last 4, oldest first
    assert fr.recorded == 10 and fr.dropped == 6
    fr.clear()
    assert fr.events() == [] and fr.recorded == 0 and fr.dropped == 0
    fr.record("k", 1)
    assert [e[2] for e in fr.events()] == [1]       # no wrap below capacity


def test_record_disabled_is_noop():
    fr = FlightRecorder(capacity=4)
    fr.enabled = False
    fr.record("k", 1)
    assert fr.events() == [] and fr.recorded == 0


# ------------------------------------------------------------ dump codec
def test_dump_bytes_roundtrip():
    fr = FlightRecorder(capacity=8)
    fr.record("rec.window", 100, 64)
    fr.record("io.demand", 7, 2, 1.5)
    payload = decode_dump(fr.dump_bytes("unit_test"))
    assert payload["reason"] == "unit_test"
    assert payload["version"] == 1
    assert payload["recorded"] == 2 and payload["dropped"] == 0
    kinds = [e[1] for e in payload["events"]]
    assert kinds == ["rec.window", "io.demand"]
    assert isinstance(payload["snapshot"], dict)
    assert isinstance(payload["baseline"], dict)


def test_torn_dump_raises_loudly():
    fr = FlightRecorder(capacity=8)
    fr.record("rec.window", 1)
    blob = fr.dump_bytes("torn")
    decode_dump(blob)                                # sanity: intact decodes
    with pytest.raises(CorruptSegmentError):
        decode_dump(blob[:-5])                       # truncated body
    with pytest.raises(CorruptSegmentError):
        decode_dump(blob + b"xx")                    # trailing garbage
    flipped = bytearray(blob)
    flipped[-3] ^= 0xFF
    with pytest.raises(CorruptSegmentError):
        decode_dump(bytes(flipped))                  # CRC mismatch
    with pytest.raises(CorruptSegmentError):
        decode_dump(b"NOPE" + blob[4:])              # wrong magic


def test_dump_to_directory_and_backend_sink(tmp_path):
    fr = FlightRecorder(capacity=8, sink=tmp_path / "bb")
    fr.record("k", 1)
    path = fr.dump("reason one")                     # spaces sanitized
    assert path is not None and Path(path).exists()
    assert "reason_one" in path and path.endswith(".rbbx")
    assert decode_dump(Path(path).read_bytes())["reason"] == "reason one"
    backend = DirectoryBackend(tmp_path / "media")
    fr.configure(sink=backend)
    key = fr.dump("via_backend")
    assert key is not None
    assert decode_dump(backend.get(key))["reason"] == "via_backend"
    fr.configure(sink=None)
    assert fr.dump("no_sink") is None


# ------------------------------------------------------- crash forensics
def test_database_crash_dumps_black_box(tmp_path):
    rng = random.Random(5)
    db, rows, base = make_primary(rng)
    drive(db, rng, 10)
    obs.FLIGHT.configure(sink=tmp_path / "bb")
    db.crash()
    path = obs.FLIGHT.last_dump
    assert path is not None
    payload = decode_dump(Path(path).read_bytes())
    assert payload["reason"] == "db.crash"
    assert payload["events"][-1][1] == "db.crash"


def test_mid_redo_crash_dump_names_redo_window(tmp_path):
    path = _failed_recovery_dump(tmp_path / "bb")
    payload = decode_dump(Path(path).read_bytes())
    assert payload["reason"] == "recover.failed"
    kinds = [e[1] for e in payload["events"]]
    assert "rec.analysis" in kinds and "rec.window" in kinds
    phase = obs.interrupted_phase(payload["events"])
    assert phase is not None and "redo window" in phase
    report = obs.render_postmortem(payload)
    assert "recover.failed" in report and "redo window" in report


def test_flight_tail_matches_tracer_record():
    """The always-on ring and the opt-in tracer see the same run: one
    rec.window flight event per redo.window tracer span."""
    image, base = _crash_image(seed=7)
    obs.reset()
    obs.enable()
    db, _ = recover(image, Strategy.LOG1, batched=True, batch_window=64)
    obs.disable()
    assert recovered_state(db) == committed_state_oracle(image, base)
    n_tracer = sum(1 for e in obs.TRACER.events
                   if e["type"] == "begin" and e["name"] == "redo.window")
    n_flight = sum(1 for e in obs.FLIGHT.events() if e[1] == "rec.window")
    assert n_tracer == n_flight > 0


def test_mid_shard_apply_crash_dump(tmp_path):
    rng = random.Random(11)
    primary, rows, base = make_primary(rng)
    drive(primary, rng, 30, abort_frac=0.0)
    rep = ShardedApplier("s1", n_shards=4, epoch_txns=8, page_size=4096,
                         cache_pages=512, tracker_interval=25,
                         bg_flush_per_txn=2, seed_tables={"t": rows})
    shipper = LogShipper(primary)
    shipper.subscribe("s1")
    obs.FLIGHT.configure(sink=tmp_path / "bb")

    def boom(txn, ops):
        raise RuntimeError("injected shard fault")

    rep.db.tc.apply_shipped_batch = boom
    with pytest.raises(RuntimeError, match="injected shard fault"):
        rep.apply_batch(shipper.poll("s1"))
        rep.pump()
    path = obs.FLIGHT.last_dump
    assert path is not None
    payload = decode_dump(Path(path).read_bytes())
    assert payload["reason"] == "shard.apply_failed"
    assert payload["events"][-1][1] == "shard.apply"
    phase = obs.interrupted_phase(payload["events"])
    assert phase is not None and "apply epoch" in phase


def test_postmortem_from_dump_file_alone(tmp_path):
    """The acceptance bar: a fresh process, given nothing but the dump
    file, renders a post-mortem naming the interrupted phase."""
    path = _failed_recovery_dump(tmp_path / "bb")
    script = ("from repro.obs import load_dump, render_postmortem\n"
              f"print(render_postmortem(load_dump({path!r})))\n")
    env = dict(os.environ, PYTHONPATH=str(REPO / "src"))
    env.pop("REPRO_BLACKBOX_DIR", None)
    proc = subprocess.run([sys.executable, "-c", script], cwd=REPO,
                          capture_output=True, text=True, env=env)
    assert proc.returncode == 0, proc.stderr
    assert "interrupted during" in proc.stdout
    assert "redo window" in proc.stdout
    assert "recover.failed" in proc.stdout


# -------------------------------------------------- commit-to-visible
def test_commit_to_visible_histograms_serial():
    rng = random.Random(21)
    primary, rows, base = make_primary(rng)
    rep = Replica("r1", page_size=4096, cache_pages=512,
                  tracker_interval=25, bg_flush_per_txn=2,
                  seed_tables={"t": rows})
    shipper = LogShipper(primary)
    shipper.subscribe("r1")
    drive(primary, rng, 20, abort_frac=0.0)
    primary.log.flush()
    batch = shipper.poll("r1")
    assert batch.stamps, "shipper should carry commit stamps"
    rep.apply_batch(batch)
    s = obs.value("repl.commit_to_visible_ms", replica="r1")
    assert s["count"] >= len(batch.stamps) > 0
    assert s["min"] >= 0.0 and s["p99"] >= s["p50"] >= 0.0
    for stage in ("repl.c2v.ship_wait_ms", "repl.c2v.queue_wait_ms",
                  "repl.c2v.apply_ms"):
        assert obs.value(stage, replica="r1")["count"] > 0


def test_commit_to_visible_histograms_sharded():
    rng = random.Random(22)
    primary, rows, base = make_primary(rng)
    rep = ShardedApplier("s9", n_shards=4, epoch_txns=4, page_size=4096,
                         cache_pages=512, tracker_interval=25,
                         bg_flush_per_txn=2, seed_tables={"t": rows})
    shipper = LogShipper(primary)
    shipper.subscribe("s9")
    drive(primary, rng, 30, abort_frac=0.0)
    primary.log.flush()
    batch = shipper.poll("s9")
    assert batch.stamps
    rep.apply_batch(batch)
    rep.pump()
    snap = obs.snapshot("repl.commit_to_visible_ms")
    sharded = {k: v for k, v in snap.items()
               if "replica=s9" in k and "shard=" in k}
    assert sum(v["count"] for v in sharded.values()) >= len(batch.stamps)


def test_commit_stamps_bounded_and_survive_crash():
    from repro.core.log import _MAX_COMMIT_STAMPS, LogManager
    from repro.core.records import CommitRec
    log = LogManager()
    for _ in range(_MAX_COMMIT_STAMPS + 50):
        log.append(CommitRec(txn=1))
    log.flush()
    assert len(log.commit_stamps) == _MAX_COMMIT_STAMPS
    # FIFO eviction: the newest commits keep their stamps
    assert log.last_commit_lsn in log.commit_stamps
    survivor = log.crash()
    assert survivor.commit_stamps == log.commit_stamps


# ------------------------------------------------------------- progress
def test_recover_progress_observer_and_gauges():
    image, base = _crash_image(seed=31)
    out = io.StringIO()
    po = ProgressObserver("recover", out=out)
    db, _ = recover(image, Strategy.LOG1, batched=True, batch_window=64,
                    progress=po)
    assert recovered_state(db) == committed_state_oracle(image, base)
    assert po.fraction == 1.0
    assert obs.value("recovery.progress") == 1.0
    assert obs.value("recovery.eta_ms") == 0
    text = out.getvalue()
    assert "recover" in text and "100.0%" in text


def test_cold_restore_progress(tmp_path):
    rng = random.Random(33)
    db, rows, base = make_primary(rng)
    backend = DirectoryBackend(tmp_path / "cold")
    store = SnapshotStore()
    arch = Archiver(db, archive=LogArchive(segment_records=64,
                                           backend=backend),
                    snapshots=store)
    drive(db, rng, 20)
    store.take(db, chunk_keys=64)
    drive(db, rng, 20)
    arch.run_once()
    sealed = arch.archive.archived_upto
    oracle = committed_state_oracle(db.crash(), base, upto_lsn=sealed)
    po = ProgressObserver("restore", out=io.StringIO())
    restored, stats = cold_restore(backend, progress=po)
    assert dict(restored.scan_all()) == oracle
    assert po.fraction == 1.0
    assert obs.value("recovery.progress") == 1.0


def test_progress_line_shape():
    po = ProgressObserver("recover", out=io.StringIO())
    po.begin(200)
    po.update(50, records=50)
    line = po.line()
    assert "recover" in line and "25.0%" in line
    po.finish()
    assert po.fraction == 1.0 and "100.0%" in po.line()


# --------------------------------------------------------------- export
def test_prometheus_text_and_sampler(tmp_path):
    obs.REGISTRY.reset("xp")
    obs.counter("xp.hits", backend="mem").inc(3)
    h = obs.histogram("xp.lat_ms")
    for v in (1.0, 2.0, 3.0):
        h.observe(v)
    text = obs.prometheus_text()
    assert "# TYPE xp_hits counter" in text
    assert 'xp_hits{backend="mem"} 3' in text
    assert "# TYPE xp_lat_ms summary" in text
    assert 'xp_lat_ms{quantile="0.5"} 2' in text
    assert "xp_lat_ms_count 3" in text
    path = tmp_path / "ts.jsonl"
    with obs.Sampler(path, period_ms=0.0, prefix="xp") as sampler:
        assert sampler.tick(note="first")
        assert sampler.tick(force=True, note="second")
    lines = [json.loads(ln) for ln in path.read_text().splitlines()]
    assert [ln["note"] for ln in lines] == ["first", "second"]
    assert lines[0]["metrics"]["xp.hits{backend=mem}"] == 3
    assert lines[1]["metrics"]["xp.lat_ms"]["count"] == 3
