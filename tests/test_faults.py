"""Faults package: deterministic injection, retry classification, and the
degraded modes the retry layer buys — archiver outages, flaky shipping,
flush failures that must not take the pool down.
"""
import random

import pytest

from repro.archive import Archiver, LogArchive, SnapshotStore
from repro.core import Database, committed_state_oracle, make_key
from repro.faults import (ALL_KINDS, KIND_CRASH, KIND_LATENCY, KIND_LOST,
                          KIND_TORN_CRASH, KIND_UNAVAILABLE, FaultPlan,
                          FaultSpec, FaultyBackend, InjectedCrash,
                          RetryPolicy, SplitMix64, make_faulty)
from repro.media import (BackendMissingError, BackendUnavailableError,
                         CorruptSegmentError, MemoryBackend)
from repro.replication import LogShipper, Replica

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:                               # image has no hypothesis
    HAVE_HYPOTHESIS = False


def _drive(backend, ops=40):
    """A fixed op script; returns the injected trace."""
    for i in range(ops):
        name = f"blob/{i % 7}"
        try:
            if i % 3 == 0:
                backend.put(name, bytes([i % 251]) * 32)
            elif i % 3 == 1:
                try:
                    backend.get(name)
                except BackendMissingError:
                    pass
            else:
                backend.list("blob/")
        except (BackendUnavailableError, InjectedCrash):
            pass
    return list(backend.plan.injected)


# ------------------------------------------------------------ determinism
def test_same_seed_same_campaign():
    for seed in (0, 1, 7, 12345, 2**63):
        p1, p2 = FaultPlan.generate(seed), FaultPlan.generate(seed)
        assert p1.faults == p2.faults
        t1 = _drive(FaultyBackend(MemoryBackend(), p1))
        t2 = _drive(FaultyBackend(MemoryBackend(), p2))
        assert t1 == t2
    assert FaultPlan.generate(1).faults != FaultPlan.generate(2).faults


if HAVE_HYPOTHESIS:
    @settings(max_examples=30, deadline=None)
    @given(st.integers(min_value=0, max_value=2**64 - 1))
    def test_seed_fully_determines_injection(seed):
        t1 = _drive(FaultyBackend(MemoryBackend(), FaultPlan.generate(seed)))
        t2 = _drive(FaultyBackend(MemoryBackend(), FaultPlan.generate(seed)))
        assert t1 == t2


def test_splitmix_is_stable():
    rng = SplitMix64(42)
    first = [rng.next_u64() for _ in range(4)]
    assert first == [SplitMix64(42).next_u64() if i == 0 else v
                     for i, v in enumerate(first)]   # re-seed reproduces
    assert all(0.0 <= SplitMix64(s).uniform() < 1.0 for s in range(50))


# ------------------------------------------------------------- fault kinds
def test_unavailable_then_retry_succeeds():
    fb = make_faulty(MemoryBackend(),
                     FaultSpec(op="put", kind=KIND_UNAVAILABLE, at=1,
                               count=2))
    retry = RetryPolicy(max_attempts=4)
    retry.call(fb.put, "a", b"x")                  # two failures absorbed
    assert fb.inner.get("a") == b"x"
    assert retry.retries == 2 and retry.slept_ms > 0


def test_latency_charges_clock():
    class Clock:
        ms = 0.0

        def work(self, ms):
            self.ms += ms

    clock = Clock()
    fb = make_faulty(MemoryBackend(),
                     FaultSpec(op="get", kind=KIND_LATENCY, at=1,
                               latency_ms=7.5),
                     clock=clock)
    fb.put("a", b"x")
    assert fb.get("a") == b"x"
    assert clock.ms == 7.5 and fb.injected_latency_ms == 7.5


def test_torn_crash_persists_prefix_then_disarms():
    fb = make_faulty(MemoryBackend(),
                     FaultSpec(op="put", kind=KIND_TORN_CRASH, at=2,
                               torn_frac=0.25))
    fb.put("a", b"A" * 100)
    with pytest.raises(InjectedCrash):
        fb.put("b", b"B" * 100)
    assert fb.inner.get("b") == b"B" * 25          # the torn prefix landed
    assert fb.plan.crashed
    fb.put("c", b"C")                              # disarmed: clean again
    assert fb.get("c") == b"C"


def test_injected_crash_evades_broad_handlers():
    fb = make_faulty(MemoryBackend(),
                     FaultSpec(op="put", kind=KIND_CRASH, at=1))
    with pytest.raises(InjectedCrash):
        try:
            fb.put("a", b"x")
        except Exception:                          # cleanup-style handler
            pytest.fail("InjectedCrash must not be an Exception")
    assert not isinstance(InjectedCrash("put", "a", 1), Exception)


def test_lost_blob_stays_lost_until_rewritten():
    fb = make_faulty(MemoryBackend(),
                     FaultSpec(op="put", kind=KIND_LOST, at=2))
    fb.put("a", b"v1")
    fb.put("a", b"v2")                             # this write is lost
    with pytest.raises(BackendMissingError):
        fb.get("a")
    assert not fb.exists("a")                      # definite absence
    fb.put("a", b"v3")                             # resurrection
    assert fb.get("a") == b"v3"


def test_all_kinds_have_distinct_codes():
    from repro.faults import KIND_CODE
    assert sorted(KIND_CODE.values()) == list(range(1, len(ALL_KINDS) + 1))


# -------------------------------------------------------- classification
def test_exists_maps_only_definite_absence():
    be = MemoryBackend()
    assert be.exists("nope") is False
    fb = make_faulty(MemoryBackend(),
                     FaultSpec(op="get_head", kind=KIND_UNAVAILABLE, at=1))
    fb.put("a", b"x")
    with pytest.raises(BackendUnavailableError):
        fb.exists("a")          # an outage is NOT "absent" — it propagates


def test_retry_never_touches_corruption():
    retry = RetryPolicy(max_attempts=5)

    def corrupt():
        raise CorruptSegmentError("CRC mismatch")

    with pytest.raises(CorruptSegmentError):
        retry.call(corrupt)
    assert retry.retries == 0                      # first throw, no retry


def test_retry_is_bounded_and_deterministic():
    calls = {"n": 0}

    def always_down():
        calls["n"] += 1
        raise BackendUnavailableError("down")

    retry = RetryPolicy(max_attempts=3, seed=9)
    with pytest.raises(BackendUnavailableError):
        retry.call(always_down)
    assert calls["n"] == 3 and retry.exhausted == 1
    # same (seed, attempt) -> same schedule; delays stay capped
    a = [RetryPolicy(seed=5).delay_ms(i) for i in range(1, 8)]
    b = [RetryPolicy(seed=5).delay_ms(i) for i in range(1, 8)]
    assert a == b
    assert all(d <= 250.0 * 1.25 for d in a)


# ------------------------------------------------------- degraded: archiver
def _primary(n_txns=30):
    rng = random.Random(7)
    db = Database(page_size=2048, cache_pages=256)
    rows = [(f"k{i:03d}".encode(), bytes([i % 251]) * 16) for i in range(40)]
    db.load_table("t", rows)
    base = {make_key("t", k): v for k, v in rows}
    for _ in range(n_txns):
        k = rows[rng.randrange(len(rows))][0]
        db.run_txn([("update", "t", k, bytes([rng.randrange(251)]) * 12)])
    return db, base


def test_archiver_outage_degrades_then_seals_backlog():
    db, base = _primary()
    fb = FaultyBackend(MemoryBackend())
    snaps = SnapshotStore()
    arch = Archiver(db, archive=LogArchive(segment_records=16, backend=fb),
                    snapshots=snaps, retry=RetryPolicy(max_attempts=2))
    snaps.take(db)
    # outage begins after the snapshot landed: every put now fails
    fb.plan = FaultPlan(faults=(
        FaultSpec(op="put", kind=KIND_UNAVAILABLE, at=1, count=1000),))
    r1 = arch.run_once()
    assert r1["ok"] is False and r1["truncated"] == 0
    assert arch.consecutive_failures == 1
    r2 = arch.run_once()
    assert r2["ok"] is False and arch.consecutive_failures == 2
    assert arch.archive.archived_upto == 0         # nothing claimed durable
    fb.plan.disarm()                               # outage ends
    r3 = arch.run_once()
    assert r3["ok"] is True and arch.consecutive_failures == 0
    assert r3["sealed"] > 0                        # whole backlog sealed
    assert arch.archive.archived_upto >= db.log.stable_lsn - 2
    assert fb.inner.list("seg/")                   # segments really landed


def test_prune_survives_transient_outage():
    db, _ = _primary()
    fb = FaultyBackend(MemoryBackend())
    snaps = SnapshotStore()
    arch = Archiver(db, archive=LogArchive(segment_records=16, backend=fb),
                    snapshots=snaps, retry=RetryPolicy(max_attempts=3))
    snaps.take(db)
    arch.run_once()
    for _ in range(10):
        db.run_txn([("update", "t", b"k001", b"zz")])
    snaps.take(db)
    arch.run_once()
    fb.plan = FaultPlan(faults=(
        FaultSpec(op="delete", kind=KIND_UNAVAILABLE, at=1),))
    out = arch.prune(keep_snapshots=1)             # one flaky delete absorbed
    assert out["snapshots_dropped"] == 1


# ----------------------------------------------- degraded: shipping/replica
def _sealed_primary_with_faulty_segments():
    """Primary whose sealed prefix lives on a FaultyBackend and whose
    in-memory log is truncated — shipping from LSN 1 must read segments.
    All state is *logged* (inserts, no bulk load) so a fresh replica can
    converge to the full oracle from the archive alone."""
    rng = random.Random(7)
    db = Database(page_size=2048, cache_pages=256)
    db.load_table("t", [])
    rows = [(f"k{i:03d}".encode(), bytes([i % 251]) * 16) for i in range(40)]
    for i in range(0, 40, 10):
        db.run_txn([("insert", "t", k, v) for k, v in rows[i:i + 10]])
    for _ in range(30):
        k = rows[rng.randrange(len(rows))][0]
        db.run_txn([("update", "t", k, bytes([rng.randrange(251)]) * 12)])
    fb = FaultyBackend(MemoryBackend())
    snaps = SnapshotStore()
    arch = Archiver(db, archive=LogArchive(segment_records=16, backend=fb),
                    snapshots=snaps, retry=RetryPolicy(max_attempts=3))
    snaps.take(db)
    arch.run_once()
    assert db.log._base > 0                        # splice reads are real
    return db, {}, fb


def test_shipper_poll_retries_transient_segment_reads():
    db, base, fb = _sealed_primary_with_faulty_segments()
    # without a policy a segment-read outage is loud at the caller
    # (this must run first: a successful read caches the segment decode)
    shipper2 = LogShipper(db.log)
    shipper2.subscribe("r2", db.log.retained_lsn)
    fb.plan = FaultPlan(faults=(
        FaultSpec(op="get", kind=KIND_UNAVAILABLE, at=1, count=2),))
    with pytest.raises(BackendUnavailableError):
        shipper2.poll("r2")
    # with a policy the same outage is absorbed
    shipper = LogShipper(db.log, retry=RetryPolicy(max_attempts=3))
    shipper.subscribe("r", db.log.retained_lsn)
    fb.plan = FaultPlan(faults=(
        FaultSpec(op="get", kind=KIND_UNAVAILABLE, at=1, count=2),))
    batch = shipper.poll("r")                      # both blips absorbed
    assert batch.records


def test_replica_catch_up_converges_through_outages():
    db, base, fb = _sealed_primary_with_faulty_segments()
    shipper = LogShipper(db.log, batch_records=8)
    rep = Replica("r", page_size=4096, cache_pages=128)
    rep.resubscribe(shipper)
    # recurring single-op outages spread over the catch-up; spaced so
    # consecutive failed polls stay under the retry budget (a failed
    # segment read is not cached, so a retried poll re-reads it at the
    # next op index — adjacent windows would chain failures)
    fb.plan = FaultPlan(faults=tuple(
        FaultSpec(op="get", kind=KIND_UNAVAILABLE, at=a)
        for a in (1, 4, 9, 14, 21)))
    rep.catch_up(shipper, retry=RetryPolicy(max_attempts=4))
    fb.plan.disarm()
    assert rep.user_state() == committed_state_oracle(db.crash(), base)


def test_replica_catch_up_bounded_on_permanent_outage():
    db, base, fb = _sealed_primary_with_faulty_segments()
    shipper = LogShipper(db.log, batch_records=8)
    rep = Replica("r", cache_pages=128)
    rep.resubscribe(shipper)
    fb.plan = FaultPlan(faults=(
        FaultSpec(op="get", kind=KIND_UNAVAILABLE, at=1, count=10_000),))
    retry = RetryPolicy(max_attempts=3)
    with pytest.raises(BackendUnavailableError):
        rep.catch_up(shipper, retry=retry)
    assert retry.retries <= retry.max_attempts     # bounded, not a spin


# --------------------------------------------------- degraded: buffer pool
def test_flush_failure_keeps_page_dirty_and_readable():
    fb = FaultyBackend(MemoryBackend())
    db = Database(page_size=1024, cache_pages=64, page_backend=fb,
                  media_retry=RetryPolicy(max_attempts=2))
    rows = [(f"k{i:03d}".encode(), bytes([i % 251]) * 24) for i in range(60)]
    db.load_table("t", rows)
    db.run_txn([("update", "t", b"k001", b"new")])
    pool = db.dc.pool
    dirty = pool.dirty_pids()
    assert dirty
    fb.plan = FaultPlan(faults=(
        FaultSpec(op="put", kind=KIND_UNAVAILABLE, at=1, count=1000),))
    with pytest.raises(BackendUnavailableError):
        pool.flush_page(dirty[0])
    assert pool.flush_failures > 0
    assert dirty[0] in pool.dirty_pids()           # nothing lost, still dirty
    assert db.dc.read("t", b"k001") == b"new"      # and still serving reads
    # background flushing degrades per-page instead of raising
    assert pool.flush_some(4) == 0
    fb.plan.disarm()
    assert pool.flush_some(64) > 0                 # outage over: drains
    assert dirty[0] not in pool.dirty_pids()


def test_eviction_raises_only_when_all_dirty_all_failing():
    fb = FaultyBackend(MemoryBackend())
    db = Database(page_size=1024, cache_pages=4, page_backend=fb,
                  media_retry=RetryPolicy(max_attempts=2))
    db.load_table("t", [(b"k0", b"v")])
    fb.plan = FaultPlan(faults=(
        FaultSpec(op="put", kind=KIND_UNAVAILABLE, at=1, count=10_000),))
    with pytest.raises(BackendUnavailableError):
        for i in range(400):                       # overflow the 4-frame pool
            db.run_txn([("insert", "t", f"x{i:04d}".encode(), b"y" * 64)])
    fb.plan.disarm()
    assert db.dc.read("t", b"x0000") == b"y" * 64  # pool survived the raise
