"""reprolint test suite.

Per rule: a violating fixture (the rule fires), a pragma'd fixture (the
same code with a reasoned pragma passes), and a clean fixture (idiomatic
code never fires).  Fixtures are mini-projects under tmp_path with the
real ``src/repro/...`` layout, because rules scope themselves by
directory.  Plus: pragma-grammar edge cases, cross-file codec parity,
CLI output shapes, and the meta-test that keeps the live tree clean.
"""
from __future__ import annotations

import json
import sys
import textwrap
from pathlib import Path

REPO = Path(__file__).resolve().parents[1]
if str(REPO) not in sys.path:
    sys.path.insert(0, str(REPO))

from tools.reprolint import run                       # noqa: E402
from tools.reprolint.__main__ import main as cli      # noqa: E402


# --------------------------------------------------------------- helpers
def lint(tmp_path, files):
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return run(tmp_path)


def fired(report, rule):
    return [v for v in report.violations if v.rule == rule]


def suppressed(report, rule):
    return [v for v in report.suppressed if v.rule == rule]


# ======================================================== loud-corruption
def test_loud_corruption_swallow_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/launch/x.py": """\
        def f(g):
            try:
                g()
            except Exception:
                pass
        """})
    assert len(fired(r, "loud-corruption")) == 1


def test_loud_corruption_reraise_outside_engine_is_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/launch/x.py": """\
        def f(g, cleanup):
            try:
                g()
            except Exception:
                cleanup()
                raise
        """})
    assert r.ok


def test_loud_corruption_engine_broad_fires_even_with_reraise(tmp_path):
    r = lint(tmp_path, {"src/repro/core/x.py": """\
        def f(g, cleanup):
            try:
                g()
            except Exception:
                cleanup()
                raise
        """})
    assert len(fired(r, "loud-corruption")) == 1


def test_loud_corruption_corruption_error_swallow_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/launch/x.py": """\
        def f(g):
            try:
                return g()
            except CorruptSegmentError:
                return None
        """})
    v = fired(r, "loud-corruption")
    assert len(v) == 1 and "CorruptSegmentError" in v[0].message


def test_loud_corruption_engine_base_catch_fires(tmp_path):
    # TruncatedLogError is a LookupError: catching the base inside the
    # engine swallows corruption just as surely as naming it
    r = lint(tmp_path, {"src/repro/replication/x.py": """\
        def f(g):
            try:
                return g()
            except LookupError:
                return None
        """})
    assert len(fired(r, "loud-corruption")) == 1


def test_loud_corruption_pragma_suppresses(tmp_path):
    r = lint(tmp_path, {"src/repro/core/x.py": """\
        def f(g, cleanup):
            try:
                g()
            # reprolint: allow(loud-corruption) — cleanup then unconditional re-raise
            except Exception:
                cleanup()
                raise
        """})
    assert r.ok
    assert len(suppressed(r, "loud-corruption")) == 1
    assert "re-raise" in suppressed(r, "loud-corruption")[0].reason


# ========================================================= wal-discipline
def test_wal_unclamped_put_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/w.py": """\
        class Store:
            def save(self):
                self.backend.put("x", b"1")
        """})
    v = fired(r, "wal-discipline")
    assert len(v) == 1 and "Store.save" in v[0].message


def test_wal_clamp_in_body_is_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/core/w.py": """\
        class Store:
            def save(self):
                cut = self.log.stable_lsn
                self.backend.put("x", bytes(cut))
        """})
    assert r.ok


def test_wal_clamp_in_every_caller_is_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/core/w.py": """\
        class Store:
            def seal(self):
                cut = self.log.stable_lsn
                self._save(cut)

            def _save(self, cut):
                self.backend.put("x", bytes(cut))
        """})
    assert r.ok


def test_wal_one_unclamped_caller_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/w.py": """\
        class Store:
            def seal(self):
                cut = self.log.stable_lsn
                self._save(cut)

            def prune(self):
                self._save(0)

            def _save(self, cut):
                self.backend.put("x", bytes(cut))
        """})
    assert len(fired(r, "wal-discipline")) == 1


def test_wal_pragma_suppresses(tmp_path):
    r = lint(tmp_path, {"src/repro/core/w.py": """\
        class Store:
            def save(self):
                # reprolint: allow(wal-discipline) — master pointer, outside WAL ordering
                self.backend.put("x", b"1")
        """})
    assert r.ok and len(suppressed(r, "wal-discipline")) == 1


def test_wal_non_backend_put_ignored(tmp_path):
    r = lint(tmp_path, {"src/repro/core/w.py": """\
        def ins(btree, k, v):
            btree.put(k, v)
        """})
    assert r.ok


# ========================================================== sorted-stream
def test_sorted_stream_unsorted_dc_apply_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/replication/s.py": """\
        def apply(dc, recs):
            dc.apply_batch(recs)
        """})
    assert len(fired(r, "sorted-stream")) == 1


def test_sorted_stream_shipped_batch_fires_any_receiver(tmp_path):
    r = lint(tmp_path, {"src/repro/archive/s.py": """\
        def ship(tc, txn, ops):
            tc.apply_shipped_batch(txn, ops)
        """})
    assert len(fired(r, "sorted-stream")) == 1


def test_sorted_stream_dominating_sort_is_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/replication/s.py": """\
        def apply(dc, recs):
            rs = sorted(recs, key=lambda r: r.lsn)
            dc.apply_batch(rs)
        """})
    assert r.ok


def test_sorted_stream_non_dc_apply_batch_ignored(tmp_path):
    # Replica.apply_batch is ship-batch ingest with no ordering
    # precondition — only the DC engine receiver is gated
    r = lint(tmp_path, {"src/repro/replication/s.py": """\
        def ingest(replica, batch):
            replica.apply_batch(batch)
        """})
    assert r.ok


def test_sorted_stream_pragma_suppresses(tmp_path):
    r = lint(tmp_path, {"src/repro/core/s.py": """\
        def redo(dc, window):
            # reprolint: allow(sorted-stream) — forward log scan, LSN-ordered by construction
            dc.apply_batch(window)
        """})
    assert r.ok and len(suppressed(r, "sorted-stream")) == 1


# =========================================================== tracer-guard
def test_tracer_unguarded_kwargs_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/t.py": """\
        def probe(pid):
            TRACER.event("io.demand", pid=pid)
        """})
    assert len(fired(r, "tracer-guard")) == 1


def test_tracer_guarded_is_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/core/t.py": """\
        def probe(pid):
            if TRACER.enabled:
                TRACER.event("io.demand", pid=pid)
        """})
    assert r.ok


def test_tracer_no_kwargs_is_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/core/t.py": """\
        def probe():
            TRACER.event("redo.start")
        """})
    assert r.ok


def test_tracer_pragma_suppresses(tmp_path):
    r = lint(tmp_path, {"src/repro/core/t.py": """\
        def probe(pid):
            # reprolint: allow(tracer-guard) — cold path, runs once per restore
            TRACER.event("restore.begin", pid=pid)
        """})
    assert r.ok and len(suppressed(r, "tracer-guard")) == 1


def test_flight_record_kwargs_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/t.py": """\
        def probe(pid):
            FLIGHT.record("io.demand", a=pid)
        """})
    v = fired(r, "tracer-guard")
    assert len(v) == 1 and "keywords" in v[0].message


def test_flight_record_fstring_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/t.py": """\
        def probe(pid):
            _FLIGHT.record(f"io.demand.{pid}", 1)
        """})
    v = fired(r, "tracer-guard")
    assert len(v) == 1 and "f-string" in v[0].message


def test_flight_record_dict_arg_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/t.py": """\
        def probe(pid):
            FLIGHT.record("io.demand", len({"pid": pid}))
        """})
    assert len(fired(r, "tracer-guard")) == 1


def test_flight_record_pragma_suppresses(tmp_path):
    r = lint(tmp_path, {"src/repro/core/t.py": """\
        def probe(pid):
            # reprolint: allow(tracer-guard) — cold path, once per dump
            FLIGHT.record("dump.meta", a=pid)
        """})
    assert r.ok and len(suppressed(r, "tracer-guard")) == 1


def test_flight_record_compact_positional_is_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/core/t.py": """\
        def probe(pid, stall):
            FLIGHT.record("io.demand", pid, 2, stall)
        """})
    assert r.ok


# ============================================================ metric-name
def test_metric_bad_name_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/m.py": """\
        def init(metrics):
            metrics.counter("badname")
        """})
    v = fired(r, "metric-name")
    assert len(v) == 1 and "badname" in v[0].message


def test_metric_bad_label_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/m.py": """\
        def init(metrics):
            metrics.gauge("repl.lag", Shard=1)
        """})
    v = fired(r, "metric-name")
    assert len(v) == 1 and "Shard" in v[0].message


def test_metric_good_names_are_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/core/m.py": """\
        def init(metrics, kind):
            metrics.counter("media.put_blobs", backend=kind)
            metrics.histogram("redo.window_ops")
        """})
    assert r.ok


def test_metric_kind_conflict_across_files_fires(tmp_path):
    r = lint(tmp_path, {
        "src/repro/core/a.py": """\
            def init(metrics):
                metrics.counter("repl.lag")
            """,
        "src/repro/replication/b.py": """\
            def init(metrics):
                metrics.gauge("repl.lag")
            """})
    v = fired(r, "metric-name")
    assert len(v) == 1 and "one name, one kind" in v[0].message


def test_metric_well_known_wrong_kind_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/replication/m.py": """\
        def init(metrics):
            metrics.gauge("repl.commit_to_visible_ms")
        """})
    v = fired(r, "metric-name")
    assert len(v) == 1 and "documented as a histogram" in v[0].message


def test_metric_well_known_right_kind_is_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/replication/m.py": """\
        def init(metrics):
            metrics.histogram("repl.commit_to_visible_ms", replica="r1")
            metrics.gauge("recovery.progress")
            metrics.gauge("recovery.eta_ms")
        """})
    assert r.ok


def test_metric_pragma_suppresses(tmp_path):
    r = lint(tmp_path, {"src/repro/core/m.py": """\
        def init(metrics):
            # reprolint: allow(metric-name) — legacy dashboard name, renamed next major
            metrics.counter("legacyname")
        """})
    assert r.ok and len(suppressed(r, "metric-name")) == 1


# ============================================================ determinism
def test_determinism_random_import_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/d.py": "import random\n"})
    assert len(fired(r, "determinism")) == 1


def test_determinism_wall_clock_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/archive/d.py": """\
        import time

        def stamp():
            return time.time()
        """})
    assert len(fired(r, "determinism")) == 1


def test_determinism_perf_counter_is_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/core/d.py": """\
        import time

        def measure():
            return time.perf_counter()
        """})
    assert r.ok


def test_determinism_outside_engine_is_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/obs/d.py": """\
        import time

        def stamp():
            return time.time()
        """})
    assert r.ok


def test_determinism_pragma_suppresses(tmp_path):
    r = lint(tmp_path, {"src/repro/core/d.py": """\
        # reprolint: allow(determinism) — seeded below, test-only jitter hook
        import random
        """})
    assert r.ok and len(suppressed(r, "determinism")) == 1


# ====================================================== dataclass-hygiene
def test_hygiene_mutable_default_arg_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/h.py": """\
        def f(xs=[]):
            xs.append(1)
            return xs
        """})
    assert len(fired(r, "dataclass-hygiene")) == 1


def test_hygiene_memo_field_without_compare_false_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/h.py": """\
        from dataclasses import dataclass, field

        @dataclass
        class Rec:
            ck: bytes = field(default=None, repr=False)
        """})
    v = fired(r, "dataclass-hygiene")
    assert len(v) == 1 and "compare=False" in v[0].message


def test_hygiene_memo_field_with_compare_false_is_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/core/h.py": """\
        from dataclasses import dataclass, field

        @dataclass
        class Rec:
            ck: bytes = field(default=None, repr=False, compare=False)
        """})
    assert r.ok


def test_hygiene_mutable_field_default_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/h.py": """\
        from dataclasses import dataclass, field

        @dataclass
        class Rec:
            ops: list = field(default=[])
        """})
    v = fired(r, "dataclass-hygiene")
    assert len(v) == 1 and "default_factory" in v[0].message


def test_hygiene_pragma_suppresses(tmp_path):
    r = lint(tmp_path, {"src/repro/core/h.py": """\
        # reprolint: allow(dataclass-hygiene) — module-constant sentinel, never mutated
        def f(xs=[]):
            return xs
        """})
    assert r.ok and len(suppressed(r, "dataclass-hygiene")) == 1


# =================================================== codec-parity (cross)
RECORDS_OK = """\
    class RecKind:
        FOO = 1

    class LogRec:
        lsn: int

    class FooRec(LogRec):
        lsn: int
        a: int

    REC_CLASSES = {RecKind.FOO: FooRec}
    """

CODEC_OK = """\
    def encode_record(rec):
        if isinstance(rec, FooRec):
            return bytes([rec.lsn, rec.a])
        raise ValueError(rec)

    def decode_record(buf):
        return FooRec(lsn=buf[0], a=buf[1])
    """


def test_codec_parity_matched_pair_is_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/core/records.py": RECORDS_OK,
                        "src/repro/media/codec.py": CODEC_OK})
    assert r.ok


def test_codec_parity_unserialized_field_fires(tmp_path):
    records = RECORDS_OK.replace("a: int", "a: int\n        b: int")
    r = lint(tmp_path, {"src/repro/core/records.py": records,
                        "src/repro/media/codec.py": CODEC_OK})
    msgs = [v.message for v in fired(r, "codec-parity")]
    assert any("FooRec.b is never serialized" in m for m in msgs)
    assert any("FooRec.b is never reconstructed" in m for m in msgs)


def test_codec_parity_unmapped_kind_fires(tmp_path):
    records = RECORDS_OK.replace("FOO = 1", "FOO = 1\n        BAR = 2")
    r = lint(tmp_path, {"src/repro/core/records.py": records,
                        "src/repro/media/codec.py": CODEC_OK})
    v = fired(r, "codec-parity")
    assert len(v) == 1 and "RecKind.BAR has no REC_CLASSES entry" in v[0].message


def test_codec_parity_missing_encode_branch_fires(tmp_path):
    records = RECORDS_OK.replace(
        "REC_CLASSES = {RecKind.FOO: FooRec}",
        "class BarRec(LogRec):\n"
        "        lsn: int\n\n"
        "    REC_CLASSES = {RecKind.FOO: FooRec, RecKind.BAR: BarRec}"
    ).replace("FOO = 1", "FOO = 1\n        BAR = 2")
    r = lint(tmp_path, {"src/repro/core/records.py": records,
                        "src/repro/media/codec.py": CODEC_OK})
    msgs = [v.message for v in fired(r, "codec-parity")]
    assert any("no isinstance branch for BarRec" in m for m in msgs)


def test_codec_parity_compare_false_field_exempt(tmp_path):
    # derived memo fields are excluded from equality AND serialization
    records = RECORDS_OK.replace(
        "a: int",
        "a: int\n        ck: bytes = field(default=None, repr=False, "
        "compare=False)")
    r = lint(tmp_path, {"src/repro/core/records.py": records,
                        "src/repro/media/codec.py": CODEC_OK})
    assert not fired(r, "codec-parity")


def test_codec_parity_pragma_suppresses(tmp_path):
    records = RECORDS_OK.replace(
        "class FooRec(LogRec):",
        "# reprolint: allow(codec-parity) — volatile field, rebuilt on decode\n"
        "    class FooRec(LogRec):").replace(
        "a: int", "a: int\n        b: int")
    r = lint(tmp_path, {"src/repro/core/records.py": records,
                        "src/repro/media/codec.py": CODEC_OK})
    assert not fired(r, "codec-parity")
    assert len(suppressed(r, "codec-parity")) == 2


# ======================================================== pragma grammar
def test_pragma_without_reason_fires_and_does_not_suppress(tmp_path):
    r = lint(tmp_path, {"src/repro/core/p.py": """\
        def f(g):
            try:
                g()
            # reprolint: allow(loud-corruption)
            except Exception:
                raise
        """})
    assert len(fired(r, "pragma-reason")) == 1
    assert len(fired(r, "loud-corruption")) == 1      # NOT suppressed


def test_unparseable_pragma_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/p.py":
                        "# reprolint: disable(everything)\n"})
    v = fired(r, "pragma-reason")
    assert len(v) == 1 and "unparseable" in v[0].message


def test_pragma_same_line_suppresses(tmp_path):
    r = lint(tmp_path, {"src/repro/core/p.py": (
        "def f(xs=[]):  "
        "# reprolint: allow(dataclass-hygiene) — sentinel, never mutated\n"
        "    return xs\n")})
    assert r.ok and len(suppressed(r, "dataclass-hygiene")) == 1


def test_unused_pragma_is_reported_in_stats(tmp_path):
    r = lint(tmp_path, {"src/repro/core/p.py": """\
        # reprolint: allow(determinism) — nothing here violates it
        def f():
            return 1
        """})
    assert r.ok
    assert r.unused_pragmas == ["src/repro/core/p.py:1"]


def test_pragma_counted_in_stats(tmp_path):
    r = lint(tmp_path, {"src/repro/core/p.py": """\
        def f(g):
            try:
                g()
            # reprolint: allow(loud-corruption) — re-raises unconditionally
            except Exception:
                raise
        """})
    assert r.pragma_count == 1
    assert r.pragmas_by_rule == {"loud-corruption": 1}


def test_pragma_in_string_is_not_a_pragma(tmp_path):
    r = lint(tmp_path, {"src/repro/core/p.py":
                        's = "# reprolint: allow(x)"\n'})
    assert r.ok and r.pragma_count == 0


# ================================================== engine / CLI plumbing
def test_parse_error_is_a_violation(tmp_path):
    r = lint(tmp_path, {"src/repro/core/bad.py": "def f(:\n"})
    assert len(fired(r, "parse")) == 1


def test_selection_filters_reporting_not_analysis(tmp_path):
    files = {
        "src/repro/core/a.py": "import random\n",
        "src/repro/core/b.py": "import random\n",
    }
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(src)
    r = run(tmp_path, paths=["src/repro/core/a.py"])
    assert [v.path for v in r.violations] == ["src/repro/core/a.py"]
    assert r.checked_files == 2          # analysis still saw the tree


def test_cli_json_shape_and_exit_codes(tmp_path, capsys):
    p = tmp_path / "src/repro/core/x.py"
    p.parent.mkdir(parents=True)
    p.write_text("import random\n")
    rc = cli(["--root", str(tmp_path), "--json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 1 and out["ok"] is False
    assert out["violation_count"] == 1
    assert out["violations"][0]["rule"] == "determinism"
    assert set(out["stats"]) == {"pragma_count", "pragmas_by_rule",
                                 "unused_pragmas"}

    p.write_text("x = 1\n")
    rc = cli(["--root", str(tmp_path), "--json"])
    out = json.loads(capsys.readouterr().out)
    assert rc == 0 and out["ok"] is True


def test_cli_stats_reports_pragma_counts(tmp_path, capsys):
    p = tmp_path / "src/repro/core/x.py"
    p.parent.mkdir(parents=True)
    p.write_text("# reprolint: allow(determinism) — seeded elsewhere\n"
                 "import random\n")
    rc = cli(["--root", str(tmp_path), "--stats"])
    out = capsys.readouterr().out
    assert rc == 0
    assert "pragma allow(determinism): 1" in out


def test_cli_list_rules(capsys):
    assert cli(["--list-rules"]) == 0
    out = capsys.readouterr().out
    for rule in ("codec-parity", "loud-corruption", "wal-discipline",
                 "sorted-stream", "tracer-guard", "metric-name",
                 "determinism", "dataclass-hygiene", "packed-mutation",
                 "retry-discipline"):
        assert rule in out


# ======================================================== packed-mutation
def test_packed_mutation_subscript_store_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/m.py": """\
        def build(page, k, v):
            page.records[k] = v
        """})
    assert len(fired(r, "packed-mutation")) == 1


def test_packed_mutation_method_call_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/core/m.py": """\
        def push(node, sep, pid):
            node.keys.append(sep)
            node.children.append(pid)
        """})
    assert len(fired(r, "packed-mutation")) == 2


def test_packed_mutation_invalidate_same_receiver_is_clean(tmp_path):
    r = lint(tmp_path, {"src/repro/core/m.py": """\
        def push(node, sep, pid):
            node.keys.append(sep)
            node.children.append(pid)
            node.invalidate_sorted()
        """})
    assert r.ok


def test_packed_mutation_invalidate_other_receiver_still_fires(tmp_path):
    # invalidating a *different* page does not license this one's write
    r = lint(tmp_path, {"src/repro/core/m.py": """\
        def push(node, other, sep):
            node.keys.append(sep)
            other.invalidate_sorted()
        """})
    assert len(fired(r, "packed-mutation")) == 1


def test_packed_mutation_whole_container_assign_is_clean(tmp_path):
    # property setters invalidate internally — whole-container
    # assignment is the sanctioned bulk-replace path
    r = lint(tmp_path, {"src/repro/core/m.py": """\
        def rebuild(leaf, items):
            leaf.records = dict(items)
        """})
    assert r.ok


def test_packed_mutation_outside_core_ignored(tmp_path):
    r = lint(tmp_path, {"src/repro/media/m.py": """\
        def build(page, k, v):
            page.records[k] = v
        """})
    assert r.ok


def test_packed_mutation_pages_py_owner_exempt(tmp_path):
    r = lint(tmp_path, {"src/repro/core/pages.py": """\
        def put(self, k, v):
            self.records[k] = v
        """})
    assert r.ok


def test_packed_mutation_pragma_suppresses(tmp_path):
    r = lint(tmp_path, {"src/repro/core/m.py": """\
        def build(page, k, v):
            # reprolint: allow(packed-mutation) — freshly allocated page, nothing cached yet
            page.records[k] = v
        """})
    assert r.ok and len(suppressed(r, "packed-mutation")) == 1


# ======================================================= retry-discipline
def test_retry_mixed_handler_fires(tmp_path):
    # one handler treating "retry me" and "stop everything" alike
    r = lint(tmp_path, {"src/repro/launch/x.py": """\
        def f(g):
            try:
                return g()
            except (BackendUnavailableError, CorruptSegmentError):
                raise
        """})
    v = fired(r, "retry-discipline")
    assert len(v) == 1 and "CorruptSegmentError" in v[0].message


def test_retry_hand_rolled_while_loop_fires(tmp_path):
    r = lint(tmp_path, {"src/repro/launch/x.py": """\
        def f(g):
            while True:
                try:
                    return g()
                except BackendUnavailableError:
                    continue
        """})
    v = fired(r, "retry-discipline")
    assert len(v) == 1 and "RetryPolicy" in v[0].message


def test_retry_loop_with_policy_backoff_is_clean(tmp_path):
    # the replica.catch_up idiom: bounded by max_attempts, waits via the
    # policy's seeded backoff — sanctioned machinery, not hand-rolled
    r = lint(tmp_path, {"src/repro/launch/x.py": """\
        def f(g, retry):
            failures = 0
            while True:
                try:
                    return g()
                except BackendUnavailableError:
                    failures += 1
                    if failures >= retry.max_attempts:
                        raise
                    retry.backoff(failures)
        """})
    assert r.ok


def test_retry_for_loop_degradation_is_clean(tmp_path):
    # the background-flusher idiom: per-item degradation in a for loop
    # is bounded by construction
    r = lint(tmp_path, {"src/repro/launch/x.py": """\
        def f(items, g):
            done = 0
            for item in items:
                try:
                    g(item)
                except BackendUnavailableError:
                    continue
                done += 1
            return done
        """})
    assert r.ok


def test_retry_transient_alone_outside_loop_is_clean(tmp_path):
    # classifying a transient error once (degrade-and-report) is the
    # archiver idiom, not a retry loop
    r = lint(tmp_path, {"src/repro/launch/x.py": """\
        def f(g):
            try:
                return g()
            except BackendUnavailableError:
                return None
        """})
    assert r.ok


def test_retry_pragma_suppresses(tmp_path):
    r = lint(tmp_path, {"src/repro/launch/x.py": """\
        def f(g):
            while True:
                try:
                    return g()
                # reprolint: allow(retry-discipline) — bounded by caller's deadline
                except BackendUnavailableError:
                    continue
        """})
    assert r.ok and len(suppressed(r, "retry-discipline")) == 1


# ============================================================== meta-test
def test_live_tree_is_clean():
    """The repo's own tree has zero unsuppressed violations, every pragma
    carries a reason (reasonless ones fire pragma-reason above), and no
    pragma is stale."""
    report = run(REPO)
    assert report.ok, "\n".join(v.format() for v in report.violations)
    assert report.unused_pragmas == []
    assert report.pragma_count > 0       # the exemptions are real & counted
