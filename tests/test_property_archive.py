"""Hypothesis property test: ``restore(target_lsn)`` equals an oracle
replay of the committed prefix <= target, for random crash points,
snapshot cadences (including fuzzy scans with writers interleaved between
chunks), truncation points, and arbitrary restore targets.

Optional dependency: degrades to a skip when hypothesis is absent (seeded
subsets of the same scenario always run in test_archive.py).
"""
import random

import pytest

hyp = pytest.importorskip("hypothesis")
from hypothesis import HealthCheck, given, settings  # noqa: E402
from hypothesis import strategies as st  # noqa: E402

from repro.archive import Archiver, LogArchive, SnapshotStore  # noqa: E402
from repro.core import committed_state_oracle  # noqa: E402

from repl_workload import drive, make_primary  # noqa: E402

N_ROWS, VAL = 120, 16


def _restore_matches_oracle(seed, n_snapshots, snapshot_gap, chunk_keys,
                            truncate, crash, n_targets):
    rng = random.Random(seed)
    db, rows, base = make_primary(rng, n_rows=N_ROWS, val=VAL,
                                  page_size=4096)
    store = SnapshotStore()
    archiver = Archiver(db, archive=LogArchive(segment_records=32),
                        snapshots=store)
    drive(db, rng, 10, n_rows=N_ROWS, val=VAL)
    for _ in range(n_snapshots):
        store.take(db, chunk_keys=chunk_keys,
                   on_chunk=lambda: drive(db, rng, 2, n_rows=N_ROWS,
                                          val=VAL))
        drive(db, rng, snapshot_gap, n_rows=N_ROWS, val=VAL)
        if truncate:
            archiver.run_once()        # seal + truncate at the horizon

    if crash:
        # leave stable in-flight work behind, then take the crash image —
        # the unforced tail (if any) must not leak into any restore
        loser = db.tc.begin()
        db.tc.update(loser, "t", rows[0][0], b"LOSER")
        db.log.flush()
        source = db.crash()
    else:
        source = db

    hi = source.log.stable_lsn
    lo = source.log.retained_lsn
    targets = {hi, lo + (hi - lo) // 3, lo + 2 * (hi - lo) // 3}
    targets.update(rng.randrange(lo, hi + 1) for _ in range(n_targets))
    for target in sorted(targets):
        restored, stats = store.restore(target, source, base_rows=base)
        oracle = committed_state_oracle(source, base, upto_lsn=target)
        assert dict(restored.scan_all()) == oracle, (
            f"restore({target}) diverged (seed={seed}, "
            f"snapshot_id={stats.snapshot_id}, redo_from={stats.redo_from})")


@settings(max_examples=12, deadline=None,
          suppress_health_check=[HealthCheck.too_slow])
@given(seed=st.integers(0, 10_000),
       n_snapshots=st.integers(0, 3),
       snapshot_gap=st.integers(3, 25),
       chunk_keys=st.integers(8, 200),
       truncate=st.booleans(),
       crash=st.booleans())
def test_property_restore_equals_committed_prefix(seed, n_snapshots,
                                                  snapshot_gap, chunk_keys,
                                                  truncate, crash):
    _restore_matches_oracle(seed, n_snapshots, snapshot_gap, chunk_keys,
                            truncate, crash, n_targets=3)
