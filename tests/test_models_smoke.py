"""Per-architecture smoke tests: REDUCED configs (same family, tiny dims) run
one forward/train step + one decode step on CPU; assert shapes + finiteness.
The FULL configs are exercised only via the dry-run (ShapeDtypeStruct)."""
import jax
import jax.numpy as jnp
import pytest

from repro.configs import get_config, list_archs
from repro.models import build_model, make_batch

ARCHS = list_archs()


@pytest.fixture(scope="module")
def rng():
    return jax.random.PRNGKey(0)


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step_smoke(arch, rng):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(rng)
    batch = make_batch(cfg, batch=2, seq=32, rng=rng)
    loss, grads = jax.jit(jax.value_and_grad(api.loss))(params, batch)
    assert jnp.isfinite(loss), f"{arch}: non-finite loss {loss}"
    leaves = jax.tree.leaves(grads)
    assert leaves, f"{arch}: no grads"
    for g in leaves:
        assert jnp.all(jnp.isfinite(g.astype(jnp.float32))), \
            f"{arch}: non-finite grad"


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_then_decode_smoke(arch, rng):
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(rng)
    batch = make_batch(cfg, batch=2, seq=16, rng=rng)
    logits, cache = jax.jit(api.prefill)(params, batch)
    assert logits.shape == (2, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))
    next_tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    logits2, cache2 = jax.jit(api.decode)(params, cache, next_tok)
    assert logits2.shape == (2, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits2.astype(jnp.float32)))
    assert int(cache2["len"]) == int(cache["len"]) + 1


@pytest.mark.parametrize("arch", ARCHS)
def test_decode_from_empty_cache_smoke(arch, rng):
    """decode-only path used by the decode_* dry-run shapes."""
    cfg = get_config(arch).reduced()
    api = build_model(cfg)
    params = api.init(rng)
    cache = api.init_cache(2, 64)
    tok = jnp.zeros((2, 1), jnp.int32)
    logits, cache2 = jax.jit(api.decode)(params, cache, tok)
    assert logits.shape == (2, cfg.vocab_size)
    assert jnp.all(jnp.isfinite(logits.astype(jnp.float32)))


def test_prefill_decode_consistency_dense(rng):
    """Decode after prefill must equal the full-forward logits (teacher
    forcing): validates cache correctness for the dense family."""
    cfg = get_config("llama3.2-3b").reduced()
    api = build_model(cfg)
    params = api.init(rng)
    tokens = jax.random.randint(rng, (2, 12), 0, cfg.vocab_size, jnp.int32)

    from repro.models import lm
    full_logits, _ = jax.jit(
        lambda p, t: lm.forward(p, t, cfg))(params, tokens)

    # prefill on first 11 tokens; decode the 12th and compare its logits
    logits_p, cache = jax.jit(api.prefill)(params, {"tokens": tokens[:, :11]})
    assert jnp.allclose(logits_p, full_logits[:, 10, :], atol=2e-2), \
        "prefill last-token logits diverge from full forward"
    logits_d, _ = jax.jit(api.decode)(params, cache, tokens[:, 11:12])
    assert jnp.allclose(logits_d, full_logits[:, 11, :], atol=2e-2), \
        "decode logits diverge from full forward"


def test_prefill_decode_consistency_rwkv(rng):
    cfg = get_config("rwkv6-3b").reduced()
    api = build_model(cfg)
    params = api.init(rng)
    tokens = jax.random.randint(rng, (2, 12), 0, cfg.vocab_size, jnp.int32)
    from repro.models import rwkv6
    full_logits, _ = jax.jit(lambda p, t: rwkv6.forward(p, t, cfg))(params, tokens)
    _, cache = jax.jit(api.prefill)(params, {"tokens": tokens[:, :11]})
    logits_d, _ = jax.jit(api.decode)(params, cache, tokens[:, 11:12])
    assert jnp.allclose(logits_d, full_logits[:, 11, :], atol=2e-2)
