"""Distribution layer: sharding rules + lowering specs on a small host mesh.

Runs in a subprocess with 8 forced host devices so the main test process
keeps its single-device view (dryrun.py's 512-device trick, miniaturized).
"""
import json
import os
import subprocess
import sys
import textwrap

import pytest

SCRIPT = textwrap.dedent("""
    import os
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    import dataclasses, json
    import jax
    from jax.sharding import PartitionSpec as P
    from repro.configs import get_config, TRAIN_4K, DECODE_32K
    from repro.launch.steps import make_spec
    from repro.parallel.sharding import param_pspec, set_layout
    from repro.models import build_model

    mesh = jax.make_mesh((2, 4), ("data", "model"))
    out = {}

    # --- param rules (full config shapes, no allocation)
    cfg = get_config("qwen3-8b")
    api = build_model(cfg)
    shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    flat = jax.tree_util.tree_flatten_with_path(shapes)[0]
    specs = {"/".join(str(getattr(p, "key", p)) for p in path):
             str(param_pspec(path, a, mesh)) for path, a in flat}
    out["wq_spec"] = specs["blocks/attn/wq"]
    out["wo_spec"] = specs["blocks/attn/wo"]
    out["embed_spec"] = specs["embed"]
    out["norm_spec"] = specs["final_norm/scale"]

    # --- a reduced config actually lowers + compiles on the small mesh
    red = dataclasses.replace(
        get_config("llama3.2-3b").reduced(), n_kv_heads=4)
    shape = dataclasses.replace(TRAIN_4K, seq_len=64, global_batch=8)
    spec = make_spec(red, shape, mesh)
    with mesh:
        compiled = jax.jit(spec.fn).lower(*spec.args).compile()
    out["train_compiles"] = True

    shape_d = dataclasses.replace(DECODE_32K, seq_len=128, global_batch=8)
    spec = make_spec(red, shape_d, mesh)
    with mesh:
        compiled = jax.jit(spec.fn).lower(*spec.args).compile()
    out["decode_compiles"] = True

    # --- fsdp layout produces no TP on feature dims
    set_layout("fsdp")
    specs2 = {"/".join(str(getattr(p, "key", p)) for p in path):
              str(param_pspec(path, a, mesh)) for path, a in flat}
    out["wq_spec_fsdp"] = specs2["blocks/attn/wq"]
    print(json.dumps(out))
""")


@pytest.fixture(scope="module")
def subproc_out():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(os.path.dirname(__file__), "..", "src")
    r = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                       capture_output=True, text=True, timeout=420)
    assert r.returncode == 0, r.stderr[-3000:]
    return json.loads(r.stdout.strip().splitlines()[-1])


def test_tp_param_rules(subproc_out):
    o = subproc_out
    assert "'data', 'model'" in o["wq_spec"]          # col-parallel + FSDP
    assert "'model', 'data'" in o["wo_spec"]          # row-parallel + FSDP
    assert "'model'" in o["embed_spec"]               # vocab over model
    assert o["norm_spec"] == "PartitionSpec()"        # norms replicate


def test_fsdp_layout_has_no_tp(subproc_out):
    # storage-only sharding: exactly one sharded dim, on the big axis
    assert subproc_out["wq_spec_fsdp"].count("'model'") <= 1
    assert "PartitionSpec(None," in subproc_out["wq_spec_fsdp"]


def test_small_mesh_lower_compile(subproc_out):
    assert subproc_out["train_compiles"] and subproc_out["decode_compiles"]
