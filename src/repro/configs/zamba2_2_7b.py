"""Zamba2-2.7B — Mamba2 backbone + shared attention blocks [arXiv:2411.15242; hf]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32,
    d_ff=10240, vocab_size=32000,
    ssm_state=64, ssm_expand=2, ssm_headdim=64, ssm_conv=4,
    attn_every=6, act="gelu", norm="rmsnorm",
)
