"""Architecture registry: ``--arch <id>`` resolution.

Each assigned architecture lives in its own module (src/repro/configs/<id>.py)
with the exact public-literature geometry; this registry maps ids to configs.
"""
from __future__ import annotations

from .base import SHAPES, ModelConfig, ShapeConfig, shape_applicable


def _load() -> dict[str, ModelConfig]:
    from . import (llama3_2_3b, moonshot_v1_16b_a3b, pixtral_12b, qwen2_5_3b,
                   qwen3_8b, qwen3_moe_30b_a3b, rwkv6_3b, stablelm_1_6b,
                   whisper_base, zamba2_2_7b)
    mods = [rwkv6_3b, stablelm_1_6b, qwen2_5_3b, qwen3_8b, llama3_2_3b,
            zamba2_2_7b, moonshot_v1_16b_a3b, qwen3_moe_30b_a3b, pixtral_12b,
            whisper_base]
    return {m.CONFIG.name: m.CONFIG for m in mods}


_REGISTRY: dict[str, ModelConfig] | None = None


def get_config(name: str) -> ModelConfig:
    global _REGISTRY
    if _REGISTRY is None:
        _REGISTRY = _load()
    if name not in _REGISTRY:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(_REGISTRY)}")
    return _REGISTRY[name]


def list_archs() -> list[str]:
    global _REGISTRY
    if _REGISTRY is None:
        _REGISTRY = _load()
    return sorted(_REGISTRY)


def all_cells() -> list[tuple[ModelConfig, ShapeConfig, bool, str]]:
    """Every (arch x shape) cell with applicability flag + skip reason."""
    out = []
    for name in list_archs():
        cfg = get_config(name)
        for shape in SHAPES:
            ok, why = shape_applicable(cfg, shape)
            out.append((cfg, shape, ok, why))
    return out
