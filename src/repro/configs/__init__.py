from .base import (DECODE_32K, LONG_500K, PREFILL_32K, SHAPES, TRAIN_4K,
                   ModelConfig, ShapeConfig, shape_applicable)
from .registry import all_cells, get_config, list_archs
