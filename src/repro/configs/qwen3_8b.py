"""Qwen3-8B — qk-norm, GQA kv=8 [hf:Qwen/Qwen3-8B]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="qwen3-8b", family="dense",
    n_layers=36, d_model=4096, n_heads=32, n_kv_heads=8,
    d_ff=12288, vocab_size=151936, head_dim=128,
    qk_norm=True, act="swiglu", norm="rmsnorm",
    rope_theta=1_000_000.0,
)
