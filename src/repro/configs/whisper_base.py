"""Whisper-base — enc-dec; conv audio frontend STUB (input_specs provide
precomputed frame embeddings) [arXiv:2212.04356; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8,
    d_ff=2048, vocab_size=51865,
    n_enc_layers=6, enc_ctx=1500,
    norm="layernorm", act="gelu", rope_theta=10_000.0,
)
