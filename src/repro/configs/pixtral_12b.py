"""Pixtral-12B — mistral-nemo text backbone, ViT patch frontend (STUB:
input_specs provide pre-projected patch embeddings)
[hf:mistralai/Pixtral-12B-2409; unverified]."""
from .base import ModelConfig

CONFIG = ModelConfig(
    name="pixtral-12b", family="vlm",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8,
    d_ff=14336, vocab_size=131072, head_dim=128,
    n_patches=256, act="swiglu", norm="rmsnorm",
    rope_theta=1_000_000.0,
)
