"""Architecture + shape configuration.

One ``ModelConfig`` covers all ten assigned architecture families; family-
specific fields are ignored by families that don't use them.  Configs are
frozen dataclasses so they hash (usable as jit static args).
"""
from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field
from typing import Optional, Tuple


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str                    # dense | ssm | hybrid | moe | vlm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int

    head_dim: int = 0              # 0 -> d_model // n_heads
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    act: str = "swiglu"            # swiglu | gelu
    qk_norm: bool = False
    qkv_bias: bool = False
    partial_rotary: float = 1.0    # fraction of head_dim that rotates
    rope_theta: float = 1_000_000.0
    tie_embeddings: bool = False

    # --- MoE
    n_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # per-expert hidden
    n_shared_experts: int = 0
    first_dense_layers: int = 0    # leading layers use dense FFN
    capacity_factor: float = 1.25

    # --- RWKV6
    rwkv_head_dim: int = 64

    # --- Mamba2 / hybrid
    ssm_state: int = 0
    ssm_expand: int = 2
    ssm_headdim: int = 64
    ssm_conv: int = 4
    attn_every: int = 0            # zamba2: shared attn block period (0=never)

    # --- enc-dec (whisper)
    n_enc_layers: int = 0
    enc_ctx: int = 1500            # audio frame positions (stub frontend)

    # --- vlm (pixtral)
    n_patches: int = 0             # image patch embeddings prepended (stub)

    max_seq: int = 532_000
    dtype: str = "bfloat16"
    remat: bool = True             # activation checkpointing in train loss

    @property
    def hd(self) -> int:
        return self.head_dim or self.d_model // self.n_heads

    @property
    def attention_free(self) -> bool:
        return self.family == "ssm"

    @property
    def subquadratic(self) -> bool:
        """Can this arch serve 500k-token contexts? (SSM / hybrid only)"""
        return self.family in ("ssm", "hybrid")

    @property
    def is_enc_dec(self) -> bool:
        return self.n_enc_layers > 0

    def n_params(self) -> int:
        """Total parameter count (exact, mirrors init fns)."""
        D, F, V, L = self.d_model, self.d_ff, self.vocab_size, self.n_layers
        H, KV, hd = self.n_heads, self.n_kv_heads, self.hd
        emb = V * D * (1 if self.tie_embeddings else 2)
        total = emb

        def attn_p() -> int:
            p = D * H * hd + 2 * D * KV * hd + H * hd * D
            if self.qkv_bias:
                p += (H + 2 * KV) * hd
            if self.qk_norm:
                p += 2 * hd
            return p

        def dense_ffn(f: int) -> int:
            return D * f * (3 if self.act == "swiglu" else 2)

        def mamba_p() -> int:
            din = self.ssm_expand * D
            nh = din // self.ssm_headdim
            inp = D * (2 * din + 2 * self.ssm_state + nh)
            conv = (din + 2 * self.ssm_state) * self.ssm_conv
            out = din * D
            extra = nh * 2 + din          # A, D, dt_bias + norm
            return inp + conv + out + extra

        if self.family in ("dense", "vlm"):
            total += L * (attn_p() + dense_ffn(F) + 2 * D)
        elif self.family == "moe":
            moe_f = self.moe_d_ff or F
            per_moe = (D * self.n_experts                      # router
                       + self.n_experts * D * moe_f * 3
                       + self.n_shared_experts * D * moe_f * 3)
            n_moe = L - self.first_dense_layers
            total += L * (attn_p() + 2 * D)
            total += n_moe * per_moe + self.first_dense_layers * dense_ffn(F)
        elif self.family == "ssm":                              # rwkv6
            hdw = self.rwkv_head_dim
            nh = D // hdw
            tmix = 6 * D + D * D * 4 + nh * hdw + D * 64 * 2 + 64 * D  # r,k,v,o,w-lora,u
            cmix = 2 * D + D * F + F * D
            total += L * (tmix + cmix + 2 * D)
        elif self.family == "hybrid":                           # zamba2
            total += L * (mamba_p() + 2 * D)
            total += attn_p() + dense_ffn(F) + 2 * D            # one shared block
        elif self.family == "audio":                            # whisper enc-dec
            enc = self.n_enc_layers * (attn_p() + dense_ffn(F) + 2 * D)
            dec = L * (2 * attn_p() + dense_ffn(F) + 3 * D)     # self+cross attn
            total += enc + dec + self.enc_ctx * D               # enc pos-embed
        total += D                                              # final norm
        return total

    def n_active_params(self) -> int:
        """Per-token active params (MoE: top_k + shared experts only)."""
        if self.family != "moe":
            return self.n_params()
        moe_f = self.moe_d_ff or self.d_ff
        per_tok_moe = (self.top_k + self.n_shared_experts) * self.d_model * moe_f * 3
        n_moe = self.n_layers - self.first_dense_layers
        all_moe = (self.n_experts + self.n_shared_experts) * self.d_model * moe_f * 3
        return self.n_params() - n_moe * (all_moe - per_tok_moe)

    def reduced(self) -> "ModelConfig":
        """Tiny same-family config for CPU smoke tests."""
        return dataclasses.replace(
            self,
            name=self.name + "-smoke",
            n_layers=max(2, self.attn_every or 0, self.first_dense_layers + 1),
            d_model=128,
            n_heads=4,
            n_kv_heads=min(4, max(1, self.n_kv_heads * 4 // self.n_heads)),
            d_ff=256,
            head_dim=32,
            vocab_size=512,
            n_experts=min(self.n_experts, 4),
            top_k=min(self.top_k, 2),
            moe_d_ff=64 if self.n_experts else 0,
            ssm_state=min(self.ssm_state, 16),
            ssm_headdim=32 if self.ssm_state else 64,
            rwkv_head_dim=32,
            n_enc_layers=2 if self.n_enc_layers else 0,
            enc_ctx=16,
            n_patches=8 if self.n_patches else 0,
            attn_every=3 if self.attn_every else 0,
            max_seq=256,
        )


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str          # train | prefill | decode


TRAIN_4K = ShapeConfig("train_4k", 4_096, 256, "train")
PREFILL_32K = ShapeConfig("prefill_32k", 32_768, 32, "prefill")
DECODE_32K = ShapeConfig("decode_32k", 32_768, 128, "decode")
LONG_500K = ShapeConfig("long_500k", 524_288, 1, "decode")

SHAPES: Tuple[ShapeConfig, ...] = (TRAIN_4K, PREFILL_32K, DECODE_32K, LONG_500K)


def shape_applicable(cfg: ModelConfig, shape: ShapeConfig) -> tuple[bool, str]:
    """Whether (arch, shape) is a runnable cell; reason when skipped."""
    if shape.name == "long_500k" and not cfg.subquadratic:
        return False, "full-attention arch: 500k context is O(L^2)-infeasible (skip per DESIGN.md)"
    return True, ""
