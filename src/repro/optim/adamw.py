"""AdamW from scratch (mixed precision, production layout).

State: fp32 master weights + fp32 first/second moments.  Model params stay in
their compute dtype (bf16) and are refreshed from the master copy each step.
Optimizer state inherits the params' (FSDP+TP) sharding, so memory per device
is params_bytes * 12 / n_devices — the ZeRO-1-equivalent layout.

Includes global-norm gradient clipping and a cosine LR schedule with warmup.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    betas: tuple = (0.9, 0.95)
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step: jax.Array) -> jax.Array:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(1.0, cfg.warmup_steps)
    t = (step - cfg.warmup_steps) / jnp.maximum(
        1.0, cfg.total_steps - cfg.warmup_steps)
    t = jnp.clip(t, 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any) -> dict:
    master = jax.tree.map(lambda p: p.astype(jnp.float32), params)
    zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {
        "step": jnp.zeros((), jnp.int32),
        "master": master,
        "m": zeros,
        "v": jax.tree.map(jnp.copy, zeros),
    }


def global_norm(tree: Any) -> jax.Array:
    return jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                        for g in jax.tree.leaves(tree)))


def apply_updates(params: Any, grads: Any, state: dict, cfg: AdamWConfig
                  ) -> tuple[Any, dict, dict]:
    """Returns (new_params_in_compute_dtype, new_state, metrics)."""
    step = state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / (gnorm + 1e-9))
    lr = schedule(cfg, step)
    b1, b2 = cfg.betas
    bc1 = 1.0 - b1 ** step.astype(jnp.float32)
    bc2 = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(p_master, g, m, v):
        g = g.astype(jnp.float32) * scale
        m = b1 * m + (1 - b1) * g
        v = b2 * v + (1 - b2) * jnp.square(g)
        mh = m / bc1
        vh = v / bc2
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p_master
        return p_master - lr * delta, m, v

    flat_master, tdef = jax.tree.flatten(state["master"])
    flat_g = jax.tree.leaves(grads)
    flat_m = jax.tree.leaves(state["m"])
    flat_v = jax.tree.leaves(state["v"])
    new_master, new_m, new_v = [], [], []
    for pm, g, m, v in zip(flat_master, flat_g, flat_m, flat_v):
        a, b, c = upd(pm, g, m, v)
        new_master.append(a); new_m.append(b); new_v.append(c)
    master = jax.tree.unflatten(tdef, new_master)
    new_state = {
        "step": step,
        "master": master,
        "m": jax.tree.unflatten(tdef, new_m),
        "v": jax.tree.unflatten(tdef, new_v),
    }
    new_params = jax.tree.map(lambda pm, p: pm.astype(p.dtype), master, params)
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_params, new_state, metrics
