from .adamw import AdamWConfig, apply_updates, global_norm, init_opt_state, schedule
