"""Pytree <-> logical record chunking.

Training state (params + optimizer) becomes a set of *logical records*:
    table = "state",  key = "<pytree/path>#<chunk_idx>"
Each record holds ``chunk_elems`` raw elements of one leaf array.  Keys are
purely logical — which page a chunk lands on is the DC's business — which is
exactly what lets the same log restore onto a DC with a different page size
or shard layout (the paper's replica argument, Section 1.1).
"""
from __future__ import annotations

import struct
from typing import Any, Iterator

import jax
import numpy as np

CHUNK_ELEMS = 16_384          # elements per record (~64 KiB fp32)
_HDR = struct.Struct("<II")   # dtype code, n elements
_DTYPES = ["float32", "bfloat16", "float16", "int32", "int64", "uint32",
           "float64", "int8", "uint8", "bool"]


def _leaf_paths(tree: Any) -> list[tuple[str, Any]]:
    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    out = []
    for path, leaf in flat:
        name = "/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                        for p in path)
        out.append((name, leaf))
    return out


def encode_chunk(arr_bytes: bytes, dtype: str, n: int) -> bytes:
    return _HDR.pack(_DTYPES.index(dtype), n) + arr_bytes


def decode_chunk(raw: bytes) -> tuple[np.ndarray, str]:
    code, n = _HDR.unpack_from(raw, 0)
    dtype = _DTYPES[code]
    np_dtype = np.uint16 if dtype == "bfloat16" else np.dtype(dtype)
    arr = np.frombuffer(raw, dtype=np_dtype, offset=_HDR.size, count=n)
    return arr, dtype


def tree_to_records(tree: Any, chunk_elems: int = CHUNK_ELEMS
                    ) -> Iterator[tuple[bytes, bytes]]:
    """Yield (key, value) records for every chunk of every leaf."""
    for name, leaf in _leaf_paths(tree):
        arr = np.asarray(leaf)
        dtype = str(leaf.dtype)
        view = (arr.view(np.uint16) if dtype == "bfloat16" else arr).reshape(-1)
        n = view.size
        n_chunks = max(1, (n + chunk_elems - 1) // chunk_elems)
        for c in range(n_chunks):
            part = view[c * chunk_elems:(c + 1) * chunk_elems]
            key = f"{name}#{c:06d}".encode()
            yield key, encode_chunk(part.tobytes(), dtype, part.size)


def records_to_tree(template: Any, records: dict[bytes, bytes],
                    chunk_elems: int = CHUNK_ELEMS) -> Any:
    """Rebuild a pytree shaped like ``template`` from chunk records."""
    leaves = []
    for name, leaf in _leaf_paths(template):
        shape = leaf.shape
        dtype = str(leaf.dtype)
        n = int(np.prod(shape)) if shape else 1
        n_chunks = max(1, (n + chunk_elems - 1) // chunk_elems)
        parts = []
        for c in range(n_chunks):
            key = f"{name}#{c:06d}".encode()
            raw = records.get(key)
            if raw is None:
                raise KeyError(f"missing state chunk {key!r}")
            arr, _ = decode_chunk(raw)
            parts.append(arr)
        flat = np.concatenate(parts) if len(parts) > 1 else parts[0]
        if dtype == "bfloat16":
            out = jax.numpy.asarray(flat.view(jax.numpy.bfloat16)).reshape(shape)
        else:
            out = jax.numpy.asarray(flat.reshape(shape))
        leaves.append(out)
    treedef = jax.tree_util.tree_structure(template)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def n_state_records(tree: Any, chunk_elems: int = CHUNK_ELEMS) -> int:
    total = 0
    for _, leaf in _leaf_paths(tree):
        n = int(np.prod(leaf.shape)) if leaf.shape else 1
        total += max(1, (n + chunk_elems - 1) // chunk_elems)
    return total
