"""TrainWAL: the paper's logical recovery as the framework's fault-tolerance
layer.

Roles (mirroring DESIGN.md's mapping):
  TC  = the training coordinator: logs *logical* records — per-step metadata
        (step id, data cursor) every step, and state-chunk after-images every
        ``chunk_interval`` steps (an incremental, fuzzy checkpoint).  It
        never knows which page a chunk lives on.
  DC  = the record store: pages + B-tree + buffer pool; flushes dirty pages
        lazily (``bg_flush_pages`` per step — continuous checkpointing, no
        stop-the-world), emits Delta-log records, answers RSSP.

Recovery after a crash:
  1. DC recovery + DPT-pruned logical redo (Algorithm 5) restores the record
     store to the last *committed* state — cost proportional to dirty pages,
     NOT total state size (the paper's claim, now for training state).
  2. The trailing steps (after the last chunk txn) are redone by *replay*:
     the data pipeline is counter-based, so the logged cursor + deterministic
     train_step reproduce them exactly — the training-world analogue of the
     "tail of the log" falling back to op re-execution.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import numpy as np

from repro.core import Database, Strategy, recover
from repro.core.dc import make_key

from .chunking import CHUNK_ELEMS, records_to_tree, tree_to_records

META_TABLE = "meta"
STATE_TABLE = "state"
_META = struct.Struct("<qqq")      # step, cursor, state_step


@dataclass
class WALConfig:
    chunk_interval: int = 10       # steps between state-chunk transactions
    ckpt_interval: int = 50        # steps between RSSP checkpoints
    bg_flush_pages: int = 8        # fuzzy-flush budget per step
    cache_pages: int = 4096
    chunk_elems: int = 8192        # 32 KiB fp32 / 16 KiB bf16 per record
    tracker_interval: int = 200    # updates between Delta-log records
    # blob-sized pages: checkpoint stores use large blocks; several chunk
    # records fit one page (and the replica example restores the same log
    # into a store with a different page_size)
    page_size: int = 65536
    strategy: Strategy = Strategy.LOG2


class TrainWAL:
    def __init__(self, cfg: WALConfig | None = None):
        self.cfg = cfg or WALConfig()
        self.db = Database(cache_pages=self.cfg.cache_pages,
                           tracker_interval=self.cfg.tracker_interval,
                           page_size=self.cfg.page_size)
        self.db.bootstrap_empty()
        self._bootstrapped = False
        self._digests: dict[bytes, int] = {}     # chunk key -> crc32

    # -------------------------------------------------------------- logging
    def log_state(self, step: int, cursor: int, state: Any,
                  delta_only: bool = True) -> None:
        """One transaction: changed state chunks + the metadata record.
        ``delta_only`` skips chunks whose bytes did not change since the last
        log_state (embedding rows / routed experts / frozen towers) — the
        update stream becomes sparse, which is exactly the locality the
        paper's DPT machinery exploits.  Commit forces the WAL."""
        import zlib
        txn = self.db.tc.begin()
        n_upd = 0
        for key, value in tree_to_records(state, self.cfg.chunk_elems):
            if delta_only and self._bootstrapped:
                dig = zlib.crc32(value)
                if self._digests.get(key) == dig:
                    continue
                self._digests[key] = dig
            elif delta_only:
                self._digests[key] = zlib.crc32(value)
            if self._bootstrapped:
                self.db.tc.update(txn, STATE_TABLE, key, value)
            else:
                self.db.tc.insert(txn, STATE_TABLE, key, value)
            n_upd += 1
            if n_upd % self.cfg.tracker_interval == 0:
                self.db.dc.emit_trackers()
        meta = _META.pack(step, cursor, step)
        if self._bootstrapped:
            self.db.tc.update(txn, META_TABLE, b"latest", meta)
        else:
            self.db.tc.insert(txn, META_TABLE, b"latest", meta)
        self.db.tc.commit(txn)
        self._bootstrapped = True
        self.db.dc.emit_trackers()
        # keep tracker records themselves durable (group-committed)
        self.db.log.flush()
        self.db.dc.maybe_background_flush(self.cfg.bg_flush_pages)

    def log_step_meta(self, step: int, cursor: int, state_step: int) -> None:
        """Per-step heartbeat: step id + data cursor (tiny txn)."""
        txn = self.db.tc.begin()
        meta = _META.pack(step, cursor, state_step)
        self.db.tc.update(txn, META_TABLE, b"latest", meta)
        self.db.tc.commit(txn)
        self.db.dc.maybe_background_flush(self.cfg.bg_flush_pages)

    def maybe_checkpoint(self, step: int) -> bool:
        if step % self.cfg.ckpt_interval == 0 and step > 0:
            self.db.checkpoint()
            return True
        return False

    # ------------------------------------------------------------- recovery
    def crash(self):
        return self.db.crash()

    @classmethod
    def restore(cls, image, template_state: Any, wal_cfg: WALConfig | None = None,
                strategy: Optional[Strategy] = None):
        """Recover the record store, rebuild the state pytree, return
        (wal, state, step, cursor, state_step, recovery_stats)."""
        cfg = wal_cfg or WALConfig()
        db, stats = recover(image, strategy or cfg.strategy,
                            cache_pages=cfg.cache_pages,
                            page_size=cfg.page_size)
        raw_meta = db.dc.read(META_TABLE, b"latest")
        assert raw_meta is not None, "no committed training state to restore"
        step, cursor, state_step = _META.unpack(raw_meta)

        records: dict[bytes, bytes] = {}
        prefix = make_key(STATE_TABLE, b"")
        for k, v in db.scan_all():
            if k.startswith(prefix):
                records[k[len(prefix):]] = v
        state = records_to_tree(template_state, records, cfg.chunk_elems)

        wal = cls.__new__(cls)
        wal.cfg = cfg
        wal.db = db
        wal._bootstrapped = True
        wal._digests = {}          # rebuilt lazily; first post-restore
        return wal, state, step, cursor, state_step, stats


# ----------------------------------------------------------------- trainer
def train_with_recovery(*, train_step: Callable, init_state: Any,
                        batch_at: Callable[[int], Any], n_steps: int,
                        wal: TrainWAL, start_step: int = 0,
                        log_every: int = 0,
                        on_step: Optional[Callable] = None):
    """Generic fault-tolerant loop: the full state is logged every
    chunk_interval steps; every step logs the (step, cursor) heartbeat."""
    state = init_state
    state_step = start_step
    for step in range(start_step, n_steps):
        batch = batch_at(step)
        state, metrics = train_step(state, batch)
        if (step + 1) % wal.cfg.chunk_interval == 0:
            wal.log_state(step + 1, step + 1, state)
            state_step = step + 1
        else:
            wal.log_step_meta(step + 1, step + 1, state_step)
        wal.maybe_checkpoint(step + 1)
        if on_step is not None:
            on_step(step, state, metrics)
        if log_every and (step + 1) % log_every == 0:
            print(f"  step {step + 1}: loss={float(metrics['loss']):.4f}")
    return state


def resume_from_crash(image, template_state, *, train_step, batch_at,
                      wal_cfg: WALConfig | None = None,
                      strategy: Optional[Strategy] = None):
    """Restore + replay the tail: chunks give state at ``state_step``; the
    heartbeat says training reached ``step``; deterministic replay re-executes
    (state_step, step] to reproduce the exact pre-crash state."""
    wal, state, step, cursor, state_step, stats = TrainWAL.restore(
        image, template_state, wal_cfg, strategy)
    for s in range(state_step, step):
        state, _ = train_step(state, batch_at(s))
    return wal, state, step, stats
