from .chunking import (CHUNK_ELEMS, n_state_records, records_to_tree,
                       tree_to_records)
from .train_wal import (TrainWAL, WALConfig, resume_from_crash,
                        train_with_recovery)
