"""Structured span/event tracer with a no-op fast path.

Tracing is **off by default** and every probe in the hot paths is written
as either ``with TRACER.span(...)`` (which returns a shared no-op span when
disabled) or ``if TRACER.enabled: TRACER.event(...)`` (so the kwargs dict
is never even built).  The CI-asserted bound in
``benchmarks/recovery_bench.bench_probe_overhead`` keeps this honest.

Event model — a flat list of dicts, one per line in the JSONL export:

  {"type": "begin", "span": 7, "parent": 3, "name": "redo.window",
   "t_ms": 12.301, "wall": 1754550000.123, "attrs": {...}}
  {"type": "end",   "span": 7, "name": "redo.window",
   "t_ms": 14.875, "dur_ms": 2.574, "attrs": {...}}
  {"type": "event", "parent": 7, "name": "io.demand",
   "t_ms": 13.002, "attrs": {"pid": 91, "outcome": "sync"}}

``t_ms`` is monotonic (``perf_counter`` relative to the tracer epoch — the
construction or last ``clear()``); ``wall`` on begin events anchors the
trace to wall-clock time.  Span ids are per-tracer-epoch; ``parent`` is the
innermost open span at emit time (0 = root).  Attributes set *during* a
span (``span.set(...)``) appear on its end event.

``TRACER`` is a process-wide singleton; toggle ``TRACER.enabled`` (or the
``enable()``/``disable()`` shims) — never rebind the name, call sites
capture the object at import.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from types import TracebackType
from typing import Any, Dict, List, Optional, Type, Union


class _NullSpan:
    """Shared no-op span returned while tracing is disabled."""
    __slots__ = ()

    def __enter__(self) -> "_NullSpan":
        return self

    def __exit__(self, exc_type: Optional[Type[BaseException]],
                 exc: Optional[BaseException],
                 tb: Optional[TracebackType]) -> bool:
        return False

    def set(self, **attrs: object) -> "_NullSpan":
        return self


_NULL_SPAN = _NullSpan()


class _Span:
    __slots__ = ("_tr", "name", "attrs", "span_id", "_t0")

    def __init__(self, tracer: "Tracer", name: str,
                 attrs: Dict[str, Any]):
        self._tr = tracer
        self.name = name
        self.attrs = attrs
        self.span_id = 0
        self._t0 = 0.0

    def __enter__(self) -> "_Span":
        tr = self._tr
        self.span_id = tr._next_id
        tr._next_id += 1
        self._t0 = tr.now_ms()
        tr.events.append({
            "type": "begin", "span": self.span_id,
            "parent": tr._stack[-1] if tr._stack else 0,
            "name": self.name, "t_ms": round(self._t0, 3),
            "wall": time.time(), "attrs": dict(self.attrs)})
        tr._stack.append(self.span_id)
        return self

    def set(self, **attrs: object) -> "_Span":
        """Attach/refresh attributes; they ride on the end event."""
        self.attrs.update(attrs)
        return self

    def __exit__(self, exc_type: Optional[Type[BaseException]],
                 exc: Optional[BaseException],
                 tb: Optional[TracebackType]) -> bool:
        tr = self._tr
        t1 = tr.now_ms()
        if tr._stack and tr._stack[-1] == self.span_id:
            tr._stack.pop()
        ev: Dict[str, Any] = {
            "type": "end", "span": self.span_id, "name": self.name,
              "t_ms": round(t1, 3), "dur_ms": round(t1 - self._t0, 3),
              "attrs": self.attrs}
        if exc_type is not None:
            ev["error"] = exc_type.__name__
        tr.events.append(ev)
        return False


class Tracer:
    def __init__(self, enabled: bool = False):
        self.enabled = enabled
        self.events: List[Dict[str, Any]] = []
        self._stack: List[int] = []
        self._next_id = 1
        self._epoch = time.perf_counter()

    def now_ms(self) -> float:
        return (time.perf_counter() - self._epoch) * 1e3

    # ------------------------------------------------------------- emission
    def span(self, name: str,
             **attrs: object) -> Union[_Span, _NullSpan]:
        """Context manager for a nested span; no-op when disabled."""
        if not self.enabled:
            return _NULL_SPAN
        return _Span(self, name, attrs)

    def event(self, name: str, **attrs: object) -> None:
        """Point event, parented to the innermost open span.  Hot paths
        must guard the *call* with ``if TRACER.enabled`` so the kwargs
        dict is never built when tracing is off."""
        if not self.enabled:
            return
        self.events.append({
            "type": "event", "parent": self._stack[-1] if self._stack else 0,
            "name": name, "t_ms": round(self.now_ms(), 3), "attrs": attrs})

    # ------------------------------------------------------------ lifecycle
    def clear(self) -> None:
        """Drop all events and start a new epoch (span ids restart, t_ms
        rebases to now)."""
        self.events.clear()
        self._stack.clear()
        self._next_id = 1
        self._epoch = time.perf_counter()

    def export_jsonl(self, path: Union[str, Path]) -> Path:
        """One event per line; returns the path written."""
        p = Path(path)
        p.parent.mkdir(parents=True, exist_ok=True)
        with open(p, "w", encoding="utf-8") as f:
            for ev in self.events:
                f.write(json.dumps(ev) + "\n")
        return p


#: the process-wide tracer; import-site convenience shims below
TRACER = Tracer()


def span(name: str, **attrs: object) -> Union[_Span, _NullSpan]:
    return TRACER.span(name, **attrs)


def event(name: str, **attrs: object) -> None:
    # reprolint: allow(tracer-guard) — the module-level convenience shim IS the unguarded form; hot paths import TRACER and guard at the call site
    TRACER.event(name, **attrs)


def enable() -> None:
    TRACER.enabled = True


def disable() -> None:
    TRACER.enabled = False


def clear() -> None:
    TRACER.clear()


def export_jsonl(path: Union[str, Path]) -> Path:
    return TRACER.export_jsonl(path)
