"""Human-readable recovery timeline: span tree + cache hit-rate footer.

``render_timeline`` consumes the flat tracer event list (live from
``TRACER.events`` or re-read from a JSONL export) and draws the span tree
with durations and attributes; point events are aggregated per parent span
(count + sums of small numeric attributes) so a thousand ``io.demand``
events render as one line, not a thousand.  An optional metrics snapshot
adds a footer with the decode-cache hit rates that explain the walls.
"""
from __future__ import annotations

import json
from pathlib import Path
from typing import Any, Dict, List, Optional

# point-event attrs worth summing in the aggregate line
_SUMMED_ATTRS = ("records", "ops", "spans", "stall_ms")


class SpanNode:
    __slots__ = ("span_id", "name", "t_ms", "dur_ms", "attrs", "children",
                 "event_counts", "event_sums")

    def __init__(self, span_id: int, name: str, t_ms: float) -> None:
        self.span_id = span_id
        self.name = name
        self.t_ms = t_ms
        self.dur_ms: Optional[float] = None      # None: never closed
        self.attrs: Dict[str, Any] = {}
        self.children: List["SpanNode"] = []
        self.event_counts: dict = {}             # name -> count
        self.event_sums: dict = {}               # (name, attr) -> sum

    def _note_event(self, ev: dict) -> None:
        name = ev["name"]
        self.event_counts[name] = self.event_counts.get(name, 0) + 1
        for k in _SUMMED_ATTRS:
            v = ev.get("attrs", {}).get(k)
            if isinstance(v, (int, float)):
                key = (name, k)
                self.event_sums[key] = self.event_sums.get(key, 0) + v


def build_tree(events: List[dict]) -> List[SpanNode]:
    """Rebuild the span forest from the flat begin/end/event list; returns
    root spans in begin order.  Unclosed spans (trace cut mid-run) keep
    ``dur_ms=None`` and render with an ellipsis."""
    roots: List[SpanNode] = []
    by_id: dict = {}
    for ev in events:
        t = ev["type"]
        if t == "begin":
            node = SpanNode(ev["span"], ev["name"], ev["t_ms"])
            node.attrs.update(ev.get("attrs", {}))
            by_id[ev["span"]] = node
            parent = by_id.get(ev.get("parent", 0))
            (parent.children if parent else roots).append(node)
        elif t == "end":
            node = by_id.get(ev["span"])
            if node is not None:
                node.dur_ms = ev.get("dur_ms")
                node.attrs.update(ev.get("attrs", {}))
        elif t == "event":
            parent = by_id.get(ev.get("parent", 0))
            if parent is not None:
                parent._note_event(ev)
    return roots


def _fmt_attrs(attrs: dict) -> str:
    parts = []
    for k, v in attrs.items():
        if isinstance(v, float):
            v = round(v, 3)
        parts.append(f"{k}={v}")
    return "  ".join(parts)


def _render_node(node: SpanNode, lines: List[str], prefix: str,
                 is_last: bool, is_root: bool) -> None:
    dur = "…" if node.dur_ms is None else f"{node.dur_ms:.2f}ms"
    attrs = _fmt_attrs(node.attrs)
    head = "" if is_root else ("└─ " if is_last else "├─ ")
    lines.append(f"{prefix}{head}{node.name}  {dur}"
                 + (f"  [{attrs}]" if attrs else ""))
    child_prefix = prefix if is_root else prefix + ("   " if is_last
                                                    else "│  ")
    # aggregated point events first, then child spans
    tails: List[str] = []
    for name in sorted(node.event_counts):
        sums = "  ".join(
            f"{k}={round(v, 3)}" for (n, k), v in sorted(node.event_sums.items())
            if n == name)
        tails.append(f"{node.event_counts[name]}x {name}"
                     + (f"  [{sums}]" if sums else ""))
    items = tails + node.children
    for i, item in enumerate(items):
        last = i == len(items) - 1
        if isinstance(item, str):
            lines.append(f"{child_prefix}{'└─ ' if last else '├─ '}{item}")
        else:
            _render_node(item, lines, child_prefix, last, False)


def _cache_footer(snap: dict) -> List[str]:
    """Hit-rate lines for the decode caches, from a metrics snapshot."""
    lines = []
    pairs = [
        ("pagestore decode cache", "pagestore.decode_hits",
         "pagestore.decode_misses", "misses"),
        ("archive segment LRU", "archive.cache_hits",
         "archive.segment_decodes", "decodes"),
        ("buffer pool", "bufferpool.hits", "bufferpool.misses", "misses"),
    ]
    for label, hit_key, miss_key, miss_word in pairs:
        hits = snap.get(hit_key, 0)
        misses = snap.get(miss_key, 0)
        total = hits + misses
        if not total:
            continue
        lines.append(f"cache: {label}  {hits} hits / {misses} {miss_word}"
                     f"  ({100.0 * hits / total:.1f}% hit)")
    evictions = snap.get("bufferpool.evictions", 0)
    flushes = snap.get("bufferpool.flushes", 0)
    pinned = snap.get("bufferpool.pinned", 0)
    if evictions or flushes:
        lines.append(f"pool: {evictions} evictions / {flushes} flushes"
                     f"  ({pinned:g} pinned now)")
    return lines


def _hist_footer(snap: dict) -> List[str]:
    """Quantile lines for every histogram in the snapshot that saw data
    (a histogram summary is the only dict-valued snapshot entry)."""
    lines = []
    for key in sorted(snap):
        s = snap[key]
        if not isinstance(s, dict) or not s.get("count"):
            continue
        lines.append(
            f"hist: {key}  n={s['count']}  p50={s.get('p50', 0):g}  "
            f"p95={s.get('p95', 0):g}  p99={s.get('p99', 0):g}  "
            f"max={s['max']:g}")
    return lines


def render_timeline(events: Optional[List[dict]] = None,
                    snapshot: Optional[dict] = None) -> str:
    """Render the trace as an indented tree.  ``events`` defaults to the
    live ``TRACER.events``; pass a metrics ``snapshot`` to append the
    cache hit-rate footer."""
    if events is None:
        from .trace import TRACER
        events = TRACER.events
    lines: List[str] = []
    for root in build_tree(events):
        _render_node(root, lines, "", True, True)
    if snapshot:
        footer = _cache_footer(snapshot) + _hist_footer(snapshot)
        if footer:
            if lines:
                lines.append("")
            lines.extend(footer)
    return "\n".join(lines)


def load_jsonl(path) -> List[dict]:
    """Read back an ``export_jsonl`` trace."""
    return [json.loads(line)
            for line in Path(path).read_text(encoding="utf-8").splitlines()
            if line]
