"""Render a black-box dump: last-seconds timeline + metric deltas.

Works from the dump alone — a cold process that shares nothing with the
one that died points this at a ``.rbbx`` blob (path, bytes, or a backend
key) and gets a human-readable post-mortem:

  * header: dump reason, wall-clock time, events captured/dropped
  * the phase the crash interrupted, derived from the newest phase-class
    flight event (analysis / redo window / apply epoch / …)
  * the event tail, timestamps relative to the dump instant, with runs
    of the same kind collapsed (``143x io.demand``)
  * metric deltas: dump-time snapshot minus the recorder's baseline

Corruption stays loud: a torn or truncated blob raises
``CorruptSegmentError`` out of :func:`load_dump` — there is no partial
render path.
"""
from __future__ import annotations

import datetime
from pathlib import Path
from typing import Any, Dict, List, Optional, Sequence, Tuple, Union

from .flightrec import decode_dump

#: flight-event kinds that mark an engine phase, newest wins; the value
#: is a template over the event's (a, b) payload numbers.
_PHASE_KINDS: Dict[str, str] = {
    "rec.analysis": "analysis (scan from LSN {a:.0f})",
    "rec.redo": "redo (from LSN {a:.0f})",
    "rec.window": "redo window (records {a:.0f}..+{b:.0f})",
    "rec.undo": "undo ({a:.0f} loser txns)",
    "rec.checkpoint": "end-of-recovery checkpoint",
    "restore.window": "restore heal window ({a:.0f} ops)",
    "repl.apply": "replica apply (commit LSN {a:.0f})",
    "shard.apply": "apply epoch (shard {a:.0f}, {b:.0f} ops)",
    "db.crash": "explicit crash (stable LSN {a:.0f})",
}

DumpSource = Union[bytes, str, Path]


def load_dump(source: DumpSource,
              backend: Optional[Any] = None) -> Dict[str, Any]:
    """Load + decode a dump from raw bytes, a filesystem path, or —
    with ``backend`` — a blob key.  Whole-or-error."""
    if backend is not None:
        if not isinstance(source, str):
            raise TypeError("backend lookup needs a str key")
        return decode_dump(backend.get(source))
    if isinstance(source, (bytes, bytearray)):
        return decode_dump(bytes(source))
    return decode_dump(Path(source).read_bytes())


def interrupted_phase(events: Sequence[Sequence[Any]]) -> str:
    """Name the phase the newest phase-class event puts the engine in."""
    for ev in reversed(list(events)):
        kind = str(ev[1])
        tpl = _PHASE_KINDS.get(kind)
        if tpl is not None:
            return tpl.format(a=float(ev[2]), b=float(ev[3]))
    return "unknown (no phase events captured)"


def _collapse(events: Sequence[Sequence[Any]],
              t_dump: float) -> List[str]:
    """Event tail with runs of one kind collapsed to a single line."""
    lines: List[str] = []
    i = 0
    evs = list(events)
    while i < len(evs):
        kind = evs[i][1]
        j = i
        while j + 1 < len(evs) and evs[j + 1][1] == kind:
            j += 1
        t_first = (float(evs[i][0]) - t_dump) * 1e3
        t_last = (float(evs[j][0]) - t_dump) * 1e3
        n = j - i + 1
        if n == 1:
            a, b, c = (float(evs[i][k]) for k in (2, 3, 4))
            detail = f"a={a:g} b={b:g} c={c:g}"
            lines.append(f"  {t_first:>10.3f}ms  {kind}  ({detail})")
        else:
            c_sum = sum(float(e[4]) for e in evs[i:j + 1])
            lines.append(f"  {t_first:>10.3f}ms..{t_last:.3f}ms  "
                         f"{n}x {kind}  (sum c={c_sum:g})")
        i = j + 1
    return lines


def _metric_deltas(baseline: Dict[str, Any],
                   snapshot: Dict[str, Any]) -> List[Tuple[str, str]]:
    """(key, rendered delta) for every metric that moved since the
    recorder's baseline, sorted by key."""
    out: List[Tuple[str, str]] = []
    for key in sorted(snapshot):
        now = snapshot[key]
        base = baseline.get(key, 0)
        if isinstance(now, dict):          # histogram summary
            base_n = base.get("count", 0) if isinstance(base, dict) else 0
            dn = now.get("count", 0) - base_n
            if dn:
                out.append((key, f"+{dn} obs (p50={now.get('p50', 0)} "
                                 f"p95={now.get('p95', 0)} "
                                 f"max={now.get('max', 0)})"))
        else:
            base_v = base if isinstance(base, (int, float)) else 0
            d = now - base_v
            if d:
                out.append((key, f"{base_v:g} -> {now:g} ({d:+g})"))
    return out


def render_postmortem(dump: Union[Dict[str, Any], DumpSource], *,
                      tail: int = 100,
                      max_deltas: int = 40) -> str:
    """Human-readable post-mortem from a dump (decoded dict or any
    :func:`load_dump` source)."""
    if not isinstance(dump, dict):
        dump = load_dump(dump)
    t_dump = float(dump["t_dump"])
    wall = dump.get("wall_dump")
    wall_s = (datetime.datetime.fromtimestamp(
        float(wall), tz=datetime.timezone.utc).isoformat()
        if wall is not None else "?")
    events = list(dump["events"])
    lines = [
        f"black box: reason={dump['reason']}  wall={wall_s}",
        f"  {len(events)} events captured, "
        f"{dump.get('dropped', 0)} dropped "
        f"(ring capacity {dump.get('capacity', '?')})",
        f"interrupted during: {interrupted_phase(events)}",
    ]
    if events:
        shown = events[-tail:]
        lines.append(f"last events (t relative to dump; showing "
                     f"{len(shown)} of {len(events)}):")
        lines.extend(_collapse(shown, t_dump))
    else:
        lines.append("last events: none captured")
    deltas = _metric_deltas(dump.get("baseline", {}), dump["snapshot"])
    if deltas:
        lines.append("metric deltas since baseline:")
        for key, txt in deltas[:max_deltas]:
            lines.append(f"  {key}: {txt}")
        if len(deltas) > max_deltas:
            lines.append(f"  ... {len(deltas) - max_deltas} more")
    return "\n".join(lines)
