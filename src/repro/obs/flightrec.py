"""Always-on flight recorder: the recovery engine's black box.

The opt-in tracer (``obs.trace``) answers "where did this run spend its
time" — when someone asked in advance.  The flight recorder answers the
question nobody asked in advance: *what was the engine doing in the last
seconds before it crashed?*  It is always on, allocation-light, and
bounded:

  * ``FLIGHT.record(kind, a, b, c)`` stores one compact tuple
    ``(perf_counter, kind, a, b, c)`` into a preallocated ring.  Call
    sites pass a literal kind string and up to three numbers — never
    f-strings or dicts (reprolint's ``tracer-guard`` rule pins this).
  * On ``Database.crash()``, a failed replica apply epoch, or any
    corruption error, ``auto_dump(reason)`` writes the ring tail plus a
    full metrics snapshot as a versioned black-box blob.  The blob uses
    the media codec discipline (magic + format-version byte + CRC32
    frame) so a cold process — ``obs.postmortem`` — can decode it with
    nothing but the file, and a torn blob raises instead of rendering
    short.
  * The sink is the ``REPRO_BLACKBOX_DIR`` env var (a directory), or
    anything with a ``.put(name, bytes)`` method (a ``MediaBackend``)
    via ``FLIGHT.configure(...)``.  No sink → ``auto_dump`` is a no-op;
    recording always happens regardless.

Import discipline: this module may import only the stdlib and sibling
``obs.metrics`` at module level — ``repro.media`` imports ``repro.core``
which imports ``repro.obs`` back, so codec helpers are imported lazily
inside :func:`decode_dump`.  The *encoder* writes the same frame layout
with ``struct``/``zlib`` directly for the same reason.
"""
from __future__ import annotations

import json
import os
import struct
import time
import zlib
from pathlib import Path
from typing import Any, Dict, List, Optional, Tuple, Union

from . import metrics as _metrics

#: 4-byte magic + format-version byte, same prologue discipline as
#: RSEG/RSNP/RMST/RAMT in ``media.codec``.
BLACKBOX_MAGIC = b"RBBX"
BLACKBOX_FORMAT_VERSION = 1
#: directory sink picked up at import time (CI sets it for test runs)
DUMP_ENV = "REPRO_BLACKBOX_DIR"
#: default ring capacity — the "last N events" of the black box
DEFAULT_CAPACITY = 4096

_U32 = struct.Struct("<I")

#: one recorded event: (perf_counter seconds, kind, a, b, c)
Event = Tuple[float, str, float, float, float]
#: a sink is a directory path or anything with .put(name, data)
Sink = Union[str, Path, Any, None]


class FlightRecorder:
    __slots__ = ("capacity", "_buf", "_idx", "recorded", "enabled",
                 "wall0", "perf0", "_sink", "_seq", "_baseline",
                 "_dumping", "last_dump")

    def __init__(self, capacity: int = DEFAULT_CAPACITY,
                 sink: Sink = None) -> None:
        self.capacity = capacity
        self.enabled = True
        self._sink: Sink = sink
        self._seq = 0
        self._dumping = False
        #: key/path of the most recent dump (None until the first one)
        self.last_dump: Optional[str] = None
        self.clear()

    # ------------------------------------------------------------- recording
    def record(self, kind: str, a: float = 0, b: float = 0,
               c: float = 0) -> None:
        """Hot path: one tuple store, no formatting, no dict building."""
        if not self.enabled:
            return
        i = self._idx
        self._buf[i] = (time.perf_counter(), kind, a, b, c)
        i += 1
        self._idx = 0 if i == self.capacity else i
        self.recorded += 1

    def clear(self) -> None:
        """Empty the ring, re-anchor wall time, re-baseline metrics."""
        self._buf: List[Optional[Event]] = [None] * self.capacity
        self._idx = 0
        self.recorded = 0
        self.wall0 = time.time()
        self.perf0 = time.perf_counter()
        self._baseline: Dict[str, Any] = dict(_metrics.snapshot())

    @property
    def dropped(self) -> int:
        return max(0, self.recorded - self.capacity)

    def events(self) -> List[Event]:
        """Ring contents, oldest first."""
        if self.recorded <= self.capacity:
            raw = self._buf[:self._idx]
        else:
            raw = self._buf[self._idx:] + self._buf[:self._idx]
        return [e for e in raw if e is not None]

    # ----------------------------------------------------------------- dumps
    def configure(self, sink: Sink = None,
                  capacity: Optional[int] = None) -> None:
        """(Re)point the dump sink and optionally resize the ring.
        Resizing clears it."""
        self._sink = sink
        if capacity is not None and capacity != self.capacity:
            self.capacity = capacity
            self.clear()

    def mark_baseline(self) -> None:
        """Snapshot current metrics as the delta baseline for the next
        dump (postmortem shows dump-time minus baseline)."""
        self._baseline = dict(_metrics.snapshot())

    def dump_bytes(self, reason: str) -> bytes:
        """Encode the black-box blob: magic + version + one CRC32 frame
        holding a JSON payload.  Same frame layout as ``media.codec`` so
        decode is whole-or-error."""
        payload = {
            "version": BLACKBOX_FORMAT_VERSION,
            "reason": reason,
            "t_dump": time.perf_counter(),
            "wall_dump": time.time(),
            "wall0": self.wall0,
            "perf0": self.perf0,
            "capacity": self.capacity,
            "recorded": self.recorded,
            "dropped": self.dropped,
            "events": [list(e) for e in self.events()],
            "baseline": self._baseline,
            "snapshot": _metrics.snapshot(),
        }
        body = json.dumps(payload, sort_keys=True).encode("utf-8")
        return (BLACKBOX_MAGIC + bytes([BLACKBOX_FORMAT_VERSION])
                + _U32.pack(len(body)) + _U32.pack(zlib.crc32(body))
                + body)

    def dump(self, reason: str) -> Optional[str]:
        """Write a black-box blob to the configured sink.  Returns the
        key/path written, or None when no sink is configured.  Reentrant
        calls (a dump failing mid-dump) no-op instead of recursing."""
        if self._sink is None or self._dumping:
            return None
        self._dumping = True
        try:
            blob = self.dump_bytes(reason)
            self._seq += 1
            safe = "".join(ch if ch.isalnum() else "_" for ch in reason)
            name = f"blackbox_{os.getpid()}_{self._seq:04d}_{safe}.rbbx"
            sink = self._sink
            put = getattr(sink, "put", None)
            if callable(put):
                put(name, blob)
                key = name
            else:
                d = Path(os.fspath(sink))
                d.mkdir(parents=True, exist_ok=True)
                (d / name).write_bytes(blob)
                key = str(d / name)
            self.last_dump = key
            self.mark_baseline()
            return key
        finally:
            self._dumping = False


def decode_dump(blob: bytes) -> Dict[str, Any]:
    """Decode a black-box blob.  Whole-or-error: a truncated, torn, or
    bit-flipped blob raises ``CorruptSegmentError`` — never a silent
    short render."""
    # Lazy import: repro.media pulls in repro.core, which imports
    # repro.obs back; module level here must stay stdlib-only.
    from ..media.codec import _Reader, _check_header, _read_frame
    from ..media.errors import CorruptSegmentError

    r = _Reader(blob, "black-box dump")
    _check_header(r, BLACKBOX_MAGIC, "black-box dump",
                  max_version=BLACKBOX_FORMAT_VERSION)
    body = _read_frame(r, "black-box dump body")
    if not r.exhausted:
        raise CorruptSegmentError(
            f"black-box dump has {len(r.buf) - r.pos} trailing bytes "
            "past the body frame — refusing a partial read")
    try:
        payload = json.loads(body.buf.decode("utf-8"))
    except (UnicodeDecodeError, ValueError) as exc:
        raise CorruptSegmentError(
            f"black-box dump body is not valid JSON: {exc}") from exc
    if not isinstance(payload, dict):
        raise CorruptSegmentError("black-box dump body is not an object")
    for k in ("version", "reason", "t_dump", "events", "snapshot"):
        if k not in payload:
            raise CorruptSegmentError(
                f"black-box dump missing field {k!r}")
    return payload


#: the process-wide recorder; sink defaults to $REPRO_BLACKBOX_DIR
FLIGHT = FlightRecorder(sink=os.environ.get(DUMP_ENV) or None)


def auto_dump(reason: str) -> Optional[str]:
    """Module-level shim for crash sites: dump the process recorder."""
    return FLIGHT.dump(reason)
