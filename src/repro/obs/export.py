"""Registry export: Prometheus text format + JSONL time-series sampler.

``prometheus_text()`` renders the whole registry in the Prometheus
exposition format: counters and gauges as plain samples, histograms as
summaries (``_count`` / ``_sum`` plus ``quantile=`` samples from the
log-bucket estimates).  Metric dots become underscores; labels carry
over verbatim.

``Sampler`` appends ``{"t_wall", "elapsed_ms", "note", "metrics"}``
JSONL lines on explicit ``tick()`` calls — no threads, no timers; bench
and example drivers own the cadence.  ``tick()`` is rate-limited by
``period_ms`` unless forced, so a driver can call it inside a tight loop
and still get an evenly spaced series.
"""
from __future__ import annotations

import json
import time
from pathlib import Path
from typing import Dict, List, Optional, TextIO, Union

from . import metrics as _metrics
from .metrics import Counter, Gauge, Histogram, MetricsRegistry

_QUANTILES = (("0.5", "p50"), ("0.95", "p95"), ("0.99", "p99"))


def _prom_name(name: str) -> str:
    return name.replace(".", "_").replace("-", "_")


def _prom_labels(labels: Dict[str, str], extra: str = "") -> str:
    parts = [f'{k}="{v}"' for k, v in sorted(labels.items())]
    if extra:
        parts.append(extra)
    return "{" + ",".join(parts) + "}" if parts else ""


def prometheus_text(registry: Optional[MetricsRegistry] = None) -> str:
    """The registry in Prometheus exposition format, one string."""
    reg = registry if registry is not None else _metrics.REGISTRY
    lines: List[str] = []
    typed: set[str] = set()
    for key, metric in reg.items():
        name, labels = MetricsRegistry.split_key(key)
        pname = _prom_name(name)
        if isinstance(metric, Histogram):
            if pname not in typed:
                typed.add(pname)
                lines.append(f"# TYPE {pname} summary")
            s = metric.summary()
            for q, field in _QUANTILES:
                lab = _prom_labels(labels, f'quantile="{q}"')
                lines.append(f"{pname}{lab} {s[field]}")
            lines.append(f"{pname}_count{_prom_labels(labels)} "
                         f"{s['count']}")
            lines.append(f"{pname}_sum{_prom_labels(labels)} {s['sum']}")
        else:
            kind = "counter" if isinstance(metric, Counter) else "gauge"
            if pname not in typed:
                typed.add(pname)
                lines.append(f"# TYPE {pname} {kind}")
            assert isinstance(metric, (Counter, Gauge))
            lines.append(f"{pname}{_prom_labels(labels)} {metric.value}")
    return "\n".join(lines) + ("\n" if lines else "")


class Sampler:
    """Appends registry snapshots as JSONL lines on ``tick()``."""

    def __init__(self, path: Union[str, Path], *,
                 period_ms: float = 1000.0, prefix: str = "") -> None:
        self.path = Path(path)
        self.period_ms = period_ms
        self.prefix = prefix
        self.t0 = time.perf_counter()
        self._t_last = float("-inf")
        self._fh: Optional[TextIO] = None
        self.samples = 0

    def tick(self, force: bool = False, note: str = "") -> bool:
        """Write one sample if ``period_ms`` has elapsed (or forced);
        returns whether a line was written."""
        now = time.perf_counter()
        if not force and (now - self._t_last) * 1e3 < self.period_ms:
            return False
        self._t_last = now
        if self._fh is None:
            self.path.parent.mkdir(parents=True, exist_ok=True)
            self._fh = open(self.path, "w", encoding="utf-8")
        line = {"t_wall": time.time(),
                "elapsed_ms": round((now - self.t0) * 1e3, 3),
                "note": note,
                "metrics": _metrics.snapshot(self.prefix)}
        self._fh.write(json.dumps(line, sort_keys=True) + "\n")
        self._fh.flush()
        self.samples += 1
        return True

    def close(self) -> None:
        if self._fh is not None:
            self._fh.close()
            self._fh = None

    def __enter__(self) -> "Sampler":
        return self

    def __exit__(self, *exc: object) -> None:
        self.close()
