"""Unified observability layer: metrics registry + span/event tracer +
recovery-timeline renderer + always-on flight recorder.

Quick tour::

    from repro import obs

    obs.enable()                         # tracing on (metrics are always on)
    db, stats = recover(image, Strategy.LOG1, batched=True)
    obs.disable()

    print(obs.render_timeline(snapshot=obs.snapshot()))
    obs.trace.export_jsonl("artifacts/recovery_trace.jsonl")

    obs.snapshot("recovery")             # {'recovery.redo_wall_ms': ..., ...}
    obs.reset()                          # zero metrics + drop trace events

Metrics (counters/gauges/histograms) are always on — a probe costs one
attribute increment, same as the ``self.x += 1`` counters it unifies.
Tracing is off by default; every tracing probe no-ops behind a shared null
span / an ``if TRACER.enabled`` guard, and the bound is CI-asserted (see
``benchmarks/recovery_bench.bench_probe_overhead``).

The flight recorder (``obs.flightrec``) is the third tier: always on like
metrics, event-shaped like the tracer, bounded like neither needs to be —
a ring of compact tuples dumped as a versioned black-box blob when the
engine crashes, rendered post hoc by ``obs.postmortem``.  Live progress
(``obs.progress``) and registry export (``obs.export``) round out the
production story.
"""
from . import metrics, timeline, trace
from . import export, flightrec, postmortem, progress
from .export import Sampler, prometheus_text
from .flightrec import FLIGHT, FlightRecorder, auto_dump, decode_dump
from .metrics import (REGISTRY, counter, gauge, histogram, load_dataclass,
                      publish_dataclass, snapshot, value)
from .postmortem import interrupted_phase, load_dump, render_postmortem
from .progress import ProgressObserver
from .timeline import build_tree, load_jsonl, render_timeline
from .trace import TRACER, event, span

__all__ = [
    "metrics", "trace", "timeline",
    "export", "flightrec", "postmortem", "progress",
    "REGISTRY", "counter", "gauge", "histogram", "value", "snapshot",
    "publish_dataclass", "load_dataclass",
    "TRACER", "span", "event",
    "render_timeline", "build_tree", "load_jsonl",
    "FLIGHT", "FlightRecorder", "auto_dump", "decode_dump",
    "load_dump", "render_postmortem", "interrupted_phase",
    "ProgressObserver", "Sampler", "prometheus_text",
    "enable", "disable", "reset",
]


def enable() -> None:
    """Turn tracing on (metrics need no enabling)."""
    trace.TRACER.enabled = True


def disable() -> None:
    trace.TRACER.enabled = False


def reset() -> None:
    """Zero every metric in place, drop all trace events, and clear the
    flight-recorder ring (re-anchoring its baseline)."""
    metrics.REGISTRY.reset()
    trace.TRACER.clear()
    flightrec.FLIGHT.clear()
