"""Unified observability layer: metrics registry + span/event tracer +
recovery-timeline renderer.

Quick tour::

    from repro import obs

    obs.enable()                         # tracing on (metrics are always on)
    db, stats = recover(image, Strategy.LOG1, batched=True)
    obs.disable()

    print(obs.render_timeline(snapshot=obs.snapshot()))
    obs.trace.export_jsonl("artifacts/recovery_trace.jsonl")

    obs.snapshot("recovery")             # {'recovery.redo_wall_ms': ..., ...}
    obs.reset()                          # zero metrics + drop trace events

Metrics (counters/gauges/histograms) are always on — a probe costs one
attribute increment, same as the ``self.x += 1`` counters it unifies.
Tracing is off by default; every tracing probe no-ops behind a shared null
span / an ``if TRACER.enabled`` guard, and the bound is CI-asserted (see
``benchmarks/recovery_bench.bench_probe_overhead``).
"""
from . import metrics, timeline, trace
from .metrics import (REGISTRY, counter, gauge, histogram, load_dataclass,
                      publish_dataclass, snapshot, value)
from .timeline import build_tree, load_jsonl, render_timeline
from .trace import TRACER, event, span

__all__ = [
    "metrics", "trace", "timeline",
    "REGISTRY", "counter", "gauge", "histogram", "value", "snapshot",
    "publish_dataclass", "load_dataclass",
    "TRACER", "span", "event",
    "render_timeline", "build_tree", "load_jsonl",
    "enable", "disable", "reset",
]


def enable() -> None:
    """Turn tracing on (metrics need no enabling)."""
    trace.TRACER.enabled = True


def disable() -> None:
    trace.TRACER.enabled = False


def reset() -> None:
    """Zero every metric in place and drop all trace events."""
    metrics.REGISTRY.reset()
    trace.TRACER.clear()
