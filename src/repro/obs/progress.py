"""Live recovery/restore progress: fraction complete, records/s, ETA.

``recover()``, ``SnapshotStore.restore()`` and ``cold_restore`` accept a
``progress=`` observer.  The engine feeds it from the analysis-pass LSN
span: ``begin(total_units)`` once the span is known, ``update(done_units,
records=...)`` at window boundaries, ``finish()`` on success.  The
observer publishes two gauges —

  * ``recovery.progress`` — fraction complete in [0, 1]
  * ``recovery.eta_ms``   — estimated remaining wall, from the observed
    unit rate (0 until one update has landed, 0 again at finish)

— and renders a one-line console display (``line()``) that examples can
carriage-return in place.  An ``out`` stream makes it self-printing.

The engine calls these methods from hot loops, so ``update`` is throttled
by ``min_interval_ms`` (0 = every call) and does only arithmetic.  Any
exception an observer raises propagates out of the recovery pass — the
black-box demo uses exactly that to script a crash mid-redo.
"""
from __future__ import annotations

import time
from typing import IO, Optional

from . import metrics as _metrics

_G_PROGRESS = _metrics.gauge("recovery.progress")
_G_ETA = _metrics.gauge("recovery.eta_ms")


class ProgressObserver:
    """Tracks one recovery/restore pass; reusable after ``finish()``."""

    def __init__(self, label: str = "recover", *,
                 out: Optional[IO[str]] = None,
                 min_interval_ms: float = 0.0) -> None:
        self.label = label
        self.out = out
        self.min_interval_ms = min_interval_ms
        self.total = 0.0
        self.done = 0.0
        self.records = 0
        self.t0 = 0.0
        self._t_last = 0.0
        self.rate = 0.0          # units/s over the whole pass so far
        self.records_per_s = 0.0
        self.eta_ms = 0.0
        self.active = False

    # ------------------------------------------------------------ lifecycle
    def begin(self, total_units: float) -> None:
        self.total = max(1.0, float(total_units))
        self.done = 0.0
        self.records = 0
        self.t0 = time.perf_counter()
        self._t_last = 0.0
        self.rate = 0.0
        self.records_per_s = 0.0
        self.eta_ms = 0.0
        self.active = True
        _G_PROGRESS.set(0.0)
        _G_ETA.set(0.0)

    def update(self, done_units: float,
               records: Optional[int] = None) -> None:
        if not self.active:
            return
        now = time.perf_counter()
        if (now - self._t_last) * 1e3 < self.min_interval_ms:
            return
        self._t_last = now
        self.done = min(float(done_units), self.total)
        if records is not None:
            self.records = records
        elapsed = now - self.t0
        if elapsed > 0:
            self.rate = self.done / elapsed
            self.records_per_s = self.records / elapsed
            if self.rate > 0:
                self.eta_ms = (self.total - self.done) / self.rate * 1e3
        _G_PROGRESS.set(round(self.fraction, 6))
        _G_ETA.set(round(self.eta_ms, 3))
        self._emit()

    def finish(self) -> None:
        if not self.active:
            return
        self.done = self.total
        self.eta_ms = 0.0
        self.active = False
        _G_PROGRESS.set(1.0)
        _G_ETA.set(0.0)
        self._emit(final=True)

    # ------------------------------------------------------------ rendering
    @property
    def fraction(self) -> float:
        return self.done / self.total if self.total else 0.0

    def line(self) -> str:
        """One-line console display: bar, percent, records/s, ETA."""
        frac = self.fraction
        filled = int(frac * 24)
        bar = "#" * filled + "-" * (24 - filled)
        eta = "done" if not self.active and frac >= 1.0 else \
            f"eta {self.eta_ms / 1e3:5.1f}s"
        return (f"{self.label} [{bar}] {frac * 100:5.1f}%  "
                f"{self.records_per_s:9.0f} rec/s  {eta}")

    def _emit(self, final: bool = False) -> None:
        if self.out is None:
            return
        self.out.write("\r" + self.line())
        if final:
            self.out.write("\n")
        self.out.flush()
