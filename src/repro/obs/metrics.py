"""Process-wide metrics registry: counters, gauges, histograms.

One registry (``REGISTRY``) spans every subsystem so a single recovery run
can be read as one coherent story — redo phase walls next to archive LRU
hits next to replica watermark lag — instead of per-object tallies that
die with their objects.  Design constraints, in order:

  * The *hot-path* cost of a probe must match the ``self.x += 1`` idiom it
    sits beside: call sites resolve their ``Counter`` once (module scope or
    ``__init__``) and then pay one attribute increment per event.  For that
    to be safe, ``reset()`` zeroes metric objects **in place** — it never
    replaces them — so cached references stay live across resets.
  * Metrics are identified by ``name`` plus optional labels, flattened into
    one key string (``repl.shard.lag{replica=r1,shard=2}``) with labels
    sorted for stability.  ``snapshot()`` returns plain JSON-able data.
  * No dependency on anything else in ``repro`` (everything else imports
    *us*).

``publish_dataclass`` / ``load_dataclass`` bridge the legacy stats
dataclasses (``RecoveryStats``, ``RestoreStats``): every numeric field —
recursing into nested stats — lands as a ``<prefix>.<field>`` gauge, and a
fresh dataclass can be rebuilt from the registry, making the dataclasses
views over the registry without giving up their cheap local tallying.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Dict, List, Optional, Tuple, Type, TypeVar, Union


class Counter:
    """Monotonic within a reset epoch; ``reset()`` starts a new epoch."""
    __slots__ = ("value",)
    kind = "counter"

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-write-wins instantaneous value."""
    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self) -> None:
        self.value: float = 0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


#: below this many observations quantiles are exact (sorted raw samples);
#: at the threshold the samples fold into the fixed log-bucket scheme.
SMALL_SAMPLE_MAX = 128
#: log2 buckets per octave: bucket width ratio 2^(1/4) ≈ 1.19, so a
#: bucketed quantile estimate is within ~±9% of the true value.
_BUCKETS_PER_OCTAVE = 4
#: bucket 1 starts at 2^-20 (~1 µs when observing ms); 256 buckets reach
#: 2^44 (~5e8 s) — anything outside clamps to the edge buckets.
_BUCKET_LOG_OFFSET = 20.0
_N_BUCKETS = 257
_QUANTILES = (("p50", 0.50), ("p95", 0.95), ("p99", 0.99))


def _bucket_index(v: float) -> int:
    if v <= 0.0:
        return 0
    i = int((math.log2(v) + _BUCKET_LOG_OFFSET) * _BUCKETS_PER_OCTAVE) + 1
    if i < 1:
        return 1
    if i >= _N_BUCKETS:
        return _N_BUCKETS - 1
    return i


def _bucket_mid(i: int) -> float:
    """Geometric midpoint of bucket ``i``'s bounds — the value reported
    for quantiles that land in it."""
    if i <= 0:
        return 0.0
    return float(2.0 ** ((i - 0.5) / _BUCKETS_PER_OCTAVE
                         - _BUCKET_LOG_OFFSET))


class Histogram:
    """Streaming count/sum/min/max plus p50/p95/p99 estimates.

    Quantiles are exact (nearest-rank over retained raw samples) below
    ``SMALL_SAMPLE_MAX`` observations; past that the samples fold into a
    fixed log2-spaced bucket scheme (sparse dict, ~¼-octave buckets) and
    quantiles become geometric-midpoint estimates clamped to the observed
    min/max.  Memory stays bounded no matter how long the run."""
    __slots__ = ("count", "total", "min", "max", "_samples", "_buckets")
    kind = "histogram"

    def __init__(self) -> None:
        self.reset()

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        samples = self._samples
        if samples is not None:
            samples.append(v)
            if len(samples) >= SMALL_SAMPLE_MAX:
                self._spill()
        else:
            b = _bucket_index(v)
            self._buckets[b] = self._buckets.get(b, 0) + 1

    def _spill(self) -> None:
        """Fold the exact sample list into the log buckets (one-way)."""
        buckets = self._buckets
        samples = self._samples
        assert samples is not None
        for v in samples:
            b = _bucket_index(v)
            buckets[b] = buckets.get(b, 0) + 1
        self._samples = None

    def reset(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")
        self._samples: Optional[List[float]] = []
        self._buckets: Dict[int, int] = {}

    def quantile(self, q: float) -> float:
        """Nearest-rank quantile: exact in the small-sample regime,
        log-bucket midpoint estimate after spill."""
        if not self.count:
            return 0.0
        samples = self._samples
        if samples is not None:
            xs = sorted(samples)
            return xs[min(len(xs) - 1, int(q * len(xs)))]
        rank = min(self.count, int(q * self.count) + 1)
        seen = 0
        for b in sorted(self._buckets):
            seen += self._buckets[b]
            if seen >= rank:
                est = _bucket_mid(b)
                return min(self.max, max(self.min, est))
        return self.max

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "avg": 0.0, "p50": 0.0, "p95": 0.0, "p99": 0.0}
        out = {"count": self.count, "sum": round(self.total, 6),
               "min": self.min, "max": self.max,
               "avg": round(self.total / self.count, 6)}
        for key, q in _QUANTILES:
            out[key] = round(self.quantile(q), 6)
        return out


Metric = Union[Counter, Gauge, Histogram]
_M = TypeVar("_M", Counter, Gauge, Histogram)
#: what ``value()`` yields: a scalar, or a histogram summary dict
Value = Union[float, Dict[str, float]]


class MetricsRegistry:
    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}

    # ----------------------------------------------------------------- keys
    @staticmethod
    def key(name: str, labels: Dict[str, object]) -> str:
        if not labels:
            return name
        inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
        return f"{name}{{{inner}}}"

    # ------------------------------------------------------------ accessors
    def _get(self, cls: Type[_M], name: str,
             labels: Dict[str, object]) -> _M:
        k = self.key(name, labels)
        m = self._metrics.get(k)
        if m is None:
            new = cls()
            self._metrics[k] = new
            return new
        if type(m) is not cls:
            raise TypeError(f"metric {k!r} already registered as "
                            f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, **labels: object) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: object) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels: object) -> Histogram:
        return self._get(Histogram, name, labels)

    def value(self, name: str, **labels: object) -> Value:
        """Current value (counters/gauges) or summary dict (histograms);
        0 for a metric nothing has touched yet."""
        m = self._metrics.get(self.key(name, labels))
        if m is None:
            return 0
        return m.summary() if isinstance(m, Histogram) else m.value

    def __contains__(self, key: str) -> bool:
        return key in self._metrics

    def __len__(self) -> int:
        return len(self._metrics)

    def items(self) -> List[Tuple[str, Metric]]:
        """(key, metric) pairs sorted by key — the typed counterpart of
        ``snapshot()`` for exporters that need metric kinds."""
        return sorted(self._metrics.items())

    @staticmethod
    def split_key(key: str) -> Tuple[str, Dict[str, str]]:
        """Inverse of ``key()``: ``'a.b{x=1,y=2}'`` → ``('a.b',
        {'x': '1', 'y': '2'})``."""
        if not key.endswith("}") or "{" not in key:
            return key, {}
        name, _, inner = key[:-1].partition("{")
        labels: Dict[str, str] = {}
        for part in inner.split(","):
            k, _, v = part.partition("=")
            labels[k] = v
        return name, labels

    # -------------------------------------------------------- bulk actions
    def snapshot(self, prefix: str = "") -> Dict[str, Value]:
        """Plain-data view of every metric whose key starts with
        ``prefix``, sorted by key — what ``benchmarks/run.py`` embeds in
        each bench artifact."""
        out: Dict[str, Value] = {}
        for k in sorted(self._metrics):
            if not k.startswith(prefix):
                continue
            m = self._metrics[k]
            out[k] = m.summary() if isinstance(m, Histogram) else m.value
        return out

    def reset(self, prefix: str = "") -> None:
        """Zero matching metrics *in place* — cached Counter/Gauge
        references at call sites stay valid across resets."""
        for k, m in self._metrics.items():
            if k.startswith(prefix):
                m.reset()


#: the process-wide registry; import-site convenience shims below
REGISTRY = MetricsRegistry()


def counter(name: str, **labels: object) -> Counter:
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels: object) -> Gauge:
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, **labels: object) -> Histogram:
    return REGISTRY.histogram(name, **labels)


def value(name: str, **labels: object) -> Value:
    return REGISTRY.value(name, **labels)


def snapshot(prefix: str = "") -> Dict[str, Value]:
    return REGISTRY.snapshot(prefix)


def reset(prefix: str = "") -> None:
    REGISTRY.reset(prefix)


# --------------------------------------------------------------------------
# dataclass <-> registry bridge
def publish_dataclass(obj: Any, prefix: str,
                      registry: "MetricsRegistry | None" = None) -> None:
    """Publish every numeric field of a dataclass (recursing into nested
    dataclasses) as ``<prefix>.<field>`` gauges.  Non-numeric fields
    (strategy names, etc.) are skipped: the registry is numeric."""
    reg = registry if registry is not None else REGISTRY
    for f in dataclasses.fields(obj):
        v = getattr(obj, f.name)
        name = f"{prefix}.{f.name}"
        if dataclasses.is_dataclass(v) and not isinstance(v, type):
            publish_dataclass(v, name, reg)
        elif isinstance(v, bool):
            reg.gauge(name).set(int(v))
        elif isinstance(v, (int, float)):
            reg.gauge(name).set(v)


_T = TypeVar("_T")


def load_dataclass(cls: Type[_T], prefix: str,
                   registry: "MetricsRegistry | None" = None) -> _T:
    """Rebuild a stats dataclass from its published gauges — the
    'dataclass as a view over the registry' direction.  Fields never
    published keep their defaults."""
    reg = registry if registry is not None else REGISTRY
    obj = cls()
    for f in dataclasses.fields(obj):
        cur = getattr(obj, f.name)
        name = f"{prefix}.{f.name}"
        if dataclasses.is_dataclass(cur) and not isinstance(cur, type):
            setattr(obj, f.name, load_dataclass(type(cur), name, reg))
        elif isinstance(cur, bool):
            if reg.key(name, {}) in reg:
                setattr(obj, f.name, bool(reg.value(name)))
        elif isinstance(cur, (int, float)):
            if reg.key(name, {}) in reg:
                setattr(obj, f.name, type(cur)(reg.value(name)))
    return obj
