"""Process-wide metrics registry: counters, gauges, histograms.

One registry (``REGISTRY``) spans every subsystem so a single recovery run
can be read as one coherent story — redo phase walls next to archive LRU
hits next to replica watermark lag — instead of per-object tallies that
die with their objects.  Design constraints, in order:

  * The *hot-path* cost of a probe must match the ``self.x += 1`` idiom it
    sits beside: call sites resolve their ``Counter`` once (module scope or
    ``__init__``) and then pay one attribute increment per event.  For that
    to be safe, ``reset()`` zeroes metric objects **in place** — it never
    replaces them — so cached references stay live across resets.
  * Metrics are identified by ``name`` plus optional labels, flattened into
    one key string (``repl.shard.lag{replica=r1,shard=2}``) with labels
    sorted for stability.  ``snapshot()`` returns plain JSON-able data.
  * No dependency on anything else in ``repro`` (everything else imports
    *us*).

``publish_dataclass`` / ``load_dataclass`` bridge the legacy stats
dataclasses (``RecoveryStats``, ``RestoreStats``): every numeric field —
recursing into nested stats — lands as a ``<prefix>.<field>`` gauge, and a
fresh dataclass can be rebuilt from the registry, making the dataclasses
views over the registry without giving up their cheap local tallying.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Dict, Type, TypeVar, Union


class Counter:
    """Monotonic within a reset epoch; ``reset()`` starts a new epoch."""
    __slots__ = ("value",)
    kind = "counter"

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Gauge:
    """Last-write-wins instantaneous value."""
    __slots__ = ("value",)
    kind = "gauge"

    def __init__(self) -> None:
        self.value: float = 0

    def set(self, v: float) -> None:
        self.value = v

    def inc(self, n: float = 1) -> None:
        self.value += n

    def reset(self) -> None:
        self.value = 0


class Histogram:
    """Streaming count/sum/min/max — enough for window-size and latency
    distributions without bucket-boundary bikeshedding."""
    __slots__ = ("count", "total", "min", "max")
    kind = "histogram"

    def __init__(self) -> None:
        self.reset()

    def observe(self, v: float) -> None:
        self.count += 1
        self.total += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v

    def reset(self) -> None:
        self.count = 0
        self.total = 0.0
        self.min = float("inf")
        self.max = float("-inf")

    def summary(self) -> Dict[str, float]:
        if not self.count:
            return {"count": 0, "sum": 0.0, "min": 0.0, "max": 0.0,
                    "avg": 0.0}
        return {"count": self.count, "sum": round(self.total, 6),
                "min": self.min, "max": self.max,
                "avg": round(self.total / self.count, 6)}


Metric = Union[Counter, Gauge, Histogram]
_M = TypeVar("_M", Counter, Gauge, Histogram)
#: what ``value()`` yields: a scalar, or a histogram summary dict
Value = Union[float, Dict[str, float]]


class MetricsRegistry:
    def __init__(self) -> None:
        self._metrics: Dict[str, Metric] = {}

    # ----------------------------------------------------------------- keys
    @staticmethod
    def key(name: str, labels: Dict[str, object]) -> str:
        if not labels:
            return name
        inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
        return f"{name}{{{inner}}}"

    # ------------------------------------------------------------ accessors
    def _get(self, cls: Type[_M], name: str,
             labels: Dict[str, object]) -> _M:
        k = self.key(name, labels)
        m = self._metrics.get(k)
        if m is None:
            new = cls()
            self._metrics[k] = new
            return new
        if type(m) is not cls:
            raise TypeError(f"metric {k!r} already registered as "
                            f"{type(m).__name__}, requested {cls.__name__}")
        return m

    def counter(self, name: str, **labels: object) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels: object) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, **labels: object) -> Histogram:
        return self._get(Histogram, name, labels)

    def value(self, name: str, **labels: object) -> Value:
        """Current value (counters/gauges) or summary dict (histograms);
        0 for a metric nothing has touched yet."""
        m = self._metrics.get(self.key(name, labels))
        if m is None:
            return 0
        return m.summary() if isinstance(m, Histogram) else m.value

    def __contains__(self, key: str) -> bool:
        return key in self._metrics

    def __len__(self) -> int:
        return len(self._metrics)

    # -------------------------------------------------------- bulk actions
    def snapshot(self, prefix: str = "") -> Dict[str, Value]:
        """Plain-data view of every metric whose key starts with
        ``prefix``, sorted by key — what ``benchmarks/run.py`` embeds in
        each bench artifact."""
        out: Dict[str, Value] = {}
        for k in sorted(self._metrics):
            if not k.startswith(prefix):
                continue
            m = self._metrics[k]
            out[k] = m.summary() if isinstance(m, Histogram) else m.value
        return out

    def reset(self, prefix: str = "") -> None:
        """Zero matching metrics *in place* — cached Counter/Gauge
        references at call sites stay valid across resets."""
        for k, m in self._metrics.items():
            if k.startswith(prefix):
                m.reset()


#: the process-wide registry; import-site convenience shims below
REGISTRY = MetricsRegistry()


def counter(name: str, **labels: object) -> Counter:
    return REGISTRY.counter(name, **labels)


def gauge(name: str, **labels: object) -> Gauge:
    return REGISTRY.gauge(name, **labels)


def histogram(name: str, **labels: object) -> Histogram:
    return REGISTRY.histogram(name, **labels)


def value(name: str, **labels: object) -> Value:
    return REGISTRY.value(name, **labels)


def snapshot(prefix: str = "") -> Dict[str, Value]:
    return REGISTRY.snapshot(prefix)


def reset(prefix: str = "") -> None:
    REGISTRY.reset(prefix)


# --------------------------------------------------------------------------
# dataclass <-> registry bridge
def publish_dataclass(obj: Any, prefix: str,
                      registry: "MetricsRegistry | None" = None) -> None:
    """Publish every numeric field of a dataclass (recursing into nested
    dataclasses) as ``<prefix>.<field>`` gauges.  Non-numeric fields
    (strategy names, etc.) are skipped: the registry is numeric."""
    reg = registry if registry is not None else REGISTRY
    for f in dataclasses.fields(obj):
        v = getattr(obj, f.name)
        name = f"{prefix}.{f.name}"
        if dataclasses.is_dataclass(v) and not isinstance(v, type):
            publish_dataclass(v, name, reg)
        elif isinstance(v, bool):
            reg.gauge(name).set(int(v))
        elif isinstance(v, (int, float)):
            reg.gauge(name).set(v)


_T = TypeVar("_T")


def load_dataclass(cls: Type[_T], prefix: str,
                   registry: "MetricsRegistry | None" = None) -> _T:
    """Rebuild a stats dataclass from its published gauges — the
    'dataclass as a view over the registry' direction.  Fields never
    published keep their defaults."""
    reg = registry if registry is not None else REGISTRY
    obj = cls()
    for f in dataclasses.fields(obj):
        cur = getattr(obj, f.name)
        name = f"{prefix}.{f.name}"
        if dataclasses.is_dataclass(cur) and not isinstance(cur, type):
            setattr(obj, f.name, load_dataclass(type(cur), name, reg))
        elif isinstance(cur, bool):
            if reg.key(name, {}) in reg:
                setattr(obj, f.name, bool(reg.value(name)))
        elif isinstance(cur, (int, float)):
            if reg.key(name, {}) in reg:
                setattr(obj, f.name, type(cur)(reg.value(name)))
    return obj
