"""Write-ahead log manager.

One LSN space; in-memory tail + "stable" prefix (what survives a crash).
``flush()`` advances the stable point (group commit forces it).  ``crash()``
returns the stable prefix — the unforced tail is lost, exactly the set of
records the paper's "tail of the log" analysis concerns itself with.

The master pointer (ARIES' master record) remembers the last complete
checkpoint and the DC's last RSSP record so recovery knows where to start
without scanning from the beginning of time.  Master LSNs may point *below*
the truncation base (an old checkpoint whose records have moved to the
archive): ``record``/``scan`` splice transparently, so the pointer stays
valid across truncation.

Truncation: once a stable prefix has been sealed into an attached
``LogArchive``, ``truncate(upto)`` drops it from memory and remembers only
the base LSN.  Every read path (``record``, ``scan``, ``scan_stable``)
splices archive segments and the live tail into one dense LSN sequence, so
recovery, analysis, DPT construction and log shipping are oblivious to
where a record physically lives.  Only records *pruned from the archive*
are gone for good — reading below ``retained_lsn`` raises
``TruncatedLogError`` (never a silent skip), which the shipper surfaces as
``SnapshotRequired``.
"""
from __future__ import annotations

import bisect
import time
from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

from .records import (LSN, NULL_LSN, BeginCkptRec, CommitRec, EndCkptRec,
                      LogRec, RSSPRec)

# Purely for IO accounting: how many log records fit a "log page".
LOG_RECS_PER_PAGE = 64

#: commit-to-visible stamp retention; bounds memory on a primary whose
#: replicas never poll (stamps for drained commits are long gone anyway)
_MAX_COMMIT_STAMPS = 8192


class TruncatedLogError(LookupError):
    """A read touched LSNs that are neither in memory nor in the archive
    (truncated without an archive, or pruned from it).  Raised instead of
    silently skipping: a recovery or shipping pass that misses records
    would corrupt state, so the hole must be loud."""


@dataclass
class Master:
    """Stable master pointer (updated atomically, survives crash)."""
    end_ckpt_lsn: LSN = NULL_LSN      # last complete checkpoint's eCkpt LSN
    bckpt_lsn: LSN = NULL_LSN         # its matching bCkpt LSN
    rssp_rec_lsn: LSN = NULL_LSN      # DC's last RSSP record (carries DC meta)


class LogManager:
    def __init__(self):
        self._recs: List[LogRec] = []
        self._base: LSN = 0                # records [1, _base] truncated away
        self._stable_lsn: LSN = 0          # records [1, _stable_lsn] are stable
        self.archive = None                # LogArchive holding the sealed prefix
        self.master = Master()
        self.forced_flushes = 0
        self.max_txn: int = 0              # largest txn id ever logged
        self.last_commit_lsn: LSN = NULL_LSN   # newest CommitRec appended
        # Newest CommitRec at or below the stable point.  This — not
        # last_commit_lsn, which may sit in the unforced tail — is the
        # reference for commit-relative staleness: a committed-only consumer
        # can never have applied past it, so lag measured against anything
        # newer is phantom lag.
        self.last_stable_commit_lsn: LSN = NULL_LSN
        # Commit LSNs in the unforced tail, ascending by construction.
        # flush() bisects here for the newest commit <= the flush target
        # instead of rescanning the flushed range backwards — O(commits
        # since the last flush), amortized O(1) per commit.
        self._pending_commits: List[LSN] = []
        # Commit LSN -> perf_counter stamp taken the moment the commit
        # became stable (its flush) — the t0 of commit-to-visible.  The
        # shipper copies stamps into batches; appliers subtract at apply.
        # Bounded FIFO: insertion order is LSN order, so evicting the
        # oldest drops the commit least likely to still be in flight.
        self.commit_stamps: dict = {}

    # ---------------------------------------------------------------- append
    def append(self, rec: LogRec) -> LSN:
        rec.lsn = self._base + len(self._recs) + 1   # dense LSNs starting at 1
        self._recs.append(rec)
        txn = getattr(rec, "txn", None)
        if txn is not None and txn > self.max_txn:
            self.max_txn = txn
        if isinstance(rec, CommitRec):
            self.last_commit_lsn = rec.lsn
            self._pending_commits.append(rec.lsn)
        return rec.lsn

    def flush(self, upto: Optional[LSN] = None) -> LSN:
        """Force the log to stable storage up to ``upto`` (default: all)."""
        tgt = self.end_lsn if upto is None else min(upto, self.end_lsn)
        if tgt > self._stable_lsn:
            # newest pending commit at or below tgt; the full flush (the
            # common case) clears the whole pending list in one del
            idx = bisect.bisect_right(self._pending_commits, tgt)
            if idx:
                self.last_stable_commit_lsn = self._pending_commits[idx - 1]
                stamps = self.commit_stamps
                now = time.perf_counter()
                for lsn in self._pending_commits[:idx]:
                    if len(stamps) >= _MAX_COMMIT_STAMPS:
                        del stamps[next(iter(stamps))]
                    stamps[lsn] = now
                del self._pending_commits[:idx]
            self._stable_lsn = tgt
            self.forced_flushes += 1
        return self.stable_lsn

    @property
    def stable_lsn(self) -> LSN:
        return self._stable_lsn            # LSN of last stable record

    @property
    def end_lsn(self) -> LSN:
        return self._base + len(self._recs)

    # -------------------------------------------------------------- archive
    def attach_archive(self, archive) -> None:
        """Wire a ``LogArchive`` in as the home of the sealed prefix; the
        read paths below splice it with the live tail from then on."""
        self.archive = archive

    @property
    def retained_lsn(self) -> LSN:
        """First LSN still obtainable (from the archive or from memory).
        Everything below it has been truncated-without-archive or pruned."""
        mem_from = self._base + 1
        a = self.archive
        if a is not None and a.retained_from < mem_from \
                and a.archived_upto >= self._base:   # contiguous splice
            return a.retained_from
        return mem_from

    @property
    def in_memory_records(self) -> int:
        """Live tail size — what truncation bounds (``end_lsn`` keeps
        counting every record ever appended)."""
        return len(self._recs)

    def truncate(self, upto: LSN) -> int:
        """Drop the in-memory prefix [1, upto]; returns records dropped.

        Never loses information: the prefix must already be sealed in the
        attached archive (and be stable — the unforced tail cannot be
        archived, it can still be disowned by a crash).  Callers pick
        ``upto`` below the ``min(snapshot horizon, slowest subscriber)``
        watermark (see ``archive.Archiver``) so the *hot* paths — shipping
        to live subscribers, restore to recent targets — stay in memory and
        only cold readers ever touch archive segments."""
        upto = min(upto, self._stable_lsn)
        if upto <= self._base:
            return 0
        if self.archive is None or self.archive.archived_upto < upto:
            have = "no archive attached" if self.archive is None else \
                f"archive sealed only through LSN {self.archive.archived_upto}"
            raise ValueError(
                f"cannot truncate through LSN {upto}: {have} — seal the "
                "prefix into a LogArchive first (truncation moves records, "
                "it never deletes them)")
        dropped = upto - self._base
        self._recs = self._recs[dropped:]
        self._base = upto
        return dropped

    # ----------------------------------------------------------------- read
    def record(self, lsn: LSN) -> LogRec:
        if lsn > self._base:
            return self._recs[lsn - self._base - 1]
        if self.archive is not None:
            return self.archive.record(lsn)     # raises TruncatedLogError
        raise TruncatedLogError(
            f"LSN {lsn} was truncated (base={self._base}) and no archive "
            "is attached")

    def scan(self, from_lsn: LSN, to_lsn: Optional[LSN] = None) -> Iterator[LogRec]:
        """Yield stable records with lsn >= from_lsn (inclusive), splicing
        archive segments below the truncation base with the live tail."""
        hi = self._stable_lsn if to_lsn is None else min(to_lsn, self._stable_lsn)
        lo = max(from_lsn, 1)
        if lo > hi:
            return
        if lo <= self._base:
            if lo < self.retained_lsn:
                raise TruncatedLogError(
                    f"scan from LSN {lo} reaches below retained_lsn="
                    f"{self.retained_lsn}: those records were pruned")
            yield from self.archive.scan(lo, min(hi, self._base))
            lo = self._base + 1
        for i in range(lo - self._base - 1, hi - self._base):
            yield self._recs[i]

    def scan_stable(self, from_lsn: LSN,
                    max_records: Optional[int] = None
                    ) -> Tuple[List[LogRec], LSN]:
        """Shipping-cursor read: a batch of stable records starting at
        ``from_lsn``, plus the cursor for the next call.

        Returns ``(records, next_lsn)`` where ``next_lsn`` is the LSN the
        caller should resume from — callers keep no other state, which is
        what makes a log shipper restartable: the cursor can always be
        reconstructed from the consumer's durable resume point.  Only the
        stable prefix is visible; the unforced tail is never shipped (it can
        still be lost, and a replica must never apply work its primary could
        disown).  Truncation is invisible here too: a cursor below the base
        reads spliced archive segments.  Below ``retained_lsn`` there is
        nothing to splice and ``TruncatedLogError`` propagates (the shipper
        turns it into ``SnapshotRequired``)."""
        lo = max(from_lsn, 1)
        hi = self._stable_lsn
        if max_records is not None:
            hi = min(hi, lo - 1 + max_records)
        if lo > hi:
            return [], lo
        recs = list(self.scan(lo, hi))
        return recs, lo + len(recs)

    # ------------------------------------------------------------ checkpoint
    def set_master(self, *, end_ckpt: Optional[LSN] = None,
                   bckpt: Optional[LSN] = None,
                   rssp_rec: Optional[LSN] = None) -> None:
        if end_ckpt is not None:
            self.master.end_ckpt_lsn = end_ckpt
        if bckpt is not None:
            self.master.bckpt_lsn = bckpt
        if rssp_rec is not None:
            self.master.rssp_rec_lsn = rssp_rec

    def save_master(self, backend=None) -> None:
        """Persist the master pointer as an encoded blob on a
        ``MediaBackend`` (default: the attached archive's backend) — the
        ARIES master record made real bytes, so a fresh process knows
        where the last complete checkpoint and RSSP live without scanning
        (``Archiver.run_once`` calls this after every seal)."""
        from ..media.codec import encode_master   # keep core import-light
        if backend is None:
            backend = getattr(self.archive, "backend", None)
        if backend is None:
            raise ValueError("save_master needs a MediaBackend (none given "
                             "and no backend-backed archive is attached)")
        # reprolint: allow(wal-discipline) — the master pointer is the recovery bootstrap, not data: it only names LSNs that seal() already clamped to stable_lsn, and a stale master is always safe (recovery just scans further)
        backend.put("master", encode_master(self.master))

    @staticmethod
    def load_master(backend) -> Master:
        """Read a master pointer back from a backend; a fresh ``Master``
        (all NULL_LSN) when none was ever saved."""
        from ..media.codec import decode_master
        if not backend.exists("master"):
            return Master()
        return decode_master(backend.get("master"))

    # ---------------------------------------------------------------- crash
    def crash(self) -> "LogManager":
        """Return the stable image of this log (tail beyond stable point
        lost).  The archive is stable storage: the survivor keeps the same
        sealed segments, so a post-truncation crash image still reads the
        full history through the splice."""
        survivor = LogManager()
        survivor._recs = self._recs[: self._stable_lsn - self._base]
        survivor._base = self._base
        survivor._stable_lsn = self._stable_lsn
        survivor.archive = self.archive
        survivor.master = Master(self.master.end_ckpt_lsn,
                                 self.master.bckpt_lsn,
                                 self.master.rssp_rec_lsn)
        # max_txn may over-approximate (tail txns lost in the crash), which is
        # safe: recovery only needs fresh txn ids to be strictly larger than
        # any id that can appear in the surviving log.
        survivor.max_txn = self.max_txn
        # last_stable_commit_lsn is maintained at every flush and is by
        # definition the newest commit that survives, so both notions
        # coincide on the survivor (a commit in the unforced tail is lost).
        survivor.last_commit_lsn = self.last_stable_commit_lsn
        survivor.last_stable_commit_lsn = self.last_stable_commit_lsn
        # Stamps belong to stable commits, all of which survive; keeping
        # them lets commit-to-visible span a failover (stamps are
        # perf_counter values, comparable within this process only).
        survivor.commit_stamps = dict(self.commit_stamps)
        return survivor

    def n_log_pages(self, from_lsn: LSN) -> int:
        n = max(0, self._stable_lsn - (from_lsn - 1))
        return (n + LOG_RECS_PER_PAGE - 1) // LOG_RECS_PER_PAGE

    def __len__(self) -> int:
        """Total records ever appended (dense LSN space, unaffected by
        truncation) — callers diff this across operations to count writes."""
        return self.end_lsn
