"""Write-ahead log manager.

One LSN space; in-memory tail + "stable" prefix (what survives a crash).
``flush()`` advances the stable point (group commit forces it).  ``crash()``
returns the stable prefix — the unforced tail is lost, exactly the set of
records the paper's "tail of the log" analysis concerns itself with.

The master pointer (ARIES' master record) remembers the last complete
checkpoint and the DC's last RSSP record so recovery knows where to start
without scanning from the beginning of time.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Iterator, List, Optional, Tuple

from .records import (LSN, NULL_LSN, BeginCkptRec, CommitRec, EndCkptRec,
                      LogRec, RSSPRec)

# Purely for IO accounting: how many log records fit a "log page".
LOG_RECS_PER_PAGE = 64


@dataclass
class Master:
    """Stable master pointer (updated atomically, survives crash)."""
    end_ckpt_lsn: LSN = NULL_LSN      # last complete checkpoint's eCkpt LSN
    bckpt_lsn: LSN = NULL_LSN         # its matching bCkpt LSN
    rssp_rec_lsn: LSN = NULL_LSN      # DC's last RSSP record (carries DC meta)


class LogManager:
    def __init__(self):
        self._recs: List[LogRec] = []
        self._stable_idx: int = 0          # records [0, _stable_idx) are stable
        self.master = Master()
        self.forced_flushes = 0
        self.max_txn: int = 0              # largest txn id ever logged
        self.last_commit_lsn: LSN = NULL_LSN   # newest CommitRec appended
        # Newest CommitRec at or below the stable point.  This — not
        # last_commit_lsn, which may sit in the unforced tail — is the
        # reference for commit-relative staleness: a committed-only consumer
        # can never have applied past it, so lag measured against anything
        # newer is phantom lag.
        self.last_stable_commit_lsn: LSN = NULL_LSN

    # ---------------------------------------------------------------- append
    def append(self, rec: LogRec) -> LSN:
        rec.lsn = len(self._recs) + 1      # dense LSNs starting at 1
        self._recs.append(rec)
        txn = getattr(rec, "txn", None)
        if txn is not None and txn > self.max_txn:
            self.max_txn = txn
        if isinstance(rec, CommitRec):
            self.last_commit_lsn = rec.lsn
        return rec.lsn

    def flush(self, upto: Optional[LSN] = None) -> LSN:
        """Force the log to stable storage up to ``upto`` (default: all)."""
        tgt = len(self._recs) if upto is None else min(upto, len(self._recs))
        if tgt > self._stable_idx:
            if self.last_commit_lsn <= tgt:
                self.last_stable_commit_lsn = self.last_commit_lsn
            else:   # a commit past tgt exists: scan just the flushed range
                for i in range(tgt - 1, self._stable_idx - 1, -1):
                    if isinstance(self._recs[i], CommitRec):
                        self.last_stable_commit_lsn = self._recs[i].lsn
                        break
            self._stable_idx = tgt
            self.forced_flushes += 1
        return self.stable_lsn

    @property
    def stable_lsn(self) -> LSN:
        return self._stable_idx            # LSN of last stable record

    @property
    def end_lsn(self) -> LSN:
        return len(self._recs)

    def record(self, lsn: LSN) -> LogRec:
        return self._recs[lsn - 1]

    def scan(self, from_lsn: LSN, to_lsn: Optional[LSN] = None) -> Iterator[LogRec]:
        """Yield stable records with lsn >= from_lsn (inclusive)."""
        hi = self._stable_idx if to_lsn is None else min(to_lsn, self._stable_idx)
        for i in range(max(from_lsn, 1) - 1, hi):
            yield self._recs[i]

    def scan_stable(self, from_lsn: LSN,
                    max_records: Optional[int] = None
                    ) -> Tuple[List[LogRec], LSN]:
        """Shipping-cursor read: a batch of stable records starting at
        ``from_lsn``, plus the cursor for the next call.

        Returns ``(records, next_lsn)`` where ``next_lsn`` is the LSN the
        caller should resume from — callers keep no other state, which is
        what makes a log shipper restartable: the cursor can always be
        reconstructed from the consumer's durable resume point.  Only the
        stable prefix is visible; the unforced tail is never shipped (it can
        still be lost, and a replica must never apply work its primary could
        disown)."""
        lo = max(from_lsn, 1)
        hi = self._stable_idx
        if max_records is not None:
            hi = min(hi, lo - 1 + max_records)
        recs = self._recs[lo - 1: hi]
        return recs, lo + len(recs)

    # ------------------------------------------------------------ checkpoint
    def set_master(self, *, end_ckpt: Optional[LSN] = None,
                   bckpt: Optional[LSN] = None,
                   rssp_rec: Optional[LSN] = None) -> None:
        if end_ckpt is not None:
            self.master.end_ckpt_lsn = end_ckpt
        if bckpt is not None:
            self.master.bckpt_lsn = bckpt
        if rssp_rec is not None:
            self.master.rssp_rec_lsn = rssp_rec

    # ---------------------------------------------------------------- crash
    def crash(self) -> "LogManager":
        """Return the stable image of this log (tail beyond stable point lost)."""
        survivor = LogManager()
        survivor._recs = self._recs[: self._stable_idx]
        survivor._stable_idx = self._stable_idx
        survivor.master = Master(self.master.end_ckpt_lsn,
                                 self.master.bckpt_lsn,
                                 self.master.rssp_rec_lsn)
        # max_txn may over-approximate (tail txns lost in the crash), which is
        # safe: recovery only needs fresh txn ids to be strictly larger than
        # any id that can appear in the surviving log.
        survivor.max_txn = self.max_txn
        if self.last_commit_lsn <= self._stable_idx:
            survivor.last_commit_lsn = self.last_commit_lsn
        else:   # a commit appended but not yet forced was lost in the crash
            survivor.last_commit_lsn = next(
                (r.lsn for r in reversed(survivor._recs)
                 if isinstance(r, CommitRec)), NULL_LSN)
        # every surviving record is stable, so the two notions coincide
        survivor.last_stable_commit_lsn = survivor.last_commit_lsn
        return survivor

    def n_log_pages(self, from_lsn: LSN) -> int:
        n = max(0, self._stable_idx - (from_lsn - 1))
        return (n + LOG_RECS_PER_PAGE - 1) // LOG_RECS_PER_PAGE

    def __len__(self) -> int:
        return len(self._recs)
