"""Page-based B+tree: the DC's placement index (logical key -> leaf PID).

All node access goes through the buffer pool so index-page IO is accounted
exactly like data-page IO (the paper's Log1/Log2 vs SQL1/SQL2 comparison
hinges on this burden).  Structure modifications (leaf/internal splits, root
growth) are logged by the DC as SMO records carrying physiological
after-images — DC-private physical information, permitted because the DC owns
placement (Section 2.1).  DC recovery replays SMOs with an slsn idempotence
test, guaranteeing a well-formed tree before TC redo begins (Section 1.2).

LSN discipline (see pages.py): splits advance ``slsn`` (and the buffer's
``wal_lsn``) but *never* ``plsn`` — record redistribution is not a data
change, so data redo tests stay exact even for splits that happen while
recovery itself is repeating history.

Simplifications vs a production engine (documented, not load-bearing for the
paper's claims): deletes do not rebalance; no sibling pointers.
"""
from __future__ import annotations

import bisect
from typing import Optional

from .bufferpool import BufferPool
from .log import LogManager
from .pages import PAGE_SIZE, Page, empty_internal, empty_leaf
from .records import LSN, NULL_LSN, NULL_PID, PID, SMORec


class LeafCursor:
    """Amortizes root-to-leaf traversal across a sorted run of keys.

    ``seek(key)`` returns the PID of the leaf owning ``key`` *without*
    fetching the leaf page — the caller's DPT test can prune the record
    before any data-page IO, exactly like ``redo_with_dpt``.  While keys
    stay inside the current leaf's separator interval ``(lo, hi]`` the
    cached PID is returned with two byte comparisons; only a key past the
    interval re-traverses the internal levels.  This is the logical
    analogue of ARIES' page-at-a-time redo locality: a batch sorted by
    (table, key) turns N traversals over one leaf into one.

    The cursor caches no leaf *page* reference across mutations it cannot
    see; ``invalidate()`` must be called after any structure modification
    (split / root growth) because separators may have moved.
    """

    __slots__ = ("tree", "pid", "lo", "hi", "traversals", "reuses")

    def __init__(self, tree: "BTree"):
        self.tree = tree
        self.pid: PID = NULL_PID
        self.lo: Optional[bytes] = None     # exclusive lower separator
        self.hi: Optional[bytes] = None     # inclusive upper separator
        self.traversals = 0
        self.reuses = 0

    def seek(self, key: bytes) -> PID:
        if (self.pid != NULL_PID
                and (self.lo is None or key > self.lo)
                and (self.hi is None or key <= self.hi)):
            self.reuses += 1
            return self.pid
        tree = self.tree
        pool = tree.pool
        pid = tree.root_pid
        lo: Optional[bytes] = None
        hi: Optional[bytes] = None
        for _ in range(tree.height - 1):
            node = pool.get(pid)
            idx = node.child_index(key)
            # child idx owns (sep[idx-1], sep[idx]]; each level's bounds
            # are contained in the parent's, so present separators are
            # always the tighter ones.  Separator reads bisect the packed
            # directory in place — no key/child list is materialized.
            if idx > 0:
                lo = node.sep_at(idx - 1)
            if idx < node.sep_count():
                hi = node.sep_at(idx)
            pid = node.child_at(idx)
        self.pid, self.lo, self.hi = pid, lo, hi
        self.traversals += 1
        return pid

    def invalidate(self) -> None:
        self.pid = NULL_PID
        self.lo = self.hi = None


class BTree:
    def __init__(self, pool: BufferPool, log: LogManager,
                 root_pid: PID = NULL_PID, height: int = 1,
                 page_size: int = PAGE_SIZE):
        self.pool = pool
        self.log = log
        self.root_pid = root_pid
        self.height = height
        self.page_size = page_size
        self.smo_count = 0

    # ------------------------------------------------------------- bootstrap
    def create(self) -> None:
        """Make an empty tree (single leaf root); logged as an SMO so recovery
        can always rebuild placement meta from the log."""
        leaf = empty_leaf(self.pool.store.allocate_pid())
        self.root_pid = leaf.pid
        self.height = 1
        rec = SMORec(root_pid=self.root_pid,
                     next_pid=self.pool.store.next_pid,
                     height=self.height)
        lsn = self.log.append(rec)
        leaf.slsn = lsn
        rec.images = {leaf.pid: leaf.to_bytes()}
        self.pool.install_new(leaf, lsn)
        self.pool.mark_dirty(leaf.pid, lsn)

    # ------------------------------------------------------------------ find
    def find_leaf(self, key: bytes) -> PID:
        """Traverse to the leaf that owns ``key`` (the logical-redo step that
        physiological recovery gets to skip)."""
        pid = self.root_pid
        for _ in range(self.height - 1):
            node = self.pool.get(pid)
            assert node is not None and not node.is_leaf, f"malformed index @pid={pid}"
            pid = node.child_at(node.child_index(key))
        return pid

    def _path_to_leaf(self, key: bytes) -> list[PID]:
        path = [self.root_pid]
        pid = self.root_pid
        for _ in range(self.height - 1):
            node = self.pool.get(pid)
            pid = node.child_at(node.child_index(key))
            path.append(pid)
        return path

    def get(self, key: bytes) -> Optional[bytes]:
        leaf = self.pool.get(self.find_leaf(key))
        return leaf.get(key) if leaf is not None else None

    # ---------------------------------------------------------------- upsert
    def put(self, key: bytes, value: bytes, lsn: LSN) -> PID:
        """Insert or update; returns the PID of the leaf finally updated.

        If a split is needed, the SMO record is *appended before* the page
        mutations (WAL ordering) and its after-images are serialized *after*
        the triggering record operation, so the image state is exactly
        "all record ops with LSN <= image.plsn applied"."""
        path = self._path_to_leaf(key)
        leaf = self.pool.get(path[-1])
        from .pages import HEADER_SIZE, SLOT_OVERHEAD
        if HEADER_SIZE + len(key) + len(value) + SLOT_OVERHEAD > self.page_size:
            raise ValueError(
                f"record ({len(key)}+{len(value)}B) exceeds page size "
                f"{self.page_size}; use a larger page_size or smaller chunks")
        pending: list[tuple[SMORec, dict[PID, Page]]] = []
        guard = 0
        while leaf.would_overflow(key, value, self.page_size):
            pending.append(self._split(path, key))
            path = self._path_to_leaf(key)
            leaf = self.pool.get(path[-1])
            guard += 1
            assert guard < 64, "split did not converge"
        leaf.put(key, value, lsn)
        self.pool.mark_dirty(leaf.pid, lsn)
        for smo_rec, touched in pending:
            smo_rec.images = {pid: pg.to_bytes()
                              for pid, pg in touched.items()}
        return leaf.pid

    def delete(self, key: bytes, lsn: LSN) -> PID:
        pid = self.find_leaf(key)
        leaf = self.pool.get(pid)
        leaf.delete(key, lsn)
        self.pool.mark_dirty(pid, lsn)
        return pid

    # ----------------------------------------------------------------- scan
    def items(self) -> list[tuple[bytes, bytes]]:
        """Full ordered scan (used by equivalence checks)."""
        out: list[tuple[bytes, bytes]] = []

        def rec(pid: PID):
            node = self.pool.get(pid)
            if node.is_leaf:
                out.extend(node.sorted_items())
            else:
                for i in range(node.child_count()):
                    rec(node.child_at(i))
        if self.root_pid != NULL_PID:
            rec(self.root_pid)
        return out

    def range_items(self, lo: Optional[bytes] = None,
                    hi: Optional[bytes] = None,
                    limit: Optional[int] = None) -> list[tuple[bytes, bytes]]:
        """Ordered scan of keys in [lo, hi) (None = unbounded), stopping
        after ``limit`` records.  Internal nodes are pruned by their
        separator keys, so a narrow range touches only the pages it spans —
        this is the index path under ranged replica reads and the chunked
        fuzzy-snapshot scan."""
        out: list[tuple[bytes, bytes]] = []
        if self.root_pid == NULL_PID:
            return out

        def walk(pid: PID) -> bool:          # True = stop the whole scan
            node = self.pool.get(pid)
            if node.is_leaf:
                for k, v in node.sorted_items():
                    if hi is not None and k >= hi:
                        return True
                    if lo is None or k >= lo:
                        out.append((k, v))
                        if limit is not None and len(out) >= limit:
                            return True
                return False
            # child i owns (sep[i-1], sep[i]] — visit those intersecting
            last = node.child_count() - 1
            i0 = 0 if lo is None else node.child_index(lo)
            i1 = last if hi is None else min(node.child_index(hi), last)
            return any(walk(node.child_at(i)) for i in range(i0, i1 + 1))

        walk(self.root_pid)
        return out

    # ---------------------------------------------------------------- cursor
    def cursor(self) -> "LeafCursor":
        """Leaf-resident cursor for batched apply (``DataComponent.
        apply_batch``): keys presented in sorted order reuse the current
        leaf instead of re-traversing from the root."""
        return LeafCursor(self)

    # ------------------------------------------------------------------ SMO
    def _split(self, path: list[PID], key: bytes) -> tuple[SMORec, dict[PID, Page]]:
        """Split the leaf on ``path`` (and ancestors as needed).  Returns the
        (already appended) SMO record and the touched pages — the caller
        serializes images after applying the triggering record op."""
        touched: dict[PID, Page] = {}

        # WAL ordering: log record exists before any page mutation can be
        # flushed (flush forces the log up to the buffer's wal_lsn).
        rec = SMORec()
        lsn = self.log.append(rec)

        # The leaf stays pinned across the whole SMO: installing the new
        # pages below can trigger eviction, and a bounded pool must never
        # pick a frame that is mid-mutation.
        leaf_pid = path[-1]
        leaf = self.pool.get(leaf_pid, pin=True)
        new_leaf = empty_leaf(self.pool.store.allocate_pid())
        items = leaf.sorted_items()
        # Separator choice ("keys <= sep stay left"; sep need not be a stored
        # key).  Append-beyond-range gets an empty right page (bulk-append /
        # state-chunk pattern); prepend-below-range an empty left page;
        # otherwise split at the middle (updates that grow a record converge
        # by repeated halving onto a single-record leaf).
        if key > items[-1][0]:
            half, sep = len(items), items[-1][0]
        elif key < items[0][0]:
            half, sep = 0, key
        else:
            half = max(1, len(items) // 2)
            sep = items[half - 1][0]
        leaf.records = dict(items[:half])
        leaf.invalidate_sorted()
        new_leaf.records = dict(items[half:])
        new_leaf.invalidate_sorted()
        new_leaf.plsn = leaf.plsn         # data state inherited, plsn preserved
        leaf.slsn = lsn
        new_leaf.slsn = lsn
        self.pool.install_new(new_leaf, lsn)
        touched[leaf.pid] = leaf
        touched[new_leaf.pid] = new_leaf
        self.pool.mark_dirty(leaf.pid, lsn)
        self.pool.mark_dirty(new_leaf.pid, lsn)
        self.pool.unpin(leaf_pid)

        # push separator up the path
        up_key: Optional[bytes] = sep
        up_child: PID = new_leaf.pid
        level = len(path) - 2
        while up_key is not None:
            if level < 0:
                root = empty_internal(self.pool.store.allocate_pid())
                root.keys = [up_key]
                root.children = [path[0], up_child]
                root.invalidate_sorted()
                root.slsn = lsn
                self.root_pid = root.pid
                self.height += 1
                self.pool.install_new(root, lsn)
                self.pool.mark_dirty(root.pid, lsn)
                touched[root.pid] = root
                break
            node_pid = path[level]
            node = self.pool.get(node_pid, pin=True)
            idx = node.child_index(up_key)
            node.keys.insert(idx, up_key)
            node.children.insert(idx + 1, up_child)
            node.invalidate_sorted()
            node.slsn = lsn
            touched[node_pid] = node
            self.pool.mark_dirty(node_pid, lsn)
            if node.serialized_size() <= self.page_size:
                up_key = None
                self.pool.unpin(node_pid)
            else:
                new_node = empty_internal(self.pool.store.allocate_pid())
                mid = len(node.keys) // 2
                up_key = node.keys[mid]
                new_node.keys = node.keys[mid + 1:]
                new_node.children = node.children[mid + 1:]
                new_node.invalidate_sorted()
                new_node.slsn = lsn
                node.keys = node.keys[:mid]
                node.children = node.children[:mid + 1]
                node.invalidate_sorted()
                self.pool.install_new(new_node, lsn)
                self.pool.mark_dirty(new_node.pid, lsn)
                self.pool.unpin(node_pid)
                touched[new_node.pid] = new_node
                up_child = new_node.pid
                level -= 1

        rec.root_pid = self.root_pid
        rec.next_pid = self.pool.store.next_pid
        rec.height = self.height
        self.smo_count += 1
        return rec, touched

    # ----------------------------------------------------------- DC recovery
    def redo_smo(self, rec: SMORec) -> None:
        """Idempotent SMO replay: restore any image whose structure is newer
        than the cached/stable copy; adopt the record's placement meta."""
        for pid, raw in rec.images.items():
            img = Page.from_bytes(raw)
            cur = self.pool.get(pid)
            if cur is None or cur.slsn < rec.lsn:
                if cur is None:
                    self.pool.install_new(img, rec.lsn)
                else:
                    self.pool.buffers[pid].page = img
                self.pool.mark_dirty(pid, rec.lsn)
        self.root_pid = rec.root_pid
        self.height = rec.height
        self.pool.store.set_next_pid(rec.next_pid)

    # ------------------------------------------------------------- structure
    def index_pids(self) -> list[PID]:
        """PIDs of all internal (index) pages — what Log2 bulk-preloads.
        Depth-bounded: never touches leaf pages (leaves are the data pages
        whose fetches the DPT machinery exists to avoid)."""
        out: list[PID] = []

        def rec(pid: PID, depth: int):
            if depth >= self.height:        # children are leaves
                return
            out.append(pid)
            node = self.pool.get(pid)
            if node is None or node.is_leaf:
                return
            for i in range(node.child_count()):
                rec(node.child_at(i), depth + 1)
        if self.root_pid != NULL_PID and self.height > 1:
            rec(self.root_pid, 1)
        return out

    def leaf_pids(self) -> list[PID]:
        out: list[PID] = []

        def rec(pid: PID):
            node = self.pool.get(pid)
            if node.is_leaf:
                out.append(pid)
            else:
                for i in range(node.child_count()):
                    rec(node.child_at(i))
        if self.root_pid != NULL_PID:
            rec(self.root_pid)
        return out

    def n_leaves(self) -> int:
        return len(self.leaf_pids())
