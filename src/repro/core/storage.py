"""Stable page store + the IO cost simulator.

The store holds *serialized* pages only (what survives a crash).  A
deterministic discrete-time disk model prices every access so recovery
strategies can be compared by modeled wall time as in the paper (whose costs
are IO-count driven — Appendix B, Eq. 1-3) even though this container serves
everything from RAM.

Model (defaults tuned to commodity-2011 disk behaviour, configurable):
  * random (sync, demand) page read ............ ``t_rand``      (8 ms)
  * sequential log page read ................... ``t_seq``       (0.5 ms)
  * block read of <=8 contiguous pages ......... ``t_block``     (10 ms, 1 IO)
  * async prefetch: ``width`` concurrent requests; a demand hit on an
    in-flight page stalls only for its residual service time.

The simulator keeps a single clock per recovery run; prefetch IOs complete in
issue order on ``width`` independent channels.
"""
from __future__ import annotations

import heapq
from collections import OrderedDict
from dataclasses import dataclass, field
from typing import TYPE_CHECKING, Dict, Iterable, Optional

if TYPE_CHECKING:  # core sits below media in the import layering
    from ..media.backend import MediaBackend

from ..obs import metrics as _metrics
from ..obs.flightrec import FLIGHT as _FLIGHT
from ..obs.trace import TRACER as _TRACER
from .pages import Page
from .records import NULL_PID, PID


@dataclass
class IOStats:
    sync_reads: int = 0            # demand-fetch random reads (stalled)
    prefetch_reads: int = 0        # pages brought in by prefetch IOs
    prefetch_ios: int = 0          # physical prefetch requests (blocks count 1)
    prefetch_hits: int = 0         # demand requests satisfied with zero stall
    partial_stalls: int = 0        # demand hit an in-flight prefetch
    log_pages: int = 0
    page_writes: int = 0
    modeled_ms: float = 0.0

    def total_reads(self) -> int:
        return self.sync_reads + self.prefetch_reads


@dataclass
class DiskModel:
    t_rand: float = 8.0
    t_seq: float = 0.5
    t_block: float = 10.0
    block_size: int = 8
    width: int = 4                 # concurrent prefetch channels


class IOSim:
    """Discrete-time disk: demand reads advance the clock; prefetches are
    queued onto ``width`` channels and overlap with redo 'work'."""

    def __init__(self, model: Optional[DiskModel] = None):
        self.m = model or DiskModel()
        self.stats = IOStats()
        self.clock = 0.0
        self._channels = [0.0] * self.m.width       # per-channel busy-until
        self._inflight: Dict[PID, float] = {}       # pid -> completion time
        self._done: set[PID] = set()                # prefetched & completed

    # -------------------------------------------------------------- demand IO
    def demand_read(self, pid: PID) -> None:
        """Synchronous random read of one page (redo stalls).

        When tracing is enabled, each demand (consume) is emitted as an
        ``io.demand`` event carrying the *modeled* clock and outcome, so
        true per-record prefetch overlap can be computed from the trace
        (``prefetch_overlap``) instead of inferred from aggregate hit
        counters."""
        t0 = self.clock
        if pid in self._done:
            self.stats.prefetch_hits += 1
            self._done.discard(pid)
            _FLIGHT.record("io.demand", pid, 0)
            if _TRACER.enabled:
                _TRACER.event("io.demand", pid=pid, outcome="hit",
                              clock=round(t0, 3))
            return
        t = self._inflight.pop(pid, None)
        if t is not None:
            # stall only for the residual prefetch time
            if t > self.clock:
                self.stats.partial_stalls += 1
                self.clock = t
                outcome = "partial"
                _FLIGHT.record("io.demand", pid, 1, self.clock - t0)
            else:
                self.stats.prefetch_hits += 1
                outcome = "hit"
                _FLIGHT.record("io.demand", pid, 0)
            self._done.discard(pid)
            if _TRACER.enabled:
                _TRACER.event("io.demand", pid=pid, outcome=outcome,
                              clock=round(t0, 3),
                              stall_ms=round(self.clock - t0, 3))
            return
        self.stats.sync_reads += 1
        self.clock += self.m.t_rand
        _FLIGHT.record("io.demand", pid, 2, self.m.t_rand)
        if _TRACER.enabled:
            _TRACER.event("io.demand", pid=pid, outcome="sync",
                          clock=round(t0, 3), stall_ms=self.m.t_rand)

    def log_read(self, n_pages: int = 1) -> None:
        self.stats.log_pages += n_pages
        self.clock += n_pages * self.m.t_seq

    def write(self, n_pages: int = 1) -> None:
        self.stats.page_writes += n_pages

    # ------------------------------------------------------------- prefetch IO
    def prefetch(self, pids: Iterable[PID], contiguous: bool = False) -> None:
        """Issue an async read.  Contiguous runs of <= block_size pages cost a
        single block IO (SQL Server's 8-page blocks, Appendix A)."""
        pids = [p for p in pids if p not in self._done and p not in self._inflight]
        if not pids:
            return
        groups: list[list[PID]] = []
        if contiguous:
            run: list[PID] = []
            for p in sorted(pids):
                if run and (p != run[-1] + 1 or len(run) >= self.m.block_size):
                    groups.append(run)
                    run = []
                run.append(p)
            if run:
                groups.append(run)
        else:
            groups = [[p] for p in pids]
        for g in groups:
            ch = min(range(len(self._channels)), key=self._channels.__getitem__)
            start = max(self.clock, self._channels[ch])
            cost = self.m.t_block if len(g) > 1 else self.m.t_rand
            fin = start + cost
            self._channels[ch] = fin
            self.stats.prefetch_ios += 1
            self.stats.prefetch_reads += len(g)
            for p in g:
                self._inflight[p] = fin
            _FLIGHT.record("io.prefetch", g[0], len(g))
            if _TRACER.enabled:
                _TRACER.event("io.prefetch.issue", pids=list(g),
                              clock=round(self.clock, 3), fin=round(fin, 3))

    def work(self, ms: float) -> None:
        """Non-IO redo work advances the clock (lets prefetch overlap)."""
        self.clock += ms
        done = [p for p, t in self._inflight.items() if t <= self.clock]
        for p in done:
            self._done.add(p)
            del self._inflight[p]

    def finish(self) -> IOStats:
        self.stats.modeled_ms = self.clock
        return self.stats


# --------------------------------------------------------------------------
# trace-derived IO analysis (the honest view the batched-mode pacing fix is
# validated against)
def issue_schedule(events) -> list:
    """Prefetch issue order from traced events: the list of pid groups, in
    issue order.  Pacing parity between per-record and batched redo means
    identical schedules here — issue *clocks* may legitimately differ,
    because demand stalls advance the modeled clock at different points."""
    return [tuple(e["attrs"]["pids"]) for e in events
            if e.get("name") == "io.prefetch.issue"]


def prefetch_overlap(events) -> dict:
    """True prefetch overlap from traced issue/consume events.

    ``overlap`` is the fraction of demand reads fully absorbed by prefetch
    (outcome "hit"); ``stall_ms`` sums the modeled time redo actually
    waited (partial stalls + sync reads)."""
    issued = consumed = hits = partials = syncs = 0
    stall = 0.0
    for e in events:
        name = e.get("name")
        if name == "io.prefetch.issue":
            issued += len(e["attrs"]["pids"])
        elif name == "io.demand":
            consumed += 1
            a = e["attrs"]
            o = a["outcome"]
            if o == "hit":
                hits += 1
            elif o == "partial":
                partials += 1
                stall += a.get("stall_ms", 0.0)
            else:
                syncs += 1
                stall += a.get("stall_ms", 0.0)
    return {"issued": issued, "consumed": consumed, "hits": hits,
            "partials": partials, "syncs": syncs,
            "stall_ms": round(stall, 3),
            "overlap": round(hits / consumed, 4) if consumed else 0.0}


_C_DECODE_HITS = _metrics.counter("pagestore.decode_hits")
_C_DECODE_MISSES = _metrics.counter("pagestore.decode_misses")


def _blob_name(pid: PID) -> str:
    return f"page/{pid:012d}"


class PageStore:
    """Crash-stable storage: serialized pages + a tiny 'master' blob, all
    living as named blobs (``page/<pid>``) on a ``MediaBackend`` — a dict
    in the default ``MemoryBackend`` case, files with atomic publication
    under a ``DirectoryBackend``.  The page tier therefore sits behind the
    same storage boundary as segments and snapshots, and a page set larger
    than memory is the backend's problem, not the pool's.

    ``clone()`` snapshots the stable state (used to build crash images that
    several recovery strategies each recover independently)."""

    # decoded pages cached at most this many before LRU eviction —
    # replaced page versions would otherwise accumulate forever
    DECODE_CACHE_MAX = 1 << 16

    def __init__(self, backend: Optional["MediaBackend"] = None) -> None:
        if backend is None:
            from ..media.backend import MemoryBackend
            backend = MemoryBackend()
        self.backend = backend
        # pid index mirroring the backend's page blobs: membership tests
        # and ``pids()`` stay O(1)/O(n) with zero backend round-trips, and
        # a missing page is an answer (None), never a swallowed
        # BackendMissingError
        self._pids: set[PID] = {int(name[5:])
                                for name in backend.list("page/")}
        # decoded-page cache, keyed by the raw serialized bytes:
        # deserializing a page is many times the cost of copying one, and
        # recovery / replicas / restores re-read the same images over and
        # over.  Content addressing makes sharing safe — a clone holds the
        # *same* bytes objects until it diverges, so crash images share
        # hits, while any write produces new bytes and thus a new key;
        # entries are private snapshots (reads hand out copies), so crash
        # semantics still flow through the serialized form only.  Ordered
        # for LRU eviction: overflow drops the coldest entry, never the
        # whole cache (a wholesale clear caused cold-miss bursts
        # mid-recovery).
        self._decoded: OrderedDict[bytes, Page] = OrderedDict()
        self.decode_hits = 0            # this instance's cache outcomes —
        self.decode_misses = 0          # the cache *object* may be shared
        # eager_decode materializes the dict form at decode time — the
        # pre-packed behaviour, kept as the measured benchmark baseline
        self.eager_decode = False
        self._next_pid: PID = max(self._pids, default=0) + 1
        self.master: dict = {}          # e.g. {'rssp_rec_lsn': ..., 'ckpt_lsn': ...}

    # allocation happens in the DC (volatile counter persisted via RSSP/SMO recs)
    def allocate_pid(self) -> PID:
        pid = self._next_pid
        self._next_pid += 1
        return pid

    def set_next_pid(self, nxt: PID) -> None:
        self._next_pid = max(self._next_pid, nxt)

    @property
    def next_pid(self) -> PID:
        return self._next_pid

    def write_page(self, page: Page) -> None:
        # the caller's object stays live and mutable — never cache it; the
        # new bytes simply miss the content-keyed cache until re-read
        self.backend.put(_blob_name(page.pid), page.to_bytes())
        self._pids.add(page.pid)

    def read_page(self, pid: PID) -> Optional[Page]:
        if pid not in self._pids:
            return None
        raw = self.backend.get(_blob_name(pid))
        cached = self._decoded.get(raw)
        if cached is None:
            if len(self._decoded) >= self.DECODE_CACHE_MAX:
                self._decoded.popitem(last=False)   # LRU, not a full clear
            cached = Page.from_bytes(raw)           # CRC-checked
            if self.eager_decode:
                cached.materialize()
            self._decoded[raw] = cached
            self.decode_misses += 1
            _C_DECODE_MISSES.inc()
        else:
            self._decoded.move_to_end(raw)
            self.decode_hits += 1
            _C_DECODE_HITS.inc()
            if cached._records is None:
                # second touch: the entry is hot, so promote it to dual
                # form — one parse here and every later copy() is a
                # C-speed container copy (still sharing the raw bytes, so
                # clean copies keep flushing in O(1)).  First touches stay
                # zero-decode: a page read once never pays a parse.
                cached.prewarm()
        return cached.copy()

    def has_page(self, pid: PID) -> bool:
        return pid in self._pids

    def pids(self):
        return self._pids

    def clone(self) -> "PageStore":
        from ..media.backend import MemoryBackend
        b = self.backend
        if isinstance(b, MemoryBackend):
            backend = b.snapshot()      # shares the immutable blob bytes
        else:
            backend = MemoryBackend()   # materialize a point-in-time copy
            for name in b.list("page/"):
                backend.put(name, b.get(name))
        s = PageStore(backend)
        # content-keyed, so sharing the cache *object* is safe across
        # divergence — recovering N strategies from one crash image decodes
        # each page once, not N times
        s._decoded = self._decoded
        s.eager_decode = self.eager_decode
        s._next_pid = self._next_pid
        s.master = dict(self.master)
        return s

    def __len__(self) -> int:
        return len(self._pids)
