"""Database cache (buffer pool) with dirty tracking, WAL enforcement, LRU
eviction, and the penultimate-checkpoint "generation bit" scheme (Section 3.2).

Listeners let the DC's Delta accumulator and the SQL-Server BW tracker observe
page dirtying / flush completions without the pool knowing about logging.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

from .log import LogManager
from .pages import Page
from .records import LSN, NULL_LSN, PID
from .storage import IOSim, PageStore


@dataclass(slots=True)
class Buffer:
    page: Page
    dirty: bool = False
    rlsn: LSN = NULL_LSN          # LSN of op that first dirtied this buffer
    wal_lsn: LSN = NULL_LSN       # max LSN applied (incl. SMOs) — WAL horizon
    dirty_gen: int = -1           # checkpoint generation when first dirtied
    tick: int = 0                 # LRU clock


class BufferPool:
    def __init__(self, store: PageStore, log: LogManager, capacity_pages: int = 1 << 30):
        self.store = store
        self.log = log
        self.capacity = capacity_pages
        self.buffers: Dict[PID, Buffer] = {}
        self._tick = 0
        self.gen = 0                               # checkpoint generation bit
        # listeners
        self.on_update: list[Callable[[PID, LSN], None]] = []   # every page update
        self.on_flush: list[Callable[[PID], None]] = []          # flush IO complete
        # stats
        self.fetches = 0
        self.evictions = 0
        self.flushes = 0
        # recovery-time IO accounting hook
        self.iosim: Optional[IOSim] = None

    # ------------------------------------------------------------------ fetch
    def get(self, pid: PID) -> Optional[Page]:
        self._tick += 1
        buf = self.buffers.get(pid)
        if buf is not None:
            buf.tick = self._tick
            return buf.page
        page = self.store.read_page(pid)
        if page is None:
            return None
        if self.iosim is not None:
            self.iosim.demand_read(pid)
        self.fetches += 1
        self._install(page, dirty=False)
        return page

    def contains(self, pid: PID) -> bool:
        return pid in self.buffers

    def install_new(self, page: Page, lsn: LSN) -> None:
        """Install a freshly allocated page (born dirty)."""
        self._install(page, dirty=True, rlsn=lsn)

    def _install(self, page: Page, dirty: bool, rlsn: LSN = NULL_LSN) -> None:
        self._evict_for_space()
        self._tick += 1
        self.buffers[page.pid] = Buffer(page=page, dirty=dirty, rlsn=rlsn,
                                        wal_lsn=rlsn,
                                        dirty_gen=self.gen if dirty else -1,
                                        tick=self._tick)

    # ------------------------------------------------------------------ dirty
    def mark_dirty(self, pid: PID, lsn: LSN) -> None:
        buf = self.buffers[pid]
        if not buf.dirty:
            buf.dirty = True
            buf.rlsn = lsn
            buf.dirty_gen = self.gen
        if lsn > buf.wal_lsn:
            buf.wal_lsn = lsn
        for cb in self.on_update:
            cb(pid, lsn)

    # ------------------------------------------------------------------ flush
    def flush_page(self, pid: PID) -> bool:
        buf = self.buffers.get(pid)
        if buf is None or not buf.dirty:
            return False
        # WAL: a page may not reach stable storage before the log records that
        # produced its state — including SMOs, hence wal_lsn not plsn.  (EOSL
        # gives the DC the TC stable point; here the integrated log is forced
        # directly.)
        if buf.wal_lsn > self.log.stable_lsn:
            self.log.flush(buf.wal_lsn)
        self.store.write_page(buf.page)
        buf.dirty = False
        buf.rlsn = NULL_LSN
        buf.dirty_gen = -1
        self.flushes += 1
        for cb in self.on_flush:
            cb(pid)
        return True

    def flush_some(self, max_pages: int) -> int:
        """Background flusher: write the oldest-dirtied pages (rate-limited).
        This is the training-framework 'fuzzy incremental checkpoint' driver."""
        dirty = [(b.rlsn, pid) for pid, b in self.buffers.items() if b.dirty]
        dirty.sort()
        n = 0
        for _, pid in dirty[:max_pages]:
            if self.flush_page(pid):
                n += 1
        return n

    # ------------------------------------------------------------- checkpoint
    def begin_checkpoint_flush(self) -> int:
        """Penultimate scheme: flip the generation bit, then flush every page
        dirtied in an earlier generation.  Pages dirtied *during* the
        checkpoint keep the new generation and are left dirty."""
        self.gen += 1
        flushed = 0
        victims = [pid for pid, b in self.buffers.items()
                   if b.dirty and b.dirty_gen < self.gen]
        for pid in victims:
            if self.flush_page(pid):
                flushed += 1
        return flushed

    # --------------------------------------------------------------- eviction
    def _evict_for_space(self) -> None:
        while len(self.buffers) >= self.capacity:
            # prefer clean LRU victim; else flush the LRU dirty page
            clean = [(b.tick, pid) for pid, b in self.buffers.items() if not b.dirty]
            if clean:
                _, victim = min(clean)
            else:
                _, victim = min((b.tick, pid) for pid, b in self.buffers.items())
                self.flush_page(victim)
            del self.buffers[victim]
            self.evictions += 1

    # ------------------------------------------------------------------ misc
    def dirty_pids(self) -> list[PID]:
        return [pid for pid, b in self.buffers.items() if b.dirty]

    def reset_stats(self) -> None:
        self.fetches = self.evictions = self.flushes = 0

    def __len__(self) -> int:
        return len(self.buffers)
