"""Bounded database cache (buffer pool): CLOCK eviction, frame pins, dirty
tracking, WAL enforcement, and the penultimate-checkpoint "generation bit"
scheme (Section 3.2).

The pool is the only path between decoded pages and the ``PageStore``
(whose bytes live as ``page/<pid>`` blobs on a ``MediaBackend``), so
bounded residency is real: at most ``capacity_pages`` frames are decoded
at once, pinned frames (a ``LeafCursor`` span mid-mutation, a split in
flight) are never victims, clean victims drop for free, and dirty victims
flush through the WAL clamp — the log is forced up to the buffer's
``wal_lsn`` before the page may reach stable storage.

Listeners let the DC's Delta accumulator and the SQL-Server BW tracker observe
page dirtying / flush completions without the pool knowing about logging.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Dict, Optional

from ..obs import metrics as _metrics
from ..obs.flightrec import FLIGHT as _FLIGHT
from .log import LogManager
from .pages import Page
from .records import LSN, NULL_LSN, PID
from .storage import IOSim, PageStore

_C_HITS = _metrics.counter("bufferpool.hits")
_C_MISSES = _metrics.counter("bufferpool.misses")
_C_EVICTIONS = _metrics.counter("bufferpool.evictions")
_C_FLUSHES = _metrics.counter("bufferpool.flushes")
_C_FLUSH_FAILURES = _metrics.counter("bufferpool.flush_failures")
_G_PINNED = _metrics.gauge("bufferpool.pinned")


@dataclass(slots=True)
class Buffer:
    page: Page
    dirty: bool = False
    rlsn: LSN = NULL_LSN          # LSN of op that first dirtied this buffer
    wal_lsn: LSN = NULL_LSN       # max LSN applied (incl. SMOs) — WAL horizon
    dirty_gen: int = -1           # checkpoint generation when first dirtied
    pins: int = 0                 # pinned frames are never eviction victims
    ref: bool = True              # CLOCK reference bit
    bg_flush_tick: int = -2       # flush_some round that last wrote this page


class BufferPool:
    def __init__(self, store: PageStore, log: LogManager,
                 capacity_pages: int = 1 << 30, retry=None):
        self.store = store
        self.log = log
        self.capacity = capacity_pages
        # a ``faults.RetryPolicy`` mediating transient page-write failures
        # (the store may sit on a remote MediaBackend).  Duck-typed and
        # optional: core must not import faults at module load.
        self.retry = retry
        self.buffers: Dict[PID, Buffer] = {}
        self._clock: list[PID] = []        # CLOCK ring (lazy compaction)
        self._hand = 0
        self._flush_tick = 0               # flush_some round counter
        self.gen = 0                               # checkpoint generation bit
        # listeners
        self.on_update: list[Callable[[PID, LSN], None]] = []   # every page update
        self.on_flush: list[Callable[[PID], None]] = []          # flush IO complete
        # stats
        self.hits = 0
        self.fetches = 0              # misses (store reads), historical name
        self.evictions = 0
        self.flushes = 0
        self.flush_failures = 0       # transient write failures (page stayed
        #                               dirty + resident; nothing was lost)
        self.pinned_count = 0
        self.peak_resident = 0        # max frames ever resident at once
        # recovery-time IO accounting hook
        self.iosim: Optional[IOSim] = None

    # ------------------------------------------------------------------ fetch
    def get(self, pid: PID, pin: bool = False) -> Optional[Page]:
        buf = self.buffers.get(pid)
        if buf is not None:
            buf.ref = True
            self.hits += 1
            _C_HITS.inc()
            if pin:
                self._pin(buf)
            return buf.page
        if self.retry is None:
            page = self.store.read_page(pid)
        else:
            # demand reads are as retryable as flushes: the backend, not
            # the bytes, failed — bounded backoff beats a dead read path
            page = self.retry.call(self.store.read_page, pid)
        if page is None:
            return None
        if self.iosim is not None:
            self.iosim.demand_read(pid)
        self.fetches += 1
        _C_MISSES.inc()
        buf = self._install(page, dirty=False)
        if pin:
            self._pin(buf)
        return page

    def contains(self, pid: PID) -> bool:
        return pid in self.buffers

    def install_new(self, page: Page, lsn: LSN) -> None:
        """Install a freshly allocated page (born dirty)."""
        self._install(page, dirty=True, rlsn=lsn)

    def _install(self, page: Page, dirty: bool,
                 rlsn: LSN = NULL_LSN) -> Buffer:
        self._evict_for_space()
        buf = Buffer(page=page, dirty=dirty, rlsn=rlsn, wal_lsn=rlsn,
                     dirty_gen=self.gen if dirty else -1)
        if page.pid not in self.buffers:
            self._clock.append(page.pid)
        self.buffers[page.pid] = buf
        if len(self.buffers) > self.peak_resident:
            self.peak_resident = len(self.buffers)
        return buf

    # ------------------------------------------------------------------- pins
    def _pin(self, buf: Buffer) -> None:
        buf.pins += 1
        self.pinned_count += 1
        _G_PINNED.inc()

    def pin(self, pid: PID) -> None:
        self._pin(self.buffers[pid])

    def unpin(self, pid: PID) -> None:
        buf = self.buffers[pid]
        assert buf.pins > 0, f"unpin of unpinned frame {pid}"
        buf.pins -= 1
        self.pinned_count -= 1
        _G_PINNED.inc(-1)

    # ------------------------------------------------------------------ dirty
    def mark_dirty(self, pid: PID, lsn: LSN) -> None:
        buf = self.buffers[pid]
        if not buf.dirty:
            buf.dirty = True
            buf.rlsn = lsn
            buf.dirty_gen = self.gen
        if lsn > buf.wal_lsn:
            buf.wal_lsn = lsn
        for cb in self.on_update:
            cb(pid, lsn)

    # ------------------------------------------------------------------ flush
    def flush_page(self, pid: PID) -> bool:
        buf = self.buffers.get(pid)
        if buf is None or not buf.dirty:
            return False
        # WAL: a page may not reach stable storage before the log records that
        # produced its state — including SMOs, hence wal_lsn not plsn.  (EOSL
        # gives the DC the TC stable point; here the integrated log is forced
        # directly.)
        if buf.wal_lsn > self.log.stable_lsn:
            self.log.flush(buf.wal_lsn)
        # call-time import: core loads before media (package layering)
        from ..media.errors import BackendUnavailableError
        try:
            if self.retry is None:
                self.store.write_page(buf.page)
            else:
                self.retry.call(self.store.write_page, buf.page)
        except BackendUnavailableError:
            # the write never happened: the buffer stays dirty (its state
            # was not touched above), stays resident, and keeps serving
            # reads — account the failure and let the caller decide
            # whether this flush was optional (background cadence) or not
            self.flush_failures += 1
            _C_FLUSH_FAILURES.inc()
            _FLIGHT.record("pool.flush_fail", pid, buf.wal_lsn)
            raise
        buf.dirty = False
        buf.rlsn = NULL_LSN
        buf.dirty_gen = -1
        self.flushes += 1
        _C_FLUSHES.inc()
        _FLIGHT.record("pool.flush", pid, buf.wal_lsn)
        for cb in self.on_flush:
            cb(pid)
        return True

    def flush_some(self, max_pages: int) -> int:
        """Background flusher: write the oldest-dirtied pages (rate-limited).
        This is the training-framework 'fuzzy incremental checkpoint' driver.

        Hot-page coalescing: a page this flusher wrote last round and that
        is dirty again already is hot — writing it every round is wasted
        serialization (it will be dirty again before any crash cares), so
        it sits out one round and flushes every other.  Cold pages are
        unaffected: with a large dirty set the rate limit never re-picks
        the same page on consecutive rounds anyway.  Correctness is
        untouched — any flush schedule is WAL-legal, a skipped page just
        stays in the DPT one round longer."""
        self._flush_tick += 1
        tick = self._flush_tick
        dirty = [(b.rlsn, pid) for pid, b in self.buffers.items()
                 if b.dirty and b.bg_flush_tick < tick - 1]
        dirty.sort()
        n = 0
        from ..media.errors import BackendUnavailableError
        for _, pid in dirty[:max_pages]:
            try:
                flushed = self.flush_page(pid)
            except BackendUnavailableError:
                # background flushing is optional by construction (any
                # flush schedule is WAL-legal): the page stays dirty and
                # the next round retries it.  flush_page accounted the
                # failure; outage-wide pressure shows up as a flush_failures
                # ramp, not a dead pool.
                continue
            if flushed:
                self.buffers[pid].bg_flush_tick = tick
                n += 1
        return n

    # ------------------------------------------------------------- checkpoint
    def begin_checkpoint_flush(self) -> int:
        """Penultimate scheme: flip the generation bit, then flush every page
        dirtied in an earlier generation.  Pages dirtied *during* the
        checkpoint keep the new generation and are left dirty."""
        self.gen += 1
        flushed = 0
        victims = [pid for pid, b in self.buffers.items()
                   if b.dirty and b.dirty_gen < self.gen]
        for pid in victims:
            if self.flush_page(pid):
                flushed += 1
        return flushed

    # --------------------------------------------------------------- eviction
    def _evict_for_space(self) -> None:
        from ..media.errors import BackendUnavailableError
        failing: set[PID] = set()      # dirty victims whose flush failed
        last_exc: Optional[Exception] = None
        while len(self.buffers) >= self.capacity:
            victim = self._clock_sweep(skip=failing)
            if victim is None:
                if last_exc is not None:
                    # every evictable frame is dirty and every flush
                    # failed: the pool genuinely cannot make space, and
                    # soft-overflowing would hide a full outage — raise
                    # the last transient error instead
                    raise last_exc
                # every frame is pinned: overflow softly rather than
                # deadlock — pins are short (one mutation window)
                break
            try:
                self._evict(victim)
            except BackendUnavailableError as exc:
                # the victim stayed resident and dirty (flush_page left
                # it intact); put it back in the ring, remember it as
                # failing, back off once, and sweep for a different
                # victim — a clean frame costs no IO and always works
                self._clock.append(victim)
                failing.add(victim)
                last_exc = exc
                if self.retry is not None:
                    self.retry.backoff(min(len(failing),
                                           self.retry.max_attempts))

    def _clock_sweep(self, skip: Optional[set] = None) -> Optional[PID]:
        """Advance the CLOCK hand to a victim: referenced frames get a
        second chance, pinned frames (and ``skip`` members — victims whose
        flush just failed) are never picked, clean frames are preferred (a
        dirty victim costs a flush IO); the first unreferenced dirty frame
        is remembered as the fallback."""
        clock = self._clock
        fallback: Optional[PID] = None
        steps = 0
        limit = 3 * len(clock) + 1
        while clock and steps < limit:
            steps += 1
            if self._hand >= len(clock):
                self._hand = 0
            pid = clock[self._hand]
            buf = self.buffers.get(pid)
            if buf is None:                       # lazily compact stale slot
                clock[self._hand] = clock[-1]
                clock.pop()
                continue
            if buf.pins or (skip is not None and pid in skip):
                self._hand += 1
                continue
            if buf.ref:
                buf.ref = False
                self._hand += 1
                continue
            if not buf.dirty:
                clock[self._hand] = clock[-1]
                clock.pop()
                return pid
            if fallback is None:
                fallback = pid
            self._hand += 1
        if fallback is not None:
            self._clock.remove(fallback)          # rare: all-victims-dirty
            return fallback
        return None

    def _evict(self, pid: PID) -> None:
        buf = self.buffers[pid]
        was_dirty = buf.dirty
        if was_dirty:
            self.flush_page(pid)                  # WAL-clamped inside
        del self.buffers[pid]
        self.evictions += 1
        _C_EVICTIONS.inc()
        _FLIGHT.record("pool.evict", pid, 1 if was_dirty else 0)

    # ------------------------------------------------------------------ misc
    def dirty_pids(self) -> list[PID]:
        return [pid for pid, b in self.buffers.items() if b.dirty]

    def reset_stats(self) -> None:
        self.hits = self.fetches = self.evictions = self.flushes = 0
        self.peak_resident = len(self.buffers)

    def __len__(self) -> int:
        return len(self.buffers)
