"""Crash recovery: the five strategies of the paper's performance study
(Section 5.2), all consuming the same crash image + common log.

  Log0: basic logical redo (Algorithm 2) — traverse + fetch every page.
  Log1: logical redo with the Delta-record DPT (Algorithms 4+5).
  Log2: Log1 + index-page preload + PF-list data prefetch (Appendix A).
  SQL1: physiological redo with the BW-record DPT (Algorithms 1+3).
  SQL2: SQL1 + log-driven data prefetch.

Every strategy shares: the SMO replay pass (well-formed B-tree / index pages —
"the only difference in methods is the time at which these SMO recovery
operations are executed", Section 2.1), the analysis scan that builds the
transaction table, and the final logical undo pass for loser transactions.
"""
from __future__ import annotations

import enum
import time
from dataclasses import dataclass, field
from typing import Optional, Union

from ..obs import metrics as obs_metrics
from ..obs.flightrec import FLIGHT as _FLIGHT
from ..obs.flightrec import auto_dump as _flight_dump
from ..obs.trace import TRACER as _TRACER
from .dc import DataComponent, RedoStats, make_key, rec_key
from .dpt import DPT, build_dpt_sql
from .log import LogManager
from .records import (LSN, NULL_LSN, AbortRec, BeginCkptRec, CLRRec,
                      CommitRec, DeltaRec, EndCkptRec, RecKind, UpdateRec)
from .storage import DiskModel, IOSim, IOStats, PageStore
from .tc import CrashImage, Database, TransactionalComponent


class Strategy(enum.Enum):
    LOG0 = "Log0"
    LOG1 = "Log1"
    LOG2 = "Log2"
    SQL1 = "SQL1"
    SQL2 = "SQL2"

    @property
    def logical(self) -> bool:
        return self in (Strategy.LOG0, Strategy.LOG1, Strategy.LOG2)

    @property
    def uses_dpt(self) -> bool:
        return self is not Strategy.LOG0

    @property
    def prefetches(self) -> bool:
        return self in (Strategy.LOG2, Strategy.SQL2)


@dataclass
class RecoveryStats:
    strategy: str = ""
    scan_from: LSN = NULL_LSN
    log_records: int = 0
    dpt_size: int = 0
    redo: RedoStats = field(default_factory=RedoStats)
    io: IOStats = field(default_factory=IOStats)
    index_fetches: int = 0
    losers: int = 0
    undone_ops: int = 0
    analysis_ms: float = 0.0
    redo_wall_ms: float = 0.0
    total_wall_ms: float = 0.0
    modeled_redo_ms: float = 0.0
    batched: bool = False            # sorted bulk apply inside each window
    batch_window: int = 0            # redo-window size (records)
    peak_window_records: int = 0     # max redo records buffered at once
    windows: int = 0                 # redo windows flushed
    cursor_traversals: int = 0       # batched mode: root-to-leaf walks
    cursor_reuses: int = 0           # batched mode: leaf-resident hits
    pool_capacity: int = 0           # buffer-pool frame budget for the run
    pool_peak_resident: int = 0      # max frames resident at once (<= cap)
    pool_evictions: int = 0          # frames evicted to stay under budget
    pool_flushes: int = 0            # dirty-page writes (incl. evictions)

    def publish(self, registry=None) -> None:
        """Mirror every numeric field (nested redo/io included) into the
        process-wide registry as ``recovery.*`` gauges — last run wins."""
        obs_metrics.publish_dataclass(self, "recovery", registry)

    @classmethod
    def from_registry(cls, registry=None) -> "RecoveryStats":
        """The registry-backed view of the most recent published run
        (numeric fields only; ``strategy`` keeps its default)."""
        return obs_metrics.load_dataclass(cls, "recovery", registry)


# --------------------------------------------------------------------------
# (The ARIES analysis state machine — EndCkpt seeding, Update/CLR advance
# the txn's chain LSN, Commit/Abort retire it — lives inline in
# ``recover``'s fused single pass; there is deliberately no second copy
# for it to drift from.)
def _redo_physiological(dc: DataComponent, dpt: DPT, rec, stats: RedoStats) -> None:
    """Algorithm 1: ARIES/SQL-Server redo with DPT + rLSN + pLSN tests.
    No index traversal: the log record's PID addresses the page directly."""
    stats.submitted += 1
    e = dpt.find(rec.pid)
    if e is None or rec.lsn < e.rlsn:
        stats.skipped_dpt += 1
        return
    page = dc.pool.get(rec.pid)
    k = rec_key(rec)
    if page is None:
        # page never reached stable storage and its creating SMO is in the
        # lost tail: repeat history logically.
        stats.redone += 1
        if rec.op == RecKind.DELETE or rec.after is None:
            dc.btree.delete(k, rec.lsn)
        else:
            dc.btree.put(k, rec.after, rec.lsn)
        return
    if rec.lsn <= page.plsn:
        stats.skipped_plsn += 1
        return
    dc._reexecute(rec, k, rec.pid)


# --------------------------------------------------------------------------
def recover(image: CrashImage, strategy: Strategy, *,
            cache_pages: int = 4096,
            disk: Optional[DiskModel] = None,
            work_ms_per_op: float = 0.02,
            lookahead: int = 64,
            delta_mode: str = "paper",
            page_size: int = None,
            tracker_interval: int = 100,
            bg_flush_per_txn: int = 0,
            run_undo: bool = True,
            batched: bool = False,
            batch_window: int = 4096,
            progress=None) -> tuple[Database, RecoveryStats]:
    """Recover a crash image with one strategy; returns a live Database that
    can continue normal execution, plus the instrumented stats.

    The redo hot path is a streaming pipeline: analysis and redo share ONE
    ``log.scan`` pass — the analysis state machine runs inline and feeds
    redo records into a bounded window of ``batch_window`` records, which
    flushes through the strategy's redo engine as it fills.  Recovery
    memory is therefore bounded by the window (plus the DPT), not by the
    log length; the old shape scanned the log twice and materialized the
    entire redo record list.

    ``batched=True`` (logical strategies only) additionally applies each
    window through ``DataComponent.apply_batch``: sorted by (table, key)
    with a leaf-resident cursor, amortizing B-tree traversal across
    consecutive ops to the same leaf.  Per-record dispatch — the paper's
    Algorithms 2/5 verbatim — remains the default so the five-strategy
    comparative study measures what the paper measured."""
    if batched and not strategy.logical:
        raise ValueError(
            f"batched redo applies logical strategies only (got "
            f"{strategy.value}): physiological redo is page-addressed and "
            "has no traversal to amortize")
    # The root span wraps the whole run so IO/window events nest under it;
    # when tracing is disabled this is the shared null span (no cost).
    with _TRACER.span("recover", strategy=strategy.value,
                      batched=batched) as rspan:
        try:
            return _recover(image, strategy, rspan, cache_pages=cache_pages,
                            disk=disk, work_ms_per_op=work_ms_per_op,
                            lookahead=lookahead, delta_mode=delta_mode,
                            page_size=page_size,
                            tracker_interval=tracker_interval,
                            bg_flush_per_txn=bg_flush_per_txn,
                            run_undo=run_undo, batched=batched,
                            batch_window=batch_window, progress=progress)
        # reprolint: allow(loud-corruption) — black-box dump hook: the flight recorder captures the interrupted phase, then the error re-raises unconditionally
        except BaseException:
            _flight_dump("recover.failed")
            raise


_H_WINDOW_RECORDS = obs_metrics.histogram("recovery.window_records")
_C_RECOVER_RUNS = obs_metrics.counter("recovery.runs")


def _recover(image: CrashImage, strategy: Strategy, rspan, *,
             cache_pages, disk, work_ms_per_op, lookahead, delta_mode,
             page_size, tracker_interval, bg_flush_per_txn, run_undo,
             batched, batch_window,
             progress=None) -> tuple[Database, RecoveryStats]:
    t0 = time.perf_counter()
    # the "analysis" span covers exactly what ``stats.analysis_ms`` times:
    # image clone, DC init, SMO replay + DPT build
    with _TRACER.span("analysis") as asp:
        store = image.store.clone()
        log = image.log.crash()            # stable prefix, private copy
        iosim = IOSim(disk or DiskModel())
        dc = DataComponent(store, log, cache_pages, delta_mode=delta_mode,
                           side_by_side=True, page_size=page_size)
        dc.pool.iosim = iosim
        stats = RecoveryStats(strategy=strategy.value, batched=batched,
                              batch_window=batch_window)

        m = log.master
        # May start below the in-memory truncation base: every log read here
        # (analysis, DPT build, redo, the EndCkpt/RSSP record fetches) goes
        # through the archive splice, so a truncated-and-archived prefix
        # recovers identically to an all-in-memory one.
        scan_from = m.bckpt_lsn if m.bckpt_lsn != NULL_LSN else 1
        stats.scan_from = scan_from
        _FLIGHT.record("rec.analysis", scan_from, log.stable_lsn)
        if progress is not None:
            # LSNs are dense, so the analysis-pass span IS the unit count
            progress.begin(log.stable_lsn - scan_from + 1)

        # --------------------------------------------------- DC recovery
        # SMO replay + Delta-record DPT come first (redo needs a well-formed
        # tree and a complete DPT — Delta records describing a page's
        # dirtying land *after* the ops they describe, so the DPT cannot
        # build inline with redo); the DC fuses both jobs into its own
        # single scan.
        dc.recover(scan_from, rssp_lsn=m.bckpt_lsn,
                   build_dpt=strategy.logical and strategy.uses_dpt,
                   preload_index=(strategy is Strategy.LOG2))
        dpt: Optional[DPT] = None
        if strategy.logical and strategy.uses_dpt:
            dpt = dc.dpt
        elif not strategy.logical:
            dpt = build_dpt_sql(log, m.bckpt_lsn)
        stats.dpt_size = len(dpt) if dpt is not None else 0
        stats.analysis_ms = (time.perf_counter() - t0) * 1e3
        asp.set(scan_from=scan_from, dpt_size=stats.dpt_size,
                analysis_ms=round(stats.analysis_ms, 3))

    # ------------------------------------- fused analysis + redo (one pass)
    t1 = time.perf_counter()
    with _TRACER.span("redo") as rdsp:
        _FLIGHT.record("rec.redo", scan_from)
        iosim.log_read(log.n_log_pages(scan_from))    # the single fused pass
        active: dict[int, LSN] = {}
        if m.end_ckpt_lsn != NULL_LSN:
            eck = log.record(m.end_ckpt_lsn)
            if isinstance(eck, EndCkptRec):
                active.update(eck.active_txns)

        window: list = []
        cursor = dc.btree.cursor() if batched else None
        pf_ptr = 0                                    # Log2 PF-list cursor
        done = 0                                      # records already flushed

        def pace_pf_list(upto: int) -> None:
            """LOG2 PF-list read-ahead: stay ``lookahead`` records ahead
            of redo position ``upto`` (Appendix A pacing — per record in
            both modes, so batched redo prices the same issue schedule the
            per-record study measures)."""
            nonlocal pf_ptr
            target = min(len(dc.pf_list), upto + lookahead)
            while pf_ptr < target:
                batch = dc.pf_list[pf_ptr:min(pf_ptr + 8, target)]
                iosim.prefetch(batch, contiguous=True)
                pf_ptr += len(batch)

        def flush_window() -> None:
            nonlocal done
            if not window:
                return
            stats.peak_window_records = max(stats.peak_window_records,
                                            len(window))
            stats.windows += 1
            _H_WINDOW_RECORDS.observe(len(window))
            _FLIGHT.record("rec.window", done, len(window))
            last_lsn = window[-1].lsn
            is_log2 = strategy is Strategy.LOG2 and bool(dc.pf_list)
            with _TRACER.span("redo.window", records=len(window),
                              start=done):
                if batched:
                    if is_log2:
                        # pace per record even though apply is batched:
                        # issuing the whole window's prefetches up front
                        # collapsed every issue onto the window-start
                        # clock and overstated overlap (nearly every
                        # demand read counted as a free hit)
                        for i in range(done, done + len(window)):
                            iosim.work(work_ms_per_op)
                            pace_pf_list(i)
                    else:
                        iosim.work(work_ms_per_op * len(window))
                    # reprolint: allow(sorted-stream) — the redo window is cut from a single forward log scan, so it is LSN-ordered by construction
                    dc.apply_batch(window,
                                   mode="dpt" if strategy.uses_dpt
                                   else "basic",
                                   cursor=cursor)
                else:
                    for i, rec in enumerate(window, start=done):
                        iosim.work(work_ms_per_op)
                        if is_log2:
                            pace_pf_list(i)
                        elif strategy is Strategy.SQL2 and dpt is not None:
                            # log-driven read-ahead over the next
                            # `lookahead` records; truncated at the window
                            # edge — the stream is not materialized, and
                            # lookahead << batch_window makes the boundary
                            # effect marginal
                            for fut in window[i - done + 1:
                                              i - done + 1 + lookahead]:
                                e = dpt.find(fut.pid)
                                if e is not None and fut.lsn >= e.rlsn:
                                    iosim.prefetch([fut.pid],
                                                   contiguous=True)
                        if strategy is Strategy.LOG0:
                            dc.redo_basic(rec)
                        elif strategy.logical:
                            dc.redo_with_dpt(rec)
                        else:
                            _redo_physiological(dc, dpt, rec, dc.redo_stats)
            done += len(window)
            window.clear()
            if progress is not None:
                progress.update(last_lsn - scan_from + 1, records=done)

        for rec in log.scan(scan_from):
            # ---- analysis state machine (ARIES transaction table)
            if isinstance(rec, UpdateRec):
                active[rec.txn] = rec.lsn
                window.append(rec)
            elif isinstance(rec, CLRRec):
                active[rec.txn] = rec.lsn
                window.append(rec)
            elif isinstance(rec, CommitRec):
                active.pop(rec.txn, None)
            elif isinstance(rec, AbortRec):
                active.pop(rec.txn, None)
            if len(window) >= batch_window:
                flush_window()
        flush_window()
        stats.log_records = done

        stats.redo = dc.redo_stats
        if cursor is not None:
            stats.cursor_traversals = cursor.traversals
            stats.cursor_reuses = cursor.reuses
        stats.redo_wall_ms = (time.perf_counter() - t1) * 1e3
        rdsp.set(log_records=done, windows=stats.windows,
                 redo_wall_ms=round(stats.redo_wall_ms, 3))
    stats.io = iosim.finish()
    stats.modeled_redo_ms = stats.io.modeled_ms
    # detach the IO model: undo / end-of-recovery checkpoint / post-recovery
    # reads must not pollute the redo-pass accounting (the paper measures
    # redo only, Section 2.1)
    dc.pool.iosim = None

    # ----------------------------------------------------------- undo pass
    _FLIGHT.record("rec.undo", len(active))
    with _TRACER.span("undo", losers=len(active)) as usp:
        tc = TransactionalComponent(log, dc)
        tc.active = dict(active)
        # txn ids must never be reused across restarts (a new txn id
        # colliding with a pre-crash aborted txn would corrupt outcome
        # attribution).  LogManager tracks the high-water mark at append
        # time, so no second O(log) scan is needed here.
        tc._next_txn = log.max_txn + 1
        stats.losers = len(active)
        if run_undo:
            before = len(log)
            for txn in sorted(active, key=lambda t: -active[t]):
                tc.abort(txn)
            stats.undone_ops = len(log) - before - len(active)  # CLRs written
            usp.set(undone_ops=stats.undone_ops)

    # ----------------------------------------------- end-of-recovery checkpoint
    # Mandatory for a *live* database: pages dirtied by redo carry their
    # original (old) LSNs, which would violate the Delta-record rLSN
    # approximation ("pages in a DirtySet were dirtied by ops newer than the
    # previous Delta record's TC-LSN") for any post-recovery Delta record.
    # Flushing them here — exactly what SQL Server's end-of-recovery
    # checkpoint does — restores the invariant and resets the redo baseline.
    _FLIGHT.record("rec.checkpoint")
    with _TRACER.span("checkpoint"):
        tc.checkpoint()
    if progress is not None:
        progress.finish()

    db = Database.__new__(Database)
    db.store, db.log, db.dc, db.tc = store, log, dc, tc
    db.tracker_interval = tracker_interval
    db.bg_flush_per_txn = bg_flush_per_txn
    db._updates_since_tracker = 0
    stats.pool_capacity = dc.pool.capacity
    stats.pool_peak_resident = dc.pool.peak_resident
    stats.pool_evictions = dc.pool.evictions
    stats.pool_flushes = dc.pool.flushes
    stats.total_wall_ms = (time.perf_counter() - t0) * 1e3
    rspan.set(log_records=stats.log_records,
              total_wall_ms=round(stats.total_wall_ms, 3))
    stats.publish()
    _C_RECOVER_RUNS.inc()
    return db, stats


# --------------------------------------------------------------------------
def committed_state_oracle(image: Union[CrashImage, "Database", LogManager],
                           base: Optional[dict[bytes, bytes]] = None,
                           upto_lsn: Optional[LSN] = None
                           ) -> dict[bytes, bytes]:
    """Ground truth: the database state recovery must reproduce — all
    committed transactions' effects (in LSN order) applied over the
    bulk-loaded ``base`` rows (composite keys), nothing else.

    ``upto_lsn`` is the point-in-time form: only transactions whose commit
    record lands at or below it count (their updates apply wholly, wherever
    their LSNs fall) — the reference for ``restore(target_lsn)``.

    Aborted transactions and losers contribute nothing: their updates are
    compensated (aborts) or undone (losers) by recovery, and with the
    serializable workloads our harness generates, net effect is absence.

    Reads the log through the truncation splice (``LogManager.scan`` from
    LSN 1 spans archive segments and the live tail transparently), so the
    oracle stays valid on truncated logs as long as nothing was pruned.

    Accepts a ``Database``, ``CrashImage`` or bare ``LogManager`` (the
    ``media.archive_log_view`` form — an oracle over cold bytes alone)."""
    log = image if isinstance(image, LogManager) else image.log
    committed: set[int] = set()
    for rec in log.scan(1, upto_lsn):
        if isinstance(rec, CommitRec):
            committed.add(rec.txn)
    state: dict[bytes, bytes] = dict(base or {})
    # a committed txn's updates all precede its commit record, so this
    # pass needs nothing past upto_lsn either
    for rec in log.scan(1, upto_lsn):
        if isinstance(rec, UpdateRec) and rec.txn in committed:
            k = make_key(rec.table, rec.key)
            if rec.op == RecKind.DELETE:
                state.pop(k, None)
            else:
                state[k] = rec.after
    return state


def recovered_state(db: Database) -> dict[bytes, bytes]:
    return dict(db.scan_all())
