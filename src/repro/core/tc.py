"""Transactional Component (TC): logical locking surface, logical logging,
checkpointing (RSSP), and the recovery driver's transaction table.

The TC never sees a PID: it logs (table, key, before, after).  In the
side-by-side prototype the DC stamps the touched PID back onto the shared log
record *after* applying — exactly how the paper's SQL-Server-derived prototype
keeps one log serving both recovery families (Section 5.1); logical recovery
ignores that field.

``Database`` is the harness: normal execution, checkpoints, trackers,
background flushing, and crash-image capture.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from ..obs.flightrec import FLIGHT as _FLIGHT
from ..obs.flightrec import auto_dump as _flight_dump
from .dc import DataComponent, make_key, rec_key, table_range
from .log import LogManager
from .records import (LSN, NULL_LSN, AbortRec, BeginCkptRec, CLRRec,
                      CommitRec, EndCkptRec, RecKind, SnapshotRec, TxnId,
                      UpdateRec)
from .storage import PageStore


class TransactionalComponent:
    def __init__(self, log: LogManager, dc: DataComponent):
        self.log = log
        self.dc = dc
        self.active: dict[TxnId, LSN] = {}       # txn -> last LSN of its chain
        self._next_txn: TxnId = 1
        # commit hooks: called as f(txn, commit_lsn) after the group-commit
        # force, i.e. once the txn's records are stable and thus shippable.
        self.on_commit: list = []
        # per-txn first write of each (table, key): (lsn, before-image) —
        # the committed value at the time the in-flight txn first touched it
        self._first_writes: dict[TxnId, dict] = {}

    # ------------------------------------------------------------------ txns
    def begin(self) -> TxnId:
        txn = self._next_txn
        self._next_txn += 1
        self.active[txn] = NULL_LSN
        return txn

    def _log_op(self, txn: TxnId, table: str, key: bytes,
                before: Optional[bytes], after: Optional[bytes],
                op: RecKind) -> UpdateRec:
        rec = UpdateRec(txn=txn, table=table, key=key, before=before,
                        after=after, prev_lsn=self.active[txn], op=op)
        self.log.append(rec)
        self.active[txn] = rec.lsn
        self._first_writes.setdefault(txn, {}).setdefault(
            (table, key), (rec.lsn, before))
        self.dc.apply(rec)       # DC stamps rec.pid (prototype common log)
        return rec

    def update(self, txn: TxnId, table: str, key: bytes, value: bytes) -> None:
        before = self.dc.read(table, key)
        self._log_op(txn, table, key, before, value, RecKind.UPDATE)

    def insert(self, txn: TxnId, table: str, key: bytes, value: bytes) -> None:
        self._log_op(txn, table, key, None, value, RecKind.INSERT)

    def delete(self, txn: TxnId, table: str, key: bytes) -> None:
        before = self.dc.read(table, key)
        self._log_op(txn, table, key, before, None, RecKind.DELETE)

    def committed_read(self, table: str, key: bytes) -> Optional[bytes]:
        """Read (table, key) as of the last commit.  The DC executes updates
        at log time — before commit — so a plain ``dc.read`` sees in-flight
        work.  The first in-flight writer of a key captured the committed
        value as its before-image; ``_first_writes`` keeps that per active
        transaction, making this O(active txns) per read."""
        best: Optional[tuple] = None
        for txn in self.active:
            hit = self._first_writes.get(txn, {}).get((table, key))
            if hit is not None and (best is None or hit[0] < best[0]):
                best = hit
        if best is not None:
            return best[1]
        return self.dc.read(table, key)

    def _committed_overlay(self) -> dict:
        """Composite key -> (first-write LSN, committed before-image) for
        every key touched by an in-flight transaction.  The DC executes
        updates at log time, so the tree holds uncommitted values; the
        earliest first-writer's before-image is the committed value (same
        reasoning as ``committed_read``, materialized for a batch)."""
        overlay: dict[bytes, tuple[LSN, Optional[bytes]]] = {}
        for txn in self.active:
            for (table, key), (lsn, before) in \
                    self._first_writes.get(txn, {}).items():
                ck = make_key(table, key)
                if ck not in overlay or lsn < overlay[ck][0]:
                    overlay[ck] = (lsn, before)
        return overlay

    def committed_chunk(self, after: Optional[bytes], n: int
                        ) -> tuple[list[tuple[bytes, bytes]],
                                   Optional[bytes], bool]:
        """One chunk of a committed-only full scan in composite-key order:
        up to ``n`` raw tree records with key > ``after``, patched to
        committed values.  Returns ``(items, cursor, more)`` — feed
        ``cursor`` back as the next ``after``.  This is the fuzzy-snapshot
        scan step: it never blocks writers (the patch is O(active txns'
        write sets), not a lock), so state observed by different chunks may
        come from different commit points — the snapshot's (begin, end)
        window plus committed redo replay absorbs exactly that.

        Patching handles all three in-flight shapes: an uncommitted UPDATE
        reverts to the before-image, an uncommitted INSERT (before None) is
        dropped, and an uncommitted DELETE — whose key is *absent* from the
        raw chunk — is re-added at its before-image."""
        lo = after + b"\x00" if after is not None else None   # key > after
        raw = self.dc.btree.range_items(lo, None, n)
        more = len(raw) == n
        # the chunk covers (after, upper]; overlay keys past upper belong
        # to a later chunk, keys inside it merge in sorted position
        upper = raw[-1][0] if more else None
        overlay = self._committed_overlay()
        patched: dict[bytes, Optional[bytes]] = dict(raw)
        for ck, (_, before) in overlay.items():
            if (after is None or ck > after) and (upper is None or ck <= upper):
                patched[ck] = before                 # None = drop (insert)
        items = [(k, v) for k, v in sorted(patched.items()) if v is not None]
        return items, upper, more

    def committed_scan_range(self, table: str, lo: Optional[bytes] = None,
                             hi: Optional[bytes] = None
                             ) -> list[tuple[bytes, bytes]]:
        """Ranged ``committed_read``: ``table`` keys in [lo, hi) at their
        last-committed values.  The primary-fallback path of routed ranged
        scans must honor the same committed-only visibility the replica
        path enforces."""
        lo_c, hi_c = table_range(table, lo, hi)
        patched: dict[bytes, Optional[bytes]] = \
            dict(self.dc.btree.range_items(lo_c, hi_c))
        for ck, (_, before) in self._committed_overlay().items():
            if ck >= lo_c and (hi_c is None or ck < hi_c):
                patched[ck] = before
        from .dc import split_key
        return [(split_key(k)[1], v)
                for k, v in sorted(patched.items()) if v is not None]

    def apply_shipped(self, txn: TxnId, shipped: UpdateRec) -> None:
        """Re-log and re-execute a logical record shipped from another TC.

        The shipped record is read-only (it belongs to the source's log); a
        fresh record is appended to OUR log with OUR LSN space, reusing the
        shipped before-image so the undo chain works without a local read.
        This is the replica apply hook: logical identity (table, key) crosses
        the wire, PIDs never do."""
        self._log_op(txn, shipped.table, shipped.key, shipped.before,
                     shipped.after, shipped.op)

    def apply_shipped_batch(self, txn: TxnId, shipped_ops) -> int:
        """Batched ``apply_shipped``: re-log a run of shipped records in
        (key, source-LSN) order, then execute them through the DC's
        leaf-resident batched engine (``DataComponent.apply_batch``) in one
        walk — the replica/restore apply hot path.

        Reordering across keys is sound for the same reason the batched
        redo is: the ops are committed absolute after-images, per-key
        source order is preserved by the stable (key, lsn) sort, and the
        local undo chain (abort on a failed apply) restores before-images
        in reverse append order, which per key is reverse source order.
        Returns the number of ops applied."""
        order = sorted(shipped_ops, key=rec_key)   # stable: per-key source
        local: list[UpdateRec] = []                # order is kept
        log, active = self.log, self.active
        for s in order:
            rec = UpdateRec(txn=txn, table=s.table, key=s.key,
                            before=s.before, after=s.after,
                            prev_lsn=active[txn], op=s.op, ck=s.ck)
            log.append(rec)
            active[txn] = rec.lsn
            self._first_writes.setdefault(txn, {}).setdefault(
                (s.table, s.key), (rec.lsn, s.before))
            local.append(rec)
        # local LSNs were assigned in sorted-key order, so the batch is
        # presorted for the engine (its sort is then a linear verify)
        self.dc.apply_batch(local, mode="execute")
        return len(local)

    def commit(self, txn: TxnId) -> LSN:
        rec = CommitRec(txn=txn, prev_lsn=self.active[txn])
        self.log.append(rec)
        self.log.flush()                          # group-commit force
        self.dc.eosl(self.log.stable_lsn)         # EOSL push
        del self.active[txn]
        self._first_writes.pop(txn, None)
        for hook in self.on_commit:
            hook(txn, rec.lsn)
        return rec.lsn

    def abort(self, txn: TxnId) -> None:
        """Logical undo of the transaction's chain, writing CLRs."""
        lsn = self.active[txn]
        while lsn != NULL_LSN:
            rec = self.log.record(lsn)
            if isinstance(rec, UpdateRec):
                self._compensate(txn, rec)
                lsn = rec.prev_lsn
            elif isinstance(rec, CLRRec):
                lsn = rec.undo_next
            else:
                break
        arec = AbortRec(txn=txn, prev_lsn=self.active[txn])
        self.log.append(arec)
        self.log.flush()
        del self.active[txn]
        self._first_writes.pop(txn, None)

    def _compensate(self, txn: TxnId, rec: UpdateRec) -> None:
        """Undo one update logically; the CLR is redo-only."""
        if rec.op == RecKind.INSERT:
            clr = CLRRec(txn=txn, table=rec.table, key=rec.key, after=None,
                         op=RecKind.DELETE, undone_lsn=rec.lsn,
                         undo_next=rec.prev_lsn)
        else:   # UPDATE or DELETE: restore the before image
            clr = CLRRec(txn=txn, table=rec.table, key=rec.key,
                         after=rec.before, op=RecKind.UPDATE,
                         undone_lsn=rec.lsn, undo_next=rec.prev_lsn)
        self.log.append(clr)
        self.active[txn] = clr.lsn
        self.dc.apply_clr(clr)

    # ------------------------------------------------------------ checkpoint
    def checkpoint(self) -> LSN:
        """Penultimate-scheme checkpoint, coordinated with the DC via RSSP.
        Returns the bCkpt LSN (= redo scan start once complete)."""
        b = BeginCkptRec()
        self.log.append(b)
        self.log.flush()
        self.dc.rssp(b.lsn)                       # DC flushes + logs RSSP rec
        e = EndCkptRec(bckpt_lsn=b.lsn, active_txns=dict(self.active))
        self.log.append(e)
        self.log.flush()
        self.log.set_master(end_ckpt=e.lsn, bckpt=b.lsn)
        return b.lsn

    # -------------------------------------------------------------- snapshot
    def snapshot_begin(self, snapshot_id: int = 0) -> SnapshotRec:
        """Anchor a fuzzy logical snapshot: log (and force) a SnapshotRec
        carrying the oldest in-flight first-write LSN.  The record's own LSN
        is the snapshot's ``begin_lsn`` — every commit at or below it is
        fully visible to the scan that follows; redo at restore time starts
        at ``oldest_active_lsn`` (when set) so transactions straddling the
        begin point re-deliver completely."""
        oldest = min((lsn for fw in self._first_writes.values()
                      for lsn, _ in fw.values()), default=NULL_LSN)
        rec = SnapshotRec(snapshot_id=snapshot_id, oldest_active_lsn=oldest)
        self.log.append(rec)
        self.log.flush()
        return rec


@dataclass
class CrashImage:
    """What survives: the stable page store and the stable log prefix."""
    store: PageStore
    log: LogManager


class Database:
    """Side-by-side prototype harness (Section 5): one normal execution run
    produces a common log + crash image that every recovery strategy consumes."""

    def __init__(self, cache_pages: int = 4096, delta_mode: str = "paper",
                 side_by_side: bool = True, tracker_interval: int = 100,
                 bg_flush_per_txn: int = 0, page_size: int = None,
                 page_backend=None, media_retry=None):
        """``media_retry``: a ``faults.RetryPolicy`` threaded into the
        buffer pool so page reads/flushes against a flaky ``page_backend``
        absorb into bounded backoff (only ``BackendUnavailableError`` —
        corruption stays first-throw loud everywhere)."""
        if page_backend is not None:
            from ..media.backend import open_backend
            self.store = PageStore(open_backend(page_backend))
        else:
            self.store = PageStore()
        self.log = LogManager()
        self.dc = DataComponent(self.store, self.log, cache_pages,
                                delta_mode=delta_mode, side_by_side=side_by_side,
                                page_size=page_size, retry=media_retry)
        self.tc = TransactionalComponent(self.log, self.dc)
        self.tracker_interval = tracker_interval
        self.bg_flush_per_txn = bg_flush_per_txn
        self._updates_since_tracker = 0

    # ---------------------------------------------------------------- setup
    def bootstrap_empty(self) -> None:
        self.dc.bootstrap()
        self.tc.checkpoint()

    def load_table(self, table: str, rows: list[tuple[bytes, bytes]]) -> None:
        from .dc import make_key
        self.dc.bulk_build([(make_key(table, k), v) for k, v in rows])
        self.tc.checkpoint()

    # ------------------------------------------------------------- workload
    def note_update(self) -> None:
        """Tracker cadence: count one logical update; emit Delta/BW records
        every ``tracker_interval`` updates."""
        self._updates_since_tracker += 1
        if self._updates_since_tracker >= self.tracker_interval:
            self.dc.emit_trackers()
            self._updates_since_tracker = 0

    def note_updates(self, n: int) -> None:
        """Batch form of ``note_update``: same cadence, one call per
        applied batch instead of one per op."""
        self._updates_since_tracker += n
        while self._updates_since_tracker >= self.tracker_interval:
            self.dc.emit_trackers()
            self._updates_since_tracker -= self.tracker_interval

    def post_commit_flush(self) -> None:
        """Background page flushing budgeted per committed transaction."""
        if self.bg_flush_per_txn:
            self.dc.maybe_background_flush(self.bg_flush_per_txn)

    def run_txn(self, ops: list[tuple[str, str, bytes, Optional[bytes]]]) -> LSN:
        """ops: (verb, table, key, value) with verb in {update, insert, delete}.
        Returns the commit LSN — usable as a read-your-writes staleness token
        against a replica set."""
        txn = self.tc.begin()
        for verb, table, key, value in ops:
            if verb == "update":
                self.tc.update(txn, table, key, value)
            elif verb == "insert":
                self.tc.insert(txn, table, key, value)
            else:
                self.tc.delete(txn, table, key)
            self.note_update()
        commit_lsn = self.tc.commit(txn)
        self.post_commit_flush()
        return commit_lsn

    def checkpoint(self) -> LSN:
        return self.tc.checkpoint()

    # ----------------------------------------------------------------- crash
    def crash(self) -> CrashImage:
        """Simulate an unplanned crash: only stable state survives.  The
        flight recorder treats this as a black-box event — the dump is
        what a post-mortem of the dead process reads."""
        _FLIGHT.record("db.crash", self.log.stable_lsn, self.log.end_lsn)
        _flight_dump("db.crash")
        return CrashImage(store=self.store.clone(), log=self.log.crash())

    # ------------------------------------------------------------- inspection
    def scan_all(self) -> list[tuple[bytes, bytes]]:
        return self.dc.btree.items()
