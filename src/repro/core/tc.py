"""Transactional Component (TC): logical locking surface, logical logging,
checkpointing (RSSP), and the recovery driver's transaction table.

The TC never sees a PID: it logs (table, key, before, after).  In the
side-by-side prototype the DC stamps the touched PID back onto the shared log
record *after* applying — exactly how the paper's SQL-Server-derived prototype
keeps one log serving both recovery families (Section 5.1); logical recovery
ignores that field.

``Database`` is the harness: normal execution, checkpoints, trackers,
background flushing, and crash-image capture.
"""
from __future__ import annotations

import random
from dataclasses import dataclass, field
from typing import Optional

from .dc import DataComponent
from .log import LogManager
from .records import (LSN, NULL_LSN, AbortRec, BeginCkptRec, CLRRec,
                      CommitRec, EndCkptRec, RecKind, TxnId, UpdateRec)
from .storage import PageStore


class TransactionalComponent:
    def __init__(self, log: LogManager, dc: DataComponent):
        self.log = log
        self.dc = dc
        self.active: dict[TxnId, LSN] = {}       # txn -> last LSN of its chain
        self._next_txn: TxnId = 1
        # commit hooks: called as f(txn, commit_lsn) after the group-commit
        # force, i.e. once the txn's records are stable and thus shippable.
        self.on_commit: list = []
        # per-txn first write of each (table, key): (lsn, before-image) —
        # the committed value at the time the in-flight txn first touched it
        self._first_writes: dict[TxnId, dict] = {}

    # ------------------------------------------------------------------ txns
    def begin(self) -> TxnId:
        txn = self._next_txn
        self._next_txn += 1
        self.active[txn] = NULL_LSN
        return txn

    def _log_op(self, txn: TxnId, table: str, key: bytes,
                before: Optional[bytes], after: Optional[bytes],
                op: RecKind) -> UpdateRec:
        rec = UpdateRec(txn=txn, table=table, key=key, before=before,
                        after=after, prev_lsn=self.active[txn], op=op)
        self.log.append(rec)
        self.active[txn] = rec.lsn
        self._first_writes.setdefault(txn, {}).setdefault(
            (table, key), (rec.lsn, before))
        self.dc.apply(rec)       # DC stamps rec.pid (prototype common log)
        return rec

    def update(self, txn: TxnId, table: str, key: bytes, value: bytes) -> None:
        before = self.dc.read(table, key)
        self._log_op(txn, table, key, before, value, RecKind.UPDATE)

    def insert(self, txn: TxnId, table: str, key: bytes, value: bytes) -> None:
        self._log_op(txn, table, key, None, value, RecKind.INSERT)

    def delete(self, txn: TxnId, table: str, key: bytes) -> None:
        before = self.dc.read(table, key)
        self._log_op(txn, table, key, before, None, RecKind.DELETE)

    def committed_read(self, table: str, key: bytes) -> Optional[bytes]:
        """Read (table, key) as of the last commit.  The DC executes updates
        at log time — before commit — so a plain ``dc.read`` sees in-flight
        work.  The first in-flight writer of a key captured the committed
        value as its before-image; ``_first_writes`` keeps that per active
        transaction, making this O(active txns) per read."""
        best: Optional[tuple] = None
        for txn in self.active:
            hit = self._first_writes.get(txn, {}).get((table, key))
            if hit is not None and (best is None or hit[0] < best[0]):
                best = hit
        if best is not None:
            return best[1]
        return self.dc.read(table, key)

    def apply_shipped(self, txn: TxnId, shipped: UpdateRec) -> None:
        """Re-log and re-execute a logical record shipped from another TC.

        The shipped record is read-only (it belongs to the source's log); a
        fresh record is appended to OUR log with OUR LSN space, reusing the
        shipped before-image so the undo chain works without a local read.
        This is the replica apply hook: logical identity (table, key) crosses
        the wire, PIDs never do."""
        self._log_op(txn, shipped.table, shipped.key, shipped.before,
                     shipped.after, shipped.op)

    def commit(self, txn: TxnId) -> LSN:
        rec = CommitRec(txn=txn, prev_lsn=self.active[txn])
        self.log.append(rec)
        self.log.flush()                          # group-commit force
        self.dc.eosl(self.log.stable_lsn)         # EOSL push
        del self.active[txn]
        self._first_writes.pop(txn, None)
        for hook in self.on_commit:
            hook(txn, rec.lsn)
        return rec.lsn

    def abort(self, txn: TxnId) -> None:
        """Logical undo of the transaction's chain, writing CLRs."""
        lsn = self.active[txn]
        while lsn != NULL_LSN:
            rec = self.log.record(lsn)
            if isinstance(rec, UpdateRec):
                self._compensate(txn, rec)
                lsn = rec.prev_lsn
            elif isinstance(rec, CLRRec):
                lsn = rec.undo_next
            else:
                break
        arec = AbortRec(txn=txn, prev_lsn=self.active[txn])
        self.log.append(arec)
        self.log.flush()
        del self.active[txn]
        self._first_writes.pop(txn, None)

    def _compensate(self, txn: TxnId, rec: UpdateRec) -> None:
        """Undo one update logically; the CLR is redo-only."""
        if rec.op == RecKind.INSERT:
            clr = CLRRec(txn=txn, table=rec.table, key=rec.key, after=None,
                         op=RecKind.DELETE, undone_lsn=rec.lsn,
                         undo_next=rec.prev_lsn)
        else:   # UPDATE or DELETE: restore the before image
            clr = CLRRec(txn=txn, table=rec.table, key=rec.key,
                         after=rec.before, op=RecKind.UPDATE,
                         undone_lsn=rec.lsn, undo_next=rec.prev_lsn)
        self.log.append(clr)
        self.active[txn] = clr.lsn
        self.dc.apply_clr(clr)

    # ------------------------------------------------------------ checkpoint
    def checkpoint(self) -> LSN:
        """Penultimate-scheme checkpoint, coordinated with the DC via RSSP.
        Returns the bCkpt LSN (= redo scan start once complete)."""
        b = BeginCkptRec()
        self.log.append(b)
        self.log.flush()
        self.dc.rssp(b.lsn)                       # DC flushes + logs RSSP rec
        e = EndCkptRec(bckpt_lsn=b.lsn, active_txns=dict(self.active))
        self.log.append(e)
        self.log.flush()
        self.log.set_master(end_ckpt=e.lsn, bckpt=b.lsn)
        return b.lsn


@dataclass
class CrashImage:
    """What survives: the stable page store and the stable log prefix."""
    store: PageStore
    log: LogManager


class Database:
    """Side-by-side prototype harness (Section 5): one normal execution run
    produces a common log + crash image that every recovery strategy consumes."""

    def __init__(self, cache_pages: int = 4096, delta_mode: str = "paper",
                 side_by_side: bool = True, tracker_interval: int = 100,
                 bg_flush_per_txn: int = 0, page_size: int = None):
        self.store = PageStore()
        self.log = LogManager()
        self.dc = DataComponent(self.store, self.log, cache_pages,
                                delta_mode=delta_mode, side_by_side=side_by_side,
                                page_size=page_size)
        self.tc = TransactionalComponent(self.log, self.dc)
        self.tracker_interval = tracker_interval
        self.bg_flush_per_txn = bg_flush_per_txn
        self._updates_since_tracker = 0

    # ---------------------------------------------------------------- setup
    def bootstrap_empty(self) -> None:
        self.dc.bootstrap()
        self.tc.checkpoint()

    def load_table(self, table: str, rows: list[tuple[bytes, bytes]]) -> None:
        from .dc import make_key
        self.dc.bulk_build([(make_key(table, k), v) for k, v in rows])
        self.tc.checkpoint()

    # ------------------------------------------------------------- workload
    def note_update(self) -> None:
        """Tracker cadence: count one logical update; emit Delta/BW records
        every ``tracker_interval`` updates."""
        self._updates_since_tracker += 1
        if self._updates_since_tracker >= self.tracker_interval:
            self.dc.emit_trackers()
            self._updates_since_tracker = 0

    def post_commit_flush(self) -> None:
        """Background page flushing budgeted per committed transaction."""
        if self.bg_flush_per_txn:
            self.dc.maybe_background_flush(self.bg_flush_per_txn)

    def run_txn(self, ops: list[tuple[str, str, bytes, Optional[bytes]]]) -> LSN:
        """ops: (verb, table, key, value) with verb in {update, insert, delete}.
        Returns the commit LSN — usable as a read-your-writes staleness token
        against a replica set."""
        txn = self.tc.begin()
        for verb, table, key, value in ops:
            if verb == "update":
                self.tc.update(txn, table, key, value)
            elif verb == "insert":
                self.tc.insert(txn, table, key, value)
            else:
                self.tc.delete(txn, table, key)
            self.note_update()
        commit_lsn = self.tc.commit(txn)
        self.post_commit_flush()
        return commit_lsn

    def checkpoint(self) -> LSN:
        return self.tc.checkpoint()

    # ----------------------------------------------------------------- crash
    def crash(self) -> CrashImage:
        return CrashImage(store=self.store.clone(), log=self.log.crash())

    # ------------------------------------------------------------- inspection
    def scan_all(self) -> list[tuple[bytes, bytes]]:
        return self.dc.btree.items()
