"""Dirty Page Table: structure + both construction algorithms.

``build_dpt_sql``     — Algorithm 3: SQL Server's analysis pass over update
                        log records (PIDs!) + BW-log records.
``build_dpt_logical`` — Algorithm 4: the paper's contribution — DC analysis
                        over Delta-log records *only*; no PID ever read from a
                        TC (update) record.

Safety invariants (checked by hypothesis property tests):
  * every page actually dirty at the crash appears in the DPT
    (conservative approximation of the dirty cache);
  * every entry's rLSN <= LSN of the first op that dirtied the page.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Optional

from .log import LogManager
from .records import (LSN, NULL_LSN, PID, BWRec, CLRRec, DeltaRec, LogRec,
                      UpdateRec)


@dataclass(slots=True)
class DPTEntry:
    pid: PID
    rlsn: LSN          # recovery LSN: <= LSN of op that first dirtied the page
    lastlsn: LSN       # LSN (approximation) of the last op seen for the page


class DPT:
    def __init__(self):
        self.entries: Dict[PID, DPTEntry] = {}

    def find(self, pid: PID) -> Optional[DPTEntry]:
        return self.entries.get(pid)

    def add(self, pid: PID, lsn: LSN) -> None:
        """ADDENTRY: new entry (rlsn=lastlsn=lsn); existing entry's lastlsn
        advances (Algorithms 3 & 4)."""
        e = self.entries.get(pid)
        if e is None:
            self.entries[pid] = DPTEntry(pid, lsn, lsn)
        elif lsn > e.lastlsn:
            e.lastlsn = lsn

    def remove(self, pid: PID) -> None:
        self.entries.pop(pid, None)

    def __contains__(self, pid: PID) -> bool:
        return pid in self.entries

    def __len__(self) -> int:
        return len(self.entries)


def build_dpt_sql(log: LogManager, bckpt_lsn: LSN) -> DPT:
    """Algorithm 3 — physiological analysis: every update record's PID enters
    the DPT; BW-log records prune flushed pages / raise rLSNs."""
    dpt = DPT()
    for rec in log.scan(bckpt_lsn + 1):
        if isinstance(rec, (UpdateRec, CLRRec)):
            dpt.add(rec.pid, rec.lsn)
        elif isinstance(rec, BWRec):
            for pid in rec.written_set:
                e = dpt.find(pid)
                if e is None:
                    continue
                if e.lastlsn <= rec.fw_lsn:
                    dpt.remove(pid)
                elif e.rlsn < rec.fw_lsn:
                    e.rlsn = rec.fw_lsn
    return dpt


class LogicalDPTBuilder:
    """Algorithm 4 — DC analysis over Delta-log records only, in
    incremental form so a fused recovery scan can feed it Delta records as
    it encounters them instead of paying a dedicated log pass
    (``build_dpt_logical`` below remains the one-shot wrapper).

    * DirtySet entries with index < FirstDirty were dirtied before the
      interval's first flush -> rLSN = TC-LSN of the *previous* Delta record
      (rsspLSN for the first).  Entries at index >= FirstDirty were dirtied
      after the first flush -> rLSN = the record's FW-LSN.
    * WrittenSet prunes entries whose lastLSN < FW-LSN; survivors' rLSNs are
      raised to FW-LSN.
    * Reduced-logging variant (Appendix D.2): records carry no FW-LSN /
      FirstDirty (fw_lsn == NULL_LSN while pages were written): every dirty
      entry uses prevDeltaLSN and pruning only removes entries created by
      *prior* Delta records.
    * Perfect variant (Appendix D.1): per-entry exact update LSNs.

    The PF-list (Appendix A.2) is the first-occurrence-ordered concatenation
    of DirtySets restricted to pages that survive in the final DPT.
    """

    def __init__(self, rssp_lsn: LSN):
        self.rssp_lsn = rssp_lsn
        self.dpt = DPT()
        self.prev_lsn = rssp_lsn
        self._pf_order: list[PID] = []
        self._seen: set[PID] = set()

    def feed(self, rec: DeltaRec) -> None:
        """Consume one Delta record (callers must feed in LSN order)."""
        if rec.tc_lsn <= self.rssp_lsn:
            return
        dpt, prev_lsn = self.dpt, self.prev_lsn
        seen, pf_order = self._seen, self._pf_order
        reduced = rec.fw_lsn == NULL_LSN and bool(rec.written_set)
        if rec.dirty_lsns is not None:                      # Appendix D.1
            for pid, ulsn in zip(rec.dirty_set, rec.dirty_lsns):
                dpt.add(pid, ulsn)
                if pid not in seen:
                    seen.add(pid)
                    pf_order.append(pid)
        else:
            first_dirty = len(rec.dirty_set) if reduced else rec.first_dirty
            for i, pid in enumerate(rec.dirty_set):
                dpt.add(pid, prev_lsn if i < first_dirty else rec.fw_lsn)
                if pid not in seen:
                    seen.add(pid)
                    pf_order.append(pid)
        for pid in rec.written_set:
            e = dpt.find(pid)
            if e is None:
                continue
            if reduced:
                # D.2: prune only entries created by PRIOR Delta records —
                # current-interval entries carry lastlsn == prev_lsn and a
                # flush recorded here may have preceded their dirtying
                if e.lastlsn < prev_lsn:
                    dpt.remove(pid)
            else:
                if e.lastlsn < rec.fw_lsn:
                    dpt.remove(pid)
                elif e.rlsn < rec.fw_lsn:
                    e.rlsn = rec.fw_lsn
        self.prev_lsn = rec.tc_lsn

    def finish(self) -> tuple[DPT, LSN, list[PID]]:
        pf_list = [pid for pid in self._pf_order if pid in self.dpt]
        return self.dpt, self.prev_lsn, pf_list


def build_dpt_logical(log: LogManager, rssp_lsn: LSN) -> tuple[DPT, LSN, list[PID]]:
    """One-shot Algorithm 4 (see ``LogicalDPTBuilder``): returns
    (DPT, TC-LSN of the last Delta record seen, PF-list)."""
    builder = LogicalDPTBuilder(rssp_lsn)
    for rec in log.scan(rssp_lsn + 1):
        if isinstance(rec, DeltaRec):
            builder.feed(rec)
    return builder.finish()
