"""Normal-execution trackers that prepare for optimized recovery.

``DeltaAccumulator``  — builds the DC's Delta-log records (Section 4.1):
    (DirtySet, WrittenSet, FW-LSN, FirstDirty, TC-LSN)
``BWAccumulator``     — builds SQL Server's BW-log records (Section 3.3):
    (WrittenSet, FW-LSN)

Both attach to the buffer pool's listener hooks.  In the side-by-side
prototype mode both are active on the same run (the paper writes Delta-log
records "exactly before BW-log records to ensure a fair comparison").

Correctness note (Section 4.1): *every* dirtied page must be captured in some
DirtySet — a missed dirty page could make redo falsely skip an operation.  The
accumulator therefore appends on every update (duplicates allowed; Appendix
D.2 explains why dedup is deliberately not attempted).  The TC-LSN recorded is
``min(TC end-of-stable-log, last op the DC has applied)`` so that an op whose
page-dirtying the DC has not yet performed can never be <= TC-LSN (such ops
fall into the "tail of the log" and use basic redo).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Optional

from .log import LogManager
from .records import LSN, NULL_LSN, PID, BWRec, DeltaRec


class DeltaAccumulator:
    def __init__(self, log: LogManager, *, perfect: bool = False, reduced: bool = False):
        """``perfect``: Appendix D.1 — also record per-update LSNs (DirtyLSNs).
        ``reduced``: Appendix D.2 — omit FW-LSN / FirstDirty at build time."""
        self.log = log
        self.perfect = perfect
        self.reduced = reduced
        self.applied_lsn: LSN = NULL_LSN     # last TC op applied by the DC
        self._reset()

    def _reset(self) -> None:
        self.dirty_set: list[PID] = []
        self.dirty_lsns: list[LSN] = []
        self.written_set: list[PID] = []
        self.fw_lsn: LSN = NULL_LSN
        self.first_dirty: Optional[int] = None

    # ------------------------------------------------------------- listeners
    def note_update(self, pid: PID, lsn: LSN) -> None:
        if self.fw_lsn != NULL_LSN and self.first_dirty is None:
            self.first_dirty = len(self.dirty_set)
        self.dirty_set.append(pid)
        if self.perfect:
            self.dirty_lsns.append(lsn)
        if lsn > self.applied_lsn:
            self.applied_lsn = lsn

    def note_flush(self, pid: PID) -> None:
        if self.fw_lsn == NULL_LSN:
            self.fw_lsn = self.log.stable_lsn      # TC end-of-stable-log at first write
        self.written_set.append(pid)

    # ----------------------------------------------------------------- write
    def emit(self) -> Optional[DeltaRec]:
        """Write the Delta-log record and reset the interval."""
        if not self.dirty_set and not self.written_set:
            return None
        tc_lsn = min(self.log.stable_lsn, self.applied_lsn) \
            if self.applied_lsn != NULL_LSN else self.log.stable_lsn
        fd = self.first_dirty if self.first_dirty is not None else len(self.dirty_set)
        rec = DeltaRec(
            dirty_set=list(self.dirty_set),
            written_set=list(self.written_set),
            fw_lsn=NULL_LSN if self.reduced else self.fw_lsn,
            first_dirty=0 if self.reduced else fd,
            tc_lsn=tc_lsn,
            dirty_lsns=list(self.dirty_lsns) if self.perfect else None,
        )
        self.log.append(rec)
        self._reset()
        return rec


class BWAccumulator:
    def __init__(self, log: LogManager):
        self.log = log
        self._reset()

    def _reset(self) -> None:
        self.written_set: list[PID] = []
        self.fw_lsn: LSN = NULL_LSN

    def note_flush(self, pid: PID) -> None:
        if self.fw_lsn == NULL_LSN:
            self.fw_lsn = self.log.stable_lsn
        self.written_set.append(pid)

    def emit(self) -> Optional[BWRec]:
        if not self.written_set:
            return None
        rec = BWRec(written_set=list(self.written_set), fw_lsn=self.fw_lsn)
        self.log.append(rec)
        self._reset()
        return rec
