"""Data Component (DC): owns placement (B-tree), the cache (buffer pool) and
stable storage.  Knows *nothing* about transactions; executes (re-)submitted
logical operations and runs its own recovery (SMO replay + DPT construction)
before the TC's redo pass (Section 1.2, 4.2).

The TC addresses records logically as (table, key); the DC maps that to a
composite byte key (length-prefixed table + key) so one tree serves many
tables, and then to a leaf PID.
"""
from __future__ import annotations

import struct
from dataclasses import dataclass, field
from typing import Optional

from .btree import BTree
from .bufferpool import BufferPool
from .delta_log import BWAccumulator, DeltaAccumulator
from .dpt import DPT, build_dpt_logical
from .log import LogManager
from .records import (LSN, NULL_LSN, NULL_PID, PID, CLRRec, DeltaRec, LogRec,
                      RecKind, RSSPRec, SMORec, UpdateRec)
from .storage import PageStore


def make_key(table: str, key: bytes) -> bytes:
    t = table.encode()
    return struct.pack("<H", len(t)) + t + key


def split_key(composite: bytes) -> tuple[str, bytes]:
    """Inverse of make_key: (table, key) from a composite tree key."""
    (tlen,) = struct.unpack_from("<H", composite)
    return composite[2:2 + tlen].decode(), composite[2 + tlen:]


def table_bounds(table: str) -> tuple[bytes, Optional[bytes]]:
    """Composite-key interval [lo, hi) covering every key of ``table``
    (hi None = end of key space).  hi is the prefix incremented with
    carry: the smallest byte string sorting after every prefix extension."""
    prefix = make_key(table, b"")
    hi = bytearray(prefix)
    while hi and hi[-1] == 0xFF:
        hi.pop()
    if not hi:
        return prefix, None
    hi[-1] += 1
    return prefix, bytes(hi)


def table_range(table: str, lo: Optional[bytes] = None,
                hi: Optional[bytes] = None) -> tuple[bytes, Optional[bytes]]:
    """Composite-key interval [lo_c, hi_c) for ``table`` keys in [lo, hi),
    where None means the table edge on that side."""
    t_lo, t_hi = table_bounds(table)
    lo_c = make_key(table, lo) if lo is not None else t_lo
    hi_c = make_key(table, hi) if hi is not None else t_hi
    return lo_c, hi_c


@dataclass
class RedoStats:
    submitted: int = 0
    redone: int = 0
    skipped_dpt: int = 0       # pruned without fetching the page (DPT miss / rLSN)
    skipped_plsn: int = 0      # page fetched, pLSN said no
    tail_ops: int = 0          # ops past the last Delta record (basic fallback)


class DataComponent:
    def __init__(self, store: PageStore, log: LogManager, cache_pages: int = 1 << 30,
                 delta_mode: str = "paper", side_by_side: bool = True,
                 page_size: int = None):
        """delta_mode: 'paper' | 'perfect' (D.1) | 'reduced' (D.2) | 'off'.
        side_by_side: also maintain SQL-Server BW records on the same log so
        physiological recovery can be compared on a common log (Section 5.1).
        page_size: stable-page byte size — replicas may differ (Section 1.1)."""
        from .pages import PAGE_SIZE
        self.page_size = page_size or PAGE_SIZE
        self.store = store
        self.log = log
        self.pool = BufferPool(store, log, cache_pages)
        self.btree = BTree(self.pool, log, page_size=self.page_size)
        self.delta_mode = delta_mode
        self.delta: Optional[DeltaAccumulator] = None
        if delta_mode != "off":
            self.delta = DeltaAccumulator(log, perfect=(delta_mode == "perfect"),
                                          reduced=(delta_mode == "reduced"))
            self.pool.on_update.append(self.delta.note_update)
            self.pool.on_flush.append(self.delta.note_flush)
        self.bw: Optional[BWAccumulator] = None
        if side_by_side:
            self.bw = BWAccumulator(log)
            self.pool.on_flush.append(self.bw.note_flush)
        self.n_delta_recs = 0
        self.n_bw_recs = 0
        # recovery artifacts
        self.dpt: Optional[DPT] = None
        self.last_delta_tc_lsn: LSN = NULL_LSN
        self.pf_list: list[PID] = []
        self.redo_stats = RedoStats()

    # ----------------------------------------------------------- bootstrap
    def bootstrap(self) -> None:
        self.btree.create()

    def bulk_build(self, items: list[tuple[bytes, bytes]]) -> None:
        """Offline index build (initial load / restore-from-backup): packs
        sorted records bottom-up straight into stable storage, no logging.
        Must be followed by a checkpoint before the workload starts."""
        from .pages import SLOT_OVERHEAD, empty_internal, empty_leaf
        items = sorted(items)
        fill = int(self.page_size * 0.7)

        # ---- leaf level: (max_key, pid) per leaf, contiguous PIDs
        leaves: list[tuple[bytes, PID]] = []
        cur = empty_leaf(self.store.allocate_pid())
        size = 0
        for k, v in items:
            rec_sz = len(k) + len(v) + SLOT_OVERHEAD
            if size + rec_sz > fill and cur.records:
                leaves.append((max(cur.records), cur.pid))
                self.store.write_page(cur)
                cur = empty_leaf(self.store.allocate_pid())
                size = 0
            cur.records[k] = v
            size += rec_sz
        leaves.append((max(cur.records) if cur.records else b"", cur.pid))
        self.store.write_page(cur)

        # ---- internal levels: children[i] holds keys <= keys[i]
        level = leaves
        height = 1
        while len(level) > 1:
            height += 1
            nxt: list[tuple[bytes, PID]] = []
            node = empty_internal(self.store.allocate_pid())
            prev_mx: Optional[bytes] = None
            for mx, pid in level:
                if node.children and node.serialized_size() + len(mx) + 24 > fill:
                    nxt.append((prev_mx, node.pid))
                    self.store.write_page(node)
                    node = empty_internal(self.store.allocate_pid())
                if node.children:
                    node.keys.append(prev_mx)
                node.children.append(pid)
                prev_mx = mx
            nxt.append((prev_mx, node.pid))
            self.store.write_page(node)
            level = nxt
        self.btree.root_pid = level[0][1]
        self.btree.height = height

    # ------------------------------------------------------- normal-mode ops
    def apply(self, rec: UpdateRec) -> None:
        """Execute a logical operation; stamps the touched PID back onto the
        (shared prototype) log record so the physiological path can use it."""
        k = make_key(rec.table, rec.key)
        if rec.op == RecKind.DELETE:
            rec.pid = self.btree.delete(k, rec.lsn)
        else:
            rec.pid = self.btree.put(k, rec.after, rec.lsn)
        if self.delta is not None and rec.lsn > self.delta.applied_lsn:
            self.delta.applied_lsn = rec.lsn

    def apply_clr(self, rec: CLRRec) -> None:
        k = make_key(rec.table, rec.key)
        if rec.op == RecKind.DELETE or rec.after is None:
            rec.pid = self.btree.delete(k, rec.lsn)
        else:
            rec.pid = self.btree.put(k, rec.after, rec.lsn)

    def read(self, table: str, key: bytes) -> Optional[bytes]:
        return self.btree.get(make_key(table, key))

    def scan_range(self, table: str, lo: Optional[bytes] = None,
                   hi: Optional[bytes] = None,
                   limit: Optional[int] = None) -> list[tuple[bytes, bytes]]:
        """Ordered read of ``table`` keys in [lo, hi) (None = table edge)."""
        lo_c, hi_c = table_range(table, lo, hi)
        return [(split_key(k)[1], v)
                for k, v in self.btree.range_items(lo_c, hi_c, limit)]

    # --------------------------------------------------------- control ops
    def eosl(self, elsn: LSN) -> None:
        """EOSL: TC's end-of-stable-log.  With the integrated prototype log the
        pool reads stability directly; kept for interface fidelity."""
        # (Deuteronomy-mode DCs would store elsn and cap page flushes by it.)
        return None

    def emit_trackers(self) -> None:
        """Write a Delta-log record, then a BW record ('exactly before', 5.2)."""
        if self.delta is not None and self.delta.emit() is not None:
            self.n_delta_recs += 1
        if self.bw is not None and self.bw.emit() is not None:
            self.n_bw_recs += 1

    def rssp(self, rssp_lsn: LSN) -> LSN:
        """RSSP: flush every page dirtied by ops <= rssp_lsn (penultimate
        checkpoint scheme via the generation bit), record the DC's meta +
        rsspLSN on the log.  Returns the RSSP record's LSN."""
        self.pool.begin_checkpoint_flush()
        self.emit_trackers()
        rec = RSSPRec(rssp_lsn=rssp_lsn, root_pid=self.btree.root_pid,
                      next_pid=self.store.next_pid, height=self.btree.height)
        lsn = self.log.append(rec)
        self.log.set_master(rssp_rec=lsn)
        return lsn

    def maybe_background_flush(self, max_pages: int) -> int:
        return self.pool.flush_some(max_pages)

    # ------------------------------------------------------------ DC recovery
    def recover(self, scan_from: LSN, rssp_lsn: LSN = NULL_LSN,
                build_dpt: bool = True, preload_index: bool = False) -> None:
        """DC-side recovery, before any TC redo (Section 4.2):
          1. adopt meta from the master RSSP record,
          2. replay SMOs so the B-tree is well-formed,
          3. build the DPT + PF-list from Delta-log records,
          4. optionally bulk-preload all index pages (Appendix A.1)."""
        m = self.log.master
        if m.rssp_rec_lsn != NULL_LSN:
            rssp = self.log.record(m.rssp_rec_lsn)
            assert isinstance(rssp, RSSPRec)
            self.btree.root_pid = rssp.root_pid
            self.btree.height = rssp.height
            self.store.set_next_pid(rssp.next_pid)
        for rec in self.log.scan(scan_from):
            if isinstance(rec, SMORec):
                self.btree.redo_smo(rec)
        if build_dpt:
            self.dpt, self.last_delta_tc_lsn, self.pf_list = \
                build_dpt_logical(self.log, rssp_lsn)
        if preload_index:
            pids = self.index_pids_from_meta()
            if self.pool.iosim is not None:
                self.pool.iosim.prefetch(pids, contiguous=True)
            for pid in pids:
                self.pool.get(pid)

    def index_pids_from_meta(self) -> list[PID]:
        return self.btree.index_pids()

    # ---------------------------------------------------------- redo service
    def redo_basic(self, rec: UpdateRec) -> None:
        """Algorithm 2: traverse, fetch, pLSN test, maybe re-execute."""
        self.redo_stats.submitted += 1
        k = make_key(rec.table, rec.key)
        pid = self.btree.find_leaf(k)
        page = self.pool.get(pid)
        if rec.lsn <= page.plsn:
            self.redo_stats.skipped_plsn += 1
            return
        self._reexecute(rec, k, pid)

    def redo_with_dpt(self, rec: UpdateRec) -> None:
        """Algorithm 5: DPT-assisted logical redo with log-tail fallback."""
        self.redo_stats.submitted += 1
        k = make_key(rec.table, rec.key)
        pid = self.btree.find_leaf(k)
        if rec.lsn <= self.last_delta_tc_lsn:
            e = self.dpt.find(pid)
            if e is None or rec.lsn < e.rlsn:
                self.redo_stats.skipped_dpt += 1
                return
        else:
            self.redo_stats.tail_ops += 1
        page = self.pool.get(pid)
        if rec.lsn <= page.plsn:
            self.redo_stats.skipped_plsn += 1
            return
        self._reexecute(rec, k, pid)

    def _reexecute(self, rec, k: bytes, pid: PID) -> None:
        self.redo_stats.redone += 1
        page = self.pool.get(pid)
        if rec.op == RecKind.DELETE or rec.after is None:
            page.delete(k, rec.lsn)
            self.pool.mark_dirty(pid, rec.lsn)
        elif not page.would_overflow(k, rec.after, self.page_size):
            page.put(k, rec.after, rec.lsn)
            self.pool.mark_dirty(pid, rec.lsn)
        else:
            # repeat history: the original insert split here too
            self.btree.put(k, rec.after, rec.lsn)
