"""Data Component (DC): owns placement (B-tree), the cache (buffer pool) and
stable storage.  Knows *nothing* about transactions; executes (re-)submitted
logical operations and runs its own recovery (SMO replay + DPT construction)
before the TC's redo pass (Section 1.2, 4.2).

The TC addresses records logically as (table, key); the DC maps that to a
composite byte key (length-prefixed table + key) so one tree serves many
tables, and then to a leaf PID.
"""
from __future__ import annotations

import bisect
import struct
from dataclasses import dataclass, field
from typing import Optional

from ..obs import metrics as _metrics
from ..obs.trace import TRACER as _TRACER
from .btree import BTree, LeafCursor
from .bufferpool import BufferPool
from .delta_log import BWAccumulator, DeltaAccumulator
from .dpt import DPT, LogicalDPTBuilder, build_dpt_logical
from .log import LogManager
from .records import (LSN, NULL_LSN, NULL_PID, PID, CLRRec, DeltaRec, LogRec,
                      RecKind, RSSPRec, SMORec, UpdateRec)
from .storage import PageStore

# batched-apply span walks: how well the leaf-resident cursor amortizes
# traversal (records/spans ~ ops per traversal)
_C_AB_CALLS = _metrics.counter("dc.apply_batch.calls")
_C_AB_RECORDS = _metrics.counter("dc.apply_batch.records")
_C_AB_SPANS = _metrics.counter("dc.apply_batch.leaf_spans")


# length-prefixed table headers, memoized: make_key is on every logical
# hot path (apply, redo, batch sort) and the prefix only depends on the
# table name (bounded set)
_TABLE_PREFIX: dict = {}


def make_key(table: str, key: bytes) -> bytes:
    p = _TABLE_PREFIX.get(table)
    if p is None:
        t = table.encode()
        p = _TABLE_PREFIX[table] = struct.pack("<H", len(t)) + t
    return p + key


def rec_key(rec) -> bytes:
    """Composite tree key of an Update/CLR record, memoized on the record
    (``rec.ck``) — the identity never changes after append and every
    redo / apply / batch-sort pass needs it."""
    ck = rec.ck
    if ck is None:
        ck = rec.ck = make_key(rec.table, rec.key)
    return ck


def split_key(composite: bytes) -> tuple[str, bytes]:
    """Inverse of make_key: (table, key) from a composite tree key."""
    (tlen,) = struct.unpack_from("<H", composite)
    return composite[2:2 + tlen].decode(), composite[2 + tlen:]


def table_bounds(table: str) -> tuple[bytes, Optional[bytes]]:
    """Composite-key interval [lo, hi) covering every key of ``table``
    (hi None = end of key space).  hi is the prefix incremented with
    carry: the smallest byte string sorting after every prefix extension."""
    prefix = make_key(table, b"")
    hi = bytearray(prefix)
    while hi and hi[-1] == 0xFF:
        hi.pop()
    if not hi:
        return prefix, None
    hi[-1] += 1
    return prefix, bytes(hi)


def table_range(table: str, lo: Optional[bytes] = None,
                hi: Optional[bytes] = None) -> tuple[bytes, Optional[bytes]]:
    """Composite-key interval [lo_c, hi_c) for ``table`` keys in [lo, hi),
    where None means the table edge on that side."""
    t_lo, t_hi = table_bounds(table)
    lo_c = make_key(table, lo) if lo is not None else t_lo
    hi_c = make_key(table, hi) if hi is not None else t_hi
    return lo_c, hi_c


@dataclass
class RedoStats:
    submitted: int = 0
    redone: int = 0
    skipped_dpt: int = 0       # pruned without fetching the page (DPT miss / rLSN)
    skipped_plsn: int = 0      # page fetched, pLSN said no
    tail_ops: int = 0          # ops past the last Delta record (basic fallback)


class DataComponent:
    def __init__(self, store: PageStore, log: LogManager, cache_pages: int = 1 << 30,
                 delta_mode: str = "paper", side_by_side: bool = True,
                 page_size: int = None, retry=None):
        """delta_mode: 'paper' | 'perfect' (D.1) | 'reduced' (D.2) | 'off'.
        side_by_side: also maintain SQL-Server BW records on the same log so
        physiological recovery can be compared on a common log (Section 5.1).
        page_size: stable-page byte size — replicas may differ (Section 1.1).
        retry: a ``faults.RetryPolicy`` the buffer pool uses to absorb
        transient page-IO failures (page blobs may live on a remote
        ``MediaBackend``); None keeps every backend error first-throw."""
        from .pages import PAGE_SIZE
        self.page_size = page_size or PAGE_SIZE
        self.store = store
        self.log = log
        self.pool = BufferPool(store, log, cache_pages, retry=retry)
        self.btree = BTree(self.pool, log, page_size=self.page_size)
        self.delta_mode = delta_mode
        self.delta: Optional[DeltaAccumulator] = None
        if delta_mode != "off":
            self.delta = DeltaAccumulator(log, perfect=(delta_mode == "perfect"),
                                          reduced=(delta_mode == "reduced"))
            self.pool.on_update.append(self.delta.note_update)
            self.pool.on_flush.append(self.delta.note_flush)
        self.bw: Optional[BWAccumulator] = None
        if side_by_side:
            self.bw = BWAccumulator(log)
            self.pool.on_flush.append(self.bw.note_flush)
        self.n_delta_recs = 0
        self.n_bw_recs = 0
        # recovery artifacts
        self.dpt: Optional[DPT] = None
        self.last_delta_tc_lsn: LSN = NULL_LSN
        self.pf_list: list[PID] = []
        self.redo_stats = RedoStats()
        # first PID allocated *during* recovery redo (set by ``recover``):
        # pages at or above it were (re-)born by redo-time splits and have
        # no DPT entry, so the DPT test must not prune ops that land there
        self.redo_pid_floor: PID = 1 << 62

    # ----------------------------------------------------------- bootstrap
    def bootstrap(self) -> None:
        self.btree.create()

    def _store_write(self, page) -> None:
        """Direct-to-store page write (bulk paths that bypass the pool),
        through the pool's retry policy when one is configured — a bulk
        load should survive the same transient blips a flush does."""
        if self.pool.retry is None:
            self.store.write_page(page)
        else:
            self.pool.retry.call(self.store.write_page, page)

    def bulk_build(self, items: list[tuple[bytes, bytes]]) -> None:
        """Offline index build (initial load / restore-from-backup): packs
        sorted records bottom-up straight into stable storage, no logging.
        Must be followed by a checkpoint before the workload starts."""
        from .pages import SLOT_OVERHEAD, empty_internal, empty_leaf
        # The build bypasses the pool and writes pages straight to stable
        # storage; WAL still demands that no page outrun the log, so force
        # the log to its end before the first write_page below.
        self.log.flush()
        assert self.log.stable_lsn >= self.log.end_lsn, \
            "bulk_build requires a fully stable log (WAL)"
        items = sorted(items)
        fill = int(self.page_size * 0.7)

        # ---- leaf level: (max_key, pid) per leaf, contiguous PIDs
        leaves: list[tuple[bytes, PID]] = []
        cur = empty_leaf(self.store.allocate_pid())
        size = 0
        for k, v in items:
            rec_sz = len(k) + len(v) + SLOT_OVERHEAD
            if size + rec_sz > fill and cur.records:
                leaves.append((max(cur.records), cur.pid))
                cur.invalidate_sorted()
                self._store_write(cur)
                cur = empty_leaf(self.store.allocate_pid())
                size = 0
            cur.records[k] = v
            size += rec_sz
        leaves.append((max(cur.records) if cur.records else b"", cur.pid))
        cur.invalidate_sorted()
        self._store_write(cur)

        # ---- internal levels: children[i] holds keys <= keys[i]
        level = leaves
        height = 1
        while len(level) > 1:
            height += 1
            nxt: list[tuple[bytes, PID]] = []
            node = empty_internal(self.store.allocate_pid())
            prev_mx: Optional[bytes] = None
            for mx, pid in level:
                if node.children and node.serialized_size() + len(mx) + 24 > fill:
                    nxt.append((prev_mx, node.pid))
                    self._store_write(node)
                    node = empty_internal(self.store.allocate_pid())
                if node.children:
                    node.keys.append(prev_mx)
                node.children.append(pid)
                node.invalidate_sorted()
                prev_mx = mx
            nxt.append((prev_mx, node.pid))
            self._store_write(node)
            level = nxt
        self.btree.root_pid = level[0][1]
        self.btree.height = height

    # ------------------------------------------------------- normal-mode ops
    def apply(self, rec: UpdateRec) -> None:
        """Execute a logical operation; stamps the touched PID back onto the
        (shared prototype) log record so the physiological path can use it."""
        k = rec_key(rec)
        if rec.op == RecKind.DELETE:
            rec.pid = self.btree.delete(k, rec.lsn)
        else:
            rec.pid = self.btree.put(k, rec.after, rec.lsn)
        if self.delta is not None and rec.lsn > self.delta.applied_lsn:
            self.delta.applied_lsn = rec.lsn

    def apply_clr(self, rec: CLRRec) -> None:
        k = rec_key(rec)
        if rec.op == RecKind.DELETE or rec.after is None:
            rec.pid = self.btree.delete(k, rec.lsn)
        else:
            rec.pid = self.btree.put(k, rec.after, rec.lsn)

    def read(self, table: str, key: bytes) -> Optional[bytes]:
        return self.btree.get(make_key(table, key))

    def scan_range(self, table: str, lo: Optional[bytes] = None,
                   hi: Optional[bytes] = None,
                   limit: Optional[int] = None) -> list[tuple[bytes, bytes]]:
        """Ordered read of ``table`` keys in [lo, hi) (None = table edge)."""
        lo_c, hi_c = table_range(table, lo, hi)
        return [(split_key(k)[1], v)
                for k, v in self.btree.range_items(lo_c, hi_c, limit)]

    # --------------------------------------------------------- control ops
    def eosl(self, elsn: LSN) -> None:
        """EOSL: TC's end-of-stable-log.  With the integrated prototype log the
        pool reads stability directly; kept for interface fidelity."""
        # (Deuteronomy-mode DCs would store elsn and cap page flushes by it.)
        return None

    def emit_trackers(self) -> None:
        """Write a Delta-log record, then a BW record ('exactly before', 5.2)."""
        if self.delta is not None and self.delta.emit() is not None:
            self.n_delta_recs += 1
        if self.bw is not None and self.bw.emit() is not None:
            self.n_bw_recs += 1

    def rssp(self, rssp_lsn: LSN) -> LSN:
        """RSSP: flush every page dirtied by ops <= rssp_lsn (penultimate
        checkpoint scheme via the generation bit), record the DC's meta +
        rsspLSN on the log.  Returns the RSSP record's LSN."""
        self.pool.begin_checkpoint_flush()
        self.emit_trackers()
        rec = RSSPRec(rssp_lsn=rssp_lsn, root_pid=self.btree.root_pid,
                      next_pid=self.store.next_pid, height=self.btree.height)
        lsn = self.log.append(rec)
        self.log.set_master(rssp_rec=lsn)
        return lsn

    def maybe_background_flush(self, max_pages: int) -> int:
        return self.pool.flush_some(max_pages)

    # ------------------------------------------------------------ DC recovery
    def recover(self, scan_from: LSN, rssp_lsn: LSN = NULL_LSN,
                build_dpt: bool = True, preload_index: bool = False) -> None:
        """DC-side recovery, before any TC redo (Section 4.2):
          1. adopt meta from the master RSSP record,
          2. replay SMOs so the B-tree is well-formed,
          3. build the DPT + PF-list from Delta-log records,
          4. optionally bulk-preload all index pages (Appendix A.1)."""
        m = self.log.master
        if m.rssp_rec_lsn != NULL_LSN:
            rssp = self.log.record(m.rssp_rec_lsn)
            assert isinstance(rssp, RSSPRec)
            self.btree.root_pid = rssp.root_pid
            self.btree.height = rssp.height
            self.store.set_next_pid(rssp.next_pid)
        # one fused scan serves both DC recovery jobs: SMO replay (from
        # ``scan_from``) and DPT construction (Delta records above
        # ``rssp_lsn``) — this used to be two full passes over the log.
        dpt_builder = LogicalDPTBuilder(rssp_lsn) if build_dpt else None
        for rec in self.log.scan(min(scan_from, rssp_lsn + 1)):
            if isinstance(rec, SMORec):
                if rec.lsn >= scan_from:
                    self.btree.redo_smo(rec)
            elif dpt_builder is not None and isinstance(rec, DeltaRec) \
                    and rec.lsn > rssp_lsn:
                dpt_builder.feed(rec)
        if dpt_builder is not None:
            self.dpt, self.last_delta_tc_lsn, self.pf_list = \
                dpt_builder.finish()
        self.redo_pid_floor = self.store.next_pid
        if preload_index:
            pids = self.index_pids_from_meta()
            if self.pool.iosim is not None:
                self.pool.iosim.prefetch(pids, contiguous=True)
            for pid in pids:
                self.pool.get(pid)

    def index_pids_from_meta(self) -> list[PID]:
        return self.btree.index_pids()

    # ---------------------------------------------------------- redo service
    def redo_basic(self, rec: UpdateRec) -> None:
        """Algorithm 2: traverse, fetch, pLSN test, maybe re-execute."""
        self.redo_stats.submitted += 1
        k = rec_key(rec)
        pid = self.btree.find_leaf(k)
        page = self.pool.get(pid)
        if rec.lsn <= page.plsn:
            self.redo_stats.skipped_plsn += 1
            return
        self._reexecute(rec, k, pid)

    def redo_with_dpt(self, rec: UpdateRec) -> None:
        """Algorithm 5: DPT-assisted logical redo with log-tail fallback."""
        self.redo_stats.submitted += 1
        k = rec_key(rec)
        pid = self.btree.find_leaf(k)
        if rec.lsn <= self.last_delta_tc_lsn:
            e = self.dpt.find(pid)
            if e is None or rec.lsn < e.rlsn:
                self.redo_stats.skipped_dpt += 1
                return
        else:
            self.redo_stats.tail_ops += 1
        page = self.pool.get(pid)
        if rec.lsn <= page.plsn:
            self.redo_stats.skipped_plsn += 1
            return
        self._reexecute(rec, k, pid)

    # ----------------------------------------------------- batched apply
    def apply_batch(self, recs, *, mode: str = "execute",
                    cursor: Optional[LeafCursor] = None) -> int:
        """Batched logical apply: sort a window of records by
        ``(composite key, lsn)`` and walk it with a leaf-resident cursor,
        amortizing index traversal across consecutive ops to the same leaf
        (the paper's Section 5 locality optimizations, made logical).
        Returns the number of ops executed (non-skipped).

        Modes select the redo tests:

          execute  replica / restore apply — no tests, every op executes
                   (the records are committed absolute after-images that
                   were just appended to the local log);
          basic    batched Log0 — page-LSN idempotence test only;
          dpt      batched Log1/Log2 — DPT prune + page-LSN test.

        Reordering within the window is sound because per-key LSN order is
        preserved (the sort is keyed on (key, lsn)) and ops carry absolute
        after-images: keys commute, re-execution is idempotent.  The
        page-LSN test, however, must not compare against a pLSN advanced
        by *this* window's out-of-order ops — so each leaf "group" captures
        its pre-window pLSN on entry and tests the whole group against
        that base.  A split during the group inherits the leaf's data
        state (and pLSN), so keys still inside the group's original
        separator interval keep the captured base; a key beyond it enters
        a fresh group and reads a fresh (window-untouched — keys ascend)
        base.  Across windows the test is exact again: windows partition
        the log in LSN order, so a later window's LSNs all exceed any pLSN
        this one can write.

        In dpt mode, a missing DPT entry prunes only pages that existed
        when redo began (``redo_pid_floor``): pages born from redo-time
        splits are absent from the DPT by construction, and — unlike the
        per-record LSN-order path, whose repeat-of-history guarantees
        their images — a key-ordered batch may reach them before their
        content does, so they must repeat history unconditionally."""
        # ``recs`` must arrive in stream (LSN) order — every caller is a
        # log-ordered window — so the stable sort on the composite key
        # alone preserves per-key LSN order without comparing LSNs
        rs = sorted(recs, key=rec_key)
        ks = [r.ck for r in rs]           # parallel key array for the span
        # bisects (rec_key above filled every ck)
        cur = cursor if cursor is not None else self.btree.cursor()
        stats = self.redo_stats
        pool = self.pool
        if mode not in ("execute", "basic", "dpt"):
            raise ValueError(f"unknown apply_batch mode {mode!r}")
        test_plsn = mode != "execute"
        dpt_mode = mode == "dpt"
        delta = self.delta if mode == "execute" else None
        dpt_find = self.dpt.find if dpt_mode else None
        tc_lsn = self.last_delta_tc_lsn
        floor = self.redo_pid_floor
        delete_op = RecKind.DELETE
        page_size = self.page_size
        ALWAYS = 1 << 62          # group rlsn: no DPT entry, pre-redo page
        NEVER = -1                # group rlsn: redo-born page, never prune
        bis_right = bisect.bisect_right

        # local tallies, folded into redo_stats once at the end — attribute
        # read-modify-writes per record are measurable at window scale
        sub = skd = skp = red = tails = executed = spans = 0

        # The sorted window is processed leaf *span* at a time: one
        # traversal, one DPT consult, one page fetch and one pre-window
        # pLSN ("base") capture per span; the span end comes from one
        # bisect against the leaf's upper separator, so a pruned record —
        # the common case — costs two integer comparisons
        n = len(ks)
        i = 0
        carry = False                     # split mid-span: carry the base
        carry_hi: Optional[bytes] = None
        carry_base: LSN = NULL_LSN
        while i < n:
            spans += 1
            k0 = ks[i]
            pid = cur.seek(k0)
            ghi = cur.hi
            j = n if ghi is None else bis_right(ks, ghi, i)
            page = None
            if carry and not (carry_hi is not None and k0 > carry_hi):
                base, base_valid = carry_base, True
            else:
                carry = False
                base, base_valid = NULL_LSN, False
            if dpt_mode:
                e = dpt_find(pid)
                grlsn = e.rlsn if e is not None else \
                    (ALWAYS if pid < floor else NEVER)
            if test_plsn:
                sub += j - i
            idx = i
            split = False
            while idx < j:
                rec = rs[idx]
                lsn = rec.lsn
                idx += 1
                if dpt_mode:
                    if lsn <= tc_lsn:
                        if lsn < grlsn:
                            skd += 1
                            continue
                    else:
                        tails += 1
                if page is None:
                    # pinned for the span: a bounded pool may otherwise
                    # evict the frame mid-mutation (the split path below
                    # fetches index pages through the same pool)
                    page = pool.get(pid, pin=True)
                    if not base_valid:
                        base = page.plsn  # pre-window pLSN of this leaf
                        base_valid = True
                if test_plsn:
                    if lsn <= base:
                        skp += 1
                        continue
                    red += 1
                after = rec.after
                if rec.op == delete_op or after is None:
                    page.delete(rec.ck, lsn)
                    pool.mark_dirty(pid, lsn)
                    rec.pid = pid
                elif not page.would_overflow(rec.ck, after, page_size):
                    page.put(rec.ck, after, lsn)
                    pool.mark_dirty(pid, lsn)
                    rec.pid = pid
                else:
                    # split path: repeat history through the ordinary put;
                    # separators moved under the cursor, so the rest of the
                    # span re-seeks.  Keys still inside this span's original
                    # interval keep its captured base (split leaves inherit
                    # data state + pLSN); the carry interval is pinned at
                    # the first split so later sub-splits cannot narrow it
                    rec.pid = self.btree.put(rec.ck, after, lsn)
                    cur.invalidate()
                    if test_plsn:
                        if not carry:
                            carry, carry_hi, carry_base = True, ghi, base
                        sub -= j - idx    # tail re-counts in the next span
                    executed += 1
                    if delta is not None and lsn > delta.applied_lsn:
                        delta.applied_lsn = lsn
                    split = True
                    break
                executed += 1
                if delta is not None and lsn > delta.applied_lsn:
                    delta.applied_lsn = lsn
            if page is not None:
                pool.unpin(pid)
            consumed = (idx if split else j) - i
            if consumed > 1:
                cur.reuses += consumed - 1    # ops that paid no traversal
            i = idx if split else j
        stats.submitted += sub
        stats.skipped_dpt += skd
        stats.skipped_plsn += skp
        stats.redone += red
        stats.tail_ops += tails
        _C_AB_CALLS.inc()
        _C_AB_RECORDS.inc(n)
        _C_AB_SPANS.inc(spans)
        if _TRACER.enabled:
            _TRACER.event("dc.apply_batch", records=n, spans=spans,
                          mode=mode, executed=executed)
        return executed

    def _reexecute(self, rec, k: bytes, pid: PID) -> None:
        self.redo_stats.redone += 1
        page = self.pool.get(pid)
        if rec.op == RecKind.DELETE or rec.after is None:
            page.delete(k, rec.lsn)
            self.pool.mark_dirty(pid, rec.lsn)
        elif not page.would_overflow(k, rec.after, self.page_size):
            page.put(k, rec.after, rec.lsn)
            self.pool.mark_dirty(pid, rec.lsn)
        else:
            # repeat history: the original insert split here too
            self.btree.put(k, rec.after, rec.lsn)
