"""Logical recovery engine — the paper's contribution.

Public surface:
  Database / CrashImage / TransactionalComponent / DataComponent
  Strategy / recover / committed_state_oracle / recovered_state
  DPT / build_dpt_sql / build_dpt_logical
"""
from .btree import BTree, LeafCursor
from .bufferpool import BufferPool
from .dc import DataComponent, make_key, split_key, table_bounds, table_range
from .dpt import DPT, LogicalDPTBuilder, build_dpt_logical, build_dpt_sql
from .log import LogManager, TruncatedLogError
from .pages import PAGE_SIZE, Page
from .records import (LSN, NULL_LSN, NULL_PID, PID, BWRec, CLRRec, CommitRec,
                      DeltaRec, RecKind, SMORec, SnapshotRec, UpdateRec)
from .recovery import (RecoveryStats, Strategy, committed_state_oracle,
                       recover, recovered_state)
from .storage import DiskModel, IOSim, IOStats, PageStore
from .tc import CrashImage, Database, TransactionalComponent

__all__ = [
    "BTree", "LeafCursor", "BufferPool", "DataComponent", "make_key",
    "split_key", "table_bounds", "table_range", "DPT", "LogicalDPTBuilder",
    "build_dpt_logical", "build_dpt_sql",
    "LogManager", "TruncatedLogError", "PAGE_SIZE", "Page",
    "LSN", "NULL_LSN", "NULL_PID", "PID", "BWRec", "CLRRec", "CommitRec",
    "DeltaRec", "RecKind", "SMORec", "SnapshotRec", "UpdateRec",
    "RecoveryStats", "Strategy", "committed_state_oracle", "recover",
    "recovered_state", "DiskModel", "IOSim", "IOStats", "PageStore",
    "CrashImage", "Database", "TransactionalComponent",
]
