"""Log record types for logical + physiological recovery.

One integrated log (as in the paper's SQL-Server-2008-derived prototype, Section
5.1) carries every record kind.  Logical recovery ignores the PIDs present on
update records; physiological (SQL1/SQL2) recovery ignores Delta-log records.

LSNs are dense integers assigned by the LogManager.  ``NULL_LSN`` (=0) sorts
before every real LSN.
"""
from __future__ import annotations

import enum
from dataclasses import dataclass, field
from typing import Optional

LSN = int
PID = int
TxnId = int

NULL_LSN: LSN = 0
NULL_PID: PID = -1


class RecKind(enum.IntEnum):
    UPDATE = 1          # logical record update (carries PID for physiological path)
    INSERT = 2          # logical record insert
    DELETE = 3          # logical record delete
    COMMIT = 4
    ABORT = 5
    CLR = 6             # compensation log record (redo-only undo action)
    BEGIN_CKPT = 7      # bCkpt
    END_CKPT = 8        # eCkpt
    BW = 9              # SQL Server buffer-write record (Section 3.3)
    DELTA = 10          # DC Delta-log record (Section 4.1)
    SMO = 11            # DC structure-modification (B-tree split / root change)
    RSSP = 12           # DC acknowledgment of redo-scan-start-point (checkpoint)
    SNAPSHOT = 13       # logical snapshot begin (fuzz-window anchor)


@dataclass(slots=True)
class LogRec:
    """Base; ``lsn`` is stamped by LogManager.append()."""
    lsn: LSN = NULL_LSN

    @property
    def kind(self) -> RecKind:
        raise NotImplementedError


@dataclass(slots=True)
class UpdateRec(LogRec):
    """Logical update/insert/delete of a record.

    Logical identity:   (table, key)                — used by Log0/Log1/Log2.
    Physiological hint: pid                         — used by SQL1/SQL2 only.
    ``before`` enables logical undo; ``after`` is the redo argument.
    ``prev_lsn`` chains a transaction's records for undo.
    """
    txn: TxnId = 0
    table: str = ""
    key: bytes = b""
    before: Optional[bytes] = None
    after: Optional[bytes] = None
    pid: PID = NULL_PID
    prev_lsn: LSN = NULL_LSN
    op: RecKind = RecKind.UPDATE
    # memoized composite tree key (dc.make_key(table, key)) — identity
    # never changes after append, and every redo/apply/batch-sort pass
    # needs it; excluded from equality so codec round-trips stay exact
    ck: Optional[bytes] = field(default=None, repr=False, compare=False)

    @property
    def kind(self) -> RecKind:
        return self.op


@dataclass(slots=True)
class CommitRec(LogRec):
    txn: TxnId = 0
    prev_lsn: LSN = NULL_LSN

    @property
    def kind(self) -> RecKind:
        return RecKind.COMMIT


@dataclass(slots=True)
class AbortRec(LogRec):
    txn: TxnId = 0
    prev_lsn: LSN = NULL_LSN

    @property
    def kind(self) -> RecKind:
        return RecKind.ABORT


@dataclass(slots=True)
class CLRRec(LogRec):
    """Compensation record: the logical undo of ``undone_lsn``.

    ``undo_next`` points at the next record of the txn still to undo, so undo
    never repeats work after a crash during recovery (ARIES semantics).
    The undo action itself is expressed logically (table/key/after-image).
    """
    txn: TxnId = 0
    table: str = ""
    key: bytes = b""
    after: Optional[bytes] = None       # state the record is restored to
    op: RecKind = RecKind.UPDATE        # UPDATE: set value; DELETE: remove; INSERT: add
    pid: PID = NULL_PID
    undone_lsn: LSN = NULL_LSN
    undo_next: LSN = NULL_LSN
    ck: Optional[bytes] = field(default=None, repr=False, compare=False)

    @property
    def kind(self) -> RecKind:
        return RecKind.CLR


@dataclass(slots=True)
class BeginCkptRec(LogRec):
    @property
    def kind(self) -> RecKind:
        return RecKind.BEGIN_CKPT


@dataclass(slots=True)
class EndCkptRec(LogRec):
    bckpt_lsn: LSN = NULL_LSN
    active_txns: dict = field(default_factory=dict)   # txn -> last_lsn at bCkpt

    @property
    def kind(self) -> RecKind:
        return RecKind.END_CKPT


@dataclass(slots=True)
class BWRec(LogRec):
    """SQL Server Buffer-Write record:  (WrittenSet, FW-LSN)."""
    written_set: list[PID] = field(default_factory=list)
    fw_lsn: LSN = NULL_LSN

    @property
    def kind(self) -> RecKind:
        return RecKind.BW


@dataclass(slots=True)
class DeltaRec(LogRec):
    """DC Delta-log record (Section 4.1):

        (DirtySet, WrittenSet, FW-LSN, FirstDirty, TC-LSN)

    DirtySet:   PIDs appended on every page update (duplicates allowed, D.2).
    WrittenSet: PIDs whose flush IO completed during the interval.
    FW-LSN:     TC end-of-stable-log captured at the interval's first flush.
    FirstDirty: index in DirtySet of the first PID dirtied after that flush.
    TC-LSN:     TC end-of-stable-log at the time this record is written
                (clamped to the last op the DC has applied — see DeltaAccumulator).
    """
    dirty_set: list[PID] = field(default_factory=list)
    written_set: list[PID] = field(default_factory=list)
    fw_lsn: LSN = NULL_LSN
    first_dirty: int = 0
    tc_lsn: LSN = NULL_LSN
    # Appendix D.1 "perfect DPT" variant: per-DirtySet-entry update LSNs.
    dirty_lsns: Optional[list[LSN]] = None

    @property
    def kind(self) -> RecKind:
        return RecKind.DELTA


@dataclass(slots=True)
class SMORec(LogRec):
    """B-tree structure modification, logged by the DC (Section 2.1).

    Physiological after-images of the affected index/leaf pages: this is DC
    private physical information — allowed, since the DC owns placement.
    ``images`` maps pid -> serialized page bytes as of this SMO.
    ``root_pid``/``next_pid`` persist tree meta so DC recovery rebuilds a
    well-formed tree before TC redo begins.
    """
    images: dict = field(default_factory=dict)        # PID -> bytes
    root_pid: PID = NULL_PID
    next_pid: PID = 0
    height: int = 1

    @property
    def kind(self) -> RecKind:
        return RecKind.SMO


@dataclass(slots=True)
class RSSPRec(LogRec):
    """DC acknowledgment that all pages dirtied by ops <= rssp_lsn are stable.

    Also carries DC meta (root pid / allocator / height) so recovery can
    bootstrap without a separate master file (the log's master pointer finds
    this record).
    """
    rssp_lsn: LSN = NULL_LSN
    root_pid: PID = NULL_PID
    next_pid: PID = 0
    height: int = 1

    @property
    def kind(self) -> RecKind:
        return RecKind.RSSP


@dataclass(slots=True)
class SnapshotRec(LogRec):
    """Anchor of a fuzzy logical snapshot's window.

    Its own LSN is the snapshot's ``begin_lsn``: every transaction that
    committed at or below it is fully visible to the snapshot scan.
    ``oldest_active_lsn`` is the first-write LSN of the oldest transaction
    still in flight at begin (NULL when none) — the redo scan of a restore
    from this snapshot must start there, because such a transaction's
    records precede the window but its commit may land inside or after it.

    Purely logical (no PIDs, no geometry): a snapshot taken on one layout
    restores onto any other, same as the update stream itself.
    """
    snapshot_id: int = 0
    oldest_active_lsn: LSN = NULL_LSN

    @property
    def kind(self) -> RecKind:
        return RecKind.SNAPSHOT


UPDATE_KINDS = (RecKind.UPDATE, RecKind.INSERT, RecKind.DELETE)

# Canonical kind -> record class registry.  The durable media codec
# (repro.media.codec) must be able to encode/decode every kind; keeping
# the authoritative enumeration here means a future RecKind added without
# codec support fails the codec coverage test instead of silently
# becoming unarchivable.
REC_CLASSES: dict[RecKind, type] = {
    RecKind.UPDATE: UpdateRec,
    RecKind.INSERT: UpdateRec,
    RecKind.DELETE: UpdateRec,
    RecKind.COMMIT: CommitRec,
    RecKind.ABORT: AbortRec,
    RecKind.CLR: CLRRec,
    RecKind.BEGIN_CKPT: BeginCkptRec,
    RecKind.END_CKPT: EndCkptRec,
    RecKind.BW: BWRec,
    RecKind.DELTA: DeltaRec,
    RecKind.SMO: SMORec,
    RecKind.RSSP: RSSPRec,
    RecKind.SNAPSHOT: SnapshotRec,
}


def is_update(rec: LogRec) -> bool:
    return isinstance(rec, UpdateRec)
