"""Fixed-size pages: the DC's physical storage unit.

A page is either a B-tree *leaf* (sorted record slots: key -> value bytes) or
an *internal* index node (separator keys + child PIDs).  Pages carry two LSNs:

  ``plsn``  — data LSN: the last *record operation* applied.  Drives the
              redo idempotence test (op needs redo iff op.lsn > plsn).
  ``slsn``  — structure LSN: the last SMO (split/root-growth) that shaped this
              page.  Drives SMO-replay idempotence during DC recovery.

They are separate on purpose: a split redistributes records without changing
the *data* state, so it must not advance ``plsn`` — otherwise a recovery-time
split would cause later record redos to be falsely skipped.  (WAL enforcement
uses the buffer-level ``wal_lsn`` = max of every LSN applied to the buffer.)

A CRC32 detects torn/corrupt stable writes at read time.  ``PAGE_SIZE``
bounds the serialized size; the B-tree splits a page when an insert would
overflow it.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field

from .records import LSN, NULL_LSN, PID

PAGE_SIZE = 8192
_HDR = struct.Struct("<qqqBIH")     # pid, plsn, slsn, is_leaf, crc, n_entries
_SLOT = struct.Struct("<HI")        # key_len, val_len
_CHILD = struct.Struct("<q")

SLOT_OVERHEAD = _SLOT.size


class PageCorruptError(Exception):
    pass


@dataclass(slots=True)
class Page:
    pid: PID
    is_leaf: bool = True
    plsn: LSN = NULL_LSN
    slsn: LSN = NULL_LSN
    # leaf payload: mapping key -> value (both bytes)
    records: dict = field(default_factory=dict)
    # internal payload: keys[i] separates children[i] (<= keys[i]) from children[i+1]
    keys: list = field(default_factory=list)
    children: list = field(default_factory=list)
    # cached sorted view of ``records`` (leaf scans re-sorting an unchanged
    # leaf on every visit was pure tax); None = stale.  Every mutation path
    # must invalidate — direct writes to ``records``/``keys``/``children``
    # bypass the caches, so they pair with ``invalidate_sorted()``.
    _sorted: object = field(default=None, repr=False, compare=False)
    # cached payload byte size, maintained incrementally by put/delete
    # (summing every slot per ``would_overflow`` call made batched apply
    # O(page) per op); -1 = stale
    _payload: int = field(default=-1, repr=False, compare=False)

    # --------------------------------------------------------- sorted view
    def sorted_items(self) -> list:
        """Sorted (key, value) view of a leaf, cached until the next write.
        Treat the returned list as read-only — it is shared across calls."""
        s = self._sorted
        if s is None:
            s = self._sorted = sorted(self.records.items())
        return s

    def invalidate_sorted(self) -> None:
        self._sorted = None
        self._payload = -1

    # ------------------------------------------------------------------ size
    def payload_size(self) -> int:
        if not self.is_leaf:
            # internal nodes are uncached on purpose: splits and bulk build
            # mutate ``keys``/``children`` in place, and sizing them is off
            # the per-op hot path anyway
            return (sum(len(k) + SLOT_OVERHEAD for k in self.keys)
                    + len(self.children) * _CHILD.size)
        p = self._payload
        if p < 0:
            p = self._payload = sum(len(k) + len(v) + SLOT_OVERHEAD
                                    for k, v in self.records.items())
        return p

    def serialized_size(self) -> int:
        return _HDR.size + self.payload_size()

    def would_overflow(self, key: bytes, value: bytes,
                       page_size: int = PAGE_SIZE) -> bool:
        extra = len(key) + len(value) + SLOT_OVERHEAD
        if self.is_leaf and key in self.records:
            extra -= len(key) + len(self.records[key]) + SLOT_OVERHEAD
        return self.serialized_size() + extra > page_size

    # ------------------------------------------------------------- leaf ops
    def get(self, key: bytes):
        return self.records.get(key)

    def put(self, key: bytes, value: bytes, lsn: LSN) -> None:
        assert self.is_leaf
        old = self.records.get(key)
        self.records[key] = value
        self._sorted = None
        if self._payload >= 0:
            self._payload += len(value) - len(old) if old is not None \
                else len(key) + len(value) + SLOT_OVERHEAD
        if lsn > self.plsn:
            self.plsn = lsn

    def delete(self, key: bytes, lsn: LSN) -> bool:
        assert self.is_leaf
        old = self.records.pop(key, None)
        self._sorted = None
        if old is not None and self._payload >= 0:
            self._payload -= len(key) + len(old) + SLOT_OVERHEAD
        if lsn > self.plsn:
            self.plsn = lsn
        return old is not None

    # --------------------------------------------------------- serialization
    def to_bytes(self) -> bytes:
        if self.is_leaf:
            items = self.sorted_items()
            body = b"".join(_SLOT.pack(len(k), len(v)) + k + v for k, v in items)
            n = len(items)
        else:
            assert len(self.children) == len(self.keys) + 1, "malformed internal node"
            body = b"".join(_SLOT.pack(len(k), 0) + k for k in self.keys)
            body += b"".join(_CHILD.pack(c) for c in self.children)
            n = len(self.keys)
        crc = zlib.crc32(body)
        return _HDR.pack(self.pid, self.plsn, self.slsn,
                         1 if self.is_leaf else 0, crc, n) + body

    @classmethod
    def from_bytes(cls, raw: bytes) -> "Page":
        pid, plsn, slsn, is_leaf, crc, n = _HDR.unpack_from(raw, 0)
        body = raw[_HDR.size:]
        if zlib.crc32(body) != crc:
            raise PageCorruptError(f"page {pid}: CRC mismatch (torn write?)")
        off = 0
        if is_leaf:
            recs = {}
            for _ in range(n):
                klen, vlen = _SLOT.unpack_from(body, off)
                off += _SLOT.size
                k = body[off:off + klen]; off += klen
                v = body[off:off + vlen]; off += vlen
                recs[k] = v
            return cls(pid=pid, is_leaf=True, plsn=plsn, slsn=slsn, records=recs)
        keys = []
        for _ in range(n):
            klen, _vlen = _SLOT.unpack_from(body, off)
            off += _SLOT.size
            keys.append(body[off:off + klen]); off += klen
        children = []
        for _ in range(n + 1):
            (c,) = _CHILD.unpack_from(body, off)
            off += _CHILD.size
            children.append(c)
        return cls(pid=pid, is_leaf=False, plsn=plsn, slsn=slsn,
                   keys=keys, children=children)

    def clone(self) -> "Page":
        return Page.from_bytes(self.to_bytes())

    def copy(self) -> "Page":
        """Independent mutable copy without a serialization round-trip.
        Keys/values/separators are immutable bytes, so container-shallow
        is deep enough; the ``_sorted`` cache is shared safely because
        invalidation replaces the list, never mutates it."""
        return Page(pid=self.pid, is_leaf=self.is_leaf, plsn=self.plsn,
                    slsn=self.slsn, records=dict(self.records),
                    keys=list(self.keys), children=list(self.children),
                    _sorted=self._sorted, _payload=self._payload)


def empty_leaf(pid: PID) -> Page:
    return Page(pid=pid, is_leaf=True)


def empty_internal(pid: PID) -> Page:
    return Page(pid=pid, is_leaf=False)
