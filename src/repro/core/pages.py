"""Fixed-size pages: the DC's physical storage unit.

A page is either a B-tree *leaf* (sorted record slots: key -> value bytes) or
an *internal* index node (separator keys + child PIDs).  Pages carry two LSNs:

  ``plsn``  — data LSN: the last *record operation* applied.  Drives the
              redo idempotence test (op needs redo iff op.lsn > plsn).
  ``slsn``  — structure LSN: the last SMO (split/root-growth) that shaped this
              page.  Drives SMO-replay idempotence during DC recovery.

They are separate on purpose: a split redistributes records without changing
the *data* state, so it must not advance ``plsn`` — otherwise a recovery-time
split would cause later record redos to be falsely skipped.  (WAL enforcement
uses the buffer-level ``wal_lsn`` = max of every LSN applied to the buffer.)

Serialized format (v1, the *packed* layout)::

    offset  size  field
    0       3     magic  b"RPG"
    3       1     version (1)
    4       1     flags   (bit0 = is_leaf)
    5       1     pad
    6       4     count   u32  (leaf records / internal separator keys)
    10      8     pid     i64
    18      8     plsn    i64
    26      8     slsn    i64
    34      4     crc32   over bytes [0:34) + [38:)
    38      ...   slot directory, then cell bytes

    leaf slot (10B):      key_off u32 | key_len u16 | val_len u32
                          (value bytes follow the key bytes in the cell
                          array: val_off = key_off + key_len)
    internal slot (6B):   key_off u32 | key_len u16
                          followed by (count+1) x child PID i64

The slot directory is in key order, so every read operation — point
``get``, ``sorted_items`` spans, separator search — bisects directly over
the packed directory with zero dict materialization.  Mutation unpacks
lazily into the dict/list form and the page repacks at flush
(``to_bytes``).  CRC framing follows the PR-4 codec discipline: any tear,
truncation or bit flip raises ``PageCorruptError`` loudly; a new layout
means a new version byte, and old bytes decode forever (v0 pages — the
pre-packed format — still live inside archived ``SMORec.images``).

``PAGE_SIZE`` bounds the serialized size; the B-tree splits a page when an
insert would overflow it.
"""
from __future__ import annotations

import struct
import zlib
from typing import Any, Dict, Iterator, List, Optional, Tuple

from .records import LSN, NULL_LSN, PID

PAGE_SIZE = 8192

# ---------------------------------------------------------------- v1 layout
PAGE_MAGIC = b"RPG"
PAGE_VERSION = 1
_HEAD = struct.Struct("<3sBBxIqqq")       # magic, ver, flags, count, pid, plsn, slsn
_CRC = struct.Struct("<I")
_LSLOT = struct.Struct("<IHI")            # key_off, key_len, val_len
_ISLOT = struct.Struct("<IH")             # key_off, key_len
_CHILD = struct.Struct("<q")
_CRC_OFF = _HEAD.size                     # 34
HEADER_SIZE = _HEAD.size + _CRC.size      # 38

#: per-record serialized overhead of a leaf slot — the page-split sizing
#: unit.  Sizing is format-independent: a dict-form page computes the
#: exact byte size its packed form will have, so split decisions replay
#: identically whether redo finds the page packed or materialized.
SLOT_OVERHEAD = _LSLOT.size               # 10
ISLOT_OVERHEAD = _ISLOT.size              # 6

# ---------------------------------------------------------------- v0 layout
# (legacy, pre-packed: kept decodable forever — archived SMO images)
_HDR_V0 = struct.Struct("<qqqBIH")        # pid, plsn, slsn, is_leaf, crc, n
_SLOT_V0 = struct.Struct("<HI")           # key_len, val_len


class PageCorruptError(Exception):
    pass


class Page:
    """A page in one of three representations:

    *packed*   ``_raw`` holds the serialized v1 bytes; reads bisect the
               slot directory in place, ``copy()``/``to_bytes()`` are O(1).
    *dict*     ``_records``/``_keys``/``_children`` hold the mutable form;
               ``to_bytes()`` repacks.
    *dual*     both at once — the page is *clean*, the containers mirror
               the bytes exactly.  Reads go through the containers (C-speed
               dict/list ops beat per-slot struct unpacking), ``copy()``
               container-copies while still sharing the raw bytes, and
               ``to_bytes()`` stays O(1).  The decode cache promotes hot
               entries to dual form so one parse is amortized across every
               later copy (``prewarm``).

    Any access to the mutable containers (the ``records``/``keys``/
    ``children`` properties, ``put``, ``delete``) drops the packed bytes —
    the caller may mutate what it was handed, so cached bytes can never be
    trusted past that point."""

    __slots__ = ("pid", "is_leaf", "plsn", "slsn",
                 "_records", "_keys", "_children",
                 "_sorted", "_payload", "_raw", "_count", "_cells")

    def __init__(self, pid: PID, is_leaf: bool = True,
                 plsn: LSN = NULL_LSN, slsn: LSN = NULL_LSN,
                 records: Optional[dict] = None,
                 keys: Optional[list] = None,
                 children: Optional[list] = None,
                 _sorted: Optional[list] = None,
                 _payload: int = -1) -> None:
        self.pid = pid
        self.is_leaf = is_leaf
        self.plsn = plsn
        self.slsn = slsn
        self._records: Optional[Dict[bytes, bytes]] = \
            records if records is not None else {}
        self._keys: Optional[List[bytes]] = keys if keys is not None else []
        self._children: Optional[List[PID]] = \
            children if children is not None else []
        self._sorted: Optional[list] = _sorted
        self._payload = _payload
        self._raw: Optional[bytes] = None
        self._count = 0
        self._cells = 0

    @classmethod
    def _from_packed(cls, raw: bytes, pid: PID, is_leaf: bool, plsn: LSN,
                     slsn: LSN, count: int) -> "Page":
        pg = cls.__new__(cls)
        pg.pid = pid
        pg.is_leaf = is_leaf
        pg.plsn = plsn
        pg.slsn = slsn
        pg._records = pg._keys = pg._children = None
        pg._sorted = None
        pg._payload = -1
        pg._raw = raw
        pg._count = count
        if is_leaf:
            pg._cells = HEADER_SIZE + count * _LSLOT.size
        else:
            pg._cells = (HEADER_SIZE + count * _ISLOT.size
                         + (count + 1) * _CHILD.size)
        return pg

    # ----------------------------------------------------------- unpacking
    def _ensure_unpacked(self) -> None:
        """Materialize the dict/list form from the packed bytes (keeps
        ``_raw``; callers that may mutate must drop it themselves)."""
        if self._records is not None:
            return
        raw = self._raw
        assert raw is not None
        n, cells = self._count, self._cells
        if self.is_leaf:
            items: List[Tuple[bytes, bytes]] = []
            for off, klen, vlen in _LSLOT.iter_unpack(
                    raw[HEADER_SIZE:cells]):
                ko = cells + off
                vo = ko + klen
                items.append((raw[ko:vo], raw[vo:vo + vlen]))
            self._records = dict(items)
            self._keys = []
            self._children = []
            if self._sorted is None:
                self._sorted = items          # directory is already sorted
            if self._payload < 0:
                self._payload = len(raw) - HEADER_SIZE
        else:
            keys: List[bytes] = []
            for off, klen in _ISLOT.iter_unpack(
                    raw[HEADER_SIZE:HEADER_SIZE + n * _ISLOT.size]):
                ko = cells + off
                keys.append(raw[ko:ko + klen])
            children = [c for (c,) in _CHILD.iter_unpack(
                raw[HEADER_SIZE + n * _ISLOT.size:cells])]
            self._records = {}
            self._keys = keys
            self._children = children

    def materialize(self) -> "Page":
        """Force the dict/list form and drop the packed bytes (the eager
        decode mode — the pre-packed behaviour, kept as the benchmark
        baseline)."""
        self._ensure_unpacked()
        self._raw = None
        return self

    def prewarm(self) -> "Page":
        """Promote to dual form: parse the containers while *keeping* the
        packed bytes.  For a page that is read or copied repeatedly (a hot
        decode-cache entry), one parse here buys C-speed container reads
        and container-copying for every later access, and ``to_bytes()``
        remains O(1) while the page stays clean."""
        self._ensure_unpacked()
        return self

    # ------------------------------------------------- mutable containers
    @property
    def records(self) -> Dict[bytes, bytes]:
        self._ensure_unpacked()
        self._raw = None          # handing out the container: may be mutated
        assert self._records is not None
        return self._records

    @records.setter
    def records(self, value: Dict[bytes, bytes]) -> None:
        if self._records is None and self.is_leaf:
            # packed leaf being wholly replaced (split path): no point
            # decoding the old payload just to discard it
            self._keys, self._children = [], []
        else:
            self._ensure_unpacked()   # keep keys/children intact
        self._records = value
        self._raw = None
        self._sorted = None
        self._payload = -1

    @property
    def keys(self) -> List[bytes]:
        self._ensure_unpacked()
        self._raw = None
        assert self._keys is not None
        return self._keys

    @keys.setter
    def keys(self, value: List[bytes]) -> None:
        self._ensure_unpacked()
        self._keys = value
        self._raw = None
        self._sorted = None
        self._payload = -1

    @property
    def children(self) -> List[PID]:
        self._ensure_unpacked()
        self._raw = None
        assert self._children is not None
        return self._children

    @children.setter
    def children(self, value: List[PID]) -> None:
        self._ensure_unpacked()
        self._children = value
        self._raw = None
        self._sorted = None
        self._payload = -1

    # --------------------------------------------------------- sorted view
    def sorted_items(self) -> list:
        """Sorted (key, value) view of a leaf, cached until the next write.
        Treat the returned list as read-only — it is shared across calls.
        On a packed page this slices cells straight out of the raw bytes;
        no dict is built."""
        s = self._sorted
        if s is not None:
            return s
        recs = self._records
        if recs is not None:
            s = self._sorted = sorted(recs.items())
            return s
        raw = self._raw
        assert raw is not None
        cells = self._cells
        items: List[Tuple[bytes, bytes]] = []
        for off, klen, vlen in _LSLOT.iter_unpack(
                raw[HEADER_SIZE:cells]):
            ko = cells + off
            vo = ko + klen
            items.append((raw[ko:vo], raw[vo:vo + vlen]))
        self._sorted = items
        return items

    def invalidate_sorted(self) -> None:
        self._sorted = None
        self._payload = -1
        self._raw = None

    # ------------------------------------------------------- packed bisect
    def _leaf_key_at(self, i: int) -> bytes:
        raw = self._raw
        assert raw is not None
        off, klen, _vlen = _LSLOT.unpack_from(raw, HEADER_SIZE
                                              + i * _LSLOT.size)
        ko = self._cells + off
        return raw[ko:ko + klen]

    def _leaf_bisect(self, key: bytes) -> int:
        """bisect_left over the packed leaf key directory."""
        raw = self._raw
        assert raw is not None
        cells = self._cells
        lo, hi = 0, self._count
        while lo < hi:
            mid = (lo + hi) // 2
            off, klen, _vlen = _LSLOT.unpack_from(raw, HEADER_SIZE
                                                  + mid * _LSLOT.size)
            ko = cells + off
            if raw[ko:ko + klen] < key:
                lo = mid + 1
            else:
                hi = mid
        return lo

    # --------------------------------------------------- separator search
    # (packed-aware navigation: the internal-node read path never builds
    #  the key/child lists — separators bisect in place)
    def sep_count(self) -> int:
        keys = self._keys
        if keys is not None:
            return len(keys)
        return self._count

    def sep_at(self, i: int) -> bytes:
        keys = self._keys
        if keys is not None:
            return keys[i]
        raw = self._raw
        assert raw is not None
        off, klen = _ISLOT.unpack_from(raw, HEADER_SIZE + i * _ISLOT.size)
        ko = self._cells + off
        return raw[ko:ko + klen]

    def child_count(self) -> int:
        children = self._children
        if children is not None:
            return len(children)
        return self._count + 1

    def child_at(self, i: int) -> PID:
        children = self._children
        if children is not None:
            return children[i]
        raw = self._raw
        assert raw is not None
        n = self._count
        if i < 0:
            i += n + 1
        (c,) = _CHILD.unpack_from(raw, HEADER_SIZE + n * _ISLOT.size
                                  + i * _CHILD.size)
        return c

    def child_index(self, key: bytes) -> int:
        """bisect_left over the separators: index of the child owning
        ``key`` (child i owns the interval (sep[i-1], sep[i]])."""
        keys = self._keys
        if keys is not None:
            lo, hi = 0, len(keys)
            while lo < hi:
                mid = (lo + hi) // 2
                if keys[mid] < key:
                    lo = mid + 1
                else:
                    hi = mid
            return lo
        raw = self._raw
        assert raw is not None
        cells = self._cells
        lo, hi = 0, self._count
        while lo < hi:
            mid = (lo + hi) // 2
            off, klen = _ISLOT.unpack_from(raw, HEADER_SIZE
                                           + mid * _ISLOT.size)
            ko = cells + off
            if raw[ko:ko + klen] < key:
                lo = mid + 1
            else:
                hi = mid
        return lo

    # ------------------------------------------------------------------ size
    def n_entries(self) -> int:
        if self.is_leaf:
            recs = self._records
            if recs is not None:
                return len(recs)
        else:
            keys = self._keys
            if keys is not None:
                return len(keys)
        return self._count

    def payload_size(self) -> int:
        if self._raw is not None:
            return len(self._raw) - HEADER_SIZE
        if not self.is_leaf:
            # internal nodes are uncached on purpose: splits and bulk build
            # mutate ``keys``/``children`` in place, and sizing them is off
            # the per-op hot path anyway
            assert self._keys is not None and self._children is not None
            return (sum(len(k) + ISLOT_OVERHEAD for k in self._keys)
                    + len(self._children) * _CHILD.size)
        p = self._payload
        if p < 0:
            assert self._records is not None
            p = self._payload = sum(len(k) + len(v) + SLOT_OVERHEAD
                                    for k, v in self._records.items())
        return p

    def serialized_size(self) -> int:
        return HEADER_SIZE + self.payload_size()

    def would_overflow(self, key: bytes, value: bytes,
                       page_size: int = PAGE_SIZE) -> bool:
        extra = len(key) + len(value) + SLOT_OVERHEAD
        if self.is_leaf:
            old = self.get(key)
            if old is not None:
                extra -= len(key) + len(old) + SLOT_OVERHEAD
        return self.serialized_size() + extra > page_size

    # ------------------------------------------------------------- leaf ops
    def get(self, key: bytes) -> Optional[bytes]:
        recs = self._records
        if recs is not None:
            return recs.get(key)
        raw = self._raw
        assert raw is not None
        i = self._leaf_bisect(key)
        if i >= self._count:
            return None
        off, klen, vlen = _LSLOT.unpack_from(raw, HEADER_SIZE
                                             + i * _LSLOT.size)
        ko = self._cells + off
        vo = ko + klen
        if raw[ko:vo] != key:
            return None
        return raw[vo:vo + vlen]

    def put(self, key: bytes, value: bytes, lsn: LSN) -> None:
        assert self.is_leaf
        self._ensure_unpacked()
        self._raw = None
        recs = self._records
        assert recs is not None
        old = recs.get(key)
        recs[key] = value
        self._sorted = None
        if self._payload >= 0:
            self._payload += len(value) - len(old) if old is not None \
                else len(key) + len(value) + SLOT_OVERHEAD
        if lsn > self.plsn:
            self.plsn = lsn

    def delete(self, key: bytes, lsn: LSN) -> bool:
        assert self.is_leaf
        self._ensure_unpacked()
        self._raw = None
        recs = self._records
        assert recs is not None
        old = recs.pop(key, None)
        self._sorted = None
        if old is not None and self._payload >= 0:
            self._payload -= len(key) + len(old) + SLOT_OVERHEAD
        if lsn > self.plsn:
            self.plsn = lsn
        return old is not None

    # --------------------------------------------------------- serialization
    def to_bytes(self) -> bytes:
        raw = self._raw
        if raw is not None:
            return raw                 # packed and unmutated: zero repack
        if self.is_leaf:
            items = self.sorted_items()
            n = len(items)
            # pack_into over one preallocated directory buffer: ~25% less
            # per-flush cost than accumulating per-slot bytes (this loop is
            # the background flusher's whole bill on redirty-heavy commits)
            dirs_buf = bytearray(n * _LSLOT.size)
            cells: List[bytes] = []
            off = 0
            pos = 0
            pack_into = _LSLOT.pack_into
            append = cells.append
            for k, v in items:
                pack_into(dirs_buf, pos, off, len(k), len(v))
                pos += _LSLOT.size
                append(k)
                append(v)
                off += len(k) + len(v)
            body = bytes(dirs_buf) + b"".join(cells)
            flags = 1
        else:
            keys, children = self._keys, self._children
            assert keys is not None and children is not None
            assert len(children) == len(keys) + 1, "malformed internal node"
            n = len(keys)
            dirs = []
            off = 0
            for k in keys:
                dirs.append(_ISLOT.pack(off, len(k)))
                off += len(k)
            body = (b"".join(dirs)
                    + b"".join(_CHILD.pack(c) for c in children)
                    + b"".join(keys))
            flags = 0
        head = _HEAD.pack(PAGE_MAGIC, PAGE_VERSION, flags, n,
                          self.pid, self.plsn, self.slsn)
        crc = zlib.crc32(body, zlib.crc32(head))
        raw = head + _CRC.pack(crc) + body
        # cache as the packed form: every raw-path reader keys off
        # ``_raw is not None``, so the directory geometry must be kept in
        # sync with the bytes (clean until the next mutation drops it)
        self._raw = raw
        self._count = n
        if self.is_leaf:
            self._cells = HEADER_SIZE + n * _LSLOT.size
        else:
            self._cells = (HEADER_SIZE + n * _ISLOT.size
                           + (n + 1) * _CHILD.size)
        return raw

    @classmethod
    def from_bytes(cls, raw: bytes) -> "Page":
        if raw[:3] == PAGE_MAGIC:
            return cls._from_packed_bytes(raw)
        return cls._from_bytes_v0(raw)

    @classmethod
    def _from_packed_bytes(cls, raw: bytes) -> "Page":
        if len(raw) < HEADER_SIZE:
            raise PageCorruptError(
                f"packed page truncated: {len(raw)}B < {HEADER_SIZE}B header")
        _magic, ver, flags, n, pid, plsn, slsn = _HEAD.unpack_from(raw, 0)
        if ver != PAGE_VERSION:
            raise PageCorruptError(
                f"page {pid}: unknown page format version {ver} "
                f"(this build reads <= {PAGE_VERSION})")
        (crc,) = _CRC.unpack_from(raw, _CRC_OFF)
        if zlib.crc32(raw[HEADER_SIZE:],
                      zlib.crc32(raw[:_CRC_OFF])) != crc:
            raise PageCorruptError(
                f"page {pid}: CRC mismatch (torn write?)")
        is_leaf = bool(flags & 1)
        # declared-length check: the directory must address exactly the
        # cell bytes present (CRC already vouches for content integrity;
        # this catches a packer that lied about its own frame)
        if is_leaf:
            cells = HEADER_SIZE + n * _LSLOT.size
            end = cells
            if n:
                off, klen, vlen = _LSLOT.unpack_from(
                    raw, HEADER_SIZE + (n - 1) * _LSLOT.size)
                end = cells + off + klen + vlen
        else:
            cells = HEADER_SIZE + n * _ISLOT.size + (n + 1) * _CHILD.size
            end = cells
            if n:
                off, klen = _ISLOT.unpack_from(
                    raw, HEADER_SIZE + (n - 1) * _ISLOT.size)
                end = cells + off + klen
        if len(raw) < cells or len(raw) != end:
            raise PageCorruptError(
                f"page {pid}: directory addresses {end}B but frame holds "
                f"{len(raw)}B")
        return cls._from_packed(raw, pid, is_leaf, plsn, slsn, n)

    @classmethod
    def _from_bytes_v0(cls, raw: bytes) -> "Page":
        """v0 (pre-packed) decode — old bytes decode forever; they live on
        inside archived ``SMORec.images``."""
        if len(raw) < _HDR_V0.size:
            raise PageCorruptError(
                f"v0 page truncated: {len(raw)}B < {_HDR_V0.size}B header")
        pid, plsn, slsn, is_leaf, crc, n = _HDR_V0.unpack_from(raw, 0)
        body = raw[_HDR_V0.size:]
        if zlib.crc32(body) != crc:
            raise PageCorruptError(f"page {pid}: CRC mismatch (torn write?)")
        off = 0
        if is_leaf:
            recs = {}
            for _ in range(n):
                klen, vlen = _SLOT_V0.unpack_from(body, off)
                off += _SLOT_V0.size
                k = body[off:off + klen]; off += klen
                v = body[off:off + vlen]; off += vlen
                recs[k] = v
            return cls(pid=pid, is_leaf=True, plsn=plsn, slsn=slsn,
                       records=recs)
        keys = []
        for _ in range(n):
            klen, _vlen = _SLOT_V0.unpack_from(body, off)
            off += _SLOT_V0.size
            keys.append(body[off:off + klen]); off += klen
        children = []
        for _ in range(n + 1):
            (c,) = _CHILD.unpack_from(body, off)
            off += _CHILD.size
            children.append(c)
        return cls(pid=pid, is_leaf=False, plsn=plsn, slsn=slsn,
                   keys=keys, children=children)

    def clone(self) -> "Page":
        return Page.from_bytes(self.to_bytes())

    def copy(self) -> "Page":
        """Independent mutable copy without a serialization round-trip.
        A packed page copies in O(1) — the raw bytes are immutable and
        shared; the copy unpacks privately if mutated.  In dict form,
        keys/values/separators are immutable bytes, so container-shallow
        is deep enough; the ``_sorted`` cache is shared safely because
        invalidation replaces the list, never mutates it."""
        raw = self._raw
        if raw is not None and self._records is None:
            pg = Page._from_packed(raw, self.pid, self.is_leaf,
                                   self.plsn, self.slsn, self._count)
            pg._sorted = self._sorted
            return pg
        assert self._records is not None
        assert self._keys is not None and self._children is not None
        pg = Page(pid=self.pid, is_leaf=self.is_leaf, plsn=self.plsn,
                  slsn=self.slsn, records=dict(self._records),
                  keys=list(self._keys), children=list(self._children),
                  _sorted=self._sorted, _payload=self._payload)
        if raw is not None:
            # dual form: the source is clean, so the copy starts clean too —
            # share the immutable bytes and keep flush at O(1); the first
            # mutation on either side drops its own reference
            pg._raw = raw
            pg._count = self._count
            pg._cells = self._cells
        return pg

    # ------------------------------------------------------------ equality
    def __eq__(self, other: Any) -> bool:
        if not isinstance(other, Page):
            return NotImplemented
        if (self.pid != other.pid or self.is_leaf != other.is_leaf
                or self.plsn != other.plsn or self.slsn != other.slsn):
            return False
        if (self._raw is not None and self._raw is other._raw):
            return True
        self._ensure_unpacked()
        other._ensure_unpacked()
        return (self._records == other._records
                and self._keys == other._keys
                and self._children == other._children)

    __hash__ = None  # type: ignore[assignment]  # mutable, like the old dataclass

    def __repr__(self) -> str:
        kind = "leaf" if self.is_leaf else "internal"
        form = ("dual" if self._raw is not None and self._records is not None
                else "packed" if self._raw is not None else "dict")
        return (f"Page(pid={self.pid}, {kind}, plsn={self.plsn}, "
                f"slsn={self.slsn}, n={self.n_entries()}, {form})")


def pack_v0(page: Page) -> bytes:
    """Serialize in the legacy v0 layout.  Production code never writes
    v0 anymore; this exists so tests can prove old bytes keep decoding."""
    if page.is_leaf:
        items = page.sorted_items()
        body = b"".join(_SLOT_V0.pack(len(k), len(v)) + k + v
                        for k, v in items)
        n = len(items)
    else:
        keys, children = page.keys, page.children
        body = b"".join(_SLOT_V0.pack(len(k), 0) + k for k in keys)
        body += b"".join(_CHILD.pack(c) for c in children)
        n = len(keys)
    crc = zlib.crc32(body)
    return _HDR_V0.pack(page.pid, page.plsn, page.slsn,
                        1 if page.is_leaf else 0, crc, n) + body


def empty_leaf(pid: PID) -> Page:
    return Page(pid=pid, is_leaf=True)


def empty_internal(pid: PID) -> Page:
    return Page(pid=pid, is_leaf=False)
