"""Fixed-size pages: the DC's physical storage unit.

A page is either a B-tree *leaf* (sorted record slots: key -> value bytes) or
an *internal* index node (separator keys + child PIDs).  Pages carry two LSNs:

  ``plsn``  — data LSN: the last *record operation* applied.  Drives the
              redo idempotence test (op needs redo iff op.lsn > plsn).
  ``slsn``  — structure LSN: the last SMO (split/root-growth) that shaped this
              page.  Drives SMO-replay idempotence during DC recovery.

They are separate on purpose: a split redistributes records without changing
the *data* state, so it must not advance ``plsn`` — otherwise a recovery-time
split would cause later record redos to be falsely skipped.  (WAL enforcement
uses the buffer-level ``wal_lsn`` = max of every LSN applied to the buffer.)

A CRC32 detects torn/corrupt stable writes at read time.  ``PAGE_SIZE``
bounds the serialized size; the B-tree splits a page when an insert would
overflow it.
"""
from __future__ import annotations

import struct
import zlib
from dataclasses import dataclass, field

from .records import LSN, NULL_LSN, PID

PAGE_SIZE = 8192
_HDR = struct.Struct("<qqqBIH")     # pid, plsn, slsn, is_leaf, crc, n_entries
_SLOT = struct.Struct("<HI")        # key_len, val_len
_CHILD = struct.Struct("<q")

SLOT_OVERHEAD = _SLOT.size


class PageCorruptError(Exception):
    pass


@dataclass(slots=True)
class Page:
    pid: PID
    is_leaf: bool = True
    plsn: LSN = NULL_LSN
    slsn: LSN = NULL_LSN
    # leaf payload: mapping key -> value (both bytes)
    records: dict = field(default_factory=dict)
    # internal payload: keys[i] separates children[i] (<= keys[i]) from children[i+1]
    keys: list = field(default_factory=list)
    children: list = field(default_factory=list)

    # ------------------------------------------------------------------ size
    def payload_size(self) -> int:
        if self.is_leaf:
            return sum(len(k) + len(v) + SLOT_OVERHEAD for k, v in self.records.items())
        return (sum(len(k) + SLOT_OVERHEAD for k in self.keys)
                + len(self.children) * _CHILD.size)

    def serialized_size(self) -> int:
        return _HDR.size + self.payload_size()

    def would_overflow(self, key: bytes, value: bytes,
                       page_size: int = PAGE_SIZE) -> bool:
        extra = len(key) + len(value) + SLOT_OVERHEAD
        if self.is_leaf and key in self.records:
            extra -= len(key) + len(self.records[key]) + SLOT_OVERHEAD
        return self.serialized_size() + extra > page_size

    # ------------------------------------------------------------- leaf ops
    def get(self, key: bytes):
        return self.records.get(key)

    def put(self, key: bytes, value: bytes, lsn: LSN) -> None:
        assert self.is_leaf
        self.records[key] = value
        if lsn > self.plsn:
            self.plsn = lsn

    def delete(self, key: bytes, lsn: LSN) -> bool:
        assert self.is_leaf
        existed = self.records.pop(key, None) is not None
        if lsn > self.plsn:
            self.plsn = lsn
        return existed

    # --------------------------------------------------------- serialization
    def to_bytes(self) -> bytes:
        if self.is_leaf:
            items = sorted(self.records.items())
            body = b"".join(_SLOT.pack(len(k), len(v)) + k + v for k, v in items)
            n = len(items)
        else:
            assert len(self.children) == len(self.keys) + 1, "malformed internal node"
            body = b"".join(_SLOT.pack(len(k), 0) + k for k in self.keys)
            body += b"".join(_CHILD.pack(c) for c in self.children)
            n = len(self.keys)
        crc = zlib.crc32(body)
        return _HDR.pack(self.pid, self.plsn, self.slsn,
                         1 if self.is_leaf else 0, crc, n) + body

    @classmethod
    def from_bytes(cls, raw: bytes) -> "Page":
        pid, plsn, slsn, is_leaf, crc, n = _HDR.unpack_from(raw, 0)
        body = raw[_HDR.size:]
        if zlib.crc32(body) != crc:
            raise PageCorruptError(f"page {pid}: CRC mismatch (torn write?)")
        off = 0
        if is_leaf:
            recs = {}
            for _ in range(n):
                klen, vlen = _SLOT.unpack_from(body, off)
                off += _SLOT.size
                k = body[off:off + klen]; off += klen
                v = body[off:off + vlen]; off += vlen
                recs[k] = v
            return cls(pid=pid, is_leaf=True, plsn=plsn, slsn=slsn, records=recs)
        keys = []
        for _ in range(n):
            klen, _vlen = _SLOT.unpack_from(body, off)
            off += _SLOT.size
            keys.append(body[off:off + klen]); off += klen
        children = []
        for _ in range(n + 1):
            (c,) = _CHILD.unpack_from(body, off)
            off += _CHILD.size
            children.append(c)
        return cls(pid=pid, is_leaf=False, plsn=plsn, slsn=slsn,
                   keys=keys, children=children)

    def clone(self) -> "Page":
        return Page.from_bytes(self.to_bytes())


def empty_leaf(pid: PID) -> Page:
    return Page(pid=pid, is_leaf=True)


def empty_internal(pid: PID) -> Page:
    return Page(pid=pid, is_leaf=False)
