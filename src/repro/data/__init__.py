from .pipeline import PipelineState, TokenPipeline
