"""Deterministic, resumable synthetic token pipeline.

Counter-based (stateless-random) batches: batch ``k`` is a pure function of
(seed, k), so the pipeline's entire state is one integer cursor.  This is
what makes the paper's logical recovery *exact* for training: a logged step
id fully determines its input batch, so redo-by-replay reproduces the same
gradients bit-for-bit.

The cursor is part of the logged training state (see state_store.train_wal);
after a crash, recovery restores the cursor with everything else.
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig


@dataclass
class PipelineState:
    seed: int
    cursor: int = 0           # next batch index


class TokenPipeline:
    """Markov-ish synthetic LM data: deterministic per (seed, batch_idx)."""

    def __init__(self, cfg: ModelConfig, batch: int, seq: int, seed: int = 0):
        self.cfg = cfg
        self.batch = batch
        self.seq = seq
        self.state = PipelineState(seed=seed)

    def batch_at(self, idx: int) -> dict:
        """Pure function of (seed, idx) — the resumability guarantee."""
        cfg = self.cfg
        key = jax.random.fold_in(jax.random.PRNGKey(self.state.seed), idx)
        k1, k2 = jax.random.split(key)
        # structured tokens (repeating n-grams) so the model has signal
        base = jax.random.randint(k1, (self.batch, self.seq // 4 + 1), 0,
                                  cfg.vocab_size, dtype=jnp.int32)
        toks = jnp.repeat(base, 4, axis=1)[:, :self.seq]
        noise = jax.random.bernoulli(k2, 0.1, toks.shape)
        rand = jax.random.randint(k2, toks.shape, 0, cfg.vocab_size,
                                  dtype=jnp.int32)
        out = {"tokens": jnp.where(noise, rand, toks)}
        if cfg.family == "vlm":
            out["patches"] = jax.random.normal(
                k2, (self.batch, cfg.n_patches, cfg.d_model),
                dtype=jnp.dtype(cfg.dtype))
        elif cfg.family == "audio":
            out["frames"] = jax.random.normal(
                k2, (self.batch, cfg.enc_ctx, cfg.d_model),
                dtype=jnp.dtype(cfg.dtype))
        return out

    def next(self) -> tuple[int, dict]:
        idx = self.state.cursor
        self.state.cursor += 1
        return idx, self.batch_at(idx)

    # -------- recovery integration
    def snapshot(self) -> dict:
        return {"seed": self.state.seed, "cursor": self.state.cursor}

    def restore(self, snap: dict) -> None:
        self.state = PipelineState(seed=int(snap["seed"]),
                                   cursor=int(snap["cursor"]))
