"""Batched serving driver: prefill a request batch, decode greedily.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen2.5-3b \
        --preset smoke --batch 4 --prompt-len 32 --gen 16

Production lowering of the same decode step (one token against a seq_len KV
cache on the 16x16 / 2x16x16 mesh) is exercised by launch.dryrun; this driver
runs the identical code path at CPU scale.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.launch.train import preset_config
from repro.models import build_model, make_batch


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen2.5-3b")
    ap.add_argument("--preset", default="smoke",
                    choices=["smoke", "30m", "100m", "full"])
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    cfg = preset_config(get_config(args.arch), args.preset)
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    batch = make_batch(cfg, args.batch, args.prompt_len,
                       jax.random.PRNGKey(7))

    t0 = time.time()
    logits, cache = jax.jit(api.prefill)(params, batch)
    logits.block_until_ready()
    t_prefill = time.time() - t0
    print(f"prefill: batch={args.batch} len={args.prompt_len} "
          f"in {t_prefill*1e3:.1f} ms")

    decode = jax.jit(api.decode)
    tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
    generated = [tok]
    t0 = time.time()
    for _ in range(args.gen):
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)[:, None]
        generated.append(tok)
    tok.block_until_ready()
    dt = time.time() - t0
    out = jnp.concatenate(generated, axis=1)
    print(f"decode: {args.gen} steps x batch {args.batch} in {dt*1e3:.1f} ms "
          f"({args.gen * args.batch / dt:.1f} tok/s)")
    for b in range(min(2, args.batch)):
        print(f"  request {b}: {out[b].tolist()}")


if __name__ == "__main__":
    main()
