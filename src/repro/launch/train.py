"""Fault-tolerant training driver.

    PYTHONPATH=src python -m repro.launch.train --arch llama3.2-3b \
        --preset 30m --steps 60 --crash-at 35

Presets scale the assigned architecture's family to CPU-runnable sizes
(--preset full uses the assigned geometry; that is what the dry-run lowers on
the production mesh).  The loop is wired to the logical-recovery state store:
per-step heartbeats, incremental chunk transactions, RSSP checkpoints; with
--crash-at it hard-crashes mid-run and then restores + replays, verifying the
resumed state matches exactly.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_config
from repro.data import TokenPipeline
from repro.models import build_model
from repro.optim import AdamWConfig, apply_updates, init_opt_state
from repro.state_store import (TrainWAL, WALConfig, resume_from_crash,
                               train_with_recovery)


def preset_config(cfg, preset: str):
    if preset == "full":
        return cfg
    if preset == "smoke":
        return cfg.reduced()
    if preset == "30m":
        return dataclasses.replace(
            cfg, name=cfg.name + "-30m", n_layers=6, d_model=384, n_heads=6,
            n_kv_heads=max(1, min(6, cfg.n_kv_heads)), d_ff=1152,
            head_dim=64, vocab_size=16384,
            n_experts=min(cfg.n_experts, 8), top_k=min(cfg.top_k, 2),
            moe_d_ff=192 if cfg.n_experts else 0,
            ssm_state=min(cfg.ssm_state, 32),
            attn_every=3 if cfg.attn_every else 0,
            n_enc_layers=4 if cfg.n_enc_layers else 0, enc_ctx=64,
            n_patches=16 if cfg.n_patches else 0, max_seq=2048)
    if preset == "100m":
        return dataclasses.replace(
            cfg, name=cfg.name + "-100m", n_layers=12, d_model=512,
            n_heads=8, n_kv_heads=max(1, min(8, cfg.n_kv_heads)), d_ff=2048,
            head_dim=64, vocab_size=50_304,
            n_experts=min(cfg.n_experts, 16), top_k=min(cfg.top_k, 4),
            moe_d_ff=512 if cfg.n_experts else 0,
            ssm_state=min(cfg.ssm_state, 64),
            attn_every=4 if cfg.attn_every else 0,
            n_enc_layers=6 if cfg.n_enc_layers else 0, enc_ctx=128,
            n_patches=32 if cfg.n_patches else 0, max_seq=2048)
    raise ValueError(preset)


def build_trainer(cfg, batch: int, seq: int, opt_cfg: AdamWConfig):
    api = build_model(cfg)
    params = api.init(jax.random.PRNGKey(0))
    state0 = {"params": params, "opt": init_opt_state(params)}

    @jax.jit
    def train_step(state, batch):
        loss, grads = jax.value_and_grad(api.loss)(state["params"], batch)
        new_p, new_opt, m = apply_updates(state["params"], grads,
                                          state["opt"], opt_cfg)
        return {"params": new_p, "opt": new_opt}, {"loss": loss, **m}

    pipe = TokenPipeline(cfg, batch, seq, seed=1234)
    return api, state0, train_step, pipe


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3.2-3b")
    ap.add_argument("--preset", default="30m",
                    choices=["smoke", "30m", "100m", "full"])
    ap.add_argument("--steps", type=int, default=60)
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--crash-at", type=int, default=0,
                    help="crash after this step, then restore + verify")
    ap.add_argument("--chunk-interval", type=int, default=10)
    ap.add_argument("--ckpt-interval", type=int, default=25)
    args = ap.parse_args()

    cfg = preset_config(get_config(args.arch), args.preset)
    n_params = cfg.n_params()
    print(f"arch={cfg.name} params={n_params/1e6:.1f}M "
          f"batch={args.batch} seq={args.seq}")

    opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=10, total_steps=args.steps)
    api, state0, train_step, pipe = build_trainer(cfg, args.batch, args.seq,
                                                  opt_cfg)
    wal_cfg = WALConfig(chunk_interval=args.chunk_interval,
                        ckpt_interval=args.ckpt_interval,
                        bg_flush_pages=32, cache_pages=8192)
    wal = TrainWAL(wal_cfg)
    wal.log_state(0, 0, state0)

    batch_at = pipe.batch_at
    t0 = time.time()
    if args.crash_at and args.crash_at < args.steps:
        state = train_with_recovery(train_step=train_step, init_state=state0,
                                    batch_at=batch_at, n_steps=args.crash_at,
                                    wal=wal, log_every=10)
        image = wal.crash()
        print(f"--- CRASH at step {args.crash_at} "
              f"(log={len(image.log)} recs, stable pages={len(image.store)})")
        t1 = time.time()
        wal, restored, step, stats = resume_from_crash(
            image, state0, train_step=train_step, batch_at=batch_at,
            wal_cfg=wal_cfg)
        print(f"--- RECOVERED to step {step} in {time.time()-t1:.2f}s wall "
              f"(redo: {stats.redo.submitted} ops submitted, "
              f"{stats.redo.redone} redone, {stats.redo.skipped_dpt} DPT-"
              f"pruned, {stats.io.sync_reads} page fetches, "
              f"DPT={stats.dpt_size})")
        leaves = zip(jax.tree.leaves(restored), jax.tree.leaves(state))
        assert all(jnp.array_equal(a, b) for a, b in leaves), \
            "restored state diverged!"
        print("--- restored state == pre-crash state (bit-exact)")
        state = train_with_recovery(train_step=train_step,
                                    init_state=restored, batch_at=batch_at,
                                    n_steps=args.steps, wal=wal,
                                    start_step=step, log_every=10)
    else:
        state = train_with_recovery(train_step=train_step, init_state=state0,
                                    batch_at=batch_at, n_steps=args.steps,
                                    wal=wal, log_every=10)
    dt = time.time() - t0
    print(f"done: {args.steps} steps in {dt:.1f}s "
          f"({args.steps * args.batch * args.seq / dt:.0f} tok/s)")


if __name__ == "__main__":
    main()
