"""Production mesh construction.

A FUNCTION, not a module-level constant, so importing this module never
touches jax device state (smoke tests must keep seeing 1 CPU device).

Target hardware: TPU v5e pods — 256 chips/pod (16x16), 2 pods for the
multi-pod dry-run.  Axis meaning:
  pod   — data-parallel replicas across pods (gradient all-reduce over DCI)
  data  — in-pod data parallel + FSDP weight sharding + SP for long contexts
  model — tensor/expert parallel
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


# v5e hardware constants (per chip) used by the roofline analysis
PEAK_FLOPS_BF16 = 197e12        # FLOP/s
HBM_BW = 819e9                  # bytes/s
ICI_BW = 50e9                   # bytes/s per link
