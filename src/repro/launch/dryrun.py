import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove the distribution config is coherent.

For every (architecture x input-shape) cell, ``jax.jit(step).lower(...)
.compile()`` must succeed on the 16x16 single-pod mesh AND the 2x16x16
multi-pod mesh.  Dumps memory_analysis + cost_analysis + the per-collective
byte census (parsed from the optimized HLO) to artifacts/dryrun/*.json — the
roofline analysis (benchmarks/roofline_table.py, EXPERIMENTS.md) reads these.

Usage:
  python -m repro.launch.dryrun --arch qwen3-8b --shape train_4k --mesh single
  python -m repro.launch.dryrun --all --mesh both
"""
import argparse
import json
import time
import traceback
from pathlib import Path

import jax

from repro.configs import SHAPES, get_config, list_archs, shape_applicable
from repro.launch.mesh import make_production_mesh
from repro.launch.steps import make_spec
from repro.roofline.analysis import collective_bytes_from_hlo, roofline_terms
from repro.roofline.jaxpr_flops import program_counts

ART = Path(__file__).resolve().parents[3] / "artifacts" / "dryrun"


def run_cell(arch: str, shape_name: str, mesh_kind: str, out_dir: Path,
             force: bool = False, layout: str = "tp",
             no_remat: bool = False) -> dict:
    import dataclasses
    from repro.parallel.sharding import recommended_layout, set_layout
    cfg = get_config(arch)
    shape = next(s for s in SHAPES if s.name == shape_name)
    if layout == "auto":
        layout = recommended_layout(cfg, shape)
    set_layout(layout)
    if no_remat:
        cfg = dataclasses.replace(cfg, remat=False)
    suffix = ("" if layout == "tp" else f"__{layout}") + \
        ("__noremat" if no_remat else "")
    out = out_dir / f"{arch}__{shape_name}__{mesh_kind}{suffix}.json"
    if out.exists() and not force:
        return json.loads(out.read_text())

    ok, why = shape_applicable(cfg, shape)
    rec = {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
           "layout": layout}
    if not ok:
        rec.update(status="skipped", reason=why)
        out.parent.mkdir(parents=True, exist_ok=True)
        out.write_text(json.dumps(rec, indent=1))
        return rec

    t0 = time.time()
    try:
        mesh = make_production_mesh(multi_pod=(mesh_kind == "multi"))
        spec = make_spec(cfg, shape, mesh)
        with mesh:
            lowered = jax.jit(spec.fn).lower(*spec.args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
            mem = compiled.memory_analysis()
            cost = compiled.cost_analysis()
            hlo = compiled.as_text()
        coll = collective_bytes_from_hlo(hlo)
        n_dev = mesh.devices.size
        # XLA:CPU cost_analysis does not multiply while-bodies by trip count,
        # so the authoritative FLOP/byte numbers come from the jaxpr walker
        # (global/logical); cost_analysis values are recorded alongside.
        prog = program_counts(spec.fn, *spec.args)
        top_prims = dict(sorted(prog.by_prim.items(),
                                key=lambda kv: -kv[1][0])[:12])
        xla_flops = float(cost.get("flops", 0.0))
        xla_bytes = float(cost.get("bytes accessed", 0.0))
        rec.update(
            status="ok",
            n_devices=int(n_dev),
            lower_s=round(t_lower, 2),
            compile_s=round(t_compile, 2),
            program_flops=prog.flops,           # global, trip-counted
            program_bytes=prog.bytes,           # global, un-fused upper bound
            program_top_prims=top_prims,
            xla_flops_per_device=xla_flops,
            xla_bytes_per_device=xla_bytes,
            collectives=coll,                   # per-device traffic estimate
            memory={
                "argument_bytes": getattr(mem, "argument_size_in_bytes", None),
                "output_bytes": getattr(mem, "output_size_in_bytes", None),
                "temp_bytes": getattr(mem, "temp_size_in_bytes", None),
                "peak_bytes": getattr(mem, "peak_memory_in_bytes", None),
            },
            model_params=cfg.n_params(),
            model_active_params=cfg.n_active_params(),
            roofline=roofline_terms(
                flops=prog.flops,
                hlo_bytes=xla_bytes * n_dev,
                collective_bytes=coll["total_bytes"] * n_dev,
                n_devices=n_dev, cfg=cfg, shape=shape),
        )
    # reprolint: allow(loud-corruption) — a failing sweep cell is a result to record, not a crash: the error and traceback land in the cell artifact
    except Exception as e:
        rec.update(status="error", error=f"{type(e).__name__}: {e}",
                   traceback=traceback.format_exc()[-4000:])
    out.parent.mkdir(parents=True, exist_ok=True)
    out.write_text(json.dumps(rec, indent=1))
    return rec


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--layout", default="tp", choices=["tp", "fsdp", "dp", "ep", "auto"])
    ap.add_argument("--no-remat", action="store_true")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default=str(ART))
    args = ap.parse_args()
    out_dir = Path(args.out)

    archs = list_archs() if (args.all or args.arch is None) else [args.arch]
    shapes = [s.name for s in SHAPES] if (args.all or args.shape is None) \
        else [args.shape]
    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    failures = 0
    for mesh_kind in meshes:
        for arch in archs:
            for shape_name in shapes:
                rec = run_cell(arch, shape_name, mesh_kind, out_dir,
                               force=args.force, layout=args.layout,
                               no_remat=args.no_remat)
                status = rec["status"]
                extra = ""
                if status == "ok":
                    extra = (f"compile={rec['compile_s']}s "
                             f"pflops={rec['program_flops']:.3g} "
                             f"coll={rec['collectives']['total_bytes']:.3g}B "
                             f"dom={rec['roofline']['dominant']}")
                elif status == "error":
                    extra = rec["error"][:160]
                    failures += 1
                print(f"[{mesh_kind:6s}] {arch:24s} {shape_name:12s} "
                      f"{status:8s} {extra}", flush=True)
    if failures:
        raise SystemExit(f"{failures} cell(s) failed")


if __name__ == "__main__":
    main()
