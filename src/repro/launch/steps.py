"""Step builders: the jit-able train / prefill / decode entry points with
their input ShapeDtypeStruct specs + shardings — shared by the dry-run, the
roofline analysis, and the real train/serve drivers.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.configs.base import ModelConfig, ShapeConfig
from repro.models import build_model
from repro.optim import AdamWConfig, apply_updates, init_opt_state
from repro.parallel.sharding import (batch_pspec, cache_pspecs,
                                     params_shardings, opt_shardings,
                                     to_named)


def _sds(shape, dtype, sharding=None):
    return jax.ShapeDtypeStruct(shape, dtype, sharding=sharding)


def batch_structs(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                  seq: Optional[int] = None) -> dict:
    B = shape.global_batch
    S = seq if seq is not None else shape.seq_len
    specs = batch_pspec(cfg, mesh, B)
    dt = jnp.dtype(cfg.dtype)
    out = {"tokens": _sds((B, S), jnp.int32,
                          NamedSharding(mesh, specs["tokens"]))}
    if cfg.family == "vlm":
        out["patches"] = _sds((B, cfg.n_patches, cfg.d_model), dt,
                              NamedSharding(mesh, specs["patches"]))
    elif cfg.family == "audio":
        out["frames"] = _sds((B, cfg.enc_ctx, cfg.d_model), dt,
                             NamedSharding(mesh, specs["frames"]))
    return out


@dataclass
class LoweringSpec:
    """Everything needed to .lower() one (arch x shape x mesh) cell."""
    fn: Callable
    args: tuple
    donate: tuple = ()


def _sharded_struct_tree(shape_tree, shardings):
    return jax.tree.map(
        lambda s, sh: _sds(s.shape, s.dtype, sh), shape_tree, shardings)


def make_train_spec(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh,
                    opt_cfg: Optional[AdamWConfig] = None) -> LoweringSpec:
    api = build_model(cfg)
    opt_cfg = opt_cfg or AdamWConfig()
    p_shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_sh = params_shardings(p_shapes, mesh)

    def train_step(params, opt_state, batch):
        loss, grads = jax.value_and_grad(api.loss)(params, batch)
        # grads adopt the optimizer-state sharding.  Intended to lower the
        # data-parallel reduction to reduce-scatter (1x traffic) instead of
        # all-reduce (2x); measured NO-OP on this XLA version — the
        # partitioner emits AR+slice anyway (EXPERIMENTS.md §Perf, llama
        # it3, refuted).  Kept because it is semantically correct and
        # future partitioners (Shardy) fuse it.
        grads = jax.tree.map(
            lambda g, s: jax.lax.with_sharding_constraint(g, s),
            grads, p_sh)
        new_params, new_opt, metrics = apply_updates(params, grads,
                                                     opt_state, opt_cfg)
        return new_params, new_opt, {"loss": loss, **metrics}
    o_shapes = jax.eval_shape(init_opt_state, p_shapes)
    o_sh = opt_shardings(o_shapes, mesh, p_sh)
    params_in = _sharded_struct_tree(p_shapes, p_sh)
    opt_in = _sharded_struct_tree(o_shapes, o_sh)
    batch_in = batch_structs(cfg, shape, mesh)
    return LoweringSpec(fn=train_step, args=(params_in, opt_in, batch_in))


def make_prefill_spec(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                      ) -> LoweringSpec:
    api = build_model(cfg)

    def prefill_step(params, batch):
        return api.prefill(params, batch)

    p_shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_sh = params_shardings(p_shapes, mesh)
    params_in = _sharded_struct_tree(p_shapes, p_sh)
    batch_in = batch_structs(cfg, shape, mesh)
    return LoweringSpec(fn=prefill_step, args=(params_in, batch_in))


def make_decode_spec(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh
                     ) -> LoweringSpec:
    """serve_step: ONE new token against a seq_len KV cache."""
    api = build_model(cfg)
    B = shape.global_batch
    max_len = shape.seq_len

    def decode_fn(params, cache, tokens):
        return api.decode(params, cache, tokens)

    p_shapes = jax.eval_shape(api.init, jax.random.PRNGKey(0))
    p_sh = params_shardings(p_shapes, mesh)
    params_in = _sharded_struct_tree(p_shapes, p_sh)
    c_shapes = jax.eval_shape(lambda: api.init_cache(B, max_len))
    c_sh = to_named(cache_pspecs(cfg, mesh, B, max_len), mesh)
    cache_in = jax.tree.map(lambda s, sh: _sds(s.shape, s.dtype, sh),
                            c_shapes, c_sh)
    tok_spec = batch_pspec(cfg, mesh, B)["tokens"]
    tokens_in = _sds((B, 1), jnp.int32, NamedSharding(mesh, tok_spec))
    return LoweringSpec(fn=decode_fn, args=(params_in, cache_in, tokens_in))


def make_spec(cfg: ModelConfig, shape: ShapeConfig, mesh: Mesh) -> LoweringSpec:
    if shape.kind == "train":
        return make_train_spec(cfg, shape, mesh)
    if shape.kind == "prefill":
        return make_prefill_spec(cfg, shape, mesh)
    return make_decode_spec(cfg, shape, mesh)
