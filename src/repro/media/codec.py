"""Versioned binary codec for durable artifacts: log records, sealed
segments, snapshot rows, and the master pointer.

Everything a dead primary leaves behind must be *bytes on a backend*, not
references into a Python heap — that is what lets ``cold_restore`` rebuild
state in a process that shares nothing with the one that died.  This
module owns the byte format:

  record   kind byte + per-kind fields (length-prefixed bytes, fixed-width
           ints); every ``RecKind`` in ``core.records`` round-trips exactly
           (``decode_record(encode_record(r)) == r``).
  frame    ``u32 length + u32 crc32 + payload`` — the unit of corruption
           detection.  A truncated or bit-flipped frame raises
           ``CorruptSegmentError``; decoding never returns a short stream.
  segment  magic + format-version byte + header frame (lo, hi, count) +
           one frame per record.  The header count is cross-checked against
           the frames actually present and their LSN run.
  snapshot magic + version + meta frame (id, begin, end, redo, chunks,
           n_rows) + one frame per row.
  master   magic + version + one frame (the three master LSNs).

The format-version byte is the compatibility hinge: decoders accept every
version they know and raise ``UnknownFormatError`` for anything newer, so
old segments stay readable when the format evolves.

Segments additionally carry a *feature byte* from format version 2 on:
a bitmask of per-blob options.  Bit 0 (``FEAT_ZLIB``) marks the record
region as zlib-compressed (the header frame stays raw so index rebuild
keeps reading 64-byte heads).  Version-1 segments have no feature byte
and decode exactly as before — old uncompressed archives stay readable —
while an unknown feature bit raises ``UnknownFormatError`` loudly: a
decoder that ignored a bit it does not understand would misparse the
payload behind it.
"""
from __future__ import annotations

import struct
import zlib
from typing import TYPE_CHECKING, Optional

from ..core.log import Master
from ..core.records import (AbortRec, BWRec, BeginCkptRec, CLRRec, CommitRec,
                            DeltaRec, EndCkptRec, LogRec, RSSPRec, RecKind,
                            SMORec, SnapshotRec, UpdateRec)
from .errors import CorruptSegmentError, UnknownFormatError

if TYPE_CHECKING:   # import cycle: archive imports the codec at runtime
    from ..archive.snapshot import Snapshot

FORMAT_VERSION = 1
# segments evolved past the other blob kinds: v2 adds the feature byte
SEGMENT_FORMAT_VERSION = 2
FEAT_ZLIB = 0x01                    # record region is zlib-compressed
KNOWN_FEATURES = FEAT_ZLIB
SEGMENT_MAGIC = b"RSEG"
SNAPSHOT_MAGIC = b"RSNP"
MASTER_MAGIC = b"RMST"
ARCHIVE_META_MAGIC = b"RAMT"

_U32 = struct.Struct("<I")
_U64 = struct.Struct("<Q")
_I64 = struct.Struct("<q")
_FRAME = struct.Struct("<II")      # length, crc32


# ------------------------------------------------------------- primitives
class _Writer:
    __slots__ = ("parts",)

    def __init__(self) -> None:
        self.parts: list[bytes] = []

    def u32(self, v: int) -> None:
        self.parts.append(_U32.pack(v))

    def u64(self, v: int) -> None:
        self.parts.append(_U64.pack(v))

    def i64(self, v: int) -> None:
        self.parts.append(_I64.pack(v))

    def blob(self, b: bytes) -> None:
        self.parts.append(_U32.pack(len(b)))
        self.parts.append(b)

    def opt_blob(self, b: Optional[bytes]) -> None:
        if b is None:
            self.parts.append(b"\x00")
        else:
            self.parts.append(b"\x01")
            self.blob(b)

    def getvalue(self) -> bytes:
        return b"".join(self.parts)


class _Reader:
    __slots__ = ("buf", "pos", "what")

    def __init__(self, buf: bytes, what: str = "payload") -> None:
        self.buf = buf
        self.pos = 0
        self.what = what

    def take(self, n: int) -> bytes:
        end = self.pos + n
        if end > len(self.buf):
            raise CorruptSegmentError(
                f"truncated {self.what}: needed {n} bytes at offset "
                f"{self.pos}, only {len(self.buf) - self.pos} remain")
        out = self.buf[self.pos:end]
        self.pos = end
        return out

    def u32(self) -> int:
        return _U32.unpack(self.take(4))[0]

    def u64(self) -> int:
        return _U64.unpack(self.take(8))[0]

    def i64(self) -> int:
        return _I64.unpack(self.take(8))[0]

    def blob(self) -> bytes:
        return self.take(self.u32())

    def opt_blob(self) -> Optional[bytes]:
        return self.blob() if self.take(1) == b"\x01" else None

    @property
    def exhausted(self) -> bool:
        return self.pos >= len(self.buf)


def _frame(payload: bytes) -> bytes:
    return _U32.pack(len(payload)) + _U32.pack(zlib.crc32(payload)) + payload


def _read_frame(r: _Reader, what: str) -> _Reader:
    r.what = what
    n = r.u32()
    crc = r.u32()
    payload = r.take(n)
    if zlib.crc32(payload) != crc:
        raise CorruptSegmentError(
            f"CRC mismatch on {what}: stored {crc:#010x}, computed "
            f"{zlib.crc32(payload):#010x} — the blob is corrupt")
    return _Reader(payload, what)


def _check_header(r: _Reader, magic: bytes, what: str,
                  max_version: int = FORMAT_VERSION) -> int:
    """Validate magic + format version; returns the version."""
    got = r.take(4)
    if got != magic:
        raise CorruptSegmentError(
            f"bad magic on {what}: expected {magic!r}, got {got!r} — "
            "not a media blob, or the wrong blob kind")
    version = r.take(1)[0]
    if version > max_version or version == 0:
        raise UnknownFormatError(
            f"{what} has format version {version}; this codec reads "
            f"versions 1..{max_version} — upgrade to read it")
    return version


def _segment_features(r: _Reader) -> int:
    """Segment prologue past the magic: version (1..2), then the v2
    feature byte.  Unknown feature bits are loud — a decoder that ignored
    one would misparse everything behind it."""
    version = _check_header(r, SEGMENT_MAGIC, "segment",
                            max_version=SEGMENT_FORMAT_VERSION)
    feat = r.take(1)[0] if version >= 2 else 0
    unknown = feat & ~KNOWN_FEATURES
    if unknown:
        raise UnknownFormatError(
            f"segment carries unknown feature bits {unknown:#04x} "
            f"(known: {KNOWN_FEATURES:#04x}) — upgrade to read it")
    return feat


# ---------------------------------------------------------------- records
def encode_record(rec: LogRec) -> bytes:
    """One record -> kind-tagged payload (no frame; see ``_frame``)."""
    w = _Writer()
    kind = rec.kind
    w.parts.append(bytes([kind]))
    w.u64(rec.lsn)
    if isinstance(rec, UpdateRec):
        w.u64(rec.txn)
        w.blob(rec.table.encode("utf-8"))
        w.blob(rec.key)
        w.opt_blob(rec.before)
        w.opt_blob(rec.after)
        w.i64(rec.pid)
        w.u64(rec.prev_lsn)
    elif isinstance(rec, (CommitRec, AbortRec)):
        w.u64(rec.txn)
        w.u64(rec.prev_lsn)
    elif isinstance(rec, CLRRec):
        w.u64(rec.txn)
        w.blob(rec.table.encode("utf-8"))
        w.blob(rec.key)
        w.opt_blob(rec.after)
        w.parts.append(bytes([rec.op]))
        w.i64(rec.pid)
        w.u64(rec.undone_lsn)
        w.u64(rec.undo_next)
    elif isinstance(rec, BeginCkptRec):
        pass
    elif isinstance(rec, EndCkptRec):
        w.u64(rec.bckpt_lsn)
        w.u32(len(rec.active_txns))
        for txn, lsn in rec.active_txns.items():
            w.u64(txn)
            w.u64(lsn)
    elif isinstance(rec, BWRec):
        w.u32(len(rec.written_set))
        for pid in rec.written_set:
            w.i64(pid)
        w.u64(rec.fw_lsn)
    elif isinstance(rec, DeltaRec):
        w.u32(len(rec.dirty_set))
        for pid in rec.dirty_set:
            w.i64(pid)
        w.u32(len(rec.written_set))
        for pid in rec.written_set:
            w.i64(pid)
        w.u64(rec.fw_lsn)
        w.u64(rec.first_dirty)
        w.u64(rec.tc_lsn)
        if rec.dirty_lsns is None:
            w.parts.append(b"\x00")
        else:
            w.parts.append(b"\x01")
            w.u32(len(rec.dirty_lsns))
            for lsn in rec.dirty_lsns:
                w.u64(lsn)
    elif isinstance(rec, SMORec):
        w.u32(len(rec.images))
        for pid, image in rec.images.items():
            w.i64(pid)
            w.blob(image)
        w.i64(rec.root_pid)
        w.i64(rec.next_pid)
        w.u64(rec.height)
    elif isinstance(rec, RSSPRec):
        w.u64(rec.rssp_lsn)
        w.i64(rec.root_pid)
        w.i64(rec.next_pid)
        w.u64(rec.height)
    elif isinstance(rec, SnapshotRec):
        w.u64(rec.snapshot_id)
        w.u64(rec.oldest_active_lsn)
    else:
        raise TypeError(f"no encoder for record type {type(rec).__name__}")
    return w.getvalue()


def decode_record(payload: bytes) -> LogRec:
    try:
        return _decode_record(payload)
    except (struct.error, IndexError, ValueError) as exc:
        # short fields, an unknown kind byte, invalid UTF-8 in a table
        # name — all corruption, all loud (CorruptSegmentError itself is
        # a RuntimeError and passes through untouched)
        raise CorruptSegmentError(
            f"corrupt record payload: {exc}") from None


def _take(payload: bytes, off: int, n: int) -> bytes:
    end = off + n
    if end > len(payload):
        raise struct.error(f"needed {n} bytes at offset {off}, "
                           f"only {len(payload) - off} remain")
    return payload[off:end]


def _decode_update(payload: bytes, kind: RecKind, lsn: int) -> UpdateRec:
    """Manual-offset fast path for the record kinds that dominate every
    redo stream — per-field reader calls, dataclass ``__init__`` kwargs
    and enum construction are the hot costs of decoding a segment, and
    cold restore is all segment decode."""
    off = 9
    txn, tl = struct.unpack_from("<QI", payload, off)
    off += 12
    table = _take(payload, off, tl).decode("utf-8")
    off += tl
    kl, = _U32.unpack_from(payload, off)
    off += 4
    key = _take(payload, off, kl)
    off += kl
    before = after = None
    if payload[off]:
        bl, = _U32.unpack_from(payload, off + 1)
        before = _take(payload, off + 5, bl)
        off += 5 + bl
    else:
        off += 1
    if payload[off]:
        al, = _U32.unpack_from(payload, off + 1)
        after = _take(payload, off + 5, al)
        off += 5 + al
    else:
        off += 1
    pid, prev_lsn = struct.unpack_from("<qQ", payload, off)
    if off + 16 != len(payload):
        raise CorruptSegmentError(
            f"record payload has {len(payload) - off - 16} trailing bytes "
            f"after a complete {kind.name} record")
    rec = UpdateRec.__new__(UpdateRec)     # bypass __init__: slot stores
    rec.lsn = lsn
    rec.txn = txn
    rec.table = table
    rec.key = key
    rec.before = before
    rec.after = after
    rec.pid = pid
    rec.prev_lsn = prev_lsn
    rec.op = kind
    rec.ck = None
    return rec


# byte value -> interned RecKind member: RecKind(x) goes through the
# EnumMeta call protocol, which is measurable at per-record scale
_KIND_BY_BYTE = {int(k): k for k in RecKind}


def _decode_record(payload: bytes) -> LogRec:
    kb = payload[0]
    lsn, = _U64.unpack_from(payload, 1)
    if kb == 1 or kb == 2 or kb == 3:      # UPDATE / INSERT / DELETE
        return _decode_update(payload, _KIND_BY_BYTE[kb], lsn)
    if kb == 4:                            # COMMIT
        txn, prev = struct.unpack_from("<QQ", payload, 9)
        if len(payload) != 25:
            raise CorruptSegmentError(
                "COMMIT record payload has trailing bytes")
        rec = CommitRec.__new__(CommitRec)
        rec.lsn = lsn
        rec.txn = txn
        rec.prev_lsn = prev
        return rec
    kind = _KIND_BY_BYTE.get(kb)
    if kind is None:
        raise ValueError(f"{kb} is not a valid RecKind")
    r = _Reader(payload, "record")
    r.pos = 9
    if kind == RecKind.ABORT:
        rec = AbortRec(lsn=lsn, txn=r.u64(), prev_lsn=r.u64())
    elif kind == RecKind.CLR:
        rec = CLRRec(lsn=lsn, txn=r.u64(),
                     table=r.blob().decode("utf-8"), key=r.blob(),
                     after=r.opt_blob(), op=RecKind(r.take(1)[0]),
                     pid=r.i64(), undone_lsn=r.u64(), undo_next=r.u64())
    elif kind == RecKind.BEGIN_CKPT:
        rec = BeginCkptRec(lsn=lsn)
    elif kind == RecKind.END_CKPT:
        bckpt = r.u64()
        active = {}
        for _ in range(r.u32()):
            txn = r.u64()            # explicit order: txn precedes its lsn
            active[txn] = r.u64()
        rec = EndCkptRec(lsn=lsn, bckpt_lsn=bckpt, active_txns=active)
    elif kind == RecKind.BW:
        written = [r.i64() for _ in range(r.u32())]
        rec = BWRec(lsn=lsn, written_set=written, fw_lsn=r.u64())
    elif kind == RecKind.DELTA:
        dirty = [r.i64() for _ in range(r.u32())]
        written = [r.i64() for _ in range(r.u32())]
        fw, first_dirty, tc = r.u64(), r.u64(), r.u64()
        dirty_lsns = None
        if r.take(1) == b"\x01":
            dirty_lsns = [r.u64() for _ in range(r.u32())]
        rec = DeltaRec(lsn=lsn, dirty_set=dirty, written_set=written,
                       fw_lsn=fw, first_dirty=first_dirty, tc_lsn=tc,
                       dirty_lsns=dirty_lsns)
    elif kind == RecKind.SMO:
        images = {}
        for _ in range(r.u32()):
            pid = r.i64()
            images[pid] = r.blob()
        rec = SMORec(lsn=lsn, images=images, root_pid=r.i64(),
                     next_pid=r.i64(), height=r.u64())
    elif kind == RecKind.RSSP:
        rec = RSSPRec(lsn=lsn, rssp_lsn=r.u64(), root_pid=r.i64(),
                      next_pid=r.i64(), height=r.u64())
    elif kind == RecKind.SNAPSHOT:
        rec = SnapshotRec(lsn=lsn, snapshot_id=r.u64(),
                          oldest_active_lsn=r.u64())
    else:  # pragma: no cover — RecKind() above already rejects unknowns
        raise CorruptSegmentError(f"unknown record kind {kind}")
    if not r.exhausted:
        raise CorruptSegmentError(
            f"record payload has {len(payload) - r.pos} trailing bytes "
            f"after a complete {kind.name} record")
    return rec


# --------------------------------------------------------------- segments
def encode_segment(records, *, compress: bool = False) -> bytes:
    """Encode one sealed, LSN-contiguous run of records.  ``compress``
    zlib-compresses the record region (feature bit ``FEAT_ZLIB``); the
    header frame stays raw so header-only reads keep working."""
    records = list(records)
    if not records:
        raise ValueError("cannot encode an empty segment")
    lo, hi = records[0].lsn, records[-1].lsn
    header = _Writer()
    header.u64(lo)
    header.u64(hi)
    header.u32(len(records))
    body = b"".join(_frame(encode_record(rec)) for rec in records)
    feat = 0
    if compress:
        feat |= FEAT_ZLIB
        body = zlib.compress(body, 6)
    return b"".join([SEGMENT_MAGIC, bytes([SEGMENT_FORMAT_VERSION, feat]),
                     _frame(header.getvalue()), body])


def decode_segment_header(blob: bytes) -> tuple[int, int, int]:
    """(lo, hi, count) without decoding the records — what ``LogArchive.
    load`` needs to rebuild its index from a backend listing."""
    r = _Reader(blob, "segment")
    _segment_features(r)
    h = _read_frame(r, "segment header")
    return h.u64(), h.u64(), h.u32()


def decode_segment_features(blob: bytes) -> int:
    """The feature byte of a segment blob (0 for v1 blobs) from its head
    alone — lets a reopened archive adopt the writer's settings instead
    of silently resetting them."""
    return _segment_features(_Reader(blob, "segment"))


def decode_segment(blob: bytes) -> list[LogRec]:
    """Decode a full segment; validates CRC per frame, the header count,
    and the LSN run — a segment is whole or it is an error, never short."""
    r = _Reader(blob, "segment")
    feat = _segment_features(r)
    h = _read_frame(r, "segment header")
    lo, hi, count = h.u64(), h.u64(), h.u32()
    if count != hi - lo + 1:
        raise CorruptSegmentError(
            f"segment header inconsistent: [{lo}, {hi}] cannot hold "
            f"{count} records")
    records = []
    buf, off = r.buf, r.pos
    if feat & FEAT_ZLIB:
        try:
            buf, off = zlib.decompress(buf[off:]), 0
        except zlib.error as exc:
            raise CorruptSegmentError(
                f"segment [{lo}, {hi}]: compressed record region does not "
                f"inflate ({exc}) — the blob is corrupt") from None
    crc32 = zlib.crc32
    for i in range(count):
        # manual-offset frame parse — this loop is the cold-restore and
        # cold-scan hot path, where per-field reader calls are pure tax
        try:
            ln, crc = _FRAME.unpack_from(buf, off)
        except struct.error:
            raise CorruptSegmentError(
                f"truncated segment record {i} of {count}: frame header "
                f"cut short at offset {off}") from None
        off += 8
        payload = buf[off:off + ln]
        if len(payload) != ln:
            raise CorruptSegmentError(
                f"truncated segment record {i} of {count}: declared "
                f"{ln} bytes, {len(payload)} present")
        if crc32(payload) != crc:
            raise CorruptSegmentError(
                f"CRC mismatch on segment record {i} of {count} — "
                "the blob is corrupt")
        off += ln
        records.append(decode_record(payload))
    if off != len(buf):
        raise CorruptSegmentError(
            f"segment [{lo}, {hi}] has {len(buf) - off} trailing "
            "bytes after its declared records")
    for want, rec in zip(range(lo, hi + 1), records):
        if rec.lsn != want:
            raise CorruptSegmentError(
                f"segment [{lo}, {hi}] record stream broke at LSN "
                f"{rec.lsn} (expected {want}) — non-contiguous run")
    return records


# -------------------------------------------------------------- snapshots
def encode_snapshot(snap) -> bytes:
    """Encode an ``archive.Snapshot`` (metadata + committed rows)."""
    meta = _Writer()
    meta.u64(snap.snapshot_id)
    meta.u64(snap.begin_lsn)
    meta.u64(snap.end_lsn)
    meta.u64(snap.redo_lsn)
    meta.u32(snap.chunks)
    meta.u32(len(snap.rows))
    parts = [SNAPSHOT_MAGIC, bytes([FORMAT_VERSION]),
             _frame(meta.getvalue())]
    for key, value in snap.rows:
        row = _Writer()
        row.blob(key)
        row.blob(value)
        parts.append(_frame(row.getvalue()))
    return b"".join(parts)


def decode_snapshot(blob: bytes) -> "Snapshot":
    """Decode a snapshot blob back into an ``archive.Snapshot``."""
    from ..archive.snapshot import Snapshot  # codec stays import-light
    r = _Reader(blob, "snapshot")
    _check_header(r, SNAPSHOT_MAGIC, "snapshot")
    meta = _read_frame(r, "snapshot metadata")
    snapshot_id, begin, end, redo = (meta.u64(), meta.u64(), meta.u64(),
                                     meta.u64())
    chunks, n_rows = meta.u32(), meta.u32()
    rows = []
    buf, off = r.buf, r.pos
    try:
        for i in range(n_rows):
            # manual-offset row parse (reseed decodes every row of a big
            # snapshot; the _Reader per-field calls are pure overhead)
            ln, crc = _FRAME.unpack_from(buf, off)
            off += 8
            payload = buf[off:off + ln]
            if len(payload) != ln:
                raise CorruptSegmentError(
                    f"truncated snapshot row {i} of {n_rows}: declared "
                    f"{ln} bytes, {len(payload)} present")
            if zlib.crc32(payload) != crc:
                raise CorruptSegmentError(
                    f"CRC mismatch on snapshot row {i} of {n_rows} — "
                    "the blob is corrupt")
            off += ln
            kl, = _U32.unpack_from(payload, 0)
            key = _take(payload, 4, kl)
            vl, = _U32.unpack_from(payload, 4 + kl)
            value = _take(payload, 8 + kl, vl)
            if 8 + kl + vl != ln:
                raise CorruptSegmentError(
                    f"snapshot row {i} frame has trailing bytes")
            rows.append((key, value))
    except struct.error as exc:
        raise CorruptSegmentError(
            f"truncated snapshot row {i} of {n_rows}: {exc}") from None
    if off != len(buf):
        raise CorruptSegmentError(
            f"snapshot {snapshot_id} has trailing bytes after its "
            f"{n_rows} declared rows")
    return Snapshot(snapshot_id=snapshot_id, begin_lsn=begin, end_lsn=end,
                    redo_lsn=redo, rows=tuple(rows), chunks=chunks)


# ----------------------------------------------------------- archive meta
def encode_archive_meta(retained_from: int, archived_upto: int,
                        pruned_records: int) -> bytes:
    """The archive's frontier state, persisted because segments alone
    cannot always reconstruct it: retention may legitimately prune *every*
    segment (a fresh snapshot's redo_lsn past the sealed frontier), and a
    fresh process must still know the frontier and the prune floor —
    otherwise a restore target inside the empty-but-covered range would
    be refused, and a scan below the floor could fail quietly."""
    w = _Writer()
    w.u64(retained_from)
    w.u64(archived_upto)
    w.u64(pruned_records)
    return (ARCHIVE_META_MAGIC + bytes([FORMAT_VERSION])
            + _frame(w.getvalue()))


def decode_archive_meta(blob: bytes) -> tuple[int, int, int]:
    """(retained_from, archived_upto, pruned_records)."""
    r = _Reader(blob, "archive meta")
    _check_header(r, ARCHIVE_META_MAGIC, "archive meta")
    m = _read_frame(r, "archive meta")
    return m.u64(), m.u64(), m.u64()


# ----------------------------------------------------------------- master
def encode_master(master: Master) -> bytes:
    w = _Writer()
    w.u64(master.end_ckpt_lsn)
    w.u64(master.bckpt_lsn)
    w.u64(master.rssp_rec_lsn)
    return (MASTER_MAGIC + bytes([FORMAT_VERSION])
            + _frame(w.getvalue()))


def decode_master(blob: bytes) -> Master:
    r = _Reader(blob, "master")
    _check_header(r, MASTER_MAGIC, "master")
    m = _read_frame(r, "master pointer")
    return Master(end_ckpt_lsn=m.u64(), bckpt_lsn=m.u64(),
                  rssp_rec_lsn=m.u64())
