"""Durable media layer: bytes on a backend, not references in a heap.

The paper's premise is that logical recovery rebuilds state from the log
without any physical context — so the durable artifacts themselves must
be expressible across a real storage boundary.  This package owns that
boundary:

  codec      versioned, length-prefixed, CRC-framed binary encoding for
             every log-record kind, sealed segments, snapshot rows, and
             the master pointer
  backend    MediaBackend interface; MemoryBackend (dict) and
             DirectoryBackend (atomic rename-on-seal, fsync'd manifest)
  restore    cold_restore / cold_restore_replica / archive_log_view —
             rebuild a writable Database or a pre-seeded standby in a
             fresh process from a backend alone
  errors     CorruptSegmentError / UnknownFormatError /
             BackendMissingError — the "loud hole" contract in byte form

``restore`` is imported lazily (module ``__getattr__``): it pulls in the
archive and TC layers, which themselves build on ``codec``/``backend``.
"""
from .backend import (DirectoryBackend, MediaBackend, MemoryBackend,
                      open_backend)
from .codec import (FORMAT_VERSION, decode_master, decode_record,
                    decode_segment, decode_segment_header, decode_snapshot,
                    encode_master, encode_record, encode_segment,
                    encode_snapshot)
from .errors import (BackendMissingError, BackendUnavailableError,
                     CorruptSegmentError, MediaError, TransientMediaError,
                     UnknownFormatError)

_LAZY = ("cold_restore", "cold_restore_replica", "archive_log_view",
         "load_media")

__all__ = [
    "MediaBackend", "MemoryBackend", "DirectoryBackend", "open_backend",
    "FORMAT_VERSION", "encode_record", "decode_record", "encode_segment",
    "decode_segment", "decode_segment_header", "encode_snapshot",
    "decode_snapshot", "encode_master", "decode_master",
    "MediaError", "TransientMediaError", "BackendUnavailableError",
    "CorruptSegmentError", "UnknownFormatError",
    "BackendMissingError", *_LAZY,
]


def __getattr__(name: str) -> object:
    if name in _LAZY:
        from . import restore
        return getattr(restore, name)
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")
