"""Pluggable blob stores for durable artifacts.

A ``MediaBackend`` is the storage boundary of the Deuteronomy-style
contract: above it, ``LogArchive`` / ``SnapshotStore`` / the master
pointer deal in *named byte blobs*; below it, bytes live wherever the
deployment wants them.  Two implementations:

  MemoryBackend     a dict — the in-process tier the existing tests and
                    benchmarks run on, byte-for-byte the same format.
  DirectoryBackend  files under a root directory, with the two properties
                    real durability needs: atomic publication (write to a
                    temp file, fsync, ``os.replace`` onto the final name —
                    a crash mid-seal leaves either the old blob or the new
                    one, never a torn file) and a fsync'd manifest that is
                    the authoritative listing (a stray temp file or a blob
                    whose manifest update never landed is invisible).

Names are hierarchical (``seg/000000000001``, ``snap/00000003``,
``master``); ``list(prefix)`` filters on the name prefix.  Blob content is
already CRC-framed by the codec, so backends store and return bytes
opaquely — corruption is detected at decode, loudly.
"""
from __future__ import annotations

import os
import tempfile
from pathlib import Path
from typing import Union

from ..obs import metrics as _metrics
from ..obs.flightrec import FLIGHT as _FLIGHT
from .errors import BackendMissingError

MANIFEST = "MANIFEST"


class MediaBackend:
    """Interface: named, immutable-by-convention byte blobs.  ``put`` on
    an existing name atomically replaces it (tail-segment extension)."""

    def put(self, name: str, data: bytes) -> None:
        raise NotImplementedError

    def get(self, name: str) -> bytes:
        raise NotImplementedError

    def delete(self, name: str) -> None:
        raise NotImplementedError

    def list(self, prefix: str = "") -> list[str]:
        """Sorted names starting with ``prefix``."""
        raise NotImplementedError

    def get_head(self, name: str, n: int) -> bytes:
        """First ``n`` bytes of a blob — enough for a framed header.
        Backends with cheap ranged reads override this so index rebuild
        (``LogArchive.load``) costs O(headers), not O(archive bytes)."""
        return self.get(name)[:n]

    def exists(self, name: str) -> bool:
        """Boolean probe: is ``name`` present?

        Classification-correct: only a *definite* absence
        (``BackendMissingError``) maps to False.  A transient outage
        (``BackendUnavailableError``) propagates — the backend did not
        answer, and reporting "missing" would let retention or restore
        act on data loss that never happened.  Corruption likewise
        propagates (this probe reads bytes, it does not validate them,
        but a backend that detects a torn blob must stay loud)."""
        try:
            self.get_head(name, 1)
            return True
        except BackendMissingError:
            return False

    def _init_metrics(self, kind: str) -> None:
        """Blob-I/O probes, labelled per backend kind; subclasses call
        this from ``__init__`` and count through the cached handles."""
        self._c_put = _metrics.counter("media.put_blobs", backend=kind)
        self._c_put_bytes = _metrics.counter("media.put_bytes", backend=kind)
        self._c_get = _metrics.counter("media.get_blobs", backend=kind)
        self._c_get_bytes = _metrics.counter("media.get_bytes", backend=kind)
        self._c_del = _metrics.counter("media.delete_blobs", backend=kind)


class MemoryBackend(MediaBackend):
    """Blobs in a dict: same codec bytes, no disk.  The default backend —
    everything PR 3 did in-process keeps exactly its old semantics, just
    with encoded segments instead of shared record references."""

    def __init__(self) -> None:
        self._blobs: dict[str, bytes] = {}
        self._init_metrics("memory")

    def put(self, name: str, data: bytes) -> None:
        self._blobs[name] = bytes(data)
        self._c_put.inc()
        self._c_put_bytes.inc(len(data))
        _FLIGHT.record("media.put", len(data))

    def get(self, name: str) -> bytes:
        try:
            raw = self._blobs[name]
        except KeyError:
            raise BackendMissingError(name, "MemoryBackend") from None
        self._c_get.inc()
        self._c_get_bytes.inc(len(raw))
        _FLIGHT.record("media.get", len(raw))
        return raw

    def delete(self, name: str) -> None:
        if self._blobs.pop(name, None) is not None:
            self._c_del.inc()

    def list(self, prefix: str = "") -> list[str]:
        return sorted(n for n in self._blobs if n.startswith(prefix))

    def snapshot(self) -> "MemoryBackend":
        """Point-in-time copy for crash images: blob bytes are immutable
        by convention, so sharing the byte objects is safe; only the name
        map is copied.  Bypasses the per-blob probes on purpose — a
        snapshot is one logical operation, not thousands of puts."""
        out = MemoryBackend()
        out._blobs = dict(self._blobs)
        return out


class DirectoryBackend(MediaBackend):
    """Blobs as files under ``root``.

    Durability discipline:
      * every blob is written to a temp file in the same directory,
        fsync'd, then ``os.replace``d onto its final path — publication is
        atomic at the filesystem level;
      * the manifest is the *only* source of ``list``/``get`` visibility:
        a blob file without a manifest entry (crash between the two
        steps) is garbage, not data.  It is an append-only op log
        (``+name`` / ``-name`` lines, fsync'd per append) so a put or
        delete costs O(1) manifest I/O regardless of how many blobs the
        backend holds — a full rewrite per mutation would make a
        steady-cadence archiver's prune quadratic over the archive's
        life, the same cost class the in-memory index fix eliminates.
        When tombstones outnumber live entries the log compacts through
        the usual temp-write + atomic-replace path.  A torn final line
        (crash mid-append) is ignored: the op it described never became
        visible, which is exactly the pre-crash state;
      * directory entries are fsync'd after each replace so the rename
        itself is durable (best-effort on platforms without O_DIRECTORY).
    """

    # compact when tombstones exceed live entries and this floor (avoids
    # rewriting a tiny manifest over and over)
    COMPACT_MIN_OPS = 64

    def __init__(self, root: Union[str, Path]) -> None:
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self._init_metrics("directory")
        self._names: set[str] = set()
        self._manifest_ops = 0          # lines in the on-disk op log
        self.manifest_bytes_written = 0  # appends + compactions, for the
        #                                  O(1)-manifest-I/O bench guard
        manifest = self.root / MANIFEST
        if manifest.exists():
            raw = manifest.read_bytes().decode("utf-8")
            lines = raw.split("\n")
            if not raw.endswith("\n") and lines:
                lines = lines[:-1]      # torn final append: op never landed
            for line in lines:
                if line.startswith("+"):
                    self._names.add(line[1:])
                elif line.startswith("-"):
                    self._names.discard(line[1:])
                elif line:              # pre-op-log format: bare names
                    self._names.add(line)
            self._manifest_ops = len(lines)

    # ------------------------------------------------------------ helpers
    def _path(self, name: str) -> Path:
        p = (self.root / name).resolve()
        if self.root.resolve() not in p.parents and p != self.root.resolve():
            raise ValueError(f"blob name {name!r} escapes the backend root")
        return p

    @staticmethod
    def _fsync_dir(path: Path) -> None:
        try:
            fd = os.open(path, os.O_RDONLY)
        except OSError:  # pragma: no cover — platform without dir-open
            return
        try:
            os.fsync(fd)
        finally:
            os.close(fd)

    def _write_atomic(self, path: Path, data: bytes) -> None:
        path.parent.mkdir(parents=True, exist_ok=True)
        fd, tmp = tempfile.mkstemp(dir=path.parent, prefix=".tmp-")
        try:
            with os.fdopen(fd, "wb") as f:
                f.write(data)
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, path)
        # reprolint: allow(loud-corruption) — unlink-the-temp cleanup that re-raises unconditionally; BaseException so KeyboardInterrupt cannot leak a torn temp file
        except BaseException:
            try:
                os.unlink(tmp)
            except OSError:
                pass
            raise
        self._fsync_dir(path.parent)

    def _append_manifest(self, op: str) -> None:
        line = op.encode("utf-8") + b"\n"
        with open(self.root / MANIFEST, "ab") as f:
            f.write(line)
            f.flush()
            os.fsync(f.fileno())
        self._manifest_ops += 1
        self.manifest_bytes_written += len(line)
        if self._manifest_ops > max(2 * len(self._names),
                                    self.COMPACT_MIN_OPS):
            self._compact_manifest()

    def _compact_manifest(self) -> None:
        text = "".join(f"+{n}\n" for n in sorted(self._names))
        self._write_atomic(self.root / MANIFEST, text.encode("utf-8"))
        self._manifest_ops = len(self._names)
        self.manifest_bytes_written += len(text)

    # ---------------------------------------------------------- interface
    def put(self, name: str, data: bytes) -> None:
        self._write_atomic(self._path(name), data)
        self._c_put.inc()
        self._c_put_bytes.inc(len(data))
        _FLIGHT.record("media.put", len(data))
        if name not in self._names:
            self._names.add(name)
            self._append_manifest(f"+{name}")

    def get(self, name: str) -> bytes:
        if name not in self._names:
            raise BackendMissingError(name, f"DirectoryBackend({self.root})")
        raw = self._path(name).read_bytes()
        self._c_get.inc()
        self._c_get_bytes.inc(len(raw))
        _FLIGHT.record("media.get", len(raw))
        return raw

    def get_head(self, name: str, n: int) -> bytes:
        if name not in self._names:
            raise BackendMissingError(name, f"DirectoryBackend({self.root})")
        with open(self._path(name), "rb") as f:
            return f.read(n)

    def delete(self, name: str) -> None:
        if name not in self._names:
            return
        self._c_del.inc()
        self._names.discard(name)
        self._append_manifest(f"-{name}")   # unlist first: a crash leaves
        try:                                # garbage, never a listed-but-
            self._path(name).unlink()       # missing blob
        except FileNotFoundError:  # pragma: no cover
            pass

    def list(self, prefix: str = "") -> list[str]:
        return sorted(n for n in self._names if n.startswith(prefix))


def open_backend(where: Union[str, Path, MediaBackend, None]
                 ) -> MediaBackend:
    """Coerce a backend argument: a ``MediaBackend`` passes through, a
    path opens a ``DirectoryBackend``, ``None`` makes a fresh
    ``MemoryBackend``."""
    if where is None:
        return MemoryBackend()
    if isinstance(where, MediaBackend):
        return where
    return DirectoryBackend(where)
