"""Cold start: rebuild a database (or a pre-seeded standby) in a fresh
process from a media backend alone.

This is the deployment the archive tier exists for — the dead primary.
Process A ran a workload, sealed segments, took snapshots, saved the
master pointer, and exited; nothing of it survives but bytes on a
backend.  ``cold_restore`` opens that backend (a directory path, in the
real case), rebuilds the ``LogArchive`` index from segment headers and
the ``SnapshotStore`` from snapshot blobs, and runs the ordinary
point-in-time restore: newest covering snapshot + committed-only logical
redo from its ``redo_lsn`` — no shared references, no pickled heap, no
physical context.  The result is a *writable* ``Database`` on whatever
geometry ``db_kwargs`` picks (restore is relayout, as everywhere else in
this system).

``cold_restore_replica`` is the standby form: a ``Replica`` (or
``ShardedApplier``) pre-seeded from the newest snapshot with its durable
``(applied, resume)`` watermark set, ready to subscribe at
``resume_lsn`` against a new primary.

``archive_log_view`` wraps the loaded archive in a read-only
``LogManager`` whose whole prefix is "truncated" into the archive — so
every existing log consumer (``committed_state_oracle``, analysis scans,
a ``LogShipper`` serving cold subscribers) runs unmodified against bytes.
"""
from __future__ import annotations

from pathlib import Path
from typing import Optional, Union

from ..archive.log_archive import LogArchive
from ..archive.snapshot import RestoreStats, SnapshotStore
from ..core.log import LogManager
from ..core.records import LSN
from ..core.tc import Database
from ..obs.trace import TRACER as _TRACER
from .backend import MediaBackend, open_backend

BackendLike = Union[str, Path, MediaBackend]


def load_media(where: BackendLike, *, cache_segments: int = 8, retry=None
               ) -> tuple[MediaBackend, LogArchive, SnapshotStore]:
    """Open a backend and rebuild the archive + snapshot store from it —
    the shared first step of every cold entry point.

    ``retry`` (a ``faults.RetryPolicy``) mediates every backend *read*
    this load and the archive it returns perform: a transient outage
    costs a bounded, deterministic backoff instead of a failed restore.
    Corruption never retries — the classification contract lives in
    ``RetryPolicy.call``."""
    backend = open_backend(where)
    archive = LogArchive.load(backend, cache_segments=cache_segments,
                              retry=retry)
    store = SnapshotStore.load(backend, archive=archive, retry=retry)
    return backend, archive, store


def cold_restore(where: BackendLike, target_lsn: Optional[LSN] = None,
                 *, cache_segments: int = 8, streaming: bool = True,
                 apply_window: int = 1024, progress: object = None,
                 retry=None,
                 **db_kwargs: object) -> tuple[Database, RestoreStats]:
    """Point-in-time restore in a fresh process: a writable ``Database``
    equal to the committed prefix <= ``target_lsn``, built from the
    backend at ``where`` (directory path or ``MediaBackend``) and nothing
    else.  ``target_lsn`` defaults to everything the archive sealed.

    The default is the streaming pipeline: segments decode through an LRU
    of ``cache_segments`` and committed ops flush through the batched
    apply engine every ``apply_window`` records, so peak memory is
    (window + in-flight straddlers + LRU), independent of archive length —
    an archive much larger than RAM restores without materializing it.
    ``streaming=False`` keeps the materializing reference path.

    A restore should survive a flaky backend but never a corrupt one:
    ``retry`` defaults to a fresh ``faults.RetryPolicy`` so transient
    ``BackendUnavailableError``s absorb into bounded backoff, while
    corruption (torn segment, torn snapshot) stays first-throw loud.
    Pass ``RetryPolicy(max_attempts=1)`` to effectively disable retries."""
    if retry is None:
        # call-time import: media.restore already sits above archive, and
        # faults sits above media — importing here keeps module-load DAG flat
        from ..faults.retry import RetryPolicy
        retry = RetryPolicy()
    with _TRACER.span("cold_restore", streaming=streaming) as sp:
        backend, archive, store = load_media(where,
                                             cache_segments=cache_segments,
                                             retry=retry)
        if target_lsn is None:
            target_lsn = archive.archived_upto
            if target_lsn == 0:
                raise ValueError(
                    f"nothing to restore: backend {where!r} holds no sealed "
                    "segments (was the archiver ever run?)")
        sp.set(target_lsn=target_lsn, segments=len(archive.segments))
        return store.restore(target_lsn, streaming=streaming,
                             apply_window=apply_window, progress=progress,
                             **db_kwargs)


def cold_restore_replica(where: BackendLike, replica_id: str, *,
                         target_lsn: Optional[LSN] = None,
                         replica_cls: Optional[type] = None,
                         **replica_kwargs: object) -> object:
    """Standby form of ``cold_restore``: a replica pre-seeded from the
    newest snapshot on the backend (<= ``target_lsn`` when given), its
    durable watermark at the snapshot window — subscribe it at
    ``resume_lsn`` and it catches up through ordinary shipping."""
    _backend, _archive, store = load_media(where)
    return store.restore_replica(replica_id, target_lsn=target_lsn,
                                 replica_cls=replica_cls, **replica_kwargs)


def archive_log_view(where: BackendLike) -> LogManager:
    """A read-only ``LogManager`` over a loaded archive: the live tail is
    empty, the base sits at the sealed frontier, and every read path
    splices down into the segments — ``scan``/``record``/``scan_stable``
    and with them the oracle and the shipper work against cold bytes.
    Appending or flushing through this view is a caller error (it holds
    no writable tail), but reads are the point."""
    backend, archive, _store = load_media(where)
    log = LogManager()
    log._base = archive.archived_upto
    log._stable_lsn = archive.archived_upto
    log.attach_archive(archive)
    log.master = LogManager.load_master(backend)
    # commit-relative consumers (Replica.lag, primary-fallback tokens)
    # measure against last_stable_commit_lsn; leaving it NULL would make
    # an arbitrarily stale replica read as fully caught up.  Walk the
    # sealed segments newest-first — the newest commit is almost always
    # in the last one, which then sits warm in the decode LRU.
    from ..core.records import CommitRec
    for i in range(len(archive.segments) - 1, -1, -1):
        newest = next((rec.lsn for rec in reversed(archive._records(i))
                       if isinstance(rec, CommitRec)), None)
        if newest is not None:
            log.last_commit_lsn = newest
            log.last_stable_commit_lsn = newest
            break
    return log
