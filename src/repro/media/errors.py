"""Errors of the durable media layer.

The contract mirrors ``TruncatedLogError``: a reader that cannot produce
the exact byte-faithful record stream must fail loudly.  A short or
corrupt segment silently yielding fewer records would make recovery,
restore or shipping *look* successful while losing committed work — the
one failure mode a recovery system must never have.

The hierarchy encodes the retry classification the whole stack obeys:

  transient  ``TransientMediaError`` / ``BackendUnavailableError`` — the
             *backend* failed (timeout, throttle, connection loss), the
             bytes themselves are presumed intact.  The only errors a
             ``RetryPolicy`` may ever swallow-and-retry.
  corrupt    ``CorruptSegmentError`` / ``UnknownFormatError`` — the bytes
             came back and are wrong.  Retrying re-reads the same wrong
             bytes; these must always propagate (reprolint
             ``loud-corruption`` / ``retry-discipline``).
  missing    ``BackendMissingError`` — a definite answer: the blob is not
             there.  Neither transient nor corrupt; ``exists`` maps it to
             False, everything else propagates it.
"""
from __future__ import annotations


class MediaError(RuntimeError):
    """Base class for durable-media failures."""


class TransientMediaError(MediaError):
    """The backend, not the bytes, failed — the one branch of the
    hierarchy a bounded retry may legitimately absorb."""


class BackendUnavailableError(TransientMediaError):
    """The backend could not serve the operation right now: timeout,
    throttle, dropped connection, injected outage (``FaultyBackend``).
    The blob's bytes are presumed intact; retrying with backoff is the
    correct response, and ``faults.RetryPolicy`` is the mediator every
    catcher must go through (reprolint ``retry-discipline``)."""


class CorruptSegmentError(MediaError):
    """An encoded blob failed validation: truncated frame, CRC mismatch,
    bad magic, or a record count that does not match the header.  The blob
    must be treated as unreadable — never as a shorter-but-valid stream."""


class UnknownFormatError(CorruptSegmentError):
    """The blob's format-version byte is newer than this codec understands.
    Old segments stay readable forever (the version gates decoding); new
    ones written by a future codec refuse loudly instead of misparsing."""


class BackendMissingError(MediaError, KeyError):
    """A named blob is absent from the backend (deleted, never sealed, or
    the wrong directory was opened)."""

    def __init__(self, name: str, backend: str) -> None:
        self.name = name
        super().__init__(f"blob {name!r} not found in {backend}")

    def __str__(self) -> str:  # KeyError quotes its arg; keep the message
        return self.args[0]
