"""Errors of the durable media layer.

The contract mirrors ``TruncatedLogError``: a reader that cannot produce
the exact byte-faithful record stream must fail loudly.  A short or
corrupt segment silently yielding fewer records would make recovery,
restore or shipping *look* successful while losing committed work — the
one failure mode a recovery system must never have.
"""
from __future__ import annotations


class MediaError(RuntimeError):
    """Base class for durable-media failures."""


class CorruptSegmentError(MediaError):
    """An encoded blob failed validation: truncated frame, CRC mismatch,
    bad magic, or a record count that does not match the header.  The blob
    must be treated as unreadable — never as a shorter-but-valid stream."""


class UnknownFormatError(CorruptSegmentError):
    """The blob's format-version byte is newer than this codec understands.
    Old segments stay readable forever (the version gates decoding); new
    ones written by a future codec refuse loudly instead of misparsing."""


class BackendMissingError(MediaError, KeyError):
    """A named blob is absent from the backend (deleted, never sealed, or
    the wrong directory was opened)."""

    def __init__(self, name: str, backend: str) -> None:
        self.name = name
        super().__init__(f"blob {name!r} not found in {backend}")

    def __str__(self) -> str:  # KeyError quotes its arg; keep the message
        return self.args[0]
