"""Logical snapshots, log archival & point-in-time restore.

The backup/restore face of logical recovery: because the log carries no
PIDs, a fuzzy snapshot of committed rows plus committed-only logical redo
rebuilds state onto any geometry — which is what lets standbys join, lag,
and recover without replaying history from LSN 1, and lets the in-memory
log stay bounded while sealed segments hold the cold prefix.

Public surface:
  LogArchive / Segment        sealed-segment cold tier: encoded blobs on a
                              repro.media backend (memory or directory),
                              decoded lazily behind an LRU; LogManager
                              splices it with the live tail on every read
                              path; LogArchive.load rebuilds the index in
                              a fresh process from the backend alone
  SnapshotStore / Snapshot    fuzzy committed-only snapshots of a live
                              Database, persisted through the same
                              backend; point-in-time restore(target_lsn)
                              and restore_replica (pre-seeded standby);
                              SnapshotStore.load for cold starts
  RestoreStats                what a restore replayed
  Archiver                    retention policy: seal (+ save the master
                              pointer), truncate below min(snapshot
                              horizon, slowest subscriber), prune below
                              what retained snapshots need
  SnapshotRequired            raised when a subscriber falls below the
                              retention horizon; the ReplicaSet auto-
                              re-seeds when a SnapshotStore is attached

The fresh-process entry points live in ``repro.media``: ``cold_restore``,
``cold_restore_replica``, ``archive_log_view``.
"""
from .errors import SnapshotRequired
from .log_archive import LogArchive, Segment
from .manager import Archiver
from .snapshot import (DEFAULT_EXCLUDE_TABLES, RestoreStats, Snapshot,
                       SnapshotStore)

__all__ = [
    "LogArchive", "Segment", "Archiver", "Snapshot", "SnapshotStore",
    "RestoreStats", "SnapshotRequired", "DEFAULT_EXCLUDE_TABLES",
]
