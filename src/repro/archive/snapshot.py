"""Fuzzy logical snapshots + point-in-time restore.

The logical log (no PIDs, pure ``(table, key, before, after)``) makes
backups geometry-independent: a snapshot is just committed rows, and a
snapshot plus committed-only logical redo rebuilds state onto *any* page
layout — a different page size, a different B-tree shape, a sharded
standby.  This module is the missing re-seed path of the replication
subsystem: nodes can join, lag, and recover without replaying history from
LSN 1.

Snapshot protocol (``SnapshotStore.take``):

  1. ``tc.snapshot_begin()`` logs and forces a ``SnapshotRec``; its LSN is
     ``begin_lsn``.  The record also captures ``oldest_active_lsn`` — the
     first-write LSN of the oldest in-flight transaction — from which the
     snapshot's ``redo_lsn`` derives.
  2. The scan walks the tree in key order, one chunk at a time, patching
     each chunk to *committed* values via the active transactions'
     first-write before-images (``tc.committed_chunk``).  Writers are never
     blocked: between chunks the workload keeps committing (``on_chunk`` in
     tests/benchmarks drives exactly that), so different chunks observe
     different commit points — the snapshot is *fuzzy*.
  3. ``end_lsn`` is the stable LSN when the scan finishes; ``(begin_lsn,
     end_lsn]`` is the fuzz window.

What makes fuzziness harmless: every chunk is committed-only (in-flight
work is patched out), and any transaction committing *inside* the window
was observed by some chunks and missed by others — so restore replays ALL
transactions with ``begin_lsn < commit <= target`` over the snapshot, and
absolute after-images make re-applying the observed ones idempotent.
Transactions with ``commit <= begin_lsn`` committed before the scan
started and are fully present in every chunk; transactions in flight at
begin may have records *below* ``begin_lsn``, which is why redo starts at
``redo_lsn = min(oldest_active, begin+1)`` rather than at the window edge.

Restore (``SnapshotStore.restore``): newest snapshot with
``end_lsn <= target``, committed-only redo from its ``redo_lsn`` up to
exactly ``target_lsn``, oracle-equal to the committed prefix <= target.
With no eligible snapshot it degrades to a full replay from LSN 1 — the
baseline the re-seed benchmark measures against.

Durability: with a ``MediaBackend`` attached, every snapshot is encoded
(``media.codec`` — CRC-framed rows + metadata) and written through it as
``snap/<id>``; ``SnapshotStore.load`` rebuilds the store in a fresh
process from those blobs alone, which together with ``LogArchive.load``
is the whole cold-restore story (``media.cold_restore``).
"""
from __future__ import annotations

import time
from dataclasses import dataclass, field
from typing import Callable, Optional, Union

from ..core.dc import split_key
from ..core.log import LogManager
from ..core.records import (LSN, NULL_LSN, AbortRec, CommitRec, SnapshotRec,
                            UpdateRec)
from ..core.tc import CrashImage, Database
from ..media.backend import MediaBackend
from ..media.codec import decode_snapshot, encode_snapshot
from ..obs import metrics as obs_metrics
from ..obs.flightrec import FLIGHT as _FLIGHT
from ..obs.trace import TRACER as _TRACER
from .log_archive import LogArchive

_H_RESTORE_WINDOW = obs_metrics.histogram("restore.window_ops")
_C_RESTORE_RUNS = obs_metrics.counter("restore.runs")

SNAP_PREFIX = "snap/"


def _snap_name(snapshot_id: int) -> str:
    return f"{SNAP_PREFIX}{snapshot_id:08d}"

# the replication watermark row is position metadata in its owner's LSN
# space — never part of a snapshot (a reseeded consumer writes its own)
DEFAULT_EXCLUDE_TABLES = ("__repl",)


@dataclass(frozen=True)
class Snapshot:
    """One fuzzy logical snapshot: committed rows + its LSN window."""
    snapshot_id: int
    begin_lsn: LSN            # SnapshotRec LSN: commits <= this fully present
    end_lsn: LSN              # stable LSN at scan end (fuzz window closes)
    redo_lsn: LSN             # committed redo replays from here
    rows: tuple               # (composite key, value), committed-only, fuzzy
    chunks: int = 0           # scan chunks (fuzz opportunities)

    @property
    def n_rows(self) -> int:
        return len(self.rows)


@dataclass
class RestoreStats:
    target_lsn: LSN = NULL_LSN
    snapshot_id: Optional[int] = None
    snapshot_rows: int = 0
    redo_from: LSN = NULL_LSN
    replayed_txns: int = 0
    replayed_ops: int = 0
    wall_ms: float = 0.0
    streaming: bool = False
    # peak redo records resident at once (in-flight txn buffers + the
    # pending apply window) — the memory the streaming path bounds; the
    # materializing path reports its full updates-dict residency here
    peak_buffered_ops: int = 0
    # peak decoded segments in the archive LRU during the redo scan
    # (0 when the scan did not read through an archive)
    peak_cached_segments: int = 0

    def publish(self, registry=None) -> None:
        """Mirror every numeric field into the process-wide registry as
        ``restore.*`` gauges — last run wins."""
        obs_metrics.publish_dataclass(self, "restore", registry)

    @classmethod
    def from_registry(cls, registry=None) -> "RestoreStats":
        """The registry-backed view of the most recent published run."""
        return obs_metrics.load_dataclass(cls, "restore", registry)


def _log_of(source) -> LogManager:
    """Accept a Database, CrashImage, or bare LogManager as the redo
    source (mirrors ``LogShipper``)."""
    return source if isinstance(source, LogManager) else source.log


class SnapshotStore:
    """Holds logical snapshots of one primary (one LSN space) and restores
    databases / standbys from them.  ``archive`` is optional and only
    advisory here — the redo scan reads through the log's own splice — but
    wiring it lets ``restore`` run from a bare archive with no live log."""

    def __init__(self, archive: Optional[LogArchive] = None,
                 exclude_tables: tuple = DEFAULT_EXCLUDE_TABLES,
                 backend: Optional[MediaBackend] = None):
        self.archive = archive
        self.exclude_tables = set(exclude_tables)
        self.backend = backend
        self.snapshots: list[Snapshot] = []
        self._next_id = 1

    def attach_backend(self, backend: MediaBackend) -> int:
        """Point this store at a backend and backfill every snapshot
        taken before the attachment — otherwise a snapshot that exists
        for in-process restore would be silently absent from cold
        restore, and a cold target below the next snapshot's window
        would degrade to full replay (or die on pruned history).
        Returns how many snapshots were backfilled."""
        self.backend = backend
        written = 0
        for snap in self.snapshots:
            name = _snap_name(snap.snapshot_id)
            if not backend.exists(name):
                # reprolint: allow(wal-discipline) — backfills snapshots that were already frontier-clamped when taken; attach re-publishes, it does not create new state
                backend.put(name, encode_snapshot(snap))
                written += 1
        return written

    @classmethod
    def load(cls, backend: MediaBackend,
             archive: Optional[LogArchive] = None,
             exclude_tables: tuple = DEFAULT_EXCLUDE_TABLES,
             retry=None) -> "SnapshotStore":
        """Rebuild a store in a fresh process from a backend's ``snap/``
        blobs alone (metadata + rows decode through the codec; CRC and
        row-count validation make a torn snapshot loud, never short).

        ``retry`` (a ``faults.RetryPolicy``) mediates the per-blob gets:
        a transient backend outage costs a bounded backoff instead of a
        failed restore; corruption still propagates on the first throw —
        re-reading the same torn snapshot cannot help."""
        store = cls(archive=archive, exclude_tables=exclude_tables,
                    backend=backend)
        get = backend.get if retry is None else \
            (lambda name: retry.call(backend.get, name))
        names = backend.list(SNAP_PREFIX) if retry is None else \
            retry.call(backend.list, SNAP_PREFIX)
        snaps = [decode_snapshot(get(name)) for name in names]
        snaps.sort(key=lambda s: (s.begin_lsn, s.snapshot_id))
        store.snapshots = snaps
        store._next_id = max((s.snapshot_id for s in snaps), default=0) + 1
        return store

    # ------------------------------------------------------------------ take
    def take(self, db: Database, *, chunk_keys: int = 256,
             on_chunk: Optional[Callable[[], None]] = None) -> Snapshot:
        """Fuzzy snapshot of a live database (see module docstring).
        ``on_chunk`` runs between scan chunks — the hook concurrent writers
        ride in this single-threaded harness."""
        rec = db.tc.snapshot_begin(self._next_id)
        begin = rec.lsn
        redo = begin + 1 if rec.oldest_active_lsn == NULL_LSN \
            else min(rec.oldest_active_lsn, begin + 1)
        rows: list = []
        cursor, more, chunks = None, True, 0
        while more:
            items, cursor, more = db.tc.committed_chunk(cursor, chunk_keys)
            rows.extend((k, v) for k, v in items
                        if split_key(k)[0] not in self.exclude_tables)
            chunks += 1
            if more and on_chunk is not None:
                on_chunk()
        snap = Snapshot(snapshot_id=rec.snapshot_id, begin_lsn=begin,
                        end_lsn=db.log.stable_lsn, redo_lsn=redo,
                        rows=tuple(rows), chunks=chunks)
        if self.backend is not None:
            self.backend.put(_snap_name(snap.snapshot_id),
                             encode_snapshot(snap))
        self.snapshots.append(snap)
        self._next_id += 1
        return snap

    # ------------------------------------------------------------- retention
    def latest(self) -> Optional[Snapshot]:
        return self.snapshots[-1] if self.snapshots else None

    def latest_for(self, target_lsn: LSN) -> Optional[Snapshot]:
        """Newest snapshot usable for ``target_lsn``: its fuzz window must
        have closed at or before the target (chunks may hold state as new
        as ``end_lsn``, which absolute-image redo can extend but never
        rewind)."""
        for snap in reversed(self.snapshots):
            if snap.end_lsn <= target_lsn:
                return snap
        return None

    def horizon(self) -> Optional[LSN]:
        """Snapshot horizon: the newest snapshot's ``redo_lsn``.  Live-log
        records below it are cold — any restore from the current snapshot,
        and any re-seed, starts at or above it — so the in-memory tail may
        be truncated up to ``horizon - 1`` (subscribers permitting)."""
        snap = self.latest()
        return snap.redo_lsn if snap else None

    def min_redo_lsn(self) -> Optional[LSN]:
        """Oldest redo point any *retained* snapshot still needs; pruning
        archive segments at or above this would brick those snapshots."""
        return min((s.redo_lsn for s in self.snapshots), default=None)

    def prune_snapshots(self, keep_last: int = 1) -> int:
        """Retire old snapshots (they pin the archive via min_redo_lsn);
        returns how many were dropped."""
        keep_last = max(keep_last, 0)
        dropped = len(self.snapshots) - keep_last
        if dropped > 0:
            retired = self.snapshots[:-keep_last] if keep_last \
                else self.snapshots
            if self.backend is not None:
                for snap in retired:
                    self.backend.delete(_snap_name(snap.snapshot_id))
            self.snapshots = self.snapshots[-keep_last:] if keep_last else []
            return dropped
        return 0

    # --------------------------------------------------------------- restore
    def restore(self, target_lsn: LSN,
                source: Union[Database, CrashImage, LogManager, None] = None,
                base_rows=None, *, streaming: bool = True,
                apply_window: int = 1024, progress=None,
                **db_kwargs) -> tuple[Database, RestoreStats]:
        """Point-in-time restore: a writable ``Database`` whose state is
        exactly the committed prefix <= ``target_lsn``.

        Loads the newest snapshot whose window closed at or before the
        target, then replays every transaction with ``begin_lsn < commit
        <= target_lsn`` through a fresh TC.  ``source`` is the log to
        replay from (``Database`` / ``CrashImage`` / ``LogManager``);
        omitted, the attached archive serves alone, which is the
        dead-primary story: sealed segments + a snapshot are enough.
        ``db_kwargs`` pick the new geometry (page_size, ...) — restore is
        relayout.

        ``streaming=True`` (default) runs the heal-replay as a bounded-
        memory pipeline: one pass over the redo scan, buffering only
        in-flight transactions (dropped at their abort, or at a commit at
        or below the snapshot begin) and batching committed ops into
        ``apply_window``-sized runs through the leaf-resident batched
        engine (``tc.apply_shipped_batch``).  Peak redo residency is the
        apply window plus the in-flight straddlers — independent of
        history length — and archive reads stay behind the decoded-segment
        LRU, so an archive much larger than RAM restores in bounded
        memory.  ``streaming=False`` keeps the materializing shape (full
        updates dict, one local transaction per source transaction) as
        the oracle/benchmark reference.

        ``base_rows``: composite-key rows present *before* LSN 1 — the
        initial ``bulk_build`` load, which is unlogged by design.  Only the
        no-eligible-snapshot full-replay path needs it (a snapshot taken at
        load time is the cleaner equivalent and makes it moot)."""
        t0 = time.perf_counter()
        archive = None
        if source is not None:
            log = _log_of(source)
            if target_lsn > log.stable_lsn:
                raise ValueError(
                    f"cannot restore to LSN {target_lsn}: only "
                    f"{log.stable_lsn} is stable (the unforced tail is not "
                    "restorable — it can still be disowned)")
            scan = log.scan
            archive = log.archive
        elif self.archive is not None:
            if target_lsn > self.archive.archived_upto:
                raise ValueError(
                    f"cannot restore to LSN {target_lsn} from the archive "
                    f"alone: sealed only through "
                    f"{self.archive.archived_upto} (pass the live log or "
                    "crash image as source)")
            scan = self.archive.scan
            archive = self.archive
        else:
            raise ValueError("restore needs a log source: pass a Database/"
                             "CrashImage/LogManager, or attach a LogArchive")

        snap = self.latest_for(target_lsn)
        begin = snap.begin_lsn if snap else 0
        redo_from = snap.redo_lsn if snap else 1
        stats = RestoreStats(target_lsn=target_lsn,
                             snapshot_id=snap.snapshot_id if snap else None,
                             snapshot_rows=snap.n_rows if snap else 0,
                             redo_from=redo_from, streaming=streaming)
        if archive is not None:
            archive.reset_cache_peak()

        db = Database(**db_kwargs)
        with _TRACER.span("restore.seed",
                          snapshot=snap.snapshot_id if snap else None) as sp:
            seed = list(snap.rows) if snap else \
                sorted(dict(base_rows or {}).items())
            db.dc.bulk_build(seed)
            db.tc.checkpoint()
            sp.set(rows=len(seed))

        with _TRACER.span("restore.heal", streaming=streaming,
                          redo_from=redo_from,
                          target_lsn=target_lsn) as hp:
            if progress is not None:
                # the heal span in LSN units, known before the first read
                progress.begin(max(1, target_lsn - redo_from + 1))
            if streaming:
                self._heal_streaming(db, scan, redo_from, target_lsn, begin,
                                     apply_window, stats, progress=progress)
            else:
                self._heal_materializing(db, scan, redo_from, target_lsn,
                                         begin, stats)
            hp.set(replayed_txns=stats.replayed_txns,
                   replayed_ops=stats.replayed_ops)
        if archive is not None:
            stats.peak_cached_segments = archive.peak_cached_segments
        if progress is not None:
            progress.finish()
        stats.wall_ms = (time.perf_counter() - t0) * 1e3
        stats.publish()
        _C_RESTORE_RUNS.inc()
        return db, stats

    @staticmethod
    def _heal_streaming(db: Database, scan, redo_from: LSN, target_lsn: LSN,
                        begin: LSN, apply_window: int,
                        stats: RestoreStats, progress=None) -> None:
        """One pass, bounded memory: buffer in-flight transactions only,
        release each at its commit into a pending window that flushes
        through the batched apply engine as it fills.  Equivalent to the
        materializing path: the same transactions replay (commit in
        ``(begin, target]``), per-key op order is preserved by the
        engine's (key, lsn) sort, and ops are absolute after-images, so
        fusing source-transaction boundaries into window-sized local
        transactions cannot change the final committed state."""
        bufs: dict[int, list[UpdateRec]] = {}
        pending: list[UpdateRec] = []
        buffered = 0                       # ops across bufs (running count)
        pos = redo_from                    # newest LSN consumed by the scan
        replayed = 0

        def flush_pending() -> None:
            nonlocal replayed
            if not pending:
                return
            _H_RESTORE_WINDOW.observe(len(pending))
            _FLIGHT.record("restore.window", len(pending))
            if _TRACER.enabled:
                _TRACER.event("restore.window", ops=len(pending))
            local = db.tc.begin()
            # reprolint: allow(sorted-stream) — heal-replay windows come off a forward archive scan in LSN order
            db.tc.apply_shipped_batch(local, pending)
            db.tc.commit(local)
            replayed += len(pending)
            pending.clear()
            if progress is not None:
                progress.update(pos - redo_from + 1, records=replayed)

        for rec in scan(redo_from, target_lsn):
            pos = rec.lsn
            if isinstance(rec, UpdateRec):
                bufs.setdefault(rec.txn, []).append(rec)
                buffered += 1
                if buffered + len(pending) > stats.peak_buffered_ops:
                    stats.peak_buffered_ops = buffered + len(pending)
            elif isinstance(rec, AbortRec):
                buffered -= len(bufs.pop(rec.txn, ()))
            elif isinstance(rec, CommitRec):
                ops = bufs.pop(rec.txn, None)
                if ops is not None:
                    buffered -= len(ops)
                if rec.lsn <= begin:
                    continue               # fully inside the snapshot
                stats.replayed_txns += 1
                if ops:
                    stats.replayed_ops += len(ops)
                    pending.extend(ops)
                    if len(pending) >= apply_window:
                        flush_pending()
        flush_pending()
        # leftover bufs are losers / post-target txns: dropped, as in the
        # materializing path (their commits never entered the range)

    @staticmethod
    def _heal_materializing(db: Database, scan, redo_from: LSN,
                            target_lsn: LSN, begin: LSN,
                            stats: RestoreStats) -> None:
        """The pre-pipeline shape, kept as the reference the streaming
        path is benchmarked and property-tested against: materialize every
        update in the redo range, then replay one local transaction per
        source transaction in commit-LSN order."""
        updates: dict[int, list[UpdateRec]] = {}
        commits: list[tuple[LSN, int]] = []       # LSN order by construction
        n_updates = 0
        for rec in scan(redo_from, target_lsn):
            if isinstance(rec, UpdateRec):
                updates.setdefault(rec.txn, []).append(rec)
                n_updates += 1
            elif isinstance(rec, CommitRec) and rec.lsn > begin:
                commits.append((rec.lsn, rec.txn))
        stats.peak_buffered_ops = n_updates
        for _lsn, txn in commits:
            ops = updates.get(txn, ())
            local = db.tc.begin()
            for rec in ops:
                db.tc.apply_shipped(local, rec)
            db.tc.commit(local)
            stats.replayed_txns += 1
            stats.replayed_ops += len(ops)

    def restore_replica(self, replica_id: str, *,
                        target_lsn: Optional[LSN] = None,
                        replica_cls=None, **replica_kwargs):
        """The standby form of restore: a ``Replica`` (or ``replica_cls``,
        e.g. ``ShardedApplier``) pre-seeded from the newest snapshot (<=
        ``target_lsn`` when given), its durable ``(applied, resume)``
        watermark set to the snapshot window.  Subscribing it at
        ``resume_lsn`` replays the fuzz window and everything after through
        the ordinary shipping path — catch-up, not history-from-LSN-1."""
        # local import: replication builds on archive's errors, so the
        # class dependency must point this way only at call time
        from ..replication.replica import Replica
        snap = self.latest() if target_lsn is None else \
            self.latest_for(target_lsn)
        if snap is None:
            raise ValueError(
                "no usable snapshot to seed from"
                + (f" at or below LSN {target_lsn}" if target_lsn else "")
                + " — take one first (SnapshotStore.take)")
        replica = (replica_cls or Replica)(replica_id, **replica_kwargs)
        replica.reseed_from(snap)
        return replica
