"""Sealed-segment archive of the stable logical log — encoded bytes on a
``MediaBackend``, not references in a heap.

``LogManager`` keeps every record in memory, which is exactly right for
the paper's recovery study and exactly wrong for a long-lived primary:
the log grows without bound while only a suffix is ever hot (shipping to
live subscribers, redo above the last snapshot).  ``LogArchive`` is the
cold tier: the stable prefix is *encoded* (``media.codec``, versioned +
CRC-framed) into immutable, LSN-contiguous segment blobs on a backend —
a dict in memory, files on disk — after which ``LogManager.truncate``
may drop it from memory.  Every log read path splices archive segments
with the live tail (one dense LSN space), decoding lazily through a
small LRU of hot segments, so recovery, analysis and shipping never know
where (or in what representation) a record lives.

Because segments are bytes on a backend, the archive is exactly what a
dead primary leaves behind: ``LogArchive.load`` rebuilds the index in a
fresh process from the backend listing alone (see ``media.cold_restore``).

Only the *stable* prefix can be sealed — an unforced record can still be
disowned by a crash, and an archive holding disowned work would resurrect
it at restore time.  Pruning deletes whole segment blobs from the cold
end and is the single place in the system where log history is genuinely
lost — everything below ``retained_from`` is gone, which is why pruning
must stay below the snapshot horizon (see ``Archiver``).
"""
from __future__ import annotations

import bisect
from collections import OrderedDict
from dataclasses import dataclass
from typing import TYPE_CHECKING, Iterator, Optional

from ..core.log import LogManager, TruncatedLogError
from ..core.records import LSN, LogRec
from ..media.backend import MediaBackend, MemoryBackend
from ..media.codec import (FEAT_ZLIB, decode_archive_meta, decode_segment,
                           decode_segment_features, decode_segment_header,
                           encode_archive_meta, encode_segment)
from ..media.errors import CorruptSegmentError
from ..obs import metrics as _metrics
from ..obs.flightrec import FLIGHT as _FLIGHT
from ..obs.flightrec import auto_dump as _flight_dump

if TYPE_CHECKING:  # pragma: no cover — annotation-only, avoids a hard edge
    from ..faults.retry import RetryPolicy

# process-wide mirrors of the per-instance LRU tallies (instance attrs
# stay: tests and benches assert them on specific archives)
_C_CACHE_HITS = _metrics.counter("archive.cache_hits")
_C_SEG_DECODES = _metrics.counter("archive.segment_decodes")
_G_CACHED_SEGS = _metrics.gauge("archive.cached_segments")

SEG_PREFIX = "seg/"
META_NAME = "archive_meta"


def _seg_name(lo: LSN) -> str:
    # keyed by lo only: extending a short tail segment re-puts the same
    # name (atomic replace), so the backend never holds two generations
    return f"{SEG_PREFIX}{lo:012d}"


@dataclass(frozen=True)
class Segment:
    """Index entry for one sealed, immutable run of consecutive LSNs
    [lo, hi]; the records themselves are encoded bytes in the backend
    blob ``name``."""
    lo: LSN
    hi: LSN
    name: str

    def __len__(self) -> int:
        return self.hi - self.lo + 1


class LogArchive:
    def __init__(self, segment_records: int = 1024,
                 backend: Optional[MediaBackend] = None,
                 cache_segments: int = 8, compress: bool = False,
                 retry: Optional["RetryPolicy"] = None):
        self.segment_records = segment_records
        self.backend = backend if backend is not None else MemoryBackend()
        self.cache_segments = cache_segments
        # transient-read mediator: segment/meta *gets* go through it when
        # present.  Writes stay direct on purpose — seal() is idempotent
        # and advances its frontier per successful put, so the Archiver
        # retries whole cycles instead (keeping backend.put calls visible
        # to the wal-discipline lint).
        self.retry = retry
        # per-segment zlib compression (codec feature byte).  Applies to
        # blobs this archive writes: new segments, and a short tail
        # segment when seal() extends it (that re-encode adopts the
        # current setting).  Full sealed segments are immutable, and
        # mixed archives read fine because the flag travels per blob;
        # LogArchive.load adopts the newest segment's feature byte so the
        # setting survives a reopen.
        self.compress = compress
        # index/offset scheme (the LogManager._base idiom): pruning only
        # advances _head past dead entries — no per-prune list shuffling —
        # and the storage compacts amortized-O(1) once half of it is dead
        self._segs: list[Segment] = []
        self._los: list[LSN] = []        # _segs[i].lo, kept in lockstep
        self._head: int = 0              # _segs[:_head] are pruned
        self._archived_upto: LSN = 0     # newest sealed LSN (contiguous from lo)
        self._retained_from: LSN = 1     # oldest LSN still held (prune floor)
        self.pruned_records = 0
        # decoded-segment LRU: name -> tuple[LogRec]; hot splice reads
        # (recovery rescans, shipping catch-up) hit it instead of
        # re-decoding the blob on every record
        self._cache: OrderedDict[str, tuple] = OrderedDict()
        self.segment_decodes = 0
        self.cache_hits = 0
        # high-water mark of decoded segments resident at once — what the
        # streaming-restore memory bound is asserted against
        self.peak_cached_segments = 0

    # ----------------------------------------------------------- loading
    @classmethod
    def load(cls, backend: MediaBackend, *, segment_records: int = 1024,
             cache_segments: int = 8, compress: Optional[bool] = None,
             retry: Optional["RetryPolicy"] = None) -> "LogArchive":
        """Rebuild the archive index from a backend alone — the fresh-
        process path.  Reads only segment *headers*; records decode
        lazily on first touch.  Validates that the sealed runs are
        LSN-contiguous (a gap means blobs were lost behind the
        manifest's back, and serving around it would be a silent hole).

        ``compress=None`` (default) adopts the newest sealed segment's
        feature byte, so a compressed archive keeps compressing across
        restarts instead of silently resetting; pass an explicit bool to
        override."""
        arch = cls(segment_records=segment_records, backend=backend,
                   cache_segments=cache_segments, compress=bool(compress),
                   retry=retry)
        entries = []
        newest_feat = 0
        newest_lo = -1
        for name in arch._get_list(SEG_PREFIX):
            # 64 bytes cover magic + version + feature byte + the framed
            # (lo, hi, count) header; records decode lazily on first touch
            head = arch._get_head(name, 64)
            lo, hi, _count = decode_segment_header(head)
            entries.append(Segment(lo, hi, name))
            if compress is None and lo > newest_lo:
                newest_lo = lo
                newest_feat = decode_segment_features(head)
        if compress is None:
            arch.compress = bool(newest_feat & FEAT_ZLIB)
        entries.sort(key=lambda s: s.lo)
        for prev, nxt in zip(entries, entries[1:]):
            if nxt.lo != prev.hi + 1:
                raise CorruptSegmentError(
                    f"archive segments are not contiguous: [{prev.lo}, "
                    f"{prev.hi}] is followed by [{nxt.lo}, {nxt.hi}] — "
                    "a sealed blob is missing")
        arch._segs = entries
        arch._los = [s.lo for s in entries]
        if entries:
            arch._retained_from = entries[0].lo
            arch._archived_upto = entries[-1].hi
        # the meta blob carries what segments alone cannot: the frontier
        # when retention emptied the archive, and the prune floor.  The
        # segments win where they know more (a seal that crashed between
        # blob and meta publication still counts its sealed records).
        if arch._exists(META_NAME):
            retained, upto, pruned = decode_archive_meta(
                arch._get(META_NAME))
            arch._retained_from = max(arch._retained_from, retained)
            arch._archived_upto = max(arch._archived_upto, upto)
            arch.pruned_records = pruned
        return arch

    # --------------------------------------------------- retry-aware reads
    # backend *reads* go through the attached RetryPolicy when one is
    # present, so a transient outage mid-restore or mid-splice costs a
    # bounded backoff instead of a failed recovery.  Only the transient
    # branch is absorbed (RetryPolicy.call's contract); corruption and
    # definite absence propagate on the first throw.
    def _get(self, name: str) -> bytes:
        if self.retry is None:
            return self.backend.get(name)
        return self.retry.call(self.backend.get, name)

    def _get_head(self, name: str, n: int) -> bytes:
        if self.retry is None:
            return self.backend.get_head(name, n)
        return self.retry.call(self.backend.get_head, name, n)

    def _get_list(self, prefix: str) -> list:
        if self.retry is None:
            return self.backend.list(prefix)
        return self.retry.call(self.backend.list, prefix)

    def _exists(self, name: str) -> bool:
        if self.retry is None:
            return self.backend.exists(name)
        return self.retry.call(self.backend.exists, name)

    def _save_meta(self) -> None:
        # reprolint: allow(wal-discipline) — archive meta records what seal/prune already did; seal clamps its segment cut to stable_lsn before this runs, and prune only ever shrinks retention
        self.backend.put(META_NAME, encode_archive_meta(
            self._retained_from, self._archived_upto, self.pruned_records))

    # ------------------------------------------------------------ inspection
    @property
    def archived_upto(self) -> LSN:
        return self._archived_upto

    @property
    def retained_from(self) -> LSN:
        return self._retained_from

    @property
    def segments(self) -> list[Segment]:
        """Live (un-pruned) segment index entries, oldest first — a
        slice view; mutate the archive through seal/prune only."""
        return self._segs[self._head:]

    @property
    def archived_records(self) -> int:
        return sum(len(self._segs[i])
                   for i in range(self._head, len(self._segs)))

    def __len__(self) -> int:
        return len(self._segs) - self._head

    # ----------------------------------------------------------------- seal
    def seal(self, log: LogManager, upto: Optional[LSN] = None) -> int:
        """Encode the not-yet-archived stable prefix of ``log`` (through
        ``upto`` when given) into sealed segment blobs; returns records
        sealed.  Idempotent and incremental: the next call resumes where
        this one stopped.  A short tail segment is re-encoded with the
        new records appended (same blob name, atomic replace) up to the
        segment size before a new one is opened."""
        hi = log.stable_lsn if upto is None else min(upto, log.stable_lsn)
        lo = self._archived_upto + 1
        if hi < lo:
            return 0
        recs = list(log.scan(lo, hi))
        sealed = len(recs)
        _FLIGHT.record("arch.seal", lo, hi)
        live = len(self._segs) > self._head
        if live and len(self._segs[-1]) < self.segment_records:
            last = self._segs[-1]
            head = recs[: self.segment_records - len(last)]
            recs = recs[len(head):]
            if head:
                merged = list(self._records(len(self._segs) - 1)) + head
                grown = Segment(last.lo, last.hi + len(head), last.name)
                self.backend.put(grown.name,
                                 encode_segment(merged,
                                                compress=self.compress))
                self._segs[-1] = grown
                # frontier advances per successful put: a transient put
                # failure later in this seal leaves index and frontier in
                # lockstep, so a whole-cycle retry resumes instead of
                # re-sealing (and double-indexing) these records
                self._archived_upto = grown.hi
                self._cache[grown.name] = tuple(merged)
                self._cache.move_to_end(grown.name)
                self._shrink_cache()
        while recs:
            chunk, recs = (recs[: self.segment_records],
                           recs[self.segment_records:])
            seg = Segment(chunk[0].lsn, chunk[-1].lsn,
                          _seg_name(chunk[0].lsn))
            self.backend.put(seg.name,
                             encode_segment(chunk, compress=self.compress))
            self._segs.append(seg)
            self._los.append(seg.lo)
            self._archived_upto = seg.hi
        self._archived_upto = hi
        self._save_meta()
        return sealed

    # ----------------------------------------------------------------- read
    def _seg_index(self, lsn: LSN) -> int:
        """Index (into ``_segs``) of the segment containing ``lsn``;
        -1 when absent or pruned."""
        i = bisect.bisect_right(self._los, lsn, lo=self._head) - 1
        if i >= self._head and self._segs[i].hi >= lsn:
            return i
        return -1

    def _shrink_cache(self) -> None:
        # the peak samples BEFORE eviction: a regression in the eviction
        # discipline (or a bypass of it) must be able to push the peak
        # past the cap, otherwise the streaming-restore residency assert
        # holds by construction and guards nothing
        if len(self._cache) > self.peak_cached_segments:
            self.peak_cached_segments = len(self._cache)
        while len(self._cache) > max(self.cache_segments, 0):
            self._cache.popitem(last=False)
        _G_CACHED_SEGS.set(len(self._cache))

    def reset_cache_peak(self) -> None:
        self.peak_cached_segments = len(self._cache)

    def _records(self, i: int) -> tuple:
        """Decoded records of ``_segs[i]``, through the LRU."""
        seg = self._segs[i]
        hit = self._cache.get(seg.name)
        if hit is not None and len(hit) == len(seg):
            self._cache.move_to_end(seg.name)
            self.cache_hits += 1
            _C_CACHE_HITS.inc()
            return hit
        try:
            records = tuple(decode_segment(self._get(seg.name)))
        except CorruptSegmentError:
            # black-box dump hook: capture the flight ring, then re-raise
            _flight_dump("corrupt_segment")
            raise
        self.segment_decodes += 1
        _C_SEG_DECODES.inc()
        if records[0].lsn != seg.lo or records[-1].lsn != seg.hi:
            _flight_dump("corrupt_segment")
            raise CorruptSegmentError(
                f"segment blob {seg.name} covers [{records[0].lsn}, "
                f"{records[-1].lsn}] but the index expects [{seg.lo}, "
                f"{seg.hi}]")
        if self.cache_segments > 0:
            self._cache[seg.name] = records
            self._cache.move_to_end(seg.name)
            self._shrink_cache()
        return records

    def record(self, lsn: LSN) -> LogRec:
        i = self._seg_index(lsn)
        if i < 0:
            _flight_dump("truncated_log")
            raise TruncatedLogError(
                f"LSN {lsn} is not in the archive (retains "
                f"[{self._retained_from}, {self._archived_upto}])")
        return self._records(i)[lsn - self._segs[i].lo]

    def scan(self, from_lsn: LSN, to_lsn: LSN) -> Iterator[LogRec]:
        """Yield archived records with from_lsn <= lsn <= to_lsn (capped at
        the sealed frontier); raises if the range reaches below the prune
        floor — a reader missing records must fail loudly."""
        lo = max(from_lsn, 1)
        hi = min(to_lsn, self._archived_upto)
        if lo > hi:
            return
        i = self._seg_index(lo)
        if lo < self._retained_from or i < 0:
            _flight_dump("truncated_log")
            raise TruncatedLogError(
                f"archive scan from LSN {lo} reaches below the prune floor "
                f"{self._retained_from}")
        for j in range(i, len(self._segs)):
            seg = self._segs[j]
            if seg.lo > hi:
                return
            records = self._records(j)
            yield from records[max(0, lo - seg.lo): hi - seg.lo + 1]

    # ---------------------------------------------------------------- prune
    def prune(self, below_lsn: LSN) -> int:
        """Drop whole segments wholly below ``below_lsn`` (the deletion
        unit — one blob on the backend); returns records dropped.  This is
        the only real data loss in the system — callers bound
        ``below_lsn`` by the snapshot horizon and the slowest subscriber
        (``Archiver.prune``).

        Amortized O(1) per dropped segment beyond the blob delete itself:
        the cut point is found by bisection, ``_head`` advances past the
        dead entries, and the backing lists compact only when more than
        half is dead (the ``LogManager._base`` idiom) — the old
        ``pop(0)``-per-segment shuffle made long-archive pruning
        quadratic."""
        cut = bisect.bisect_right(self._los, below_lsn, lo=self._head)
        while cut > self._head and self._segs[cut - 1].hi >= below_lsn:
            cut -= 1
        dropped = 0
        for i in range(self._head, cut):
            seg = self._segs[i]
            dropped += len(seg)
            self.backend.delete(seg.name)
            self._cache.pop(seg.name, None)
        self._head = cut
        if self._head > len(self._segs) // 2:
            del self._segs[: self._head]
            del self._los[: self._head]
            self._head = 0
        floor = self._segs[self._head].lo if self._head < len(self._segs) \
            else min(below_lsn, self._archived_upto + 1)
        self._retained_from = max(self._retained_from, floor)
        self.pruned_records += dropped
        self._save_meta()
        _FLIGHT.record("arch.prune", below_lsn, dropped)
        return dropped
