"""Sealed-segment archive of the stable logical log.

``LogManager`` keeps every record in memory, which is exactly right for the
paper's recovery study and exactly wrong for a long-lived primary: the log
grows without bound while only a suffix is ever hot (shipping to live
subscribers, redo above the last snapshot).  ``LogArchive`` is the cold
tier: the stable prefix is copied into immutable, LSN-contiguous segments,
after which ``LogManager.truncate`` may drop it from memory.  Every log
read path splices archive segments with the live tail (one dense LSN
space), so recovery, analysis and shipping never know where a record lives.

Only the *stable* prefix can be sealed — an unforced record can still be
disowned by a crash, and an archive holding disowned work would resurrect
it at restore time.  Sealing copies references, never mutates; pruning
drops whole segments from the cold end (the unit a real deployment would
delete as a file), and is the single place in the system where log history
is genuinely lost — everything below ``retained_from`` is gone, which is
why pruning must stay below the snapshot horizon (see ``Archiver``).
"""
from __future__ import annotations

import bisect
from dataclasses import dataclass
from typing import Iterator, Optional

from ..core.log import LogManager, TruncatedLogError
from ..core.records import LSN, LogRec


@dataclass(frozen=True)
class Segment:
    """One sealed, immutable run of consecutive LSNs [lo, hi]."""
    lo: LSN
    hi: LSN
    records: tuple

    def __len__(self) -> int:
        return len(self.records)


class LogArchive:
    def __init__(self, segment_records: int = 1024):
        self.segment_records = segment_records
        self.segments: list[Segment] = []
        self._seg_los: list[LSN] = []    # segments[i].lo, kept in lockstep
        self._archived_upto: LSN = 0     # newest sealed LSN (contiguous from lo)
        self._retained_from: LSN = 1     # oldest LSN still held (prune floor)
        self.pruned_records = 0

    # ------------------------------------------------------------ inspection
    @property
    def archived_upto(self) -> LSN:
        return self._archived_upto

    @property
    def retained_from(self) -> LSN:
        return self._retained_from

    @property
    def archived_records(self) -> int:
        return sum(len(s) for s in self.segments)

    def __len__(self) -> int:
        return len(self.segments)

    # ----------------------------------------------------------------- seal
    def seal(self, log: LogManager, upto: Optional[LSN] = None) -> int:
        """Copy the not-yet-archived stable prefix of ``log`` (through
        ``upto`` when given) into sealed segments; returns records sealed.
        Idempotent and incremental: the next call resumes where this one
        stopped.  A short tail segment is extended in place up to the
        segment size before a new one is opened."""
        hi = log.stable_lsn if upto is None else min(upto, log.stable_lsn)
        lo = self._archived_upto + 1
        if hi < lo:
            return 0
        recs = list(log.scan(lo, hi))
        sealed = len(recs)
        if self.segments and len(self.segments[-1]) < self.segment_records:
            last = self.segments[-1]
            head = recs[: self.segment_records - len(last)]
            recs = recs[len(head):]
            if head:
                self.segments[-1] = Segment(last.lo, last.hi + len(head),
                                            last.records + tuple(head))
        while recs:
            chunk, recs = (recs[: self.segment_records],
                           recs[self.segment_records:])
            self.segments.append(
                Segment(chunk[0].lsn, chunk[-1].lsn, tuple(chunk)))
            self._seg_los.append(chunk[0].lsn)
        self._archived_upto = hi
        return sealed

    # ----------------------------------------------------------------- read
    def _seg_index(self, lsn: LSN) -> int:
        """Index of the segment containing ``lsn``; -1 when absent."""
        i = bisect.bisect_right(self._seg_los, lsn) - 1
        if i >= 0 and self.segments[i].hi >= lsn:
            return i
        return -1

    def record(self, lsn: LSN) -> LogRec:
        i = self._seg_index(lsn)
        if i < 0:
            raise TruncatedLogError(
                f"LSN {lsn} is not in the archive (retains "
                f"[{self._retained_from}, {self._archived_upto}])")
        seg = self.segments[i]
        return seg.records[lsn - seg.lo]

    def scan(self, from_lsn: LSN, to_lsn: LSN) -> Iterator[LogRec]:
        """Yield archived records with from_lsn <= lsn <= to_lsn (capped at
        the sealed frontier); raises if the range reaches below the prune
        floor — a reader missing records must fail loudly."""
        lo = max(from_lsn, 1)
        hi = min(to_lsn, self._archived_upto)
        if lo > hi:
            return
        if lo < self._retained_from:
            raise TruncatedLogError(
                f"archive scan from LSN {lo} reaches below the prune floor "
                f"{self._retained_from}")
        i = self._seg_index(lo)
        for seg in self.segments[i:]:
            if seg.lo > hi:
                return
            yield from seg.records[max(0, lo - seg.lo): hi - seg.lo + 1]

    # ---------------------------------------------------------------- prune
    def prune(self, below_lsn: LSN) -> int:
        """Drop whole segments wholly below ``below_lsn`` (the deletion
        unit); returns records dropped.  This is the only real data loss in
        the system — callers bound ``below_lsn`` by the snapshot horizon
        and the slowest subscriber (``Archiver.prune``)."""
        dropped = 0
        while self.segments and self.segments[0].hi < below_lsn:
            dropped += len(self.segments.pop(0))
            self._seg_los.pop(0)
        floor = self.segments[0].lo if self.segments \
            else min(below_lsn, self._archived_upto + 1)
        self._retained_from = max(self._retained_from, floor)
        self.pruned_records += dropped
        return dropped
