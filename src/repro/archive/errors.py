"""Errors shared across the archive subsystem (kept import-light: the log
shipper raises ``SnapshotRequired`` without pulling in the snapshot/restore
machinery)."""
from __future__ import annotations

from ..core.records import LSN


class SnapshotRequired(RuntimeError):
    """A subscriber asked for log records below what the primary still
    retains (in memory or in un-pruned archive segments).  The log cannot
    serve it — silent empty batches would strand the subscriber forever —
    so the remedy is stated instead: re-seed from a logical snapshot and
    resume shipping from that snapshot's ``redo_lsn``.

    ``ReplicaSet`` with a ``SnapshotStore`` attached performs that re-seed
    automatically; without one, this error reaches the operator."""

    def __init__(self, replica_id: str, requested_lsn: LSN, retained_lsn: LSN):
        self.replica_id = replica_id
        self.requested_lsn = requested_lsn
        self.retained_lsn = retained_lsn
        super().__init__(
            f"subscriber {replica_id!r} needs the log from LSN "
            f"{requested_lsn}, but records below {retained_lsn} are no "
            "longer retained — re-seed the subscriber from a logical "
            "snapshot (SnapshotStore.restore_replica / Replica.reseed_from) "
            "and re-subscribe from its redo_lsn, or attach a SnapshotStore "
            "to the ReplicaSet to have this happen automatically")
