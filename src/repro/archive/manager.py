"""Retention coordinator: decides *where* the log may be cut.

Two different cuts with two different stakes:

  truncate — drop the in-memory prefix of ``LogManager``.  Information-
      preserving (the prefix is sealed in the archive first; every reader
      splices), so its watermark is pure policy: keep the *hot* ranges in
      memory.  Hot means (a) at or above the snapshot horizon — the redo
      range a restore from the current snapshot replays — and (b) at or
      above the slowest live subscriber's cursor — the range shipping will
      read next.  Hence ``min(snapshot horizon, slowest subscriber)``.

  prune — delete sealed segments.  Destroys history, so its watermark is a
      correctness bound: never at or above what a *retained* snapshot's
      restore needs (``min_redo_lsn``), never at or above a live cursor.

Run ``run_once`` at whatever cadence taste dictates (the archive benchmark
sweeps it); the live record count then stays bounded by the snapshot
cadence instead of growing with history.
"""
from __future__ import annotations

from typing import Optional

from ..core.records import LSN, NULL_LSN
from ..core.tc import Database
from ..faults.retry import RetryPolicy
from ..media.errors import BackendUnavailableError
from ..obs import metrics as _metrics
from ..obs.flightrec import FLIGHT as _FLIGHT
from .log_archive import LogArchive
from .snapshot import SnapshotStore

_G_CONSEC_FAILURES = _metrics.gauge("archiver.consecutive_failures")


class Archiver:
    """Binds one primary's log to its archive (attaching the splice) and
    applies the watermark policy above.  ``shippers`` is any iterable of
    objects with ``min_cursor()`` — in practice ``LogShipper``s — whose
    subscribers truncation must not push into the cold tier.

    Degraded mode: a backend outage must not take the primary down with
    it — archiving is a *background* duty.  ``run_once`` retries the
    whole cycle through ``retry`` (the cycle is idempotent: seal resumes
    at the archived frontier, the master pointer put is a pure
    overwrite, truncation never runs on a failed cycle), and when the
    outage outlasts the retry budget it reports ``ok=False``, bumps the
    ``archiver.consecutive_failures`` health gauge, and leaves the whole
    backlog in memory for the next cadence tick to seal."""

    def __init__(self, db: Database, archive: Optional[LogArchive] = None,
                 snapshots: Optional[SnapshotStore] = None, shippers=(),
                 retry: Optional[RetryPolicy] = None):
        self.db = db
        self.archive = archive if archive is not None else LogArchive()
        self.snapshots = snapshots
        self.shippers = list(shippers)
        self.retry = retry if retry is not None else RetryPolicy()
        self.consecutive_failures = 0
        db.log.attach_archive(self.archive)
        if snapshots is not None and snapshots.archive is None:
            snapshots.archive = self.archive
        # one backend for every durable artifact: segments, snapshot rows
        # and the master pointer land on the same store, which is what
        # makes the directory (or dict) self-contained for cold_restore;
        # attach_backend also backfills snapshots taken before this
        # Archiver existed, so in-process and cold restore see the same set
        if snapshots is not None and snapshots.backend is None:
            snapshots.attach_backend(self.archive.backend)

    def watermark(self) -> LSN:
        """Highest LSN through which the in-memory tail may be dropped:
        ``min(snapshot horizon, slowest subscriber) - 1``, capped at the
        stable point.  No snapshot yet means no truncation — there is
        nothing to re-seed laggards from, so the whole log is hot."""
        wm = self.db.log.stable_lsn
        if self.snapshots is not None:
            horizon = self.snapshots.horizon()
            wm = min(wm, (horizon or 1) - 1)
        for shipper in self.shippers:
            cursor = shipper.min_cursor()
            if cursor is not None:
                wm = min(wm, cursor - 1)
        return max(wm, 0)

    def _cycle(self) -> dict:
        """One seal + master-save + truncate pass.  Safe to re-run after
        a transient failure at any point: seal resumes where the last
        successful put left the frontier, and truncation (the only
        destructive step — it drops memory) runs strictly last, after
        everything it drops is durably sealed."""
        sealed = self.archive.seal(self.db.log)
        self.db.log.save_master(self.archive.backend)
        truncated = self.db.log.truncate(self.watermark())
        return {
            "sealed": sealed,
            "truncated": truncated,
            "archived_upto": self.archive.archived_upto,
            "in_memory_records": self.db.log.in_memory_records,
        }

    def run_once(self) -> dict:
        """Seal the stable prefix, persist the master pointer, then
        truncate memory to the watermark.  Returns counters for
        inspection/benchmarks, plus ``ok``: False means the backend
        outage outlasted the retry budget and this cycle was skipped —
        nothing was truncated, the backlog seals next cycle."""
        try:
            result = self.retry.call(self._cycle)
        except BackendUnavailableError:
            # retry budget exhausted: degrade, stay alive, stay loud in
            # telemetry.  No truncation happened (it runs last), so no
            # record is lost — memory just keeps the backlog.
            self.consecutive_failures += 1
            _G_CONSEC_FAILURES.set(self.consecutive_failures)
            _FLIGHT.record("arch.outage", self.consecutive_failures)
            return {
                "ok": False,
                "sealed": 0,
                "truncated": 0,
                "archived_upto": self.archive.archived_upto,
                "in_memory_records": self.db.log.in_memory_records,
                "consecutive_failures": self.consecutive_failures,
            }
        self.consecutive_failures = 0
        _G_CONSEC_FAILURES.set(0)
        result["ok"] = True
        return result

    def prune(self, keep_snapshots: int = 1) -> dict:
        """Retire old snapshots, then drop archive segments nothing needs:
        below ``min(min_redo_lsn of retained snapshots, slowest
        subscriber)``.  After this, a subscriber appearing below the floor
        gets ``SnapshotRequired`` — the horizon is real."""
        return self.retry.call(self._prune_cycle, keep_snapshots)

    def _prune_cycle(self, keep_snapshots: int) -> dict:
        # retry-safe for the same reason seal is: snapshot retirement and
        # segment deletion are idempotent (deleting an already-deleted
        # blob is a no-op), and the in-memory index only advances past
        # blobs whose delete returned
        dropped_snaps = 0
        bound: Optional[LSN] = None
        if self.snapshots is not None:
            dropped_snaps = self.snapshots.prune_snapshots(keep_snapshots)
            bound = self.snapshots.min_redo_lsn()
        if bound is None:
            return {"snapshots_dropped": dropped_snaps, "records_pruned": 0,
                    "retained_from": self.archive.retained_from}
        for shipper in self.shippers:
            cursor = shipper.min_cursor()
            if cursor is not None:
                bound = min(bound, cursor)
        # the live primary's own crash story is a redo scan from the
        # master checkpoint (bCkpt): pruning at or above it would strand
        # in-process recovery of this very process (the cold story has
        # its snapshot; the warm one needs those records).  The classic
        # reclamation discipline applies: advance the checkpoint first,
        # then destroy the history it no longer needs.
        bckpt = self.db.log.master.bckpt_lsn
        if bckpt == NULL_LSN or bckpt < bound:
            self.db.checkpoint()
        pruned = self.archive.prune(bound)
        return {"snapshots_dropped": dropped_snaps, "records_pruned": pruned,
                "retained_from": self.archive.retained_from}
