"""Retention coordinator: decides *where* the log may be cut.

Two different cuts with two different stakes:

  truncate — drop the in-memory prefix of ``LogManager``.  Information-
      preserving (the prefix is sealed in the archive first; every reader
      splices), so its watermark is pure policy: keep the *hot* ranges in
      memory.  Hot means (a) at or above the snapshot horizon — the redo
      range a restore from the current snapshot replays — and (b) at or
      above the slowest live subscriber's cursor — the range shipping will
      read next.  Hence ``min(snapshot horizon, slowest subscriber)``.

  prune — delete sealed segments.  Destroys history, so its watermark is a
      correctness bound: never at or above what a *retained* snapshot's
      restore needs (``min_redo_lsn``), never at or above a live cursor.

Run ``run_once`` at whatever cadence taste dictates (the archive benchmark
sweeps it); the live record count then stays bounded by the snapshot
cadence instead of growing with history.
"""
from __future__ import annotations

from typing import Optional

from ..core.records import LSN
from ..core.tc import Database
from .log_archive import LogArchive
from .snapshot import SnapshotStore


class Archiver:
    """Binds one primary's log to its archive (attaching the splice) and
    applies the watermark policy above.  ``shippers`` is any iterable of
    objects with ``min_cursor()`` — in practice ``LogShipper``s — whose
    subscribers truncation must not push into the cold tier."""

    def __init__(self, db: Database, archive: Optional[LogArchive] = None,
                 snapshots: Optional[SnapshotStore] = None, shippers=()):
        self.db = db
        self.archive = archive if archive is not None else LogArchive()
        self.snapshots = snapshots
        self.shippers = list(shippers)
        db.log.attach_archive(self.archive)
        if snapshots is not None and snapshots.archive is None:
            snapshots.archive = self.archive
        # one backend for every durable artifact: segments, snapshot rows
        # and the master pointer land on the same store, which is what
        # makes the directory (or dict) self-contained for cold_restore;
        # attach_backend also backfills snapshots taken before this
        # Archiver existed, so in-process and cold restore see the same set
        if snapshots is not None and snapshots.backend is None:
            snapshots.attach_backend(self.archive.backend)

    def watermark(self) -> LSN:
        """Highest LSN through which the in-memory tail may be dropped:
        ``min(snapshot horizon, slowest subscriber) - 1``, capped at the
        stable point.  No snapshot yet means no truncation — there is
        nothing to re-seed laggards from, so the whole log is hot."""
        wm = self.db.log.stable_lsn
        if self.snapshots is not None:
            horizon = self.snapshots.horizon()
            wm = min(wm, (horizon or 1) - 1)
        for shipper in self.shippers:
            cursor = shipper.min_cursor()
            if cursor is not None:
                wm = min(wm, cursor - 1)
        return max(wm, 0)

    def run_once(self) -> dict:
        """Seal the stable prefix, persist the master pointer, then
        truncate memory to the watermark.  Returns counters for
        inspection/benchmarks."""
        sealed = self.archive.seal(self.db.log)
        self.db.log.save_master(self.archive.backend)
        truncated = self.db.log.truncate(self.watermark())
        return {
            "sealed": sealed,
            "truncated": truncated,
            "archived_upto": self.archive.archived_upto,
            "in_memory_records": self.db.log.in_memory_records,
        }

    def prune(self, keep_snapshots: int = 1) -> dict:
        """Retire old snapshots, then drop archive segments nothing needs:
        below ``min(min_redo_lsn of retained snapshots, slowest
        subscriber)``.  After this, a subscriber appearing below the floor
        gets ``SnapshotRequired`` — the horizon is real."""
        dropped_snaps = 0
        bound: Optional[LSN] = None
        if self.snapshots is not None:
            dropped_snaps = self.snapshots.prune_snapshots(keep_snapshots)
            bound = self.snapshots.min_redo_lsn()
        if bound is None:
            return {"snapshots_dropped": dropped_snaps, "records_pruned": 0,
                    "retained_from": self.archive.retained_from}
        for shipper in self.shippers:
            cursor = shipper.min_cursor()
            if cursor is not None:
                bound = min(bound, cursor)
        pruned = self.archive.prune(bound)
        return {"snapshots_dropped": dropped_snaps, "records_pruned": pruned,
                "retained_from": self.archive.retained_from}
