"""Whisper-base backbone: encoder-decoder transformer (arXiv:2212.04356).

The conv audio frontend is a STUB per the assignment: ``input_specs`` provide
precomputed frame embeddings (B, enc_ctx, D) — i.e. the output the two conv
layers would produce.  Encoder: bidirectional attention + sinusoidal
positions.  Decoder: causal self-attention (RoPE stands in for Whisper's
learned positions — mechanical deviation noted in DESIGN.md, required for the
assignment's 32k decode shapes which exceed Whisper's native 448 positions)
plus cross-attention into the encoder output.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import (attention, attention_decode, dtype_of, init_attention,
                     init_mlp, init_norm, mlp, norm, shard_hint)

Array = jax.Array


def _sinusoid(length: int, d: int) -> Array:
    pos = jnp.arange(length, dtype=jnp.float32)[:, None]
    dim = jnp.arange(0, d, 2, dtype=jnp.float32)[None, :]
    angle = pos / jnp.power(10_000.0, dim / d)
    return jnp.concatenate([jnp.sin(angle), jnp.cos(angle)], axis=-1)


def init_cross_attention(cfg: ModelConfig, key, shape_prefix=()) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    ks = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(D)
    dt = dtype_of(cfg)
    return {
        "wq": (jax.random.normal(ks[0], (*shape_prefix, D, H * hd)) * s).astype(dt),
        "wk": (jax.random.normal(ks[1], (*shape_prefix, D, KV * hd)) * s).astype(dt),
        "wv": (jax.random.normal(ks[2], (*shape_prefix, D, KV * hd)) * s).astype(dt),
        "wo": (jax.random.normal(ks[3], (*shape_prefix, H * hd, D))
               / math.sqrt(H * hd)).astype(dt),
    }


def init_whisper(cfg: ModelConfig, rng) -> dict:
    D, V = cfg.d_model, cfg.vocab_size
    Le, Ld = cfg.n_enc_layers, cfg.n_layers
    ks = jax.random.split(rng, 8)
    dt = dtype_of(cfg)
    return {
        "embed": (jax.random.normal(ks[0], (V, D)) * 0.02).astype(dt),
        "enc_blocks": {
            "ln1": init_norm(cfg, (Le,)),
            "attn": init_attention(cfg, ks[1], (Le,)),
            "ln2": init_norm(cfg, (Le,)),
            "mlp": init_mlp(cfg, ks[2], shape_prefix=(Le,)),
        },
        "enc_norm": init_norm(cfg),
        "dec_blocks": {
            "ln1": init_norm(cfg, (Ld,)),
            "self_attn": init_attention(cfg, ks[3], (Ld,)),
            "ln_x": init_norm(cfg, (Ld,)),
            "cross_attn": init_cross_attention(cfg, ks[4], (Ld,)),
            "ln2": init_norm(cfg, (Ld,)),
            "mlp": init_mlp(cfg, ks[5], shape_prefix=(Ld,)),
        },
        "dec_norm": init_norm(cfg),
    }


def encode(params, frames: Array, cfg: ModelConfig) -> Array:
    """frames: (B, Ta, D) stub frontend output -> encoder states."""
    B, Ta, D = frames.shape
    x = shard_hint(frames + _sinusoid(Ta, D).astype(frames.dtype),
                   "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(Ta, dtype=jnp.int32), (B, Ta))

    def body(x, bp):
        h = norm(x, bp["ln1"], cfg.norm)
        x = x + attention(h, bp["attn"], cfg, positions, causal=False)
        h = norm(x, bp["ln2"], cfg.norm)
        return x + mlp(h, bp["mlp"], cfg), None

    x, _ = jax.lax.scan(body, x, params["enc_blocks"])
    return norm(x, params["enc_norm"], cfg.norm)


def _cross(x, enc, p, cfg):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"]).reshape(B, S, H, hd)
    k = jnp.einsum("btd,dh->bth", enc, p["wk"]).reshape(B, -1, KV, hd)
    v = jnp.einsum("btd,dh->bth", enc, p["wv"]).reshape(B, -1, KV, hd)
    from .layers import _sdpa
    o = _sdpa(q, k, v, causal=False)
    return jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1), p["wo"])


def decode_full(params, tokens: Array, enc: Array, cfg: ModelConfig,
                remat: bool = False) -> Array:
    """Teacher-forced decoder pass -> logits (B, S, V)."""
    B, S = tokens.shape
    x = shard_hint(jnp.take(params["embed"], tokens, axis=0),
                   "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    def body(x, bp):
        h = norm(x, bp["ln1"], cfg.norm)
        x = x + attention(h, bp["self_attn"], cfg, positions)
        h = norm(x, bp["ln_x"], cfg.norm)
        x = x + _cross(h, enc, bp["cross_attn"], cfg)
        h = norm(x, bp["ln2"], cfg.norm)
        return x + mlp(h, bp["mlp"], cfg), None

    if remat:
        body = jax.checkpoint(body)
    x, _ = jax.lax.scan(body, x, params["dec_blocks"])
    x = norm(x, params["dec_norm"], cfg.norm)
    return jnp.einsum("bsd,vd->bsv", x, params["embed"])      # tied head


def loss_fn(params, batch, cfg: ModelConfig, remat: bool = True) -> Array:
    enc = encode(params, batch["frames"], cfg)
    tokens = batch["tokens"]
    logits = decode_full(params, tokens, enc, cfg,
                         remat=remat and cfg.remat)
    logits = logits[:, :-1, :].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def prefill(params, tokens: Array, frames: Array, cfg: ModelConfig,
            max_len: int | None = None):
    """Encode audio + run the prompt through the decoder, build caches."""
    enc = encode(params, frames, cfg)
    B, S = tokens.shape
    max_len = max_len or cfg.max_seq
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    KV, hd = cfg.n_kv_heads, cfg.hd

    def body(x, bp):
        from .layers import _project_qkv, _sdpa
        h = norm(x, bp["ln1"], cfg.norm)
        q, k, v = _project_qkv(h, bp["self_attn"], cfg, positions)
        o = _sdpa(q, k, v, causal=True)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1),
                           bp["self_attn"]["wo"])
        h = norm(x, bp["ln_x"], cfg.norm)
        x = x + _cross(h, enc, bp["cross_attn"], cfg)
        # precompute this layer's cross K/V for decode
        ck = jnp.einsum("btd,dh->bth", enc, bp["cross_attn"]["wk"]
                        ).reshape(B, -1, KV, hd)
        cv = jnp.einsum("btd,dh->bth", enc, bp["cross_attn"]["wv"]
                        ).reshape(B, -1, KV, hd)
        h = norm(x, bp["ln2"], cfg.norm)
        return x + mlp(h, bp["mlp"], cfg), (k, v, ck, cv)

    x, (k_all, v_all, ck_all, cv_all) = jax.lax.scan(body, x,
                                                     params["dec_blocks"])
    x = norm(x, params["dec_norm"], cfg.norm)
    logits = jnp.einsum("bd,vd->bv", x[:, -1, :], params["embed"])
    pad = max_len - S
    k_all = jnp.pad(k_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    v_all = jnp.pad(v_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": k_all, "v": v_all, "cross_k": ck_all, "cross_v": cv_all,
             "len": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(params, cache, tokens: Array, cfg: ModelConfig):
    """One-token decode with cached self-attn KV + precomputed cross KV."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)
    pos = cache["len"]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd

    def body(x, xs):
        bp, k_l, v_l, ck_l, cv_l = xs
        h = norm(x, bp["ln1"], cfg.norm)
        o, new_kv = attention_decode(h, bp["self_attn"], cfg,
                                     {"k": k_l, "v": v_l, "len": pos}, pos)
        x = x + o
        h = norm(x, bp["ln_x"], cfg.norm)
        q = jnp.einsum("bsd,dh->bsh", h, bp["cross_attn"]["wq"]
                       ).reshape(B, 1, H, hd)
        from .layers import _sdpa
        o = _sdpa(q, ck_l, cv_l, causal=False)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(B, 1, -1),
                           bp["cross_attn"]["wo"])
        h = norm(x, bp["ln2"], cfg.norm)
        return x + mlp(h, bp["mlp"], cfg), (new_kv["k"], new_kv["v"])

    x, (k_new, v_new) = jax.lax.scan(
        body, x, (params["dec_blocks"], cache["k"], cache["v"],
                  cache["cross_k"], cache["cross_v"]))
    x = norm(x, params["dec_norm"], cfg.norm)
    logits = jnp.einsum("bsd,vd->bsv", x, params["embed"])[:, 0, :]
    return logits, {"k": k_new, "v": v_new, "cross_k": cache["cross_k"],
                    "cross_v": cache["cross_v"], "len": pos + 1}
