from .api import ModelAPI, build_model, make_batch
