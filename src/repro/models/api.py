"""Unified model API: one surface over all ten architectures.

    api = build_model(cfg)
    params = api.init(rng)
    loss   = api.loss(params, batch)                  # train
    logits, cache = api.prefill(params, batch)        # inference prefill
    logits, cache = api.decode(params, cache, tokens) # one decode step
    cache  = api.init_cache(batch_size, max_len)      # decode-only lowering

Batch dict keys: 'tokens' (B,S) int32 always; 'patches' (B,Np,D) for vlm;
'frames' (B,Ta,D) for audio — modality frontends are stubs per assignment.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from . import lm, rwkv6, whisper, zamba2
from .layers import dtype_of


@dataclass
class ModelAPI:
    cfg: ModelConfig
    init: Callable[[Any], dict]
    loss: Callable[[dict, dict], jax.Array]
    prefill: Callable[[dict, dict], tuple]
    decode: Callable[[dict, dict, jax.Array], tuple]
    init_cache: Callable[[int, int], dict]


def build_model(cfg: ModelConfig) -> ModelAPI:
    fam = cfg.family
    if fam in ("dense", "moe", "vlm"):
        def init(rng):
            return lm.init_lm(cfg, rng)

        def loss(params, batch):
            return lm.loss_fn(params, batch, cfg)

        def prefill_fn(params, batch):
            return lm.prefill(params, batch["tokens"], cfg,
                              patches=batch.get("patches"))

        def decode_fn(params, cache, tokens):
            return lm.decode_step(params, cache, tokens, cfg)

        def init_cache(batch, max_len):
            from .layers import init_kv_cache
            c = init_kv_cache(cfg, batch, max_len)
            return c

    elif fam == "ssm":
        def init(rng):
            return rwkv6.init_rwkv6(cfg, rng)

        def loss(params, batch):
            return rwkv6.loss_fn(params, batch, cfg)

        def prefill_fn(params, batch):
            return rwkv6.prefill(params, batch["tokens"], cfg)

        def decode_fn(params, cache, tokens):
            return rwkv6.decode_step(params, cache, tokens, cfg)

        def init_cache(batch, max_len):
            return {"state": rwkv6.init_state(cfg, batch),
                    "len": jnp.zeros((), jnp.int32)}

    elif fam == "hybrid":
        def init(rng):
            return zamba2.init_zamba2(cfg, rng)

        def loss(params, batch):
            return zamba2.loss_fn(params, batch, cfg)

        def prefill_fn(params, batch):
            return zamba2.prefill(params, batch["tokens"], cfg,
                                  max_len=batch["tokens"].shape[1] + 8)

        def decode_fn(params, cache, tokens):
            return zamba2.decode_step(params, cache, tokens, cfg)

        def init_cache(batch, max_len):
            return zamba2.init_state(cfg, batch, max_len)

    elif fam == "audio":
        def init(rng):
            return whisper.init_whisper(cfg, rng)

        def loss(params, batch):
            return whisper.loss_fn(params, batch, cfg)

        def prefill_fn(params, batch):
            return whisper.prefill(params, batch["tokens"], batch["frames"],
                                   cfg, max_len=batch["tokens"].shape[1] + 8)

        def decode_fn(params, cache, tokens):
            return whisper.decode_step(params, cache, tokens, cfg)

        def init_cache(batch, max_len):
            L, KV, hd = cfg.n_layers, cfg.n_kv_heads, cfg.hd
            dt = dtype_of(cfg)
            return {
                "k": jnp.zeros((L, batch, max_len, KV, hd), dt),
                "v": jnp.zeros((L, batch, max_len, KV, hd), dt),
                "cross_k": jnp.zeros((L, batch, cfg.enc_ctx, KV, hd), dt),
                "cross_v": jnp.zeros((L, batch, cfg.enc_ctx, KV, hd), dt),
                "len": jnp.zeros((), jnp.int32),
            }
    else:
        raise ValueError(f"unknown family {fam!r}")

    return ModelAPI(cfg=cfg, init=init, loss=loss, prefill=prefill_fn,
                    decode=decode_fn, init_cache=init_cache)


def make_batch(cfg: ModelConfig, batch: int, seq: int, rng=None,
               for_loss: bool = True) -> dict:
    """Concrete (smoke-test) batch; mirrors launch/specs.input_specs."""
    rng = rng if rng is not None else jax.random.PRNGKey(0)
    k1, k2 = jax.random.split(rng)
    out = {"tokens": jax.random.randint(k1, (batch, seq), 0, cfg.vocab_size,
                                        dtype=jnp.int32)}
    if cfg.family == "vlm":
        out["patches"] = jax.random.normal(
            k2, (batch, cfg.n_patches, cfg.d_model)).astype(dtype_of(cfg))
    elif cfg.family == "audio":
        out["frames"] = jax.random.normal(
            k2, (batch, cfg.enc_ctx, cfg.d_model)).astype(dtype_of(cfg))
    return out
