"""Shared model primitives: norms, rotary, GQA attention (train + cached
decode), gated MLPs, and the capacity-based MoE layer.

All functions are pure; parameters are plain dict pytrees.  Layer stacks store
parameters with a leading layer axis and run under ``jax.lax.scan`` so HLO
size (and 1-core compile time for the 80 dry-run cells) is depth-independent.

Compute dtype is the input dtype (bf16 in production configs); softmax and
norm statistics accumulate in fp32.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

Array = jax.Array
BIG_NEG = -2.0 ** 30


def dtype_of(cfg: ModelConfig):
    return jnp.dtype(cfg.dtype)


# ------------------------------------------------------- sharding hints
def shard_hint(x: Array, *axes) -> Array:
    """with_sharding_constraint against the ambient mesh, if any.

    ``axes`` entries: 'batch' (expands to whichever of pod/data exist),
    'model', 'data', or None.  Outside a mesh context (unit tests, smoke
    tests) this is the identity, so model code can hint unconditionally.
    """
    names: set = set()
    try:                                   # classic `with mesh:` context
        from jax._src import mesh as _mesh_lib
        m = _mesh_lib.thread_resources.env.physical_mesh
        if m is not None and not m.empty:
            names = set(m.axis_names)
    except (ImportError, AttributeError):
        pass
    if not names:
        try:                               # new explicit-sharding context
            m = jax.sharding.get_abstract_mesh()
            if m is not None and m.axis_names:
                names = set(m.axis_names)
        except (ImportError, AttributeError):
            pass
    if not names:
        return x
    try:
        from repro.parallel.sharding import LAYOUT
        layout = LAYOUT.get()
    except (ImportError, AttributeError, LookupError):
        layout = "tp"
    fsdp = layout in ("fsdp", "ep")    # no TP on feature dims
    batch_gets_model = layout == "fsdp"
    mesh_sizes = dict(zip(m.axis_names, m.devices.shape)) \
        if hasattr(m, "devices") else {}
    spec = []
    for i, a in enumerate(axes):
        if a == "batch":
            cand = ("pod", "data", "model") if batch_gets_model \
                else ("pod", "data")
            ba = tuple(n for n in cand if n in names)
            if ba and mesh_sizes and i < x.ndim:
                total = 1
                for n in ba:
                    total *= mesh_sizes.get(n, 1)
                while ba and x.shape[i] % total != 0:
                    total //= mesh_sizes.get(ba[-1], 1)
                    ba = ba[:-1]
            spec.append(ba if ba else None)
        elif a == "expert":
            # expert-parallel axis: stays on 'model' under EVERY layout
            spec.append("model" if "model" in names else None)
        elif a in names:
            # under fsdp, 'model' belongs to the batch dims — never to
            # feature dims (no tensor parallelism)
            spec.append(None if (fsdp and a == "model") else a)
        else:
            spec.append(None)
    try:
        return jax.lax.with_sharding_constraint(
            x, jax.sharding.PartitionSpec(*spec))
    # reprolint: allow(loud-corruption) — sharding hints are best-effort: outside a mesh context the constraint is meaningless and the identity is the correct degradation
    except Exception:
        return x


# ----------------------------------------------------------------- norms
def rms_norm(x: Array, scale: Array, eps: float = 1e-6) -> Array:
    var = jnp.mean(jnp.square(x.astype(jnp.float32)), axis=-1, keepdims=True)
    out = x.astype(jnp.float32) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32)).astype(x.dtype)


def layer_norm(x: Array, scale: Array, bias: Array, eps: float = 1e-5) -> Array:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    return (out * scale.astype(jnp.float32) + bias.astype(jnp.float32)).astype(x.dtype)


def norm(x: Array, p: dict, kind: str) -> Array:
    if kind == "layernorm":
        return layer_norm(x, p["scale"], p["bias"])
    return rms_norm(x, p["scale"])


def init_norm(cfg: ModelConfig, shape_prefix=()) -> dict:
    d = cfg.d_model
    p = {"scale": jnp.ones(shape_prefix + (d,), dtype_of(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros(shape_prefix + (d,), dtype_of(cfg))
    return p


# ----------------------------------------------------------------- rotary
def rope_freqs(cfg: ModelConfig, rot_dim: int) -> Array:
    exponent = jnp.arange(0, rot_dim, 2, dtype=jnp.float32) / rot_dim
    return 1.0 / (cfg.rope_theta ** exponent)          # (rot_dim//2,)


def apply_rope(x: Array, positions: Array, cfg: ModelConfig) -> Array:
    """x: (..., S, n_heads, head_dim); positions: (..., S)."""
    hd = x.shape[-1]
    rot = int(hd * cfg.partial_rotary) // 2 * 2
    if rot == 0:
        return x
    freqs = rope_freqs(cfg, rot)                        # (rot/2,)
    angles = positions[..., None].astype(jnp.float32) * freqs    # (..., S, rot/2)
    cos = jnp.cos(angles)[..., None, :]                 # (..., S, 1, rot/2)
    sin = jnp.sin(angles)[..., None, :]
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = jnp.split(x_rot.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return jnp.concatenate([out.astype(x.dtype), x_pass], axis=-1)


# -------------------------------------------------------------- attention
def init_attention(cfg: ModelConfig, key, shape_prefix=()) -> dict:
    D, H, KV, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    k1, k2, k3, k4 = jax.random.split(key, 4)
    s = 1.0 / math.sqrt(D)
    so = 1.0 / math.sqrt(H * hd)
    dt = dtype_of(cfg)
    p = {
        "wq": (jax.random.normal(k1, (*shape_prefix, D, H * hd)) * s).astype(dt),
        "wk": (jax.random.normal(k2, (*shape_prefix, D, KV * hd)) * s).astype(dt),
        "wv": (jax.random.normal(k3, (*shape_prefix, D, KV * hd)) * s).astype(dt),
        "wo": (jax.random.normal(k4, (*shape_prefix, H * hd, D)) * so).astype(dt),
    }
    if cfg.qkv_bias:
        p["bq"] = jnp.zeros((*shape_prefix, H * hd), dt)
        p["bk"] = jnp.zeros((*shape_prefix, KV * hd), dt)
        p["bv"] = jnp.zeros((*shape_prefix, KV * hd), dt)
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((*shape_prefix, hd), dt)
        p["k_norm"] = jnp.ones((*shape_prefix, hd), dt)
    return p


def _project_qkv(x: Array, p: dict, cfg: ModelConfig, positions: Array):
    B, S, D = x.shape
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = jnp.einsum("bsd,dh->bsh", x, p["wq"])
    k = jnp.einsum("bsd,dh->bsh", x, p["wk"])
    v = jnp.einsum("bsd,dh->bsh", x, p["wv"])
    if cfg.qkv_bias:
        q, k, v = q + p["bq"], k + p["bk"], v + p["bv"]
    q = q.reshape(B, S, H, hd)
    k = k.reshape(B, S, KV, hd)
    v = v.reshape(B, S, KV, hd)
    if cfg.qk_norm:
        q = rms_norm(q, p["q_norm"])
        k = rms_norm(k, p["k_norm"])
    q = apply_rope(q, positions, cfg)
    k = apply_rope(k, positions, cfg)
    return q, k, v


SDPA_CHUNK_THRESHOLD = 2048          # direct-path limit on max(Sq, Skv)
Q_CHUNK = 512
KV_CHUNK = 1024


def _sdpa_direct(q: Array, k: Array, v: Array, causal: bool,
                 q_offset: int | Array = 0) -> Array:
    B, Sq, H, hd = q.shape
    KV = k.shape[2]
    G = H // KV
    qg = q.reshape(B, Sq, KV, G, hd)
    scores = jnp.einsum("bqkgh,bskh->bkgqs", qg, k).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    if causal:
        qpos = jax.lax.broadcasted_iota(jnp.int32, (Sq, k.shape[1]), 0) + q_offset
        kpos = jax.lax.broadcasted_iota(jnp.int32, (Sq, k.shape[1]), 1)
        scores = jnp.where(qpos >= kpos, scores, BIG_NEG)
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqs,bskh->bqkgh", w, v)
    return out.reshape(B, Sq, H, hd)


def _sdpa_chunked(q: Array, k: Array, v: Array, causal: bool) -> Array:
    """Flash-style online-softmax attention in jnp: O(S) memory.

    Scans q in blocks of Q_CHUNK; for each, scans kv in blocks of KV_CHUNK
    carrying (running max, running denom, weighted accumulator).  Peak temp
    is one (B,KV,G,Cq,Ckv) tile instead of the full S^2 score matrix — this
    is the same tiling the Pallas kernel (kernels/flash_attention.py) uses
    natively in VMEM.
    """
    B, Sq, H, hd = q.shape
    Skv, KV = k.shape[1], k.shape[2]
    G = H // KV

    def _pick(n: int, target: int) -> int:
        c = min(target, n)
        while c > 1 and n % c:
            c //= 2
        return c if n % c == 0 else 1

    Cq = _pick(Sq, Q_CHUNK)
    Ck = _pick(Skv, KV_CHUNK)
    nq, nk = Sq // Cq, Skv // Ck
    scale = 1.0 / math.sqrt(hd)

    qb = q.reshape(B, nq, Cq, KV, G, hd)
    qb = jnp.moveaxis(qb, 1, 0)                       # (nq,B,Cq,KV,G,hd)
    kb = jnp.moveaxis(k.reshape(B, nk, Ck, KV, hd), 1, 0)
    vb = jnp.moveaxis(v.reshape(B, nk, Ck, KV, hd), 1, 0)

    def q_block(qi, qt):
        m0 = jnp.full((B, KV, G, Cq), -jnp.inf, jnp.float32)
        l0 = jnp.zeros((B, KV, G, Cq), jnp.float32)
        a0 = jnp.zeros((B, KV, G, Cq, hd), jnp.float32)

        def kv_step(carry, inp):
            m, l, acc = carry
            kj, kt, vt = inp
            s = jnp.einsum("bqkgh,bskh->bkgqs", qt, kt).astype(jnp.float32)
            s *= scale
            if causal:
                qpos = qi * Cq + jax.lax.broadcasted_iota(
                    jnp.int32, (Cq, Ck), 0)
                kpos = kj * Ck + jax.lax.broadcasted_iota(
                    jnp.int32, (Cq, Ck), 1)
                s = jnp.where(qpos >= kpos, s, -jnp.inf)
            m_new = jnp.maximum(m, s.max(axis=-1))
            # guard fully-masked rows (m_new = -inf)
            m_safe = jnp.where(jnp.isfinite(m_new), m_new, 0.0)
            p = jnp.exp(s - m_safe[..., None])
            p = jnp.where(jnp.isfinite(s), p, 0.0)
            alpha = jnp.where(jnp.isfinite(m), jnp.exp(m - m_safe), 0.0)
            l = l * alpha + p.sum(axis=-1)
            acc = acc * alpha[..., None] + jnp.einsum(
                "bkgqs,bskh->bkgqh", p.astype(qt.dtype), vt)
            return (m_new, l, acc), None

        ks = jnp.arange(nk, dtype=jnp.int32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0), (ks, kb, vb))
        out = acc / jnp.maximum(l, 1e-30)[..., None]
        return jnp.moveaxis(out, 3, 1).reshape(B, Cq, KV * G, hd)

    qi = jnp.arange(nq, dtype=jnp.int32)
    out = jax.lax.map(lambda xs: q_block(*xs), (qi, qb))   # (nq,B,Cq,H,hd)
    out = jnp.moveaxis(out, 0, 1).reshape(B, Sq, H, hd)
    return out.astype(q.dtype)


def _sdpa(q: Array, k: Array, v: Array, causal: bool,
          q_offset: int | Array = 0) -> Array:
    """q: (B,Sq,H,hd); k,v: (B,Skv,KV,hd) -> (B,Sq,H,hd)."""
    Sq, Skv = q.shape[1], k.shape[1]
    if max(Sq, Skv) <= SDPA_CHUNK_THRESHOLD or Sq == 1:
        return _sdpa_direct(q, k, v, causal, q_offset)
    return _sdpa_chunked(q, k, v, causal)


def attention(x: Array, p: dict, cfg: ModelConfig, positions: Array,
              causal: bool = True) -> Array:
    """Full-sequence attention (train / prefill)."""
    B, S, D = x.shape
    q, k, v = _project_qkv(x, p, cfg, positions)
    out = _sdpa(q, k, v, causal)
    return jnp.einsum("bsh,hd->bsd", out.reshape(B, S, -1), p["wo"])


def attention_decode(x: Array, p: dict, cfg: ModelConfig, cache: dict,
                     pos: Array) -> tuple[Array, dict]:
    """One-token decode against a KV cache.

    cache: {'k','v': (B, S_max, KV, hd), 'len': scalar int32 current length}
    x: (B, 1, D); pos broadcasts (B,) or scalar.
    The cache sequence axis may be sharded (SP for long contexts): the
    partial-softmax combine is left to XLA SPMD over the masked full-length
    score vector.
    """
    B, S1, D = x.shape
    positions = jnp.broadcast_to(jnp.asarray(pos), (B,))[:, None]      # (B,1)
    q, k_new, v_new = _project_qkv(x, p, cfg, positions)
    k_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["k"], k_new.astype(cache["k"].dtype), cache["len"], axis=1)
    v_cache = jax.lax.dynamic_update_slice_in_dim(
        cache["v"], v_new.astype(cache["v"].dtype), cache["len"], axis=1)
    S = k_cache.shape[1]
    H, KV, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    G = H // KV
    qg = q.reshape(B, KV, G, hd)
    scores = jnp.einsum("bkgh,bskh->bkgs", qg, k_cache).astype(jnp.float32)
    scores *= 1.0 / math.sqrt(hd)
    kpos = jax.lax.broadcasted_iota(jnp.int32, (1, 1, 1, S), 3)
    scores = jnp.where(kpos <= cache["len"], scores, BIG_NEG)
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgs,bskh->bkgh", w, v_cache).reshape(B, 1, H * hd)
    y = jnp.einsum("bsh,hd->bsd", out, p["wo"])
    return y, {"k": k_cache, "v": v_cache, "len": cache["len"] + 1}


def init_kv_cache(cfg: ModelConfig, batch: int, max_len: int,
                  n_layers: Optional[int] = None) -> dict:
    KV, hd = cfg.n_kv_heads, cfg.hd
    L = cfg.n_layers if n_layers is None else n_layers
    dt = dtype_of(cfg)
    return {
        "k": jnp.zeros((L, batch, max_len, KV, hd), dt),
        "v": jnp.zeros((L, batch, max_len, KV, hd), dt),
        "len": jnp.zeros((), jnp.int32),
    }


# ------------------------------------------------------------------- MLPs
def init_mlp(cfg: ModelConfig, key, d_ff: Optional[int] = None,
             shape_prefix=()) -> dict:
    D = cfg.d_model
    F = d_ff or cfg.d_ff
    dt = dtype_of(cfg)
    s_in, s_out = 1.0 / math.sqrt(D), 1.0 / math.sqrt(F)
    ks = jax.random.split(key, 3)
    if cfg.act == "swiglu":
        return {
            "wg": (jax.random.normal(ks[0], (*shape_prefix, D, F)) * s_in).astype(dt),
            "wu": (jax.random.normal(ks[1], (*shape_prefix, D, F)) * s_in).astype(dt),
            "wd": (jax.random.normal(ks[2], (*shape_prefix, F, D)) * s_out).astype(dt),
        }
    return {
        "wu": (jax.random.normal(ks[0], (*shape_prefix, D, F)) * s_in).astype(dt),
        "wd": (jax.random.normal(ks[1], (*shape_prefix, F, D)) * s_out).astype(dt),
    }


def mlp(x: Array, p: dict, cfg: ModelConfig) -> Array:
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("bsd,df->bsf", x, p["wg"]))
        h = h * jnp.einsum("bsd,df->bsf", x, p["wu"])
    else:
        h = jax.nn.gelu(jnp.einsum("bsd,df->bsf", x, p["wu"]))
    return jnp.einsum("bsf,fd->bsd", h, p["wd"])


# -------------------------------------------------------------------- MoE
MOE_GROUP = 512      # tokens per dispatch group (memory/parallelism tradeoff)


def init_moe(cfg: ModelConfig, key, shape_prefix=()) -> dict:
    D, E, F = cfg.d_model, cfg.n_experts, cfg.moe_d_ff
    dt = dtype_of(cfg)
    ks = jax.random.split(key, 5)
    s_in, s_out = 1.0 / math.sqrt(D), 1.0 / math.sqrt(F)
    p = {
        "router": (jax.random.normal(ks[0], (*shape_prefix, D, E)) * s_in
                   ).astype(jnp.float32),
        "wg": (jax.random.normal(ks[1], (*shape_prefix, E, D, F)) * s_in).astype(dt),
        "wu": (jax.random.normal(ks[2], (*shape_prefix, E, D, F)) * s_in).astype(dt),
        "wd": (jax.random.normal(ks[3], (*shape_prefix, E, F, D)) * s_out).astype(dt),
    }
    if cfg.n_shared_experts:
        Fs = cfg.moe_d_ff * cfg.n_shared_experts
        kk = jax.random.split(ks[4], 3)
        p["shared"] = {
            "wg": (jax.random.normal(kk[0], (*shape_prefix, D, Fs)) * s_in).astype(dt),
            "wu": (jax.random.normal(kk[1], (*shape_prefix, D, Fs)) * s_in).astype(dt),
            "wd": (jax.random.normal(kk[2], (*shape_prefix, Fs, D)) * s_out).astype(dt),
        }
    return p


def moe_ffn(x: Array, p: dict, cfg: ModelConfig) -> tuple[Array, Array]:
    """Top-k capacity-based MoE (GShard-style einsum dispatch).

    x: (B, S, D) -> (B, S, D), plus aux load-balancing loss.
    Tokens are processed in groups of MOE_GROUP so the dispatch one-hots stay
    bounded; groups map onto the data axis, experts onto the model axis (EP).
    """
    B, S, D = x.shape
    E, K = cfg.n_experts, cfg.top_k
    N = B * S
    G = min(MOE_GROUP, N)
    n_groups = N // G
    assert n_groups * G == N, f"MoE group {G} must divide tokens {N}"
    cap = max(1, int(G * K * cfg.capacity_factor / E))

    xg = x.reshape(n_groups, G, D)
    logits = jnp.einsum("ngd,de->nge", xg.astype(jnp.float32), p["router"])
    probs = jax.nn.softmax(logits, axis=-1)                    # (n,G,E)
    gate_vals, expert_idx = jax.lax.top_k(probs, K)            # (n,G,K)
    gate_vals = gate_vals / (gate_vals.sum(-1, keepdims=True) + 1e-9)

    # position-in-expert bookkeeping, slot by slot (K is small)
    counts = jnp.zeros((n_groups, E), jnp.int32)
    dispatch = jnp.zeros((n_groups, G, E, cap), jnp.bool_)
    combine = jnp.zeros((n_groups, G, E, cap), jnp.float32)
    for slot in range(K):
        oh = jax.nn.one_hot(expert_idx[..., slot], E, dtype=jnp.int32)  # (n,G,E)
        pos = jnp.cumsum(oh, axis=1) - 1 + counts[:, None, :]           # (n,G,E)
        keep = (pos < cap) & (oh > 0)
        pos_oh = jax.nn.one_hot(pos, cap, dtype=jnp.bool_) & keep[..., None]
        dispatch = dispatch | pos_oh
        combine = combine + pos_oh * gate_vals[..., slot][..., None, None]
        counts = counts + (oh * keep).sum(axis=1)

    # NOTE (§Perf, qwen3-moe iterations): explicit expert-axis constraints
    # here were tried and REFUTED — GSPMD lowers the n->e reshard to
    # data-axis all-gathers (16x a2a volume) whichever way it is phrased;
    # the proper fix is an explicit shard_map a2a dispatch (future work).
    expert_in = jnp.einsum("ngec,ngd->necd", dispatch.astype(x.dtype), xg)
    if cfg.act == "swiglu":
        h = jax.nn.silu(jnp.einsum("necd,edf->necf", expert_in, p["wg"]))
        h = h * jnp.einsum("necd,edf->necf", expert_in, p["wu"])
    else:
        h = jax.nn.gelu(jnp.einsum("necd,edf->necf", expert_in, p["wu"]))
    expert_out = jnp.einsum("necf,efd->necd", h, p["wd"])
    y = jnp.einsum("ngec,necd->ngd", combine.astype(x.dtype), expert_out)
    y = y.reshape(B, S, D)

    if cfg.n_shared_experts:
        y = y + mlp(x, p["shared"], cfg)

    # aux: Switch-style load-balance loss
    me = probs.mean(axis=1)                                    # (n,E)
    ce = (dispatch.sum(axis=(1, 3)) / G).astype(jnp.float32)   # fraction per e
    aux = (me * ce).sum(axis=-1).mean() * E
    return y, aux
