"""Decoder-only LM covering the dense / moe / vlm families
(stablelm, qwen2.5, qwen3, llama3.2, moonshot-moe, qwen3-moe, pixtral).

Layers are weight-stacked and executed with ``jax.lax.scan`` (optionally
rematerialized), so HLO size and compile time are depth-independent.
VLM (pixtral): the stub frontend supplies pre-projected patch embeddings that
are prepended to the token sequence; loss covers text positions only.
"""
from __future__ import annotations

import math
from typing import Optional

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import (attention, attention_decode, dtype_of, init_attention,
                     init_kv_cache, init_mlp, init_moe, init_norm, mlp,
                     moe_ffn, norm, shard_hint)

Array = jax.Array


# ---------------------------------------------------------------- init
def init_lm(cfg: ModelConfig, rng) -> dict:
    L = cfg.n_layers
    n_dense = cfg.first_dense_layers
    n_scan = L - n_dense
    k_emb, k_blocks, k_dense, k_head = jax.random.split(rng, 4)
    dt = dtype_of(cfg)
    D, V = cfg.d_model, cfg.vocab_size
    params: dict = {
        "embed": (jax.random.normal(k_emb, (V, D)) * 0.02).astype(dt),
        "final_norm": init_norm(cfg),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = (jax.random.normal(k_head, (D, V))
                             / math.sqrt(D)).astype(dt)

    def make_block(key, prefix):
        ka, km = jax.random.split(key)
        block = {
            "ln1": init_norm(cfg, prefix),
            "attn": init_attention(cfg, ka, prefix),
            "ln2": init_norm(cfg, prefix),
        }
        if cfg.n_experts:
            block["moe"] = init_moe(cfg, km, prefix)
        else:
            block["mlp"] = init_mlp(cfg, km, shape_prefix=prefix)
        return block

    params["blocks"] = make_block(k_blocks, (n_scan,))
    if n_dense:
        # leading dense-FFN layers (e.g. moonshot first_dense_layers=1)
        dense_block = {
            "ln1": init_norm(cfg, (n_dense,)),
            "attn": init_attention(cfg, k_dense, (n_dense,)),
            "ln2": init_norm(cfg, (n_dense,)),
            "mlp": init_mlp(cfg, jax.random.fold_in(k_dense, 1),
                            shape_prefix=(n_dense,)),
        }
        params["dense_blocks"] = dense_block
    return params


# ---------------------------------------------------------------- forward
def _block_fwd(x: Array, bp: dict, cfg: ModelConfig, positions: Array,
               use_moe: bool) -> tuple[Array, Array]:
    h = norm(x, bp["ln1"], cfg.norm)
    x = x + attention(h, bp["attn"], cfg, positions)
    h = norm(x, bp["ln2"], cfg.norm)
    if use_moe:
        y, aux = moe_ffn(h, bp["moe"], cfg)
    else:
        y, aux = mlp(h, bp["mlp"], cfg), jnp.zeros((), jnp.float32)
    return shard_hint(x + y, "batch", None, None), aux


def forward(params: dict, tokens: Array, cfg: ModelConfig,
            patches: Optional[Array] = None, remat: bool = False
            ) -> tuple[Array, Array]:
    """tokens: (B, S) int32; patches: (B, Np, D) or None.
    Returns (logits over full sequence, aux loss)."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    x = shard_hint(x, "batch", None, None)
    B, S, D = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))

    aux_total = jnp.zeros((), jnp.float32)
    if "dense_blocks" in params:
        db = params["dense_blocks"]
        for i in range(cfg.first_dense_layers):
            bp = jax.tree.map(lambda a: a[i], db)
            x, _ = _block_fwd(x, bp, cfg, positions, use_moe=False)

    def body(carry, bp):
        x, aux = carry
        x, a = _block_fwd(x, bp, cfg, positions, use_moe=bool(cfg.n_experts))
        return (x, aux + a), None

    if remat:
        body = jax.checkpoint(body)
    (x, aux_total), _ = jax.lax.scan(body, (x, aux_total), params["blocks"])

    x = norm(x, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = shard_hint(jnp.einsum("bsd,dv->bsv", x, head),
                        "batch", None, "model")
    return logits, aux_total


def loss_fn(params: dict, batch: dict, cfg: ModelConfig,
            remat: bool = True) -> Array:
    """Next-token cross-entropy (text positions only for VLM)."""
    tokens = batch["tokens"]
    logits, aux = forward(params, tokens, cfg,
                          patches=batch.get("patches"),
                          remat=remat and cfg.remat)
    if batch.get("patches") is not None:
        logits = logits[:, batch["patches"].shape[1]:, :]
    logits = logits[:, :-1, :].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (logz - gold).mean() + 0.01 * aux


# ----------------------------------------------------------------- decode
def prefill(params: dict, tokens: Array, cfg: ModelConfig,
            patches: Optional[Array] = None,
            max_len: Optional[int] = None) -> tuple[Array, dict]:
    """Run the full prompt, build the KV cache (padded to ``max_len`` so
    decode steps have room), return last-token logits."""
    x = jnp.take(params["embed"], tokens, axis=0)
    if patches is not None:
        x = jnp.concatenate([patches.astype(x.dtype), x], axis=1)
    x = shard_hint(x, "batch", None, None)
    B, S, D = x.shape
    positions = jnp.broadcast_to(jnp.arange(S, dtype=jnp.int32), (B, S))
    KV, hd = cfg.n_kv_heads, cfg.hd

    n_dense = cfg.first_dense_layers
    caches = []

    def run_block(x, bp, use_moe):
        h = norm(x, bp["ln1"], cfg.norm)
        from .layers import _project_qkv, _sdpa
        q, k, v = _project_qkv(h, bp["attn"], cfg, positions)
        o = _sdpa(q, k, v, causal=True)
        x = x + jnp.einsum("bsh,hd->bsd", o.reshape(B, S, -1), bp["attn"]["wo"])
        h = norm(x, bp["ln2"], cfg.norm)
        y = moe_ffn(h, bp["moe"], cfg)[0] if use_moe else mlp(h, bp["mlp"], cfg)
        return x + y, (k, v)

    if n_dense:
        for i in range(n_dense):
            bp = jax.tree.map(lambda a: a[i], params["dense_blocks"])
            x, kv = run_block(x, bp, use_moe=False)
            caches.append(kv)

    def body(x, bp):
        x, kv = run_block(x, bp, use_moe=bool(cfg.n_experts))
        return x, kv

    x, scan_kv = jax.lax.scan(body, x, params["blocks"])

    x = norm(x, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bd,dv->bv", x[:, -1, :], head)

    k_all, v_all = scan_kv
    if caches:
        k_pre = jnp.stack([c[0] for c in caches])
        v_pre = jnp.stack([c[1] for c in caches])
        k_all = jnp.concatenate([k_pre, k_all], axis=0)
        v_all = jnp.concatenate([v_pre, v_all], axis=0)
    pad = (max_len or S + 8) - S
    if pad > 0:
        k_all = jnp.pad(k_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
        v_all = jnp.pad(v_all, ((0, 0), (0, 0), (0, pad), (0, 0), (0, 0)))
    cache = {"k": k_all, "v": v_all,
             "len": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(params: dict, cache: dict, tokens: Array, cfg: ModelConfig
                ) -> tuple[Array, dict]:
    """One decode step.  tokens: (B, 1); cache from init_kv_cache/prefill
    with k/v: (L, B, S_max, KV, hd)."""
    x = shard_hint(jnp.take(params["embed"], tokens, axis=0),
                   "batch", None, None)
    pos = cache["len"]
    n_dense = cfg.first_dense_layers

    def run_block(x, bp, kv, use_moe):
        h = norm(x, bp["ln1"], cfg.norm)
        o, new_kv = attention_decode(h, bp["attn"], cfg,
                                     {"k": kv[0], "v": kv[1], "len": pos}, pos)
        x = x + o
        h = norm(x, bp["ln2"], cfg.norm)
        y = moe_ffn(h, bp["moe"], cfg)[0] if use_moe else mlp(h, bp["mlp"], cfg)
        return x + y, (new_kv["k"], new_kv["v"])

    new_k, new_v = [], []
    if n_dense:
        for i in range(n_dense):
            bp = jax.tree.map(lambda a: a[i], params["dense_blocks"])
            x, (k, v) = run_block(x, bp, (cache["k"][i], cache["v"][i]), False)
            new_k.append(k); new_v.append(v)

    def body(x, xs):
        bp, k_l, v_l = xs
        x, (k, v) = run_block(x, bp, (k_l, v_l), bool(cfg.n_experts))
        return x, (k, v)

    x, (k_scan, v_scan) = jax.lax.scan(
        body, x, (params["blocks"], cache["k"][n_dense:], cache["v"][n_dense:]))

    if new_k:
        k_scan = jnp.concatenate([jnp.stack(new_k), k_scan], axis=0)
        v_scan = jnp.concatenate([jnp.stack(new_v), v_scan], axis=0)

    x = norm(x, params["final_norm"], cfg.norm)
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = jnp.einsum("bsd,dv->bsv", x, head)[:, 0, :]
    return logits, {"k": k_scan, "v": v_scan, "len": pos + 1}
