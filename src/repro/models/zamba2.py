"""Zamba2: Mamba2 (SSD) backbone with a single *shared* attention block
applied every ``attn_every`` layers (arXiv:2411.15242).

Mamba2 mixer per layer (multi-head SSD, n_groups=1):
    h_t = exp(A dt_t) h_{t-1} + dt_t * x_t (x) B_t        h: (H, P, N)
    y_t = h_t . C_t + D x_t
with a causal depthwise conv (width 4) on (x, B, C) and a gated RMS-norm
before out-projection.  The model forward is an exact ``lax.scan`` over time
(chunked production path: kernels/ssd_scan.py).

The shared attention block's weights are reused at every invocation; each
invocation keeps its *own* KV cache (stacked on a leading invocation axis) —
weight sharing is a parameter-count device, not a cache-sharing one.
`long_500k` runs: mamba state decode is O(1), and the shared-attn KV cache's
sequence axis is shardable over the data mesh axis (SP).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import (attention, attention_decode, dtype_of, init_attention,
                     init_mlp, init_norm, mlp, norm, shard_hint)

Array = jax.Array


def dims(cfg: ModelConfig):
    d_inner = cfg.ssm_expand * cfg.d_model
    H = d_inner // cfg.ssm_headdim
    return d_inner, H, cfg.ssm_headdim, cfg.ssm_state


# ------------------------------------------------------------------ init
def init_zamba2(cfg: ModelConfig, rng) -> dict:
    D, V, L = cfg.d_model, cfg.vocab_size, cfg.n_layers
    d_inner, H, P, N = dims(cfg)
    conv_dim = d_inner + 2 * N
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 10)
    s = 1.0 / math.sqrt(D)

    def mat(k, *shape, scale=s):
        return (jax.random.normal(k, shape) * scale).astype(dt)

    params = {
        "embed": mat(ks[0], V, D, scale=0.02),
        "lm_head": mat(ks[1], D, V),
        "final_norm": init_norm(cfg),
        "blocks": {
            "ln": init_norm(cfg, (L,)),
            # per-component projections (instead of one fused in_proj): x/z
            # are head-sharded (TP over the model axis); B/C/dt are small and
            # replicated — the split keeps TP boundaries on head boundaries.
            "w_z": mat(ks[2], L, D, d_inner),
            "w_x": mat(jax.random.fold_in(ks[2], 1), L, D, d_inner),
            "w_bc": mat(jax.random.fold_in(ks[2], 2), L, D, 2 * N),
            "w_dt": mat(jax.random.fold_in(ks[2], 3), L, D, H),
            "conv_x_w": (jax.random.normal(ks[3], (L, cfg.ssm_conv, d_inner))
                         * 0.1).astype(dt),
            "conv_x_b": jnp.zeros((L, d_inner), dt),
            "conv_bc_w": (jax.random.normal(jax.random.fold_in(ks[3], 1),
                                            (L, cfg.ssm_conv, 2 * N))
                          * 0.1).astype(dt),
            "conv_bc_b": jnp.zeros((L, 2 * N), dt),
            "A_log": jnp.zeros((L, H), jnp.float32),
            "D": jnp.ones((L, H), jnp.float32),
            "dt_bias": jnp.zeros((L, H), jnp.float32),
            "gate_norm": jnp.ones((L, d_inner), dt),
            "out_proj": mat(ks[4], L, d_inner, D,
                            scale=1.0 / math.sqrt(d_inner)),
        },
        # one shared attention+MLP block
        "shared": {
            "ln1": init_norm(cfg),
            "attn": init_attention(cfg, ks[5]),
            "ln2": init_norm(cfg),
            "mlp": init_mlp(cfg, ks[6]),
        },
    }
    return params


# ----------------------------------------------------------------- mamba2
def _causal_conv(x: Array, w: Array, b: Array, conv_state: Array):
    """x: (B,T,C); w: (K,C) depthwise; conv_state: (B,K-1,C) from the left.
    Returns (out (B,T,C), new_conv_state)."""
    K = w.shape[0]
    xx = jnp.concatenate([conv_state.astype(x.dtype), x], axis=1)
    out = sum(xx[:, i:i + x.shape[1], :] * w[i] for i in range(K))
    new_state = xx[:, -(K - 1):, :] if K > 1 else conv_state
    return jax.nn.silu(out + b), new_state


def _ssd_scan(xh, dt_h, B_in, C_in, A, h0):
    """Exact SSD recurrence.
    xh: (B,T,H,P); dt_h: (B,T,H); B_in,C_in: (B,T,N); A: (H,) negative.
    h0: (B,H,P,N).  Returns (y (B,T,H,P), hT)."""

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp
        decay = jnp.exp(A * dt_t)[..., None, None]            # (B,H,1,1)
        upd = (dt_t[..., None, None] * x_t[..., :, None]
               * b_t[:, None, None, :])                       # (B,H,P,N)
        h = decay * h + upd
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    xs = (jnp.moveaxis(xh.astype(jnp.float32), 1, 0),
          jnp.moveaxis(dt_h, 1, 0),
          jnp.moveaxis(B_in.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C_in.astype(jnp.float32), 1, 0))
    hT, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 1), hT


def mamba_mixer(x, bp, cfg: ModelConfig, state):
    """state: ((conv_x (B,K-1,d_inner), conv_bc (B,K-1,2N)), ssm (B,H,P,N))."""
    B, T, D = x.shape
    d_inner, H, P, N = dims(cfg)
    (conv_x_state, conv_bc_state), ssm_state = state
    z = x @ bp["w_z"]
    xc = x @ bp["w_x"]
    bc = x @ bp["w_bc"]
    dt_raw = x @ bp["w_dt"]
    xc, new_conv_x = _causal_conv(xc, bp["conv_x_w"], bp["conv_x_b"],
                                  conv_x_state)
    bc, new_conv_bc = _causal_conv(bc, bp["conv_bc_w"], bp["conv_bc_b"],
                                   conv_bc_state)
    B_in, C_in = jnp.split(bc, [N], axis=-1)
    new_conv = (new_conv_x, new_conv_bc)
    dt_h = jax.nn.softplus(dt_raw.astype(jnp.float32) + bp["dt_bias"])
    A = -jnp.exp(bp["A_log"])
    xh = xc.reshape(B, T, H, P)
    y, new_ssm = _ssd_scan(xh, dt_h, B_in, C_in, A, ssm_state)
    y = y + bp["D"][:, None] * xh.astype(jnp.float32)
    y = y.reshape(B, T, d_inner)
    # gated RMS norm, then out-projection
    g = y * jax.nn.silu(z.astype(jnp.float32))
    var = jnp.mean(jnp.square(g), axis=-1, keepdims=True)
    g = (g * jax.lax.rsqrt(var + 1e-6) * bp["gate_norm"]).astype(x.dtype)
    return g @ bp["out_proj"], (new_conv, new_ssm)


# ------------------------------------------------------------------ model
def init_state(cfg: ModelConfig, batch: int, attn_len: int) -> dict:
    d_inner, H, P, N = dims(cfg)
    L = cfg.n_layers
    K = cfg.ssm_conv
    n_inv = L // cfg.attn_every if cfg.attn_every else 0
    dt = dtype_of(cfg)
    KV, hd = cfg.n_kv_heads, cfg.hd
    return {
        "conv_x": jnp.zeros((L, batch, K - 1, d_inner), dt),
        "conv_bc": jnp.zeros((L, batch, K - 1, 2 * N), dt),
        "ssm": jnp.zeros((L, batch, H, P, N), jnp.float32),
        "attn_k": jnp.zeros((n_inv, batch, attn_len, KV, hd), dt),
        "attn_v": jnp.zeros((n_inv, batch, attn_len, KV, hd), dt),
        "len": jnp.zeros((), jnp.int32),
    }


def _shared_attn_full(x, sp, cfg, positions):
    h = norm(x, sp["ln1"], cfg.norm)
    x = x + attention(h, sp["attn"], cfg, positions)
    h = norm(x, sp["ln2"], cfg.norm)
    return x + mlp(h, sp["mlp"], cfg)


def forward(params, tokens, cfg: ModelConfig, remat=False):
    """Training/prefill forward (no cache plumbing): logits."""
    B, T = tokens.shape
    x = shard_hint(jnp.take(params["embed"], tokens, axis=0),
                   "batch", None, None)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    sp = params["shared"]
    L = cfg.n_layers
    d_inner, H, P, N = dims(cfg)
    K = cfg.ssm_conv
    conv_x0 = jnp.zeros((L, B, K - 1, d_inner), x.dtype)
    conv_bc0 = jnp.zeros((L, B, K - 1, 2 * N), x.dtype)
    ssm0 = jnp.zeros((L, B, H, P, N), jnp.float32)

    def body(x, xs):
        bp, cx_s, cbc_s, ssm_s, idx = xs
        h = norm(x, bp["ln"], cfg.norm)
        o, _ = mamba_mixer(h, bp, cfg, ((cx_s, cbc_s), ssm_s))
        x = x + o
        if cfg.attn_every:
            x = jax.lax.cond((idx + 1) % cfg.attn_every == 0,
                             lambda v: _shared_attn_full(v, sp, cfg, positions),
                             lambda v: v, x)
        return shard_hint(x, "batch", None, None), None

    if remat:
        body = jax.checkpoint(body)
    idxs = jnp.arange(L, dtype=jnp.int32)
    x, _ = jax.lax.scan(body, x,
                        (params["blocks"], conv_x0, conv_bc0, ssm0, idxs))
    x = norm(x, params["final_norm"], cfg.norm)
    return shard_hint(jnp.einsum("btd,dv->btv", x, params["lm_head"]),
                      "batch", None, "model")


def loss_fn(params, batch, cfg: ModelConfig, remat=True):
    tokens = batch["tokens"]
    logits = forward(params, tokens, cfg,
                     remat=remat and cfg.remat)[:, :-1, :].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def prefill(params, tokens, cfg: ModelConfig, max_len: int | None = None):
    """Prefill returning decode state (mamba states + per-invocation KV)."""
    B, T = tokens.shape
    max_len = max_len or cfg.max_seq
    x = jnp.take(params["embed"], tokens, axis=0)
    positions = jnp.broadcast_to(jnp.arange(T, dtype=jnp.int32), (B, T))
    sp = params["shared"]
    state = init_state(cfg, B, max_len)
    L = cfg.n_layers
    KV, hd = cfg.n_kv_heads, cfg.hd

    def body(carry, xs):
        x, ak, av = carry
        bp, cx_s, cbc_s, ssm_s, idx = xs
        h = norm(x, bp["ln"], cfg.norm)
        o, ((cx_n, cbc_n), ssm_n) = mamba_mixer(h, bp, cfg,
                                                ((cx_s, cbc_s), ssm_s))
        x = x + o

        def with_attn(args):
            x, ak, av = args
            from .layers import _project_qkv, _sdpa
            h = norm(x, sp["ln1"], cfg.norm)
            q, k, v = _project_qkv(h, sp["attn"], cfg, positions)
            o = _sdpa(q, k, v, causal=True)
            x = x + jnp.einsum("bsh,hd->bsd", o.reshape(B, T, -1),
                               sp["attn"]["wo"])
            h2 = norm(x, sp["ln2"], cfg.norm)
            x = x + mlp(h2, sp["mlp"], cfg)
            inv = (idx + 1) // cfg.attn_every - 1
            pad = jnp.zeros((B, max_len - T, KV, hd), ak.dtype)
            k_full = jnp.concatenate([k.astype(ak.dtype), pad], axis=1)
            v_full = jnp.concatenate([v.astype(av.dtype), pad], axis=1)
            ak = jax.lax.dynamic_update_slice_in_dim(ak, k_full[None], inv, 0)
            av = jax.lax.dynamic_update_slice_in_dim(av, v_full[None], inv, 0)
            return x, ak, av

        if cfg.attn_every:
            x, ak, av = jax.lax.cond((idx + 1) % cfg.attn_every == 0,
                                     with_attn, lambda a: a, (x, ak, av))
        return (x, ak, av), (cx_n, cbc_n, ssm_n)

    idxs = jnp.arange(L, dtype=jnp.int32)
    (x, ak, av), (cx_f, cbc_f, ssm_f) = jax.lax.scan(
        body, (x, state["attn_k"], state["attn_v"]),
        (params["blocks"], state["conv_x"], state["conv_bc"], state["ssm"],
         idxs))

    x = norm(x, params["final_norm"], cfg.norm)
    logits = jnp.einsum("bd,dv->bv", x[:, -1, :], params["lm_head"])
    return logits, {"conv_x": cx_f, "conv_bc": cbc_f, "ssm": ssm_f,
                    "attn_k": ak, "attn_v": av,
                    "len": jnp.asarray(T, jnp.int32)}


def decode_step(params, cache, tokens, cfg: ModelConfig):
    """One-token decode: O(1) mamba update + cached shared attention."""
    B = tokens.shape[0]
    x = jnp.take(params["embed"], tokens, axis=0)      # (B,1,D)
    sp = params["shared"]
    pos = cache["len"]
    L = cfg.n_layers

    def body(carry, xs):
        x, ak, av = carry
        bp, cx_s, cbc_s, ssm_s, idx = xs
        h = norm(x, bp["ln"], cfg.norm)
        o, ((cx_n, cbc_n), ssm_n) = mamba_mixer(h, bp, cfg,
                                                ((cx_s, cbc_s), ssm_s))
        x = x + o

        def with_attn(args):
            x, ak, av = args
            inv = (idx + 1) // cfg.attn_every - 1
            kc = jax.lax.dynamic_index_in_dim(ak, inv, 0, keepdims=False)
            vc = jax.lax.dynamic_index_in_dim(av, inv, 0, keepdims=False)
            h = norm(x, sp["ln1"], cfg.norm)
            o, new_kv = attention_decode(h, sp["attn"], cfg,
                                         {"k": kc, "v": vc, "len": pos}, pos)
            x = x + o
            h2 = norm(x, sp["ln2"], cfg.norm)
            x = x + mlp(h2, sp["mlp"], cfg)
            ak = jax.lax.dynamic_update_slice_in_dim(ak, new_kv["k"][None], inv, 0)
            av = jax.lax.dynamic_update_slice_in_dim(av, new_kv["v"][None], inv, 0)
            return x, ak, av

        if cfg.attn_every:
            x, ak, av = jax.lax.cond((idx + 1) % cfg.attn_every == 0,
                                     with_attn, lambda a: a, (x, ak, av))
        return (x, ak, av), (cx_n, cbc_n, ssm_n)

    idxs = jnp.arange(L, dtype=jnp.int32)
    (x, ak, av), (cx_f, cbc_f, ssm_f) = jax.lax.scan(
        body, (x, cache["attn_k"], cache["attn_v"]),
        (params["blocks"], cache["conv_x"], cache["conv_bc"], cache["ssm"],
         idxs))

    x = norm(x, params["final_norm"], cfg.norm)
    logits = jnp.einsum("bsd,dv->bsv", x, params["lm_head"])[:, 0, :]
    return logits, {"conv_x": cx_f, "conv_bc": cbc_f, "ssm": ssm_f,
                    "attn_k": ak, "attn_v": av, "len": pos + 1}
