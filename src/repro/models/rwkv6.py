"""RWKV-6 "Finch": attention-free LM with data-dependent per-channel decay
(arXiv:2404.05892).

Time-mix (WKV6) recurrence per head (state S: key-dim x value-dim):

    y_t = r_t^T (S_{t-1} + diag(u) k_t v_t^T)
    S_t = diag(w_t) S_{t-1} + k_t v_t^T,   w_t = exp(-exp(wr_t))  (data-dep.)

The model forward uses an exact ``lax.scan`` over time (compile-time is
T-independent; the production TPU path is the chunked Pallas kernel in
kernels/wkv6.py, which computes intra-chunk interactions in log-space inside
VMEM).  Decode carries (S, token-shift) state — O(1) per token, which is why
rwkv6 runs the ``long_500k`` cell.

Deviations noted in DESIGN.md: token-shift lerp coefficients are static (the
paper's LoRA-produced dynamic lerp is an accuracy refinement orthogonal to the
systems work); decay LoRA is kept because it is the data-dependence itself.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig

from .layers import dtype_of, init_norm, norm, shard_hint

Array = jax.Array
LORA = 64
DECAY_CLAMP = 8.0     # |log w| <= 8 per step: numerics guard for chunked form


def init_rwkv6(cfg: ModelConfig, rng) -> dict:
    D, F, V, L = cfg.d_model, cfg.d_ff, cfg.vocab_size, cfg.n_layers
    hd = cfg.rwkv_head_dim
    H = D // hd
    dt = dtype_of(cfg)
    ks = jax.random.split(rng, 16)
    s = 1.0 / math.sqrt(D)

    def mat(k, *shape, scale=s):
        return (jax.random.normal(k, shape) * scale).astype(dt)

    params = {
        "embed": mat(ks[0], V, D, scale=0.02),
        "lm_head": mat(ks[1], D, V),
        "final_norm": init_norm(cfg),
        "blocks": {
            "ln1": init_norm(cfg, (L,)),
            "ln2": init_norm(cfg, (L,)),
            # time-mix
            "mu": jnp.full((L, 5, D), 0.5, dt),          # r,k,v,w,g lerps
            "wr": mat(ks[2], L, D, D), "wk": mat(ks[3], L, D, D),
            "wv": mat(ks[4], L, D, D), "wg": mat(ks[5], L, D, D),
            "wo": mat(ks[6], L, D, D),
            "w_bias": jnp.full((L, D), -2.0, jnp.float32),
            "w_lora_a": mat(ks[7], L, D, LORA),
            "w_lora_b": mat(ks[8], L, LORA, D, scale=1.0 / math.sqrt(LORA)),
            "u": (jax.random.normal(ks[9], (L, H, hd)) * 0.1).astype(jnp.float32),
            "gn_scale": jnp.ones((L, H, hd), dt),        # per-head groupnorm
            # channel-mix
            "mu_c": jnp.full((L, 2, D), 0.5, dt),        # k,r lerps
            "ck": mat(ks[10], L, D, F),
            "cv": mat(ks[11], L, F, D, scale=1.0 / math.sqrt(F)),
            "cr": mat(ks[12], L, D, D),
        },
    }
    return params


def _shift(x: Array, prev: Array) -> Array:
    """Token shift: x_{t-1}; position 0 uses ``prev`` (decode carry)."""
    return jnp.concatenate([prev[:, None, :], x[:, :-1, :]], axis=1)


def _decay(xw: Array, bp: dict) -> Array:
    """Data-dependent per-channel log-decay, clamped for chunked numerics."""
    lora = jnp.tanh(xw.astype(jnp.float32) @ bp["w_lora_a"].astype(jnp.float32))
    raw = bp["w_bias"] + lora @ bp["w_lora_b"].astype(jnp.float32)
    return -jnp.clip(jnp.exp(raw), 1e-4, DECAY_CLAMP)      # log w_t  (negative)


def wkv_scan(r, k, v, logw, u, state):
    """Exact recurrence.  r,k,v: (B,T,H,hd); logw: (B,T,H,hd) log-decay;
    u: (H,hd); state: (B,H,hd,hd).  Returns (y (B,T,H,hd), final state)."""
    B, T, H, hd = r.shape

    def step(S, inp):
        r_t, k_t, v_t, lw_t = inp                       # (B,H,hd)
        w_t = jnp.exp(lw_t)
        kv = k_t[..., :, None] * v_t[..., None, :]      # (B,H,hd,hd)
        y = jnp.einsum("bhi,bhij->bhj", r_t,
                       S + u[..., :, None] * kv)
        S = w_t[..., :, None] * S + kv
        return S, y

    rs, ks_, vs, lws = (jnp.moveaxis(a, 1, 0) for a in (r, k, v, logw))
    state, ys = jax.lax.scan(step, state, (rs.astype(jnp.float32),
                                           ks_.astype(jnp.float32),
                                           vs.astype(jnp.float32), lws))
    return jnp.moveaxis(ys, 0, 1), state


def _time_mix(x, bp, cfg, tm_prev, wkv_state):
    """Returns (out, new_tm_shift, new_wkv_state)."""
    B, T, D = x.shape
    hd = cfg.rwkv_head_dim
    H = D // hd
    xs = _shift(x, tm_prev)
    mu = bp["mu"]
    xr, xk, xv, xw, xg = (x + (xs - x) * mu[i] for i in range(5))
    r = (xr @ bp["wr"]).reshape(B, T, H, hd)
    k = (xk @ bp["wk"]).reshape(B, T, H, hd)
    v = (xv @ bp["wv"]).reshape(B, T, H, hd)
    g = jax.nn.silu(xg @ bp["wg"])
    logw = _decay(xw, bp).reshape(B, T, H, hd)
    y, new_state = wkv_scan(r, k, v, logw, bp["u"], wkv_state)
    # per-head group norm
    yf = y.astype(jnp.float32)
    mu_h = yf.mean(-1, keepdims=True)
    var = yf.var(-1, keepdims=True)
    y = ((yf - mu_h) * jax.lax.rsqrt(var + 1e-5) * bp["gn_scale"]
         ).reshape(B, T, D).astype(x.dtype)
    out = (y * g) @ bp["wo"]
    return out, x[:, -1, :], new_state


def _channel_mix(x, bp, cfg, cm_prev):
    xs = _shift(x, cm_prev)
    mu = bp["mu_c"]
    xk = x + (xs - x) * mu[0]
    xr = x + (xs - x) * mu[1]
    kk = jnp.square(jax.nn.relu(xk @ bp["ck"]))
    out = jax.nn.sigmoid(xr @ bp["cr"]) * (kk @ bp["cv"])
    return out, x[:, -1, :]


def _block(x, bp, cfg, state):
    tm_prev, cm_prev, wkv = state
    h = norm(x, bp["ln1"], cfg.norm)
    o, tm_new, wkv_new = _time_mix(h, bp, cfg, tm_prev, wkv)
    x = x + o
    h = norm(x, bp["ln2"], cfg.norm)
    o, cm_new = _channel_mix(h, bp, cfg, cm_prev)
    return x + o, (tm_new, cm_new, wkv_new)


def init_state(cfg: ModelConfig, batch: int) -> tuple:
    D = cfg.d_model
    hd = cfg.rwkv_head_dim
    H = D // hd
    L = cfg.n_layers
    dt = dtype_of(cfg)
    return (jnp.zeros((L, batch, D), dt),                    # time-mix shift
            jnp.zeros((L, batch, D), dt),                    # channel-mix shift
            jnp.zeros((L, batch, H, hd, hd), jnp.float32))   # wkv state


def forward(params, tokens, cfg: ModelConfig, state=None, remat=False):
    """tokens (B,T) -> (logits, final state)."""
    B, T = tokens.shape
    x = shard_hint(jnp.take(params["embed"], tokens, axis=0),
                   "batch", None, None)
    if state is None:
        state = init_state(cfg, B)
    tm0, cm0, wkv0 = state

    def body(x, xs):
        bp, tm, cm, wkv = xs
        x, (tm2, cm2, wkv2) = _block(x, bp, cfg, (tm, cm, wkv))
        return shard_hint(x, "batch", None, None), (tm2, cm2, wkv2)

    if remat:
        body = jax.checkpoint(body)
    x, (tm, cm, wkv) = jax.lax.scan(body, x, (params["blocks"], tm0, cm0, wkv0))
    x = norm(x, params["final_norm"], cfg.norm)
    logits = shard_hint(jnp.einsum("btd,dv->btv", x, params["lm_head"]),
                        "batch", None, "model")
    return logits, (tm, cm, wkv)


def loss_fn(params, batch, cfg: ModelConfig, remat=True):
    tokens = batch["tokens"]
    logits, _ = forward(params, tokens, cfg, remat=remat and cfg.remat)
    logits = logits[:, :-1, :].astype(jnp.float32)
    targets = tokens[:, 1:]
    logz = jax.scipy.special.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    return (logz - gold).mean()


def prefill(params, tokens, cfg: ModelConfig):
    logits, state = forward(params, tokens, cfg)
    return logits[:, -1, :], {"state": state,
                              "len": jnp.asarray(tokens.shape[1], jnp.int32)}


def decode_step(params, cache, tokens, cfg: ModelConfig):
    """O(1) decode: seq_len only sets the position counter (no KV cache)."""
    logits, state = forward(params, tokens, cfg, state=cache["state"])
    return logits[:, -1, :], {"state": state, "len": cache["len"] + 1}
