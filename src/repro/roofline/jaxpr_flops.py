"""Exact program-level FLOP/byte accounting by walking the jaxpr.

XLA:CPU's cost_analysis does not multiply while-loop bodies by trip count, so
a scan-over-layers model reports ~1/L of its real FLOPs.  This walker counts
the *logical* program: dot_general/conv FLOPs, elementwise/reduce ops, with
``scan`` bodies multiplied by length — including rematerialized recompute
(remat shows up as extra equations in the VJP jaxpr), which is exactly what
the MODEL_FLOPS / PROGRAM_FLOPS ratio in the roofline table needs to expose.

Bytes here are "logical traffic": sum of operand+result sizes of every
equation (an un-fused upper bound; the table reports XLA's fused
'bytes accessed' alongside).
"""
from __future__ import annotations

import math
from dataclasses import dataclass, field
from typing import Any

import jax
import numpy as np
from jax import core as jcore


@dataclass
class Counts:
    flops: float = 0.0
    bytes: float = 0.0
    by_prim: dict = field(default_factory=dict)

    def add(self, prim: str, flops: float, nbytes: float) -> None:
        self.flops += flops
        self.bytes += nbytes
        e = self.by_prim.setdefault(prim, [0.0, 0.0])
        e[0] += flops
        e[1] += nbytes


def _size(aval) -> int:
    try:
        return int(np.prod(aval.shape)) if aval.shape else 1
    except (TypeError, ValueError, AttributeError, OverflowError):
        return 0


def _bytes(aval) -> int:
    try:
        return _size(aval) * aval.dtype.itemsize
    except (TypeError, ValueError, AttributeError, OverflowError):
        return 0


_ELEMENTWISE_2X = {"exp", "log", "tanh", "logistic", "erf", "rsqrt", "sqrt",
                   "pow", "integer_pow", "sin", "cos"}
_FREE = {"reshape", "broadcast_in_dim", "transpose", "convert_element_type",
         "squeeze", "slice", "dynamic_slice", "dynamic_update_slice",
         "concatenate", "pad", "gather", "scatter", "iota", "copy",
         "stop_gradient", "rev", "bitcast_convert_type", "split"}


def _dot_flops(eqn) -> float:
    lhs, rhs = eqn.invars[0].aval, eqn.invars[1].aval
    dims = eqn.params["dimension_numbers"]
    (lc, rc), (lb, rb) = dims
    contract = 1
    for d in lc:
        contract *= lhs.shape[d]
    batch = 1
    for d in lb:
        batch *= lhs.shape[d]
    m = _size(lhs) // max(1, contract * batch)
    n = _size(rhs) // max(1, contract * batch)
    return 2.0 * batch * m * n * contract


def count_jaxpr(jaxpr, counts: Counts, mult: float = 1.0) -> None:
    for eqn in jaxpr.eqns:
        prim = eqn.primitive.name
        io_bytes = (sum(_bytes(v.aval) for v in eqn.invars
                        if hasattr(v, "aval"))
                    + sum(_bytes(v.aval) for v in eqn.outvars))

        if prim == "dot_general":
            counts.add(prim, mult * _dot_flops(eqn), mult * io_bytes)
        elif prim == "conv_general_dilated":
            out = eqn.outvars[0].aval
            rhs = eqn.invars[1].aval
            counts.add(prim, mult * 2.0 * _size(out) * _size(rhs)
                       / max(1, rhs.shape[-1]), mult * io_bytes)
        elif prim == "scan":
            length = eqn.params["length"]
            inner = eqn.params["jaxpr"]
            sub = Counts()
            count_jaxpr(inner.jaxpr, sub, 1.0)
            # totals once; breakdown entries bypass the totals accumulator
            counts.flops += mult * length * sub.flops
            counts.bytes += mult * length * sub.bytes
            for p, (f, b) in sub.by_prim.items():
                e = counts.by_prim.setdefault(f"scan/{p}", [0.0, 0.0])
                e[0] += mult * length * f
                e[1] += mult * length * b
        elif prim == "while":
            # trip count unknown statically: count body once (documented)
            inner = eqn.params["body_jaxpr"]
            count_jaxpr(inner.jaxpr, counts, mult)
        elif prim == "cond":
            branches = eqn.params["branches"]
            subs = []
            for br in branches:
                s = Counts()
                count_jaxpr(br.jaxpr, s, 1.0)
                subs.append(s)
            worst = max(subs, key=lambda s: s.flops)
            counts.add("cond", mult * worst.flops, mult * worst.bytes)
        elif prim in ("pjit", "closed_call", "remat2", "custom_jvp_call",
                      "custom_vjp_call", "custom_vjp_call_jaxpr",
                      "checkpoint", "core_call"):
            inner = (eqn.params.get("jaxpr")
                     or eqn.params.get("call_jaxpr")
                     or eqn.params.get("fun_jaxpr"))
            if inner is not None:
                ij = inner.jaxpr if hasattr(inner, "jaxpr") else inner
                count_jaxpr(ij, counts, mult)
        elif prim in _FREE:
            counts.add(prim, 0.0, mult * io_bytes)
        elif prim in _ELEMENTWISE_2X:
            out_sz = sum(_size(v.aval) for v in eqn.outvars)
            counts.add(prim, mult * 2.0 * out_sz, mult * io_bytes)
        elif prim in ("reduce_sum", "reduce_max", "reduce_min", "reduce_prod",
                      "argmax", "argmin", "cumsum", "cumlogsumexp", "cummax",
                      "reduce_and", "reduce_or", "sort", "top_k"):
            in_sz = sum(_size(v.aval) for v in eqn.invars
                        if hasattr(v, "aval"))
            counts.add(prim, mult * in_sz, mult * io_bytes)
        else:
            out_sz = sum(_size(v.aval) for v in eqn.outvars)
            counts.add(prim, mult * out_sz, mult * io_bytes)


def program_counts(fn, *args) -> Counts:
    closed = jax.make_jaxpr(fn)(*args)
    c = Counts()
    count_jaxpr(closed.jaxpr, c)
    return c
