"""Roofline terms from the compiled dry-run artifact.

    compute   = HLO_FLOPs / (chips * peak_FLOP/s)
    memory    = HLO_bytes / (chips * HBM_bw)
    collective= collective_bytes / (chips * link_bw)

cost_analysis() supplies FLOPs / bytes; collective traffic is parsed out of
the optimized HLO text (all-gather / all-reduce / reduce-scatter / all-to-all
/ collective-permute), summing *operand* sizes per the assignment.
"""
from __future__ import annotations

import re
from typing import Optional

from repro.configs.base import ModelConfig, ShapeConfig

PEAK_FLOPS_BF16 = 197e12
HBM_BW = 819e9
ICI_BW = 50e9

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "c128": 16,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  bf16[16,1024,512]  or  f32[]
_SHAPE_RE = re.compile(r"\b(pred|[sub]\d+|bf16|f16|f32|f64|s32|u32|s64|u64)"
                       r"\[([0-9,]*)\]")
_KIND_RE = re.compile(
    r"=\s*((?:\([^)]*\)|\S+))\s+"
    r"(all-gather-start|all-gather|all-reduce-start|all-reduce|"
    r"reduce-scatter|all-to-all|collective-permute-start|collective-permute)"
    r"\(")
_GROUPS_RE = re.compile(r"replica_groups=\[(\d+),(\d+)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


_COMP_HDR_RE = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\{\s*$")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_REF_RES = [
    (re.compile(r"body=%?([\w.\-]+)"), "body"),
    (re.compile(r"condition=%?([\w.\-]+)"), "cond"),
    (re.compile(r"calls=%?([\w.\-]+)"), "call"),
    (re.compile(r"to_apply=%?([\w.\-]+)"), "call"),
    (re.compile(r"(?:branch_computations|called_computations)=\{([^}]*)\}"),
     "branches"),
]


def collective_bytes_from_hlo(hlo: str) -> dict:
    """Census of collective ops from the optimized (post-SPMD) HLO, with
    while-loop trip counts applied (a collective inside a scanned layer body
    executes L times but is printed once).

    Post-optimization HLO prints only the RESULT type inline, so bytes are
    derived from it with ring-algorithm traffic factors per device:
      all-gather:     ~ result              (each device receives O*(n-1)/n)
      all-reduce:     ~ 2 * result          (reduce-scatter + all-gather)
      reduce-scatter: ~ result * group_size (operand = result * n)
      all-to-all / collective-permute: ~ result
    """
    # ---- pass 1: split into computations; collect collectives + call edges
    comps: dict[str, dict] = {}
    cur = None
    entry = None
    for line in hlo.splitlines():
        hdr = _COMP_HDR_RE.match(line)
        if hdr is not None:
            cur = hdr.group(2)
            comps.setdefault(cur, {"coll": [], "edges": []})
            if hdr.group(1):
                entry = cur
            continue
        if cur is None:
            continue
        comp = comps[cur]
        m = _KIND_RE.search(line)
        if m is not None:
            kind = m.group(2).replace("-start", "")
            result_ty = m.group(1)
            nbytes = sum(_shape_bytes(d, s)
                         for d, s in _SHAPE_RE.findall(result_ty))
            g = _GROUPS_RE.search(line)
            group_size = int(g.group(2)) if g else 1
            if kind == "all-reduce":
                traffic = 2.0 * nbytes
            elif kind == "reduce-scatter":
                traffic = float(nbytes) * group_size
            else:
                traffic = float(nbytes)
            comp["coll"].append((kind, traffic))
        trip = None
        tm = _TRIP_RE.search(line)
        if tm:
            trip = int(tm.group(1))
        for ref_re, role in _REF_RES:
            for rm in ref_re.finditer(line):
                if role == "branches":
                    for name in re.findall(r"%?([\w.\-]+)", rm.group(1)):
                        comp["edges"].append((name, 1.0))
                elif role == "body":
                    comp["edges"].append((rm.group(1), float(trip or 1)))
                else:
                    comp["edges"].append((rm.group(1), 1.0))

    # ---- pass 2: propagate multipliers from the entry computation
    mult: dict[str, float] = {}

    def visit(name: str, m: float, depth: int = 0) -> None:
        if name not in comps or depth > 50:
            return
        mult[name] = mult.get(name, 0.0) + m
        for child, f in comps[name]["edges"]:
            visit(child, m * f, depth + 1)

    visit(entry or next(iter(comps), ""), 1.0)

    per_kind: dict[str, dict] = {}
    total = 0.0
    for name, comp in comps.items():
        m = mult.get(name, 0.0)
        if m == 0.0 or not comp["coll"]:
            continue
        for kind, traffic in comp["coll"]:
            k = per_kind.setdefault(kind, {"count": 0, "bytes": 0.0})
            k["count"] += m
            k["bytes"] += traffic * m
            total += traffic * m
    return {"per_kind": per_kind, "total_bytes": total}


def model_flops(cfg: ModelConfig, shape: ShapeConfig) -> float:
    """MODEL_FLOPS = 6*N*D (train) / 2*N*D (inference) with N = active params
    and D = tokens processed by the step."""
    n = cfg.n_active_params()
    if shape.kind == "train":
        tokens = shape.global_batch * shape.seq_len
        return 6.0 * n * tokens
    if shape.kind == "prefill":
        tokens = shape.global_batch * shape.seq_len
        return 2.0 * n * tokens
    tokens = shape.global_batch          # one new token per request
    return 2.0 * n * tokens


def roofline_terms(*, flops: float, hlo_bytes: float, collective_bytes: float,
                   n_devices: int, cfg: Optional[ModelConfig] = None,
                   shape: Optional[ShapeConfig] = None) -> dict:
    compute_s = flops / (n_devices * PEAK_FLOPS_BF16)
    memory_s = hlo_bytes / (n_devices * HBM_BW)
    collective_s = collective_bytes / (n_devices * ICI_BW)
    terms = {"compute_s": compute_s, "memory_s": memory_s,
             "collective_s": collective_s}
    dom = max(terms, key=terms.get)
    out = {**terms, "dominant": dom,
           "bound_s": terms[dom],
           "roofline_fraction": terms["compute_s"] / max(
               1e-30, max(terms.values()))}
    if cfg is not None and shape is not None:
        mf = model_flops(cfg, shape)
        out["model_flops"] = mf
        out["useful_flop_ratio"] = mf / max(flops, 1.0)
    return out
