"""Pure-jnp oracles for every kernel — the ground truth the Pallas kernels
are allclose-tested against (tests/test_kernels.py sweeps shapes/dtypes)."""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp


def flash_attention_ref(q, k, v, *, causal: bool = True):
    """q: (B,H,Sq,hd); k,v: (B,KV,Skv,hd) -> (B,H,Sq,hd)."""
    B, H, Sq, hd = q.shape
    KV, Skv = k.shape[1], k.shape[2]
    G = H // KV
    k = jnp.repeat(k, G, axis=1)
    v = jnp.repeat(v, G, axis=1)
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) / math.sqrt(hd)
    if causal:
        qpos = jax.lax.broadcasted_iota(jnp.int32, (Sq, Skv), 0)
        kpos = jax.lax.broadcasted_iota(jnp.int32, (Sq, Skv), 1)
        s = jnp.where(qpos >= kpos, s, -jnp.inf)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", w,
                      v.astype(jnp.float32)).astype(q.dtype)


def wkv6_ref(r, k, v, logw, u):
    """Exact sequential recurrence.  r,k,v,logw: (B,H,T,hd); u: (H,hd)."""
    B, H, T, hd = r.shape

    def step(S, inp):
        r_t, k_t, v_t, lw_t = inp                 # (B,H,hd)
        kv = k_t[..., :, None] * v_t[..., None, :]
        y = jnp.einsum("bhi,bhij->bhj", r_t, S + u[..., :, None] * kv)
        S = jnp.exp(lw_t)[..., :, None] * S + kv
        return S, y

    xs = tuple(jnp.moveaxis(a.astype(jnp.float32), 2, 0)
               for a in (r, k, v, logw))
    S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
    _, ys = jax.lax.scan(step, S0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(r.dtype)


def ssd_scan_ref(x, dt, B_in, C_in, A):
    """Exact sequential SSD.  x: (B,H,T,P); dt: (B,H,T); B/C: (B,T,N); A: (H,)."""
    Bsz, H, T, P = x.shape

    def step(h, inp):
        x_t, dt_t, b_t, c_t = inp                 # (B,H,P),(B,H),(B,N),(B,N)
        decay = jnp.exp(A[None, :] * dt_t)[..., None, None]
        upd = dt_t[..., None, None] * x_t[..., :, None] * b_t[:, None, None, :]
        h = decay * h + upd
        y = jnp.einsum("bhpn,bn->bhp", h, c_t)
        return h, y

    xs = (jnp.moveaxis(x.astype(jnp.float32), 2, 0),
          jnp.moveaxis(dt.astype(jnp.float32), 2, 0),
          jnp.moveaxis(B_in.astype(jnp.float32), 1, 0),
          jnp.moveaxis(C_in.astype(jnp.float32), 1, 0))
    h0 = jnp.zeros((Bsz, H, P, B_in.shape[-1]), jnp.float32)
    _, ys = jax.lax.scan(step, h0, xs)
    return jnp.moveaxis(ys, 0, 2).astype(x.dtype)


def delta_apply_ref(pages, vals, slot_idx, mask, *, additive: bool = False):
    """Sequential masked scatter, one page at a time."""
    def per_page(page, v, s, m):
        def body(u, pg):
            cur = pg[s[u]]
            new = pg[s[u]] + v[u] if additive else v[u]
            return pg.at[s[u]].set(jnp.where(m[u], new, cur))
        return jax.lax.fori_loop(0, v.shape[0], body, page)
    return jax.vmap(per_page)(pages, vals, slot_idx, mask)
