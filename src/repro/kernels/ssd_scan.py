"""Mamba2 SSD chunked Pallas TPU kernel.

Per head (headdim P, state N), scalar decay per step a_t = exp(A*dt_t):
    h_t = a_t h_{t-1} + dt_t x_t B_t^T         h: (P, N)
    y_t = h_t C_t + D x_t                      (D handled by the wrapper)

Chunked dual form per (batch, head, chunk) in VMEM:
  cd_t  = cumsum dt                      (C,)
  L_t   = exp(A cd_t)                    within-chunk decay from chunk start
  inter: y[t] += (L_t h) C_t       ->    (C,N) @ (N,P) with row scaling
  intra: M[t,s] = (C_t . B_s) exp(A (cd_t - cd_s)) dt_s   (s <= t)
         y += M @ x
  carry: h' = exp(A cd_C) h + Σ_s exp(A(cd_C - cd_s)) dt_s x_s B_s^T

Grid last dim walks chunks sequentially; h is VMEM scratch.  The (C,C)
pairwise matrix is per-head scalar-decay — tiny compared to wkv6's (C,C,hd).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_CHUNK = 128


def _ssd_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref, y_ref, h_ref, *,
                chunk: int):
    ti = pl.program_id(2)

    @pl.when(ti == 0)
    def _init():
        h_ref[...] = jnp.zeros_like(h_ref)

    x = x_ref[0, 0].astype(jnp.float32)          # (C, P)
    dt = dt_ref[0, 0].astype(jnp.float32)        # (C,)
    Bm = b_ref[0].astype(jnp.float32)            # (C, N)
    Cm = c_ref[0].astype(jnp.float32)            # (C, N)
    A = a_ref[0].astype(jnp.float32)             # scalar (per head)
    h = h_ref[...]                                # (P, N)

    cd = jnp.cumsum(dt)                           # (C,)
    decay = jnp.exp(A * cd)                       # L_t

    # inter-chunk: y[t] = C_t . (L_t * h)  -> (C,P)
    y_inter = decay[:, None] * jax.lax.dot_general(
        Cm, h, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32)

    # intra-chunk
    scores = jax.lax.dot_general(Cm, Bm, (((1,), (1,)), ((), ())),
                                 preferred_element_type=jnp.float32)  # (C,C)
    pair = jnp.exp(A * (cd[:, None] - cd[None, :]))
    tri = (jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 0)
           >= jax.lax.broadcasted_iota(jnp.int32, (chunk, chunk), 1))
    M = jnp.where(tri, scores * pair, 0.0) * dt[None, :]
    y_intra = jax.lax.dot_general(M, x, (((1,), (0,)), ((), ())),
                                  preferred_element_type=jnp.float32)

    y_ref[0, 0] = (y_inter + y_intra).astype(y_ref.dtype)

    # carry
    w = jnp.exp(A * (cd[-1] - cd)) * dt           # (C,)
    h_new = (jnp.exp(A * cd[-1]) * h
             + jax.lax.dot_general(x * w[:, None], Bm,
                                   (((0,), (0,)), ((), ())),
                                   preferred_element_type=jnp.float32))
    h_ref[...] = h_new


def ssd_scan(x, dt, B_in, C_in, A, *, chunk: int = DEFAULT_CHUNK,
             interpret: bool = False):
    """x: (B, H, T, P); dt: (B, H, T); B_in, C_in: (B, T, N); A: (H,)
    -> y (B, H, T, P)."""
    Bsz, H, T, P = x.shape
    N = B_in.shape[-1]
    chunk = min(chunk, T)
    assert T % chunk == 0
    nt = T // chunk
    kernel = functools.partial(_ssd_kernel, chunk=chunk)
    return pl.pallas_call(
        kernel,
        grid=(Bsz, H, nt),
        in_specs=[
            pl.BlockSpec((1, 1, chunk, P), lambda b, h, t: (b, h, t, 0)),
            pl.BlockSpec((1, 1, chunk), lambda b, h, t: (b, h, t)),
            pl.BlockSpec((1, chunk, N), lambda b, h, t: (b, t, 0)),
            pl.BlockSpec((1, chunk, N), lambda b, h, t: (b, t, 0)),
            pl.BlockSpec((1,), lambda b, h, t: (h,)),
        ],
        out_specs=pl.BlockSpec((1, 1, chunk, P), lambda b, h, t: (b, h, t, 0)),
        out_shape=jax.ShapeDtypeStruct((Bsz, H, T, P), x.dtype),
        scratch_shapes=[pltpu.VMEM((P, N), jnp.float32)],
        interpret=interpret,
    )(x, dt, B_in, C_in, A)
